#!/usr/bin/env bash
# Observability smoke gate: build and run the stats-smoke binary, which
# boots the continuous-batching server on a loopback port, sends a
# generate request plus `{"cmd": "stats"}` control requests, and
# validates the JSON + Prometheus stats surface (required metric
# families, one `# TYPE` per family, monotone counters).  Exits
# non-zero with a diagnostic on any failure.
#
# Usage: scripts/stats_smoke.sh   (from the repo root or anywhere)
set -euo pipefail

cd "$(dirname "$0")/../rust"
exec cargo run --release --quiet --bin stats-smoke
