//! Quickstart: load a teacher, quantize it to dual-binary 2-bit with
//! FDB, and compare perplexity against the full-precision model.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` (build-time python ran once; everything
//! here is pure rust + the AOT XLA executables).

use db_llm::data::TokenStream;
use db_llm::eval::ppl::perplexity;
use db_llm::eval::tables::{make_student, Method, TableOpts};
use db_llm::runtime::{session::load_teacher, Runtime, Session};

fn main() -> anyhow::Result<()> {
    let mut rt = Runtime::open("artifacts")?;
    let opts = TableOpts { windows: 64, dad_batches: 32, ..Default::default() };

    // 1. the full-precision teacher
    let teacher = load_teacher(&rt, "M")?;
    println!(
        "teacher M: {} params",
        db_llm::util::eng(teacher.config.n_params() as f64)
    );
    let fp_session = Session::new(&rt, &teacher)?;
    let stream = TokenStream::load("artifacts/corpus_wiki_eval.tok")?;
    let fp_ppl = perplexity(&mut rt, &fp_session, &stream, opts.windows)?;
    println!("FP16 perplexity (wiki): {fp_ppl:.2}");

    // 2. DB-LLM: FDB split + scale fit + DAD fine-tune (all data-free)
    let student = make_student(&mut rt, "M", Method::DbLlm, &opts, None)?;
    let (s1, s2, avg) = db_llm::eval::QuantPipeline::fdb_sparsity(&student.fdb_layers);
    println!(
        "FDB planes: sparsity b1 {:.1}%  b2 {:.1}%  avg {:.1}%",
        s1 * 100.0,
        s2 * 100.0,
        avg * 100.0
    );
    if let Some((first, last)) = student.dad_trend {
        println!("DAD distillation loss: {first:.4} -> {last:.4}");
    }

    // 3. evaluate the 2-bit student through the same AOT executable
    let q_session = Session::new(&rt, &student.weights)?;
    let q_ppl = perplexity(&mut rt, &q_session, &stream, opts.windows)?;
    println!("DB-LLM W2 perplexity (wiki): {q_ppl:.2}");
    println!(
        "degradation: {:.1}% (2-bit weights, {:.2} effective bits/weight)",
        100.0 * (q_ppl / fp_ppl - 1.0),
        student
            .fdb_layers
            .values()
            .map(|l| db_llm::codec::effective_bits(l).total)
            .sum::<f64>()
            / student.fdb_layers.len() as f64
    );
    Ok(())
}
