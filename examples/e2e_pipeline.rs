//! End-to-end validation driver (DESIGN.md deliverable): exercises the
//! FULL system on the real small workload and reports the paper's
//! headline metric — W2 perplexity vs baselines — proving all layers
//! compose:
//!
//!   python (ran once at `make artifacts`): trained the teacher, lowered
//!     the model + Pallas FDB kernel + DAD gradient graph to HLO;
//!   rust (this program): loads the teacher, collects calibration
//!     activations with the native forward, quantizes with RTN / GPTQ /
//!     OmniQuant / FDB, runs the DAD fine-tuning loop through the AOT
//!     `dad_step` executable (AdamW in rust, gradients from XLA),
//!     evaluates perplexity + a zero-shot suite through the AOT
//!     `fwd_nll` executable, and verifies the Pallas-kernel FDB path
//!     agrees with the dequantized path.
//!
//!     cargo run --release --example e2e_pipeline
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use db_llm::data::{TaskSuite, TokenStream};
use db_llm::eval::ppl::{perplexity, perplexity_native};
use db_llm::eval::tables::{make_student, Method, TableOpts};
use db_llm::eval::zeroshot;
use db_llm::runtime::{session::load_teacher, Runtime, Session};

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut rt = Runtime::open("artifacts")?;
    let tag = "L";
    let opts = TableOpts { windows: 96, dad_batches: 48, ..Default::default() };
    let wiki = TokenStream::load("artifacts/corpus_wiki_eval.tok")?;
    let floor = rt.manifest.corpus_ppl_floor("wiki")?;

    println!("=== DB-LLM end-to-end pipeline (teacher {tag}) ===");
    let teacher = load_teacher(&rt, tag)?;
    println!(
        "[1] teacher loaded: {} params (corpus entropy floor: ppl {floor:.2})",
        db_llm::util::eng(teacher.config.n_params() as f64),
    );

    // cross-check: native rust forward vs AOT XLA executable
    let fp_session = Session::new(&rt, &teacher)?;
    let ppl_xla = perplexity(&mut rt, &fp_session, &wiki, 24)?;
    let ppl_native = perplexity_native(&teacher, &wiki, 24);
    let rel = (ppl_xla - ppl_native).abs() / ppl_native;
    println!(
        "[2] FP forward cross-check: XLA ppl {ppl_xla:.3} vs native ppl {ppl_native:.3} \
         ({:.3}% apart)",
        rel * 100.0
    );
    assert!(rel < 0.01, "layer-2/layer-3 disagreement");

    // headline: the paper's W2 comparison
    println!("[3] W2 quantization grid:");
    let mut results = Vec::new();
    for method in [
        Method::Fp16,
        Method::RtnW2,
        Method::GptqW2,
        Method::OmniW2,
        Method::PbLlm,
        Method::DbLlm,
    ] {
        let student = make_student(&mut rt, tag, method, &opts, None)?;
        let session = Session::new(&rt, &student.weights)?;
        let ppl = perplexity(&mut rt, &session, &wiki, opts.windows)?;
        println!("      {:<16} wiki ppl {ppl:8.3}", method.label());
        if let Some((a, b)) = student.dad_trend {
            println!("      {:<16} DAD loss {a:.4} -> {b:.4}", "");
        }
        results.push((method, ppl, student));
    }
    let fp = results[0].1;
    let dbllm = results.last().unwrap().1;
    let rtn = results[1].1;
    println!(
        "      degradation: RTN {:+.1}%  DB-LLM {:+.1}%",
        100.0 * (rtn / fp - 1.0),
        100.0 * (dbllm / fp - 1.0)
    );

    // zero-shot through the same stack (trimmed item count)
    let mut suite = TaskSuite::standard(rt.manifest.seq_len() + 1)[0].clone();
    suite.n_items = 80;
    let suite = &suite;
    let fp_acc = zeroshot::accuracy(&mut rt, &fp_session, suite, &wiki)?;
    let db_sess = Session::new(&rt, &results.last().unwrap().2.weights)?;
    let db_acc = zeroshot::accuracy(&mut rt, &db_sess, suite, &wiki)?;
    println!(
        "[4] zero-shot ({}): FP {:.1}%  DB-LLM W2 {:.1}%",
        suite.name,
        fp_acc * 100.0,
        db_acc * 100.0
    );

    // bit-serial path: the packed dual-binary matmul agrees with dequant
    let fdb_layers = &results.last().unwrap().2.fdb_layers;
    let name = "layers.0.wq";
    let layer = &fdb_layers[name];
    let mut rng = db_llm::util::Pcg32::seeded(5);
    let x = db_llm::tensor::Matrix::randn(8, layer.din, &mut rng, 1.0);
    let y_bits = layer.matmul(&x);
    let y_deq = x.matmul(&layer.dequant());
    let mut err = 0.0f32;
    for (a, b) in y_bits.data.iter().zip(&y_deq.data) {
        err = err.max((a - b).abs());
    }
    println!("[5] bit-serial vs dequant matmul: max err {err:.2e}");
    assert!(err < 1e-3);

    println!(
        "=== complete in {:.1}s — headline: DB-LLM W2 ppl {dbllm:.3} vs FP {fp:.3} \
         (floor {floor:.2}) ===",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
