//! Serving demo: boots the TCP server with a pool of DB-LLM-quantized
//! engines, drives it with concurrent synthetic clients mixing
//! per-request decode parameters, and prints the latency/throughput
//! metrics — the coordinator story end to end.
//!
//!     cargo run --release --example serve_demo

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use db_llm::coordinator::batcher::BatchPolicy;
use db_llm::coordinator::metrics::Metrics;
use db_llm::coordinator::serve::{serve, Engine, EngineWorker};
use db_llm::eval::tables::{make_student, Method, TableOpts};
use db_llm::runtime::{Runtime, Session};

fn main() -> anyhow::Result<()> {
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let workers = 2;

    // serve on an ephemeral port; each worker builds its own engine
    // inside its thread (PJRT handles are not Send)
    let addr = serve(
        || {
            let mut rt = Runtime::open("artifacts")?;
            let opts = TableOpts { dad_batches: 16, ..Default::default() };
            let student = make_student(&mut rt, "S", Method::DbLlmNoDad, &opts, None)?;
            let vocab = rt.manifest.vocab();
            let session = Session::new(&rt, &student.weights)?;
            eprintln!("engine: DB-LLM-quantized teacher S pinned on device");
            Ok(EngineWorker { rt, engine: Engine::new(session, vocab, 7) })
        },
        "127.0.0.1:0",
        BatchPolicy::default(),
        workers,
        metrics.clone(),
        running.clone(),
    )?;
    println!("server on {addr} ({workers} workers)");

    // concurrent synthetic clients; every request carries its own
    // max_tokens and temperature, so one batch can mix greedy short
    // requests with sampled long ones
    let n_clients = 8;
    let reqs_per_client = 4;
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let addr = addr;
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<(usize, usize)>> {
            // server may still be compiling the engines: retry connect
            let mut stream = loop {
                match TcpStream::connect(addr) {
                    Ok(s) => break s,
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(100)),
                }
            };
            let mut reader = BufReader::new(stream.try_clone()?);
            let mut lens = Vec::new();
            for r in 0..reqs_per_client {
                let prompt: Vec<String> =
                    (0..6).map(|i| ((7 * c + 3 * r + i) % 512).to_string()).collect();
                let max_tokens = 4 + (c + r) % 8; // mixed budgets per batch
                let temperature = if c % 2 == 0 { 0.0 } else { 0.8 };
                writeln!(
                    stream,
                    "{{\"prompt\": [{}], \"max_tokens\": {max_tokens}, \
                     \"temperature\": {temperature}}}",
                    prompt.join(",")
                )?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                let j = db_llm::util::Json::parse(line.trim())?;
                lens.push((j.usize_list("tokens")?.len(), max_tokens));
            }
            Ok(lens)
        }));
    }
    let mut total_tokens = 0usize;
    for h in handles {
        let lens = h.join().expect("client thread")?;
        assert!(
            lens.iter().all(|&(got, want)| got == want),
            "wrong per-request lengths: {lens:?}"
        );
        total_tokens += lens.iter().map(|&(got, _)| got).sum::<usize>();
    }
    println!("{n_clients} clients x {reqs_per_client} requests -> {total_tokens} tokens");
    println!("metrics: {}", metrics.snapshot());
    running.store(false, std::sync::atomic::Ordering::Relaxed);
    Ok(())
}
