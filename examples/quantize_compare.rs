//! Method shoot-out on one teacher: every baseline the paper compares
//! (a Table 1 row-slice), plus storage/cost diagnostics the tables
//! don't show.
//!
//!     cargo run --release --example quantize_compare [teacher] [windows]

use db_llm::data::TokenStream;
use db_llm::eval::ppl::perplexity;
use db_llm::eval::tables::{make_student, Method, TableOpts};
use db_llm::runtime::{Runtime, Session};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tag = args.first().cloned().unwrap_or_else(|| "M".to_string());
    let windows: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);

    let mut rt = Runtime::open("artifacts")?;
    let opts = TableOpts { windows, dad_batches: 48, ..Default::default() };
    let wiki = TokenStream::load("artifacts/corpus_wiki_eval.tok")?;
    let web = TokenStream::load("artifacts/corpus_web_eval.tok")?;

    println!("teacher {tag}: method comparison ({windows} windows)");
    println!(
        "{:<18}{:>10}{:>10}{:>12}{:>14}",
        "method", "wiki", "web", "bits/w", "t_quant(s)"
    );
    for method in Method::main_grid() {
        let t0 = std::time::Instant::now();
        let student = make_student(&mut rt, &tag, method, &opts, None)?;
        let quant_secs = t0.elapsed().as_secs_f64();
        let session = Session::new(&rt, &student.weights)?;
        let p_wiki = perplexity(&mut rt, &session, &wiki, windows)?;
        let p_web = perplexity(&mut rt, &session, &web, windows)?;
        let bits = if method == Method::Fp16 {
            "16".to_string()
        } else if !student.fdb_layers.is_empty() {
            let eff: f64 = student
                .fdb_layers
                .values()
                .map(|l| db_llm::codec::effective_bits(l).total)
                .sum::<f64>()
                / student.fdb_layers.len() as f64;
            format!("{eff:.2}*")
        } else {
            "-".to_string()
        };
        println!(
            "{:<18}{:>10.2}{:>10.2}{:>12}{:>14.1}",
            method.label(),
            p_wiki,
            p_web,
            bits,
            quant_secs
        );
    }
    println!("(* = measured effective bits after entropy coding)");
    Ok(())
}
