"""Make `pytest python/tests/` work from the repo root: the build-time
package (`compile`) lives in this directory."""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
