"""Deviation-Aware Distillation (Eq. 9-11) semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import ModelConfig
from compile import model as M
from compile import quant as Q

TINY = ModelConfig("tiny", d_model=64, n_layers=2, n_heads=4, d_ff=192, vocab=128)


def rand_logits(seed, shape=(2, 8, 128), scale=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(scale * rng.standard_normal(shape), jnp.float32)


def test_entropy_limits():
    v = 128
    uniform = jnp.zeros((1, 1, v))
    assert float(M.entropy(uniform)[0, 0]) == pytest.approx(np.log(v), rel=1e-5)
    peaked = jnp.zeros((1, 1, v)).at[0, 0, 0].set(1e4)
    assert float(M.entropy(peaked)[0, 0]) == pytest.approx(0.0, abs=1e-3)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_soft_ce_gibbs_inequality(seed):
    """CE(t, s) >= H(t), equality iff s == t."""
    t = rand_logits(seed)
    s = rand_logits(seed + 1)
    ce = np.asarray(M.soft_ce(t, s))
    ht = np.asarray(M.entropy(t))
    assert (ce >= ht - 1e-5).all()
    ce_self = np.asarray(M.soft_ce(t, t))
    np.testing.assert_allclose(ce_self, ht, rtol=1e-5, atol=1e-5)


def test_dad_loss_zero_when_matched():
    t = rand_logits(3)
    total, ce, dad = M.dad_losses(t, t, 0.1, 0.1)
    ht = float(np.mean(np.asarray(M.entropy(t))))
    # matched student: CE collapses to teacher entropy, dad ~= H^{1+...}
    assert float(ce) == pytest.approx(ht, rel=1e-4)
    assert float(total) >= float(ce)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), gamma=st.floats(0.0, 1.0))
def test_dad_nonnegative_and_gamma_interpolates(seed, gamma):
    t = rand_logits(seed)
    s = rand_logits(seed + 7)
    total, ce, dad = M.dad_losses(s, t, gamma, 0.1)
    assert float(dad) >= 0.0
    assert float(ce) >= 0.0
    assert float(total) == pytest.approx(0.1 * float(dad) + float(ce), rel=1e-5)


def test_dad_upweights_ambiguous_samples():
    """Positions where the teacher is uncertain must contribute more:
    same CE, higher teacher entropy => higher DAD term (Eq. 10)."""
    v = 128
    # teacher A: confident; teacher B: ambiguous — same student mismatch
    conf = jnp.zeros((1, 1, v)).at[0, 0, 0].set(8.0)
    ambi = jnp.zeros((1, 1, v))  # uniform = max entropy
    student = jnp.zeros((1, 1, v)).at[0, 0, 1].set(4.0)
    _, ce_a, dad_a = M.dad_losses(student, conf, 0.1, 0.1)
    _, ce_b, dad_b = M.dad_losses(student, ambi, 0.1, 0.1)
    # normalize by the CE so we compare pure weighting
    assert float(dad_b) / float(ce_b) > float(dad_a) / float(ce_a)


def test_dad_step_grads_flow_only_to_alphas():
    params = M.init_params(TINY, jax.random.PRNGKey(0))
    frozen, planes, alphas = Q.fdb_quantize_model(params, TINY)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, TINY.vocab, (2, 8)), jnp.int32)
    t_logits = M.forward(params, toks, TINY)
    (total, ce, dad), grads = M.dad_step(
        alphas, planes, frozen, toks, t_logits, TINY, 0.1, 0.1
    )
    assert set(grads.keys()) == set(alphas.keys())
    assert all(g.shape == alphas[k].shape for k, g in grads.items())
    assert float(total) > 0.0
    gnorm = sum(float(jnp.sum(g * g)) for g in grads.values())
    assert np.isfinite(gnorm) and gnorm > 0.0


def test_dad_gradient_descent_reduces_loss():
    """A few SGD steps on alphas must reduce the DAD total loss — the core
    promise of the fine-tuning stage."""
    params = M.init_params(TINY, jax.random.PRNGKey(1))
    frozen, planes, alphas = Q.fdb_quantize_model(params, TINY)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, TINY.vocab, (2, 8)), jnp.int32)
    t_logits = M.forward(params, toks, TINY)

    (l0, _, _), grads = M.dad_step(alphas, planes, frozen, toks, t_logits, TINY, 0.1, 0.1)
    lr = 1e-3
    for _ in range(5):
        alphas = {k: v - lr * grads[k] for k, v in alphas.items()}
        (l1, _, _), grads = M.dad_step(alphas, planes, frozen, toks, t_logits, TINY, 0.1, 0.1)
    assert float(l1) < float(l0)
