"""L2 model semantics: shapes, causality, FDB-forward equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import GROUP_SIZE, ModelConfig
from compile import model as M
from compile import quant as Q

TINY = ModelConfig("tiny", d_model=64, n_layers=2, n_heads=4, d_ff=192, vocab=128)


@pytest.fixture(scope="module")
def params():
    return M.init_params(TINY, jax.random.PRNGKey(0))


def tokens(b, t, seed=0, vocab=128):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, vocab, (b, t)), jnp.int32)


def test_forward_shape(params):
    logits = M.forward(params, tokens(3, 16), TINY)
    assert logits.shape == (3, 16, TINY.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_param_names_cover_params(params):
    assert sorted(M.param_names(TINY)) == sorted(params.keys())
    assert M.param_names(TINY)[0] == "tok_emb"
    assert M.param_names(TINY)[-1] == "head"


def test_causality(params):
    """Changing a future token must not affect earlier logits."""
    t1 = tokens(1, 16, seed=1)
    t2 = t1.at[0, 10].set((t1[0, 10] + 1) % TINY.vocab)
    l1 = M.forward(params, t1, TINY)
    l2 = M.forward(params, t2, TINY)
    np.testing.assert_allclose(l1[0, :10], l2[0, :10], atol=1e-5)
    assert not np.allclose(l1[0, 10:], l2[0, 10:], atol=1e-5)


def test_nll_consistent_with_forward(params):
    tp1 = tokens(2, 17, seed=2)
    nll = M.nll(params, tp1, TINY)
    logits = M.forward(params, tp1[:, :-1], TINY)
    logp = jax.nn.log_softmax(logits, -1)
    ref = -np.take_along_axis(np.asarray(logp), np.asarray(tp1[:, 1:, None]), -1)[..., 0]
    np.testing.assert_allclose(np.asarray(nll), ref, rtol=1e-5, atol=1e-6)
    assert nll.shape == (2, 16)


def test_rope_preserves_norm(params):
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 4, 16))
    cos, sin = M.rope_tables(TINY, jnp.arange(8))
    y = M.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-4,
    )
    # position 0 is the identity rotation
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(x[:, 0]), atol=1e-6)


def test_fdb_forward_pallas_equals_dequant(params):
    """The Pallas-kernel student and the dequant student are the same model."""
    frozen, planes, alphas = Q.fdb_quantize_model(params, TINY)
    quads = {**planes, **alphas}
    t = tokens(2, 16, seed=4)
    lp = M.fdb_forward(frozen, quads, t, TINY, use_pallas=True)
    ld = M.fdb_forward(frozen, quads, t, TINY, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ld), rtol=3e-4, atol=3e-4)


def test_fdb_dequant_model_matches_fdb_forward(params):
    """Running FP forward on dequantized weights == FDB forward."""
    frozen, planes, alphas = Q.fdb_quantize_model(params, TINY)
    deq = Q.fdb_dequant_model(frozen, planes, alphas, TINY)
    t = tokens(2, 12, seed=5)
    l1 = M.forward(deq, t, TINY)
    l2 = M.fdb_forward(frozen, {**planes, **alphas}, t, TINY, use_pallas=False)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)


def test_fdb_student_close_to_teacher(params):
    """2-bit FDB init should stay within a sane logit distance (not collapse)."""
    frozen, planes, alphas = Q.fdb_quantize_model(params, TINY)
    t = tokens(2, 16, seed=6)
    lt = M.forward(params, t, TINY)
    ls = M.fdb_forward(frozen, {**planes, **alphas}, t, TINY, use_pallas=False)
    # untrained weights -> logits are small; just require same magnitude class
    assert float(jnp.mean((lt - ls) ** 2)) < float(jnp.mean(lt ** 2)) + 1.0


def test_collect_linear_inputs(params):
    t = tokens(1, 8, seed=7)
    logits, acts = M.collect_linear_inputs(params, t, TINY)
    ref = M.forward(params, t, TINY)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref), atol=1e-6)
    assert set(acts.keys()) == set(M.linear_param_names(TINY))
    assert acts["layers.0.wq"].shape == (1, 8, TINY.d_model)
    assert acts["layers.0.w_down"].shape == (1, 8, TINY.d_ff)


def test_sample_shapes_and_determinism(params):
    key = jax.random.PRNGKey(11)
    starts = jnp.zeros((4,), jnp.int32)
    s1 = M.sample(params, starts, key, TINY, 12)
    s2 = M.sample(params, starts, key, TINY, 12)
    assert s1.shape == (4, 12)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert (np.asarray(s1) >= 0).all() and (np.asarray(s1) < TINY.vocab).all()
    np.testing.assert_array_equal(np.asarray(s1[:, 0]), np.asarray(starts))


def test_sample_matches_forward_distribution(params):
    """Greedy-ish check: the KV-cache step logits equal full forward logits."""
    key = jax.random.PRNGKey(12)
    starts = jnp.asarray([1, 2, 3, 4], jnp.int32)
    toks = M.sample(params, starts, key, TINY, 10)
    # re-run full forward on the sampled prefix; the sampled token at
    # position p must have nonzero probability under the forward model
    logits = M.forward(params, toks, TINY)
    logp = jax.nn.log_softmax(logits, -1)
    picked = np.take_along_axis(
        np.asarray(logp[:, :-1]), np.asarray(toks[:, 1:, None]), -1
    )
    assert picked.min() > -30.0
