"""FDB arithmetic properties (Eq. 1-7): the splitting math itself."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.configs import GROUP_SIZE
from compile.kernels.ref import (
    fdb_dequant,
    fdb_split,
    rtn2_group_quantize,
    step_split_ref,
)


def rand_w(seed, din=128, dout=96, scale=1.0):
    rng = np.random.default_rng(seed)
    return (scale * rng.standard_normal((din, dout))).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 100.0))
def test_rtn2_error_bound(seed, scale):
    """|w - s·wq| <= s/2 wherever the grid isn't clipped, <= s at the edges."""
    w = rand_w(seed, scale=scale)
    wq, s = rtn2_group_quantize(jnp.asarray(w), GROUP_SIZE)
    se = np.repeat(np.asarray(s), GROUP_SIZE, axis=0)
    err = np.abs(w - np.asarray(wq) * se)
    # s = max|w|/2 so |w| <= 2s; worst clip case is w = +2s vs level 1 -> err s
    assert (err <= se * (1.0 + 1e-4) + 1e-7).all()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_split_levels_are_dual_binary_grid(seed):
    """Dequantized values land exactly on {-s, 0, s, 2s} per group/col."""
    w = rand_w(seed)
    _, s = rtn2_group_quantize(jnp.asarray(w), GROUP_SIZE)
    b1, b2, a1, a2 = fdb_split(jnp.asarray(w), s, GROUP_SIZE)
    w_hat = np.asarray(fdb_dequant(b1, b2, a1, a2, GROUP_SIZE))
    se = np.repeat(np.asarray(s), GROUP_SIZE, axis=0)
    ratio = w_hat / se
    levels = np.unique(np.round(ratio).astype(int))
    assert set(levels.tolist()) <= {-1, 0, 1, 2}
    np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_split_is_nearest_level(seed):
    """Eq. 6-7 assignment == nearest level on the dual-binary grid."""
    w = rand_w(seed)
    _, s = rtn2_group_quantize(jnp.asarray(w), GROUP_SIZE)
    b1, b2, a1, a2 = fdb_split(jnp.asarray(w), s, GROUP_SIZE)
    w_hat = np.asarray(fdb_dequant(b1, b2, a1, a2, GROUP_SIZE))
    se = np.repeat(np.asarray(s), GROUP_SIZE, axis=0)
    # brute-force nearest of the four levels
    grid = np.stack([-se, 0 * se, se, 2 * se])  # [4, in, out]
    idx = np.argmin(np.abs(grid - w[None]), axis=0)
    nearest = np.take_along_axis(grid, idx[None], axis=0)[0]
    # ties (exact midpoints) may go either way; exclude them
    d = np.sort(np.abs(grid - w[None]), axis=0)
    non_tie = (d[1] - d[0]) > 1e-6
    np.testing.assert_allclose(w_hat[non_tie], nearest[non_tie], rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_planes_are_binary(seed):
    w = rand_w(seed)
    _, s = rtn2_group_quantize(jnp.asarray(w), GROUP_SIZE)
    b1, b2, _, _ = fdb_split(jnp.asarray(w), s, GROUP_SIZE)
    for b in (np.asarray(b1), np.asarray(b2)):
        assert set(np.unique(b).tolist()) <= {0.0, 1.0}


def test_split_consistent_with_step_split():
    """fdb_split is literally step_split_ref at α₁=2s, α₂=-s."""
    w = rand_w(7)
    _, s = rtn2_group_quantize(jnp.asarray(w), GROUP_SIZE)
    b1a, b2a, a1, a2 = fdb_split(jnp.asarray(w), s, GROUP_SIZE)
    b1b, b2b = step_split_ref(jnp.asarray(w), a1, a2, GROUP_SIZE)
    np.testing.assert_array_equal(np.asarray(b1a), np.asarray(b1b))
    np.testing.assert_array_equal(np.asarray(b2a), np.asarray(b2b))


def test_sparsity_on_gaussian_exceeds_half():
    """Paper §3.2: average plane sparsity on Gaussian weights > 50%
    (the paper reports >60% on LLaMA-1-7B; for a pure N(0, σ) matrix the
    expected zero fraction is ~62%)."""
    w = rand_w(3, din=512, dout=512)
    _, s = rtn2_group_quantize(jnp.asarray(w), GROUP_SIZE)
    b1, b2, _, _ = fdb_split(jnp.asarray(w), s, GROUP_SIZE)
    sparsity = 1.0 - 0.5 * (float(jnp.mean(b1)) + float(jnp.mean(b2)))
    assert sparsity > 0.55
    # one plane is markedly sparser than the other (paper: w₂ᵇ > 70%;
    # which plane wins depends on the weight distribution's tails — for
    # pure N(0,1) it is the α₁-gated plane, see EXPERIMENTS.md Table 6)
    s1 = 1.0 - float(jnp.mean(b1))
    s2 = 1.0 - float(jnp.mean(b2))
    assert max(s1, s2) > 0.70


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), grow=st.floats(0.5, 2.0))
def test_step_split_tracks_scale_updates(seed, grow):
    """After scaling α, Eq. 6-7 still yields the nearest-grid assignment
    (re-splitting with moved centers can only reduce per-element error
    vs keeping stale planes)."""
    w = rand_w(seed)
    _, s = rtn2_group_quantize(jnp.asarray(w), GROUP_SIZE)
    a1, a2 = 2.0 * s * grow, -s
    b1_new, b2_new = step_split_ref(jnp.asarray(w), a1, a2, GROUP_SIZE)
    w_new = np.asarray(fdb_dequant(b1_new, b2_new, a1, a2, GROUP_SIZE))
    # stale planes from the un-grown scales
    b1_old, b2_old = step_split_ref(jnp.asarray(w), 2.0 * s, -s, GROUP_SIZE)
    w_old = np.asarray(fdb_dequant(b1_old, b2_old, a1, a2, GROUP_SIZE))
    err_new = np.abs(w - w_new)
    err_old = np.abs(w - w_old)
    assert err_new.sum() <= err_old.sum() + 1e-4
