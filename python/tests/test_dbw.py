"""`.dbw` weight-blob format roundtrip (python side)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.dbw import ALIGN, MAGIC, load_dbw, save_dbw


def test_roundtrip_basic(tmp_path):
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b.c": np.zeros((7,), np.float32),
        "scalar": np.asarray(3.5, np.float32).reshape(()),
    }
    p = str(tmp_path / "w.dbw")
    save_dbw(p, {"k": 1, "s": "x"}, tensors)
    cfg, back = load_dbw(p)
    assert cfg == {"k": 1, "s": "x"}
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].shape == tensors[k].shape


@settings(max_examples=15, deadline=None)
@given(
    shapes=st.lists(
        st.lists(st.integers(1, 9), min_size=0, max_size=3), min_size=1, max_size=6
    ),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_property(shapes, seed):
    import tempfile, os

    rng = np.random.default_rng(seed)
    tensors = {
        f"t{i}": rng.standard_normal(shape).astype(np.float32)
        for i, shape in enumerate(map(tuple, shapes))
    }
    tmpdir = tempfile.mkdtemp()
    p = os.path.join(tmpdir, f"w{seed}.dbw")
    save_dbw(p, {"n": len(tensors)}, tensors)
    _, back = load_dbw(p)
    for k, v in tensors.items():
        np.testing.assert_array_equal(back[k], v)


def test_alignment(tmp_path):
    p = str(tmp_path / "w.dbw")
    save_dbw(p, {}, {"a": np.ones((3,), np.float32), "b": np.ones((5,), np.float32)})
    import json, struct

    blob = open(p, "rb").read()
    assert blob[:4] == MAGIC
    (jl,) = struct.unpack_from("<I", blob, 4)
    hdr = json.loads(blob[8 : 8 + jl])
    for e in hdr["tensors"]:
        assert e["offset"] % ALIGN == 0


def test_bad_magic_raises(tmp_path):
    p = str(tmp_path / "bad.dbw")
    open(p, "wb").write(b"NOPE" + b"\0" * 16)
    with pytest.raises(ValueError):
        load_dbw(p)
