"""L1 kernel correctness: Pallas FDB matmul vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/scale magnitudes; every case asserts
allclose against `kernels.ref.fdb_matmul_ref` — the CORE correctness
signal for the Layer-1 contribution.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import GROUP_SIZE
from compile.kernels.fdb import (
    fdb_matmul,
    fdb_matmul_any,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import fdb_dequant, fdb_matmul_ref


def make_case(rng, m, k, n, scale):
    x = rng.standard_normal((m, k)).astype(np.float32)
    w1 = (rng.random((k, n)) > 0.55).astype(np.float32)
    w2 = (rng.random((k, n)) > 0.72).astype(np.float32)
    g = k // GROUP_SIZE
    a1 = (scale * np.abs(rng.standard_normal((g, n)))).astype(np.float32)
    a2 = (-0.5 * scale * np.abs(rng.standard_normal((g, n)))).astype(np.float32)
    return x, w1, w2, a1, a2


@settings(max_examples=20, deadline=None)
@given(
    m_blocks=st.integers(1, 3),
    k_groups=st.integers(1, 4),
    n_blocks=st.integers(1, 2),
    scale=st.floats(1e-3, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_swept(m_blocks, k_groups, n_blocks, scale, seed):
    """Property: kernel == oracle over swept block-aligned shapes/scales."""
    rng = np.random.default_rng(seed)
    m, k, n = 8 * m_blocks, GROUP_SIZE * k_groups, 128 * n_blocks
    x, w1, w2, a1, a2 = make_case(rng, m, k, n, scale)
    y = fdb_matmul(x, w1, w2, a1, a2, group=GROUP_SIZE, bm=8, bn=128)
    ref = fdb_matmul_ref(x, w1, w2, a1, a2, GROUP_SIZE)
    # f32 accumulation error grows with the scale and the K extent
    atol = 2e-5 + 3e-6 * scale * np.sqrt(k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4, atol=atol)


@pytest.mark.parametrize("m,k,n,bm,bn", [
    (64, 64, 128, 64, 128),
    (128, 256, 256, 64, 128),
    (8, 128, 512, 8, 128),
    (256, 192, 128, 64, 128),   # k = 3 groups
])
def test_kernel_matches_ref_shapes(m, k, n, bm, bn):
    rng = np.random.default_rng(m * 7919 + k * 31 + n)
    x, w1, w2, a1, a2 = make_case(rng, m, k, n, 1.0)
    y = fdb_matmul(x, w1, w2, a1, a2, group=GROUP_SIZE, bm=bm, bn=bn)
    ref = fdb_matmul_ref(x, w1, w2, a1, a2, GROUP_SIZE)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_kernel_equals_dequant_matmul():
    """Eq. 8 == x @ (Eq. 4 dequant): the two FDB forms are identical."""
    rng = np.random.default_rng(0)
    x, w1, w2, a1, a2 = make_case(rng, 32, 128, 128, 1.0)
    y = fdb_matmul(x, w1, w2, a1, a2, group=GROUP_SIZE, bm=32, bn=128)
    w_hat = fdb_dequant(jnp.asarray(w1), jnp.asarray(w2), jnp.asarray(a1),
                        jnp.asarray(a2), GROUP_SIZE)
    np.testing.assert_allclose(np.asarray(y), x @ np.asarray(w_hat),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    lead=st.sampled_from([(5,), (2, 7), (3, 1, 4)]),
    k_groups=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_rank_agnostic_wrapper(lead, k_groups, seed):
    """fdb_matmul_any handles arbitrary leading dims + non-block M."""
    rng = np.random.default_rng(seed)
    k, n = GROUP_SIZE * k_groups, 128
    m = int(np.prod(lead))
    x, w1, w2, a1, a2 = make_case(rng, m, k, n, 1.0)
    x = x.reshape(*lead, k)
    y = fdb_matmul_any(x, w1, w2, a1, a2, group=GROUP_SIZE)
    ref = fdb_matmul_ref(jnp.asarray(x), w1, w2, a1, a2, GROUP_SIZE)
    assert y.shape == (*lead, n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_zero_scales_give_zero():
    rng = np.random.default_rng(1)
    x, w1, w2, a1, a2 = make_case(rng, 8, 64, 128, 1.0)
    y = fdb_matmul(x, w1, w2, 0 * a1, 0 * a2, group=GROUP_SIZE, bm=8, bn=128)
    assert np.abs(np.asarray(y)).max() == 0.0


def test_default_blockspec_within_vmem_budget():
    """The chosen default tiling must fit a TPU core's VMEM with headroom
    for double-buffering (DESIGN.md §Perf)."""
    from compile.kernels.fdb import DEFAULT_BM, DEFAULT_BN

    bytes_per_step = vmem_footprint_bytes(DEFAULT_BM, GROUP_SIZE, DEFAULT_BN)
    assert 2 * bytes_per_step < 16 * 1024 * 1024  # double-buffered < 16 MiB


def test_mxu_utilization_estimator():
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert mxu_utilization_estimate(64, 128, 128) == 0.5
    # partial tiles waste lanes
    assert mxu_utilization_estimate(130, 128, 128) < 0.6
