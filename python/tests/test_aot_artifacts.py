"""Artifact contract tests: if `make artifacts` has run, the manifest
and files must satisfy the python↔rust interchange contract.  Skipped
cleanly when artifacts are absent (CI without the build step)."""

import json
import os

import numpy as np
import pytest

from compile import data as D
from compile import model as M
from compile.configs import GROUP_SIZE, MODEL_SIZES, SEQ_LEN, VOCAB_SIZE
from compile.dbw import load_dbw

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_globals(manifest):
    assert manifest["group_size"] == GROUP_SIZE
    assert manifest["vocab"] == VOCAB_SIZE
    assert manifest["seq_len"] == SEQ_LEN
    assert manifest["dad"]["gamma"] == 0.1


def test_every_executable_file_exists(manifest):
    for key, meta in manifest["executables"].items():
        path = os.path.join(ART, meta["file"])
        assert os.path.exists(path), f"{key}: missing {meta['file']}"
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{key}: not HLO text"


def test_param_order_matches_model(manifest):
    for size, cfg in MODEL_SIZES.items():
        meta = manifest["executables"][f"fwd_logits_{size}"]
        assert meta["params"] == M.param_names(cfg)
        frozen, quads = M.fdb_param_names(cfg)
        fmeta = manifest["executables"][f"fwd_fdb_nll_{size}"]
        assert fmeta["frozen"] == frozen
        assert fmeta["quads"] == quads
        dmeta = manifest["executables"][f"dad_step_{size}"]
        assert dmeta["alphas"] == [n for n in quads if n.endswith((".a1", ".a2"))]


def test_teacher_checkpoints_load_and_match_config(manifest):
    for tag, tinfo in manifest["teachers"].items():
        cfg_dict, tensors = load_dbw(os.path.join(ART, tinfo["dbw"]))
        cfg = MODEL_SIZES[tinfo["size"]]
        assert cfg_dict["d_model"] == cfg.d_model
        assert set(tensors) == set(M.param_names(cfg))
        assert tensors["tok_emb"].shape == (cfg.vocab, cfg.d_model)
        # weights are trained, not init noise: rmsnorm gains moved off 1
        gains = tensors["final_norm"]
        assert np.abs(gains - 1.0).max() > 1e-3


def test_calib_streams_valid(manifest):
    for tag, tinfo in manifest["teachers"].items():
        toks = D.load_tokens(os.path.join(ART, tinfo["calib"]))
        assert len(toks) == tinfo["calib_seqs"] * SEQ_LEN
        assert toks.max() < VOCAB_SIZE


def test_eval_streams_match_config(manifest):
    for name, cinfo in manifest["corpora"].items():
        toks = D.load_tokens(os.path.join(ART, cinfo["eval_file"]))
        assert len(toks) == cinfo["eval_tokens"]
        assert toks.max() < VOCAB_SIZE
        # long-tail marginal: head eighth dominates tail eighth
        counts = np.bincount(toks, minlength=VOCAB_SIZE)
        assert counts[: VOCAB_SIZE // 8].sum() > 3 * counts[-VOCAB_SIZE // 8 :].sum()


def test_teacher_beats_unigram_baseline(manifest):
    # recorded eval ppl must beat the unigram entropy of its corpus by a
    # clear margin (the teachers learned the bigram structure)
    for tag, tinfo in manifest["teachers"].items():
        assert tinfo["eval_ppl"]["wiki"] < 40.0
        floor = manifest["corpora"]["wiki"]["ppl_floor"]
        assert tinfo["eval_ppl"]["wiki"] > floor * 0.95
