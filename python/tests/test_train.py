"""Training-loop substrate tests (tiny, fast)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import train as T
from compile.configs import CorpusConfig, ModelConfig, TeacherSpec, TrainConfig


def test_adamw_converges_quadratic():
    params = {"x": jnp.zeros(3)}
    target = jnp.asarray([1.0, -2.0, 0.5])
    state = T.adamw_init(params)
    for _ in range(300):
        grads = {"x": 2 * (params["x"] - target)}
        params, state = T.adamw_update(params, grads, state, 0.05, wd=0.0)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target), atol=0.05)


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, gn = T.clip_by_global_norm(grads, 1.0)
    assert float(gn) == 5.0
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-5)
    same, _ = T.clip_by_global_norm(grads, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0, 4.0], rtol=1e-6)


def test_lr_schedule_shape():
    lrs = [float(T.lr_schedule(s, 1.0, 10, 100)) for s in range(100)]
    assert lrs[0] < lrs[9]                     # warmup rises
    assert abs(lrs[9] - 1.0) < 0.15            # reaches base
    assert lrs[-1] < 0.2                       # cosine decays
    assert lrs[-1] >= 0.09                     # floor at 10%


def test_short_training_reduces_loss():
    cfg = ModelConfig("t", d_model=64, n_layers=2, n_heads=4, d_ff=192, vocab=512)
    ccfg = CorpusConfig("t", seed=5, zipf_s=1.05, bigram_mix=0.6, train_tokens=1 << 15)
    stream = D.sample_stream(ccfg, ccfg.train_tokens)
    spec = TeacherSpec("t", "S", TrainConfig(steps=25, batch=8, lr=3e-3, seed=3))
    # monkey-build: train on size S config with our tiny streams
    params, history = T.train_teacher(spec, {"wiki": stream, "web": stream}, log=lambda s: None)
    first = history[0][1]
    last = history[-1][1]
    assert last < first - 0.5, f"loss {first} -> {last}"
    del cfg, params
