"""Synthetic corpus generator properties."""

import numpy as np
import pytest

from compile import data as D
from compile.configs import CORPORA, VOCAB_SIZE, CorpusConfig

SMALL = CorpusConfig("t", seed=42, zipf_s=1.05, bigram_mix=0.6, train_tokens=1 << 14)


def test_transition_matrix_is_stochastic():
    t = D.transition_matrix(SMALL)
    assert t.shape == (VOCAB_SIZE, VOCAB_SIZE)
    np.testing.assert_allclose(t.sum(axis=1), 1.0, rtol=1e-9)
    assert (t >= 0).all()


def test_stream_deterministic():
    s1 = D.sample_stream(SMALL, 4096)
    s2 = D.sample_stream(SMALL, 4096)
    np.testing.assert_array_equal(s1, s2)
    s3 = D.sample_stream(SMALL, 4096, seed_offset=1)
    assert not np.array_equal(s1, s3)


def test_stream_range_and_dtype():
    s = D.sample_stream(SMALL, 1000)
    assert s.dtype == np.uint16
    assert len(s) == 1000
    assert s.max() < VOCAB_SIZE


def test_unigram_is_long_tailed():
    """Head tokens (low ids) must dominate — the Zipf property Fig. 6 uses."""
    s = D.sample_stream(SMALL, 1 << 16)
    counts = np.bincount(s, minlength=VOCAB_SIZE)
    head = counts[: VOCAB_SIZE // 8].sum()
    tail = counts[-VOCAB_SIZE // 8 :].sum()
    assert head > 4 * tail


def test_entropy_floor_sane():
    for cfg in CORPORA.values():
        h = D.markov_entropy_bits(cfg)
        assert 1.0 < h < np.log2(VOCAB_SIZE)
        # structure must buy something real vs the uniform ceiling
        assert 2.0 ** h < VOCAB_SIZE / 4


def test_wiki_more_structured_than_web():
    """'wiki' (higher bigram mix) must have a lower entropy floor than 'web'."""
    assert D.markov_entropy_bits(CORPORA["wiki"]) < D.markov_entropy_bits(CORPORA["web"])


def test_token_file_roundtrip(tmp_path):
    s = D.sample_stream(SMALL, 2048)
    p = str(tmp_path / "x.tok")
    D.save_tokens(p, s)
    np.testing.assert_array_equal(D.load_tokens(p), s)


def test_batch_iterator_shapes():
    s = D.sample_stream(SMALL, 8192)
    rng = np.random.default_rng(0)
    it = D.batch_iterator(s, 4, 65, rng)
    b = next(it)
    assert b.shape == (4, 65)
    assert b.dtype == np.int32
    # windows are contiguous slices of the stream
    row = b[0]
    pos = np.where((s[None, : len(s) - 65] == row[0]))[1]
    assert any((s[p : p + 65] == row).all() for p in pos)
