"""Layer-2: the LLaMA-style decoder-only transformer in pure JAX.

This module defines everything the AOT path lowers to HLO:

* `forward`            — FP forward (teacher + dequantized students):
                         weights are *function parameters*, so the same
                         executable serves the teacher and any student
                         whose weights rust dequantizes.
* `nll`                — per-token negative log-likelihood (perplexity).
* `fdb_forward`        — the FDB student: every linear runs the Layer-1
                         Pallas dual-binary kernel (Eq. 8).
* `dad_losses`/`dad_step` — Deviation-Aware Distillation (Eq. 9-11) with
                         gradients w.r.t. the FDB scales only.
* `sample`             — KV-cached ancestral sampler (data-free
                         calibration set generation, LLM-QAT style).

Parameters are a flat `{name: array}` dict; `param_names(cfg)` fixes the
order used by every HLO export and recorded in the manifest, so the rust
runtime can marshal positionally.

Weight convention: linear weights are [in, out] (y = x @ W).
"""

import functools

import jax
import jax.numpy as jnp

from .configs import GROUP_SIZE, ModelConfig
from .kernels.fdb import fdb_matmul_any
from .kernels.ref import fdb_dequant

# The seven quantizable linears of each block, in canonical order.
LINEAR_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def param_names(cfg: ModelConfig) -> "list[str]":
    """Canonical flat parameter order (manifest + HLO argument order)."""
    names = ["tok_emb"]
    for i in range(cfg.n_layers):
        names.append(f"layers.{i}.attn_norm")
        names += [f"layers.{i}.{n}" for n in ("wq", "wk", "wv", "wo")]
        names.append(f"layers.{i}.mlp_norm")
        names += [f"layers.{i}.{n}" for n in ("w_gate", "w_up", "w_down")]
    names += ["final_norm", "head"]
    return names


def linear_param_names(cfg: ModelConfig) -> "list[str]":
    """The quantizable subset of `param_names` (order preserved)."""
    return [
        f"layers.{i}.{n}" for i in range(cfg.n_layers) for n in LINEAR_NAMES
    ]


def linear_shape(cfg: ModelConfig, name: str) -> "tuple[int, int]":
    """[in, out] shape of a quantizable linear."""
    d, f = cfg.d_model, cfg.d_ff
    base = name.rsplit(".", 1)[-1]
    return {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "w_gate": (d, f), "w_up": (d, f), "w_down": (f, d),
    }[base]


def init_params(cfg: ModelConfig, key: jax.Array) -> "dict[str, jnp.ndarray]":
    """Scaled-Gaussian init (GPT-2 style residual scaling)."""
    params = {}
    keys = iter(jax.random.split(key, 8 * cfg.n_layers + 8))
    std = 0.02 + 0.02 * (64 / cfg.d_model) ** 0.5
    resid_scale = 1.0 / (2.0 * cfg.n_layers) ** 0.5

    def gauss(shape, scale=1.0):
        return scale * std * jax.random.normal(next(keys), shape, jnp.float32)

    params["tok_emb"] = gauss((cfg.vocab, cfg.d_model))
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        params[p + "attn_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        params[p + "wq"] = gauss((cfg.d_model, cfg.d_model))
        params[p + "wk"] = gauss((cfg.d_model, cfg.d_model))
        params[p + "wv"] = gauss((cfg.d_model, cfg.d_model))
        params[p + "wo"] = gauss((cfg.d_model, cfg.d_model), resid_scale)
        params[p + "mlp_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        params[p + "w_gate"] = gauss((cfg.d_model, cfg.d_ff))
        params[p + "w_up"] = gauss((cfg.d_model, cfg.d_ff))
        params[p + "w_down"] = gauss((cfg.d_ff, cfg.d_model), resid_scale)
    params["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    params["head"] = gauss((cfg.d_model, cfg.vocab))
    return params


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, gain: jnp.ndarray, eps: float) -> jnp.ndarray:
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def rope_tables(cfg: ModelConfig, positions: jnp.ndarray):
    """(cos, sin) tables [T, head_dim/2] for the given positions."""
    hd = cfg.head_dim
    inv = cfg.rope_theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    ang = positions[:, None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x [B, T, H, hd] -> rotated (pairs (0,1),(2,3),…)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    ro = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return ro.reshape(x.shape)


def _attention(q, k, v, cfg: ModelConfig):
    """Causal SDPA. q,k,v [B, T, H, hd] -> [B, T, H*hd]."""
    b, t, h, hd = q.shape
    scale = hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return ctx.reshape(b, t, h * hd)


def _block(x, p, prefix, cfg: ModelConfig, matmul):
    """One transformer block; `matmul(name, x)` performs the linear."""
    b, t, d = x.shape
    h = rmsnorm(x, p[prefix + "attn_norm"], cfg.rmsnorm_eps)
    q = matmul(prefix + "wq", h).reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = matmul(prefix + "wk", h).reshape(b, t, cfg.n_heads, cfg.head_dim)
    v = matmul(prefix + "wv", h).reshape(b, t, cfg.n_heads, cfg.head_dim)
    cos, sin = rope_tables(cfg, jnp.arange(t))
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    ctx = _attention(q, k, v, cfg)
    x = x + matmul(prefix + "wo", ctx)
    h = rmsnorm(x, p[prefix + "mlp_norm"], cfg.rmsnorm_eps)
    gate = jax.nn.silu(matmul(prefix + "w_gate", h))
    up = matmul(prefix + "w_up", h)
    x = x + matmul(prefix + "w_down", gate * up)
    return x


def forward(params, tokens, cfg: ModelConfig) -> jnp.ndarray:
    """FP forward: tokens [B, T] int32 -> logits [B, T, vocab]."""
    matmul = lambda name, x: x @ params[name]
    x = params["tok_emb"][tokens]
    for i in range(cfg.n_layers):
        x = _block(x, params, f"layers.{i}.", cfg, matmul)
    x = rmsnorm(x, params["final_norm"], cfg.rmsnorm_eps)
    return x @ params["head"]


def nll(params, tokens_p1, cfg: ModelConfig) -> jnp.ndarray:
    """Per-token NLL: tokens_p1 [B, T+1] -> nll [B, T] (nats)."""
    logits = forward(params, tokens_p1[:, :-1], cfg)
    targets = tokens_p1[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


def collect_linear_inputs(params, tokens, cfg: ModelConfig):
    """Forward that also returns each quantizable linear's input.

    Returns (logits, {linear_name: [B, T, in]}).  Build-time only — the
    rust GPTQ/AWQ calibration path uses its own native forward; this
    exists for cross-validation tests between the two.
    """
    acts = {}

    def matmul(name, x):
        acts[name] = x
        return x @ params[name]

    x = params["tok_emb"][tokens]
    for i in range(cfg.n_layers):
        x = _block(x, params, f"layers.{i}.", cfg, matmul)
    x = rmsnorm(x, params["final_norm"], cfg.rmsnorm_eps)
    return x @ params["head"], acts


# --------------------------------------------------------------------------
# FDB student
# --------------------------------------------------------------------------

def fdb_param_names(cfg: ModelConfig):
    """(frozen_names, quad_names): quad = 4 tensors per quantized linear.

    quad order per linear: b1 [in,out], b2 [in,out], a1 [g,out], a2 [g,out].
    """
    lin = linear_param_names(cfg)
    frozen = [n for n in param_names(cfg) if n not in set(lin)]
    quads = []
    for n in lin:
        quads += [n + ".b1", n + ".b2", n + ".a1", n + ".a2"]
    return frozen, quads


def fdb_forward(frozen, quads, tokens, cfg: ModelConfig, *, use_pallas: bool):
    """FDB forward. frozen/quads are {name: array} dicts.

    use_pallas=True  -> every linear runs the Layer-1 kernel (Eq. 8);
                        this is what `fwd_fdb_nll` exports.
    use_pallas=False -> dequantize-then-matmul (mathematically identical,
                        differentiable w.r.t. scales) — the DAD path.
    """

    def matmul(name, x):
        if name in frozen:
            return x @ frozen[name]
        b1 = quads[name + ".b1"]
        b2 = quads[name + ".b2"]
        a1 = quads[name + ".a1"]
        a2 = quads[name + ".a2"]
        if use_pallas:
            return fdb_matmul_any(x, b1, b2, a1, a2, group=GROUP_SIZE)
        w_hat = fdb_dequant(b1, b2, a1, a2, GROUP_SIZE)
        return x @ w_hat

    p = dict(frozen)
    x = frozen["tok_emb"][tokens]
    for i in range(cfg.n_layers):
        x = _block(x, p, f"layers.{i}.", cfg, matmul)
    x = rmsnorm(x, frozen["final_norm"], cfg.rmsnorm_eps)
    return x @ frozen["head"]


def fdb_nll(frozen, quads, tokens_p1, cfg: ModelConfig, *, use_pallas: bool):
    """Per-token NLL through the FDB student."""
    logits = fdb_forward(frozen, quads, tokens_p1[:, :-1], cfg, use_pallas=use_pallas)
    targets = tokens_p1[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


# --------------------------------------------------------------------------
# Deviation-Aware Distillation (Eq. 9-11)
# --------------------------------------------------------------------------

def entropy(logits: jnp.ndarray) -> jnp.ndarray:
    """H(P) per position, nats (Eq. 9)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


def soft_ce(teacher_logits: jnp.ndarray, student_logits: jnp.ndarray) -> jnp.ndarray:
    """ℓ_CE(Pᵗ, Pˢ) per position: -Σ pᵗ log pˢ (data-free soft targets)."""
    pt = jax.nn.softmax(teacher_logits, axis=-1)
    logps = jax.nn.log_softmax(student_logits, axis=-1)
    return -jnp.sum(pt * logps, axis=-1)


def dad_losses(student_logits, teacher_logits, gamma, lam):
    """(total, ce_mean, dad_mean) per Eq. 10-11.

    ℓ_DAD = H(Pᵗ)^γ · H(Pˢ)^(1-γ) · ℓ_CE(Pᵗ,Pˢ)   (per position)
    ℓ_total = λ·mean(ℓ_DAD) + mean(ℓ_CE)
    """
    ht = entropy(teacher_logits)
    hs = entropy(student_logits)
    ce = soft_ce(teacher_logits, student_logits)
    eps = 1e-6
    dad = (ht + eps) ** gamma * (hs + eps) ** (1.0 - gamma) * ce
    ce_mean = jnp.mean(ce)
    dad_mean = jnp.mean(dad)
    return lam * dad_mean + ce_mean, ce_mean, dad_mean


def dad_step(alphas, planes, frozen, tokens, teacher_logits, cfg: ModelConfig,
             gamma, lam):
    """One DAD evaluation: ((total, ce, dad), grads-w.r.t.-alphas).

    alphas: {"<lin>.a1"/".a2": [g,out]} — the only trainable leaves.
    planes: {"<lin>.b1"/".b2": [in,out]} — frozen {0,1} planes.
    The AOT export lowers exactly this (value_and_grad over `alphas`);
    rust/src/coordinator/finetune.rs runs the AdamW loop around it.
    gamma/lam are traced scalars so the γ-sweep (Table 4) reuses one
    executable.
    """

    def loss_fn(alphas_):
        quads = dict(planes)
        quads.update(alphas_)
        logits = fdb_forward(frozen, quads, tokens, cfg, use_pallas=False)
        total, ce, dad = dad_losses(logits, teacher_logits, gamma, lam)
        return total, (ce, dad)

    (total, (ce, dad)), grads = jax.value_and_grad(loss_fn, has_aux=True)(alphas)
    return (total, ce, dad), grads


# --------------------------------------------------------------------------
# sampling (data-free calibration generation)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "length", "temperature"))
def sample(params, start_tokens, key, cfg: ModelConfig, length: int,
           temperature: float = 1.0):
    """Ancestral sampling with a KV cache.

    start_tokens [B] int32 -> tokens [B, length] (first column =
    start_tokens).  Used at build time to synthesize the data-free
    calibration set from each teacher (LLM-QAT recipe) and by the
    prediction-distribution studies (Fig. 6).
    """
    b = start_tokens.shape[0]
    h, hd, nl = cfg.n_heads, cfg.head_dim, cfg.n_layers

    def step_logits(p, tok, kcache, vcache, pos):
        """One-token forward; caches are [nl, B, length, h, hd]."""
        x = p["tok_emb"][tok][:, None, :]  # [B,1,d]
        cos, sin = rope_tables(cfg, jnp.array([pos]))
        kc_new = kcache
        vc_new = vcache
        for i in range(nl):
            pre = f"layers.{i}."
            hin = rmsnorm(x, p[pre + "attn_norm"], cfg.rmsnorm_eps)
            q = (hin @ p[pre + "wq"]).reshape(b, 1, h, hd)
            k = (hin @ p[pre + "wk"]).reshape(b, 1, h, hd)
            v = (hin @ p[pre + "wv"]).reshape(b, 1, h, hd)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
            kc_new = jax.lax.dynamic_update_slice(kc_new, k[None], (i, 0, pos, 0, 0))
            vc_new = jax.lax.dynamic_update_slice(vc_new, v[None], (i, 0, pos, 0, 0))
            mask = (jnp.arange(length) <= pos)[None, None, None, :]
            scores = jnp.einsum("bqhd,bkhd->bhqk", q, kc_new[i]) * hd ** -0.5
            scores = jnp.where(mask, scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1)
            ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, vc_new[i]).reshape(b, 1, h * hd)
            x = x + ctx @ p[pre + "wo"]
            hin = rmsnorm(x, p[pre + "mlp_norm"], cfg.rmsnorm_eps)
            x = x + (jax.nn.silu(hin @ p[pre + "w_gate"]) * (hin @ p[pre + "w_up"])) @ p[pre + "w_down"]
        x = rmsnorm(x, p["final_norm"], cfg.rmsnorm_eps)
        return (x @ p["head"])[:, 0, :], kc_new, vc_new

    kc0 = jnp.zeros((nl, b, length, h, hd), jnp.float32)
    vc0 = jnp.zeros_like(kc0)

    def body(carry, pos):
        tok, kc, vc, key_ = carry
        logits, kc, vc = step_logits(params, tok, kc, vc, pos)
        key_, sub = jax.random.split(key_)
        nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
        nxt = nxt.astype(jnp.int32)
        return (nxt, kc, vc, key_), tok

    (_, _, _, _), toks = jax.lax.scan(
        body, (start_tokens.astype(jnp.int32), kc0, vc0, key), jnp.arange(length)
    )
    return jnp.transpose(toks, (1, 0))  # [B, length]
