"""Python-side whole-model FDB construction (build-time / test oracle).

The production quantizer lives in rust (`rust/src/quant/fdb.rs`); this
mirror exists so (a) the AOT export has concrete example arguments with
the exact shapes/dtypes, (b) python tests can check the rust pipeline's
artifacts against an independent implementation, and (c) Fig. 3/4-style
analyses can be cross-validated.
"""

import jax.numpy as jnp

from .configs import GROUP_SIZE, ModelConfig
from .kernels.ref import fdb_dequant, fdb_split, rtn2_group_quantize
from .model import linear_param_names


def fdb_quantize_model(params: dict, cfg: ModelConfig, group: int = GROUP_SIZE):
    """Split every quantizable linear into FDB quads.

    Returns (frozen, planes, alphas):
      frozen: non-quantized params (embeddings, norms, head)
      planes: {"<lin>.b1"/".b2": {0,1} f32 [in,out]}
      alphas: {"<lin>.a1"/".a2": f32 [in/group, out]}
    """
    lin = set(linear_param_names(cfg))
    frozen, planes, alphas = {}, {}, {}
    for name, w in params.items():
        if name not in lin:
            frozen[name] = w
            continue
        _, s = rtn2_group_quantize(w, group)
        b1, b2, a1, a2 = fdb_split(w, s, group)
        planes[name + ".b1"] = b1
        planes[name + ".b2"] = b2
        alphas[name + ".a1"] = a1
        alphas[name + ".a2"] = a2
    return frozen, planes, alphas


def fdb_dequant_model(frozen: dict, planes: dict, alphas: dict, cfg: ModelConfig,
                      group: int = GROUP_SIZE):
    """Reassemble a full fp param dict from FDB pieces (ŵ per Eq. 4)."""
    params = dict(frozen)
    for name in linear_param_names(cfg):
        params[name] = fdb_dequant(
            planes[name + ".b1"], planes[name + ".b2"],
            alphas[name + ".a1"], alphas[name + ".a2"], group,
        )
    return params


def sparsity_report(planes: dict) -> dict:
    """Fraction of zeros per plane kind — the paper's >60% avg / >70% w₂ᵇ claim."""
    s1 = [float(1.0 - jnp.mean(v)) for k, v in planes.items() if k.endswith(".b1")]
    s2 = [float(1.0 - jnp.mean(v)) for k, v in planes.items() if k.endswith(".b2")]
    n = max(len(s1), 1)
    return {
        "b1_mean": sum(s1) / n,
        "b2_mean": sum(s2) / n,
        "overall": (sum(s1) + sum(s2)) / (2 * n),
    }
