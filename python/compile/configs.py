"""Model/corpus/artifact configuration shared across the compile path.

These configs are the single source of truth for the build-time (python)
half of the system.  `aot.py` serializes everything the rust layer needs
into ``artifacts/manifest.json`` so the two layers never share python.

Substitution note (DESIGN.md §2): the paper evaluates LLaMA-1
{7B,13B,30B,65B} and LLaMA-2 {7B,13B,70B}.  On this testbed (1 CPU core)
we substitute a four-point size ladder S/M/L/XL of LLaMA-style
decoder-only transformers, trained at build time, plus a second "v2"
family (same architectures, different seed + corpus mixture) standing in
for LLaMA-2.
"""

from dataclasses import dataclass, field, asdict


# Per-group quantization granularity.  The paper's headline setting is
# W2A16 with group size 64 — we keep 64 exactly (all linear in-dims below
# are multiples of 64).
GROUP_SIZE = 64

# Vocabulary: BPE-like long-tail vocab (Zipfian unigram) — small enough
# for CPU softmax, large enough that head/tail prediction statistics
# (Fig. 6) are meaningful.
VOCAB_SIZE = 512

# Fixed AOT shapes (HLO is shape-specialized).
SEQ_LEN = 64          # model context for all exported executables
LOGITS_BATCH = 4      # fwd_logits / dad_step batch (paper fine-tunes at 2)
NLL_BATCH = 8         # fwd_nll (perplexity) batch


@dataclass(frozen=True)
class ModelConfig:
    """LLaMA-style decoder-only transformer hyper-parameters."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int = VOCAB_SIZE
    seq_len: int = SEQ_LEN
    rope_theta: float = 10000.0
    rmsnorm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        """Exact parameter count (untied embeddings)."""
        per_layer = (
            4 * self.d_model * self.d_model     # wq wk wv wo
            + 3 * self.d_model * self.d_ff      # gate up down
            + 2 * self.d_model                  # two rmsnorm gains
        )
        return (
            self.vocab * self.d_model           # tok_emb
            + self.n_layers * per_layer
            + self.d_model                      # final norm
            + self.d_model * self.vocab         # lm head
        )

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["n_params"] = self.n_params()
        return d


# The size ladder.  Every linear in-dimension (d_model and d_ff) is a
# multiple of GROUP_SIZE so group quantization tiles exactly.
MODEL_SIZES = {
    "S": ModelConfig("S", d_model=64, n_layers=2, n_heads=4, d_ff=192),
    "M": ModelConfig("M", d_model=128, n_layers=3, n_heads=4, d_ff=320),
    "L": ModelConfig("L", d_model=192, n_layers=5, n_heads=6, d_ff=512),
    "XL": ModelConfig("XL", d_model=256, n_layers=6, n_heads=8, d_ff=704),
}


@dataclass(frozen=True)
class CorpusConfig:
    """Synthetic Zipf-Markov corpus parameters (DESIGN.md §2)."""

    name: str
    seed: int
    zipf_s: float            # unigram long-tail exponent
    bigram_mix: float        # weight on the sparse bigram component
    n_succ: int = 6          # preferred successors per token
    vocab: int = VOCAB_SIZE
    train_tokens: int = 1 << 21   # ~2.1M
    eval_tokens: int = 1 << 16    # 65k

    def to_dict(self) -> dict:
        return asdict(self)


CORPORA = {
    # WikiText2 stand-in: stronger structure, steeper long tail.
    "wiki": CorpusConfig("wiki", seed=1001, zipf_s=1.08, bigram_mix=0.62),
    # C4 stand-in: broader, noisier.
    "web": CorpusConfig("web", seed=2002, zipf_s=1.00, bigram_mix=0.50),
}


@dataclass(frozen=True)
class TrainConfig:
    """Teacher pre-training schedule (build-time only)."""

    steps: int
    batch: int = 16
    lr: float = 3e-3
    warmup: int = 40
    weight_decay: float = 0.01
    clip: float = 1.0
    seed: int = 0
    # fraction of batches drawn from "wiki" (rest from "web")
    wiki_frac: float = 0.7


@dataclass(frozen=True)
class TeacherSpec:
    """One build-time teacher: architecture + training recipe."""

    tag: str                  # artifact tag, e.g. "S" or "S2"
    size: str                 # key into MODEL_SIZES
    train: TrainConfig = field(default_factory=lambda: TrainConfig(steps=400))

    @property
    def config(self) -> ModelConfig:
        return MODEL_SIZES[self.size]


# v1 family (stands in for LLaMA-1 {7,13,30,65}B) trains mostly on wiki;
# v2 family (stands in for LLaMA-2 {7,13,70}B) uses a different seed and a
# different corpus mixture — enough to produce genuinely distinct weight
# statistics, mirroring the distinct LLaMA-2 pre-training run.
TEACHERS = [
    TeacherSpec("S", "S", TrainConfig(steps=500, seed=11)),
    TeacherSpec("M", "M", TrainConfig(steps=420, seed=12)),
    TeacherSpec("L", "L", TrainConfig(steps=340, seed=13)),
    TeacherSpec("XL", "XL", TrainConfig(steps=280, seed=14)),
    TeacherSpec("S2", "S", TrainConfig(steps=500, seed=21, wiki_frac=0.45)),
    TeacherSpec("M2", "M", TrainConfig(steps=420, seed=22, wiki_frac=0.45)),
    TeacherSpec("L2", "L", TrainConfig(steps=340, seed=23, wiki_frac=0.45)),
]

TEACHER_BY_TAG = {t.tag: t for t in TEACHERS}

# DAD hyper-parameters (paper §4.3): gamma = lambda = 0.1.
DAD_GAMMA = 0.1
DAD_LAMBDA = 0.1
