"""AOT build: train teachers, quantize nothing, lower everything.

`python -m compile.aot --out-dir ../artifacts` produces every artifact
the rust layer consumes (DESIGN.md §4):

  corpus_{wiki,web}_eval.tok      eval token streams (u16 LE)
  teacher_{tag}.dbw               teacher weights + config header
  calib_{tag}.tok                 data-free calibration tokens (sampled
                                  from the teacher itself, LLM-QAT style)
  fwd_logits_{size}.hlo.txt       (params…, tokens[B4,T]) -> (logits,)
  fwd_nll_{size}.hlo.txt          (params…, tokens[B8,T+1]) -> (nll,)
  fwd_fdb_nll_{size}.hlo.txt      (frozen…, quads…, tokens) -> (nll,)
                                  — linears run the Pallas FDB kernel
  dad_step_{size}.hlo.txt         (alphas…, planes…, frozen…, tokens,
                                  teacher_logits, γ, λ)
                                  -> (total, ce, dad, grads…)
  fdb_kernel.hlo.txt              standalone Layer-1 kernel (benching)
  manifest.json                   shapes, orders, seeds, metrics, hashes

HLO TEXT is the interchange format — jax >= 0.5 serialized protos carry
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Python runs ONCE; the rust binary is self-contained afterwards.
"""

import argparse
import functools
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as M
from . import quant as Q
from . import train as T
from .configs import (
    CORPORA,
    DAD_GAMMA,
    DAD_LAMBDA,
    GROUP_SIZE,
    LOGITS_BATCH,
    MODEL_SIZES,
    NLL_BATCH,
    SEQ_LEN,
    TEACHERS,
    VOCAB_SIZE,
)
from .dbw import save_dbw
from .kernels.fdb import DEFAULT_BM, DEFAULT_BN, fdb_matmul

CALIB_SEQS = 512  # sequences of SEQ_LEN tokens in the data-free calib set


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def write(path: str, text: str, log) -> dict:
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    log(f"  wrote {path} ({len(text) / 1e6:.2f} MB, sha256:{digest})")
    return {"file": os.path.basename(path), "bytes": len(text), "sha256_16": digest}


# --------------------------------------------------------------------------
# per-size HLO exports
# --------------------------------------------------------------------------

def export_fwd_logits(cfg, out_dir, log):
    names = M.param_names(cfg)

    def fn(*args):
        params = dict(zip(names, args[:-1]))
        return (M.forward(params, args[-1], cfg),)

    specs = [spec(MShape(cfg, n)) for n in names]
    specs.append(spec((LOGITS_BATCH, SEQ_LEN), jnp.int32))
    lowered = jax.jit(fn).lower(*specs)
    meta = write(f"{out_dir}/fwd_logits_{cfg.name}.hlo.txt", to_hlo_text(lowered), log)
    meta.update(params=names, tokens_shape=[LOGITS_BATCH, SEQ_LEN],
                outputs=["logits"])
    return meta


def export_fwd_nll(cfg, out_dir, log):
    names = M.param_names(cfg)

    def fn(*args):
        params = dict(zip(names, args[:-1]))
        return (M.nll(params, args[-1], cfg),)

    specs = [spec(MShape(cfg, n)) for n in names]
    specs.append(spec((NLL_BATCH, SEQ_LEN + 1), jnp.int32))
    lowered = jax.jit(fn).lower(*specs)
    meta = write(f"{out_dir}/fwd_nll_{cfg.name}.hlo.txt", to_hlo_text(lowered), log)
    meta.update(params=names, tokens_shape=[NLL_BATCH, SEQ_LEN + 1],
                outputs=["nll"])
    return meta


def export_fwd_fdb_nll(cfg, out_dir, log):
    frozen_names, quad_names = M.fdb_param_names(cfg)

    def fn(*args):
        nf = len(frozen_names)
        nq = len(quad_names)
        frozen = dict(zip(frozen_names, args[:nf]))
        quads = dict(zip(quad_names, args[nf : nf + nq]))
        return (M.fdb_nll(frozen, quads, args[-1], cfg, use_pallas=True),)

    specs = [spec(MShape(cfg, n)) for n in frozen_names]
    specs += [spec(quad_shape(cfg, n)) for n in quad_names]
    specs.append(spec((NLL_BATCH, SEQ_LEN + 1), jnp.int32))
    lowered = jax.jit(fn).lower(*specs)
    meta = write(f"{out_dir}/fwd_fdb_nll_{cfg.name}.hlo.txt", to_hlo_text(lowered), log)
    meta.update(frozen=frozen_names, quads=quad_names,
                tokens_shape=[NLL_BATCH, SEQ_LEN + 1], outputs=["nll"])
    return meta


def export_dad_step(cfg, out_dir, log):
    frozen_names, quad_names = M.fdb_param_names(cfg)
    alpha_names = [n for n in quad_names if n.endswith((".a1", ".a2"))]
    plane_names = [n for n in quad_names if n.endswith((".b1", ".b2"))]

    def fn(*args):
        na, npl, nf = len(alpha_names), len(plane_names), len(frozen_names)
        alphas = dict(zip(alpha_names, args[:na]))
        planes = dict(zip(plane_names, args[na : na + npl]))
        frozen = dict(zip(frozen_names, args[na + npl : na + npl + nf]))
        tokens, teacher_logits, gamma, lam = args[na + npl + nf :]
        (total, ce, dad), grads = M.dad_step(
            alphas, planes, frozen, tokens, teacher_logits, cfg, gamma, lam
        )
        return (total, ce, dad) + tuple(grads[n] for n in alpha_names)

    specs = [spec(quad_shape(cfg, n)) for n in alpha_names]
    specs += [spec(quad_shape(cfg, n)) for n in plane_names]
    specs += [spec(MShape(cfg, n)) for n in frozen_names]
    specs.append(spec((LOGITS_BATCH, SEQ_LEN), jnp.int32))
    specs.append(spec((LOGITS_BATCH, SEQ_LEN, cfg.vocab)))
    specs.append(spec(()))  # gamma
    specs.append(spec(()))  # lambda
    lowered = jax.jit(fn).lower(*specs)
    meta = write(f"{out_dir}/dad_step_{cfg.name}.hlo.txt", to_hlo_text(lowered), log)
    meta.update(
        alphas=alpha_names, planes=plane_names, frozen=frozen_names,
        tokens_shape=[LOGITS_BATCH, SEQ_LEN],
        teacher_logits_shape=[LOGITS_BATCH, SEQ_LEN, cfg.vocab],
        outputs=["total", "ce", "dad"] + [f"grad:{n}" for n in alpha_names],
    )
    return meta


def export_fdb_kernel(out_dir, log, m=256, k=256, n=256):
    """Standalone Layer-1 kernel export (runtime smoke + criterion bench)."""

    def fn(x, w1, w2, a1, a2):
        return (fdb_matmul(x, w1, w2, a1, a2, group=GROUP_SIZE,
                           bm=DEFAULT_BM, bn=DEFAULT_BN),)

    g = k // GROUP_SIZE
    specs = [spec((m, k)), spec((k, n)), spec((k, n)), spec((g, n)), spec((g, n))]
    lowered = jax.jit(fn).lower(*specs)
    meta = write(f"{out_dir}/fdb_kernel.hlo.txt", to_hlo_text(lowered), log)
    meta.update(m=m, k=k, n=n, group=GROUP_SIZE, outputs=["y"])
    return meta


def MShape(cfg, name):
    """Shape of a full-precision parameter."""
    if name == "tok_emb":
        return (cfg.vocab, cfg.d_model)
    if name == "head":
        return (cfg.d_model, cfg.vocab)
    if name.endswith("norm"):
        return (cfg.d_model,)
    return M.linear_shape(cfg, name)


def quad_shape(cfg, name):
    """Shape of an FDB quad tensor (<lin>.{b1,b2,a1,a2})."""
    base, kind = name.rsplit(".", 1)
    din, dout = M.linear_shape(cfg, base)
    if kind in ("b1", "b2"):
        return (din, dout)
    return (din // GROUP_SIZE, dout)


# --------------------------------------------------------------------------
# main build
# --------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="tiny training run (CI smoke), marked in manifest")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    t_start = time.time()
    log = lambda s: print(s, flush=True)

    manifest = {
        "group_size": GROUP_SIZE,
        "vocab": VOCAB_SIZE,
        "seq_len": SEQ_LEN,
        "logits_batch": LOGITS_BATCH,
        "nll_batch": NLL_BATCH,
        "dad": {"gamma": DAD_GAMMA, "lambda": DAD_LAMBDA},
        "fast": bool(args.fast),
        "corpora": {},
        "sizes": {k: v.to_dict() for k, v in MODEL_SIZES.items()},
        "teachers": {},
        "executables": {},
    }

    # ---- corpora ---------------------------------------------------------
    log("== corpora ==")
    streams = {}
    for name, ccfg in CORPORA.items():
        n_train = ccfg.train_tokens if not args.fast else 1 << 17
        streams[name] = data_mod.sample_stream(ccfg, n_train)
        ev = data_mod.sample_stream(ccfg, ccfg.eval_tokens, seed_offset=99)
        data_mod.save_tokens(f"{out}/corpus_{name}_eval.tok", ev)
        floor = data_mod.markov_entropy_bits(ccfg)
        manifest["corpora"][name] = {
            **ccfg.to_dict(),
            "eval_file": f"corpus_{name}_eval.tok",
            "entropy_floor_bits": floor,
            "ppl_floor": 2.0 ** floor,
        }
        log(f"  {name}: floor ppl {2.0 ** floor:.2f}, "
            f"train {len(streams[name])} eval {len(ev)} tokens")

    # ---- teachers --------------------------------------------------------
    for tspec in TEACHERS:
        cfg = tspec.config
        tr = tspec.train
        if args.fast:
            tr = type(tr)(steps=30, batch=8, seed=tr.seed, wiki_frac=tr.wiki_frac)
            tspec = type(tspec)(tspec.tag, tspec.size, tr)
        log(f"== teacher {tspec.tag} ({cfg.name}, {cfg.n_params()/1e6:.2f}M params, "
            f"{tr.steps} steps) ==")
        params, history = T.train_teacher(tspec, streams, log=log)
        ppl = {name: T.eval_ppl(params, cfg, s) for name, s in streams.items()}
        log(f"  eval ppl: " + " ".join(f"{k}={v:.2f}" for k, v in ppl.items()))

        tensors = {n: np.asarray(params[n]) for n in M.param_names(cfg)}
        save_dbw(
            f"{out}/teacher_{tspec.tag}.dbw",
            {"tag": tspec.tag, "size": cfg.name, **cfg.to_dict()},
            tensors,
        )

        # data-free calibration set: sampled from the teacher itself
        key = jax.random.PRNGKey(tr.seed + 9999)
        chunks = []
        bsz = 64
        for c in range(CALIB_SEQS // bsz):
            key, k1, k2 = jax.random.split(key, 3)
            starts = jax.random.randint(k1, (bsz,), 0, cfg.vocab)
            toks = M.sample(params, starts, k2, cfg, SEQ_LEN, temperature=1.0)
            chunks.append(np.asarray(toks, dtype=np.uint16))
        calib = np.concatenate(chunks).reshape(-1)
        data_mod.save_tokens(f"{out}/calib_{tspec.tag}.tok", calib)

        # quick sanity: measured sparsity of the FDB init (paper: >60%)
        _, planes, _ = Q.fdb_quantize_model(params, cfg)
        sp = Q.sparsity_report(planes)
        log(f"  FDB init sparsity: b1 {sp['b1_mean']:.3f} b2 {sp['b2_mean']:.3f} "
            f"overall {sp['overall']:.3f}")

        manifest["teachers"][tspec.tag] = {
            "size": cfg.name,
            "dbw": f"teacher_{tspec.tag}.dbw",
            "calib": f"calib_{tspec.tag}.tok",
            "calib_seqs": CALIB_SEQS,
            "train": {"steps": tr.steps, "batch": tr.batch, "lr": tr.lr,
                      "seed": tr.seed, "wiki_frac": tr.wiki_frac},
            "history": history,
            "eval_ppl": ppl,
            "fdb_init_sparsity": sp,
        }

    # ---- HLO exports (one set per architecture size) ----------------------
    for size, cfg in MODEL_SIZES.items():
        log(f"== lowering {size} ==")
        manifest["executables"][f"fwd_logits_{size}"] = export_fwd_logits(cfg, out, log)
        manifest["executables"][f"fwd_nll_{size}"] = export_fwd_nll(cfg, out, log)
        manifest["executables"][f"fwd_fdb_nll_{size}"] = export_fwd_fdb_nll(cfg, out, log)
        manifest["executables"][f"dad_step_{size}"] = export_dad_step(cfg, out, log)

    log("== lowering standalone fdb kernel ==")
    manifest["executables"]["fdb_kernel"] = export_fdb_kernel(out, log)

    manifest["build_seconds"] = round(time.time() - t_start, 1)
    with open(f"{out}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"== done in {manifest['build_seconds']}s ==")


if __name__ == "__main__":
    main()
