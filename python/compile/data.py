"""Synthetic Zipf-Markov corpora (WikiText2 / C4 stand-ins).

The generator produces a token stream whose unigram marginal is Zipfian
(BPE-style long tail, the property Fig. 6 of the paper depends on) and
whose bigram structure is sparse-but-strong (each token prefers a small
successor set), so a small transformer can reduce perplexity far below
the unigram entropy — giving quantization methods a real dynamic range
to separate on.

Streams are serialized as little-endian u16 (`.tok` files); the rust
layer (`rust/src/data`) reads the identical format.
"""

import numpy as np

from .configs import CorpusConfig


def _zipf_probs(vocab: int, s: float) -> np.ndarray:
    """Zipf unigram over token ids; id 0 is the head of the distribution."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-s)
    return p / p.sum()


def transition_matrix(cfg: CorpusConfig) -> np.ndarray:
    """Dense [vocab, vocab] next-token distribution.

    p(next | cur) = mix * bigram_pref(cur) + (1 - mix) * zipf_unigram
    where bigram_pref(cur) puts geometric-decay mass on `n_succ`
    pseudo-random (seeded) successors of cur.
    """
    rng = np.random.default_rng(cfg.seed)
    uni = _zipf_probs(cfg.vocab, cfg.zipf_s)
    trans = np.tile(uni * (1.0 - cfg.bigram_mix), (cfg.vocab, 1))
    # geometric decay over the successor set, normalized
    w = 0.5 ** np.arange(cfg.n_succ)
    w = w / w.sum()
    for t in range(cfg.vocab):
        succ = rng.choice(cfg.vocab, size=cfg.n_succ, replace=False, p=uni)
        np.add.at(trans[t], succ, cfg.bigram_mix * w)
    # rows already sum to 1 by construction; renormalize for fp safety
    trans /= trans.sum(axis=1, keepdims=True)
    return trans.astype(np.float64)


def sample_stream(cfg: CorpusConfig, n_tokens: int, seed_offset: int = 0) -> np.ndarray:
    """Sample a token stream of length `n_tokens` as u16.

    Vectorized across 256 parallel Markov chains (inverse-CDF sampling),
    then concatenated — sequence boundaries land mid-stream, which is
    fine: training/eval windows are drawn uniformly anyway.
    """
    rng = np.random.default_rng(cfg.seed + 7919 * (seed_offset + 1))
    trans = transition_matrix(cfg)
    cum = np.cumsum(trans, axis=1)
    cum[:, -1] = 1.0  # exact upper edge

    chains = 256
    steps = -(-n_tokens // chains)  # ceil
    uni = _zipf_probs(cfg.vocab, cfg.zipf_s)
    cur = rng.choice(cfg.vocab, size=chains, p=uni)
    out = np.empty((steps, chains), dtype=np.uint16)
    for i in range(steps):
        r = rng.random(chains)
        # next[c] = first j with cum[cur[c], j] > r[c]
        rows = cum[cur]
        nxt = (rows < r[:, None]).sum(axis=1)
        out[i] = nxt
        cur = nxt
    return out.T.reshape(-1)[:n_tokens].astype(np.uint16)


def markov_entropy_bits(cfg: CorpusConfig) -> float:
    """Exact conditional entropy H(X_{t+1} | X_t) in bits.

    This is the per-token information floor — the best achievable PPL is
    2**H.  Recorded in the manifest so EXPERIMENTS.md can report how close
    each teacher gets to the floor.
    """
    trans = transition_matrix(cfg)
    # stationary distribution via power iteration
    pi = _zipf_probs(cfg.vocab, cfg.zipf_s)
    for _ in range(200):
        pi = pi @ trans
    pi /= pi.sum()
    h_rows = -(trans * np.log2(np.maximum(trans, 1e-300))).sum(axis=1)
    return float((pi * h_rows).sum())


def save_tokens(path: str, tokens: np.ndarray) -> None:
    assert tokens.dtype == np.uint16
    tokens.astype("<u2").tofile(path)


def load_tokens(path: str) -> np.ndarray:
    return np.fromfile(path, dtype="<u2")


def batch_iterator(stream: np.ndarray, batch: int, seq_plus_one: int, rng: np.random.Generator):
    """Yield [batch, seq_plus_one] windows sampled uniformly from `stream`."""
    n = len(stream) - seq_plus_one - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        yield np.stack([stream[s : s + seq_plus_one] for s in starts]).astype(np.int32)
