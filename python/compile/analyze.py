"""§Perf L2: XLA cost analysis of the lowered modules.

Re-lowers the exported functions (same code path as aot.py) and prints
FLOPs / bytes-accessed / output size per executable plus the analytic
expectation, so EXPERIMENTS.md §Perf can compare.  Build-time tool.

    cd python && python -m compile.analyze [--sizes S,XL]
"""

import argparse

import jax
import jax.numpy as jnp

from . import model as M
from .configs import LOGITS_BATCH, MODEL_SIZES, NLL_BATCH, SEQ_LEN


def analyze(name: str, fn, specs) -> None:
    compiled = jax.jit(fn).lower(*specs).compile()
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
    except Exception as e:  # pragma: no cover - backend-dependent
        print(f"{name}: cost analysis unavailable ({e})")
        return
    flops = cost.get("flops", float("nan"))
    bytes_ = cost.get("bytes accessed", float("nan"))
    print(f"{name:<16} flops {flops/1e9:8.3f}G   bytes {bytes_/1e6:9.1f}M   "
          f"arithmetic intensity {flops/max(bytes_,1):6.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="S,XL")
    args = ap.parse_args()

    for size in args.sizes.split(","):
        cfg = MODEL_SIZES[size]
        names = M.param_names(cfg)
        spec = lambda shape, dt=jnp.float32: jax.ShapeDtypeStruct(shape, dt)

        def pshape(n):
            if n == "tok_emb":
                return (cfg.vocab, cfg.d_model)
            if n == "head":
                return (cfg.d_model, cfg.vocab)
            if n.endswith("norm"):
                return (cfg.d_model,)
            return M.linear_shape(cfg, n)

        def fwd_nll(*xs):
            params = dict(zip(names, xs[:-1]))
            return (M.nll(params, xs[-1], cfg),)

        specs = [spec(pshape(n)) for n in names]
        specs.append(spec((NLL_BATCH, SEQ_LEN + 1), jnp.int32))
        # analytic expectation: 2*params*tokens (linears+emb+head) + attn
        toks = NLL_BATCH * SEQ_LEN
        analytic = 2 * cfg.n_params() * toks + cfg.n_layers * 4 * SEQ_LEN * toks * cfg.d_model
        print(f"== size {size} ({cfg.n_params()/1e6:.2f}M params) ==")
        print(f"analytic fwd_nll ≈ {analytic/1e9:.3f} GFLOP")
        analyze(f"fwd_nll_{size}", fwd_nll, specs)

        def fwd_logits(*xs):
            params = dict(zip(names, xs[:-1]))
            return (M.forward(params, xs[-1], cfg),)

        specs_l = [spec(pshape(n)) for n in names]
        specs_l.append(spec((LOGITS_BATCH, SEQ_LEN), jnp.int32))
        analyze(f"fwd_logits_{size}", fwd_logits, specs_l)


if __name__ == "__main__":
    main()
