"""`.dbw` — the weight-blob interchange format between python and rust.

Layout (all little-endian):

    magic   : 4 bytes  b"DBW1"
    jsonlen : u32      length of the UTF-8 JSON header
    header  : jsonlen bytes — {"config": {...}, "tensors": [
                  {"name": str, "dtype": "f32", "shape": [..],
                   "offset": int, "nbytes": int}, ...]}
    payload : concatenated row-major tensor bytes, 64-byte aligned each

The rust reader lives in `rust/src/model/store.rs`; both sides are
round-trip tested against each other through the artifacts.
"""

import json
import struct

import numpy as np

MAGIC = b"DBW1"
ALIGN = 64


def save_dbw(path: str, config: dict, tensors: "dict[str, np.ndarray]") -> None:
    """Write tensors (name -> f32 ndarray) with a JSON config header."""
    entries = []
    payload = bytearray()
    for name, arr in tensors.items():
        shape = list(np.shape(arr))  # before ascontiguousarray (0-d -> 1-d)
        arr = np.ascontiguousarray(arr, dtype="<f4")
        pad = (-len(payload)) % ALIGN
        payload.extend(b"\0" * pad)
        entries.append(
            {
                "name": name,
                "dtype": "f32",
                "shape": shape,
                "offset": len(payload),
                "nbytes": arr.nbytes,
            }
        )
        payload.extend(arr.tobytes())
    header = json.dumps({"config": config, "tensors": entries}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        f.write(bytes(payload))


def load_dbw(path: str) -> "tuple[dict, dict[str, np.ndarray]]":
    """Read back (config, {name: f32 ndarray})."""
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:4] != MAGIC:
        raise ValueError(f"{path}: bad magic {blob[:4]!r}")
    (jsonlen,) = struct.unpack_from("<I", blob, 4)
    header = json.loads(blob[8 : 8 + jsonlen].decode())
    base = 8 + jsonlen
    tensors = {}
    for e in header["tensors"]:
        if e["dtype"] != "f32":
            raise ValueError(f"unsupported dtype {e['dtype']}")
        start = base + e["offset"]
        arr = np.frombuffer(blob, dtype="<f4", count=e["nbytes"] // 4, offset=start)
        tensors[e["name"]] = arr.reshape(e["shape"]).copy()
    return header["config"], tensors
