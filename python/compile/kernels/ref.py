"""Pure-jnp oracles for the Pallas kernels and the FDB arithmetic.

Everything here is the *specification*: the Pallas kernel
(`kernels/fdb.py`), the rust quantizer (`rust/src/quant/fdb.rs`) and the
rust bit-serial matmul are all tested against these functions.

Conventions: weights are [in, out] (activations right-multiply: y = x@W);
quantization groups tile the *in* dimension with size `group`; per-group
scales have shape [in/group, out].
"""

import jax.numpy as jnp


def rtn2_group_quantize(w: jnp.ndarray, group: int):
    """2-bit RTN proxy, per-(group, out-column), symmetric grid (Eq. 1-2).

    Levels {-2,-1,0,1}·s with s = max|w| / 2 per group/column.
    Returns (wq int8 in {-2..1}, s [in/group, out]).
    """
    din, dout = w.shape
    assert din % group == 0, (din, group)
    g = din // group
    wg = w.reshape(g, group, dout)
    s = jnp.max(jnp.abs(wg), axis=1) / 2.0  # [g, out]
    s = jnp.maximum(s, 1e-8)
    wq = jnp.clip(jnp.round(wg / s[:, None, :]), -2, 1).astype(jnp.int8)
    return wq.reshape(din, dout), s


def fdb_split(w: jnp.ndarray, s: jnp.ndarray, group: int):
    """Split fp weights into dual {0,1} planes + scales (Eq. 4-7, Fig. 5).

    With α₁ = 2s > 0 and α₂ = -s < 0 (Eq. 5) the dual-binary grid is
    {α₂, 0, α₁+α₂, α₁} = {-s, 0, s, 2s} — Fig. 5's four levels.  The
    proxy 2-bit scale s comes from `rtn2_group_quantize`; plane
    assignment follows the level-center comparison of Eq. 6-7
    (`step_split_ref`), which is exactly nearest-level rounding onto the
    dual-binary grid.

    Returns (b1, b2, a1, a2): b* {0,1} f32 [in,out], a* f32 [in/group,out].
    """
    a1 = 2.0 * s
    a2 = -s
    b1, b2 = step_split_ref(w, a1, a2, group)
    return b1, b2, a1, a2


def fdb_dequant(b1, b2, a1, a2, group: int):
    """ŵ = α₁·w₁ᵇ + α₂·w₂ᵇ with per-(group, out-col) scales (Eq. 4)."""
    a1e = jnp.repeat(a1, group, axis=0)
    a2e = jnp.repeat(a2, group, axis=0)
    return a1e * b1 + a2e * b2


def fdb_matmul_ref(x, b1, b2, a1, a2, group: int):
    """Reference for the Pallas kernel (Eq. 8).

    y = Σ_g α₁[g]·(x_g @ b1_g) + α₂[g]·(x_g @ b2_g)

    x [.., in], b* [in, out], a* [in/group, out] -> y [.., out].
    Mathematically identical to x @ fdb_dequant(...), but expressed as the
    dual binary-sparse matmul — the efficient form the kernel implements.
    """
    din, dout = b1.shape
    g = din // group
    xg = x.reshape(*x.shape[:-1], g, group)
    b1g = b1.reshape(g, group, dout)
    b2g = b2.reshape(g, group, dout)
    p1 = jnp.einsum("...gk,gkn->...gn", xg, b1g)
    p2 = jnp.einsum("...gk,gkn->...gn", xg, b2g)
    return (p1 * a1 + p2 * a2).sum(axis=-2)


def step_split_ref(w: jnp.ndarray, a1: jnp.ndarray, a2: jnp.ndarray, group: int):
    """Re-derive binary planes from fp weights and current scales (Eq. 6-7).

    After DAD moves the scales the level centers move, so plane
    assignment is recomputed by comparing against the centers:

        b1 = H(w - (α₁+α₂)/2)
        b2 = H(-(w - α₁·b1 - α₂/2))

    H = unit step (1 for x > 0 else 0).  Assumes α₁ > 0 > α₂ (Fig. 5).
    """
    a1e = jnp.repeat(a1, group, axis=0)
    a2e = jnp.repeat(a2, group, axis=0)
    b1 = (w - (a1e + a2e) / 2.0 > 0).astype(jnp.float32)
    b2 = (-(w - a1e * b1 - a2e / 2.0) > 0).astype(jnp.float32)
    return b1, b2
