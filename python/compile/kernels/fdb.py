"""Layer-1 Pallas kernel: the FDB dual-binary grouped matmul (Eq. 8).

    y[m,n] = Σ_g  α₁[g,n] · (x[m,Kg] @ w₁ᵇ[Kg,n])
           + Σ_g  α₂[g,n] · (x[m,Kg] @ w₂ᵇ[Kg,n])

Tiling (DESIGN.md §Hardware-Adaptation): the grid iterates (M/bm, N/bn,
K/bk) with bk == GROUP_SIZE so each k-step consumes exactly one scale
group; the output block (i, j) is revisited across k and accumulates in
place — the Pallas expression of a K-blocked GEMM with fused per-group
scale combine.  On TPU the two binary planes live in VMEM as 0/1 tiles
feeding the MXU; on this testbed the kernel runs under interpret=True
(Mosaic custom-calls cannot execute on the CPU PJRT plugin) and its HLO
lowers into the same artifact the rust runtime loads.

VMEM budget per block (f32): bm·bk + 2·bk·bn + 2·bn + bm·bn floats.
With the default (bm, bk, bn) = (64, 64, 128) that is 45 KiB — far under
the 16 MiB VMEM of a TPUv4 core, leaving room for double-buffering
(analyzed in EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes; bk must equal the quantization group size so one
# k-step = one scale group.
DEFAULT_BM = 64
DEFAULT_BN = 128


def _fdb_kernel(x_ref, w1_ref, w2_ref, a1_ref, a2_ref, o_ref):
    """One (bm, bn) output block, one k-group step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    # Two binary-plane partial products; on MXU these are bf16 0/1 mask
    # matmuls, here f32 for exactness under interpret mode.
    p1 = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    p2 = jnp.dot(x, w2_ref[...], preferred_element_type=jnp.float32)
    # Per-group scale combine fused into the accumulation.
    o_ref[...] += p1 * a1_ref[0] + p2 * a2_ref[0]


@functools.partial(
    jax.jit, static_argnames=("group", "bm", "bn", "interpret")
)
def fdb_matmul(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    w2: jnp.ndarray,
    a1: jnp.ndarray,
    a2: jnp.ndarray,
    *,
    group: int = 64,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> jnp.ndarray:
    """FDB grouped dual-binary matmul.

    x  [M, K]       activations (fp)
    w1 [K, N]       binary plane 1 as {0,1} f32
    w2 [K, N]       binary plane 2 as {0,1} f32
    a1 [K/group, N] plane-1 scales (α₁)
    a2 [K/group, N] plane-2 scales (α₂)
    -> [M, N]

    Shapes must tile exactly: group | K, bm | M, bn | N.  The wrapper in
    `fdb_matmul_any` pads arbitrary M.
    """
    m, kdim = x.shape
    _, n = w1.shape
    bm = min(bm, m)
    bn = min(bn, n)
    bk = group
    assert kdim % bk == 0 and m % bm == 0 and n % bn == 0, (x.shape, w1.shape, bm, bn, bk)
    grid = (m // bm, n // bn, kdim // bk)
    return pl.pallas_call(
        _fdb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w1, w2, a1, a2)


def fdb_matmul_any(x, w1, w2, a1, a2, *, group: int = 64, interpret: bool = True):
    """Rank-agnostic wrapper: flattens leading dims, pads M to a block.

    Used by the L2 model so [B, T, d] activations flow straight through.
    """
    lead = x.shape[:-1]
    kdim = x.shape[-1]
    n = w1.shape[-1]
    x2 = x.reshape(-1, kdim)
    m = x2.shape[0]
    # pick bm dividing padded m
    bm = DEFAULT_BM if m >= DEFAULT_BM else m
    pad = (-m) % bm
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, kdim), x2.dtype)], axis=0)
    bn = DEFAULT_BN if n % DEFAULT_BN == 0 else n
    y = fdb_matmul(x2, w1, w2, a1, a2, group=group, bm=bm, bn=bn, interpret=interpret)
    if pad:
        y = y[:m]
    return y.reshape(*lead, n)


def vmem_footprint_bytes(bm: int, bk: int, bn: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM bytes for one grid step (inputs + scales + acc).

    Used by the §Perf analysis and by `python/tests/test_kernel.py` to
    keep chosen block shapes inside budget.
    """
    floats = bm * bk + 2 * bk * bn + 2 * bn + bm * bn
    return floats * dtype_bytes


def mxu_utilization_estimate(bm: int, bk: int, bn: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes busy for one (bm,bk)x(bk,bn) pass.

    The MXU processes mxu×mxu tiles; partial tiles waste lanes.  This is
    the structural estimate DESIGN.md commits to for real-TPU perf (the
    interpret-mode kernel gives no hardware timing signal).
    """
    import math

    eff = lambda d: d / (math.ceil(d / mxu) * mxu)
    return eff(bm) * eff(bk) * eff(bn)
