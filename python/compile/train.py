"""Build-time teacher pre-training (AdamW + cosine schedule, pure JAX).

Runs only inside `make artifacts`.  Teachers are seeded and fully
deterministic; the resulting weights are the stand-ins for the LLaMA
checkpoints (DESIGN.md §2).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .configs import SEQ_LEN, CorpusConfig, TeacherSpec
from .model import forward, init_params


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    """One decoupled-weight-decay Adam step (Loshchilov & Hutter)."""
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return p - step - lr * wd * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def lr_schedule(step, base_lr, warmup, total):
    warm = base_lr * (step + 1) / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def ce_loss(params, batch, cfg):
    """batch [B, T+1] -> mean next-token CE (nats)."""
    logits = forward(params, batch[:, :-1], cfg)
    targets = batch[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train_teacher(spec: TeacherSpec, streams: "dict[str, np.ndarray]", log=print):
    """Train one teacher; returns (params, history list of (step, loss))."""
    cfg = spec.config
    tc = spec.train
    key = jax.random.PRNGKey(tc.seed)
    params = init_params(cfg, key)

    opt = adamw_init(params)
    loss_grad = jax.value_and_grad(ce_loss)

    @jax.jit
    def step_fn(params, opt, batch, lr):
        loss, grads = loss_grad(params, batch, cfg)
        grads, gn = clip_by_global_norm(grads, tc.clip)
        params, opt = adamw_update(params, grads, opt, lr, wd=tc.weight_decay)
        return params, opt, loss, gn

    rng = np.random.default_rng(tc.seed + 555)
    iters = {
        name: data_mod.batch_iterator(stream, tc.batch, SEQ_LEN + 1, rng)
        for name, stream in streams.items()
    }
    history = []
    t0 = time.time()
    for step in range(tc.steps):
        src = "wiki" if rng.random() < tc.wiki_frac else "web"
        batch = jnp.asarray(next(iters[src]))
        lr = lr_schedule(step, tc.lr, tc.warmup, tc.steps)
        params, opt, loss, gn = step_fn(params, opt, batch, lr)
        if step % 50 == 0 or step == tc.steps - 1:
            loss_f = float(loss)
            history.append((step, loss_f))
            log(
                f"[train {spec.tag}] step {step:4d}/{tc.steps} "
                f"loss {loss_f:.4f} ppl {np.exp(loss_f):8.2f} "
                f"({time.time() - t0:.1f}s)"
            )
    return params, history


def eval_ppl(params, cfg, stream: np.ndarray, n_windows: int = 64, seed: int = 0) -> float:
    """Quick python-side perplexity (sanity metric recorded in manifest)."""
    rng = np.random.default_rng(seed)
    it = data_mod.batch_iterator(stream, 8, SEQ_LEN + 1, rng)

    @jax.jit
    def batch_nll(batch):
        logits = forward(params, batch[:, :-1], cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, batch[:, 1:, None], axis=-1)[..., 0]

    tot, cnt = 0.0, 0
    for _ in range(n_windows // 8):
        nll = np.asarray(batch_nll(jnp.asarray(next(it))))
        tot += nll.sum()
        cnt += nll.size
    return float(np.exp(tot / cnt))
