//! Entropy coding for the sparse binary planes — the substrate behind
//! the paper's "≈1.88 effective bits per weight" claim (§3.2, citing
//! Shannon 1948 / Huffman / Van Leeuwen 1976).
//!
//! Pipeline: a packed `BitPlane` is byte-serialized, optionally
//! run-length preprocessed, then Huffman coded.  `effective_bits`
//! measures the realized bits/weight of an `FdbLinear` after coding,
//! which EXPERIMENTS.md compares against the paper's 1.88 figure.

#![warn(missing_docs)]

/// MSB-first bit-stream reader/writer shared by the coders.
pub mod bitio;
/// Canonical Huffman coder over byte streams.
pub mod huffman;
/// Zero-run run-length preprocessor for sparse plane bytes.
pub mod rle;

use crate::quant::FdbLinear;

/// Shannon entropy (bits/symbol) of a byte stream.
pub fn byte_entropy(data: &[u8]) -> f64 {
    let mut counts = [0u64; 256];
    for &b in data {
        counts[b as usize] += 1;
    }
    let n = data.len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

/// Bernoulli entropy (bits/bit) for a plane with ones-density p.
pub fn bit_entropy(p: f64) -> f64 {
    if p <= 0.0 || p >= 1.0 {
        return 0.0;
    }
    -p * p.log2() - (1.0 - p) * (1.0 - p).log2()
}

/// Compressed size in bytes of one plane byte-stream (RLE+Huffman,
/// whichever of {huffman, rle+huffman} is smaller — both losslessly
/// invertible; headers included).
pub fn compress_plane_bytes(data: &[u8]) -> usize {
    let h = huffman::encode(data).len();
    let r = rle::encode(data);
    let rh = huffman::encode(&r).len() + 1; // 1-byte mode tag
    h.min(rh)
}

/// Storage accounting for one FDB linear after entropy coding.
pub struct EffectiveBits {
    /// coded bits per weight for the two planes combined
    pub plane_bits: f64,
    /// scale overhead bits per weight (2 × f16 per group)
    pub scale_bits: f64,
    /// total effective bits per weight
    pub total: f64,
    /// Shannon floor (entropy bound) for comparison
    pub shannon_floor: f64,
}

/// Measure the realized effective bits/weight of an FDB layer.
pub fn effective_bits(fdb: &FdbLinear) -> EffectiveBits {
    let n_weights = (fdb.din * fdb.dout) as f64;
    let bytes1 = fdb.b1.to_bytes();
    let bytes2 = fdb.b2.to_bytes();
    let coded1 = compress_plane_bytes(&bytes1) as f64 * 8.0;
    let coded2 = compress_plane_bytes(&bytes2) as f64 * 8.0;
    let plane_bits = (coded1 + coded2) / n_weights;
    let scale_bits = 2.0 * 16.0 / fdb.group as f64;
    let p1 = 1.0 - fdb.b1.sparsity();
    let p2 = 1.0 - fdb.b2.sparsity();
    EffectiveBits {
        plane_bits,
        scale_bits,
        total: plane_bits + scale_bits,
        shannon_floor: bit_entropy(p1) + bit_entropy(p2) + scale_bits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::FdbLinear;
    use crate::tensor::Matrix;
    use crate::util::Pcg32;

    #[test]
    fn byte_entropy_limits() {
        assert_eq!(byte_entropy(&[7u8; 1000]), 0.0);
        let uniform: Vec<u8> = (0..=255).cycle().take(25600).collect();
        assert!((byte_entropy(&uniform) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn bit_entropy_known() {
        assert!((bit_entropy(0.5) - 1.0).abs() < 1e-12);
        assert_eq!(bit_entropy(0.0), 0.0);
        assert!((bit_entropy(0.25) - 0.8112781).abs() < 1e-5);
    }

    #[test]
    fn effective_bits_below_2_for_sparse_planes() {
        // the §3.2 claim: coded dual planes cost < 2 bits/weight
        let mut rng = Pcg32::seeded(71);
        let w = Matrix::randn(512, 256, &mut rng, 1.0);
        let fdb = FdbLinear::from_weights(&w, 64);
        let eb = effective_bits(&fdb);
        assert!(eb.total < 2.5, "effective bits {}", eb.total);
        assert!(eb.plane_bits >= eb.shannon_floor - eb.scale_bits - 0.2);
    }

    #[test]
    fn compression_never_catastrophically_expands() {
        let mut rng = Pcg32::seeded(72);
        let random: Vec<u8> = (0..4096).map(|_| rng.next_u32() as u8).collect();
        let c = compress_plane_bytes(&random);
        // incompressible data: bounded overhead (< 10%)
        assert!(c < random.len() + random.len() / 10 + 300, "{c}");
    }
}
