//! Bit-granular I/O used by the Huffman coder.

/// MSB-first bit writer.
#[derive(Default)]
pub struct BitWriter {
    out: Vec<u8>,
    cur: u8,
    nbits: u8,
}

impl BitWriter {
    /// An empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one bit (MSB-first within each output byte).
    #[inline]
    pub fn push_bit(&mut self, bit: bool) {
        self.cur = (self.cur << 1) | bit as u8;
        self.nbits += 1;
        if self.nbits == 8 {
            self.out.push(self.cur);
            self.cur = 0;
            self.nbits = 0;
        }
    }

    /// Write the low `len` bits of `code`, MSB first.
    pub fn push_code(&mut self, code: u32, len: u8) {
        for i in (0..len).rev() {
            self.push_bit((code >> i) & 1 == 1);
        }
    }

    /// Flush, padding the tail with zeros; returns (bytes, bit_len).
    pub fn finish(mut self) -> (Vec<u8>, usize) {
        let bit_len = self.out.len() * 8 + self.nbits as usize;
        if self.nbits > 0 {
            self.cur <<= 8 - self.nbits;
            self.out.push(self.cur);
        }
        (self.out, bit_len)
    }
}

/// MSB-first bit reader.
pub struct BitReader<'a> {
    data: &'a [u8],
    pos: usize, // bit position
    len: usize, // total bits available
}

impl<'a> BitReader<'a> {
    /// Read over `data`, exposing at most `bit_len` bits (clamped to the
    /// byte length so a short buffer can never over-read).
    pub fn new(data: &'a [u8], bit_len: usize) -> Self {
        BitReader { data, pos: 0, len: bit_len.min(data.len() * 8) }
    }

    /// The next bit, or `None` once all `bit_len` bits are consumed.
    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        if self.pos >= self.len {
            return None;
        }
        let byte = self.data[self.pos / 8];
        let bit = (byte >> (7 - self.pos % 8)) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    /// Bits left to read.
    pub fn remaining(&self) -> usize {
        self.len - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg32};

    #[test]
    fn roundtrip_bits() {
        prop::check(20, |rng: &mut Pcg32| {
            let n = rng.range(1, 200);
            let bits: Vec<bool> = (0..n).map(|_| rng.below(2) == 1).collect();
            let mut w = BitWriter::new();
            for &b in &bits {
                w.push_bit(b);
            }
            let (bytes, len) = w.finish();
            assert_eq!(len, n);
            let mut r = BitReader::new(&bytes, len);
            for &b in &bits {
                assert_eq!(r.read_bit(), Some(b));
            }
            assert_eq!(r.read_bit(), None);
        });
    }

    #[test]
    fn push_code_msb_first() {
        let mut w = BitWriter::new();
        w.push_code(0b101, 3);
        let (bytes, len) = w.finish();
        assert_eq!(len, 3);
        assert_eq!(bytes, vec![0b1010_0000]);
    }
}
