//! Byte run-length preprocessing.  Sparse planes serialize to byte
//! streams dominated by 0x00 runs; RLE turns those into short (marker,
//! len) pairs that the Huffman stage then squeezes further.
//!
//! Format: any byte b != 0x00 encodes itself; 0x00 is followed by a
//! varint-style run length (1..=255 per chunk, chained).

/// Encode.  Worst case (no zero runs) adds nothing; all-zeros shrinks
/// ~128x before Huffman.
pub fn encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 4 + 16);
    let mut i = 0;
    while i < data.len() {
        if data[i] == 0 {
            let mut run = 0usize;
            while i + run < data.len() && data[i + run] == 0 {
                run += 1;
            }
            i += run;
            while run > 0 {
                let chunk = run.min(255);
                out.push(0u8);
                out.push(chunk as u8);
                run -= chunk;
            }
        } else {
            out.push(data[i]);
            i += 1;
        }
    }
    out
}

/// Decode an `encode` stream.
pub fn decode(data: &[u8]) -> anyhow::Result<Vec<u8>> {
    let mut out = Vec::with_capacity(data.len() * 2);
    let mut i = 0;
    while i < data.len() {
        if data[i] == 0 {
            anyhow::ensure!(i + 1 < data.len(), "rle: dangling zero marker");
            let run = data[i + 1] as usize;
            anyhow::ensure!(run > 0, "rle: zero-length run");
            out.extend(std::iter::repeat(0u8).take(run));
            i += 2;
        } else {
            out.push(data[i]);
            i += 1;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg32};

    #[test]
    fn roundtrip_property() {
        prop::check(25, |rng: &mut Pcg32| {
            let n = rng.range(0, 2000);
            let density = rng.f32();
            let data: Vec<u8> = (0..n)
                .map(|_| if rng.f32() < density { rng.next_u32() as u8 } else { 0 })
                .collect();
            let enc = encode(&data);
            assert_eq!(decode(&enc).unwrap(), data);
        });
    }

    #[test]
    fn shrinks_zero_runs() {
        let data = vec![0u8; 10_000];
        let enc = encode(&data);
        assert!(enc.len() <= 2 * (10_000 / 255 + 1));
    }

    #[test]
    fn long_runs_chain() {
        let data = vec![0u8; 300];
        let enc = encode(&data);
        assert_eq!(enc, vec![0, 255, 0, 45]);
        assert_eq!(decode(&enc).unwrap(), data);
    }

    #[test]
    fn no_expansion_without_zeros() {
        let data: Vec<u8> = (1..=255).cycle().take(1000).collect();
        assert_eq!(encode(&data).len(), data.len());
    }

    #[test]
    fn rejects_malformed() {
        assert!(decode(&[0]).is_err());
        assert!(decode(&[0, 0]).is_err());
    }
}
