//! Canonical Huffman coding over byte symbols (Huffman 1952; length
//! construction per Van Leeuwen 1976's two-queue method).
//!
//! Container format:
//!   u32 LE  original length (bytes)
//!   u32 LE  payload bit length
//!   256 × u8  code lengths (canonical codes are rebuilt from lengths)
//!   payload bits (MSB-first)

use super::bitio::{BitReader, BitWriter};

// Compact header: u32 orig len, u32 payload bits, u16 symbol count,
// then (symbol, len) pairs for present symbols only.
const HEADER_FIXED: usize = 4 + 4 + 2;
/// Cap code length so the canonical rebuild fits u32 codes comfortably.
const MAX_LEN: u8 = 31;

/// Build optimal code lengths with the two-queue method over sorted leaf
/// weights — O(n log n) in the sort, O(n) in the merge (Van Leeuwen).
fn code_lengths(freqs: &[u64; 256]) -> [u8; 256] {
    let symbols: Vec<usize> = (0..256).filter(|&s| freqs[s] > 0).collect();
    let mut lens = [0u8; 256];
    match symbols.len() {
        0 => return lens,
        1 => {
            lens[symbols[0]] = 1;
            return lens;
        }
        _ => {}
    }

    // node = (weight, id); ids < 256 are leaves, >= 256 internal
    let mut leaves: Vec<(u64, usize)> = symbols.iter().map(|&s| (freqs[s], s)).collect();
    leaves.sort();
    let mut merged: std::collections::VecDeque<(u64, usize)> = Default::default();
    let mut leaf_q: std::collections::VecDeque<(u64, usize)> = leaves.into_iter().collect();
    let mut parent = vec![usize::MAX; 512 + 256];
    let mut next_id = 256;

    let pop_min = |leaf_q: &mut std::collections::VecDeque<(u64, usize)>,
                       merged: &mut std::collections::VecDeque<(u64, usize)>| {
        match (leaf_q.front(), merged.front()) {
            (Some(a), Some(b)) => {
                if a.0 <= b.0 {
                    leaf_q.pop_front().expect("front() was Some")
                } else {
                    merged.pop_front().expect("front() was Some")
                }
            }
            (Some(_), None) => leaf_q.pop_front().expect("front() was Some"),
            (None, Some(_)) => merged.pop_front().expect("front() was Some"),
            (None, None) => unreachable!(),
        }
    };

    while leaf_q.len() + merged.len() > 1 {
        let a = pop_min(&mut leaf_q, &mut merged);
        let b = pop_min(&mut leaf_q, &mut merged);
        parent[a.1] = next_id;
        parent[b.1] = next_id;
        merged.push_back((a.0 + b.0, next_id));
        next_id += 1;
    }

    for &s in &symbols {
        let mut d = 0u8;
        let mut n = s;
        while parent[n] != usize::MAX {
            n = parent[n];
            d += 1;
        }
        lens[s] = d.min(MAX_LEN);
    }
    lens
}

/// Canonical codes from lengths: shorter codes first, ties by symbol.
fn canonical_codes(lens: &[u8; 256]) -> [(u32, u8); 256] {
    let mut order: Vec<usize> = (0..256).filter(|&s| lens[s] > 0).collect();
    order.sort_by_key(|&s| (lens[s], s));
    let mut codes = [(0u32, 0u8); 256];
    let mut code = 0u32;
    let mut prev_len = 0u8;
    for &s in &order {
        code <<= lens[s] - prev_len;
        codes[s] = (code, lens[s]);
        code += 1;
        prev_len = lens[s];
    }
    codes
}

/// Encode `data`; output includes the self-describing header.
pub fn encode(data: &[u8]) -> Vec<u8> {
    let mut freqs = [0u64; 256];
    for &b in data {
        freqs[b as usize] += 1;
    }
    let lens = code_lengths(&freqs);
    let codes = canonical_codes(&lens);
    let mut w = BitWriter::new();
    for &b in data {
        let (c, l) = codes[b as usize];
        w.push_code(c, l);
    }
    let (payload, bit_len) = w.finish();

    let present: Vec<u8> = (0u16..256).filter(|&s| lens[s as usize] > 0).map(|s| s as u8).collect();
    let mut out = Vec::with_capacity(HEADER_FIXED + 2 * present.len() + payload.len());
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out.extend_from_slice(&(bit_len as u32).to_le_bytes());
    out.extend_from_slice(&(present.len() as u16).to_le_bytes());
    for s in present {
        out.push(s);
        out.push(lens[s as usize]);
    }
    out.extend_from_slice(&payload);
    out
}

/// Decode an `encode` container.
pub fn decode(blob: &[u8]) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(blob.len() >= HEADER_FIXED, "huffman blob too short");
    let n = u32::from_le_bytes(blob[0..4].try_into()?) as usize;
    let bit_len = u32::from_le_bytes(blob[4..8].try_into()?) as usize;
    let n_sym = u16::from_le_bytes(blob[8..10].try_into()?) as usize;
    anyhow::ensure!(blob.len() >= HEADER_FIXED + 2 * n_sym, "huffman header truncated");
    let mut lens = [0u8; 256];
    for i in 0..n_sym {
        let sym = blob[HEADER_FIXED + 2 * i];
        lens[sym as usize] = blob[HEADER_FIXED + 2 * i + 1];
    }
    let header = HEADER_FIXED + 2 * n_sym;
    let codes = canonical_codes(&lens);

    // decoding table: sorted (len, code) -> symbol via linear scan per bit
    // (canonical property: track the running code value per length)
    let mut by_len: Vec<Vec<(u32, u8)>> = vec![Vec::new(); (MAX_LEN + 1) as usize];
    for s in 0..256usize {
        let (c, l) = codes[s];
        if l > 0 {
            by_len[l as usize].push((c, s as u8));
        }
    }
    for v in &mut by_len {
        v.sort();
    }

    let mut r = BitReader::new(&blob[header..], bit_len);
    let mut out = Vec::with_capacity(n);
    let mut code = 0u32;
    let mut len = 0u8;
    while out.len() < n {
        let bit = r
            .read_bit()
            .ok_or_else(|| anyhow::anyhow!("huffman payload truncated"))?;
        code = (code << 1) | bit as u32;
        len += 1;
        anyhow::ensure!(len <= MAX_LEN, "code length overflow");
        if let Ok(i) = by_len[len as usize].binary_search_by_key(&code, |&(c, _)| c) {
            out.push(by_len[len as usize][i].1);
            code = 0;
            len = 0;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::byte_entropy;
    use crate::util::{prop, Pcg32};

    #[test]
    fn roundtrip_property() {
        prop::check(25, |rng: &mut Pcg32| {
            let n = rng.range(0, 3000);
            // skewed alphabet to exercise variable lengths
            let alpha = rng.range(1, 5) as u32;
            let data: Vec<u8> = (0..n)
                .map(|_| {
                    let r = rng.f32();
                    (r.powi(alpha as i32) * 255.0) as u8
                })
                .collect();
            let enc = encode(&data);
            let dec = decode(&enc).unwrap();
            assert_eq!(dec, data);
        });
    }

    #[test]
    fn compresses_skewed_within_one_bit_of_entropy() {
        // Huffman optimality: avg code length < H + 1 (Shannon bound)
        let mut rng = Pcg32::seeded(81);
        let data: Vec<u8> = (0..20_000)
            .map(|_| if rng.f32() < 0.9 { 0u8 } else { rng.next_u32() as u8 })
            .collect();
        let enc = encode(&data);
        let payload_bits = (enc.len() - HEADER_FIXED) as f64 * 8.0; // header upper bound ok
        let h = byte_entropy(&data);
        let avg = payload_bits / data.len() as f64;
        assert!(avg < h + 1.0 + 0.1, "avg {avg:.3} vs H {h:.3}");
    }

    #[test]
    fn single_symbol_stream() {
        let data = vec![42u8; 500];
        let enc = encode(&data);
        assert_eq!(decode(&enc).unwrap(), data);
        // 1 bit per symbol + compact header
        assert!(enc.len() <= HEADER_FIXED + 2 + 500 / 8 + 2);
    }

    #[test]
    fn empty_stream() {
        let enc = encode(&[]);
        assert_eq!(decode(&enc).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn rejects_truncated() {
        let enc = encode(b"hello world hello world");
        assert!(decode(&enc[..enc.len() - 2]).is_err());
        assert!(decode(&enc[..10]).is_err());
    }
}
