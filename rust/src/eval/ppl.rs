//! Perplexity evaluation through the AOT `fwd_nll` executables.
//!
//! PPL = exp(mean per-token NLL) over sequential windows of the eval
//! stream — the WikiText2/C4 protocol of Tables 1/2/7, on the synthetic
//! stand-in corpora.

use anyhow::Result;

use crate::data::TokenStream;
use crate::runtime::{session::pack_batch, Runtime, Session};

/// Evaluate perplexity of a pinned session over `stream`.
///
/// `max_windows` bounds cost (0 = all full windows).  Windows are
/// consecutive `seq_len+1`-token slices; the same slices are used for
/// every method so comparisons are paired.
pub fn perplexity(
    rt: &mut Runtime,
    session: &Session,
    stream: &TokenStream,
    max_windows: usize,
) -> Result<f64> {
    let width = session.seq_len + 1;
    let batch = session.nll_batch;
    let windows: Vec<Vec<u32>> = stream.windows(width).map(|w| w.to_vec()).collect();
    let n = if max_windows == 0 { windows.len() } else { windows.len().min(max_windows) };
    anyhow::ensure!(n > 0, "stream too short for one window");

    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    let mut i = 0;
    while i < n {
        let chunk = &windows[i..(i + batch).min(n)];
        let used = chunk.len();
        let packed = pack_batch(chunk, batch, width)?;
        let nll = session.nll(rt, &packed)?;
        // only count the real (non-padded) rows
        let per_row = session.seq_len;
        for r in 0..used {
            for v in &nll[r * per_row..(r + 1) * per_row] {
                total_nll += *v as f64;
            }
            total_tok += per_row;
        }
        i += used;
    }
    Ok((total_nll / total_tok as f64).exp())
}

/// Perplexity via the native CPU forward (cross-check + calibration-free
/// paths); slower, used by tests and the landscape study.
pub fn perplexity_native(
    weights: &crate::model::Weights,
    stream: &TokenStream,
    max_windows: usize,
) -> f64 {
    let width = weights.config.seq_len + 1;
    let mut fwd = crate::model::native::Forward::new(weights);
    let mut total = 0.0f64;
    let mut count = 0usize;
    for (i, w) in stream.windows(width).enumerate() {
        if max_windows > 0 && i >= max_windows {
            break;
        }
        for nll in fwd.nll(w) {
            total += nll;
            count += 1;
        }
    }
    (total / count.max(1) as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Weights};

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            vocab: 64,
            seq_len: 16,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    #[test]
    fn native_ppl_near_vocab_for_random_weights() {
        // an untrained model is ~uniform -> PPL ~ vocab
        let w = Weights::synthetic(&tiny(), 1);
        let stream = TokenStream { tokens: (0..2000).map(|i| (i * 17 + 3) % 64).collect() };
        let ppl = perplexity_native(&w, &stream, 8);
        assert!((30.0..110.0).contains(&ppl), "ppl {ppl}");
    }

    #[test]
    fn native_ppl_detects_structure() {
        // constant stream -> a model can't be worse than uniform, and
        // perplexity must be finite/positive
        let w = Weights::synthetic(&tiny(), 2);
        let stream = TokenStream { tokens: vec![5; 600] };
        let ppl = perplexity_native(&w, &stream, 4);
        assert!(ppl > 0.0 && ppl.is_finite());
    }
}
