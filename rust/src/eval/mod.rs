//! Evaluation harness: perplexity, zero-shot suites, prediction
//! statistics (Fig. 6/7), loss landscapes (Fig. 4) and the quantization
//! pipeline that ties quantizers + calibration + the runtime together.

pub mod landscape;
pub mod pipeline;
pub mod ppl;
pub mod tables;
pub mod predstats;
pub mod zeroshot;

pub use pipeline::QuantPipeline;
