//! Prediction-distribution statistics — Fig. 6 (head-class bias of
//! low-bit models under random generation) and Fig. 7 (prediction
//! entropy vs task loss correlation).

use anyhow::Result;

use crate::runtime::{Runtime, Session};
use crate::util::Pcg32;

/// Fig. 6: sample continuations from the model and histogram the
/// predicted tokens.  `steps` rounds of batch generation; greedy-free
/// ancestral sampling with the given temperature.
pub fn prediction_histogram(
    rt: &mut Runtime,
    session: &Session,
    vocab: usize,
    steps: usize,
    seed: u64,
) -> Result<Vec<u64>> {
    let mut rng = Pcg32::seeded(seed);
    let (b, t) = (session.logits_batch, session.seq_len);
    let mut hist = vec![0u64; vocab];
    for _ in 0..steps {
        // random prompt prefix, model predicts every next position; we
        // sample from the categorical at each position (paper: "gathered
        // through random generation")
        let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u32) as i32).collect();
        let logits = session.logits(rt, &tokens)?;
        for pos in 0..b * t {
            let row = &logits[pos * vocab..(pos + 1) * vocab];
            let tok = sample_categorical(row, &mut rng);
            hist[tok] += 1;
        }
    }
    Ok(hist)
}

fn sample_categorical(logits: &[f32], rng: &mut Pcg32) -> usize {
    let mx = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let weights: Vec<f64> = logits.iter().map(|&v| ((v - mx) as f64).exp()).collect();
    rng.categorical(&weights)
}

/// Head/tail mass ratio relative to a reference histogram — the Fig. 6
/// "1.6× more likely to predict head classes" statistic.  `frac` is the
/// head/tail fraction of the vocab (paper uses the BPE band structure;
/// we use the top/bottom eighth).
pub fn head_tail_ratio(hist: &[u64], reference: &[u64], frac: f64) -> f64 {
    let v = hist.len();
    let k = ((v as f64 * frac) as usize).max(1);
    let h: f64 = hist.iter().take(k).sum::<u64>() as f64;
    let t: f64 = hist.iter().skip(v - k).sum::<u64>() as f64;
    let hr: f64 = reference.iter().take(k).sum::<u64>() as f64;
    let tr: f64 = reference.iter().skip(v - k).sum::<u64>() as f64;
    let model_ratio = h / t.max(1.0);
    let ref_ratio = hr / tr.max(1.0);
    model_ratio / ref_ratio.max(1e-9)
}

/// Total-variation distance between two normalized histograms.
pub fn tv_distance(a: &[u64], b: &[u64]) -> f64 {
    let sa: f64 = a.iter().sum::<u64>() as f64;
    let sb: f64 = b.iter().sum::<u64>() as f64;
    0.5 * a
        .iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 / sa - y as f64 / sb).abs())
        .sum::<f64>()
}

/// Fig. 7: per-position (teacher entropy, student entropy, CE loss)
/// triples over evaluation windows.
pub struct EntropyLossPoints {
    pub teacher_entropy: Vec<f64>,
    pub student_entropy: Vec<f64>,
    pub loss: Vec<f64>,
}

pub fn entropy_vs_loss(
    rt: &mut Runtime,
    teacher: &Session,
    student: &Session,
    windows: &[Vec<u32>],
    vocab: usize,
) -> Result<EntropyLossPoints> {
    let (b, t) = (teacher.logits_batch, teacher.seq_len);
    let mut points = EntropyLossPoints {
        teacher_entropy: Vec::new(),
        student_entropy: Vec::new(),
        loss: Vec::new(),
    };
    for chunk in windows.chunks(b) {
        if chunk.len() < b {
            break;
        }
        // windows carry t+1 tokens: inputs + next-token targets
        let inputs: Vec<i32> = chunk.iter().flat_map(|w| w[..t].iter().map(|&x| x as i32)).collect();
        let lt = teacher.logits(rt, &inputs)?;
        let ls = student.logits(rt, &inputs)?;
        for (row, w) in chunk.iter().enumerate() {
            for pos in 0..t {
                let off = (row * t + pos) * vocab;
                let tr = &lt[off..off + vocab];
                let sr = &ls[off..off + vocab];
                points.teacher_entropy.push(entropy(tr));
                points.student_entropy.push(entropy(sr));
                points.loss.push(ce_loss(sr, w[pos + 1] as usize));
            }
        }
    }
    Ok(points)
}

pub fn entropy(logits: &[f32]) -> f64 {
    let mx = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
    let mut z = 0.0f64;
    for &v in logits {
        z += ((v as f64) - mx).exp();
    }
    let lnz = z.ln();
    let mut h = 0.0f64;
    for &v in logits {
        let lp = (v as f64) - mx - lnz;
        h -= lp.exp() * lp;
    }
    h
}

pub fn ce_loss(logits: &[f32], target: usize) -> f64 {
    let mx = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
    let z: f64 = logits.iter().map(|&v| ((v as f64) - mx).exp()).sum();
    mx + z.ln() - logits[target] as f64
}

/// Pearson correlation (the Fig. 7 summary statistic).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (a, b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    sxy / (sxx.sqrt() * syy.sqrt()).max(1e-12)
}

/// Binned means of `y` ordered by `x` (for the Fig. 7 curve rendering).
pub fn binned_means(x: &[f64], y: &[f64], bins: usize) -> Vec<(f64, f64)> {
    let mut idx: Vec<usize> = (0..x.len()).collect();
    idx.sort_by(|&a, &b| x[a].partial_cmp(&x[b]).expect("curve inputs are finite"));
    let per = (x.len() / bins).max(1);
    idx.chunks(per)
        .map(|c| {
            let mx = c.iter().map(|&i| x[i]).sum::<f64>() / c.len() as f64;
            let my = c.iter().map(|&i| y[i]).sum::<f64>() / c.len() as f64;
            (mx, my)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_of_uniform_and_peaked() {
        let v = 64;
        let uniform = vec![0.0f32; v];
        assert!((entropy(&uniform) - (v as f64).ln()).abs() < 1e-9);
        let mut peaked = vec![0.0f32; v];
        peaked[3] = 1e4;
        assert!(entropy(&peaked) < 1e-3);
    }

    #[test]
    fn pearson_known() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let y = vec![2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = vec![8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn head_tail_ratio_detects_bias() {
        let v = 64;
        let reference: Vec<u64> = (0..v).map(|i| 1000 / (i as u64 + 1) + 1).collect();
        // model that over-predicts the head
        let biased: Vec<u64> = (0..v).map(|i| 2000 / (i as u64 + 1) / (i as u64 / 8 + 1) + 1).collect();
        let r = head_tail_ratio(&biased, &reference, 0.125);
        assert!(r > 1.0, "ratio {r}");
        // identical histograms -> ratio 1
        let r1 = head_tail_ratio(&reference, &reference, 0.125);
        assert!((r1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tv_distance_bounds() {
        let a = vec![10u64, 0, 0];
        let b = vec![0u64, 10, 0];
        assert!((tv_distance(&a, &b) - 1.0).abs() < 1e-12);
        assert_eq!(tv_distance(&a, &a), 0.0);
    }

    #[test]
    fn ce_loss_matches_entropy_for_uniform() {
        let logits = vec![0.0f32; 32];
        assert!((ce_loss(&logits, 5) - (32f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn binned_means_sorted() {
        let x = vec![3.0, 1.0, 2.0, 4.0];
        let y = vec![30.0, 10.0, 20.0, 40.0];
        let b = binned_means(&x, &y, 2);
        assert_eq!(b.len(), 2);
        assert!((b[0].1 - 15.0).abs() < 1e-12);
        assert!((b[1].1 - 35.0).abs() < 1e-12);
    }
}
