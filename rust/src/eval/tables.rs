//! Table/figure drivers: each function regenerates one table or figure
//! of the paper (DESIGN.md §5 experiment index) and prints the same
//! rows/series the paper reports, plus a JSON record under `results/`.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{Context, Result};

use crate::codec;
use crate::coordinator::{DadConfig, DadTrainer};
use crate::data::{TaskSuite, TokenStream};
use crate::model::{flops, ModelConfig, Weights};
use crate::quant::{
    awq::Awq, fdb::Fdb, gptq::Gptq, omniquant::OmniQuant, pbllm::PbLlm, rtn::Rtn, Calib,
    FdbLinear, Quantizer,
};
use crate::runtime::{session::load_teacher, Runtime, Session};
use crate::util::Json;

use super::landscape;
use super::pipeline::QuantPipeline;
use super::ppl::perplexity;
use super::predstats;
use super::zeroshot;

/// Cost/selection knobs shared by all drivers.
#[derive(Clone, Debug)]
pub struct TableOpts {
    /// PPL windows per (model, corpus); 0 = full stream
    pub windows: usize,
    /// DAD fine-tuning batches
    pub dad_batches: usize,
    /// restrict to these teacher tags (empty = driver default)
    pub teachers: Vec<String>,
    /// where JSON records go
    pub out_dir: PathBuf,
    /// zero-shot items per suite (0 = suite default)
    pub zs_items: usize,
    /// override the calibration token stream (diagnostics)
    pub calib_override: Option<PathBuf>,
    /// override the quantization group size (stress ablation; DAD
    /// fine-tuning requires the manifest group, so it is skipped when
    /// this differs)
    pub group_override: Option<usize>,
}

impl Default for TableOpts {
    fn default() -> Self {
        TableOpts {
            windows: 96,
            dad_batches: 48,
            teachers: vec![],
            out_dir: PathBuf::from("results"),
            zs_items: 120,
            calib_override: None,
            group_override: None,
        }
    }
}

/// The method grid of Tables 1/2/5/7.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Fp16,
    RtnW2,
    RtnW3,
    AwqW2,
    AwqW3,
    GptqW2,
    OmniW2,
    PbLlm,
    DbLlm,
    /// ablation: FDB init without DAD fine-tuning
    DbLlmNoDad,
    /// ablation: raw 2-bit RTN proxy (no FDB, no DAD)
    DbLlmNoDadNoFdb,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Fp16 => "FP16",
            Method::RtnW2 => "RTN W2",
            Method::RtnW3 => "RTN W3",
            Method::AwqW2 => "AWQ W2",
            Method::AwqW3 => "AWQ W3",
            Method::GptqW2 => "GPTQ W2",
            Method::OmniW2 => "OmniQuant W2",
            Method::PbLlm => "PB-LLM W2*",
            Method::DbLlm => "DB-LLM W2",
            Method::DbLlmNoDad => "DB-LLM -DAD",
            Method::DbLlmNoDadNoFdb => "DB-LLM -DAD -FDB",
        }
    }

    pub fn main_grid() -> Vec<Method> {
        vec![
            Method::Fp16,
            Method::RtnW2,
            Method::RtnW3,
            Method::AwqW2,
            Method::AwqW3,
            Method::GptqW2,
            Method::OmniW2,
            Method::PbLlm,
            Method::DbLlm,
        ]
    }
}

/// One evaluated (teacher, method) student: dequantized weights plus
/// the FDB layers when applicable.
pub struct Student {
    pub weights: Weights,
    pub fdb_layers: BTreeMap<String, FdbLinear>,
    pub dad_trend: Option<(f64, f64)>,
}

/// Quantize (and for DB-LLM, DAD-fine-tune) one teacher with one method.
pub fn make_student(
    rt: &mut Runtime,
    teacher_tag: &str,
    method: Method,
    opts: &TableOpts,
    dad_overrides: Option<DadConfig>,
) -> Result<Student> {
    let weights = load_teacher(rt, teacher_tag)?;
    if method == Method::Fp16 {
        return Ok(Student { weights, fdb_layers: BTreeMap::new(), dad_trend: None });
    }
    let info = rt.manifest.teacher(teacher_tag)?;
    let calib_path = opts
        .calib_override
        .clone()
        .unwrap_or_else(|| rt.artifacts_dir.join(&info.calib));
    let calib_stream = TokenStream::load(&calib_path)?;
    let pipeline = QuantPipeline::new(rt.manifest.seq_len());
    // activation collection runs the native forward over 16 sequences —
    // cache it per (teacher, calib) across the many methods of a table
    static CALIB_CACHE: OnceLock<Mutex<BTreeMap<String, Arc<BTreeMap<String, Calib>>>>> =
        OnceLock::new();
    let cache_key = format!("{teacher_tag}:{}", calib_path.display());
    let calib = {
        let cache = CALIB_CACHE.get_or_init(Default::default);
        let hit = cache.lock().expect("calib cache poisoned").get(&cache_key).cloned();
        match hit {
            Some(c) => c,
            None => {
                let c = Arc::new(pipeline.collect_calib(&weights, &calib_stream));
                cache.lock().expect("calib cache poisoned").insert(cache_key, c.clone());
                c
            }
        }
    };
    let group = opts.group_override.unwrap_or_else(|| rt.manifest.group_size());

    let quantizer: Box<dyn Quantizer> = match method {
        Method::RtnW2 | Method::DbLlmNoDadNoFdb => Box::new(Rtn::new(2, group)),
        Method::RtnW3 => Box::new(Rtn::new(3, group)),
        Method::AwqW2 => Box::new(Awq::new(2, group)),
        Method::AwqW3 => Box::new(Awq::new(3, group)),
        Method::GptqW2 => Box::new(Gptq::new(2, group)),
        Method::OmniW2 => Box::new(OmniQuant::new(2, group)),
        Method::PbLlm => Box::new(PbLlm::new(group)),
        Method::DbLlm | Method::DbLlmNoDad => Box::new(Fdb { group }),
        Method::Fp16 => unreachable!(),
    };
    let qm = pipeline.quantize(&weights, quantizer.as_ref(), &calib)?;
    let mut fdb_layers = qm.fdb_layers;
    let mut student_weights = qm.weights;
    let mut dad_trend = None;

    if (method == Method::DbLlm || method == Method::DbLlmNoDad)
        && group == rt.manifest.group_size()
    {
        // DAD fine-tuning (paper §3.3): teacher session supplies logits.
        // The "-DAD" ablation keeps the distillation fine-tune but drops
        // the deviation-aware reweighting (λ = 0, pure soft CE) — matching
        // Table 3's reading where removing FDB (not DAD) removes the
        // fine-tuning procedure itself.
        let teacher_session = Session::new(rt, &weights)?;
        let mut cfg = dad_overrides.unwrap_or_default();
        if method == Method::DbLlmNoDad {
            cfg.lambda = 0.0;
        }
        cfg.max_batches = cfg.max_batches.min(opts.dad_batches.max(1));
        let mut trainer = DadTrainer::new(rt, &weights.config.name, &fdb_layers, cfg)?;
        trainer.train(rt, &teacher_session, &weights, &fdb_layers, &calib_stream, |s| {
            eprintln!(
                "  [dad {teacher_tag}] step {:3} total {:.4} ce {:.4} dad {:.4}",
                s.step, s.total, s.ce, s.dad
            );
        })?;
        trainer.apply(&mut fdb_layers, &weights);
        dad_trend = trainer.loss_trend();
        // rebuild dequantized weights from the fine-tuned layers
        student_weights = weights.map_linears(|name, _| fdb_layers[name].dequant());
    }

    Ok(Student { weights: student_weights, fdb_layers, dad_trend })
}

fn eval_ppl_for(
    rt: &mut Runtime,
    student: &Student,
    streams: &BTreeMap<String, TokenStream>,
    windows: usize,
) -> Result<BTreeMap<String, f64>> {
    let session = Session::new(rt, &student.weights)?;
    let mut out = BTreeMap::new();
    for (name, stream) in streams {
        out.insert(name.clone(), perplexity(rt, &session, stream, windows)?);
    }
    Ok(out)
}

fn load_streams(rt: &Runtime) -> Result<BTreeMap<String, TokenStream>> {
    let mut streams = BTreeMap::new();
    for name in rt.manifest.corpus_names()? {
        let f = rt.manifest.corpus_eval_file(&name)?;
        streams.insert(name.clone(), TokenStream::load(rt.artifacts_dir.join(f))?);
    }
    Ok(streams)
}

fn save_json(opts: &TableOpts, name: &str, j: &Json) -> Result<()> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let p = opts.out_dir.join(format!("{name}.json"));
    std::fs::write(&p, j.to_string()).with_context(|| format!("writing {p:?}"))?;
    eprintln!("  saved {p:?}");
    Ok(())
}

// ------------------------------------------------------------------------
// Tables 1 & 2 — perplexity grids
// ------------------------------------------------------------------------

/// Table 1 (v1 family over both corpora) / Table 2 (v2 family, wiki).
pub fn table_ppl(rt: &mut Runtime, opts: &TableOpts, v2: bool) -> Result<Json> {
    let default_teachers: Vec<String> = if v2 {
        vec!["S2".into(), "M2".into(), "L2".into()]
    } else {
        vec!["S".into(), "M".into(), "L".into(), "XL".into()]
    };
    let teachers = if opts.teachers.is_empty() { default_teachers } else { opts.teachers.clone() };
    let streams = load_streams(rt)?;
    let corpora: Vec<String> =
        if v2 { vec!["wiki".into()] } else { streams.keys().cloned().collect() };

    let title = if v2 { "Table 2 (LLaMA-2 stand-in: v2 teacher family)" } else { "Table 1 (LLaMA-1 stand-in: v1 teacher family)" };
    println!("\n== {title} ==");
    print!("{:<18}", "method");
    for t in &teachers {
        for c in &corpora {
            print!("{:>12}", format!("{t}/{c}"));
        }
    }
    println!();

    let mut rows = Vec::new();
    for method in Method::main_grid() {
        print!("{:<18}", method.label());
        let mut row = vec![("method".to_string(), Json::str(method.label()))];
        for tag in &teachers {
            let student = make_student(rt, tag, method, opts, None)?;
            let ppls = eval_ppl_for(rt, &student, &streams, opts.windows)?;
            for c in &corpora {
                print!("{:>12.2}", ppls[c]);
                row.push((format!("{tag}/{c}"), Json::num(ppls[c])));
            }
            use std::io::Write;
            std::io::stdout().flush().ok();
        }
        println!();
        rows.push(Json::Obj(row.into_iter().map(|(k, v)| (k, v)).collect()));
    }
    let j = Json::obj(vec![
        ("table", Json::str(if v2 { "2" } else { "1" })),
        ("windows", Json::num(opts.windows as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    save_json(opts, if v2 { "table2" } else { "table1" }, &j)?;
    Ok(j)
}

// ------------------------------------------------------------------------
// Table 3 — component ablation
// ------------------------------------------------------------------------

pub fn table3(rt: &mut Runtime, opts: &TableOpts) -> Result<Json> {
    let tag = opts.teachers.first().cloned().unwrap_or_else(|| "M".to_string());
    let streams = load_streams(rt)?;
    println!("\n== Table 3 (component ablation, teacher {tag}) ==");
    println!("{:<20}{:>10}{:>10}{:>10}", "variant", "wiki", "web", "avg");
    let mut rows = Vec::new();
    for (label, method) in [
        ("W16A16", Method::Fp16),
        ("Ours (FDB+DAD)", Method::DbLlm),
        ("- DAD", Method::DbLlmNoDad),
        ("- DAD - FDB", Method::DbLlmNoDadNoFdb),
    ] {
        let student = make_student(rt, &tag, method, opts, None)?;
        let ppls = eval_ppl_for(rt, &student, &streams, opts.windows)?;
        let avg = (ppls["wiki"] + ppls["web"]) / 2.0;
        println!("{:<20}{:>10.2}{:>10.2}{:>10.2}", label, ppls["wiki"], ppls["web"], avg);
        rows.push(Json::obj(vec![
            ("variant", Json::str(label)),
            ("wiki", Json::num(ppls["wiki"])),
            ("web", Json::num(ppls["web"])),
            ("avg", Json::num(avg)),
        ]));
    }
    let j = Json::obj(vec![("table", Json::str("3")), ("teacher", Json::str(tag)), ("rows", Json::Arr(rows))]);
    save_json(opts, "table3", &j)?;
    Ok(j)
}

// ------------------------------------------------------------------------
// Table 4 — γ sweep
// ------------------------------------------------------------------------

pub fn table4(rt: &mut Runtime, opts: &TableOpts) -> Result<Json> {
    let tag = opts.teachers.first().cloned().unwrap_or_else(|| "M".to_string());
    let streams = load_streams(rt)?;
    let gammas = [0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0];
    println!("\n== Table 4 (γ ablation, teacher {tag}, wiki PPL) ==");
    print!("{:<10}", "gamma");
    for g in gammas {
        print!("{g:>9.1}");
    }
    println!();
    print!("{:<10}", "ppl");
    let mut rows = Vec::new();
    for g in gammas {
        let cfg = DadConfig { gamma: g, ..DadConfig::default() };
        let student = make_student(rt, &tag, Method::DbLlm, opts, Some(cfg))?;
        let ppls = eval_ppl_for(rt, &student, &streams, opts.windows)?;
        print!("{:>9.3}", ppls["wiki"]);
        use std::io::Write;
        std::io::stdout().flush().ok();
        rows.push(Json::obj(vec![("gamma", Json::num(g)), ("wiki", Json::num(ppls["wiki"]))]));
    }
    println!();
    let j = Json::obj(vec![("table", Json::str("4")), ("teacher", Json::str(tag)), ("rows", Json::Arr(rows))]);
    save_json(opts, "table4", &j)?;
    Ok(j)
}

// ------------------------------------------------------------------------
// Tables 5 & 7 — zero-shot accuracy
// ------------------------------------------------------------------------

pub fn table_zeroshot(rt: &mut Runtime, opts: &TableOpts, v2: bool) -> Result<Json> {
    let default_teachers: Vec<String> = if v2 {
        vec!["S2".into(), "M2".into(), "L2".into()]
    } else {
        vec!["S".into(), "M".into(), "L".into(), "XL".into()]
    };
    let teachers = if opts.teachers.is_empty() { default_teachers } else { opts.teachers.clone() };
    let streams = load_streams(rt)?;
    let stream = &streams["wiki"];
    let width = rt.manifest.seq_len() + 1;
    let mut suites = TaskSuite::standard(width);
    if opts.zs_items > 0 {
        for s in &mut suites {
            s.n_items = opts.zs_items;
        }
    }
    let methods = [Method::Fp16, Method::GptqW2, Method::AwqW2, Method::OmniW2, Method::PbLlm, Method::DbLlm];

    let title = if v2 { "Table 7 (zero-shot, v2 family)" } else { "Table 5 (zero-shot, v1 family)" };
    println!("\n== {title} ==");
    let mut rows = Vec::new();
    for tag in &teachers {
        println!("-- teacher {tag} --");
        print!("{:<18}", "method");
        for s in &suites {
            print!("{:>12}", s.name);
        }
        println!("{:>9}", "avg");
        for method in methods {
            let student = make_student(rt, tag, method, opts, None)?;
            let session = Session::new(rt, &student.weights)?;
            print!("{:<18}", method.label());
            let mut accs = Vec::new();
            let mut row = vec![
                ("teacher".to_string(), Json::str(tag.clone())),
                ("method".to_string(), Json::str(method.label())),
            ];
            for suite in &suites {
                let acc = zeroshot::accuracy(rt, &session, suite, stream)?;
                print!("{:>11.1}%", acc * 100.0);
                row.push((suite.name.clone(), Json::num(acc)));
                accs.push(acc);
                use std::io::Write;
                std::io::stdout().flush().ok();
            }
            let avg = accs.iter().sum::<f64>() / accs.len() as f64;
            println!("{:>8.1}%", avg * 100.0);
            row.push(("avg".to_string(), Json::num(avg)));
            rows.push(Json::Obj(row.into_iter().collect()));
        }
    }
    let j = Json::obj(vec![
        ("table", Json::str(if v2 { "7" } else { "5" })),
        ("rows", Json::Arr(rows)),
    ]);
    save_json(opts, if v2 { "table7" } else { "table5" }, &j)?;
    Ok(j)
}

// ------------------------------------------------------------------------
// Table 6 — size / sparsity / FLOPs
// ------------------------------------------------------------------------

pub fn table6(rt: &mut Runtime, opts: &TableOpts) -> Result<Json> {
    // measured sparsities from our largest teacher's FDB layers
    let tag = opts.teachers.first().cloned().unwrap_or_else(|| "XL".to_string());
    let student = make_student(rt, &tag, Method::DbLlmNoDad, opts, None)?;
    let (s1, s2, _avg) = QuantPipeline::fdb_sparsity(&student.fdb_layers);
    // measured 2-bit sparsity (fraction of zero levels in the RTN grid)
    let weights = load_teacher(rt, &tag)?;
    let mut zeros = 0usize;
    let mut total = 0usize;
    let group = opts.group_override.unwrap_or_else(|| rt.manifest.group_size());
    for name in weights.config.linear_names() {
        let (q, _) = Rtn::new(2, group).quantize_with_scales(weights.mat(&name));
        zeros += q.data.iter().filter(|&&v| v == 0.0).count();
        total += q.data.len();
    }
    let s2bit = zeros as f64 / total as f64;
    // measured effective bits after entropy coding (paper: ~1.88)
    let mut eff_bits = 0.0;
    for layer in student.fdb_layers.values() {
        eff_bits += codec::effective_bits(layer).total;
    }
    eff_bits /= student.fdb_layers.len() as f64;

    println!("\n== Table 6 (size / sparsity / FLOPs) ==");
    for (label, cfg) in [
        ("paper's LLaMA-1-7B config", ModelConfig::llama1_7b()),
        ("our XL teacher config", weights.config.clone()),
    ] {
        println!("-- {label}, 32-token sentence --");
        println!("{:<22}{:>12}{:>10}{:>12}", "method", "size", "sparsity", "FLOPs");
        let schemes = [
            flops::Scheme::Fp16,
            flops::Scheme::Uniform { bits: 3.0, sparsity: 0.0 },
            flops::Scheme::Uniform { bits: 2.0, sparsity: s2bit },
            flops::Scheme::Binary,
            flops::Scheme::Fdb { sparsity_b1: s1, sparsity_b2: s2, effective_bits: eff_bits },
        ];
        for s in &schemes {
            let r = flops::report(&cfg, 32.0, s);
            println!(
                "{:<22}{:>12}{:>10}{:>12}",
                r.method,
                format!("{}B", crate::util::eng(r.model_size_bytes)),
                r.sparsity.map_or("-".to_string(), |v| format!("{:.1}%", v * 100.0)),
                crate::util::eng(r.flops),
            );
        }
    }
    println!(
        "measured: b1 sparsity {:.1}%, b2 sparsity {:.1}%, coded bits/weight {:.3}",
        s1 * 100.0,
        s2 * 100.0,
        eff_bits
    );
    let j = Json::obj(vec![
        ("table", Json::str("6")),
        ("sparsity_b1", Json::num(s1)),
        ("sparsity_b2", Json::num(s2)),
        ("sparsity_2bit", Json::num(s2bit)),
        ("effective_bits", Json::num(eff_bits)),
    ]);
    save_json(opts, "table6", &j)?;
    Ok(j)
}

// ------------------------------------------------------------------------
// Figures
// ------------------------------------------------------------------------

/// Fig. 1: PPL vs model size — FP16, DB-LLM W2, AWQ W3.
pub fn figure1(rt: &mut Runtime, opts: &TableOpts) -> Result<Json> {
    let teachers = ["S", "M", "L", "XL"];
    let streams = load_streams(rt)?;
    println!("\n== Figure 1 (wiki PPL vs model size) ==");
    println!("{:<10}{:>12}{:>14}{:>14}{:>14}", "teacher", "params", "FP16", "DB-LLM W2", "AWQ W3");
    let mut rows = Vec::new();
    for tag in teachers {
        let cfg_size = rt.manifest.size_config(&rt.manifest.teacher(tag)?.size)?;
        let mut vals = BTreeMap::new();
        for method in [Method::Fp16, Method::DbLlm, Method::AwqW3] {
            let student = make_student(rt, tag, method, opts, None)?;
            let ppls = eval_ppl_for(rt, &student, &streams, opts.windows)?;
            vals.insert(method.label().to_string(), ppls["wiki"]);
        }
        println!(
            "{:<10}{:>12}{:>14.2}{:>14.2}{:>14.2}",
            tag,
            crate::util::eng(cfg_size.n_params() as f64),
            vals["FP16"],
            vals["DB-LLM W2"],
            vals["AWQ W3"]
        );
        rows.push(Json::obj(vec![
            ("teacher", Json::str(tag)),
            ("params", Json::num(cfg_size.n_params() as f64)),
            ("fp16", Json::num(vals["FP16"])),
            ("dbllm_w2", Json::num(vals["DB-LLM W2"])),
            ("awq_w3", Json::num(vals["AWQ W3"])),
        ]));
    }
    let j = Json::obj(vec![("figure", Json::str("1")), ("rows", Json::Arr(rows))]);
    save_json(opts, "figure1", &j)?;
    Ok(j)
}

/// Fig. 3: grid-searched optimal levels of the first output projection.
pub fn figure3(rt: &mut Runtime, opts: &TableOpts) -> Result<Json> {
    use crate::quant::grid::{search, Format};
    let tag = opts.teachers.first().cloned().unwrap_or_else(|| "M".to_string());
    let weights = load_teacher(rt, &tag)?;
    let w = weights.mat("layers.0.wo");
    println!("\n== Figure 3 (optimal levels, first o_proj of teacher {tag}) ==");
    let mut rows = Vec::new();
    let mut spans = BTreeMap::new();
    for (fmt, name) in [(Format::Binary, "binarization"), (Format::Int2, "2-bit"), (Format::Fdb, "FDB")] {
        let res = search(&w.data, fmt, 60);
        println!(
            "{:<14} levels {:?}  span {:.4}  mse {:.6}",
            name,
            res.levels.iter().map(|l| (l * 1e4).round() / 1e4).collect::<Vec<_>>(),
            res.span,
            res.mse
        );
        spans.insert(name.to_string(), res.span as f64);
        rows.push(Json::obj(vec![
            ("format", Json::str(name)),
            ("levels", Json::Arr(res.levels.iter().map(|&l| Json::num(l as f64)).collect())),
            ("span", Json::num(res.span as f64)),
            ("mse", Json::num(res.mse)),
        ]));
    }
    println!(
        "span ratio binary/2-bit = {:.3} (paper: binarization span < half of 2-bit)",
        spans["binarization"] / spans["2-bit"]
    );
    let j = Json::obj(vec![("figure", Json::str("3")), ("rows", Json::Arr(rows))]);
    save_json(opts, "figure3", &j)?;
    Ok(j)
}

/// Fig. 4: loss landscapes over scale perturbations.
pub fn figure4(rt: &mut Runtime, opts: &TableOpts) -> Result<Json> {
    let tag = opts.teachers.first().cloned().unwrap_or_else(|| "M".to_string());
    let weights = load_teacher(rt, &tag)?;
    let info = rt.manifest.teacher(&tag)?;
    let calib_stream = TokenStream::load(rt.artifacts_dir.join(&info.calib))?;
    let pipeline = QuantPipeline::new(rt.manifest.seq_len());
    let calibs = pipeline.collect_calib(&weights, &calib_stream);
    let name = "layers.0.wo";
    let w = weights.mat(name);
    let calib = &calibs[name];
    let axis = landscape::default_axis(13);

    println!("\n== Figure 4 (loss landscape over scale perturbations, {name}) ==");
    let surfaces = [
        landscape::binary_landscape(w, calib, &axis),
        landscape::int2_landscape(w, calib, &axis),
        landscape::fdb_landscape(w, calib, &axis),
    ];
    let theta = 1.5 * surfaces[1].min_loss.max(surfaces[2].min_loss);
    println!("{:<14}{:>12}{:>12}{:>16}", "format", "min loss", "flatness", "sublevel@1.5x2b");
    let mut rows = Vec::new();
    for l in &surfaces {
        println!(
            "{:<14}{:>12.6}{:>12.3}{:>16.3}",
            l.method,
            l.min_loss,
            l.flatness,
            l.sublevel_fraction(theta)
        );
        rows.push(Json::obj(vec![
            ("format", Json::str(l.method.clone())),
            ("min_loss", Json::num(l.min_loss)),
            ("flatness", Json::num(l.flatness)),
            ("sublevel", Json::num(l.sublevel_fraction(theta))),
            (
                "surface",
                Json::Arr(
                    l.loss
                        .iter()
                        .map(|row| Json::Arr(row.iter().map(|&v| Json::num(v)).collect()))
                        .collect(),
                ),
            ),
        ]));
    }
    let j = Json::obj(vec![("figure", Json::str("4")), ("rows", Json::Arr(rows))]);
    save_json(opts, "figure4", &j)?;
    Ok(j)
}

/// Fig. 6: prediction-frequency histograms, FP vs 2-bit.
pub fn figure6(rt: &mut Runtime, opts: &TableOpts) -> Result<Json> {
    let tag = opts.teachers.first().cloned().unwrap_or_else(|| "M".to_string());
    let vocab = rt.manifest.vocab();
    let streams = load_streams(rt)?;
    let corpus_hist = streams["wiki"].unigram(vocab);

    let fp = make_student(rt, &tag, Method::Fp16, opts, None)?;
    let q2 = make_student(rt, &tag, Method::RtnW2, opts, None)?;
    let fp_sess = Session::new(rt, &fp.weights)?;
    let fp_hist = predstats::prediction_histogram(rt, &fp_sess, vocab, 8, 606)?;
    let q2_sess = Session::new(rt, &q2.weights)?;
    let q2_hist = predstats::prediction_histogram(rt, &q2_sess, vocab, 8, 606)?;

    let r_fp = predstats::head_tail_ratio(&fp_hist, &corpus_hist, 0.125);
    let r_q2 = predstats::head_tail_ratio(&q2_hist, &corpus_hist, 0.125);
    let tv_fp = predstats::tv_distance(&fp_hist, &corpus_hist);
    let tv_q2 = predstats::tv_distance(&q2_hist, &corpus_hist);
    println!("\n== Figure 6 (prediction distributions under random generation, teacher {tag}) ==");
    println!("{:<12}{:>18}{:>16}", "model", "head/tail vs ref", "TV vs corpus");
    println!("{:<12}{:>18.3}{:>16.3}", "FP16", r_fp, tv_fp);
    println!("{:<12}{:>18.3}{:>16.3}", "2-bit", r_q2, tv_q2);
    println!("(paper: low-bit model ~1.6x more head-biased than FP)");
    let j = Json::obj(vec![
        ("figure", Json::str("6")),
        ("head_tail_fp", Json::num(r_fp)),
        ("head_tail_2bit", Json::num(r_q2)),
        ("tv_fp", Json::num(tv_fp)),
        ("tv_2bit", Json::num(tv_q2)),
        ("hist_fp", Json::Arr(fp_hist.iter().map(|&v| Json::num(v as f64)).collect())),
        ("hist_2bit", Json::Arr(q2_hist.iter().map(|&v| Json::num(v as f64)).collect())),
    ]);
    save_json(opts, "figure6", &j)?;
    Ok(j)
}

/// Fig. 7: prediction entropy vs task loss.
pub fn figure7(rt: &mut Runtime, opts: &TableOpts) -> Result<Json> {
    let tag = opts.teachers.first().cloned().unwrap_or_else(|| "M".to_string());
    let vocab = rt.manifest.vocab();
    let streams = load_streams(rt)?;
    let t = rt.manifest.seq_len();
    let windows = streams["wiki"].sample_windows(32, t + 1, 707);

    let fp = make_student(rt, &tag, Method::Fp16, opts, None)?;
    let q2 = make_student(rt, &tag, Method::DbLlmNoDad, opts, None)?;
    let fp_sess = Session::new(rt, &fp.weights)?;
    let q2_sess = Session::new(rt, &q2.weights)?;
    let pts = predstats::entropy_vs_loss(rt, &fp_sess, &q2_sess, &windows, vocab)?;

    let r_teacher = predstats::pearson(&pts.teacher_entropy, &pts.loss);
    let r_student = predstats::pearson(&pts.student_entropy, &pts.loss);
    println!("\n== Figure 7 (entropy vs task loss, teacher {tag}) ==");
    println!("pearson(teacher entropy, loss) = {r_teacher:.3}");
    println!("pearson(student entropy, loss) = {r_student:.3}");
    let curve_t = predstats::binned_means(&pts.teacher_entropy, &pts.loss, 10);
    let curve_s = predstats::binned_means(&pts.student_entropy, &pts.loss, 10);
    println!("{:>12}{:>12}   {:>12}{:>12}", "H(teacher)", "loss", "H(student)", "loss");
    for i in 0..curve_t.len().min(curve_s.len()) {
        println!(
            "{:>12.3}{:>12.3}   {:>12.3}{:>12.3}",
            curve_t[i].0, curve_t[i].1, curve_s[i].0, curve_s[i].1
        );
    }
    let j = Json::obj(vec![
        ("figure", Json::str("7")),
        ("pearson_teacher", Json::num(r_teacher)),
        ("pearson_student", Json::num(r_student)),
        (
            "curve_teacher",
            Json::Arr(curve_t.iter().map(|&(x, y)| Json::Arr(vec![Json::num(x), Json::num(y)])).collect()),
        ),
        (
            "curve_student",
            Json::Arr(curve_s.iter().map(|&(x, y)| Json::Arr(vec![Json::num(x), Json::num(y)])).collect()),
        ),
    ]);
    save_json(opts, "figure7", &j)?;
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_grid_covers_paper_methods() {
        let grid = Method::main_grid();
        assert!(grid.contains(&Method::DbLlm));
        assert!(grid.contains(&Method::OmniW2));
        assert!(grid.contains(&Method::PbLlm));
        assert_eq!(grid.len(), 9);
    }

    #[test]
    fn labels_unique() {
        let mut labels: Vec<&str> = Method::main_grid().iter().map(|m| m.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 9);
    }
}
