//! Zero-shot multiple-choice evaluation (Tables 5 / 7).
//!
//! Protocol: each choice is scored by the length-normalized
//! log-likelihood of its continuation span given the context; argmax
//! wins (lm-eval-harness convention the paper's numbers use).

use anyhow::Result;

use crate::data::{TaskSuite, TokenStream, ZeroShotTask};
use crate::runtime::{session::pack_batch, Runtime, Session};

/// Accuracy of a session on one suite.
pub fn accuracy(
    rt: &mut Runtime,
    session: &Session,
    suite: &TaskSuite,
    stream: &TokenStream,
) -> Result<f64> {
    let items = suite.generate(stream);
    let width = session.seq_len + 1;
    let per_row = session.seq_len;
    let batch = session.nll_batch;

    // flatten all (item, choice) sequences, score in batches
    let mut seqs: Vec<Vec<u32>> = Vec::new();
    for item in &items {
        for i in 0..item.choices.len() {
            let s = item.sequence(i);
            anyhow::ensure!(s.len() == width, "task width {} != {width}", s.len());
            seqs.push(s);
        }
    }
    let mut scores = vec![0.0f64; seqs.len()];
    let mut i = 0;
    while i < seqs.len() {
        let chunk = &seqs[i..(i + batch).min(seqs.len())];
        let packed = pack_batch(chunk, batch, width)?;
        let nll = session.nll(rt, &packed)?;
        for (r, _) in chunk.iter().enumerate() {
            // continuation span = last cont_len positions
            let cont = suite.cont_len;
            let row = &nll[r * per_row..(r + 1) * per_row];
            let s: f64 = row[per_row - cont..].iter().map(|&v| v as f64).sum();
            scores[i + r] = -s / cont as f64; // normalized log-likelihood
        }
        i += chunk.len();
    }

    let mut correct = 0usize;
    let mut k = 0usize;
    for item in &items {
        let n = item.choices.len();
        let best = (0..n)
            .max_by(|&a, &b| scores[k + a].partial_cmp(&scores[k + b]).expect("scores are finite"))
            .expect("every task item has at least one choice");
        if best == item.answer {
            correct += 1;
        }
        k += n;
    }
    Ok(correct as f64 / items.len() as f64)
}

/// Native-forward scoring (slow path, used by tests).
pub fn accuracy_native(
    weights: &crate::model::Weights,
    suite: &TaskSuite,
    stream: &TokenStream,
    max_items: usize,
) -> f64 {
    let items: Vec<ZeroShotTask> = suite
        .generate(stream)
        .into_iter()
        .take(if max_items == 0 { usize::MAX } else { max_items })
        .collect();
    let mut fwd = crate::model::native::Forward::new(weights);
    let mut correct = 0usize;
    for item in &items {
        let cont = item.cont_len();
        let mut best = (f64::NEG_INFINITY, 0usize);
        for i in 0..item.choices.len() {
            let seq = item.sequence(i);
            let nll = fwd.nll(&seq);
            let score: f64 = -nll[nll.len() - cont..].iter().sum::<f64>() / cont as f64;
            if score > best.0 {
                best = (score, i);
            }
        }
        if best.1 == item.answer {
            correct += 1;
        }
    }
    correct as f64 / items.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Weights};
    use crate::util::Pcg32;

    #[test]
    fn random_model_near_chance() {
        let cfg = ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            vocab: 64,
            seq_len: 32,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        };
        let w = Weights::synthetic(&cfg, 1);
        let mut rng = Pcg32::seeded(3);
        let stream = TokenStream {
            tokens: (0..30_000).map(|_| rng.below(64)).collect(),
        };
        let suite = TaskSuite {
            name: "t4".into(),
            context_len: 20,
            cont_len: 4,
            n_choices: 4,
            hard_negatives: false,
            n_items: 60,
            seed: 5,
        };
        let acc = accuracy_native(&w, &suite, &stream, 60);
        // 4 choices -> chance 0.25; a random model must sit near it
        assert!((0.05..0.55).contains(&acc), "acc {acc}");
    }
}
