//! The quantization pipeline: calibration activations (native forward
//! over the data-free calib tokens) → per-linear quantization → a
//! dequantized `Weights` ready for the runtime, plus the packed FDB
//! layers when the method is DB-LLM.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::data::TokenStream;
use crate::model::{native::Forward, Weights};
use crate::quant::{Calib, FdbLinear, Quantizer};

/// Quantize a whole model with one method.
pub struct QuantPipeline {
    /// rows of activation sample per linear (subsampled)
    pub calib_rows: usize,
    /// number of calibration sequences to run (cost knob)
    pub calib_seqs: usize,
    pub seq_len: usize,
}

/// Result of quantizing a model.
pub struct QuantizedModel {
    pub weights: Weights,
    pub method: String,
    pub bits_per_weight: f64,
    /// present when the method produces FDB layers
    pub fdb_layers: BTreeMap<String, FdbLinear>,
    /// mean weight MSE across linears (diagnostics)
    pub mean_weight_mse: f64,
}

impl QuantPipeline {
    pub fn new(seq_len: usize) -> Self {
        QuantPipeline { calib_rows: 1024, calib_seqs: 16, seq_len }
    }

    /// Collect per-linear activation samples by running the native
    /// forward over calibration sequences.
    pub fn collect_calib(
        &self,
        weights: &Weights,
        calib: &TokenStream,
    ) -> BTreeMap<String, Calib> {
        let mut fwd = Forward::collecting(weights);
        for (i, win) in calib.windows(self.seq_len).enumerate() {
            if i >= self.calib_seqs {
                break;
            }
            let _ = fwd.run(win);
        }
        fwd.take_activations()
            .into_iter()
            .map(|(name, x)| (name, Calib::new(x).subsample(self.calib_rows)))
            .collect()
    }

    /// Quantize every linear of `weights` with `method`.
    pub fn quantize(
        &self,
        weights: &Weights,
        method: &dyn Quantizer,
        calib: &BTreeMap<String, Calib>,
    ) -> Result<QuantizedModel> {
        let mut fdb_layers = BTreeMap::new();
        let mut bits = 0.0f64;
        let mut mse = 0.0f64;
        let mut n = 0usize;
        let empty = Calib::empty(0);
        let quantized = weights.map_linears(|name, w| {
            let c = calib.get(name).unwrap_or(&empty);
            let q = method.quantize(w, c);
            bits += q.bits_per_weight;
            mse += q.w_hat.mse(w);
            n += 1;
            if let Some(fdb) = q.fdb {
                fdb_layers.insert(name.to_string(), fdb);
            }
            q.w_hat
        });
        Ok(QuantizedModel {
            weights: quantized,
            method: method.name(),
            bits_per_weight: bits / n as f64,
            fdb_layers,
            mean_weight_mse: mse / n as f64,
        })
    }

    /// Measured mean sparsity across all FDB layers (Table 6 input).
    pub fn fdb_sparsity(layers: &BTreeMap<String, FdbLinear>) -> (f64, f64, f64) {
        let mut s1 = 0.0;
        let mut s2 = 0.0;
        let n = layers.len().max(1) as f64;
        for l in layers.values() {
            s1 += l.b1.sparsity();
            s2 += l.b2.sparsity();
        }
        (s1 / n, s2 / n, 0.5 * (s1 + s2) / n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::quant::{fdb::Fdb, gptq::Gptq, rtn::Rtn};

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            vocab: 64,
            seq_len: 16,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    fn stream() -> TokenStream {
        TokenStream { tokens: (0..4000).map(|i| (i * 13 + 7) % 64).collect() }
    }

    #[test]
    fn calib_covers_all_linears() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 1);
        let p = QuantPipeline::new(cfg.seq_len);
        let calib = p.collect_calib(&w, &stream());
        assert_eq!(calib.len(), cfg.linear_names().len());
        for name in cfg.linear_names() {
            let c = &calib[&name];
            assert!(c.x.rows > 0);
            assert_eq!(c.x.cols, cfg.linear_shape(&name).0);
        }
    }

    #[test]
    fn rtn_pipeline_produces_quantized_weights() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 2);
        let p = QuantPipeline::new(cfg.seq_len);
        let calib = p.collect_calib(&w, &stream());
        let qm = p.quantize(&w, &Rtn::new(2, 64), &calib).unwrap();
        assert!(qm.mean_weight_mse > 0.0);
        assert!((qm.bits_per_weight - 2.25).abs() < 1e-9);
        assert!(qm.fdb_layers.is_empty());
        // non-linear params untouched
        assert_eq!(qm.weights.mat("tok_emb").data, w.mat("tok_emb").data);
    }

    #[test]
    fn fdb_pipeline_packs_all_linears() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 3);
        let p = QuantPipeline::new(cfg.seq_len);
        let calib = BTreeMap::new();
        let qm = p.quantize(&w, &Fdb { group: 64 }, &calib).unwrap();
        assert_eq!(qm.fdb_layers.len(), cfg.linear_names().len());
        let (s1, s2, avg) = QuantPipeline::fdb_sparsity(&qm.fdb_layers);
        assert!(avg > 0.4 && s1 > 0.0 && s2 > 0.0);
    }

    #[test]
    fn gptq_pipeline_not_worse_than_rtn_on_ppl_proxy() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 4);
        let p = QuantPipeline::new(cfg.seq_len);
        let calib = p.collect_calib(&w, &stream());
        let qg = p.quantize(&w, &Gptq::new(2, 64), &calib).unwrap();
        let qr = p.quantize(&w, &Rtn::new(2, 64), &calib).unwrap();
        // compare summed layer output MSE on the calib set
        let mut mg = 0.0;
        let mut mr = 0.0;
        for name in cfg.linear_names() {
            let c = &calib[&name];
            mg += c.output_mse(w.mat(&name), qg.weights.mat(&name));
            mr += c.output_mse(w.mat(&name), qr.weights.mat(&name));
        }
        assert!(mg <= mr * 1.05, "gptq {mg:.4e} rtn {mr:.4e}");
    }
}
