//! Loss-landscape study (Fig. 4): perturb the *trainable quantization
//! parameters* (the scales) of one linear layer along two fixed random
//! directions and measure output MSE against the full-precision layer
//! on calibration activations — the standard loss-landscape protocol
//! (filter-normalized random directions), applied equally to the three
//! formats the paper compares:
//!
//!   * binarization: θ = α (per-group),   ŵ = α·sign(w)
//!   * 2-bit:        θ = s (per-group),   ŵ = s·round(w/s).clamp(-2,1)
//!   * FDB:          θ = (α₁, α₂),        planes re-derived per Eq. 6-7
//!
//! Summary statistics: the minimum loss and `sublevel_fraction(θ)` —
//! the Fig. 4(d) juxtaposition (how much of the perturbation range each
//! format keeps below a shared absolute loss).

use crate::quant::{fdb::FdbLinear, rtn::proxy_scales, rtn::Rtn, Calib};
use crate::tensor::Matrix;
use crate::util::Pcg32;

#[derive(Clone, Debug)]
pub struct Landscape {
    pub method: String,
    /// grid of perturbation magnitudes per axis (relative, e.g. ±0.5)
    pub axis: Vec<f64>,
    /// `loss[i][j]` at (`axis[i]` along direction u, `axis[j]` along v)
    pub loss: Vec<Vec<f64>>,
    pub min_loss: f64,
    /// fraction of grid within 2x of this surface's own minimum
    pub flatness: f64,
}

impl Landscape {
    /// Fraction of the grid at or below an *absolute* loss threshold —
    /// the Fig. 4(d) juxtaposition statistic, comparable across methods.
    pub fn sublevel_fraction(&self, threshold: f64) -> f64 {
        let total = self.loss.iter().flatten().count() as f64;
        let within = self.loss.iter().flatten().filter(|&&l| l <= threshold).count() as f64;
        within / total
    }
}

fn summary(method: &str, axis: Vec<f64>, loss: Vec<Vec<f64>>) -> Landscape {
    let min_loss = loss.iter().flatten().cloned().fold(f64::INFINITY, f64::min);
    let total = loss.iter().flatten().count() as f64;
    let within =
        loss.iter().flatten().filter(|&&l| l <= 2.0 * min_loss + 1e-12).count() as f64;
    Landscape { method: method.into(), axis, loss, min_loss, flatness: within / total }
}

/// Default symmetric perturbation grid (relative magnitudes).
pub fn default_axis(steps: usize) -> Vec<f64> {
    (0..steps)
        .map(|i| -0.6 + 1.2 * i as f64 / (steps - 1) as f64)
        .collect()
}

/// Two filter-normalized random directions of the same shape as `theta`:
/// perturbed = θ ⊙ (1 + ε₁·u + ε₂·v).
fn directions(rows: usize, cols: usize, seed: u64) -> (Matrix, Matrix) {
    let mut rng = Pcg32::seeded(seed);
    (Matrix::randn(rows, cols, &mut rng, 1.0), Matrix::randn(rows, cols, &mut rng, 1.0))
}

fn perturb(theta: &Matrix, u: &Matrix, v: &Matrix, e1: f64, e2: f64) -> Matrix {
    let mut out = theta.clone();
    for i in 0..out.data.len() {
        out.data[i] *= 1.0 + (e1 as f32) * u.data[i] + (e2 as f32) * v.data[i];
    }
    out
}

/// Binarization surface: ŵ = α'·sign(w), α perturbed per group.
pub fn binary_landscape(w: &Matrix, calib: &Calib, axis: &[f64]) -> Landscape {
    let (_, alpha) = Rtn::new(1, 64).quantize_with_scales(w);
    let (u, v) = directions(alpha.rows, alpha.cols, 4001);
    let loss = grid(axis, |e1, e2| {
        let a = perturb(&alpha, &u, &v, e1, e2);
        let mut w_hat = Matrix::zeros(w.rows, w.cols);
        for c in 0..w.cols {
            for r in 0..w.rows {
                let s = a.at(r / 64, c);
                *w_hat.at_mut(r, c) = if w.at(r, c) >= 0.0 { s } else { -s };
            }
        }
        calib.output_mse(w, &w_hat)
    });
    summary("binarization", axis.to_vec(), loss)
}

/// 2-bit surface: grid scale perturbed, weights re-rounded.
pub fn int2_landscape(w: &Matrix, calib: &Calib, axis: &[f64]) -> Landscape {
    let (_, scales) = Rtn::new(2, 64).quantize_with_scales(w);
    let (u, v) = directions(scales.rows, scales.cols, 4002);
    let loss = grid(axis, |e1, e2| {
        let s = perturb(&scales, &u, &v, e1, e2);
        let mut w_hat = Matrix::zeros(w.rows, w.cols);
        for c in 0..w.cols {
            for r in 0..w.rows {
                let sc = s.at(r / 64, c).max(1e-8);
                let q = (w.at(r, c) / sc).round().clamp(-2.0, 1.0);
                *w_hat.at_mut(r, c) = q * sc;
            }
        }
        calib.output_mse(w, &w_hat)
    });
    summary("2-bit", axis.to_vec(), loss)
}

/// FDB surface: (α₁, α₂) perturbed along a *shared* pair of directions
/// (the same per-group noise hits both scales, keeping the axes
/// comparable with the 1-parameter formats), planes re-derived per
/// Eq. 6-7 at every grid point — the paper's flexibility mechanism.
pub fn fdb_landscape(w: &Matrix, calib: &Calib, axis: &[f64]) -> Landscape {
    let s = proxy_scales(w, 64);
    let a1_0 = s.scale(2.0);
    let a2_0 = s.scale(-1.0);
    let (u, v) = directions(s.rows, s.cols, 4003);
    let loss = grid(axis, |e1, e2| {
        let a1 = perturb(&a1_0, &u, &v, e1, e2);
        let a2 = perturb(&a2_0, &u, &v, e1, e2);
        let f = FdbLinear::from_scales(w, &a1, &a2, 64);
        calib.output_mse(w, &f.dequant())
    });
    summary("FDB", axis.to_vec(), loss)
}

fn grid(axis: &[f64], mut f: impl FnMut(f64, f64) -> f64) -> Vec<Vec<f64>> {
    axis.iter().map(|&e1| axis.iter().map(|&e2| f(e1, e2)).collect()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Matrix, Calib) {
        let mut rng = Pcg32::seeded(91);
        let w = Matrix::randn(128, 64, &mut rng, 1.0);
        let x = Matrix::randn(96, 128, &mut rng, 1.0);
        (w, Calib::new(x))
    }

    #[test]
    fn fig4_shape_fdb_lower_and_at_least_as_flat() {
        let (w, calib) = setup();
        let axis = default_axis(9);
        let bin = binary_landscape(&w, &calib, &axis);
        let fdb = fdb_landscape(&w, &calib, &axis);
        let int2 = int2_landscape(&w, &calib, &axis);
        // Fig. 4 ordering: FDB min ≈ 2-bit min << binary min
        assert!(fdb.min_loss < bin.min_loss, "{} vs {}", fdb.min_loss, bin.min_loss);
        assert!(fdb.min_loss <= int2.min_loss * 1.1);
        // Fig. 4(d): at a shared absolute threshold FDB keeps the loss
        // low over at least as much of the range as the other formats
        let theta = 1.5 * int2.min_loss.max(fdb.min_loss);
        assert!(
            fdb.sublevel_fraction(theta) + 1e-9 >= int2.sublevel_fraction(theta),
            "fdb {} int2 {}",
            fdb.sublevel_fraction(theta),
            int2.sublevel_fraction(theta)
        );
        assert!(fdb.sublevel_fraction(theta) >= bin.sublevel_fraction(theta));
    }

    #[test]
    fn landscape_dims() {
        let (w, calib) = setup();
        let axis = default_axis(5);
        let l = fdb_landscape(&w, &calib, &axis);
        assert_eq!(l.loss.len(), 5);
        assert!(l.loss.iter().all(|r| r.len() == 5));
        assert!(l.min_loss.is_finite());
        assert!((0.0..=1.0).contains(&l.flatness));
    }

    #[test]
    fn min_near_zero_perturbation_for_fdb() {
        // the init scales are near-optimal: the surface minimum should be
        // close to the loss at (0, 0)
        let (w, calib) = setup();
        let axis = default_axis(9);
        let l = fdb_landscape(&w, &calib, &axis);
        let mid = axis.iter().position(|&a| a.abs() < 1e-9).unwrap();
        let at_zero = l.loss[mid][mid];
        assert!(at_zero <= 2.5 * l.min_loss, "zero {} min {}", at_zero, l.min_loss);
    }

    #[test]
    fn loss_grows_away_from_center() {
        let (w, calib) = setup();
        let axis = default_axis(9);
        for l in [
            binary_landscape(&w, &calib, &axis),
            int2_landscape(&w, &calib, &axis),
            fdb_landscape(&w, &calib, &axis),
        ] {
            let mid = axis.len() / 2;
            let center = l.loss[mid][mid];
            let corner = l.loss[0][0]
                .min(l.loss[0][axis.len() - 1])
                .min(l.loss[axis.len() - 1][0])
                .min(l.loss[axis.len() - 1][axis.len() - 1]);
            assert!(corner >= center * 0.9, "{}: corner {corner} center {center}", l.method);
        }
    }
}
