//! Incremental single-position forward over a [`KvCache`].
//!
//! `prefill` runs the prompt as one batched pass (identical math to
//! `model::native::Forward::run` — it shares the same rmsnorm / RoPE /
//! attention primitives) while filling the cache; each `step` then
//! costs one Q/K/V/O projection + attention over the cached window +
//! MLP, i.e. O(T) per decoded token instead of the O(T²) of re-running
//! the whole window.
//!
//! Every linear dispatches through [`LinearOp`]: dense fp weights, or a
//! compiled [`FdbExec`] so a dual-binarized student decodes on the
//! paper's sparse bitwise-derived kernel end to end.
//!
//! Equivalence contract: while the total sequence length stays within
//! the cache window, prefill + steps produce the same logits as the
//! batched forward over the same tokens (fp tolerance).  Once the
//! window slides, the cached path keeps each evicted-era token's K/V as
//! computed at its own decode time (streaming attention), whereas full
//! recompute re-encodes the truncated window — the two decode modes
//! legitimately diverge there (see `rust/README.md` §Backends).

use std::collections::BTreeMap;

use crate::model::native::{
    apply_rope, attend_one, causal_attention, rmsnorm, rmsnorm_row, rope_pos, rope_row,
    rope_tables, silu,
};
use crate::model::{ModelConfig, Weights};
use crate::quant::kernel::FdbExec;
use crate::quant::FdbLinear;
use crate::runtime::session::recent_window;
use crate::tensor::Matrix;

use super::kv::KvCache;

/// y = xᵀ·W for dense `[din, dout]` weights (row-major, zero-skipping
/// like `Matrix::matmul`).
pub fn dense_matvec(w: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), w.rows, "matvec input width");
    assert_eq!(y.len(), w.cols, "matvec output width");
    y.fill(0.0);
    for (k, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        for (o, &wv) in y.iter_mut().zip(w.row(k)) {
            *o += xv * wv;
        }
    }
}

/// One linear layer in either execution form.
pub enum LinearOp {
    /// dense fp weights `[din, dout]`
    Dense(Matrix),
    /// compiled dual-binarized layer — the paper's sparse kernel
    Fdb(FdbExec),
}

impl LinearOp {
    pub fn din(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.rows,
            LinearOp::Fdb(e) => e.din,
        }
    }

    pub fn dout(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.cols,
            LinearOp::Fdb(e) => e.dout,
        }
    }

    /// Single-row product (the decode-step hot path; allocation-free).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        match self {
            LinearOp::Dense(w) => dense_matvec(w, x, y),
            LinearOp::Fdb(e) => e.matvec(x, y),
        }
    }

    /// Batched product (the prefill path).
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        match self {
            LinearOp::Dense(w) => x.matmul(w),
            LinearOp::Fdb(e) => e.matmul(x),
        }
    }
}

/// One decoder layer's operators.
struct LayerOps {
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
    wq: LinearOp,
    wk: LinearOp,
    wv: LinearOp,
    wo: LinearOp,
    w_gate: LinearOp,
    w_up: LinearOp,
    w_down: LinearOp,
}

/// Reused per-step buffers — a decode step allocates nothing but the
/// returned logits row.
struct StepScratch {
    x: Vec<f32>,
    hn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    act: Vec<f32>,
    down: Vec<f32>,
    scores: Vec<f64>,
}

impl StepScratch {
    fn new(d: usize, d_ff: usize) -> StepScratch {
        StepScratch {
            x: vec![0.0; d],
            hn: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            ctx: vec![0.0; d],
            proj: vec![0.0; d],
            gate: vec![0.0; d_ff],
            up: vec![0.0; d_ff],
            act: vec![0.0; d_ff],
            down: vec![0.0; d],
            scores: Vec::new(),
        }
    }
}

/// The incremental model: embeddings/norms/head plus per-layer
/// [`LinearOp`]s, stateless across requests (all sequence state lives
/// in the caller's [`KvCache`]).
pub struct IncrementalForward {
    pub cfg: ModelConfig,
    tok_emb: Matrix,
    head: Matrix,
    final_norm: Vec<f32>,
    layers: Vec<LayerOps>,
    scratch: StepScratch,
}

impl IncrementalForward {
    /// Build from a full weight set; every linear named in `fdb` is
    /// compiled to the sparse [`FdbExec`] form (its dense copy is
    /// dropped), the rest stay dense.
    pub fn new(weights: Weights, fdb: &BTreeMap<String, FdbLinear>) -> IncrementalForward {
        let Weights { config: cfg, mut mats, mut vecs } = weights;
        let take = |mats: &mut BTreeMap<String, Matrix>, name: &str| -> LinearOp {
            let dense = mats.remove(name).unwrap_or_else(|| panic!("missing linear {name}"));
            match fdb.get(name) {
                Some(layer) => {
                    assert_eq!((layer.din, layer.dout), (dense.rows, dense.cols), "{name} shape");
                    LinearOp::Fdb(FdbExec::compile(layer))
                }
                None => LinearOp::Dense(dense),
            }
        };
        let layers = (0..cfg.n_layers)
            .map(|l| {
                let pre = format!("layers.{l}.");
                LayerOps {
                    attn_norm: vecs.remove(&format!("{pre}attn_norm")).expect("attn_norm"),
                    mlp_norm: vecs.remove(&format!("{pre}mlp_norm")).expect("mlp_norm"),
                    wq: take(&mut mats, &format!("{pre}wq")),
                    wk: take(&mut mats, &format!("{pre}wk")),
                    wv: take(&mut mats, &format!("{pre}wv")),
                    wo: take(&mut mats, &format!("{pre}wo")),
                    w_gate: take(&mut mats, &format!("{pre}w_gate")),
                    w_up: take(&mut mats, &format!("{pre}w_up")),
                    w_down: take(&mut mats, &format!("{pre}w_down")),
                }
            })
            .collect();
        let scratch = StepScratch::new(cfg.d_model, cfg.d_ff);
        IncrementalForward {
            tok_emb: mats.remove("tok_emb").expect("tok_emb"),
            head: mats.remove("head").expect("head"),
            final_norm: vecs.remove("final_norm").expect("final_norm"),
            layers,
            cfg,
            scratch,
        }
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    /// Number of FDB-compiled linears (diagnostics).
    pub fn n_fdb_ops(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_gate, &l.w_up, &l.w_down])
            .filter(|op| matches!(op, LinearOp::Fdb(_)))
            .count()
    }

    /// Run the prompt in one batched pass, filling `cache` (which must
    /// be cleared); prompts longer than the window keep the last
    /// `cache.window` tokens.  Returns the logits row at the last
    /// prompt position — the distribution of the first decoded token.
    pub fn prefill(&mut self, cache: &mut KvCache, tokens: &[u32]) -> Vec<f32> {
        assert!(cache.is_empty(), "prefill expects a cleared cache");
        assert_eq!(cache.width, self.cfg.d_model, "cache width != d_model");
        let toks = recent_window(tokens, cache.window);
        assert!(!toks.is_empty(), "empty prompt");
        let cfg = &self.cfg;
        let (t, d) = (toks.len(), cfg.d_model);
        let (h, hd) = (cfg.n_heads, cfg.head_dim());

        let mut x = Matrix::zeros(t, d);
        for (i, &tok) in toks.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.tok_emb.row(tok as usize));
        }
        let (cos, sin) = rope_tables(t, hd, cfg.rope_theta);
        // cache is empty and t <= window: no eviction during the pass
        let slots: Vec<usize> = (0..t).map(|_| cache.advance()).collect();

        for (l, layer) in self.layers.iter().enumerate() {
            let hn = rmsnorm(&x, &layer.attn_norm, cfg.rmsnorm_eps);
            let mut q = layer.wq.matmul(&hn);
            let mut k = layer.wk.matmul(&hn);
            let v = layer.wv.matmul(&hn);
            apply_rope(&mut q, h, hd, &cos, &sin);
            apply_rope(&mut k, h, hd, &cos, &sin);
            for (i, &slot) in slots.iter().enumerate() {
                cache.write(l, slot, k.row(i), v.row(i));
            }
            let ctx = causal_attention(&q, &k, &v, h, hd);
            let proj = layer.wo.matmul(&ctx);
            x = x.add(&proj);
            let hn = rmsnorm(&x, &layer.mlp_norm, cfg.rmsnorm_eps);
            let gate = layer.w_gate.matmul(&hn);
            let up = layer.w_up.matmul(&hn);
            let mut act = Matrix::zeros(t, cfg.d_ff);
            for i in 0..t * cfg.d_ff {
                act.data[i] = silu(gate.data[i]) * up.data[i];
            }
            let down = layer.w_down.matmul(&act);
            x = x.add(&down);
        }

        rmsnorm_row(x.row(t - 1), &self.final_norm, cfg.rmsnorm_eps, &mut self.scratch.hn);
        let mut logits = vec![0.0f32; cfg.vocab];
        dense_matvec(&self.head, &self.scratch.hn, &mut logits);
        logits
    }

    /// One decode step: append `token` to the cached sequence and
    /// return the next-token logits.  Cost is O(window), independent of
    /// how many tokens were decoded before — the tentpole property.
    pub fn step(&mut self, cache: &mut KvCache, token: u32) -> Vec<f32> {
        let cfg = &self.cfg;
        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        assert!((token as usize) < cfg.vocab, "token {token} out of vocab");
        assert_eq!(cache.width, cfg.d_model, "cache width != d_model");

        let (cos, sin) = rope_pos(cache.next_pos(), hd, cfg.rope_theta);
        let slot = cache.advance();
        self.scratch.x.copy_from_slice(self.tok_emb.row(token as usize));

        for (l, layer) in self.layers.iter().enumerate() {
            // attention
            rmsnorm_row(&self.scratch.x, &layer.attn_norm, cfg.rmsnorm_eps, &mut self.scratch.hn);
            layer.wq.matvec(&self.scratch.hn, &mut self.scratch.q);
            layer.wk.matvec(&self.scratch.hn, &mut self.scratch.k);
            layer.wv.matvec(&self.scratch.hn, &mut self.scratch.v);
            rope_row(&mut self.scratch.q, h, hd, &cos, &sin);
            rope_row(&mut self.scratch.k, h, hd, &cos, &sin);
            cache.write(l, slot, &self.scratch.k, &self.scratch.v);
            let n = cache.len();
            attend_one(
                &self.scratch.q,
                n,
                |i| cache.k_row(l, i),
                |i| cache.v_row(l, i),
                h,
                hd,
                &mut self.scratch.scores,
                &mut self.scratch.ctx,
            );
            layer.wo.matvec(&self.scratch.ctx, &mut self.scratch.proj);
            for (xi, &p) in self.scratch.x.iter_mut().zip(&self.scratch.proj) {
                *xi += p;
            }
            // mlp
            rmsnorm_row(&self.scratch.x, &layer.mlp_norm, cfg.rmsnorm_eps, &mut self.scratch.hn);
            layer.w_gate.matvec(&self.scratch.hn, &mut self.scratch.gate);
            layer.w_up.matvec(&self.scratch.hn, &mut self.scratch.up);
            for i in 0..cfg.d_ff {
                self.scratch.act[i] = silu(self.scratch.gate[i]) * self.scratch.up[i];
            }
            layer.w_down.matvec(&self.scratch.act, &mut self.scratch.down);
            for (xi, &p) in self.scratch.x.iter_mut().zip(&self.scratch.down) {
                *xi += p;
            }
        }

        rmsnorm_row(&self.scratch.x, &self.final_norm, cfg.rmsnorm_eps, &mut self.scratch.hn);
        let mut logits = vec![0.0f32; cfg.vocab];
        dense_matvec(&self.head, &self.scratch.hn, &mut logits);
        logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            vocab: 96,
            seq_len: 32,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    #[test]
    fn dense_matvec_matches_matmul() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::randn(48, 24, &mut rng, 1.0);
        let x = Matrix::randn(1, 48, &mut rng, 1.0);
        let mut y = vec![0.0f32; 24];
        dense_matvec(&w, x.row(0), &mut y);
        let y_ref = x.matmul(&w);
        for (a, b) in y.iter().zip(&y_ref.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn prefill_then_step_logits_are_finite_and_shaped() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 11);
        let mut f = IncrementalForward::new(w, &BTreeMap::new());
        let mut cache = KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model);
        let l0 = f.prefill(&mut cache, &[1, 2, 3]);
        assert_eq!(l0.len(), cfg.vocab);
        assert!(l0.iter().all(|v| v.is_finite()));
        assert_eq!(cache.len(), 3);
        let l1 = f.step(&mut cache, 4);
        assert_eq!(l1.len(), cfg.vocab);
        assert!(l1.iter().all(|v| v.is_finite()));
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.next_pos(), 4);
    }

    #[test]
    fn fdb_ops_are_compiled_in() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 12);
        let mut fdb = BTreeMap::new();
        for name in cfg.linear_names() {
            fdb.insert(name.clone(), FdbLinear::from_weights(w.mat(&name), 64));
        }
        let f = IncrementalForward::new(w, &fdb);
        assert_eq!(f.n_fdb_ops(), cfg.linear_names().len());
    }

    #[test]
    fn long_prompt_keeps_recent_window() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 13);
        let mut f = IncrementalForward::new(w, &BTreeMap::new());
        let window = 4;
        let mut cache = KvCache::new(cfg.n_layers, window, cfg.d_model);
        let long: Vec<u32> = (0..10u32).collect();
        let full = f.prefill(&mut cache, &long);
        assert_eq!(cache.len(), window);
        // same logits as prefilling just the tail explicitly
        let mut cache2 = KvCache::new(cfg.n_layers, window, cfg.d_model);
        let tail = f.prefill(&mut cache2, &long[long.len() - window..]);
        for (a, b) in full.iter().zip(&tail) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
