//! Incremental single-position forward over a [`KvCache`].
//!
//! `prefill` runs the prompt as one batched pass (identical math to
//! `model::native::Forward::run` — it shares the same rmsnorm / RoPE /
//! attention primitives) while filling the cache; each `step` then
//! costs one Q/K/V/O projection + attention over the cached window +
//! MLP, i.e. O(T) per decoded token instead of the O(T²) of re-running
//! the whole window.
//!
//! Every linear dispatches through [`LinearOp`]: dense fp weights, or a
//! compiled [`FdbExec`] so a dual-binarized student decodes on the
//! paper's sparse bitwise-derived kernel end to end.
//!
//! Equivalence contract: while the total sequence length stays within
//! the cache window, prefill + steps produce the same logits as the
//! batched forward over the same tokens (fp tolerance) — and prefill,
//! [`IncrementalForward::prefill_suffix`] and `step` are bit-identical
//! to *each other* (all three run the same per-row primitives in the
//! same order: `rmsnorm_row`, per-row-exact batched matmuls,
//! per-position RoPE, `attend_one`), which is what makes warm
//! (cached-prefix) and cold prefill emit identical token streams
//! (`tests/prefix_cache.rs`).  Once the window slides, the cached path
//! keeps each evicted-era token's K/V as computed at its own decode
//! time (streaming attention), whereas full recompute re-encodes the
//! truncated window — the two decode modes legitimately diverge there
//! (see `rust/README.md` §Backends).

use std::collections::BTreeMap;

use crate::model::native::{attend_one, rmsnorm_row, rope_pos_into, rope_row, silu};
use crate::model::{ModelConfig, Weights};
use crate::quant::kernel::{FdbExec, FdbScratch};
use crate::quant::FdbLinear;
use crate::runtime::session::recent_window;
use crate::tensor::Matrix;

use super::kv::{advance_rows, write_rows, KvCache};

/// y = xᵀ·W for dense `[din, dout]` weights (row-major, zero-skipping
/// like `Matrix::matmul`).
pub fn dense_matvec(w: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), w.rows, "matvec input width");
    assert_eq!(y.len(), w.cols, "matvec output width");
    y.fill(0.0);
    for (k, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        for (o, &wv) in y.iter_mut().zip(w.row(k)) {
            *o += xv * wv;
        }
    }
}

/// y = x·W for dense `[din, dout]` weights into a caller-owned
/// `[m, dout]` row-major buffer — the batched counterpart of
/// [`dense_matvec`] with the identical per-row operation order (same
/// zero-skipping ikj loop as `Matrix::matmul`), which is what keeps
/// the fused and sequential decode paths bit-identical.
pub fn dense_matmul_rows(w: &Matrix, x: &Matrix, y: &mut [f32]) {
    assert_eq!(x.cols, w.rows, "matmul input width");
    assert_eq!(y.len(), x.rows * w.cols, "output buffer is not [m, dout]");
    let n = w.cols;
    for r in 0..x.rows {
        let yrow = &mut y[r * n..(r + 1) * n];
        yrow.fill(0.0);
        for (k, &xv) in x.row(r).iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            for (o, &wv) in yrow.iter_mut().zip(w.row(k)) {
                *o += xv * wv;
            }
        }
    }
}

/// One linear layer in either execution form.
pub enum LinearOp {
    /// dense fp weights `[din, dout]`
    Dense(Matrix),
    /// compiled dual-binarized layer — the paper's sparse kernel
    Fdb(FdbExec),
}

impl LinearOp {
    /// Input width.
    pub fn din(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.rows,
            LinearOp::Fdb(e) => e.din,
        }
    }

    /// Output width.
    pub fn dout(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.cols,
            LinearOp::Fdb(e) => e.dout,
        }
    }

    /// Single-row product (the decode-step hot path; allocation-free).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        match self {
            LinearOp::Dense(w) => dense_matvec(w, x, y),
            LinearOp::Fdb(e) => e.matvec(x, y),
        }
    }

    /// Batched product (the prefill path).
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        match self {
            LinearOp::Dense(w) => x.matmul(w),
            LinearOp::Fdb(e) => e.matmul(x),
        }
    }

    /// Batched product into a reused output — the fused multi-slot
    /// decode hot path (one call per linear per tick advances every
    /// active row).  `out` is reshaped to `[m, dout]` around its kept
    /// allocation; dense weights run the ikj loop, FDB layers the CSC
    /// kernel with the batch innermost and no output transpose — both
    /// with the same per-row operation order as
    /// [`matvec`](Self::matvec), so fused and sequential steps agree
    /// bit-for-bit.
    pub fn matmul_rows(&self, x: &Matrix, out: &mut Matrix, scratch: &mut FdbScratch) {
        set_shape(out, x.rows, self.dout());
        match self {
            LinearOp::Dense(w) => dense_matmul_rows(w, x, &mut out.data),
            LinearOp::Fdb(e) => e.matmul_rows(x, &mut out.data, scratch),
        }
    }
}

/// Reshape a reused matrix around its kept allocation (callers fully
/// overwrite the data, so stale values never leak).
fn set_shape(mat: &mut Matrix, rows: usize, cols: usize) {
    mat.rows = rows;
    mat.cols = cols;
    mat.data.resize(rows * cols, 0.0);
}

/// rmsnorm into a reused output matrix — the fused-step counterpart of
/// [`crate::model::native::rmsnorm`], built on the same row primitive.
fn rmsnorm_rows(x: &Matrix, gain: &[f32], eps: f64, out: &mut Matrix) {
    set_shape(out, x.rows, x.cols);
    for r in 0..x.rows {
        rmsnorm_row(x.row(r), gain, eps, out.row_mut(r));
    }
}

/// One decoder layer's operators.
struct LayerOps {
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
    wq: LinearOp,
    wk: LinearOp,
    wv: LinearOp,
    wo: LinearOp,
    w_gate: LinearOp,
    w_up: LinearOp,
    w_down: LinearOp,
}

/// Reused per-step buffers — a decode step allocates nothing but the
/// returned logits row.
struct StepScratch {
    x: Vec<f32>,
    hn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    act: Vec<f32>,
    down: Vec<f32>,
    scores: Vec<f64>,
    /// (cos, sin) half-rows at the stepped position — filled in place
    /// each step so the hot path never allocates for RoPE
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl StepScratch {
    fn new(d: usize, d_ff: usize, half: usize) -> StepScratch {
        StepScratch {
            x: vec![0.0; d],
            hn: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            ctx: vec![0.0; d],
            proj: vec![0.0; d],
            gate: vec![0.0; d_ff],
            up: vec![0.0; d_ff],
            act: vec![0.0; d_ff],
            down: vec![0.0; d],
            scores: Vec::new(),
            cos: vec![0.0; half],
            sin: vec![0.0; half],
        }
    }
}

/// Reused fused-step buffers — the batched counterpart of
/// [`StepScratch`], reshaped on demand for each tick's row count and
/// kept across ticks (pre-sized via
/// [`IncrementalForward::reserve_rows`], so a steady-state fused step
/// allocates nothing but the returned logits rows).
struct RowsScratch {
    fdb: FdbScratch,
    x: Matrix,
    hn: Matrix,
    q: Matrix,
    k: Matrix,
    v: Matrix,
    ctx: Matrix,
    proj: Matrix,
    gate: Matrix,
    up: Matrix,
    act: Matrix,
    down: Matrix,
    logits: Matrix,
    /// per-row (cos, sin) half-rows at each row's own absolute position
    cos: Vec<f32>,
    sin: Vec<f32>,
    /// cache index per row (mirrors the `rows` argument)
    slots: Vec<usize>,
    /// ring slot per row, reserved by `advance_rows`
    ring: Vec<usize>,
    /// cached positions visible to each row's attention: for a row of a
    /// repeated cache, later rows of the same cache are excluded (their
    /// K/V is already written when attention runs, but a causal row
    /// must not see them)
    vis: Vec<usize>,
    scores: Vec<f64>,
}

impl RowsScratch {
    fn new() -> RowsScratch {
        RowsScratch {
            fdb: FdbScratch::default(),
            x: Matrix::zeros(0, 0),
            hn: Matrix::zeros(0, 0),
            q: Matrix::zeros(0, 0),
            k: Matrix::zeros(0, 0),
            v: Matrix::zeros(0, 0),
            ctx: Matrix::zeros(0, 0),
            proj: Matrix::zeros(0, 0),
            gate: Matrix::zeros(0, 0),
            up: Matrix::zeros(0, 0),
            act: Matrix::zeros(0, 0),
            down: Matrix::zeros(0, 0),
            logits: Matrix::zeros(0, 0),
            cos: Vec::new(),
            sin: Vec::new(),
            slots: Vec::new(),
            ring: Vec::new(),
            vis: Vec::new(),
            scores: Vec::new(),
        }
    }

    /// Shape the buffers `step_rows` writes before the batched
    /// products (everything else is reshaped by its producer).
    fn ensure(&mut self, m: usize, d: usize, half: usize) {
        set_shape(&mut self.x, m, d);
        set_shape(&mut self.ctx, m, d);
        self.cos.resize(m * half, 0.0);
        self.sin.resize(m * half, 0.0);
        self.slots.clear();
    }
}

/// The incremental model: embeddings/norms/head plus per-layer
/// [`LinearOp`]s, stateless across requests (all sequence state lives
/// in the caller's [`KvCache`]).
pub struct IncrementalForward {
    /// the model geometry these operators were built from
    pub cfg: ModelConfig,
    tok_emb: Matrix,
    head: Matrix,
    final_norm: Vec<f32>,
    layers: Vec<LayerOps>,
    scratch: StepScratch,
    rows_scratch: RowsScratch,
}

impl IncrementalForward {
    /// Build from a full weight set; every linear named in `fdb` is
    /// compiled to the sparse [`FdbExec`] form (its dense copy is
    /// dropped), the rest stay dense.
    pub fn new(weights: Weights, fdb: &BTreeMap<String, FdbLinear>) -> IncrementalForward {
        let Weights { config: cfg, mut mats, mut vecs } = weights;
        let take = |mats: &mut BTreeMap<String, Matrix>, name: &str| -> LinearOp {
            let dense = mats.remove(name).unwrap_or_else(|| panic!("missing linear {name}"));
            match fdb.get(name) {
                Some(layer) => {
                    assert_eq!((layer.din, layer.dout), (dense.rows, dense.cols), "{name} shape");
                    LinearOp::Fdb(FdbExec::compile(layer))
                }
                None => LinearOp::Dense(dense),
            }
        };
        let layers = (0..cfg.n_layers)
            .map(|l| {
                let pre = format!("layers.{l}.");
                LayerOps {
                    attn_norm: vecs.remove(&format!("{pre}attn_norm")).expect("attn_norm"),
                    mlp_norm: vecs.remove(&format!("{pre}mlp_norm")).expect("mlp_norm"),
                    wq: take(&mut mats, &format!("{pre}wq")),
                    wk: take(&mut mats, &format!("{pre}wk")),
                    wv: take(&mut mats, &format!("{pre}wv")),
                    wo: take(&mut mats, &format!("{pre}wo")),
                    w_gate: take(&mut mats, &format!("{pre}w_gate")),
                    w_up: take(&mut mats, &format!("{pre}w_up")),
                    w_down: take(&mut mats, &format!("{pre}w_down")),
                }
            })
            .collect();
        let scratch = StepScratch::new(cfg.d_model, cfg.d_ff, cfg.head_dim() / 2);
        IncrementalForward {
            tok_emb: mats.remove("tok_emb").expect("tok_emb"),
            head: mats.remove("head").expect("head"),
            final_norm: vecs.remove("final_norm").expect("final_norm"),
            layers,
            cfg,
            scratch,
            rows_scratch: RowsScratch::new(),
        }
    }

    /// Pre-size the fused-step buffers for up to `max_rows` active rows
    /// over a `window`-entry cache, so the first fused decode tick pays
    /// no allocation (engines call this at build time, once the slot
    /// count is known).
    pub fn reserve_rows(&mut self, max_rows: usize, window: usize) {
        let m = max_rows.max(1);
        let cfg = &self.cfg;
        let (d, d_ff) = (cfg.d_model, cfg.d_ff);
        let half = cfg.head_dim() / 2;
        let wide = d.max(d_ff);
        let s = &mut self.rows_scratch;
        s.fdb.reserve(m, wide, wide);
        set_shape(&mut s.x, m, d);
        set_shape(&mut s.hn, m, d);
        set_shape(&mut s.q, m, d);
        set_shape(&mut s.k, m, d);
        set_shape(&mut s.v, m, d);
        set_shape(&mut s.ctx, m, d);
        set_shape(&mut s.proj, m, d);
        set_shape(&mut s.gate, m, d_ff);
        set_shape(&mut s.up, m, d_ff);
        set_shape(&mut s.act, m, d_ff);
        set_shape(&mut s.down, m, d);
        set_shape(&mut s.logits, m, cfg.vocab);
        s.cos.resize(m * half, 0.0);
        s.sin.resize(m * half, 0.0);
        s.slots.reserve(m);
        s.ring.reserve(m);
        s.vis.reserve(m);
        s.scores.reserve(window);
    }

    /// Vocabulary size (logits row width).
    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    /// Number of FDB-compiled linears (diagnostics).
    pub fn n_fdb_ops(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_gate, &l.w_up, &l.w_down])
            .filter(|op| matches!(op, LinearOp::Fdb(_)))
            .count()
    }

    /// Run the prompt in one batched pass, filling `cache` (which must
    /// be cleared); prompts longer than the window keep the last
    /// `cache.window` tokens.  Returns the logits row at the last
    /// prompt position — the distribution of the first decoded token.
    ///
    /// Implemented as [`prefill_suffix`](Self::prefill_suffix) from
    /// position 0, so a cold prefill and a warm (cached-prefix) one run
    /// the exact same code over the suffix rows — the root of the
    /// bit-identical warm-vs-cold guarantee.
    pub fn prefill(&mut self, cache: &mut KvCache, tokens: &[u32]) -> Vec<f32> {
        assert!(cache.is_empty(), "prefill expects a cleared cache");
        let toks = recent_window(tokens, cache.window);
        assert!(!toks.is_empty(), "empty prompt");
        self.prefill_suffix(cache, toks)
    }

    /// Batched prefill of a *suffix*: append `tokens` to the sequence
    /// already cached (possibly none), attending over the cached prefix
    /// rows plus the in-pass suffix rows.  This is the entry the
    /// cross-request prefix cache uses — the matched prefix's pool
    /// blocks are spliced in by handle ([`KvCache::append_shared`],
    /// zero row copies) and only the uncached suffix pays model work.
    /// Returns the logits at the last suffix position.
    ///
    /// Requirements: the cache must not have slid (`next_pos == len`,
    /// always true for imported prefixes) and prefix + suffix must fit
    /// the window — callers with longer prompts take the cold
    /// [`prefill`](Self::prefill) path instead.
    ///
    /// Equivalence: every per-row operation (rmsnorm, the batched
    /// matmuls, RoPE, attention, residual adds) is independent of which
    /// other rows share the batch, so splitting a prompt into
    /// prefix-import + suffix passes is **bit-identical** to one cold
    /// pass over the whole prompt.
    pub fn prefill_suffix(&mut self, cache: &mut KvCache, tokens: &[u32]) -> Vec<f32> {
        let cfg = &self.cfg;
        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        let (d, d_ff) = (cfg.d_model, cfg.d_ff);
        let half = hd / 2;
        let ts = tokens.len();
        let base = cache.len();
        assert!(ts > 0, "empty suffix");
        assert_eq!(cache.width, d, "cache width != d_model");
        assert_eq!(cache.next_pos(), base, "suffix prefill needs an unslid cache");
        assert!(base + ts <= cache.window, "prefix + suffix overflow the window");
        for &t in tokens {
            assert!((t as usize) < cfg.vocab, "token {t} out of vocab");
        }

        let s = &mut self.rows_scratch;
        s.ensure(ts, d, half);
        // embeddings + per-position RoPE at absolute positions
        // base..base+ts, then reserve the cache rows (no eviction: the
        // whole sequence fits the window)
        for (i, &tok) in tokens.iter().enumerate() {
            rope_pos_into(
                base + i,
                hd,
                cfg.rope_theta,
                &mut s.cos[i * half..(i + 1) * half],
                &mut s.sin[i * half..(i + 1) * half],
            );
            s.x.row_mut(i).copy_from_slice(self.tok_emb.row(tok as usize));
        }
        s.ring.clear();
        for _ in 0..ts {
            s.ring.push(cache.advance());
        }

        for (l, layer) in self.layers.iter().enumerate() {
            // attention: batched projections, per-row rope/append, then
            // each suffix row attends over prefix + suffix rows ≤ it
            rmsnorm_rows(&s.x, &layer.attn_norm, cfg.rmsnorm_eps, &mut s.hn);
            layer.wq.matmul_rows(&s.hn, &mut s.q, &mut s.fdb);
            layer.wk.matmul_rows(&s.hn, &mut s.k, &mut s.fdb);
            layer.wv.matmul_rows(&s.hn, &mut s.v, &mut s.fdb);
            for i in 0..ts {
                let cs = &s.cos[i * half..(i + 1) * half];
                let sn = &s.sin[i * half..(i + 1) * half];
                rope_row(s.q.row_mut(i), h, hd, cs, sn);
                rope_row(s.k.row_mut(i), h, hd, cs, sn);
            }
            for i in 0..ts {
                cache.write(l, s.ring[i], s.k.row(i), s.v.row(i));
            }
            for i in 0..ts {
                attend_one(
                    s.q.row(i),
                    base + i + 1,
                    |j| cache.k_row(l, j),
                    |j| cache.v_row(l, j),
                    h,
                    hd,
                    &mut s.scores,
                    s.ctx.row_mut(i),
                );
            }
            layer.wo.matmul_rows(&s.ctx, &mut s.proj, &mut s.fdb);
            for (xi, &p) in s.x.data.iter_mut().zip(&s.proj.data) {
                *xi += p;
            }
            // mlp
            rmsnorm_rows(&s.x, &layer.mlp_norm, cfg.rmsnorm_eps, &mut s.hn);
            layer.w_gate.matmul_rows(&s.hn, &mut s.gate, &mut s.fdb);
            layer.w_up.matmul_rows(&s.hn, &mut s.up, &mut s.fdb);
            set_shape(&mut s.act, ts, d_ff);
            for i in 0..ts * d_ff {
                s.act.data[i] = silu(s.gate.data[i]) * s.up.data[i];
            }
            layer.w_down.matmul_rows(&s.act, &mut s.down, &mut s.fdb);
            for (xi, &p) in s.x.data.iter_mut().zip(&s.down.data) {
                *xi += p;
            }
        }

        rmsnorm_row(s.x.row(ts - 1), &self.final_norm, cfg.rmsnorm_eps, &mut self.scratch.hn);
        let mut logits = vec![0.0f32; cfg.vocab];
        dense_matvec(&self.head, &self.scratch.hn, &mut logits);
        logits
    }

    /// One decode step: append `token` to the cached sequence and
    /// return the next-token logits.  Cost is O(window), independent of
    /// how many tokens were decoded before — the tentpole property.
    pub fn step(&mut self, cache: &mut KvCache, token: u32) -> Vec<f32> {
        // tidy:no-alloc(start): the per-token decode hot path — every
        // buffer is reused scratch; only the returned logits row
        // allocates (annotated below).
        let cfg = &self.cfg;
        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        assert!((token as usize) < cfg.vocab, "token {token} out of vocab");
        assert_eq!(cache.width, cfg.d_model, "cache width != d_model");

        rope_pos_into(
            cache.next_pos(),
            hd,
            cfg.rope_theta,
            &mut self.scratch.cos,
            &mut self.scratch.sin,
        );
        let slot = cache.advance();
        self.scratch.x.copy_from_slice(self.tok_emb.row(token as usize));

        for (l, layer) in self.layers.iter().enumerate() {
            // attention
            rmsnorm_row(&self.scratch.x, &layer.attn_norm, cfg.rmsnorm_eps, &mut self.scratch.hn);
            layer.wq.matvec(&self.scratch.hn, &mut self.scratch.q);
            layer.wk.matvec(&self.scratch.hn, &mut self.scratch.k);
            layer.wv.matvec(&self.scratch.hn, &mut self.scratch.v);
            rope_row(&mut self.scratch.q, h, hd, &self.scratch.cos, &self.scratch.sin);
            rope_row(&mut self.scratch.k, h, hd, &self.scratch.cos, &self.scratch.sin);
            cache.write(l, slot, &self.scratch.k, &self.scratch.v);
            let n = cache.len();
            attend_one(
                &self.scratch.q,
                n,
                |i| cache.k_row(l, i),
                |i| cache.v_row(l, i),
                h,
                hd,
                &mut self.scratch.scores,
                &mut self.scratch.ctx,
            );
            layer.wo.matvec(&self.scratch.ctx, &mut self.scratch.proj);
            for (xi, &p) in self.scratch.x.iter_mut().zip(&self.scratch.proj) {
                *xi += p;
            }
            // mlp
            rmsnorm_row(&self.scratch.x, &layer.mlp_norm, cfg.rmsnorm_eps, &mut self.scratch.hn);
            layer.w_gate.matvec(&self.scratch.hn, &mut self.scratch.gate);
            layer.w_up.matvec(&self.scratch.hn, &mut self.scratch.up);
            for i in 0..cfg.d_ff {
                self.scratch.act[i] = silu(self.scratch.gate[i]) * self.scratch.up[i];
            }
            layer.w_down.matvec(&self.scratch.act, &mut self.scratch.down);
            for (xi, &p) in self.scratch.x.iter_mut().zip(&self.scratch.down) {
                *xi += p;
            }
        }

        rmsnorm_row(&self.scratch.x, &self.final_norm, cfg.rmsnorm_eps, &mut self.scratch.hn);
        let mut logits = vec![0.0f32; cfg.vocab]; // tidy:allow(no-alloc): the returned row
        dense_matvec(&self.head, &self.scratch.hn, &mut logits);
        logits
        // tidy:no-alloc(end)
    }

    /// Fused multi-slot decode: advance `rows` — (cache index, token)
    /// pairs — in ONE forward pass.  The active rows' embeddings are
    /// gathered into an `[m, d_model]` batch and each of the 7
    /// per-layer linears plus the LM head runs once as a batched
    /// product ([`LinearOp::matmul_rows`]: dense ikj / FDB CSC with the
    /// batch innermost), amortizing every weight traversal across the
    /// active slots; RoPE, K/V appends and attention stay per-row
    /// against each row's own cache and absolute position.  Returns one
    /// next-token logits row per entry, in order.
    ///
    /// A cache index may repeat — the speculative verify pass feeds a
    /// run `[last, d₁, …, d_k]` of draft positions for one slot in a
    /// single call.  Repeated rows are appended in listed order, each
    /// row's RoPE position advancing past the same cache's earlier rows
    /// in the batch, and each row's attention sees exactly the cached
    /// prefix plus the batch rows *before* it (causal visibility; the
    /// K/V of later rows is already written but masked out by the row's
    /// visible-length bound).  A repeated cache must not slide its
    /// window mid-batch (`len + run ≤ window`) — an eviction between
    /// two rows of the same cache is sequential-only behaviour that a
    /// batched pass cannot reproduce; the speculative decoder stops
    /// drafting before any slot could slide.
    ///
    /// Equivalence: every per-element operation runs in the same order
    /// as [`step`](Self::step) — and for repeated indices, the same
    /// order as [`prefill_suffix`](Self::prefill_suffix) over the run —
    /// so fused, sequential, and speculative-verify decode agree
    /// bit-for-bit (`tests/fused_decode.rs` and `tests/spec_decode.rs`
    /// pin this).
    pub fn step_rows(&mut self, caches: &mut [KvCache], rows: &[(usize, u32)]) -> Vec<Vec<f32>> {
        // tidy:no-alloc(start): the fused decode hot path — buffers are
        // pre-sized by `reserve_rows` and reused across ticks; only the
        // returned logits rows allocate (annotated per line).
        let m = rows.len();
        if m == 0 {
            return Vec::new();
        }
        let cfg = &self.cfg;
        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        let (d, d_ff) = (cfg.d_model, cfg.d_ff);
        let half = hd / 2;
        #[cfg(debug_assertions)]
        {
            for &(slot, token) in rows {
                debug_assert!(slot < caches.len(), "cache index {slot} out of range");
                debug_assert!((token as usize) < cfg.vocab, "token {token} out of vocab");
                debug_assert_eq!(caches[slot].width, d, "cache width != d_model");
                debug_assert!(!caches[slot].is_empty(), "step on a cache without prefill");
                let run = rows.iter().filter(|&&(s2, _)| s2 == slot).count();
                debug_assert!(
                    run == 1 || caches[slot].len() + run <= caches[slot].window,
                    "repeated cache {slot} would slide its window mid-batch"
                );
            }
        }

        let s = &mut self.rows_scratch;
        s.ensure(m, d, half);
        s.slots.extend(rows.iter().map(|&(slot, _)| slot));

        // per-row RoPE at each row's own absolute position — the
        // cache's next position plus how many earlier batch rows target
        // the same cache — read before the rings advance (same order as
        // `step`), and the embedding gather; then one batched
        // chronology bump across the caches
        for (i, &(slot, token)) in rows.iter().enumerate() {
            let prior = rows[..i].iter().filter(|&&(s2, _)| s2 == slot).count();
            rope_pos_into(
                caches[slot].next_pos() + prior,
                hd,
                cfg.rope_theta,
                &mut s.cos[i * half..(i + 1) * half],
                &mut s.sin[i * half..(i + 1) * half],
            );
            s.x.row_mut(i).copy_from_slice(self.tok_emb.row(token as usize));
        }
        advance_rows(caches, &s.slots, &mut s.ring);
        // causal visibility per row: everything this cache holds after
        // the batch advance, minus the same cache's later batch rows
        // (identical to `cache.len()` when every index is distinct)
        s.vis.clear();
        for (i, &(slot, _)) in rows.iter().enumerate() {
            let later = rows[i + 1..].iter().filter(|&&(s2, _)| s2 == slot).count();
            s.vis.push(caches[slot].len() - later);
        }

        for (l, layer) in self.layers.iter().enumerate() {
            // attention: batched projections, per-row rope/append/attend
            rmsnorm_rows(&s.x, &layer.attn_norm, cfg.rmsnorm_eps, &mut s.hn);
            layer.wq.matmul_rows(&s.hn, &mut s.q, &mut s.fdb);
            layer.wk.matmul_rows(&s.hn, &mut s.k, &mut s.fdb);
            layer.wv.matmul_rows(&s.hn, &mut s.v, &mut s.fdb);
            for i in 0..m {
                let cs = &s.cos[i * half..(i + 1) * half];
                let sn = &s.sin[i * half..(i + 1) * half];
                rope_row(s.q.row_mut(i), h, hd, cs, sn);
                rope_row(s.k.row_mut(i), h, hd, cs, sn);
            }
            write_rows(caches, &s.slots, &s.ring, l, &s.k, &s.v);
            for i in 0..m {
                let cache = &caches[s.slots[i]];
                let n = s.vis[i];
                attend_one(
                    s.q.row(i),
                    n,
                    |j| cache.k_row(l, j),
                    |j| cache.v_row(l, j),
                    h,
                    hd,
                    &mut s.scores,
                    s.ctx.row_mut(i),
                );
            }
            layer.wo.matmul_rows(&s.ctx, &mut s.proj, &mut s.fdb);
            for (xi, &p) in s.x.data.iter_mut().zip(&s.proj.data) {
                *xi += p;
            }
            // mlp: three batched products around the elementwise gate
            rmsnorm_rows(&s.x, &layer.mlp_norm, cfg.rmsnorm_eps, &mut s.hn);
            layer.w_gate.matmul_rows(&s.hn, &mut s.gate, &mut s.fdb);
            layer.w_up.matmul_rows(&s.hn, &mut s.up, &mut s.fdb);
            set_shape(&mut s.act, m, d_ff);
            for i in 0..m * d_ff {
                s.act.data[i] = silu(s.gate.data[i]) * s.up.data[i];
            }
            layer.w_down.matmul_rows(&s.act, &mut s.down, &mut s.fdb);
            for (xi, &p) in s.x.data.iter_mut().zip(&s.down.data) {
                *xi += p;
            }
        }

        // the LM head, once, as a batched product (dense ikj == per-row
        // matvec, so this too matches `step` bit-for-bit)
        rmsnorm_rows(&s.x, &self.final_norm, cfg.rmsnorm_eps, &mut s.hn);
        set_shape(&mut s.logits, m, cfg.vocab);
        dense_matmul_rows(&self.head, &s.hn, &mut s.logits.data);
        (0..m).map(|i| s.logits.row(i).to_vec()).collect() // tidy:allow(no-alloc): returned rows
        // tidy:no-alloc(end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            vocab: 96,
            seq_len: 32,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    #[test]
    fn dense_matvec_matches_matmul() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::randn(48, 24, &mut rng, 1.0);
        let x = Matrix::randn(1, 48, &mut rng, 1.0);
        let mut y = vec![0.0f32; 24];
        dense_matvec(&w, x.row(0), &mut y);
        let y_ref = x.matmul(&w);
        for (a, b) in y.iter().zip(&y_ref.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn prefill_then_step_logits_are_finite_and_shaped() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 11);
        let mut f = IncrementalForward::new(w, &BTreeMap::new());
        let mut cache = KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model);
        let l0 = f.prefill(&mut cache, &[1, 2, 3]);
        assert_eq!(l0.len(), cfg.vocab);
        assert!(l0.iter().all(|v| v.is_finite()));
        assert_eq!(cache.len(), 3);
        let l1 = f.step(&mut cache, 4);
        assert_eq!(l1.len(), cfg.vocab);
        assert!(l1.iter().all(|v| v.is_finite()));
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.next_pos(), 4);
    }

    #[test]
    fn fdb_ops_are_compiled_in() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 12);
        let mut fdb = BTreeMap::new();
        for name in cfg.linear_names() {
            fdb.insert(name.clone(), FdbLinear::from_weights(w.mat(&name), 64));
        }
        let f = IncrementalForward::new(w, &fdb);
        assert_eq!(f.n_fdb_ops(), cfg.linear_names().len());
    }

    #[test]
    fn dense_matmul_rows_matches_matvec_bitwise() {
        let mut rng = Pcg32::seeded(21);
        let w = Matrix::randn(48, 24, &mut rng, 1.0);
        let x = Matrix::randn(5, 48, &mut rng, 1.0);
        let mut y = vec![0.0f32; 5 * 24];
        dense_matmul_rows(&w, &x, &mut y);
        let mut row = vec![0.0f32; 24];
        for r in 0..5 {
            dense_matvec(&w, x.row(r), &mut row);
            assert_eq!(&y[r * 24..(r + 1) * 24], &row[..], "row {r} not bit-identical");
        }
    }

    /// The fused multi-slot step must be bit-identical to sequential
    /// per-cache steps — mixed FDB/dense linears, staggered positions.
    /// (`tests/fused_decode.rs` runs the full engine-level property.)
    #[test]
    fn step_rows_matches_sequential_steps_bitwise() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 19);
        let mut fdb = BTreeMap::new();
        // half the linears on the sparse kernel, half dense
        for (i, name) in cfg.linear_names().iter().enumerate() {
            if i % 2 == 0 {
                fdb.insert(name.clone(), FdbLinear::from_weights(w.mat(name), 64));
            }
        }
        let mut seq = IncrementalForward::new(w.clone(), &fdb);
        let mut fus = IncrementalForward::new(w, &fdb);
        fus.reserve_rows(2, cfg.seq_len);
        let mk = || KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model);
        let mut sc = vec![mk(), mk()];
        let mut fc = vec![mk(), mk()];
        // staggered prefills: the rows sit at different positions
        seq.prefill(&mut sc[0], &[1, 2, 3]);
        fus.prefill(&mut fc[0], &[1, 2, 3]);
        seq.prefill(&mut sc[1], &[4, 5]);
        fus.prefill(&mut fc[1], &[4, 5]);
        let _ = seq.step(&mut sc[1], 6);
        let _ = fus.step(&mut fc[1], 6);
        for round in 0..3u32 {
            let (t0, t1) = (7 + round, 11 + round);
            let a0 = seq.step(&mut sc[0], t0);
            let a1 = seq.step(&mut sc[1], t1);
            let b = fus.step_rows(&mut fc, &[(0, t0), (1, t1)]);
            assert_eq!(b.len(), 2);
            assert_eq!(a0, b[0], "row 0 diverged at round {round}");
            assert_eq!(a1, b[1], "row 1 diverged at round {round}");
            assert_eq!(sc[0].next_pos(), fc[0].next_pos());
            assert_eq!(sc[1].next_pos(), fc[1].next_pos());
        }
    }

    /// The speculative-verify shape: one `step_rows` call with a
    /// repeated cache index must be bit-identical to feeding the same
    /// run through sequential `step` calls — logits and every cached
    /// K/V row — including when the run is interleaved with other
    /// slots' rows in the same batch.
    #[test]
    fn step_rows_repeated_cache_matches_sequential_steps_bitwise() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 37);
        let mut fdb = BTreeMap::new();
        for (i, name) in cfg.linear_names().iter().enumerate() {
            if i % 2 == 0 {
                fdb.insert(name.clone(), FdbLinear::from_weights(w.mat(name), 64));
            }
        }
        let mut seq = IncrementalForward::new(w.clone(), &fdb);
        let mut fus = IncrementalForward::new(w, &fdb);
        fus.reserve_rows(5, cfg.seq_len);
        let mk = || KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model);
        // staggered prefills so the runs start at different positions
        let (mut sc, mut fc) = (vec![mk(), mk()], vec![mk(), mk()]);
        seq.prefill(&mut sc[0], &[1, 2, 3]);
        fus.prefill(&mut fc[0], &[1, 2, 3]);
        seq.prefill(&mut sc[1], &[4, 5]);
        fus.prefill(&mut fc[1], &[4, 5]);
        // cache 0 repeated 3 times, cache 1 twice, interleaved
        let rows = [(0usize, 7u32), (1, 11), (0, 8), (0, 9), (1, 12)];
        let a: Vec<Vec<f32>> = rows.iter().map(|&(c, t)| seq.step(&mut sc[c], t)).collect();
        let b = fus.step_rows(&mut fc, &rows);
        assert_eq!(b.len(), rows.len());
        for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
            assert_eq!(ra, rb, "row {i} diverged");
        }
        for c in 0..2 {
            assert_eq!(sc[c].next_pos(), fc[c].next_pos());
            for l in 0..cfg.n_layers {
                for i in 0..sc[c].len() {
                    assert_eq!(sc[c].k_row(l, i), fc[c].k_row(l, i), "K {c}/{l}/{i}");
                    assert_eq!(sc[c].v_row(l, i), fc[c].v_row(l, i), "V {c}/{l}/{i}");
                }
            }
        }
    }

    #[test]
    fn step_rows_empty_batch_is_a_noop() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 23);
        let mut f = IncrementalForward::new(w, &BTreeMap::new());
        let mut caches = vec![KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model)];
        f.prefill(&mut caches[0], &[1, 2]);
        let out = f.step_rows(&mut caches, &[]);
        assert!(out.is_empty());
        assert_eq!(caches[0].len(), 2, "empty fused step must not touch any cache");
    }

    /// The prefix-sharing foundation: prefilling `[0, split)` then
    /// suffix-prefilling `[split, n)` must be *bit-identical* — logits
    /// and every cached K/V row — to one cold pass over all `n` tokens,
    /// for dense and FDB-mixed layers at every split point.
    #[test]
    fn prefill_split_is_bit_identical_to_cold() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 29);
        let mut fdb = BTreeMap::new();
        for (i, name) in cfg.linear_names().iter().enumerate() {
            if i % 2 == 0 {
                fdb.insert(name.clone(), FdbLinear::from_weights(w.mat(name), 64));
            }
        }
        let toks: Vec<u32> = (0..10u32).map(|i| (i * 7) % cfg.vocab as u32).collect();
        let mut cold = IncrementalForward::new(w.clone(), &fdb);
        let mut cache_cold = KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model);
        let cold_logits = cold.prefill(&mut cache_cold, &toks);
        for split in 1..toks.len() {
            let mut warm = IncrementalForward::new(w.clone(), &fdb);
            let mut cache = KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model);
            warm.prefill(&mut cache, &toks[..split]);
            let warm_logits = warm.prefill_suffix(&mut cache, &toks[split..]);
            assert_eq!(warm_logits, cold_logits, "split {split}: logits diverge");
            for l in 0..cfg.n_layers {
                for i in 0..toks.len() {
                    assert_eq!(cache.k_row(l, i), cache_cold.k_row(l, i), "K {l}/{i}");
                    assert_eq!(cache.v_row(l, i), cache_cold.v_row(l, i), "V {l}/{i}");
                }
            }
        }
    }

    /// `step` is a 1-token `prefill_suffix`: appending one token either
    /// way produces bit-identical logits and cache rows — the contract
    /// that lets decoded positions feed future prefix matches.
    #[test]
    fn step_matches_one_token_suffix_bitwise() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 31);
        let mut a = IncrementalForward::new(w.clone(), &BTreeMap::new());
        let mut b = IncrementalForward::new(w, &BTreeMap::new());
        let mk = || KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model);
        let (mut ca, mut cb) = (mk(), mk());
        a.prefill(&mut ca, &[3, 1, 4]);
        b.prefill(&mut cb, &[3, 1, 4]);
        let la = a.step(&mut ca, 15);
        let lb = b.prefill_suffix(&mut cb, &[15]);
        assert_eq!(la, lb, "step and 1-token suffix prefill diverge");
        for l in 0..cfg.n_layers {
            assert_eq!(ca.k_row(l, 3), cb.k_row(l, 3));
            assert_eq!(ca.v_row(l, 3), cb.v_row(l, 3));
        }
    }

    #[test]
    #[should_panic(expected = "overflow the window")]
    fn prefill_suffix_rejects_window_overflow() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 33);
        let mut f = IncrementalForward::new(w, &BTreeMap::new());
        let mut cache = KvCache::new(cfg.n_layers, 4, cfg.d_model);
        f.prefill(&mut cache, &[1, 2, 3]);
        // 3 cached + 2 suffix > window 4: must panic, not slide silently
        f.prefill_suffix(&mut cache, &[4, 5]);
    }

    #[test]
    fn long_prompt_keeps_recent_window() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 13);
        let mut f = IncrementalForward::new(w, &BTreeMap::new());
        let window = 4;
        let mut cache = KvCache::new(cfg.n_layers, window, cfg.d_model);
        let long: Vec<u32> = (0..10u32).collect();
        let full = f.prefill(&mut cache, &long);
        assert_eq!(cache.len(), window);
        // same logits as prefilling just the tail explicitly
        let mut cache2 = KvCache::new(cfg.n_layers, window, cfg.d_model);
        let tail = f.prefill(&mut cache2, &long[long.len() - window..]);
        for (a, b) in full.iter().zip(&tail) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
