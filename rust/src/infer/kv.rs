//! Paged KV cache: slot caches are block-table views into a shared
//! [`KvPool`] of fixed-size K/V blocks — the vLLM/PagedAttention move
//! that makes KV memory proportional to *tokens actually resident*
//! instead of a worst-case `window × layers × width` reservation per
//! slot, and makes prefix-cache hits zero-copy (shared block handles
//! instead of row memcpys).
//!
//! Window semantics are unchanged from the ring era and still match
//! `runtime::session::recent_window` (and thus `pack_decode_windows` /
//! the XLA decode loop): the cache always exposes the *most recent*
//! `window` positions; once full, appending a position retires the
//! oldest.  Keys are stored RoPE'd at their *absolute* position — RoPE
//! attention scores depend only on relative position, so sliding the
//! window never requires re-rotating the survivors.
//!
//! # Sharing protocol
//!
//! A block handle is an `Arc<KvPoolBlock>`: the ref count IS the Arc
//! strong count.  The prefix cache retains published blocks, and
//! [`KvCache::append_shared`] splices the same handles into another
//! slot's table with **zero** K/V row copies.  Mutation goes through
//! `Arc::get_mut`, so a slot can only write a block it uniquely owns;
//! when a slot would append into a shared tail, [`KvCache::advance`]
//! first clones it into a private block (copy-on-write, counted in
//! [`KvPoolStats::cow_copies`]).  Dropping the last handle retires the
//! block's storage into the pool's recycle list.
//!
//! # Memory
//!
//! A block holds `2 (K,V) · n_layers · block_tokens · width` floats.
//! A slot holding `len` positions pins `⌈covered / block_tokens⌉`
//! blocks where `covered < len + block_tokens` — i.e. at most one
//! partially-dead head block plus a partially-filled tail of slack,
//! versus the full-window reservation of the old design.
//!
//! # Lock discipline
//!
//! The pool's only mutex guards the recycle free list (`recycled`), a
//! leaf lock held for a single push/pop — never while running a model
//! forward, touching a cache, or calling into the prefix cache.  All
//! other pool state is atomic counters.
//!
//! The fused multi-slot decode advances several independent caches per
//! tick; [`advance_rows`] / [`write_rows`] are its batched append
//! primitives (one chronology bump per row, then one per-layer scatter
//! of the batched K/V matrices into each row's own block table).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::tensor::Matrix;

/// Default tokens per pool block (and thus the prefix-cache publish
/// granularity).  16 balances sharing granularity (shorter common
/// prefixes still match a block) against per-block bookkeeping;
/// `PrefixCache::new` takes the block size explicitly, and the engine
/// rebuilds its pool to match whatever cache it attaches.
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Retired block storage kept for reuse before falling back to fresh
/// heap allocations.  Bounds the free list so a transient burst of
/// slots doesn't pin its high-water mark forever.
const RECYCLE_CAP: usize = 256;

/// Shared fixed-size K/V block allocator: the engine owns one pool and
/// every slot cache (plus the prefix cache's retained blocks) draws
/// from it.  `max_blocks` is a *soft* admission budget: [`KvPool::alloc`]
/// never fails — mid-decode appends must always succeed — and the
/// scheduler instead gates new admissions on [`KvPool::free_blocks`].
///
/// # Examples
///
/// ```
/// use std::sync::Arc;
/// use db_llm::infer::KvPool;
///
/// // 2 layers, rows of width 4, 8-token blocks, budget of 3 blocks
/// let pool = Arc::new(KvPool::new(8, 2, 4, 3));
/// let a = pool.alloc();
/// assert_eq!(pool.free_blocks(), 2);
/// drop(a); // retiring the handle returns the block to the free list
/// assert_eq!(pool.free_blocks(), 3);
/// assert_eq!(pool.blocks_for(17), 3); // ⌈17 / 8⌉
/// ```
pub struct KvPool {
    /// positions per block (the sharing granularity)
    block_tokens: usize,
    /// layers each block carries K/V rows for
    n_layers: usize,
    /// row width (`n_heads * head_dim`)
    width: usize,
    /// soft block budget gating admission (`usize::MAX` = unbounded)
    max_blocks: usize,
    /// blocks currently alive (allocated, not yet retired)
    live: AtomicUsize,
    /// high-water mark of `live`
    peak_live: AtomicUsize,
    /// blocks allocated from fresh heap storage
    fresh_allocs: AtomicUsize,
    /// blocks allocated from the recycle free list
    recycle_hits: AtomicUsize,
    /// blocks retired (last handle dropped)
    retired: AtomicUsize,
    /// copy-on-write clones (a slot mutated a shared block)
    cow_copies: AtomicUsize,
    /// cached positions whose K/V rows were memcpy'd (legacy
    /// `append_block` imports + COW clones); zero-copy sharing never
    /// bumps this — the warm-prefill tests assert it stays flat
    copied_rows: AtomicUsize,
    /// retired storage awaiting reuse — the pool's only lock, a leaf
    /// held for one push/pop
    recycled: Mutex<Vec<Vec<f32>>>,
}

impl KvPool {
    /// Soft budget value meaning "never gate admission on blocks".
    pub const UNBOUNDED: usize = usize::MAX;

    /// Build a pool of `block_tokens`-position blocks for `n_layers`
    /// layers of `width`-float rows, with a soft budget of
    /// `max_blocks` ([`KvPool::UNBOUNDED`] to disable gating).
    pub fn new(block_tokens: usize, n_layers: usize, width: usize, max_blocks: usize) -> KvPool {
        assert!(block_tokens > 0, "block size must be positive");
        assert!(width > 0, "row width must be positive");
        KvPool {
            block_tokens,
            n_layers,
            width,
            max_blocks,
            live: AtomicUsize::new(0),
            peak_live: AtomicUsize::new(0),
            fresh_allocs: AtomicUsize::new(0),
            recycle_hits: AtomicUsize::new(0),
            retired: AtomicUsize::new(0),
            cow_copies: AtomicUsize::new(0),
            copied_rows: AtomicUsize::new(0),
            recycled: Mutex::new(Vec::new()),
        }
    }

    /// Positions per block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Layers each block carries K/V rows for.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Row width in floats.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Soft block budget (`usize::MAX` when unbounded).
    pub fn max_blocks(&self) -> usize {
        self.max_blocks
    }

    /// Floats per block: `2 (K,V) · n_layers · block_tokens · width`.
    fn block_floats(&self) -> usize {
        2 * self.n_layers * self.block_tokens * self.width
    }

    /// Heap bytes one block pins.
    pub fn block_bytes(&self) -> usize {
        self.block_floats() * 4
    }

    /// Blocks needed to hold `tokens` positions: `⌈tokens / block_tokens⌉`.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Blocks still under the soft budget.  Saturates at zero; an
    /// unbounded pool reports `usize::MAX - live`.
    pub fn free_blocks(&self) -> usize {
        self.max_blocks.saturating_sub(self.live.load(Ordering::Relaxed))
    }

    /// Pop recycled storage or heap-allocate fresh, and account for it.
    /// Recycled storage is *not* re-zeroed: cache rows are always
    /// written before they are read, and the window/table bookkeeping
    /// never exposes unwritten rows.
    fn raw_data(self: &Arc<Self>) -> Vec<f32> {
        let reused = match self.recycled.lock() {
            Ok(mut free) => free.pop(),
            // poisoned free list: fall through to a fresh allocation
            Err(_) => None,
        };
        match reused {
            Some(data) => {
                self.recycle_hits.fetch_add(1, Ordering::Relaxed);
                data
            }
            None => {
                self.fresh_allocs.fetch_add(1, Ordering::Relaxed);
                vec![0.0; self.block_floats()]
            }
        }
    }

    /// Allocate an empty block.  Never fails: the budget is enforced at
    /// admission time ([`KvPool::free_blocks`]), not allocation time,
    /// so a mid-decode append can't panic a request that was admitted.
    pub fn alloc(self: &Arc<Self>) -> Arc<KvPoolBlock> {
        let data = self.raw_data();
        let live = self.live.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_live.fetch_max(live, Ordering::Relaxed);
        Arc::new(KvPoolBlock { len: 0, data, pool: Arc::clone(self) })
    }

    /// Copy-on-write clone: a private block carrying the same rows as
    /// `src`, for a slot that must mutate a block another holder still
    /// pins.
    fn alloc_cow(self: &Arc<Self>, src: &KvPoolBlock) -> Arc<KvPoolBlock> {
        let mut data = self.raw_data();
        data.copy_from_slice(&src.data);
        let live = self.live.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_live.fetch_max(live, Ordering::Relaxed);
        self.cow_copies.fetch_add(1, Ordering::Relaxed);
        self.copied_rows.fetch_add(src.len, Ordering::Relaxed);
        Arc::new(KvPoolBlock { len: src.len, data, pool: Arc::clone(self) })
    }

    /// Account `n` positions copied row-by-row (the legacy
    /// [`KvCache::append_block`] import path).
    fn note_copied(&self, n: usize) {
        self.copied_rows.fetch_add(n, Ordering::Relaxed);
    }

    /// Return retired storage to the free list (called from block
    /// `Drop`).  Past [`RECYCLE_CAP`] the storage is simply freed.
    fn retire(&self, data: Vec<f32>) {
        self.live.fetch_sub(1, Ordering::Relaxed);
        self.retired.fetch_add(1, Ordering::Relaxed);
        if let Ok(mut free) = self.recycled.lock() {
            if free.len() < RECYCLE_CAP {
                free.push(data);
            }
        }
    }

    /// Snapshot the pool's counters.
    pub fn stats(&self) -> KvPoolStats {
        KvPoolStats {
            block_tokens: self.block_tokens,
            block_bytes: self.block_bytes(),
            max_blocks: self.max_blocks,
            live_blocks: self.live.load(Ordering::Relaxed),
            peak_blocks: self.peak_live.load(Ordering::Relaxed),
            fresh_allocs: self.fresh_allocs.load(Ordering::Relaxed),
            recycle_hits: self.recycle_hits.load(Ordering::Relaxed),
            retired: self.retired.load(Ordering::Relaxed),
            cow_copies: self.cow_copies.load(Ordering::Relaxed),
            copied_rows: self.copied_rows.load(Ordering::Relaxed),
        }
    }

    /// Audit the pool's accounting.  Panics on the first violation:
    ///
    /// * retired blocks never exceed allocated blocks,
    /// * live blocks never exceed allocated blocks,
    /// * every recycled storage buffer spans exactly one block, and
    /// * the recycle list respects its cap.
    ///
    /// Counter loads are ordered (retired, then live, then allocs) so
    /// the audit is sound even while other threads allocate/retire.
    pub fn assert_invariants(&self) {
        let retired = self.retired.load(Ordering::Relaxed);
        let live = self.live.load(Ordering::Relaxed);
        // COW clones draw through raw_data, so fresh + recycled covers
        // every allocation
        let allocs =
            self.fresh_allocs.load(Ordering::Relaxed) + self.recycle_hits.load(Ordering::Relaxed);
        assert!(retired <= allocs, "pool retired {retired} blocks but only allocated {allocs}");
        assert!(live <= allocs, "pool claims {live} live blocks but only allocated {allocs}");
        if let Ok(free) = self.recycled.lock() {
            assert!(free.len() <= RECYCLE_CAP, "recycle list over its cap");
            for (i, data) in free.iter().enumerate() {
                assert_eq!(
                    data.len(),
                    self.block_floats(),
                    "recycled storage {i} drifted from block geometry"
                );
            }
        }
    }
}

/// Point-in-time snapshot of a [`KvPool`]'s accounting counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct KvPoolStats {
    /// positions per block
    pub block_tokens: usize,
    /// heap bytes per block
    pub block_bytes: usize,
    /// soft admission budget in blocks (`usize::MAX` = unbounded)
    pub max_blocks: usize,
    /// blocks currently alive
    pub live_blocks: usize,
    /// high-water mark of live blocks
    pub peak_blocks: usize,
    /// blocks served from fresh heap allocations
    pub fresh_allocs: usize,
    /// blocks served from the recycle free list
    pub recycle_hits: usize,
    /// blocks retired (last handle dropped)
    pub retired: usize,
    /// copy-on-write clones of shared blocks
    pub cow_copies: usize,
    /// cached positions whose rows were memcpy'd (legacy import + COW);
    /// zero on a pure zero-copy warm path
    pub copied_rows: usize,
}

/// One fixed-size block of K/V rows for every layer, allocated from a
/// [`KvPool`].  Shared immutably via `Arc` (the strong count is the ref
/// count); mutated only through `Arc::get_mut` by the uniquely-owning
/// slot.  Dropping the last handle retires the storage to the pool.
///
/// Layout: one flat buffer; layer `l`'s K row `r` at
/// `((2l)·block_tokens + r)·width`, its V row at
/// `((2l+1)·block_tokens + r)·width`.
pub struct KvPoolBlock {
    /// filled positions (≤ `block_tokens`)
    len: usize,
    /// flat K/V storage, `2 · n_layers · block_tokens · width` floats
    data: Vec<f32>,
    /// owning pool (retire target)
    pool: Arc<KvPool>,
}

impl KvPoolBlock {
    /// Filled positions in this block.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no position is filled.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when every position is filled (only full blocks are shared
    /// through the prefix chain).
    pub fn is_full(&self) -> bool {
        self.len == self.pool.block_tokens
    }

    /// Heap bytes this block pins (the budget unit for
    /// [`super::prefix::PrefixCache`] eviction).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    fn k_off(&self, layer: usize, row: usize) -> usize {
        (2 * layer * self.pool.block_tokens + row) * self.pool.width
    }

    fn v_off(&self, layer: usize, row: usize) -> usize {
        ((2 * layer + 1) * self.pool.block_tokens + row) * self.pool.width
    }

    /// Layer `layer`'s key row at block-local index `row`.
    pub fn k_row(&self, layer: usize, row: usize) -> &[f32] {
        debug_assert!(row < self.len, "read of unwritten block row");
        let o = self.k_off(layer, row);
        &self.data[o..o + self.pool.width]
    }

    /// Layer `layer`'s value row at block-local index `row`.
    pub fn v_row(&self, layer: usize, row: usize) -> &[f32] {
        debug_assert!(row < self.len, "read of unwritten block row");
        let o = self.v_off(layer, row);
        &self.data[o..o + self.pool.width]
    }

    fn k_row_mut(&mut self, layer: usize, row: usize) -> &mut [f32] {
        let o = self.k_off(layer, row);
        let w = self.pool.width;
        &mut self.data[o..o + w]
    }

    fn v_row_mut(&mut self, layer: usize, row: usize) -> &mut [f32] {
        let o = self.v_off(layer, row);
        let w = self.pool.width;
        &mut self.data[o..o + w]
    }
}

impl Drop for KvPoolBlock {
    fn drop(&mut self) {
        self.pool.retire(std::mem::take(&mut self.data));
    }
}

/// A contiguous run of prefilled positions, exported from one
/// [`KvCache`] by value — the legacy copy-based interchange format,
/// kept for callers that need an owned snapshot (the zero-copy path is
/// [`KvCache::share_block`] / [`KvCache::append_shared`]).  Layout:
/// `layers[l]` holds that layer's `(k, v)` rows as `[len, width]`
/// row-major, row `i` being the block's `i`-th position in
/// chronological order.
#[derive(Clone, Debug, PartialEq)]
pub struct KvBlock {
    /// positions in this block
    pub len: usize,
    /// row width (`n_heads * head_dim`)
    pub width: usize,
    /// per-layer `(k, v)` rows, each `[len * width]` row-major
    pub layers: Vec<(Vec<f32>, Vec<f32>)>,
}

impl KvBlock {
    /// Heap bytes this block pins.
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|(k, v)| (k.len() + v.len()) * 4).sum()
    }
}

/// Paged K/V for every layer of one sequence: a table of pool-block
/// handles plus window bookkeeping.  All layers share one chronology:
/// `advance()` reserves the row for the next position once, then every
/// layer writes its K/V into that row.
///
/// # Examples
///
/// ```
/// use db_llm::infer::KvCache;
///
/// // 1 layer, a 2-position window, rows of width 2
/// let mut cache = KvCache::new(1, 2, 2);
/// for t in 0..3u32 {
///     let slot = cache.advance(); // reserve the table row once …
///     let row = [t as f32, -(t as f32)];
///     cache.write(0, slot, &row, &row); // … then write each layer
/// }
/// // the window keeps the most recent 2 of the 3 appended positions
/// assert_eq!(cache.len(), 2);
/// assert_eq!(cache.k_row(0, 0), &[1.0, -1.0]); // oldest survivor
/// assert_eq!(cache.pos_of(1), 2); // absolute position of the newest
/// ```
pub struct KvCache {
    /// max cached positions (the sliding-window length)
    pub window: usize,
    /// row width = n_heads * head_dim (= d_model here)
    pub width: usize,
    /// block allocator this cache draws from
    pool: Arc<KvPool>,
    /// resident blocks in chronological order (front = oldest)
    blocks: VecDeque<Arc<KvPoolBlock>>,
    /// absolute position of the front block's first row (multiple of
    /// `block_tokens`; rows below the window's oldest position are
    /// stale-but-present block slack)
    base: usize,
    /// filled positions exposed by the window (≤ window)
    len: usize,
    /// absolute position of the next appended token (monotonic)
    next_pos: usize,
}

impl KvCache {
    /// Cache over a private, unbounded pool with the default block
    /// size — the drop-in constructor for standalone use (tests, the
    /// static path).  Engines build their slots with
    /// [`KvCache::new_in_pool`] so all slots share one budget.
    pub fn new(n_layers: usize, window: usize, width: usize) -> KvCache {
        KvCache::with_block_tokens(n_layers, window, width, DEFAULT_BLOCK_TOKENS)
    }

    /// Like [`KvCache::new`] with an explicit block size (must match
    /// the prefix cache it will exchange blocks with).
    pub fn with_block_tokens(
        n_layers: usize,
        window: usize,
        width: usize,
        block_tokens: usize,
    ) -> KvCache {
        let pool = Arc::new(KvPool::new(block_tokens, n_layers, width, KvPool::UNBOUNDED));
        KvCache::new_in_pool(&pool, window)
    }

    /// Cache drawing its blocks from a shared pool.
    pub fn new_in_pool(pool: &Arc<KvPool>, window: usize) -> KvCache {
        assert!(window > 0, "window must be positive");
        KvCache {
            window,
            width: pool.width,
            pool: Arc::clone(pool),
            blocks: VecDeque::new(),
            base: 0,
            len: 0,
            next_pos: 0,
        }
    }

    /// Cached positions (chronological indices run `0..len()`).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Number of layers this cache holds K/V rows for (lets callers
    /// clone a cache's geometry without carrying the model config).
    pub fn n_layers(&self) -> usize {
        self.pool.n_layers
    }

    /// Positions per block in the backing pool.
    pub fn block_tokens(&self) -> usize {
        self.pool.block_tokens
    }

    /// The pool this cache draws blocks from.
    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// True when no position is cached (fresh or just cleared).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Absolute position the next appended token will occupy.
    pub fn next_pos(&self) -> usize {
        self.next_pos
    }

    /// Absolute position of chronological index `i`.
    pub fn pos_of(&self, i: usize) -> usize {
        debug_assert!(i < self.len);
        self.next_pos - self.len + i
    }

    /// Reset for a new request.  Releases every block handle (retiring
    /// uniquely-owned blocks into the pool's recycle list, so the next
    /// request reuses their storage without fresh heap allocations).
    pub fn clear(&mut self) {
        self.blocks.clear();
        self.base = 0;
        self.len = 0;
        self.next_pos = 0;
        #[cfg(debug_assertions)]
        self.assert_invariants();
    }

    /// Make the tail block writable, cloning it first if another holder
    /// (prefix cache, other slot, audit pin) still shares it — the
    /// copy-on-write half of the sharing protocol.
    fn ensure_tail_writable(&mut self) {
        let tail = self.blocks.back_mut().expect("tail block exists");
        if Arc::get_mut(tail).is_some() {
            return;
        }
        let private = KvPool::alloc_cow(&self.pool, tail);
        *tail = private;
    }

    /// Reserve the table row for the next position, retiring head
    /// blocks that slid entirely out of the window.  Returns the
    /// table-local row index to pass to `write`.  Call exactly once per
    /// position, before the per-layer writes.  Allocates a fresh block
    /// only every `block_tokens` appends (amortized, and usually a
    /// recycle-list hit).
    pub fn advance(&mut self) -> usize {
        let pos = self.next_pos;
        let bt = self.pool.block_tokens;
        let tail_full = self.blocks.back().is_none_or(|b| b.len == bt);
        if tail_full {
            let fresh = self.pool.alloc();
            self.blocks.push_back(fresh);
        } else {
            self.ensure_tail_writable();
        }
        {
            let tail = self.blocks.back_mut().expect("tail block exists after push");
            let tail = Arc::get_mut(tail).expect("tail uniquely owned after copy-on-write");
            tail.len += 1;
        }
        if self.len < self.window {
            self.len += 1;
        }
        self.next_pos += 1;
        // release head blocks whose every row is older than the window
        while self.base + bt <= self.next_pos - self.len {
            self.blocks.pop_front();
            self.base += bt;
        }
        #[cfg(debug_assertions)]
        self.assert_invariants();
        pos - self.base
    }

    /// Roll back the chronology to `new_next_pos`, discarding every
    /// position at or beyond it — the speculative-decode rejection
    /// path.  Whole tail blocks past the new end are popped (a handle
    /// drop retiring the block, **no row copies**); a partially
    /// surviving tail block has its fill count shrunk in place.  The
    /// discarded rows' storage is not zeroed: like recycled blocks,
    /// rows are always rewritten by `advance` + `write` before they can
    /// be read again.
    ///
    /// Only *resident* positions can be discarded
    /// (`next_pos - new_next_pos ≤ len`): positions already evicted by
    /// the sliding window cannot be resurrected.  The speculative
    /// decoder guarantees this by never drafting once a slot's window
    /// could slide.  `base` never changes — rollback never slides the
    /// window forward.
    ///
    /// Returns the number of positions discarded.
    ///
    /// # Examples
    ///
    /// ```
    /// use db_llm::infer::KvCache;
    ///
    /// let mut cache = KvCache::new(1, 8, 2);
    /// for t in 0..5u32 {
    ///     let slot = cache.advance();
    ///     let row = [t as f32, 0.0];
    ///     cache.write(0, slot, &row, &row);
    /// }
    /// // reject the last two speculative positions
    /// assert_eq!(cache.truncate_to(3), 2);
    /// assert_eq!(cache.len(), 3);
    /// assert_eq!(cache.next_pos(), 3);
    /// assert_eq!(cache.k_row(0, 2), &[2.0, 0.0]); // survivors untouched
    /// ```
    pub fn truncate_to(&mut self, new_next_pos: usize) -> usize {
        assert!(
            new_next_pos <= self.next_pos,
            "truncate_to({new_next_pos}) cannot extend the chronology ({})",
            self.next_pos
        );
        let dropped = self.next_pos - new_next_pos;
        if dropped == 0 {
            return 0;
        }
        assert!(
            dropped <= self.len,
            "rollback of {dropped} positions past the {} resident would resurrect evicted rows",
            self.len
        );
        // `new_next_pos ≥ oldest resident ≥ base`, so this never
        // underflows
        let target = new_next_pos - self.base;
        let mut covered = self.next_pos - self.base;
        while covered > target {
            let tail_len = self.blocks.back().expect("coverage implies a tail block").len;
            if covered - tail_len >= target {
                // the whole tail block is rejected: drop the handle
                self.blocks.pop_back();
                covered -= tail_len;
            } else {
                // the tail block partially survives: shrink its fill
                // count in place (copy-on-write first if pinned)
                let keep = tail_len - (covered - target);
                self.ensure_tail_writable();
                let tail = self.blocks.back_mut().expect("tail block exists");
                let tail = Arc::get_mut(tail).expect("tail uniquely owned after copy-on-write");
                tail.len = keep;
                covered = target;
            }
        }
        self.len -= dropped;
        self.next_pos = new_next_pos;
        #[cfg(debug_assertions)]
        self.assert_invariants();
        dropped
    }

    /// Audit the block-table/window bookkeeping.  Debug builds run this
    /// after every mutating call; test suites call it directly.  Panics
    /// on the first violation:
    ///
    /// * `len ≤ window` (the view never claims more than the window),
    /// * `next_pos ≥ len` (absolute chronology is never behind the
    ///   resident count — their difference is the evicted-prefix
    ///   length),
    /// * `base` is block-aligned and the oldest resident position lies
    ///   inside the front block (head blocks are released eagerly),
    /// * the blocks cover exactly positions `[base, next_pos)`, every
    ///   non-tail block full,
    /// * every block's storage spans exactly one pool block, and
    /// * the pool's own accounting holds ([`KvPool::assert_invariants`]).
    pub fn assert_invariants(&self) {
        let bt = self.pool.block_tokens;
        assert!(
            self.len <= self.window,
            "kv table holds {} positions but the window is {}",
            self.len,
            self.window
        );
        assert!(
            self.next_pos >= self.len,
            "kv chronology behind resident count ({} < {})",
            self.next_pos,
            self.len
        );
        assert_eq!(self.base % bt, 0, "table base {} not block-aligned", self.base);
        let covered: usize = self.blocks.iter().map(|b| b.len).sum();
        assert_eq!(
            self.base + covered,
            self.next_pos,
            "blocks cover [{}, {}) but chronology is at {}",
            self.base,
            self.base + covered,
            self.next_pos
        );
        if self.len > 0 {
            let oldest = self.next_pos - self.len;
            assert!(
                self.base <= oldest && oldest < self.base + bt,
                "front block [{}, {}) does not contain the oldest position {}",
                self.base,
                self.base + bt,
                oldest
            );
        }
        for (i, b) in self.blocks.iter().enumerate() {
            assert_eq!(
                b.data.len(),
                self.pool.block_floats(),
                "block {i} storage drifted from pool geometry"
            );
            if i + 1 < self.blocks.len() {
                assert_eq!(b.len, bt, "non-tail block {i} is not full");
            }
        }
        self.pool.assert_invariants();
    }

    /// Write one layer's K/V rows for the row returned by `advance`.
    /// The target block is always the uniquely-owned tail (`advance`
    /// runs copy-on-write first), so this is a plain in-place store.
    pub fn write(&mut self, layer: usize, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.width);
        debug_assert_eq!(v_row.len(), self.width);
        let bt = self.pool.block_tokens;
        let block = Arc::get_mut(&mut self.blocks[slot / bt])
            .expect("written block uniquely owned (advance runs copy-on-write first)");
        block.k_row_mut(layer, slot % bt).copy_from_slice(k_row);
        block.v_row_mut(layer, slot % bt).copy_from_slice(v_row);
    }

    /// Layer `layer`'s key row at chronological index `i` (0 = oldest).
    pub fn k_row(&self, layer: usize, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        let idx = self.next_pos - self.len + i - self.base;
        let bt = self.pool.block_tokens;
        self.blocks[idx / bt].k_row(layer, idx % bt)
    }

    /// Layer `layer`'s value row at chronological index `i`.
    pub fn v_row(&self, layer: usize, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        let idx = self.next_pos - self.len + i - self.base;
        let bt = self.pool.block_tokens;
        self.blocks[idx / bt].v_row(layer, idx % bt)
    }

    /// Copy chronological positions `[start, start + len)` out as a
    /// [`KvBlock`] — the legacy by-value export.  Positions must still
    /// carry their original absolute labels, i.e. the window must not
    /// have slid (`pos_of(start) == start`); `prefill` never slides
    /// within one pass, so prompt blocks always qualify.  A mislabeled
    /// export fails fast here instead of poisoning the prefix cache.
    pub fn export_block(&self, start: usize, len: usize) -> KvBlock {
        assert!(start + len <= self.len, "export range outside cached positions");
        assert_eq!(
            self.next_pos - self.len + start,
            start,
            "export after the window slid would mislabel the block"
        );
        let layers = (0..self.pool.n_layers)
            .map(|l| {
                let mut k = Vec::with_capacity(len * self.width);
                let mut v = Vec::with_capacity(len * self.width);
                for i in start..start + len {
                    k.extend_from_slice(self.k_row(l, i));
                    v.extend_from_slice(self.v_row(l, i));
                }
                (k, v)
            })
            .collect();
        KvBlock { len, width: self.width, layers }
    }

    /// Append an exported block's positions row by row — the legacy
    /// copy-in path (each position memcpy'd, counted in
    /// [`KvPoolStats::copied_rows`]).  The zero-copy equivalent is
    /// [`KvCache::append_shared`].  A warm cache built either way is
    /// byte-identical to one that prefilled the same tokens.
    pub fn append_block(&mut self, block: &KvBlock) {
        assert_eq!(block.width, self.width, "block width != cache width");
        assert_eq!(block.layers.len(), self.pool.n_layers, "block layer count");
        assert!(
            self.len + block.len <= self.window && self.len == self.next_pos,
            "prefix import must fit the window before any slide"
        );
        let w = self.width;
        for i in 0..block.len {
            let slot = self.advance();
            for (l, (k, v)) in block.layers.iter().enumerate() {
                self.write(l, slot, &k[i * w..(i + 1) * w], &v[i * w..(i + 1) * w]);
            }
        }
        self.pool.note_copied(block.len);
        #[cfg(debug_assertions)]
        self.assert_invariants();
    }

    /// Splice a shared pool block into this cache's table with zero
    /// row copies — the warm-prefill import.  The handle is an `Arc`
    /// clone; this slot will copy-on-write only if it ever had to
    /// mutate the block (it never does: shared blocks are full, and
    /// appends go to a fresh tail).  Requires geometry match, a full
    /// block, a block-aligned unslid cache, and room in the window.
    pub fn append_shared(&mut self, block: &Arc<KvPoolBlock>) {
        let bt = self.pool.block_tokens;
        assert_eq!(block.pool.width, self.width, "block width != cache width");
        assert_eq!(block.pool.n_layers, self.pool.n_layers, "block layer count");
        assert_eq!(block.pool.block_tokens, bt, "block size != cache block size");
        assert!(block.is_full(), "only full blocks are shared");
        assert!(
            self.len + bt <= self.window && self.len == self.next_pos,
            "prefix import must fit the window before any slide"
        );
        assert_eq!(self.len % bt, 0, "zero-copy import must land on a block boundary");
        self.blocks.push_back(Arc::clone(block));
        self.len += bt;
        self.next_pos += bt;
        #[cfg(debug_assertions)]
        self.assert_invariants();
    }

    /// Share block `chunk` (covering absolute positions
    /// `[chunk·block_tokens, (chunk+1)·block_tokens)`) by handle — the
    /// zero-copy publish half of prefix sharing.  Returns `None` if the
    /// head of the table was already released (absolute labels would no
    /// longer equal block-local chronology) or the block isn't full.
    pub fn share_block(&self, chunk: usize) -> Option<Arc<KvPoolBlock>> {
        if self.base != 0 {
            return None;
        }
        self.blocks.get(chunk).filter(|b| b.is_full()).map(Arc::clone)
    }

    /// Clone the tail block's handle with no alignment or fullness
    /// checks — an audit surface for the copy-on-write soak tests,
    /// which use it to pin the exact block a slot is about to mutate.
    /// Production sharing goes through [`KvCache::share_block`].
    pub fn share_tail_for_audit(&self) -> Option<Arc<KvPoolBlock>> {
        self.blocks.back().map(Arc::clone)
    }
}

/// Batched append across independent caches: reserve the next table row
/// in each listed cache (exactly one [`KvCache::advance`] per row).
/// `slots[i]` names the cache row `i` appends to, and the reserved row
/// index per cache lands in `ring` (cleared first), to be passed to
/// [`write_rows`] for every layer.  A cache index may repeat — the
/// speculative verify pass appends a run of draft positions to one
/// cache — in which case its rows are reserved in listed order
/// (advances are sequential, so repeats are well-defined).
pub fn advance_rows(caches: &mut [KvCache], slots: &[usize], ring: &mut Vec<usize>) {
    ring.clear();
    for &slot in slots {
        ring.push(caches[slot].advance());
    }
}

/// Write one layer's batched K/V rows (`k`, `v` are `[m, width]`
/// row-major, row `i` belonging to `caches[slots[i]]`) into the table
/// rows reserved by [`advance_rows`].
pub fn write_rows(
    caches: &mut [KvCache],
    slots: &[usize],
    ring: &[usize],
    layer: usize,
    k: &Matrix,
    v: &Matrix,
) {
    debug_assert_eq!(slots.len(), ring.len());
    debug_assert_eq!(k.rows, slots.len());
    debug_assert_eq!(v.rows, slots.len());
    for (i, (&slot, &rs)) in slots.iter().zip(ring).enumerate() {
        caches[slot].write(layer, rs, k.row(i), v.row(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_then_evicts_oldest() {
        let mut c = KvCache::new(1, 3, 2);
        for t in 0..5u32 {
            let slot = c.advance();
            let row = [t as f32, -(t as f32)];
            c.write(0, slot, &row, &row);
        }
        // window 3 over 5 appends: chronological content is 2, 3, 4
        assert_eq!(c.len(), 3);
        assert_eq!(c.next_pos(), 5);
        for (i, expect) in [2.0f32, 3.0, 4.0].iter().enumerate() {
            assert_eq!(c.k_row(0, i)[0], *expect);
            assert_eq!(c.v_row(0, i)[1], -expect);
            assert_eq!(c.pos_of(i), 2 + i);
        }
    }

    #[test]
    fn layers_share_one_chronology() {
        let mut c = KvCache::new(2, 2, 1);
        let s0 = c.advance();
        c.write(0, s0, &[10.0], &[10.5]);
        c.write(1, s0, &[20.0], &[20.5]);
        let s1 = c.advance();
        c.write(0, s1, &[11.0], &[11.5]);
        c.write(1, s1, &[21.0], &[21.5]);
        assert_eq!(c.k_row(0, 0), &[10.0]);
        assert_eq!(c.k_row(1, 1), &[21.0]);
        assert_eq!(c.v_row(1, 0), &[20.5]);
    }

    #[test]
    fn batched_append_matches_sequential_appends() {
        // two caches at different occupancies: the batched helpers must
        // land the same rows in the same table rows as per-cache
        // advance+write
        let build = || {
            let mut a = KvCache::new(2, 3, 2);
            let mut b = KvCache::new(2, 3, 2);
            for t in 0..4u32 {
                // cache 0 is already wrapping, cache 1 half full
                let s = a.advance();
                a.write(0, s, &[t as f32, 0.0], &[0.0, t as f32]);
                a.write(1, s, &[t as f32, 1.0], &[1.0, t as f32]);
            }
            let s = b.advance();
            b.write(0, s, &[9.0, 9.0], &[9.0, 9.0]);
            b.write(1, s, &[8.0, 8.0], &[8.0, 8.0]);
            vec![a, b]
        };

        let mut seq = build();
        let k = Matrix::from_vec(2, 2, vec![10.0, 11.0, 20.0, 21.0]);
        let v = Matrix::from_vec(2, 2, vec![30.0, 31.0, 40.0, 41.0]);
        for (row, cache) in seq.iter_mut().enumerate() {
            let s = cache.advance();
            for l in 0..2 {
                cache.write(l, s, k.row(row), v.row(row));
            }
        }

        let mut fused = build();
        let slots = vec![0usize, 1];
        let mut ring = Vec::new();
        advance_rows(&mut fused, &slots, &mut ring);
        assert_eq!(ring.len(), 2);
        for l in 0..2 {
            write_rows(&mut fused, &slots, &ring, l, &k, &v);
        }

        for (a, b) in seq.iter().zip(&fused) {
            assert_eq!(a.len(), b.len());
            assert_eq!(a.next_pos(), b.next_pos());
            for l in 0..2 {
                for i in 0..a.len() {
                    assert_eq!(a.k_row(l, i), b.k_row(l, i), "layer {l} row {i}");
                    assert_eq!(a.v_row(l, i), b.v_row(l, i), "layer {l} row {i}");
                }
            }
        }
    }

    #[test]
    fn advance_rows_reuses_ring_buffer() {
        let mut caches = vec![KvCache::new(1, 2, 1)];
        let mut ring = vec![7usize, 7, 7];
        advance_rows(&mut caches, &[0], &mut ring);
        assert_eq!(ring, vec![0], "stale entries must be cleared");
        advance_rows(&mut caches, &[0], &mut ring);
        assert_eq!(ring, vec![1]);
    }

    #[test]
    fn export_then_append_is_byte_identical() {
        // fill a source cache, export its first 3 positions, import
        // them into a fresh cache: rows, positions and chronology must
        // match what direct advance+write would have produced
        let mut src = KvCache::new(2, 8, 2);
        for t in 0..5u32 {
            let slot = src.advance();
            for l in 0..2 {
                let row = [t as f32 + l as f32 * 10.0, -(t as f32)];
                src.write(l, slot, &row, &row);
            }
        }
        let block = src.export_block(0, 3);
        assert_eq!(block.len, 3);
        assert_eq!(block.bytes(), 2 * 2 * 3 * 2 * 4, "2 layers x (k,v) x 3 rows x 2 f32s");

        let mut dst = KvCache::new(2, 8, 2);
        dst.append_block(&block);
        assert_eq!(dst.len(), 3);
        assert_eq!(dst.next_pos(), 3);
        for l in 0..2 {
            for i in 0..3 {
                assert_eq!(dst.k_row(l, i), src.k_row(l, i), "layer {l} row {i}");
                assert_eq!(dst.v_row(l, i), src.v_row(l, i), "layer {l} row {i}");
            }
        }
        // the copy-in path is the one that bumps the copy counter
        assert_eq!(dst.pool().stats().copied_rows, 3);
        // appending continues the chronology exactly where the block ends
        let slot = dst.advance();
        dst.write(0, slot, &[9.0, 9.0], &[9.0, 9.0]);
        assert_eq!(dst.pos_of(3), 3);
    }

    #[test]
    #[should_panic(expected = "must fit the window")]
    fn append_block_rejects_overflow() {
        let mut src = KvCache::new(1, 4, 1);
        for _ in 0..4 {
            let s = src.advance();
            src.write(0, s, &[1.0], &[1.0]);
        }
        let block = src.export_block(0, 4);
        let mut dst = KvCache::new(1, 3, 1);
        dst.append_block(&block);
    }

    #[test]
    #[should_panic(expected = "window slid")]
    fn export_after_slide_panics() {
        // 5 appends over a window of 3: positions 0 and 1 were evicted,
        // so chronological index 0 is absolute position 2 — exporting
        // it as "position 0" must fail fast
        let mut c = KvCache::new(1, 3, 1);
        for _ in 0..5 {
            let s = c.advance();
            c.write(0, s, &[1.0], &[1.0]);
        }
        let _ = c.export_block(0, 1);
    }

    #[test]
    fn clear_resets_without_reallocating() {
        let mut c = KvCache::new(1, 2, 1);
        for _ in 0..3 {
            let s = c.advance();
            c.write(0, s, &[1.0], &[1.0]);
        }
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.next_pos(), 0);
        let s = c.advance();
        c.write(0, s, &[9.0], &[9.0]);
        assert_eq!(c.k_row(0, 0), &[9.0]);
        assert_eq!(c.pos_of(0), 0);
        // the cleared block came back from the pool's recycle list
        let s = c.pool().stats();
        assert_eq!(s.recycle_hits, 1, "clear retires storage for reuse, not for free()");
    }

    #[test]
    fn shared_append_is_zero_copy() {
        // one pool, two caches: publishing a full block from `src` and
        // splicing it into `dst` must exchange a handle, not rows
        let pool = Arc::new(KvPool::new(2, 1, 2, KvPool::UNBOUNDED));
        let mut src = KvCache::new_in_pool(&pool, 8);
        for t in 0..4u32 {
            let s = src.advance();
            let row = [t as f32, t as f32 + 0.5];
            src.write(0, s, &row, &row);
        }
        let shared = src.share_block(0).expect("first block is full");
        assert!(shared.is_full());

        let mut dst = KvCache::new_in_pool(&pool, 8);
        dst.append_shared(&shared);
        assert_eq!(dst.len(), 2);
        assert_eq!(dst.next_pos(), 2);
        for i in 0..2 {
            assert_eq!(dst.k_row(0, i), src.k_row(0, i));
        }
        // same storage, zero rows copied
        let again = dst.share_block(0).expect("imported block is sharable");
        assert!(Arc::ptr_eq(&shared, &again), "import must alias, not copy");
        assert_eq!(pool.stats().copied_rows, 0);

        // decode continues into a fresh tail; the shared block is never
        // mutated, so no copy-on-write fires either
        let s = dst.advance();
        dst.write(0, s, &[9.0, 9.0], &[9.0, 9.0]);
        assert_eq!(dst.pos_of(2), 2);
        assert_eq!(pool.stats().cow_copies, 0);
    }

    #[test]
    fn mutating_a_shared_tail_copies_on_write() {
        let pool = Arc::new(KvPool::new(4, 1, 1, KvPool::UNBOUNDED));
        let mut c = KvCache::new_in_pool(&pool, 8);
        for _ in 0..2 {
            let s = c.advance();
            c.write(0, s, &[1.0], &[1.0]);
        }
        // pin the partially-filled tail, then keep decoding into it
        let pinned = c.share_tail_for_audit().expect("tail exists");
        assert_eq!(pinned.len(), 2);
        let s = c.advance();
        c.write(0, s, &[7.0], &[7.0]);

        let stats = pool.stats();
        assert_eq!(stats.cow_copies, 1, "shared tail must be cloned before mutation");
        assert_eq!(stats.copied_rows, 2, "the clone carries the 2 already-written rows");
        // the pinned snapshot is untouched; the cache sees the new row
        assert_eq!(pinned.len(), 2);
        assert_eq!(c.len(), 3);
        assert_eq!(c.k_row(0, 2), &[7.0]);
        assert_eq!(c.k_row(0, 0), pinned.k_row(0, 0), "pre-COW rows match");
        c.assert_invariants();
    }

    #[test]
    fn window_slide_releases_head_blocks() {
        // bt=2, window=4: after 9 appends positions 5..9 are resident;
        // blocks [0,2) and [2,4) must have been returned to the pool
        let pool = Arc::new(KvPool::new(2, 1, 1, KvPool::UNBOUNDED));
        let mut c = KvCache::new_in_pool(&pool, 4);
        for t in 0..9u32 {
            let s = c.advance();
            c.write(0, s, &[t as f32], &[t as f32]);
        }
        assert_eq!(c.len(), 4);
        assert_eq!(c.next_pos(), 9);
        for (i, expect) in [5.0f32, 6.0, 7.0, 8.0].iter().enumerate() {
            assert_eq!(c.k_row(0, i), &[*expect]);
        }
        let s = pool.stats();
        // resident span [4, 9) covers blocks 2,3,4 — the rest retired
        assert_eq!(s.live_blocks, 3);
        assert_eq!(s.retired, 2);
        assert!(s.recycle_hits >= 1, "later blocks reuse retired storage");
        // once the head released, blocks lose their absolute labels
        assert!(c.share_block(0).is_none(), "slid cache must not publish");
        c.assert_invariants();
    }

    #[test]
    fn truncate_shrinks_partial_tail_in_place() {
        // bt=4, 5 appends → blocks [4][1]; truncating to 3 pops the
        // 1-row tail block and shrinks the full block to 3 rows
        let pool = Arc::new(KvPool::new(4, 1, 1, KvPool::UNBOUNDED));
        let mut c = KvCache::new_in_pool(&pool, 16);
        for t in 0..5u32 {
            let s = c.advance();
            c.write(0, s, &[t as f32], &[t as f32]);
        }
        assert_eq!(c.truncate_to(3), 2);
        assert_eq!(c.len(), 3);
        assert_eq!(c.next_pos(), 3);
        for i in 0..3 {
            assert_eq!(c.k_row(0, i), &[i as f32], "survivors untouched");
        }
        let s = pool.stats();
        assert_eq!(s.retired, 1, "the fully-rejected tail block is retired");
        assert_eq!(s.cow_copies, 0, "rollback of a private tail never copies");
        assert_eq!(s.copied_rows, 0, "rollback is a bookkeeping edit, not a row copy");
        c.assert_invariants();
    }

    #[test]
    fn truncate_then_append_matches_never_overextended() {
        // speculative shape: overextend with rejected drafts, roll
        // back, append the real tokens — rows must be byte-identical
        // to a cache that never held the rejects, across a block
        // boundary (bt=4, rollback from 6 to 3)
        let build = || {
            let pool = Arc::new(KvPool::new(4, 2, 2, KvPool::UNBOUNDED));
            let mut c = KvCache::new_in_pool(&pool, 16);
            for t in 0..3u32 {
                let s = c.advance();
                for l in 0..2 {
                    let row = [t as f32, l as f32];
                    c.write(l, s, &row, &row);
                }
            }
            c
        };
        let mut spec = build();
        for t in 3..6u32 {
            let s = spec.advance();
            for l in 0..2 {
                let junk = [99.0 + t as f32, 99.0];
                spec.write(l, s, &junk, &junk);
            }
        }
        assert_eq!(spec.truncate_to(3), 3);
        let mut plain = build();
        for c in [&mut spec, &mut plain] {
            for t in 3..7u32 {
                let s = c.advance();
                for l in 0..2 {
                    let row = [t as f32 * 2.0, l as f32];
                    c.write(l, s, &row, &row);
                }
            }
        }
        assert_eq!(spec.len(), plain.len());
        assert_eq!(spec.next_pos(), plain.next_pos());
        for l in 0..2 {
            for i in 0..plain.len() {
                assert_eq!(spec.k_row(l, i), plain.k_row(l, i), "layer {l} row {i}");
                assert_eq!(spec.v_row(l, i), plain.v_row(l, i), "layer {l} row {i}");
            }
        }
        spec.assert_invariants();
    }

    #[test]
    fn truncate_to_current_pos_is_noop() {
        let mut c = KvCache::new(1, 4, 1);
        for _ in 0..3 {
            let s = c.advance();
            c.write(0, s, &[1.0], &[1.0]);
        }
        assert_eq!(c.truncate_to(3), 0);
        assert_eq!(c.len(), 3);
        assert_eq!(c.next_pos(), 3);
    }

    #[test]
    fn truncate_to_zero_releases_every_block() {
        let pool = Arc::new(KvPool::new(2, 1, 1, KvPool::UNBOUNDED));
        let mut c = KvCache::new_in_pool(&pool, 8);
        for _ in 0..5 {
            let s = c.advance();
            c.write(0, s, &[1.0], &[1.0]);
        }
        assert_eq!(c.truncate_to(0), 5);
        assert!(c.is_empty());
        assert_eq!(c.next_pos(), 0);
        assert_eq!(pool.stats().live_blocks, 0, "no leaked blocks after full rollback");
        c.assert_invariants();
    }

    #[test]
    fn truncate_copies_on_write_when_tail_is_pinned() {
        // a pinned tail must not see its fill count shrink: rollback
        // clones it first, and the snapshot keeps its rows
        let pool = Arc::new(KvPool::new(4, 1, 1, KvPool::UNBOUNDED));
        let mut c = KvCache::new_in_pool(&pool, 8);
        for t in 0..3u32 {
            let s = c.advance();
            c.write(0, s, &[t as f32], &[t as f32]);
        }
        let pinned = c.share_tail_for_audit().expect("tail exists");
        assert_eq!(c.truncate_to(1), 2);
        assert_eq!(pinned.len(), 3, "audit pin keeps its snapshot");
        assert_eq!(c.len(), 1);
        assert_eq!(pool.stats().cow_copies, 1, "pinned tail cloned before the shrink");
        c.assert_invariants();
    }

    #[test]
    #[should_panic(expected = "resurrect evicted rows")]
    fn truncate_past_resident_window_panics() {
        // window 3 over 5 appends: oldest resident is position 2;
        // rolling back to 1 would need evicted rows back
        let mut c = KvCache::new(1, 3, 1);
        for _ in 0..5 {
            let s = c.advance();
            c.write(0, s, &[1.0], &[1.0]);
        }
        let _ = c.truncate_to(1);
    }

    #[test]
    #[should_panic(expected = "cannot extend the chronology")]
    fn truncate_forward_panics() {
        let mut c = KvCache::new(1, 4, 1);
        let s = c.advance();
        c.write(0, s, &[1.0], &[1.0]);
        let _ = c.truncate_to(2);
    }

    #[test]
    fn advance_rows_allows_repeated_cache_indices() {
        // the speculative verify pass appends a run of positions to one
        // cache in a single batched call: repeats advance sequentially
        let mut caches = vec![KvCache::new(1, 8, 1), KvCache::new(1, 8, 1)];
        let mut ring = Vec::new();
        let slots = [0usize, 0, 1, 0];
        advance_rows(&mut caches, &slots, &mut ring);
        assert_eq!(ring, vec![0, 1, 0, 2], "repeats reserve consecutive rows");
        let k = Matrix::from_vec(4, 1, vec![10.0, 11.0, 20.0, 12.0]);
        let v = Matrix::from_vec(4, 1, vec![-10.0, -11.0, -20.0, -12.0]);
        write_rows(&mut caches, &slots, &ring, 0, &k, &v);
        assert_eq!(caches[0].len(), 3);
        assert_eq!(caches[1].len(), 1);
        for (i, expect) in [10.0f32, 11.0, 12.0].iter().enumerate() {
            assert_eq!(caches[0].k_row(0, i), &[*expect]);
            assert_eq!(caches[0].v_row(0, i), &[-*expect]);
        }
        assert_eq!(caches[1].k_row(0, 0), &[20.0]);
    }

    #[test]
    fn pool_budget_gates_admission_not_allocation() {
        let pool = Arc::new(KvPool::new(2, 1, 1, 2));
        assert_eq!(pool.free_blocks(), 2);
        assert_eq!(pool.blocks_for(3), 2);
        let a = pool.alloc();
        let b = pool.alloc();
        assert_eq!(pool.free_blocks(), 0);
        // over budget: allocation still succeeds (soft budget), the
        // free count just stays pinned at zero
        let c = pool.alloc();
        assert_eq!(pool.free_blocks(), 0);
        assert_eq!(pool.stats().live_blocks, 3);
        assert_eq!(pool.stats().peak_blocks, 3);
        drop((a, b, c));
        assert_eq!(pool.free_blocks(), 2);
        assert_eq!(pool.stats().retired, 3);
        pool.assert_invariants();
    }
}
