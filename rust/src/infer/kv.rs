//! Per-request KV cache: per-layer K/V ring buffers over a sliding
//! window of the last `window` positions — the state that turns the
//! O(T²) full-recompute decode loop into an O(T) incremental one.
//!
//! Window semantics match `runtime::session::recent_window` (and thus
//! `pack_decode_windows` / the XLA decode loop): the cache always holds
//! the *most recent* `window` positions; once full, appending a
//! position evicts the oldest.  Keys are stored RoPE'd at their
//! *absolute* position — RoPE attention scores depend only on relative
//! position, so evicting the head of the window never requires
//! re-rotating the survivors.
//!
//! Memory: `2 (K,V) · n_layers · window · d_model · 4` bytes per
//! request, allocated once and reused (`clear`) across requests.
//!
//! The fused multi-slot decode advances several independent caches per
//! tick; [`advance_rows`] / [`write_rows`] are its batched append
//! primitives (one chronology bump per row, then one per-layer scatter
//! of the batched K/V matrices into each row's own ring).

use crate::tensor::Matrix;

/// One layer's K and V ring storage, `[window, width]` row-major each.
struct LayerKv {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// A contiguous run of prefilled positions, exported from one
/// [`KvCache`] so another cache (or the shared
/// [`super::prefix::PrefixCache`]) can reuse the K/V rows without
/// re-running the model.  Layout: `layers[l]` holds that layer's
/// `(k, v)` rows as `[len, width]` row-major, row `i` being the
/// block's `i`-th position in chronological order.
#[derive(Clone, Debug, PartialEq)]
pub struct KvBlock {
    /// positions in this block
    pub len: usize,
    /// row width (`n_heads * head_dim`)
    pub width: usize,
    /// per-layer `(k, v)` rows, each `[len * width]` row-major
    pub layers: Vec<(Vec<f32>, Vec<f32>)>,
}

impl KvBlock {
    /// Heap bytes this block pins (the budget unit for
    /// [`super::prefix::PrefixCache`] eviction).
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|(k, v)| (k.len() + v.len()) * 4).sum()
    }
}

/// Ring-buffered K/V for every layer of one sequence.  All layers share
/// one chronology: `advance()` reserves the slot for the next position
/// once, then every layer writes its rows into that slot.
///
/// # Examples
///
/// ```
/// use db_llm::infer::KvCache;
///
/// // 1 layer, a 2-position window, rows of width 2
/// let mut cache = KvCache::new(1, 2, 2);
/// for t in 0..3u32 {
///     let slot = cache.advance(); // reserve the ring slot once …
///     let row = [t as f32, -(t as f32)];
///     cache.write(0, slot, &row, &row); // … then write each layer
/// }
/// // the window keeps the most recent 2 of the 3 appended positions
/// assert_eq!(cache.len(), 2);
/// assert_eq!(cache.k_row(0, 0), &[1.0, -1.0]); // oldest survivor
/// assert_eq!(cache.pos_of(1), 2); // absolute position of the newest
/// ```
pub struct KvCache {
    /// max cached positions (the sliding-window length)
    pub window: usize,
    /// row width = n_heads * head_dim (= d_model here)
    pub width: usize,
    layers: Vec<LayerKv>,
    /// filled positions (≤ window)
    len: usize,
    /// ring index of the oldest cached position
    start: usize,
    /// absolute position of the next appended token (monotonic)
    next_pos: usize,
}

impl KvCache {
    /// Allocate a cache of `window` positions × `width` floats per row
    /// for each of `n_layers` layers (K and V each), zero-filled.
    pub fn new(n_layers: usize, window: usize, width: usize) -> KvCache {
        assert!(window > 0, "window must be positive");
        let layers = (0..n_layers)
            .map(|_| LayerKv { k: vec![0.0; window * width], v: vec![0.0; window * width] })
            .collect();
        KvCache { window, width, layers, len: 0, start: 0, next_pos: 0 }
    }

    /// Cached positions (chronological indices run `0..len()`).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Number of layers this cache holds K/V rows for (lets callers
    /// clone a cache's geometry without carrying the model config).
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// True when no position is cached (fresh or just cleared).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Absolute position the next appended token will occupy.
    pub fn next_pos(&self) -> usize {
        self.next_pos
    }

    /// Absolute position of chronological index `i`.
    pub fn pos_of(&self, i: usize) -> usize {
        debug_assert!(i < self.len);
        self.next_pos - self.len + i
    }

    /// Reset for a new request without touching the allocations.
    pub fn clear(&mut self) {
        self.len = 0;
        self.start = 0;
        self.next_pos = 0;
        #[cfg(debug_assertions)]
        self.assert_invariants();
    }

    /// Reserve the ring slot for the next position, evicting the oldest
    /// when the window is full.  Returns the slot to pass to `write`.
    /// Call exactly once per position, before the per-layer writes.
    pub fn advance(&mut self) -> usize {
        let slot = (self.start + self.len) % self.window;
        if self.len == self.window {
            self.start = (self.start + 1) % self.window;
        } else {
            self.len += 1;
        }
        self.next_pos += 1;
        #[cfg(debug_assertions)]
        self.assert_invariants();
        slot
    }

    /// Audit the ring/window bookkeeping.  Debug builds run this after
    /// every mutating call; test suites call it directly.  Panics on
    /// the first violation:
    ///
    /// * `len ≤ window` (the ring never claims more than it holds),
    /// * `start < window` (the oldest-position index stays in range),
    /// * `next_pos ≥ len` (absolute chronology is never behind the
    ///   resident count — their difference is the evicted-prefix
    ///   length), and
    /// * every layer's K and V storage spans exactly `window × width`
    ///   floats (geometry never drifts after construction).
    pub fn assert_invariants(&self) {
        assert!(
            self.len <= self.window,
            "kv ring holds {} positions but the window is {}",
            self.len,
            self.window
        );
        assert!(
            self.start < self.window,
            "kv ring start {} outside window {}",
            self.start,
            self.window
        );
        assert!(
            self.next_pos >= self.len,
            "kv chronology behind resident count ({} < {})",
            self.next_pos,
            self.len
        );
        for (i, l) in self.layers.iter().enumerate() {
            assert!(
                l.k.len() == self.window * self.width && l.v.len() == l.k.len(),
                "layer {i} K/V storage drifted from window x width"
            );
        }
    }

    /// Write one layer's K/V rows for the slot returned by `advance`.
    pub fn write(&mut self, layer: usize, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.width);
        debug_assert_eq!(v_row.len(), self.width);
        let l = &mut self.layers[layer];
        l.k[slot * self.width..(slot + 1) * self.width].copy_from_slice(k_row);
        l.v[slot * self.width..(slot + 1) * self.width].copy_from_slice(v_row);
    }

    /// Layer `layer`'s key row at chronological index `i` (0 = oldest).
    pub fn k_row(&self, layer: usize, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        let slot = (self.start + i) % self.window;
        &self.layers[layer].k[slot * self.width..(slot + 1) * self.width]
    }

    /// Layer `layer`'s value row at chronological index `i`.
    pub fn v_row(&self, layer: usize, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        let slot = (self.start + i) % self.window;
        &self.layers[layer].v[slot * self.width..(slot + 1) * self.width]
    }

    /// Copy chronological positions `[start, start + len)` out as a
    /// [`KvBlock`] — the publish half of cross-request prefix sharing.
    /// Callers must only export positions whose absolute position
    /// equals their chronological index (i.e. before the window ever
    /// slid), or the block would be mislabeled; `prefill` never slides
    /// within one pass, so prompt blocks always qualify.
    pub fn export_block(&self, start: usize, len: usize) -> KvBlock {
        assert!(start + len <= self.len, "export range outside cached positions");
        let layers = (0..self.layers.len())
            .map(|l| {
                let mut k = Vec::with_capacity(len * self.width);
                let mut v = Vec::with_capacity(len * self.width);
                for i in start..start + len {
                    k.extend_from_slice(self.k_row(l, i));
                    v.extend_from_slice(self.v_row(l, i));
                }
                (k, v)
            })
            .collect();
        KvBlock { len, width: self.width, layers }
    }

    /// Append an exported block's positions — the copy-in half of
    /// prefix sharing.  The block's rows are appended in chronological
    /// order exactly as `advance` + `write` would have, so a warm
    /// cache is byte-identical to one that prefilled the same tokens.
    pub fn append_block(&mut self, block: &KvBlock) {
        assert_eq!(block.width, self.width, "block width != cache width");
        assert_eq!(block.layers.len(), self.layers.len(), "block layer count");
        assert!(
            self.len + block.len <= self.window && self.len == self.next_pos,
            "prefix import must fit the window before any slide"
        );
        let w = self.width;
        for i in 0..block.len {
            let slot = self.advance();
            for (l, (k, v)) in block.layers.iter().enumerate() {
                self.write(l, slot, &k[i * w..(i + 1) * w], &v[i * w..(i + 1) * w]);
            }
        }
        #[cfg(debug_assertions)]
        self.assert_invariants();
    }
}

/// Batched append across independent caches: reserve the next ring slot
/// in each listed cache (exactly one [`KvCache::advance`] per row).
/// `slots[i]` names the cache row `i` appends to — slots must be
/// distinct — and the reserved ring slot per row lands in `ring`
/// (cleared first), to be passed to [`write_rows`] for every layer.
pub fn advance_rows(caches: &mut [KvCache], slots: &[usize], ring: &mut Vec<usize>) {
    ring.clear();
    for &slot in slots {
        ring.push(caches[slot].advance());
    }
}

/// Write one layer's batched K/V rows (`k`, `v` are `[m, width]`
/// row-major, row `i` belonging to `caches[slots[i]]`) into the ring
/// slots reserved by [`advance_rows`].
pub fn write_rows(
    caches: &mut [KvCache],
    slots: &[usize],
    ring: &[usize],
    layer: usize,
    k: &Matrix,
    v: &Matrix,
) {
    debug_assert_eq!(slots.len(), ring.len());
    debug_assert_eq!(k.rows, slots.len());
    debug_assert_eq!(v.rows, slots.len());
    for (i, (&slot, &rs)) in slots.iter().zip(ring).enumerate() {
        caches[slot].write(layer, rs, k.row(i), v.row(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_then_evicts_oldest() {
        let mut c = KvCache::new(1, 3, 2);
        for t in 0..5u32 {
            let slot = c.advance();
            let row = [t as f32, -(t as f32)];
            c.write(0, slot, &row, &row);
        }
        // window 3 over 5 appends: chronological content is 2, 3, 4
        assert_eq!(c.len(), 3);
        assert_eq!(c.next_pos(), 5);
        for (i, expect) in [2.0f32, 3.0, 4.0].iter().enumerate() {
            assert_eq!(c.k_row(0, i)[0], *expect);
            assert_eq!(c.v_row(0, i)[1], -expect);
            assert_eq!(c.pos_of(i), 2 + i);
        }
    }

    #[test]
    fn layers_share_one_chronology() {
        let mut c = KvCache::new(2, 2, 1);
        let s0 = c.advance();
        c.write(0, s0, &[10.0], &[10.5]);
        c.write(1, s0, &[20.0], &[20.5]);
        let s1 = c.advance();
        c.write(0, s1, &[11.0], &[11.5]);
        c.write(1, s1, &[21.0], &[21.5]);
        assert_eq!(c.k_row(0, 0), &[10.0]);
        assert_eq!(c.k_row(1, 1), &[21.0]);
        assert_eq!(c.v_row(1, 0), &[20.5]);
    }

    #[test]
    fn batched_append_matches_sequential_appends() {
        // two caches at different occupancies: the batched helpers must
        // land the same rows in the same ring slots as per-cache
        // advance+write
        let build = || {
            let mut a = KvCache::new(2, 3, 2);
            let mut b = KvCache::new(2, 3, 2);
            for t in 0..4u32 {
                // cache 0 is already wrapping, cache 1 half full
                let s = a.advance();
                a.write(0, s, &[t as f32, 0.0], &[0.0, t as f32]);
                a.write(1, s, &[t as f32, 1.0], &[1.0, t as f32]);
            }
            let s = b.advance();
            b.write(0, s, &[9.0, 9.0], &[9.0, 9.0]);
            b.write(1, s, &[8.0, 8.0], &[8.0, 8.0]);
            vec![a, b]
        };

        let mut seq = build();
        let k = Matrix::from_vec(2, 2, vec![10.0, 11.0, 20.0, 21.0]);
        let v = Matrix::from_vec(2, 2, vec![30.0, 31.0, 40.0, 41.0]);
        for (row, cache) in seq.iter_mut().enumerate() {
            let s = cache.advance();
            for l in 0..2 {
                cache.write(l, s, k.row(row), v.row(row));
            }
        }

        let mut fused = build();
        let slots = vec![0usize, 1];
        let mut ring = Vec::new();
        advance_rows(&mut fused, &slots, &mut ring);
        assert_eq!(ring.len(), 2);
        for l in 0..2 {
            write_rows(&mut fused, &slots, &ring, l, &k, &v);
        }

        for (a, b) in seq.iter().zip(&fused) {
            assert_eq!(a.len(), b.len());
            assert_eq!(a.next_pos(), b.next_pos());
            for l in 0..2 {
                for i in 0..a.len() {
                    assert_eq!(a.k_row(l, i), b.k_row(l, i), "layer {l} row {i}");
                    assert_eq!(a.v_row(l, i), b.v_row(l, i), "layer {l} row {i}");
                }
            }
        }
    }

    #[test]
    fn advance_rows_reuses_ring_buffer() {
        let mut caches = vec![KvCache::new(1, 2, 1)];
        let mut ring = vec![7usize, 7, 7];
        advance_rows(&mut caches, &[0], &mut ring);
        assert_eq!(ring, vec![0], "stale entries must be cleared");
        advance_rows(&mut caches, &[0], &mut ring);
        assert_eq!(ring, vec![1]);
    }

    #[test]
    fn export_then_append_is_byte_identical() {
        // fill a source cache, export its first 3 positions, import
        // them into a fresh cache: rows, positions and chronology must
        // match what direct advance+write would have produced
        let mut src = KvCache::new(2, 8, 2);
        for t in 0..5u32 {
            let slot = src.advance();
            for l in 0..2 {
                let row = [t as f32 + l as f32 * 10.0, -(t as f32)];
                src.write(l, slot, &row, &row);
            }
        }
        let block = src.export_block(0, 3);
        assert_eq!(block.len, 3);
        assert_eq!(block.bytes(), 2 * 2 * 3 * 2 * 4, "2 layers x (k,v) x 3 rows x 2 f32s");

        let mut dst = KvCache::new(2, 8, 2);
        dst.append_block(&block);
        assert_eq!(dst.len(), 3);
        assert_eq!(dst.next_pos(), 3);
        for l in 0..2 {
            for i in 0..3 {
                assert_eq!(dst.k_row(l, i), src.k_row(l, i), "layer {l} row {i}");
                assert_eq!(dst.v_row(l, i), src.v_row(l, i), "layer {l} row {i}");
            }
        }
        // appending continues the chronology exactly where the block ends
        let slot = dst.advance();
        dst.write(0, slot, &[9.0, 9.0], &[9.0, 9.0]);
        assert_eq!(dst.pos_of(3), 3);
    }

    #[test]
    #[should_panic(expected = "must fit the window")]
    fn append_block_rejects_overflow() {
        let mut src = KvCache::new(1, 4, 1);
        for _ in 0..4 {
            let s = src.advance();
            src.write(0, s, &[1.0], &[1.0]);
        }
        let block = src.export_block(0, 4);
        let mut dst = KvCache::new(1, 3, 1);
        dst.append_block(&block);
    }

    #[test]
    fn clear_resets_without_reallocating() {
        let mut c = KvCache::new(1, 2, 1);
        for _ in 0..3 {
            let s = c.advance();
            c.write(0, s, &[1.0], &[1.0]);
        }
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.next_pos(), 0);
        let s = c.advance();
        c.write(0, s, &[9.0], &[9.0]);
        assert_eq!(c.k_row(0, 0), &[9.0]);
        assert_eq!(c.pos_of(0), 0);
    }
}
