//! `NativeEngine` — the KV-cached native decode backend for the
//! serving stack.  Implements the same [`Generator`] contract as the
//! XLA-backed `EngineWorker` (per-row `DecodeParams`, early exit,
//! NaN-safe sampling), so `serve()` runs the whole worker-pool /
//! batcher / metrics stack unchanged on top of it via `--backend
//! native`.
//!
//! Rows decode sequentially: prefill fills the request's KV cache in
//! one batched pass, then each token costs a single O(window)
//! incremental step — not a full-window forward.  One cache allocation
//! is reused (`clear`) across rows and requests.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::serve::{argmax, sample, DecodeParams, Generation, Generator};
use crate::model::Weights;
use crate::quant::FdbLinear;
use crate::util::Pcg32;

use super::kv::KvCache;
use super::step::IncrementalForward;

/// Native incremental generation engine.
pub struct NativeEngine {
    model: IncrementalForward,
    cache: KvCache,
    rng: Pcg32,
}

impl NativeEngine {
    /// Build from a full weight set; linears named in `fdb` decode on
    /// the compiled sparse kernel.  `window` is the sliding attention
    /// window (use the manifest `seq_len` to mirror the XLA backend).
    pub fn new(
        weights: Weights,
        fdb: &BTreeMap<String, FdbLinear>,
        window: usize,
        seed: u64,
    ) -> NativeEngine {
        let n_layers = weights.config.n_layers;
        let d = weights.config.d_model;
        let model = IncrementalForward::new(weights, fdb);
        NativeEngine {
            model,
            cache: KvCache::new(n_layers, window.max(1), d),
            rng: Pcg32::seeded(seed),
        }
    }

    /// Number of FDB-compiled linears (diagnostics / startup log).
    pub fn n_fdb_ops(&self) -> usize {
        self.model.n_fdb_ops()
    }

    /// Move the sampler onto its own PCG stream (worker pools build
    /// every engine from one factory).
    pub fn fork_rng(&mut self, stream: u64) {
        let state = self.rng.next_u64();
        self.rng = Pcg32::new(state, stream);
    }
}

impl Generator for NativeEngine {
    /// Decode each row to completion under its own `DecodeParams`.
    /// `Generation::steps` reports the longest row's decoded length —
    /// the same "batch forwards" accounting as the XLA decode loop, so
    /// the early-exit metric stays comparable across backends.
    fn generate(&mut self, prompts: &[Vec<u32>], params: &[DecodeParams]) -> Result<Generation> {
        anyhow::ensure!(params.len() == prompts.len(), "params/prompts length mismatch");
        let vocab = self.model.vocab();
        for p in prompts {
            anyhow::ensure!(!p.is_empty(), "empty prompt");
            for &t in p {
                anyhow::ensure!((t as usize) < vocab, "prompt token {t} out of vocab {vocab}");
            }
        }
        let mut outputs: Vec<Vec<u32>> = vec![Vec::new(); prompts.len()];
        let mut steps = 0usize;
        for (r, (prompt, p)) in prompts.iter().zip(params).enumerate() {
            if p.max_tokens == 0 {
                continue;
            }
            self.cache.clear();
            let mut logits = self.model.prefill(&mut self.cache, prompt);
            let out = &mut outputs[r];
            loop {
                let idx = if p.temperature <= 0.0 {
                    argmax(&logits)
                } else {
                    sample(&logits, p.temperature, &mut self.rng)
                };
                let next = idx as u32;
                out.push(next);
                if out.len() >= p.max_tokens || p.stop == Some(next) {
                    break;
                }
                logits = self.model.step(&mut self.cache, next);
            }
            steps = steps.max(out.len());
        }
        Ok(Generation { outputs, steps })
    }

    fn fork_rng(&mut self, stream: u64) {
        NativeEngine::fork_rng(self, stream);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            vocab: 96,
            seq_len: 32,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    fn engine(seed: u64) -> NativeEngine {
        let cfg = tiny();
        NativeEngine::new(Weights::synthetic(&cfg, seed), &BTreeMap::new(), cfg.seq_len, 42)
    }

    #[test]
    fn per_row_budgets_and_early_exit() {
        let mut e = engine(1);
        let prompts = vec![vec![1u32, 2], vec![3u32], vec![4u32, 5, 6]];
        let params = vec![
            DecodeParams::greedy(2),
            DecodeParams::greedy(0),
            DecodeParams::greedy(5),
        ];
        let g = e.generate(&prompts, &params).unwrap();
        assert_eq!(g.outputs[0].len(), 2);
        assert!(g.outputs[1].is_empty());
        assert_eq!(g.outputs[2].len(), 5);
        assert_eq!(g.steps, 5, "longest row bounds the step count");
    }

    #[test]
    fn greedy_is_deterministic_and_stop_fires() {
        let mut e = engine(2);
        let prompts = vec![vec![7u32, 8, 9]];
        let params = vec![DecodeParams::greedy(4)];
        let a = e.generate(&prompts, &params).unwrap().outputs.remove(0);
        let b = e.generate(&prompts, &params).unwrap().outputs.remove(0);
        assert_eq!(a, b, "greedy decode must be deterministic");
        // stopping on the first greedy token truncates to length 1
        let stopped = e
            .generate(
                &prompts,
                &[DecodeParams { max_tokens: 4, temperature: 0.0, stop: Some(a[0]) }],
            )
            .unwrap();
        assert_eq!(stopped.outputs[0], vec![a[0]]);
    }

    #[test]
    fn rejects_bad_prompts() {
        let mut e = engine(3);
        assert!(e.generate(&[vec![]], &[DecodeParams::greedy(1)]).is_err());
        assert!(e.generate(&[vec![9999]], &[DecodeParams::greedy(1)]).is_err());
        assert!(e.generate(&[vec![1]], &[]).is_err());
    }

    #[test]
    fn decodes_past_the_window_with_bounded_cache() {
        let cfg = tiny();
        let window = 8;
        let mut e =
            NativeEngine::new(Weights::synthetic(&cfg, 4), &BTreeMap::new(), window, 42);
        let prompt: Vec<u32> = (0..6u32).collect();
        let g = e.generate(&[prompt], &[DecodeParams::greedy(10)]).unwrap();
        // 6 prompt + 10 decoded blows past window 8; the ring must cap
        assert_eq!(g.outputs[0].len(), 10);
        assert_eq!(e.cache.len(), window);
        assert!(g.outputs[0].iter().all(|&t| (t as usize) < cfg.vocab));
    }
}
