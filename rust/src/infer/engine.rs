//! `NativeEngine` — the KV-cached native decode backend for the
//! serving stack.  `serve --backend native` drives it through the
//! continuous-batching scheduler (`coordinator::scheduler`); the
//! [`Generator`] contract (per-row `DecodeParams`, early exit,
//! NaN-safe sampling, same as the XLA-backed `EngineWorker`) is kept
//! for the static path — tests, benches, and equivalence checks.
//!
//! Two decode lifecycles over one model:
//!
//! - the batch-at-a-time [`Generator`] contract (rows decode
//!   sequentially on slot 0's cache — the static path);
//! - the slot-granular [`SlotEngine`] contract for the continuous
//!   scheduler: one [`KvCache`] per slot, so `prefill_slot(i)` /
//!   `step_slot(i)` / `reset_slot(i)` touch slot `i`'s state only and
//!   a freed row can be refilled while its neighbours keep decoding.
//!
//! Every slot cache is a block-table view into one engine-owned
//! [`KvPool`]: KV bytes are pooled across slots, prefix-cache hits
//! splice shared block handles in with zero row copies, decoded blocks
//! publish back into the prefix chain at block boundaries (multi-turn
//! conversations re-enter warm), and the scheduler's admission gate
//! ([`SlotEngine::can_admit`]) runs on the pool's free-block count
//! instead of worst-case per-slot reservations.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::scheduler::{EngineTimers, PrefixCounters, SlotEngine};
use crate::coordinator::serve::{argmax, sample, DecodeParams, Generation, Generator};
use crate::model::Weights;
use crate::quant::FdbLinear;
use crate::util::Pcg32;

use super::kv::{KvCache, KvPool, KvPoolBlock, DEFAULT_BLOCK_TOKENS};
use super::prefix::PrefixCache;
use super::step::IncrementalForward;

/// Sample one fused decode step in this many for the engine-side phase
/// timer (`EngineTimers::step_ns`).  Prefills are timed on every call —
/// they are rare and expensive — while steps run per tick, so sampling
/// keeps the two `Instant` reads off all but 1-in-64 hot-path calls.
const ENGINE_PROFILE_EVERY: u64 = 64;

/// Native incremental generation engine.
pub struct NativeEngine {
    model: IncrementalForward,
    /// shared block allocator every slot cache draws from
    pool: Arc<KvPool>,
    /// operator-configured soft KV budget in bytes (`None` = unbounded);
    /// kept in bytes so a pool rebuild under a different block size
    /// preserves the operator's intent
    pool_budget_bytes: Option<usize>,
    /// one KV cache (block-table view) per decode slot; `new` starts
    /// with a single slot
    caches: Vec<KvCache>,
    /// cross-request prefix sharing, usually one cache shared across
    /// every worker's engine (`with_prefix_cache`); `None` = every
    /// prefill is cold
    prefix: Option<Arc<Mutex<PrefixCache>>>,
    /// per-slot pinned prefix blocks (released on reset / re-prefill)
    slot_pins: Vec<Vec<u64>>,
    /// per-slot cached-token history (prompt + decoded tokens fed back
    /// in), the key under which decoded blocks publish back into the
    /// prefix chain
    slot_tokens: Vec<Vec<u32>>,
    /// per-slot publish-back eligibility; cleared once a slot's window
    /// slides (absolute labels gone) or its lifecycle left the slot API
    slot_share: Vec<bool>,
    /// this engine's cumulative hit/miss/eviction tally (per-engine so
    /// per-worker metric deltas never double-count the shared cache)
    prefix_counters: PrefixCounters,
    /// engine-side phase timers: every prefill, 1-in-N fused steps
    timers: EngineTimers,
    /// fused-step call counter driving the 1-in-N timer sample
    step_seq: u64,
    rng: Pcg32,
}

impl NativeEngine {
    /// Build from a full weight set; linears named in `fdb` decode on
    /// the compiled sparse kernel.  `window` is the sliding attention
    /// window (use the manifest `seq_len` to mirror the XLA backend).
    pub fn new(
        weights: Weights,
        fdb: &BTreeMap<String, FdbLinear>,
        window: usize,
        seed: u64,
    ) -> NativeEngine {
        let n_layers = weights.config.n_layers;
        let d = weights.config.d_model;
        let wide = d.max(weights.config.d_ff);
        let window = window.max(1);
        // engine construction runs on the thread that will decode, so
        // warm the per-thread prefill scratch here: the first request's
        // batched FDB products ([≤window, d|d_ff] inputs) allocate
        // nothing
        crate::quant::kernel::warm_thread_scratch(window, wide, wide);
        let model = IncrementalForward::new(weights, fdb);
        let pool = Arc::new(KvPool::new(DEFAULT_BLOCK_TOKENS, n_layers, d, KvPool::UNBOUNDED));
        let caches = vec![KvCache::new_in_pool(&pool, window)];
        NativeEngine {
            model,
            pool,
            pool_budget_bytes: None,
            caches,
            prefix: None,
            slot_pins: vec![Vec::new()],
            slot_tokens: vec![Vec::new()],
            slot_share: vec![false],
            prefix_counters: PrefixCounters::default(),
            timers: EngineTimers::default(),
            step_seq: 0,
            rng: Pcg32::seeded(seed),
        }
    }

    /// Soft block budget for the current configuration: the operator's
    /// byte budget translated to blocks, floored so a single request
    /// can always prefill a full window and decode one block past it —
    /// the budget bounds *concurrency*, never a lone request.
    fn budget_blocks(&self, block_tokens: usize) -> usize {
        let window = self.caches[0].window;
        match self.pool_budget_bytes {
            None => KvPool::UNBOUNDED,
            Some(bytes) => {
                let block_bytes = 2 * self.pool.n_layers() * block_tokens * self.pool.width() * 4;
                let floor = window.div_ceil(block_tokens) + 2;
                (bytes / block_bytes.max(1)).max(floor)
            }
        }
    }

    /// Replace the pool (new block size and/or budget) and rebuild
    /// every slot cache as a view into it.  Slot state is dropped.
    fn rebuild_pool(&mut self, block_tokens: usize) {
        self.release_all_pins();
        let window = self.caches[0].window;
        let slots = self.caches.len();
        let max_blocks = self.budget_blocks(block_tokens);
        self.pool = Arc::new(KvPool::new(
            block_tokens,
            self.pool.n_layers(),
            self.pool.width(),
            max_blocks,
        ));
        self.caches = (0..slots).map(|_| KvCache::new_in_pool(&self.pool, window)).collect();
        self.slot_pins = (0..slots).map(|_| Vec::new()).collect();
        self.slot_tokens = (0..slots).map(|_| Vec::new()).collect();
        self.slot_share = vec![false; slots];
    }

    /// Resize to `slots` independent decode slots (each a fresh view
    /// into the shared pool) for the continuous scheduler.  Slot state
    /// is dropped; call before serving, not mid-request.
    pub fn with_slots(mut self, slots: usize) -> NativeEngine {
        self.release_all_pins();
        let window = self.caches[0].window;
        let slots = slots.max(1);
        self.caches = (0..slots).map(|_| KvCache::new_in_pool(&self.pool, window)).collect();
        self.slot_pins = (0..slots).map(|_| Vec::new()).collect();
        self.slot_tokens = (0..slots).map(|_| Vec::new()).collect();
        self.slot_share = vec![false; slots];
        // a fused tick can batch every slot at once: pre-size the row
        // scratch so the first decode tick pays no allocation
        self.model.reserve_rows(self.caches.len(), window);
        self
    }

    /// Cap the engine's KV pool at (roughly) `bytes` of block storage.
    /// The cap is a *soft* admission budget: allocation never fails,
    /// the scheduler just stops admitting once
    /// [`KvPool::free_blocks`] can't cover a new prompt (see
    /// [`SlotEngine::can_admit`]).  Zero means unbounded.  Slot state
    /// is dropped; call before serving.
    pub fn with_kv_pool_bytes(mut self, bytes: usize) -> NativeEngine {
        self.pool_budget_bytes = if bytes == 0 { None } else { Some(bytes) };
        self.rebuild_pool(self.pool.block_tokens());
        self
    }

    /// The shared block pool (stats surface for benches and tests).
    pub fn kv_pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// Attach a shared cross-request prefix cache: prefills splice the
    /// longest cached prefix match into the slot's block table (zero
    /// row copies), run the model over the uncached suffix only, and
    /// publish the prompt's full blocks back; decoded blocks also
    /// publish at block boundaries so multi-turn conversations re-enter
    /// warm.  Every engine sharing one cache must share model geometry
    /// (same factory) — block shapes are asserted on splice-in.  The
    /// engine's pool is rebuilt to the cache's block size when they
    /// differ.  Warm and cold prefills emit bit-identical logits
    /// (`tests/prefix_cache.rs`).
    pub fn with_prefix_cache(mut self, cache: Arc<Mutex<PrefixCache>>) -> NativeEngine {
        let bt = match cache.lock() {
            Ok(g) => g.block_tokens(),
            // poisoned at attach time: keep the current block size (a
            // poisoned cache degrades every prefill to cold anyway)
            Err(_) => self.pool.block_tokens(),
        };
        if bt != self.pool.block_tokens() {
            self.rebuild_pool(bt);
        }
        self.prefix = Some(cache);
        self
    }

    /// Audit every slot's block table, the shared pool's accounting,
    /// and, when attached (and not poisoned or held elsewhere), the
    /// shared prefix cache.  Test suites call this between decode
    /// steps; see `docs/INVARIANTS.md` for the invariant catalogue.
    pub fn assert_invariants(&self) {
        assert_eq!(
            self.slot_pins.len(),
            self.caches.len(),
            "pin table and cache table disagree on slot count"
        );
        assert_eq!(
            self.slot_tokens.len(),
            self.caches.len(),
            "token-history table and cache table disagree on slot count"
        );
        assert_eq!(
            self.slot_share.len(),
            self.caches.len(),
            "share table and cache table disagree on slot count"
        );
        for (slot, c) in self.caches.iter().enumerate() {
            c.assert_invariants();
            assert_eq!(
                c.block_tokens(),
                self.pool.block_tokens(),
                "slot {slot} cache drifted from the engine pool's block size"
            );
            // a share-eligible slot's token history names exactly the
            // positions its cache holds rows for
            if self.slot_share[slot] {
                assert_eq!(
                    self.slot_tokens[slot].len(),
                    c.next_pos(),
                    "slot {slot} token history out of step with its cache"
                );
            }
        }
        self.pool.assert_invariants();
        if let Some(pc) = &self.prefix {
            if let Ok(g) = pc.try_lock() {
                g.assert_invariants();
            }
        }
    }

    /// Unpin every prefix block `slot` was holding.
    fn release_pins(&mut self, slot: usize) {
        let Some(pins) = self.slot_pins.get_mut(slot) else { return };
        if pins.is_empty() {
            return;
        }
        let pins = std::mem::take(pins);
        if let Some(pc) = &self.prefix {
            match pc.lock() {
                Ok(mut g) => g.release(&pins),
                // poisoned: the pins leak (the cache keeps those blocks
                // pinned), but decode stays up — and the event is
                // counted instead of silently degrading the hit rate
                Err(_) => self.prefix_counters.lock_poisoned += 1,
            }
        }
    }

    fn release_all_pins(&mut self) {
        for slot in 0..self.slot_pins.len() {
            self.release_pins(slot);
        }
    }

    /// Prefill `slot` through the prefix cache when one is attached:
    /// walk the longest cached prefix, splice its block handles in
    /// (zero row copies), run
    /// [`IncrementalForward::prefill_suffix`] over the rest, publish
    /// the prompt's blocks back.  Falls back to a cold prefill when
    /// sharing is off, the prompt overflows the window (sliding-window
    /// truncation relabels positions, so those prompts never share),
    /// or the cache lock is poisoned.
    fn prefill_cached(&mut self, slot: usize, prompt: &[u32]) -> Vec<f32> {
        // every prefill is timed: admissions are rare relative to decode
        // ticks and dominate TTFT, so full coverage is worth two
        // `Instant` reads per request
        let t0 = std::time::Instant::now();
        let logits = self.prefill_cached_inner(slot, prompt);
        self.timers.prefill_calls += 1;
        self.timers.prefill_ns += t0.elapsed().as_nanos() as u64;
        logits
    }

    fn prefill_cached_inner(&mut self, slot: usize, prompt: &[u32]) -> Vec<f32> {
        self.release_pins(slot);
        self.caches[slot].clear();
        self.slot_tokens[slot].clear();
        self.slot_share[slot] = false;
        let window = self.caches[slot].window;
        let Some(pc) = self.prefix.clone() else {
            return self.model.prefill(&mut self.caches[slot], prompt);
        };
        if prompt.len() > window {
            self.prefix_counters.miss_tokens += window as u64;
            return self.model.prefill(&mut self.caches[slot], prompt);
        }
        let mut pins = Vec::new();
        let mut matched = 0usize;
        let mut blocks: Vec<Arc<KvPoolBlock>> = Vec::new();
        match pc.lock() {
            Ok(mut g) => {
                let (p, m) = g.acquire(prompt);
                blocks.extend(p.iter().map(|h| g.block(*h).expect("pinned block vanished")));
                (pins, matched) = (p, m);
            }
            // poisoned: count the event and degrade to a cold prefill
            // (the whole prompt is a miss) rather than skip silently
            Err(_) => self.prefix_counters.lock_poisoned += 1,
        }
        // zero-copy import *outside* the shared cache lock: every
        // matched block enters the slot's table as an `Arc` clone — no
        // K/V row moves, so a warm admission costs
        // O(matched / block_tokens) handle pushes instead of an
        // O(matched) memcpy (and never stalls another worker behind it)
        for block in &blocks {
            self.caches[slot].append_shared(block);
        }
        self.prefix_counters.hit_tokens += matched as u64;
        self.prefix_counters.miss_tokens += (prompt.len() - matched) as u64;
        let logits = self.model.prefill_suffix(&mut self.caches[slot], &prompt[matched..]);
        match pc.lock() {
            Ok(mut g) => {
                self.prefix_counters.evictions += g.publish(prompt, &self.caches[slot]);
            }
            Err(_) => self.prefix_counters.lock_poisoned += 1,
        }
        self.slot_pins[slot] = pins;
        // decoded tokens extend this history; publish-back at block
        // boundaries keeps multi-turn conversations warm
        self.slot_tokens[slot].extend_from_slice(prompt);
        self.slot_share[slot] = true;
        logits
    }

    /// Publish `slot`'s full blocks (prompt *and* decoded positions)
    /// back into the prefix chain once its cached-token count crosses a
    /// block boundary — the re-entry path for multi-turn conversations,
    /// whose next request's prompt is this request's prompt + reply.
    /// Stops for good once the slot's window slides (absolute position
    /// labels are gone) or its token history fell out of step with the
    /// cache (the static path stepping outside the slot lifecycle).
    fn maybe_publish_decoded(&mut self, slot: usize) {
        if !self.slot_share[slot] {
            return;
        }
        let n = self.slot_tokens[slot].len();
        {
            let cache = &self.caches[slot];
            if cache.next_pos() != cache.len() || n != cache.next_pos() {
                self.slot_share[slot] = false;
                return;
            }
            if n % cache.block_tokens() != 0 {
                return;
            }
        }
        let Some(pc) = self.prefix.clone() else { return };
        match pc.lock() {
            Ok(mut g) => {
                let evicted = g.publish(&self.slot_tokens[slot], &self.caches[slot]);
                self.prefix_counters.evictions += evicted;
            }
            Err(_) => self.prefix_counters.lock_poisoned += 1,
        }
    }

    /// Record a decoded token fed back into `slot` and publish at block
    /// boundaries.  Called after every successful slot step.
    fn note_step(&mut self, slot: usize, token: u32) {
        if self.slot_share[slot] {
            self.slot_tokens[slot].push(token);
            self.maybe_publish_decoded(slot);
        }
    }

    /// The fused multi-slot step body; `SlotEngine::step_slots` wraps
    /// it with the 1-in-N phase timer.
    fn step_slots_inner(&mut self, steps: &[(usize, u32)]) -> Result<Vec<Vec<f32>>> {
        let vocab = self.model.vocab();
        let mut seen = vec![false; self.caches.len()];
        for &(slot, token) in steps {
            anyhow::ensure!(slot < self.caches.len(), "slot {slot} out of range");
            anyhow::ensure!(!seen[slot], "slot {slot} listed twice in one fused step");
            seen[slot] = true;
            anyhow::ensure!(!self.caches[slot].is_empty(), "step on a slot without prefill");
            anyhow::ensure!((token as usize) < vocab, "token {token} out of vocab {vocab}");
        }
        if steps.len() == 1 {
            // one active row: the allocation-free single-row kernel
            // beats the batched path (no transpose staging)
            let (slot, token) = steps[0];
            return Ok(vec![self.model.step(&mut self.caches[slot], token)]);
        }
        Ok(self.model.step_rows(&mut self.caches, steps))
    }

    /// Number of FDB-compiled linears (diagnostics / startup log).
    pub fn n_fdb_ops(&self) -> usize {
        self.model.n_fdb_ops()
    }

    /// Move the sampler onto its own PCG stream (worker pools build
    /// every engine from one factory).
    pub fn fork_rng(&mut self, stream: u64) {
        let state = self.rng.next_u64();
        self.rng = Pcg32::new(state, stream);
    }
}

impl Generator for NativeEngine {
    /// Decode each row to completion under its own `DecodeParams`.
    /// `Generation::steps` reports the longest row's decoded length —
    /// the same "batch forwards" accounting as the XLA decode loop, so
    /// the early-exit metric stays comparable across backends.
    fn generate(&mut self, prompts: &[Vec<u32>], params: &[DecodeParams]) -> Result<Generation> {
        anyhow::ensure!(params.len() == prompts.len(), "params/prompts length mismatch");
        let vocab = self.model.vocab();
        for p in prompts {
            anyhow::ensure!(!p.is_empty(), "empty prompt");
            for &t in p {
                anyhow::ensure!((t as usize) < vocab, "prompt token {t} out of vocab {vocab}");
            }
        }
        let mut outputs: Vec<Vec<u32>> = vec![Vec::new(); prompts.len()];
        let mut steps = 0usize;
        for (r, (prompt, p)) in prompts.iter().zip(params).enumerate() {
            if p.max_tokens == 0 {
                continue;
            }
            // the static path decodes every row on slot 0's cache
            // (prefix-shared when a cache is attached); it steps the
            // model directly below, outside the slot lifecycle that
            // tracks decoded-token history, so publish-back is off
            let mut logits = self.prefill_cached(0, prompt);
            self.slot_share[0] = false;
            let out = &mut outputs[r];
            loop {
                let idx = if p.temperature <= 0.0 {
                    argmax(&logits)
                } else {
                    sample(&logits, p.temperature, &mut self.rng)
                };
                let next = idx as u32;
                out.push(next);
                if out.len() >= p.max_tokens || p.stop == Some(next) {
                    break;
                }
                logits = self.model.step(&mut self.caches[0], next);
            }
            steps = steps.max(out.len());
        }
        Ok(Generation { outputs, steps })
    }

    fn fork_rng(&mut self, stream: u64) {
        NativeEngine::fork_rng(self, stream);
    }
}

impl SlotEngine for NativeEngine {
    fn slots(&self) -> usize {
        self.caches.len()
    }

    fn prefill_slot(&mut self, slot: usize, prompt: &[u32]) -> Result<Vec<f32>> {
        anyhow::ensure!(slot < self.caches.len(), "slot {slot} out of range");
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let vocab = self.model.vocab();
        for &t in prompt {
            anyhow::ensure!((t as usize) < vocab, "prompt token {t} out of vocab {vocab}");
        }
        Ok(self.prefill_cached(slot, prompt))
    }

    fn step_slot(&mut self, slot: usize, token: u32) -> Result<Vec<f32>> {
        anyhow::ensure!(slot < self.caches.len(), "slot {slot} out of range");
        anyhow::ensure!(!self.caches[slot].is_empty(), "step on a slot without prefill");
        let vocab = self.model.vocab();
        anyhow::ensure!((token as usize) < vocab, "token {token} out of vocab {vocab}");
        let logits = self.model.step(&mut self.caches[slot], token);
        self.note_step(slot, token);
        Ok(logits)
    }

    /// Fused multi-slot step: every linear (and the LM head) runs once
    /// as a batched product over the active rows instead of once per
    /// slot.  The whole batch is validated *before* any slot advances,
    /// so an `Err` means no state changed — the contract the
    /// scheduler's per-row fallback depends on.  1-in-N calls are
    /// timed into [`EngineTimers`] (`ENGINE_PROFILE_EVERY`); the timer
    /// reads are outside the decode math, so sampled and unsampled
    /// ticks produce bit-identical logits.
    fn step_slots(&mut self, steps: &[(usize, u32)]) -> Result<Vec<Vec<f32>>> {
        let sampled = self.step_seq % ENGINE_PROFILE_EVERY == 0;
        self.step_seq += 1;
        let t0 = if sampled { Some(std::time::Instant::now()) } else { None };
        let out = self.step_slots_inner(steps);
        if out.is_ok() {
            // publish-back bookkeeping happens outside the timed decode
            // math, and mutates only the shared prefix chain — never a
            // logit — so fused and sequential streams stay bit-identical
            for &(slot, token) in steps {
                self.note_step(slot, token);
            }
        }
        if let (Some(t0), Ok(_)) = (t0, &out) {
            self.timers.step_sampled += 1;
            self.timers.step_ns += t0.elapsed().as_nanos() as u64;
        }
        out
    }

    /// `step_slots` validates the whole batch before mutating any
    /// slot (and the fused math after validation is infallible), so a
    /// failed call never advances state — the scheduler may retry row
    /// by row.
    fn step_slots_atomic(&self) -> bool {
        true
    }

    fn reset_slot(&mut self, slot: usize) {
        self.release_pins(slot);
        if let Some(cache) = self.caches.get_mut(slot) {
            cache.clear();
        }
        if let Some(tokens) = self.slot_tokens.get_mut(slot) {
            tokens.clear();
        }
        if let Some(share) = self.slot_share.get_mut(slot) {
            *share = false;
        }
    }

    /// Post-panic slot reclamation.  `reset_slot` is already total on
    /// any reachable slot state — a half-finished prefill or step
    /// leaves the cache's block table and pin list internally
    /// consistent, so releasing the pins (a poisoned prefix lock is
    /// counted, never propagated), clearing the block table (each
    /// dropped handle returns its pool block), and wiping the token
    /// history is a complete quarantine with no panic path.
    fn quarantine_slot(&mut self, slot: usize) {
        self.reset_slot(slot);
    }

    /// Engine-wide repair after every slot was quarantined: clear a
    /// prefix-cache lock the panicking thread may have poisoned, reset
    /// every slot (now able to release pins the poisoned lock blocked),
    /// and audit the shared structures.  The audits are asserts — a
    /// violated pool invariant panics, which the supervisor treats as
    /// an unrecoverable engine and retires the worker.
    fn recover(&mut self) -> Result<()> {
        if let Some(pc) = &self.prefix {
            pc.clear_poison();
        }
        for slot in 0..self.caches.len() {
            self.reset_slot(slot);
        }
        if let Some(pc) = &self.prefix {
            if let Ok(g) = pc.try_lock() {
                g.assert_invariants();
            }
        }
        self.pool.assert_invariants();
        Ok(())
    }

    /// Admission gate on the shared pool: a prompt needs
    /// `⌈min(prompt, window) / block_tokens⌉` blocks to prefill plus
    /// one block of decode headroom.  An unbounded pool (no
    /// `--kv-pool-mb`) always admits — slot count alone gates, exactly
    /// the pre-pool behavior.
    fn can_admit(&self, prompt_tokens: usize) -> bool {
        let window = self.caches[0].window;
        let need = self.pool.blocks_for(prompt_tokens.min(window)) + 1;
        self.pool.free_blocks() >= need
    }

    /// Present only when a prefix cache is attached, so backends
    /// without sharing keep `prefix_*` metrics at zero instead of
    /// reporting all-miss traffic.
    fn prefix_counters(&self) -> Option<PrefixCounters> {
        self.prefix.as_ref().map(|_| self.prefix_counters)
    }

    /// Monotonic engine-side phase totals: every prefill timed, fused
    /// steps sampled 1-in-`ENGINE_PROFILE_EVERY`.
    fn phase_timers(&self) -> Option<EngineTimers> {
        Some(self.timers)
    }
}

impl Drop for NativeEngine {
    /// Unpin everything on teardown: a worker that exits mid-request
    /// must not leave its slots' prefix blocks pinned (and therefore
    /// unevictable) in the shared cache for the process's lifetime.
    fn drop(&mut self) {
        self.release_all_pins();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::PrefixCache;
    use crate::model::ModelConfig;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            vocab: 96,
            seq_len: 32,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    fn engine(seed: u64) -> NativeEngine {
        let cfg = tiny();
        NativeEngine::new(Weights::synthetic(&cfg, seed), &BTreeMap::new(), cfg.seq_len, 42)
    }

    #[test]
    fn per_row_budgets_and_early_exit() {
        let mut e = engine(1);
        let prompts = vec![vec![1u32, 2], vec![3u32], vec![4u32, 5, 6]];
        let params = vec![
            DecodeParams::greedy(2),
            DecodeParams::greedy(0),
            DecodeParams::greedy(5),
        ];
        let g = e.generate(&prompts, &params).unwrap();
        assert_eq!(g.outputs[0].len(), 2);
        assert!(g.outputs[1].is_empty());
        assert_eq!(g.outputs[2].len(), 5);
        assert_eq!(g.steps, 5, "longest row bounds the step count");
    }

    #[test]
    fn greedy_is_deterministic_and_stop_fires() {
        let mut e = engine(2);
        let prompts = vec![vec![7u32, 8, 9]];
        let params = vec![DecodeParams::greedy(4)];
        let a = e.generate(&prompts, &params).unwrap().outputs.remove(0);
        let b = e.generate(&prompts, &params).unwrap().outputs.remove(0);
        assert_eq!(a, b, "greedy decode must be deterministic");
        // stopping on the first greedy token truncates to length 1
        let stopped = e
            .generate(
                &prompts,
                &[DecodeParams { max_tokens: 4, temperature: 0.0, stop: Some(a[0]), speculate: true }],
            )
            .unwrap();
        assert_eq!(stopped.outputs[0], vec![a[0]]);
    }

    #[test]
    fn rejects_bad_prompts() {
        let mut e = engine(3);
        assert!(e.generate(&[vec![]], &[DecodeParams::greedy(1)]).is_err());
        assert!(e.generate(&[vec![9999]], &[DecodeParams::greedy(1)]).is_err());
        assert!(e.generate(&[vec![1]], &[]).is_err());
    }

    #[test]
    fn decodes_past_the_window_with_bounded_cache() {
        let cfg = tiny();
        let window = 8;
        let mut e =
            NativeEngine::new(Weights::synthetic(&cfg, 4), &BTreeMap::new(), window, 42);
        let prompt: Vec<u32> = (0..6u32).collect();
        let g = e.generate(&[prompt], &[DecodeParams::greedy(10)]).unwrap();
        // 6 prompt + 10 decoded blows past window 8; the ring must cap
        assert_eq!(g.outputs[0].len(), 10);
        assert_eq!(e.caches[0].len(), window);
        assert!(g.outputs[0].iter().all(|&t| (t as usize) < cfg.vocab));
    }

    /// Slot-granular lifecycle: decoding on one slot must not disturb
    /// another slot's in-flight sequence — prefill slot 1 mid-decode of
    /// slot 0 and the slot-0 stream must match an undisturbed run.
    #[test]
    fn slot_isolation_under_interleaving() {
        let mut reference = engine(7).with_slots(1);
        let prompt = vec![4u32, 9, 2];
        let mut expect = Vec::new();
        let mut logits = reference.prefill_slot(0, &prompt).unwrap();
        for _ in 0..6 {
            let tok = argmax(&logits) as u32;
            expect.push(tok);
            logits = reference.step_slot(0, tok).unwrap();
        }

        let mut e = engine(7).with_slots(3);
        assert_eq!(SlotEngine::slots(&e), 3);
        let mut got = Vec::new();
        let mut logits = e.prefill_slot(0, &prompt).unwrap();
        for i in 0..6 {
            let tok = argmax(&logits) as u32;
            got.push(tok);
            if i == 2 {
                // mid-flight admission into a neighbour slot
                e.prefill_slot(1, &[1u32, 2, 3]).unwrap();
                let other = argmax(&e.step_slot(1, 5).unwrap()) as u32;
                assert!((other as usize) < tiny().vocab);
                e.reset_slot(1);
            }
            logits = e.step_slot(0, tok).unwrap();
        }
        assert_eq!(got, expect, "slot 0 stream disturbed by slot 1 traffic");
    }

    #[test]
    fn slot_engine_validates_inputs() {
        let mut e = engine(8).with_slots(2);
        assert!(e.prefill_slot(2, &[1]).is_err(), "slot out of range");
        assert!(e.prefill_slot(0, &[]).is_err(), "empty prompt");
        assert!(e.prefill_slot(0, &[9999]).is_err(), "token out of vocab");
        assert!(e.step_slot(1, 1).is_err(), "step before prefill");
        e.prefill_slot(1, &[1, 2]).unwrap();
        assert!(e.step_slot(1, 1).is_ok());
        e.reset_slot(1);
        assert!(e.step_slot(1, 1).is_err(), "reset drops the sequence");
    }

    /// Panic-recovery contract: quarantining a mid-request slot
    /// returns its pool blocks, releases its prefix pins, and
    /// `recover` leaves the shared structures audit-clean.
    #[test]
    fn quarantine_and_recover_reclaim_blocks_and_pins() {
        // no prefix cache: every live block belongs to a slot, so a
        // full quarantine must return the pool to zero live blocks
        let mut e = engine(31).with_slots(2);
        e.prefill_slot(0, &(0..9u32).collect::<Vec<_>>()).unwrap();
        e.prefill_slot(1, &[1u32, 2, 3]).unwrap();
        e.step_slot(0, 3).unwrap();
        assert!(e.pool.stats().live_blocks > 0);
        e.quarantine_slot(0);
        e.quarantine_slot(1);
        e.recover().unwrap();
        assert_eq!(e.pool.stats().live_blocks, 0, "quarantine leaked pool blocks");
        e.assert_invariants();

        // with a shared prefix cache: quarantine releases the slots'
        // pins so the cache can evict those blocks again
        let pc = Arc::new(Mutex::new(PrefixCache::new(4, 1 << 20)));
        let mut e = engine(31).with_slots(2).with_prefix_cache(pc.clone());
        let prompt: Vec<u32> = (0..9u32).collect();
        e.prefill_slot(0, &prompt).unwrap(); // cold: publishes blocks
        e.prefill_slot(1, &prompt).unwrap(); // warm: pins them
        assert!(!e.slot_pins[1].is_empty(), "warm prefill pinned cached blocks");
        e.quarantine_slot(0);
        e.quarantine_slot(1);
        assert!(e.slot_pins.iter().all(Vec::is_empty), "quarantine left pins behind");
        e.recover().unwrap();
        e.assert_invariants();
        // decode after recovery starts from a clean slate
        e.prefill_slot(0, &prompt).unwrap();
        e.step_slot(0, 1).unwrap();
    }

    /// The fused batch is validated before any slot advances: a failed
    /// `step_slots` must leave every slot exactly where it was.
    #[test]
    fn step_slots_validates_before_stepping() {
        let mut e = engine(11).with_slots(2);
        e.prefill_slot(0, &[1, 2]).unwrap();
        assert!(e.step_slots(&[(0, 3), (1, 4)]).is_err(), "slot 1 never prefilled");
        assert!(e.step_slots(&[(0, 3), (0, 4)]).is_err(), "duplicate slot");
        assert!(e.step_slots(&[(0, 9999)]).is_err(), "token out of vocab");
        assert!(e.step_slots(&[(2, 1)]).is_err(), "slot out of range");
        // slot 0 must continue exactly where an undisturbed engine does
        let mut clean = engine(11).with_slots(2);
        clean.prefill_slot(0, &[1, 2]).unwrap();
        let got = e.step_slot(0, 3).unwrap();
        let expect = clean.step_slot(0, 3).unwrap();
        assert_eq!(got, expect, "failed fused call advanced slot state");
    }

    /// Engine-level prefix-sharing smoke check (the full property —
    /// whole greedy streams, eviction, racing — lives in
    /// `tests/prefix_cache.rs`): a warm prefill's logits are
    /// bit-identical to a cold engine's, and the hit/miss counters
    /// account exactly the block-granular reuse.
    #[test]
    fn prefix_cache_warms_prefill_bit_identically() {
        let pc = Arc::new(Mutex::new(PrefixCache::new(4, 1 << 20)));
        let mut cold = engine(21).with_slots(2);
        let mut warm = engine(21).with_slots(2).with_prefix_cache(pc.clone());
        assert!(SlotEngine::prefix_counters(&cold).is_none());
        let prompt: Vec<u32> = (0..9u32).collect();
        let a = cold.prefill_slot(0, &prompt).unwrap();
        // first warm-engine prefill is a miss; it publishes 2 full
        // 4-token blocks (8 of the 9 prompt tokens)
        let b = warm.prefill_slot(0, &prompt).unwrap();
        assert_eq!(a, b, "cold-vs-cold engines diverge");
        assert_eq!(pc.lock().unwrap().entries(), 2);
        // second prefill hits both blocks and only runs 1 suffix token
        let c = warm.prefill_slot(1, &prompt).unwrap();
        assert_eq!(a, c, "warm prefill logits diverge from cold");
        let ctr = SlotEngine::prefix_counters(&warm).unwrap();
        assert_eq!(ctr.hit_tokens, 8);
        assert_eq!(ctr.miss_tokens, 9 + 1);
        // decode continues identically on the imported rows
        for tok in [3u32, 5, 8] {
            let x = cold.step_slot(0, tok).unwrap();
            let y = warm.step_slot(1, tok).unwrap();
            assert_eq!(x, y, "post-warm decode diverges");
        }
    }

    /// Engine-level fused-vs-sequential check (the full property lives
    /// in `tests/fused_decode.rs`): same logits, same cache state.
    #[test]
    fn step_slots_matches_sequential_step_slot() {
        let mut seq = engine(12).with_slots(3);
        let mut fus = engine(12).with_slots(3);
        for (slot, prompt) in [(0usize, vec![1u32, 2, 3]), (1, vec![4u32]), (2, vec![5u32, 6])] {
            seq.prefill_slot(slot, &prompt).unwrap();
            fus.prefill_slot(slot, &prompt).unwrap();
        }
        let steps = [(0usize, 7u32), (1, 8), (2, 9)];
        for _ in 0..4 {
            let a: Vec<Vec<f32>> =
                steps.iter().map(|&(s, t)| seq.step_slot(s, t).unwrap()).collect();
            let b = fus.step_slots(&steps).unwrap();
            assert_eq!(a, b, "fused logits diverge from sequential");
        }
        // an empty batch is a no-op
        assert!(fus.step_slots(&[]).unwrap().is_empty());
    }

    /// Engine phase timers: every prefill is counted, fused steps are
    /// sampled 1-in-`ENGINE_PROFILE_EVERY` (the first call lands on the
    /// sample), and a failed fused call is never timed as work done.
    #[test]
    fn phase_timers_cover_prefills_and_sample_steps() {
        let mut e = engine(5).with_slots(2);
        assert_eq!(SlotEngine::phase_timers(&e).unwrap(), EngineTimers::default());
        e.prefill_slot(0, &[1, 2]).unwrap();
        e.prefill_slot(1, &[3]).unwrap();
        for _ in 0..3 {
            e.step_slots(&[(0, 4), (1, 5)]).unwrap();
        }
        let t = SlotEngine::phase_timers(&e).unwrap();
        assert_eq!(t.prefill_calls, 2, "every prefill timed");
        assert!(t.prefill_ns > 0, "prefill wall time recorded");
        assert_eq!(t.step_sampled, 1, "calls 2..64 skip the sample");
        assert!(t.step_ns > 0, "sampled step wall time recorded");
        assert!(e.step_slots(&[(0, 9999)]).is_err());
        let t2 = SlotEngine::phase_timers(&e).unwrap();
        assert_eq!(t2.step_sampled, t.step_sampled, "failed steps are not timed");
    }

    /// A poisoned prefix-cache lock degrades to a cold prefill and is
    /// *counted*, never silently swallowed: the acquire and publish
    /// sites each record the event in `PrefixCounters.lock_poisoned`.
    #[test]
    fn poisoned_prefix_lock_is_counted_not_silent() {
        let pc = Arc::new(Mutex::new(PrefixCache::new(4, 1 << 20)));
        let mut cold = engine(33).with_slots(1);
        let mut warm = engine(33).with_slots(1).with_prefix_cache(pc.clone());
        // poison the mutex: a thread panics while holding the guard
        let pc2 = pc.clone();
        std::thread::spawn(move || {
            let _g = pc2.lock().unwrap();
            panic!("poison the prefix lock");
        })
        .join()
        .unwrap_err();
        assert!(pc.lock().is_err(), "mutex should be poisoned");
        let prompt: Vec<u32> = (0..9u32).collect();
        let a = cold.prefill_slot(0, &prompt).unwrap();
        let b = warm.prefill_slot(0, &prompt).unwrap();
        assert_eq!(a, b, "poisoned-lock prefill must fall back to a cold prefill");
        let ctr = SlotEngine::prefix_counters(&warm).unwrap();
        assert_eq!(ctr.lock_poisoned, 2, "acquire + publish each count: {ctr:?}");
        assert_eq!(ctr.hit_tokens, 0, "no hits through a poisoned lock");
        assert_eq!(ctr.miss_tokens, prompt.len() as u64);
    }

    /// The acceptance property of the paged pool: a prefix-cache hit
    /// copies zero K/V rows.  The pool's `copied_rows` counter is
    /// bumped by every row memcpy (legacy imports, COW) — after a warm
    /// prefill that reuses 8 cached tokens it must still read zero,
    /// and the warm slot's table must alias the published blocks.
    #[test]
    fn warm_prefill_copies_zero_kv_rows() {
        let pc = Arc::new(Mutex::new(PrefixCache::new(4, 1 << 20)));
        let mut e = engine(40).with_slots(2).with_prefix_cache(pc.clone());
        assert_eq!(e.kv_pool().block_tokens(), 4, "pool rebuilt to the cache's block size");
        let prompt: Vec<u32> = (0..9u32).collect();
        e.prefill_slot(0, &prompt).unwrap();
        e.prefill_slot(1, &prompt).unwrap();
        let ctr = SlotEngine::prefix_counters(&e).unwrap();
        assert_eq!(ctr.hit_tokens, 8, "second prefill reuses both full blocks");
        let stats = e.kv_pool().stats();
        assert_eq!(stats.copied_rows, 0, "prefix hit must copy zero K/V rows");
        assert_eq!(stats.cow_copies, 0, "nothing mutated a shared block");
        // the two slots literally share storage for the matched prefix
        let a = e.caches[0].share_block(0).expect("slot 0 block 0");
        let b = e.caches[1].share_block(0).expect("slot 1 block 0");
        assert!(Arc::ptr_eq(&a, &b), "warm slot must alias, not copy");
        e.assert_invariants();
    }

    /// Decoded blocks publish back into the prefix chain: after a
    /// request decodes past a block boundary, a follow-up whose prompt
    /// is the previous prompt + reply (the multi-turn shape) re-enters
    /// warm across the *decoded* tokens too, not just the old prompt.
    #[test]
    fn decoded_blocks_publish_back_for_multiturn() {
        let pc = Arc::new(Mutex::new(PrefixCache::new(4, 1 << 20)));
        let mut e = engine(41).with_slots(2).with_prefix_cache(pc.clone());
        let prompt: Vec<u32> = (0..4u32).collect();
        e.prefill_slot(0, &prompt).unwrap();
        assert_eq!(pc.lock().unwrap().entries(), 1, "prompt block published");
        // feed 4 decoded tokens: history [0..8) crosses a block
        // boundary, so block [4..8) publishes mid-decode
        for tok in [10u32, 11, 12, 13] {
            e.step_slot(0, tok).unwrap();
        }
        assert_eq!(pc.lock().unwrap().entries(), 2, "decoded block published");
        // the multi-turn follow-up: old prompt + reply + new user turn
        let turn2: Vec<u32> = vec![0, 1, 2, 3, 10, 11, 12, 13, 20];
        e.prefill_slot(1, &turn2).unwrap();
        let ctr = SlotEngine::prefix_counters(&e).unwrap();
        assert_eq!(ctr.hit_tokens, 8, "both prompt and decoded blocks hit");
        assert_eq!(e.kv_pool().stats().copied_rows, 0);
        e.assert_invariants();
    }

    /// The pool budget gates admission, not allocation: `can_admit`
    /// goes false once free blocks can't cover a new prompt plus
    /// decode headroom, while the already-admitted slots keep stepping
    /// (soft budget).
    #[test]
    fn pool_budget_gates_admission_softly() {
        // window 32, default 16-token blocks; budget = 4 blocks' bytes
        let cfg = tiny();
        let block_bytes = 2 * cfg.n_layers * 16 * cfg.d_model * 4;
        let mut e = NativeEngine::new(Weights::synthetic(&cfg, 5), &BTreeMap::new(), 32, 42)
            .with_kv_pool_bytes(4 * block_bytes)
            .with_slots(4);
        assert_eq!(e.kv_pool().max_blocks(), 4);
        assert!(e.can_admit(8), "empty pool admits");
        e.prefill_slot(0, &[1, 2, 3]).unwrap(); // 1 block resident
        assert!(e.can_admit(8), "3 free ≥ 1 needed + 1 headroom");
        e.prefill_slot(1, &[4, 5, 6]).unwrap(); // 2 blocks resident
        assert!(e.can_admit(8), "2 free ≥ 2");
        e.prefill_slot(2, &[7, 8, 9]).unwrap(); // 3 blocks resident
        assert!(!e.can_admit(8), "1 free < 2: admission deferred");
        // the budget is soft: resident slots decode on regardless
        e.step_slot(0, 1).unwrap();
        e.step_slot(2, 1).unwrap();
        e.reset_slot(1);
        assert!(e.can_admit(8), "freed blocks re-open admission");
        e.assert_invariants();
    }
}
