//! Native incremental inference: the KV-cached decode engine behind
//! `serve --backend native`.
//!
//! Four pieces:
//! - [`kv::KvPool`] + [`kv::KvCache`] — a shared pool of fixed-size
//!   K/V blocks (vLLM/PagedAttention-style: free list, Arc ref counts,
//!   copy-on-write) with per-slot caches as block-table views over a
//!   sliding window (`runtime::session::recent_window` semantics);
//!   admission gates on free blocks, and prefix reuse exchanges block
//!   handles instead of copying rows;
//! - [`step::IncrementalForward`] — prefill (one batched pass) +
//!   O(window) single-position decode steps, every linear dispatched
//!   through [`step::LinearOp`] (dense, or the compiled FDB sparse
//!   kernel — the paper's "efficient bitwise operation" on the decode
//!   path end to end); plus the fused multi-slot
//!   [`step::IncrementalForward::step_rows`], which advances every
//!   active slot in one pass — each linear and the LM head run once
//!   per tick as a batched product, bit-identical to looping `step`;
//! - [`engine::NativeEngine`] — the `coordinator::serve::Generator`
//!   implementation that plugs it under the static worker pool, plus
//!   the slot-granular `coordinator::scheduler::SlotEngine` lifecycle
//!   (one `KvCache` per slot via `with_slots`, batched ticks via
//!   `step_slots`) that the continuous batching scheduler drives:
//!   prefill a freed slot mid-flight while the other slots keep
//!   decoding, then advance all of them together;
//! - [`spec::SpecDecoder`] — speculative decoding over both halves of
//!   the DB-LLM pair: the FDB student drafts `k` tokens per slot, the
//!   dense teacher verifies them in one fused `step_rows` pass, and
//!   greedy accept-longest-prefix with paged KV rollback
//!   ([`kv::KvCache::truncate_to`]) keeps the emitted stream
//!   bit-identical to teacher-only decode;
//! - [`prefix::PrefixCache`] — cross-request prefix sharing: prefilled
//!   K/V blocks keyed by token-prefix hash chains, ref-counted, LRU
//!   under a byte budget, shared across every scheduler worker so an
//!   admission only runs prefill over its *uncached suffix*
//!   ([`step::IncrementalForward::prefill_suffix`]) — bit-identical to
//!   a cold prefill.

#![warn(missing_docs)]

pub mod engine;
pub mod kv;
pub mod prefix;
pub mod spec;
pub mod step;

pub use engine::NativeEngine;
pub use kv::{DEFAULT_BLOCK_TOKENS, KvBlock, KvCache, KvPool, KvPoolBlock, KvPoolStats};
pub use prefix::{PrefixCache, PrefixCacheStats};
pub use spec::SpecDecoder;
pub use step::{IncrementalForward, LinearOp};
