//! `SpecDecoder` — speculative decoding over the native backend: a
//! cheap dual-binarized (FDB) **student** drafts `k` tokens per slot
//! per tick, the dense **teacher** verifies the whole run in ONE
//! batched forward through the fused [`IncrementalForward::step_rows`]
//! path, and greedy accept-longest-prefix keeps the emitted stream
//! **bit-identical** to teacher-only decode (`tests/spec_decode.rs`
//! pins this).  DB-LLM's accuracy story becomes a latency story: the
//! student burns the cheap 2-bit kernel, the teacher amortizes one
//! weight traversal over `k + 1` positions instead of one per token,
//! and every accepted draft is a dense forward the plain path would
//! have paid.
//!
//! # Lifecycle per speculative tick (one slot)
//!
//! With the teacher cache at `T` positions and `last` the token the
//! scheduler is about to feed:
//!
//! 1. **draft** — the student catches up on any teacher tokens it has
//!    not cached (`ctx[S..T]`, one batched
//!    [`IncrementalForward::prefill_suffix`] call that also feeds
//!    `last`), then drafts `d₁ … d_k` greedily with `k - 1` single
//!    [`IncrementalForward::step`]s;
//! 2. **verify** — the teacher feeds `[last, d₁, …, d_k]` as `k + 1`
//!    rows of one fused `step_rows` call (repeated cache index:
//!    causal visibility per row), producing logits `L₀ … L_k` that are
//!    each bit-identical to what sequential teacher steps would yield;
//! 3. **accept** — the accepted prefix length `a` is the longest run
//!    with `argmax(L_{i-1}) == d_i`; rows `L₀ … L_a` go back to the
//!    scheduler, which emits `d₁ … d_a` plus the bonus/correction
//!    token `argmax(L_a)` — always ≥ 1 token of progress;
//! 4. **rollback** — rejected draft positions are discarded by
//!    [`KvCache::truncate_to`]: block-table truncation on the paged
//!    pool (handles dropped, fill counts shrunk), **zero row copies**.
//!
//! # Window gate
//!
//! Speculation requires `T + k + 1 ≤ window`: a batched verify must
//! not slide the window mid-run (an eviction between two rows of the
//! same cache is sequential-only behaviour), and rollback must never
//! need evicted rows back.  Once a slot's chronology crosses the gate
//! it decodes plain for the rest of the request (counted in
//! [`SpecCounters::fallback_rows`]) — exactly the teacher-only path,
//! so the stream is unaffected.
//!
//! # Scope
//!
//! The decoder intentionally has **no prefix-cache integration**: a
//! shared-prefix splice would have to be mirrored into the student
//! cache to keep draft positions aligned, and the interaction with
//! rollback is not worth the coupling yet (`--prefix-cache-mb` is
//! rejected alongside `--speculate-k` at the CLI).  Only greedy rows
//! speculate — sampled rows cannot replay the teacher's RNG stream
//! through a draft/verify split — which the scheduler enforces by
//! routing rows by `DecodeParams` (`temperature ≤ 0` and
//! `speculate`).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::scheduler::{EngineTimers, SlotEngine, SpecCounters, SpecRows};
use crate::coordinator::serve::argmax;
use crate::model::Weights;
use crate::quant::FdbLinear;
use crate::runtime::session::recent_window;

use super::kv::{KvCache, KvPool, DEFAULT_BLOCK_TOKENS};
use super::step::IncrementalForward;

/// Sample one speculative/fused step in this many for the engine-side
/// phase timer (mirrors `NativeEngine`'s sampling: prefills are always
/// timed, steps 1-in-N).
const SPEC_PROFILE_EVERY: u64 = 64;

/// Speculative decode engine: dense teacher + FDB student over one
/// shared KV block pool, one teacher cache and one student cache per
/// slot.  Implements [`SlotEngine`], so it drops into the continuous
/// scheduler (EDF admission, deadlines, chaos supervision, worker
/// respawn) wherever `NativeEngine` does.
pub struct SpecDecoder {
    /// the dense (exact) model whose stream is the contract
    teacher: IncrementalForward,
    /// the cheap draft model (FDB-compiled linears)
    student: IncrementalForward,
    /// draft length per speculative tick (≥ 1)
    k: usize,
    /// sliding attention window (shared by both cache sets)
    window: usize,
    /// shared block allocator both cache sets draw from
    pool: Arc<KvPool>,
    /// operator byte budget (`None` = unbounded), kept for pool rebuilds
    pool_budget_bytes: Option<usize>,
    teacher_caches: Vec<KvCache>,
    student_caches: Vec<KvCache>,
    /// per-slot token history: `ctx[slot][p]` is the token whose K/V
    /// sits at teacher position `p`.  Tracked only while the slot can
    /// still speculate (length stays equal to the teacher chronology
    /// and below the window); the student catch-up feeds from it.
    ctx: Vec<Vec<u32>>,
    counters: SpecCounters,
    timers: EngineTimers,
    step_seq: u64,
    /// flattened verify rows, reused across ticks
    verify_buf: Vec<(usize, u32)>,
    /// student catch-up suffix, reused across ticks
    suffix_buf: Vec<u32>,
}

/// One slot's span inside the flattened verify batch.
struct SpecGroup {
    /// slot this group advances
    slot: usize,
    /// first row index in `verify_buf`
    start: usize,
    /// teacher chronology before the verify pass
    base_pos: usize,
    /// drafts in this group (0 = plain single-row fallback)
    drafted: usize,
}

impl SpecDecoder {
    /// Build from a dense teacher weight set and a student weight set
    /// whose linears named in `student_fdb` run on the compiled sparse
    /// kernel.  Both models must share geometry (they are the same
    /// architecture at different precisions — the DB-LLM setup).
    /// `window` is the sliding attention window and `k` the draft
    /// length per speculative tick.
    pub fn new(
        teacher: Weights,
        student: Weights,
        student_fdb: &BTreeMap<String, FdbLinear>,
        window: usize,
        k: usize,
    ) -> SpecDecoder {
        assert!(k >= 1, "draft length k must be >= 1 (use NativeEngine when not speculating)");
        let tc = &teacher.config;
        let sc = &student.config;
        assert_eq!(
            (tc.d_model, tc.n_layers, tc.n_heads, tc.d_ff, tc.vocab),
            (sc.d_model, sc.n_layers, sc.n_heads, sc.d_ff, sc.vocab),
            "teacher and student geometry must match"
        );
        let n_layers = tc.n_layers;
        let d = tc.d_model;
        let wide = d.max(tc.d_ff);
        let window = window.max(1);
        // both prefills and the batched verify run on this thread:
        // warm the per-thread scratch like `NativeEngine::new` does
        crate::quant::kernel::warm_thread_scratch(window, wide, wide);
        let teacher = IncrementalForward::new(teacher, &BTreeMap::new());
        let student = IncrementalForward::new(student, student_fdb);
        let pool = Arc::new(KvPool::new(DEFAULT_BLOCK_TOKENS, n_layers, d, KvPool::UNBOUNDED));
        let mut dec = SpecDecoder {
            teacher,
            student,
            k,
            window,
            pool,
            pool_budget_bytes: None,
            teacher_caches: Vec::new(),
            student_caches: Vec::new(),
            ctx: Vec::new(),
            counters: SpecCounters::default(),
            timers: EngineTimers::default(),
            step_seq: 0,
            verify_buf: Vec::new(),
            suffix_buf: Vec::new(),
        };
        dec.rebuild_slots(1);
        dec
    }

    /// Soft block budget for the shared pool: the operator's byte
    /// budget in blocks, floored so a single request can always hold a
    /// full teacher window *and* a full student window plus draft
    /// headroom — the budget bounds concurrency, never a lone request.
    fn budget_blocks(&self) -> usize {
        match self.pool_budget_bytes {
            None => KvPool::UNBOUNDED,
            Some(bytes) => {
                let bt = self.pool.block_tokens();
                let block_bytes = 2 * self.pool.n_layers() * bt * self.pool.width() * 4;
                let floor = 2 * (self.window.div_ceil(bt) + 2);
                (bytes / block_bytes.max(1)).max(floor)
            }
        }
    }

    /// Rebuild the pool and both cache sets for `slots` decode slots.
    /// Slot state is dropped; call before serving, not mid-request.
    fn rebuild_slots(&mut self, slots: usize) {
        let slots = slots.max(1);
        self.pool = Arc::new(KvPool::new(
            self.pool.block_tokens(),
            self.pool.n_layers(),
            self.pool.width(),
            self.budget_blocks(),
        ));
        self.teacher_caches =
            (0..slots).map(|_| KvCache::new_in_pool(&self.pool, self.window)).collect();
        self.student_caches =
            (0..slots).map(|_| KvCache::new_in_pool(&self.pool, self.window)).collect();
        self.ctx = (0..slots).map(|_| Vec::new()).collect();
        // the verify pass batches up to k + 1 rows per slot; the
        // student catch-up is a suffix prefill of up to `window` rows
        self.teacher.reserve_rows(slots * (self.k + 1), self.window);
        self.student.reserve_rows(self.window.max(slots), self.window);
        self.verify_buf = Vec::with_capacity(slots * (self.k + 1));
    }

    /// Resize to `slots` independent decode slots for the continuous
    /// scheduler.  Slot state is dropped; call before serving.
    pub fn with_slots(mut self, slots: usize) -> SpecDecoder {
        self.rebuild_slots(slots);
        self
    }

    /// Cap the shared KV pool at (roughly) `bytes` of block storage —
    /// the same *soft* admission budget as
    /// `NativeEngine::with_kv_pool_bytes`, except a speculative
    /// admission reserves teacher + student blocks.  Zero means
    /// unbounded.  Slot state is dropped; call before serving.
    pub fn with_kv_pool_bytes(mut self, bytes: usize) -> SpecDecoder {
        self.pool_budget_bytes = if bytes == 0 { None } else { Some(bytes) };
        let slots = self.teacher_caches.len();
        self.rebuild_slots(slots);
        self
    }

    /// The shared block pool (stats surface for benches and tests).
    pub fn kv_pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// Draft length per speculative tick.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Cumulative speculative-decode counters.
    pub fn counters(&self) -> SpecCounters {
        self.counters
    }

    /// Number of FDB-compiled linears in the *student* (diagnostics /
    /// startup log; the teacher is dense by construction).
    pub fn n_fdb_ops(&self) -> usize {
        self.student.n_fdb_ops()
    }

    /// Audit every slot's teacher/student block tables, their
    /// alignment (the student never runs ahead of the teacher beyond
    /// its drafts, and both stay unslid while speculation is on), and
    /// the shared pool's accounting.
    pub fn assert_invariants(&self) {
        assert_eq!(self.teacher_caches.len(), self.student_caches.len(), "cache sets disagree");
        assert_eq!(self.ctx.len(), self.teacher_caches.len(), "ctx table out of step");
        for (slot, (t, s)) in self.teacher_caches.iter().zip(&self.student_caches).enumerate() {
            t.assert_invariants();
            s.assert_invariants();
            let ctx = &self.ctx[slot];
            if ctx.len() == t.next_pos() {
                // the slot is still speculation-capable: the student
                // holds a prefix of the teacher's chronology (it may
                // lag by exactly one position after a fully-accepted
                // run) and neither cache has slid
                assert!(
                    s.next_pos() <= t.next_pos(),
                    "slot {slot}: student ran ahead of the teacher"
                );
                assert_eq!(t.next_pos(), t.len(), "slot {slot}: teacher slid while tracked");
                assert_eq!(s.next_pos(), s.len(), "slot {slot}: student slid while tracked");
            }
        }
        self.pool.assert_invariants();
    }

    /// True while `slot` can still take a speculative tick: `k + 1`
    /// verify positions must fit before the teacher window slides, and
    /// the token history must still mirror the teacher chronology.
    fn slot_can_speculate(&self, slot: usize) -> bool {
        let t = &self.teacher_caches[slot];
        t.next_pos() + self.k + 1 <= self.window && self.ctx[slot].len() == t.next_pos()
    }

    /// Record a token fed to the teacher at the position it now
    /// occupies.  Stops tracking (permanently, for this request) once
    /// the history falls out of step with the chronology or would
    /// cross the window — after that the slot decodes plain.
    fn note_token(&mut self, slot: usize, token: u32) {
        let ctx = &mut self.ctx[slot];
        if ctx.len() + 1 == self.teacher_caches[slot].next_pos() && ctx.len() < self.window {
            ctx.push(token);
        }
    }

    /// Shared validation for the plain and speculative batched steps:
    /// an `Err` here is the only failure path, so both calls are
    /// atomic (nothing advanced on `Err`).
    fn validate_steps(&self, steps: &[(usize, u32)]) -> Result<()> {
        let vocab = self.teacher.vocab();
        let mut seen = vec![false; self.teacher_caches.len()];
        for &(slot, token) in steps {
            anyhow::ensure!(slot < self.teacher_caches.len(), "slot {slot} out of range");
            anyhow::ensure!(!seen[slot], "slot {slot} listed twice in one fused step");
            seen[slot] = true;
            anyhow::ensure!(!self.teacher_caches[slot].is_empty(), "step on a slot without prefill");
            anyhow::ensure!((token as usize) < vocab, "token {token} out of vocab {vocab}");
        }
        Ok(())
    }

    /// The plain fused step body (teacher only), shared by
    /// `step_slots` and the ineligible-slot fallback.
    fn step_slots_inner(&mut self, steps: &[(usize, u32)]) -> Result<Vec<Vec<f32>>> {
        self.validate_steps(steps)?;
        let out = if steps.len() == 1 {
            let (slot, token) = steps[0];
            vec![self.teacher.step(&mut self.teacher_caches[slot], token)]
        } else {
            self.teacher.step_rows(&mut self.teacher_caches, steps)
        };
        for &(slot, token) in steps {
            self.note_token(slot, token);
        }
        Ok(out)
    }

    /// The speculative tick body; `step_slots_speculative` wraps it
    /// with the 1-in-N phase timer.
    fn speculative_inner(&mut self, steps: &[(usize, u32)]) -> Result<Vec<SpecRows>> {
        self.validate_steps(steps)?;
        // ---- draft phase: per-slot student loops, flattened into one
        // verify batch (plain rows for slots past the window gate ride
        // along in the same batched teacher forward)
        self.verify_buf.clear();
        let mut groups: Vec<SpecGroup> = Vec::with_capacity(steps.len());
        let mut any_drafted = false;
        for &(slot, last) in steps {
            let start = self.verify_buf.len();
            let base_pos = self.teacher_caches[slot].next_pos();
            if !self.slot_can_speculate(slot) {
                self.counters.fallback_rows += 1;
                self.verify_buf.push((slot, last));
                groups.push(SpecGroup { slot, start, base_pos, drafted: 0 });
                continue;
            }
            // student catch-up + first draft in one batched pass: feed
            // the teacher tokens the student has not cached, then
            // `last`; the returned logits row drafts d₁
            let s_pos = self.student_caches[slot].next_pos();
            debug_assert!(s_pos <= base_pos, "student ran ahead of the teacher");
            self.suffix_buf.clear();
            self.suffix_buf.extend_from_slice(&self.ctx[slot][s_pos..base_pos]);
            self.suffix_buf.push(last);
            let mut logits =
                self.student.prefill_suffix(&mut self.student_caches[slot], &self.suffix_buf);
            self.verify_buf.push((slot, last));
            for i in 0..self.k {
                let draft = argmax(&logits) as u32;
                self.verify_buf.push((slot, draft));
                if i + 1 < self.k {
                    logits = self.student.step(&mut self.student_caches[slot], draft);
                }
            }
            any_drafted = true;
            groups.push(SpecGroup { slot, start, base_pos, drafted: self.k });
        }

        // ---- verify phase: ONE batched teacher forward over every
        // slot's run (repeated cache indices; bit-identical rows)
        let flat = self.teacher.step_rows(&mut self.teacher_caches, &self.verify_buf);
        debug_assert_eq!(flat.len(), self.verify_buf.len(), "verify rows went missing");
        if any_drafted {
            self.counters.verify_passes += 1;
        }

        // ---- accept + rollback phase
        let mut flat = flat.into_iter();
        let mut out = Vec::with_capacity(steps.len());
        for g in &groups {
            if g.drafted == 0 {
                let row = flat.next().expect("one verify row per plain group");
                let (_, last) = self.verify_buf[g.start];
                self.note_token(g.slot, last);
                out.push(SpecRows { rows: vec![row], drafted: 0, accepted: 0 });
                continue;
            }
            let mut rows: Vec<Vec<f32>> = flat.by_ref().take(g.drafted + 1).collect();
            debug_assert_eq!(rows.len(), g.drafted + 1, "verify rows went missing");
            // accept-longest-prefix: draft dᵢ₊₁ survives while it
            // matches the teacher's greedy pick from row i
            let mut accepted = 0usize;
            while accepted < g.drafted {
                let draft = self.verify_buf[g.start + 1 + accepted].1;
                if argmax(&rows[accepted]) as u32 == draft {
                    accepted += 1;
                } else {
                    break;
                }
            }
            // rollback: the teacher keeps [last, d₁..d_a]; the student
            // (at base + k after drafting) keeps the same prefix — or
            // lags one position when every draft was accepted
            let keep = g.base_pos + accepted + 1;
            let mut rolled = self.teacher_caches[g.slot].truncate_to(keep);
            if accepted < g.drafted {
                rolled += self.student_caches[g.slot].truncate_to(keep);
            }
            // the emitted tokens extend the tracked history: last, then
            // the accepted drafts (the bonus token is fed next tick)
            let (_, last) = self.verify_buf[g.start];
            self.ctx[g.slot].push(last);
            for i in 0..accepted {
                let draft = self.verify_buf[g.start + 1 + i].1;
                self.ctx[g.slot].push(draft);
            }
            debug_assert_eq!(self.ctx[g.slot].len(), self.teacher_caches[g.slot].next_pos());
            self.counters.drafted += g.drafted as u64;
            self.counters.accepted += accepted as u64;
            self.counters.rejected += (g.drafted - accepted) as u64;
            self.counters.bonus += 1;
            self.counters.rolled_back_rows += rolled as u64;
            rows.truncate(accepted + 1);
            out.push(SpecRows { rows, drafted: g.drafted as u32, accepted: accepted as u32 });
        }
        debug_assert!(flat.next().is_none(), "verify rows left over");
        Ok(out)
    }
}

impl SlotEngine for SpecDecoder {
    fn slots(&self) -> usize {
        self.teacher_caches.len()
    }

    /// Prefill both the teacher and the student cache with the prompt
    /// (window-truncated the same way), seed the slot's token history,
    /// and return the teacher's first-token logits — the stream
    /// contract is the teacher's alone.
    fn prefill_slot(&mut self, slot: usize, prompt: &[u32]) -> Result<Vec<f32>> {
        anyhow::ensure!(slot < self.teacher_caches.len(), "slot {slot} out of range");
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let vocab = self.teacher.vocab();
        for &t in prompt {
            anyhow::ensure!((t as usize) < vocab, "prompt token {t} out of vocab {vocab}");
        }
        let t0 = std::time::Instant::now();
        self.teacher_caches[slot].clear();
        self.student_caches[slot].clear();
        let toks = recent_window(prompt, self.window);
        self.ctx[slot].clear();
        self.ctx[slot].extend_from_slice(toks);
        let logits = self.teacher.prefill(&mut self.teacher_caches[slot], prompt);
        self.student.prefill(&mut self.student_caches[slot], prompt);
        self.timers.prefill_calls += 1;
        self.timers.prefill_ns += t0.elapsed().as_nanos() as u64;
        Ok(logits)
    }

    fn step_slot(&mut self, slot: usize, token: u32) -> Result<Vec<f32>> {
        anyhow::ensure!(slot < self.teacher_caches.len(), "slot {slot} out of range");
        anyhow::ensure!(!self.teacher_caches[slot].is_empty(), "step on a slot without prefill");
        let vocab = self.teacher.vocab();
        anyhow::ensure!((token as usize) < vocab, "token {token} out of vocab {vocab}");
        let logits = self.teacher.step(&mut self.teacher_caches[slot], token);
        self.note_token(slot, token);
        Ok(logits)
    }

    /// Plain fused step for rows the scheduler keeps off the
    /// speculative path (sampled rows, opted-out rows): teacher-only,
    /// identical math to `NativeEngine`.
    fn step_slots(&mut self, steps: &[(usize, u32)]) -> Result<Vec<Vec<f32>>> {
        self.step_slots_inner(steps)
    }

    /// Both batched paths validate the whole batch up front and the
    /// math after validation is infallible, so a failed call never
    /// advances state — the scheduler may retry row by row.
    fn step_slots_atomic(&self) -> bool {
        true
    }

    fn reset_slot(&mut self, slot: usize) {
        if let Some(cache) = self.teacher_caches.get_mut(slot) {
            cache.clear();
        }
        if let Some(cache) = self.student_caches.get_mut(slot) {
            cache.clear();
        }
        if let Some(ctx) = self.ctx.get_mut(slot) {
            ctx.clear();
        }
    }

    /// Post-panic reclamation: `reset_slot` is total on any reachable
    /// slot state (a half-drafted student cache and an overextended
    /// teacher cache both clear block-by-block), so quarantine is a
    /// plain reset — same argument as `NativeEngine`.
    fn quarantine_slot(&mut self, slot: usize) {
        self.reset_slot(slot);
    }

    /// Engine-wide repair after a panic: reset every slot and audit
    /// the shared pool (a violated pool invariant panics, which the
    /// supervisor treats as an unrecoverable engine).
    fn recover(&mut self) -> Result<()> {
        for slot in 0..self.teacher_caches.len() {
            self.reset_slot(slot);
        }
        self.pool.assert_invariants();
        Ok(())
    }

    /// Admission gate on the shared pool: a speculative admission
    /// prefills the prompt into *both* cache sets, so it reserves
    /// twice the prompt's blocks plus a block of decode/draft headroom
    /// each.  Unbounded pools always admit.
    fn can_admit(&self, prompt_tokens: usize) -> bool {
        let need = 2 * (self.pool.blocks_for(prompt_tokens.min(self.window)) + 1);
        self.pool.free_blocks() >= need
    }

    fn phase_timers(&self) -> Option<EngineTimers> {
        Some(self.timers)
    }

    fn speculate_k(&self) -> usize {
        self.k
    }

    /// The speculative tick: draft on the student, verify in one
    /// batched teacher forward, accept the longest matching prefix,
    /// roll rejected positions back.  1-in-N calls are wall-timed into
    /// [`EngineTimers`]; the timer reads sit outside the decode math,
    /// so sampled and unsampled ticks produce bit-identical logits.
    fn step_slots_speculative(&mut self, steps: &[(usize, u32)]) -> Result<Vec<SpecRows>> {
        let sampled = self.step_seq % SPEC_PROFILE_EVERY == 0;
        self.step_seq += 1;
        let t0 = if sampled { Some(std::time::Instant::now()) } else { None };
        let out = self.speculative_inner(steps);
        if let (Some(t0), Ok(_)) = (t0, &out) {
            self.timers.step_sampled += 1;
            self.timers.step_ns += t0.elapsed().as_nanos() as u64;
        }
        out
    }

    fn spec_counters(&self) -> Option<SpecCounters> {
        Some(self.counters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            vocab: 96,
            seq_len: 32,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    /// Dense teacher + FDB student from the same synthetic seed — the
    /// student is a *quantized* (lossy) view of the teacher, so drafts
    /// genuinely disagree sometimes.
    fn build(seed: u64, k: usize, slots: usize) -> SpecDecoder {
        let cfg = tiny();
        let teacher = Weights::synthetic(&cfg, seed);
        let student = Weights::synthetic(&cfg, seed);
        let mut fdb = BTreeMap::new();
        for name in cfg.linear_names() {
            fdb.insert(name.clone(), FdbLinear::from_weights(student.mat(&name), 64));
        }
        SpecDecoder::new(teacher, student, &fdb, cfg.seq_len, k).with_slots(slots)
    }

    fn teacher_only(seed: u64) -> crate::infer::NativeEngine {
        let cfg = tiny();
        crate::infer::NativeEngine::new(
            Weights::synthetic(&cfg, seed),
            &BTreeMap::new(),
            cfg.seq_len,
            42,
        )
        .with_slots(1)
    }

    /// The module-level smoke check (the full battery — staggered
    /// prefills, refills, block-boundary rollback, scheduler
    /// integration — lives in `tests/spec_decode.rs`): a greedy
    /// speculative stream equals the teacher-only stream token for
    /// token, and the counters satisfy the work model.
    #[test]
    fn speculative_stream_matches_teacher_only() {
        for seed in [3u64, 7, 11] {
            let mut reference = teacher_only(seed);
            let prompt = vec![5u32, 9, 2, 14];
            let budget = 12usize;
            let mut expect = Vec::new();
            let mut logits = reference.prefill_slot(0, &prompt).unwrap();
            for _ in 0..budget {
                let tok = argmax(&logits) as u32;
                expect.push(tok);
                logits = reference.step_slot(0, tok).unwrap();
            }

            let mut spec = build(seed, 3, 1);
            let mut got = Vec::new();
            let logits = spec.prefill_slot(0, &prompt).unwrap();
            let mut last = argmax(&logits) as u32;
            got.push(last);
            while got.len() < budget {
                let groups = spec.step_slots_speculative(&[(0, last)]).unwrap();
                assert_eq!(groups.len(), 1);
                let g = &groups[0];
                assert!(g.accepted <= g.drafted, "accepted beyond k");
                assert_eq!(g.rows.len() as u32, g.accepted + 1);
                for row in &g.rows {
                    if got.len() >= budget {
                        break;
                    }
                    last = argmax(row) as u32;
                    got.push(last);
                }
            }
            assert_eq!(got, expect, "seed {seed}: speculative stream diverged");
            let c = spec.counters();
            assert_eq!(c.drafted, c.accepted + c.rejected, "seed {seed}: tally broken");
            assert!(c.bonus > 0, "every verified group emits its bonus row");
            spec.assert_invariants();
        }
    }

    #[test]
    fn rollback_leaks_no_blocks_and_copies_no_rows() {
        let mut spec = build(5, 4, 2);
        for slot in 0..2 {
            let logits = spec.prefill_slot(slot, &[1, 2, 3]).unwrap();
            let mut last = argmax(&logits) as u32;
            for _ in 0..4 {
                let groups = spec.step_slots_speculative(&[(slot, last)]).unwrap();
                last = argmax(groups[0].rows.last().unwrap()) as u32;
            }
        }
        let c = spec.counters();
        assert!(c.drafted > 0, "speculation never engaged");
        assert_eq!(spec.kv_pool().stats().copied_rows, 0, "rollback must not copy rows");
        spec.assert_invariants();
        spec.reset_slot(0);
        spec.reset_slot(1);
        assert_eq!(spec.kv_pool().stats().live_blocks, 0, "reset leaked pool blocks");
    }

    #[test]
    fn window_gate_falls_back_to_plain_rows() {
        // window 8, k 3: a 5-token prompt leaves no room for 4 verify
        // positions, so the first speculative call must fall back
        let cfg = tiny();
        let teacher = Weights::synthetic(&cfg, 9);
        let student = Weights::synthetic(&cfg, 9);
        let fdb = BTreeMap::new();
        let mut spec = SpecDecoder::new(teacher, student, &fdb, 8, 3).with_slots(1);
        let logits = spec.prefill_slot(0, &[1, 2, 3, 4, 5]).unwrap();
        let last = argmax(&logits) as u32;
        let groups = spec.step_slots_speculative(&[(0, last)]).unwrap();
        assert_eq!(groups[0].drafted, 0, "gated slot must not draft");
        assert_eq!(groups[0].rows.len(), 1);
        let c = spec.counters();
        assert_eq!(c.fallback_rows, 1);
        assert_eq!(c.drafted, 0);
        // and the plain row equals the teacher-only step at the same window
        let cfg2 = tiny();
        let mut reference = crate::infer::NativeEngine::new(
            Weights::synthetic(&cfg2, 9),
            &BTreeMap::new(),
            8,
            42,
        )
        .with_slots(1);
        let r = reference.prefill_slot(0, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(argmax(&r) as u32, last);
        let expect = reference.step_slot(0, last).unwrap();
        assert_eq!(groups[0].rows[0], expect, "gated row diverged from teacher");
    }

    #[test]
    fn validates_before_any_state_change() {
        let mut spec = build(13, 2, 2);
        spec.prefill_slot(0, &[1, 2]).unwrap();
        assert!(spec.step_slots_speculative(&[(0, 3), (1, 4)]).is_err(), "slot 1 not prefilled");
        assert!(spec.step_slots_speculative(&[(0, 3), (0, 4)]).is_err(), "duplicate slot");
        assert!(spec.step_slots_speculative(&[(0, 9999)]).is_err(), "token out of vocab");
        assert!(spec.step_slots_speculative(&[(2, 1)]).is_err(), "slot out of range");
        // slot 0 must continue exactly where an undisturbed engine does
        let mut clean = build(13, 2, 2);
        clean.prefill_slot(0, &[1, 2]).unwrap();
        let a = spec.step_slots_speculative(&[(0, 3)]).unwrap();
        let b = clean.step_slots_speculative(&[(0, 3)]).unwrap();
        assert_eq!(a, b, "failed speculative call advanced slot state");
    }
}
