//! Cross-request prefix sharing for the native KV cache.
//!
//! Production traffic is dominated by requests sharing long common
//! prefixes (system prompts, few-shot templates).  The FDB kernels keep
//! *decode* cheap, so after PR 2–4 the repeated cost is **prefill**:
//! every admission re-ran the full prompt even when an identical prefix
//! was prefilled moments ago.  This module stores prefilled K/V rows in
//! a shared, block-granular [`PrefixCache`] (vLLM-style) so a new
//! request only runs the model over its *uncached suffix*.
//!
//! Design:
//!
//! - **Blocks.** The token stream is cut into fixed-size blocks
//!   (`block_tokens`, default [`DEFAULT_BLOCK_TOKENS`]).  A block is
//!   keyed by the *hash chain* of everything up to and including it
//!   (`h₀ = H(block₀)`, `hᵢ = H(hᵢ₋₁, blockᵢ)`), so one key identifies
//!   the whole prefix, not just the block's own tokens.  Entries also
//!   store their tokens and verify them on lookup — a hash collision
//!   degrades to a miss, never to wrong K/V.
//! - **Zero-copy blocks.** Entries hold [`super::KvPoolBlock`] handles
//!   — the same `Arc`s a slot's block table holds.  Publishing shares
//!   the slot's handle ([`KvCache::share_block`]), and a warm request
//!   splices the handle straight into its own table
//!   ([`KvCache::append_shared`]): no K/V row is ever copied in either
//!   direction.
//! - **Ref-counting.** A decode slot that splices cached blocks pins
//!   them ([`PrefixCache::acquire`] increments `refs`, the engine
//!   releases on slot reset).  Pinned blocks are never evicted, and
//!   the `Arc` keeps the bytes alive even across an eviction that
//!   races a release.
//! - **LRU eviction under a byte budget.** Publishing past
//!   `budget_bytes` evicts least-recently-used *unpinned leaf* blocks
//!   (no cached extension, no active reader).  Evicting leaves first
//!   keeps every stored chain walkable from block 0; if nothing is
//!   evictable the publish is skipped — the cache never overshoots its
//!   budget and never blocks decode.
//! - **Bit-identical reuse.** Cached K/V rows are the bytes a cold
//!   prefill wrote; the suffix pass
//!   ([`super::step::IncrementalForward::prefill_suffix`]) is built on
//!   the same per-row primitives as full prefill, so a warm prefill's
//!   logits — and therefore its greedy token stream — are bit-identical
//!   to a cold one (`tests/prefix_cache.rs` pins this).
//!
//! The cache is engine-agnostic state: `infer::NativeEngine` shares one
//! `Arc<Mutex<PrefixCache>>` across every scheduler worker, so a prefix
//! prefilled by one worker warms all of them.
//!
//! # Examples
//!
//! Publish a prefilled prompt, then warm a second cache from it:
//!
//! ```
//! use db_llm::infer::{KvCache, PrefixCache};
//!
//! let mut cache = PrefixCache::new(2, 1 << 20); // 2-token blocks, 1 MiB
//! let prompt = [10u32, 11, 12, 13, 14];
//!
//! // a cold request prefilled `prompt` into its slot's KvCache
//! // (built with a matching block size) …
//! let mut slot = KvCache::with_block_tokens(1, 8, 4, 2);
//! for _ in 0..prompt.len() {
//!     let s = slot.advance();
//!     slot.write(0, s, &[1.0; 4], &[2.0; 4]);
//! }
//! // … and publishes the full blocks (2 of them — 4 of 5 tokens)
//! cache.publish(&prompt, &slot);
//! assert_eq!(cache.entries(), 2);
//!
//! // a second request with the same prompt matches both blocks …
//! let (pins, matched) = cache.acquire(&prompt);
//! assert_eq!(matched, 4);
//! // … and splices the shared handles straight into its own table —
//! // zero K/V rows copied (the returned `Arc` lets real engines do
//! // this outside the cache lock) …
//! let mut warm = KvCache::with_block_tokens(1, 8, 4, 2);
//! for pin in &pins {
//!     warm.append_shared(&cache.block(*pin).unwrap());
//! }
//! assert_eq!(warm.len(), 4);
//! // … and unpins them once its slot is reset
//! cache.release(&pins);
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use super::kv::{KvCache, KvPoolBlock};

pub use super::kv::DEFAULT_BLOCK_TOKENS;

/// Cache-wide introspection counters (monotonic except the gauges).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PrefixCacheStats {
    /// blocks currently stored (gauge)
    pub entries: u64,
    /// bytes currently stored (gauge)
    pub bytes: u64,
    /// blocks inserted by `publish`
    pub insertions: u64,
    /// blocks evicted under budget pressure
    pub evictions: u64,
    /// publishes skipped because nothing was evictable under budget
    pub rejected_inserts: u64,
}

struct Entry {
    /// this block's own tokens (verified on lookup: a chain-hash
    /// collision degrades to a miss, never to wrong K/V)
    tokens: Vec<u32>,
    /// chain hash of the parent block (`None` for block 0)
    parent: Option<u64>,
    /// the pool block holding this prefix chunk's K/V rows — the very
    /// handle the publishing slot's block table held, so readers splice
    /// it into their own table with zero row copies (pins keep the
    /// entry alive, and the `Arc` keeps the bytes alive even across an
    /// eviction)
    block: Arc<KvPoolBlock>,
    /// active readers (slots mid-copy or mid-decode); pinned blocks
    /// are never evicted
    refs: usize,
    /// cached blocks extending this prefix; only leaves are evictable
    children: usize,
    /// LRU clock value at last touch
    last_used: u64,
}

/// Shared store of prefilled K/V blocks keyed by token-prefix hash
/// chains, with ref-counting and LRU eviction under a byte budget.
///
/// See the [module docs](self) for the design and an end-to-end
/// example; `infer::NativeEngine::with_prefix_cache` wires it under
/// the serving stack.
pub struct PrefixCache {
    block_tokens: usize,
    budget_bytes: usize,
    used_bytes: usize,
    entries: HashMap<u64, Entry>,
    clock: u64,
    stats: PrefixCacheStats,
}

/// FNV-1a over the parent chain hash and a block's tokens.
fn chain_hash(parent: Option<u64>, tokens: &[u32]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |bytes: [u8; 8]| {
        for b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    eat(parent.unwrap_or(u64::MAX).to_le_bytes());
    for &t in tokens {
        eat((t as u64).to_le_bytes());
    }
    h
}

impl PrefixCache {
    /// Build a cache of `block_tokens`-sized blocks holding at most
    /// `budget_bytes` of K/V rows.  A zero budget is valid: every
    /// publish is refused, every lookup misses — the disabled form the
    /// CLI maps `--prefix-cache-mb 0` to.
    pub fn new(block_tokens: usize, budget_bytes: usize) -> PrefixCache {
        assert!(block_tokens > 0, "block_tokens must be positive");
        PrefixCache {
            block_tokens,
            budget_bytes,
            used_bytes: 0,
            entries: HashMap::new(),
            clock: 0,
            stats: PrefixCacheStats::default(),
        }
    }

    /// Tokens per block (lookup / publish granularity).
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Blocks currently stored.
    pub fn entries(&self) -> usize {
        self.entries.len()
    }

    /// Bytes of K/V rows currently stored (always ≤ the budget).
    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    /// Introspection counters (entries/bytes gauges refreshed here).
    pub fn stats(&self) -> PrefixCacheStats {
        let mut s = self.stats;
        s.entries = self.entries.len() as u64;
        s.bytes = self.used_bytes as u64;
        s
    }

    /// Walk the longest cached chain matching `prompt`, pin every
    /// matched block (`refs += 1`), and return the pinned chain hashes
    /// plus the matched token count.  The caller copies each block via
    /// [`block`](Self::block) and must pair this with exactly one
    /// [`release`](Self::release) once the slot is done with them.
    ///
    /// Never matches the *entire* prompt: at least one suffix token is
    /// always left for the model to run, because the last position's
    /// forward is what produces the first decoded token's logits.
    pub fn acquire(&mut self, prompt: &[u32]) -> (Vec<u64>, usize) {
        self.clock += 1;
        let b = self.block_tokens;
        let mut pins = Vec::new();
        let mut parent = None;
        let mut matched = 0usize;
        // `end < prompt.len()` (strict): a full-prompt match holds its
        // last block back so the suffix is never empty
        while matched + b < prompt.len() {
            let tokens = &prompt[matched..matched + b];
            let h = chain_hash(parent, tokens);
            match self.entries.get_mut(&h) {
                // the entry must match the block's own tokens AND its
                // parent chain — by induction the whole prefix is then
                // token-verified, so a 64-bit chain-hash collision can
                // only ever degrade to a miss, never to wrong K/V
                Some(e) if e.tokens == tokens && e.parent == parent => {
                    e.refs += 1;
                    e.last_used = self.clock;
                    pins.push(h);
                    parent = Some(h);
                    matched += b;
                }
                // absent, or a hash collision: stop at the last good block
                _ => break,
            }
        }
        #[cfg(debug_assertions)]
        self.assert_invariants();
        (pins, matched)
    }

    /// The pool block behind a pinned chain hash.  Returns a clone of
    /// the entry's `Arc` so the caller can drop the cache lock before
    /// splicing the handle into a slot's `KvCache`
    /// ([`KvCache::append_shared`]) — no bulk copy happens under (or
    /// after) the lock.
    pub fn block(&self, hash: u64) -> Option<Arc<KvPoolBlock>> {
        self.entries.get(&hash).map(|e| Arc::clone(&e.block))
    }

    /// Unpin blocks previously pinned by [`acquire`](Self::acquire).
    pub fn release(&mut self, pins: &[u64]) {
        for h in pins {
            if let Some(e) = self.entries.get_mut(h) {
                e.refs = e.refs.saturating_sub(1);
            }
        }
        #[cfg(debug_assertions)]
        self.assert_invariants();
    }

    /// Publish the full blocks of a freshly prefilled (or decoded)
    /// `prompt` whose K/V rows sit in `cache` (chronological row `i` =
    /// prompt position `i`).  Zero-copy: the cache's own block handles
    /// are retained ([`KvCache::share_block`]), no rows move.  Existing
    /// blocks are refreshed (LRU) and deduplicated — two requests
    /// racing the same cold prefix store its handle once.  Returns the
    /// number of evictions the inserts forced.
    pub fn publish(&mut self, prompt: &[u32], cache: &KvCache) -> u64 {
        self.clock += 1;
        let b = self.block_tokens;
        assert_eq!(
            cache.block_tokens(),
            b,
            "publishing cache's block size must match the prefix cache"
        );
        let mut parent = None;
        let mut start = 0usize;
        let mut evicted = 0u64;
        // the chain is pinned as it is walked so budget-pressure
        // eviction for a later block can never take an earlier block of
        // this very chain (released before returning)
        let mut walked: Vec<u64> = Vec::new();
        while start + b <= prompt.len() && start + b <= cache.len() {
            let tokens = &prompt[start..start + b];
            let h = chain_hash(parent, tokens);
            match self.entries.get_mut(&h) {
                // same tokens+parent verification as `acquire`: only a
                // true duplicate refreshes, a collision stops the walk
                Some(e) if e.tokens == tokens && e.parent == parent => {
                    e.last_used = self.clock;
                    e.refs += 1;
                }
                Some(_) => {
                    // collision on the chain key: storing would corrupt
                    // the chain, so stop publishing this prompt here
                    break;
                }
                None => {
                    // share the slot's own handle; `None` (slid head or
                    // partial block) can't happen for the engine's
                    // unslid publishes but ends the walk defensively
                    let Some(block) = cache.share_block(start / b) else { break };
                    let need = block.bytes();
                    evicted += self.evict_for(need);
                    if self.used_bytes + need > self.budget_bytes {
                        // nothing (more) evictable: skip the rest of the
                        // chain — a child without its parent would be
                        // unreachable anyway
                        self.stats.rejected_inserts += 1;
                        break;
                    }
                    self.used_bytes += need;
                    self.stats.insertions += 1;
                    if let Some(p) = parent {
                        if let Some(pe) = self.entries.get_mut(&p) {
                            pe.children += 1;
                        }
                    }
                    self.entries.insert(
                        h,
                        Entry {
                            tokens: tokens.to_vec(),
                            parent,
                            block,
                            refs: 1,
                            children: 0,
                            last_used: self.clock,
                        },
                    );
                }
            }
            walked.push(h);
            parent = Some(h);
            start += b;
        }
        self.release(&walked);
        #[cfg(debug_assertions)]
        self.assert_invariants();
        evicted
    }

    /// Evict least-recently-used unpinned leaves until `need` more
    /// bytes fit the budget (or nothing evictable remains).  Returns
    /// the number of blocks evicted.
    fn evict_for(&mut self, need: usize) -> u64 {
        let mut evicted = 0u64;
        while self.used_bytes + need > self.budget_bytes {
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.refs == 0 && e.children == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&h, _)| h);
            let Some(h) = victim else { break };
            let e = self.entries.remove(&h).expect("victim vanished");
            self.used_bytes -= e.block.bytes();
            if let Some(p) = e.parent {
                if let Some(pe) = self.entries.get_mut(&p) {
                    pe.children = pe.children.saturating_sub(1);
                }
            }
            self.stats.evictions += 1;
            evicted += 1;
        }
        evicted
    }

    /// Audit the cache's structural invariants.  Debug builds run this
    /// after every mutating call; the test suites call it directly so
    /// release-mode CI checks them too.  Panics on the first violation:
    ///
    /// * **byte accounting** — `used_bytes` equals the sum of resident
    ///   block bytes and never exceeds the budget,
    /// * **chain integrity** — every entry's key is the chain hash of
    ///   its `(parent, tokens)`, its parent is resident, and it holds a
    ///   full block (leaf-first eviction keeps chains walkable),
    /// * **child counts** — every entry's `children` equals the number
    ///   of resident entries naming it as parent (the leaf test
    ///   `children == 0` depends on this),
    /// * **clock monotonicity** — no entry was touched "in the future".
    ///
    /// External pins cannot be audited from inside the cache; the
    /// pinned-never-evicted rule is enforced structurally by
    /// `evict_for`'s `refs == 0` victim filter.
    pub fn assert_invariants(&self) {
        let mut bytes = 0usize;
        let mut child_counts: HashMap<u64, usize> = HashMap::new();
        for (&h, e) in &self.entries {
            bytes += e.block.bytes();
            assert_eq!(
                chain_hash(e.parent, &e.tokens),
                h,
                "prefix-cache entry keyed by a hash that is not its own chain hash"
            );
            assert_eq!(
                e.tokens.len(),
                self.block_tokens,
                "prefix-cache entry holds a partial block"
            );
            assert!(
                e.last_used <= self.clock,
                "prefix-cache entry touched in the future (last_used {} > clock {})",
                e.last_used,
                self.clock
            );
            if let Some(p) = e.parent {
                assert!(
                    self.entries.contains_key(&p),
                    "prefix-cache chain broken: parent {p:#x} of {h:#x} is not resident"
                );
                *child_counts.entry(p).or_insert(0) += 1;
            }
        }
        assert_eq!(
            bytes, self.used_bytes,
            "prefix-cache byte accounting drifted from the resident blocks"
        );
        assert!(
            self.used_bytes <= self.budget_bytes,
            "prefix-cache overshot its byte budget ({} > {})",
            self.used_bytes,
            self.budget_bytes
        );
        for (&h, e) in &self.entries {
            assert_eq!(
                e.children,
                child_counts.get(&h).copied().unwrap_or(0),
                "prefix-cache child count drifted for entry {h:#x}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A KvCache standing in for a prefilled slot: position `i`'s K
    /// row starts with `seed + i`, so block contents are position- and
    /// request-distinguishable.
    fn filled(n: usize, seed: f32) -> KvCache {
        // block size 2 to match the 2-token PrefixCaches below
        let mut c = KvCache::with_block_tokens(1, 32, 2, 2);
        for i in 0..n {
            let s = c.advance();
            let row = [seed + i as f32, 1.0];
            c.write(0, s, &row, &row);
        }
        c
    }

    #[test]
    fn acquire_walks_longest_chain_and_pins() {
        let mut pc = PrefixCache::new(2, 1 << 20);
        let prompt = [1u32, 2, 3, 4, 5, 6];
        pc.publish(&prompt, &filled(6, 0.0));
        assert_eq!(pc.entries(), 3);

        // identical prompt: all blocks short of the suffix rule match
        let (pins, matched) = pc.acquire(&[1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(matched, 6);
        assert_eq!(pins.len(), 3);
        // diverging third block: chain stops after two
        let (pins2, matched2) = pc.acquire(&[1, 2, 3, 4, 9, 9, 9]);
        assert_eq!(matched2, 4);
        assert_eq!(pins2.len(), 2);
        // diverging first token: nothing matches
        let (pins3, matched3) = pc.acquire(&[9, 2, 3, 4]);
        assert!(pins3.is_empty());
        assert_eq!(matched3, 0);
        pc.release(&pins);
        pc.release(&pins2);
    }

    #[test]
    fn never_matches_the_entire_prompt() {
        let mut pc = PrefixCache::new(2, 1 << 20);
        let prompt = [1u32, 2, 3, 4];
        pc.publish(&prompt, &filled(4, 0.0));
        // prompt == cached prefix: the last block is held back so the
        // suffix pass still has ≥ 1 token to run
        let (pins, matched) = pc.acquire(&prompt);
        assert_eq!(matched, 2, "full-prompt match must leave a suffix");
        assert_eq!(pins.len(), 1);
        pc.release(&pins);
    }

    #[test]
    fn publish_deduplicates_racing_prefixes() {
        let mut pc = PrefixCache::new(2, 1 << 20);
        let prompt = [1u32, 2, 3, 4];
        pc.publish(&prompt, &filled(4, 0.0));
        let bytes = pc.used_bytes();
        // the losing racer publishes the same prefix: no growth
        pc.publish(&prompt, &filled(4, 0.0));
        assert_eq!(pc.used_bytes(), bytes, "racing publish must not double-store");
        assert_eq!(pc.entries(), 2);
        assert_eq!(pc.stats().insertions, 2);
    }

    #[test]
    fn lru_evicts_unpinned_leaves_first() {
        // budget fits exactly two 2-token blocks of width 2 (1 layer)
        let block_bytes = filled(2, 0.0).export_block(0, 2).bytes();
        let mut pc = PrefixCache::new(2, 2 * block_bytes);
        pc.publish(&[1, 2], &filled(2, 0.0));
        pc.publish(&[3, 4], &filled(2, 10.0));
        assert_eq!(pc.entries(), 2);
        // pin [1,2] (an active slot is reading it), then publish a third
        // prefix: the unpinned [3,4] must be the victim
        let (pins, matched) = pc.acquire(&[1, 2, 99]);
        assert_eq!(matched, 2);
        pc.publish(&[5, 6], &filled(2, 20.0));
        assert_eq!(pc.entries(), 2);
        assert!(pc.used_bytes() <= 2 * block_bytes);
        let (gone, m) = pc.acquire(&[3, 4, 99]);
        assert_eq!(m, 0, "unpinned LRU block should have been evicted");
        assert!(gone.is_empty());
        let (kept, m) = pc.acquire(&[5, 6, 99]);
        assert_eq!(m, 2, "newly published block must be resident");
        assert_eq!(pc.stats().evictions, 1);
        pc.release(&pins);
        pc.release(&kept);
    }

    #[test]
    fn chains_evict_leaf_first_and_stay_walkable() {
        let block_bytes = filled(2, 0.0).export_block(0, 2).bytes();
        // room for three blocks: one 3-block chain overflows by zero,
        // then pressure evicts its *leaf*, never an interior block
        let mut pc = PrefixCache::new(2, 3 * block_bytes);
        pc.publish(&[1, 2, 3, 4, 5, 6], &filled(6, 0.0));
        assert_eq!(pc.entries(), 3);
        pc.publish(&[7, 8], &filled(2, 50.0));
        // the chain's leaf (tokens [5,6]) was the only evictable entry
        let (pins, matched) = pc.acquire(&[1, 2, 3, 4, 5, 6, 9]);
        assert_eq!(matched, 4, "interior blocks must survive, leaf evicted");
        let (pins2, m2) = pc.acquire(&[7, 8, 9]);
        assert_eq!(m2, 2);
        pc.release(&pins);
        pc.release(&pins2);
    }

    #[test]
    fn zero_budget_disables_storage() {
        let mut pc = PrefixCache::new(2, 0);
        pc.publish(&[1, 2, 3, 4], &filled(4, 0.0));
        assert_eq!(pc.entries(), 0);
        assert_eq!(pc.used_bytes(), 0);
        let (pins, matched) = pc.acquire(&[1, 2, 3, 4]);
        assert!(pins.is_empty());
        assert_eq!(matched, 0);
        assert!(pc.stats().rejected_inserts >= 1);
    }

    #[test]
    fn pinned_blocks_survive_total_pressure() {
        let block_bytes = filled(2, 0.0).export_block(0, 2).bytes();
        let mut pc = PrefixCache::new(2, block_bytes);
        pc.publish(&[1, 2], &filled(2, 0.0));
        let (pins, _) = pc.acquire(&[1, 2, 3]);
        // budget full and the only entry is pinned: publish must be
        // refused, not evict the in-use block
        pc.publish(&[3, 4], &filled(2, 9.0));
        let (still, m) = pc.acquire(&[1, 2, 3]);
        assert_eq!(m, 2, "pinned block evicted under pressure");
        assert_eq!(pc.stats().rejected_inserts, 1);
        pc.release(&pins);
        pc.release(&still);
        // unpinned now: the next publish may evict it
        pc.publish(&[3, 4], &filled(2, 9.0));
        let (_, m) = pc.acquire(&[3, 4, 5]);
        assert_eq!(m, 2);
    }

    #[test]
    fn release_is_idempotent_per_pin() {
        let mut pc = PrefixCache::new(2, 1 << 20);
        pc.publish(&[1, 2], &filled(2, 0.0));
        let (pins, _) = pc.acquire(&[1, 2, 3]);
        pc.release(&pins);
        pc.release(&pins); // saturates at zero, no underflow panic
        let (pins2, m) = pc.acquire(&[1, 2, 3]);
        assert_eq!(m, 2);
        pc.release(&pins2);
    }
}
