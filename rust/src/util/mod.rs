//! Small self-contained substrates that replace unavailable third-party
//! crates (this build is fully offline): a PCG RNG (`rand`), a JSON
//! parser/writer (`serde_json`), a micro-benchmark harness (`criterion`)
//! and a property-testing helper (`proptest`).

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;

pub use json::Json;
pub use rng::Pcg32;

/// Format a float with engineering suffixes (for table/metric printing).
pub fn eng(v: f64) -> String {
    let a = v.abs();
    if a >= 1e12 {
        format!("{:.1}T", v / 1e12)
    } else if a >= 1e9 {
        format!("{:.1}G", v / 1e9)
    } else if a >= 1e6 {
        format!("{:.1}M", v / 1e6)
    } else if a >= 1e3 {
        format!("{:.1}K", v / 1e3)
    } else {
        format!("{v:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eng_suffixes() {
        assert_eq!(eng(423.4e9), "423.4G");
        assert_eq!(eng(12.0), "12.0");
        assert_eq!(eng(2_300.0), "2.3K");
        assert_eq!(eng(29.8e9), "29.8G");
        assert_eq!(eng(5.1e12), "5.1T");
    }
}
