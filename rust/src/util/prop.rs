//! Property-testing helper (proptest replacement, offline build).
//!
//! `check(seed_cases, |rng| { ... })` runs a closure over many seeded
//! RNGs; on failure it reports the failing case index + seed so the case
//! reproduces exactly.  Shrinking is traded for deterministic seeds —
//! failures are directly re-runnable.

use super::rng::Pcg32;

/// Run `f` over `cases` deterministic seeds; panics with the failing seed.
pub fn check<F: FnMut(&mut Pcg32)>(cases: usize, mut f: F) {
    for case in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64.wrapping_mul(case as u64 + 1);
        let mut rng = Pcg32::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Random dims helper: a shape whose product stays below `max_elems`.
pub fn dims(rng: &mut Pcg32, max_dim: usize, max_elems: usize) -> (usize, usize) {
    loop {
        let r = rng.range(1, max_dim + 1);
        let c = rng.range(1, max_dim + 1);
        if r * c <= max_elems {
            return (r, c);
        }
    }
}

/// A random f32 vector with occasionally-extreme magnitudes.
pub fn vec_f32(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    let scale = match rng.below(4) {
        0 => 1e-3,
        1 => 1.0,
        2 => 10.0,
        _ => 1e3,
    };
    (0..n).map(|_| scale * rng.normal()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check(25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn check_reports_failure() {
        check(10, |rng| {
            let v = rng.below(5);
            assert!(v < 4, "hit {v}");
        });
    }

    #[test]
    fn dims_bounded() {
        let mut rng = Pcg32::seeded(1);
        for _ in 0..100 {
            let (r, c) = dims(&mut rng, 64, 512);
            assert!(r * c <= 512 && r >= 1 && c >= 1);
        }
    }
}
