//! PCG32 pseudo-random generator (O'Neill 2014) — deterministic, seeded,
//! no external dependency.  Every stochastic component of the system
//! (calibration batching, workload generation, property tests) draws from
//! this so runs are reproducible end to end.

/// PCG-XSH-RR 64/32.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::seeded(43);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_unbiased_range() {
        let mut r = Pcg32::seeded(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(3);
        let xs = r.normal_vec(50_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg32::seeded(4);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.7..3.3).contains(&ratio), "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
