//! Micro-benchmark harness (criterion replacement, offline build).
//!
//! Usage in a `harness = false` bench target:
//! ```no_run
//! use db_llm::util::bench::Bench;
//! let mut b = Bench::new("fdb_matmul");
//! b.bench("packed_256", || { /* work */ });
//! b.report();
//! ```
//! Each case is warmed up, then timed over adaptively-chosen iteration
//! counts until the total run budget is met; mean / p50 / p95 and
//! throughput derived metrics are printed in a stable, parseable format.

use std::time::{Duration, Instant};

/// One measured case.
pub struct Case {
    pub name: String,
    pub samples_ns: Vec<f64>,
    /// Optional work units per iteration (e.g. FLOPs) for throughput.
    pub work_per_iter: Option<f64>,
}

impl Case {
    fn stats(&self) -> (f64, f64, f64) {
        let mut s = self.samples_ns.clone();
        s.sort_by(|a, b| a.partial_cmp(b).expect("sample times are finite"));
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        let p50 = s[s.len() / 2];
        let idx95 = ((s.len() as f64 * 0.95) as usize).min(s.len() - 1);
        let p95 = s[idx95];
        (mean, p50, p95)
    }
}

/// A named group of benchmark cases.
pub struct Bench {
    pub group: String,
    pub cases: Vec<Case>,
    /// Target wall-clock per case.
    pub budget: Duration,
    pub min_samples: usize,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // Allow a fast mode for CI-style smoke runs.
        let quick = std::env::var("BENCH_QUICK").is_ok();
        Bench {
            group: group.to_string(),
            cases: Vec::new(),
            budget: if quick { Duration::from_millis(200) } else { Duration::from_secs(2) },
            min_samples: if quick { 3 } else { 10 },
        }
    }

    /// Time `f`, auto-scaling iterations; returns mean ns/iter.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> f64 {
        self.bench_with_work(name, None, f)
    }

    /// Like `bench` but records work units/iter for throughput reporting.
    pub fn bench_with_work<F: FnMut()>(
        &mut self,
        name: &str,
        work_per_iter: Option<f64>,
        mut f: F,
    ) -> f64 {
        // warmup + estimate per-iter cost
        let t0 = Instant::now();
        f();
        let per = t0.elapsed().as_nanos().max(1) as f64;
        let iters_per_sample = ((1e7 / per).ceil() as usize).clamp(1, 10_000);

        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_samples
            || (start.elapsed() < self.budget && samples.len() < 200)
        {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        }
        let case = Case { name: name.to_string(), samples_ns: samples, work_per_iter };
        let mean = case.stats().0;
        self.cases.push(case);
        mean
    }

    /// Print the report table.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<40} {:>12} {:>12} {:>12} {:>14}",
            "case", "mean", "p50", "p95", "throughput"
        );
        for c in &self.cases {
            let (mean, p50, p95) = c.stats();
            let thr = match c.work_per_iter {
                Some(w) => format!("{}/s", super::eng(w / (mean / 1e9))),
                None => "-".to_string(),
            };
            println!(
                "{:<40} {:>12} {:>12} {:>12} {:>14}",
                c.name,
                fmt_ns(mean),
                fmt_ns(p50),
                fmt_ns(p95),
                thr
            );
        }
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        let mut acc = 0u64;
        b.bench("noop", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(b.cases.len(), 1);
        assert!(b.cases[0].samples_ns.len() >= 3);
        b.report();
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(12.0), "12ns");
        assert_eq!(fmt_ns(1.2e4), "12.000us");
        assert_eq!(fmt_ns(2.5e9), "2.500s");
    }
}
