//! Minimal JSON value model + recursive-descent parser + writer.
//!
//! Replaces `serde_json` for manifest/config interchange with the python
//! layer.  Supports the full JSON grammar (objects, arrays, strings with
//! escapes incl. \uXXXX, numbers, bools, null).  Object key order is
//! preserved (insertion order) so round-trips are stable.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps deterministic ordering for serialization.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a usize: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// `obj.str_list("params")` -> Vec<String>.
    pub fn str_list(&self, key: &str) -> Result<Vec<String>> {
        self.get(key)?
            .as_arr()?
            .iter()
            .map(|v| v.as_str().map(str::to_string))
            .collect()
    }

    /// `obj.usize_list("shape")` -> Vec<usize>.
    pub fn usize_list(&self, key: &str) -> Result<Vec<usize>> {
        self.get(key)?.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let hex2 = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 4;
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                cp
                            };
                            s.push(char::from_u32(ch).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // re-decode multi-byte utf8 from the raw bytes
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
        assert!(!j.get("d").unwrap().get("e").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"b":[1,2.5,"x"],"a":{"k":null,"t":true}}"#;
        let j = Json::parse(src).unwrap();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"shape": [2, 3], "names": ["a", "b"]}"#).unwrap();
        assert_eq!(j.usize_list("shape").unwrap(), vec![2, 3]);
        assert_eq!(j.str_list("names").unwrap(), vec!["a", "b"]);
        assert!(j.usize_list("names").is_err());
        assert!(j.get("missing").is_err());
    }
}
