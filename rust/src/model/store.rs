//! `.dbw` weight-blob reader/writer — the binary interchange format the
//! python layer emits (see `python/compile/dbw.py` for the layout spec).
//!
//! ```text
//! magic   : 4 bytes  b"DBW1"
//! jsonlen : u32 LE
//! header  : JSON {"config": {...}, "tensors": [{name, dtype,
//!           shape, offset, nbytes}, ...]}
//! payload : 64-byte-aligned row-major f32 tensors
//! ```

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::Matrix;
use crate::util::Json;

const MAGIC: &[u8; 4] = b"DBW1";
const ALIGN: usize = 64;

/// A loaded `.dbw` file: config JSON + named tensors.
pub struct Dbw {
    pub config: Json,
    /// name -> (shape, row-major data)
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl Dbw {
    pub fn load(path: impl AsRef<Path>) -> Result<Dbw> {
        let blob = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        ensure!(blob.len() >= 8, "dbw too short");
        ensure!(&blob[..4] == MAGIC, "bad magic {:?}", &blob[..4]);
        let jsonlen = u32::from_le_bytes(blob[4..8].try_into()?) as usize;
        ensure!(blob.len() >= 8 + jsonlen, "truncated header");
        let header = Json::parse(std::str::from_utf8(&blob[8..8 + jsonlen])?)?;
        let base = 8 + jsonlen;

        let mut tensors = BTreeMap::new();
        for e in header.get("tensors")?.as_arr()? {
            let name = e.get("name")?.as_str()?.to_string();
            let dtype = e.get("dtype")?.as_str()?;
            if dtype != "f32" {
                bail!("unsupported dtype {dtype} for {name}");
            }
            let shape = e.usize_list("shape")?;
            let offset = e.get("offset")?.as_usize()?;
            let nbytes = e.get("nbytes")?.as_usize()?;
            let start = base + offset;
            ensure!(start + nbytes <= blob.len(), "tensor {name} out of bounds");
            let n = nbytes / 4;
            ensure!(
                n == shape.iter().product::<usize>().max(1),
                "tensor {name}: shape/byte mismatch"
            );
            let mut data = vec![0.0f32; n];
            for (i, chunk) in blob[start..start + nbytes].chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(chunk.try_into().expect("chunks_exact(4) yields 4"));
            }
            tensors.insert(name, (shape, data));
        }
        Ok(Dbw { config: header.get("config")?.clone(), tensors })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut payload: Vec<u8> = Vec::new();
        let mut entries = Vec::new();
        for (name, (shape, data)) in &self.tensors {
            let pad = (ALIGN - payload.len() % ALIGN) % ALIGN;
            payload.extend(std::iter::repeat(0u8).take(pad));
            let offset = payload.len();
            for v in data {
                payload.extend_from_slice(&v.to_le_bytes());
            }
            entries.push(Json::obj(vec![
                ("name", Json::str(name.clone())),
                ("dtype", Json::str("f32")),
                ("shape", Json::Arr(shape.iter().map(|&s| Json::num(s as f64)).collect())),
                ("offset", Json::num(offset as f64)),
                ("nbytes", Json::num((data.len() * 4) as f64)),
            ]));
        }
        let header = Json::obj(vec![
            ("config", self.config.clone()),
            ("tensors", Json::Arr(entries)),
        ])
        .to_string();

        let mut f = std::fs::File::create(path.as_ref())?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        f.write_all(&payload)?;
        Ok(())
    }

    /// Fetch a 2-D tensor as a Matrix.
    pub fn matrix(&self, name: &str) -> Result<Matrix> {
        let (shape, data) = self
            .tensors
            .get(name)
            .with_context(|| format!("missing tensor {name}"))?;
        ensure!(shape.len() == 2, "{name} is not 2-D: {shape:?}");
        Ok(Matrix::from_vec(shape[0], shape[1], data.clone()))
    }

    /// Fetch a 1-D tensor.
    pub fn vector(&self, name: &str) -> Result<Vec<f32>> {
        let (shape, data) = self
            .tensors
            .get(name)
            .with_context(|| format!("missing tensor {name}"))?;
        ensure!(shape.len() == 1, "{name} is not 1-D: {shape:?}");
        Ok(data.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dbllm_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut tensors = BTreeMap::new();
        tensors.insert("a".to_string(), (vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        tensors.insert("b.v".to_string(), (vec![4], vec![0.5; 4]));
        let dbw = Dbw {
            config: Json::obj(vec![("tag", Json::str("t")), ("n", Json::num(2.0))]),
            tensors,
        };
        let p = tmp("roundtrip.dbw");
        dbw.save(&p).unwrap();
        let back = Dbw::load(&p).unwrap();
        assert_eq!(back.config.get("tag").unwrap().as_str().unwrap(), "t");
        let m = back.matrix("a").unwrap();
        assert_eq!((m.rows, m.cols), (2, 3));
        assert_eq!(m.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(back.vector("b.v").unwrap(), vec![0.5; 4]);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmp("bad.dbw");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(Dbw::load(&p).is_err());
    }

    #[test]
    fn matrix_rejects_1d() {
        let mut tensors = BTreeMap::new();
        tensors.insert("v".to_string(), (vec![4], vec![0.0; 4]));
        let dbw = Dbw { config: Json::Null, tensors };
        let p = tmp("dim.dbw");
        dbw.save(&p).unwrap();
        let back = Dbw::load(&p).unwrap();
        assert!(back.matrix("v").is_err());
        assert!(back.vector("v").is_ok());
    }
}
