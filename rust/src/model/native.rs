//! Native CPU forward pass — a from-scratch implementation of the same
//! LLaMA-style architecture the JAX layer defines.
//!
//! Purposes:
//! 1. **Calibration**: GPTQ/AWQ/OmniQuant/PB-LLM need each linear's
//!    input activations; this forward records them without any HLO
//!    round-trip.
//! 2. **Cross-check**: integration tests assert this forward and the
//!    AOT `fwd_logits` executable agree to fp tolerance — validating
//!    both the runtime marshalling and this substrate at once.
//!
//! Shapes: activations are `Matrix[[T, d]]` per sequence (batch = loop).

use std::collections::BTreeMap;

use crate::tensor::Matrix;

use super::Weights;

/// Forward output: logits `[T, vocab]` and (optionally) per-linear
/// inputs concatenated over positions.
pub struct Forward<'w> {
    pub weights: &'w Weights,
    /// when set, every linear's input rows are appended here
    pub collect: Option<BTreeMap<String, Vec<Matrix>>>,
}

impl<'w> Forward<'w> {
    pub fn new(weights: &'w Weights) -> Self {
        Forward { weights, collect: None }
    }

    pub fn collecting(weights: &'w Weights) -> Self {
        Forward { weights, collect: Some(BTreeMap::new()) }
    }

    fn linear(&mut self, name: &str, x: &Matrix) -> Matrix {
        if let Some(c) = &mut self.collect {
            c.entry(name.to_string()).or_default().push(x.clone());
        }
        x.matmul(self.weights.mat(name))
    }

    /// Run one sequence of token ids; returns logits `[T, vocab]`.
    pub fn run(&mut self, tokens: &[u32]) -> Matrix {
        let cfg = self.weights.config.clone();
        let t = tokens.len();
        let d = cfg.d_model;

        // embed
        let emb = self.weights.mat("tok_emb");
        let mut x = Matrix::zeros(t, d);
        for (i, &tok) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(emb.row(tok as usize));
        }

        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        let (cos, sin) = rope_tables(t, hd, cfg.rope_theta);

        for l in 0..cfg.n_layers {
            let pre = format!("layers.{l}.");
            // attention
            let hn = rmsnorm(&x, self.weights.vec(&format!("{pre}attn_norm")), cfg.rmsnorm_eps);
            let mut q = self.linear(&format!("{pre}wq"), &hn);
            let mut k = self.linear(&format!("{pre}wk"), &hn);
            let v = self.linear(&format!("{pre}wv"), &hn);
            apply_rope(&mut q, h, hd, &cos, &sin);
            apply_rope(&mut k, h, hd, &cos, &sin);
            let ctx = causal_attention(&q, &k, &v, h, hd);
            let proj = self.linear(&format!("{pre}wo"), &ctx);
            x = x.add(&proj);
            // mlp
            let hn = rmsnorm(&x, self.weights.vec(&format!("{pre}mlp_norm")), cfg.rmsnorm_eps);
            let gate = self.linear(&format!("{pre}w_gate"), &hn);
            let up = self.linear(&format!("{pre}w_up"), &hn);
            let mut act = Matrix::zeros(t, cfg.d_ff);
            for i in 0..t * cfg.d_ff {
                act.data[i] = silu(gate.data[i]) * up.data[i];
            }
            let down = self.linear(&format!("{pre}w_down"), &act);
            x = x.add(&down);
        }

        let xn = rmsnorm(&x, self.weights.vec("final_norm"), cfg.rmsnorm_eps);
        xn.matmul(self.weights.mat("head"))
    }

    /// Per-token NLL (nats) of `tokens[1..]` under the model.
    pub fn nll(&mut self, tokens: &[u32]) -> Vec<f64> {
        let logits = self.run(&tokens[..tokens.len() - 1]);
        (0..logits.rows)
            .map(|i| {
                let row = logits.row(i);
                let lse = log_sum_exp(row);
                lse - row[tokens[i + 1] as usize] as f64
            })
            .collect()
    }

    /// Take the collected activations as Calib-ready matrices
    /// `[rows, in]` per linear.
    pub fn take_activations(&mut self) -> BTreeMap<String, Matrix> {
        let collected = self.collect.take().unwrap_or_default();
        collected
            .into_iter()
            .map(|(name, chunks)| {
                let cols = chunks[0].cols;
                let rows: usize = chunks.iter().map(|c| c.rows).sum();
                let mut m = Matrix::zeros(rows, cols);
                let mut r0 = 0;
                for ch in chunks {
                    m.data[r0 * cols..(r0 + ch.rows) * cols].copy_from_slice(&ch.data);
                    r0 += ch.rows;
                }
                (name, m)
            })
            .collect()
    }
}

pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// RMSNorm one row into `out` — the row primitive shared by the batched
/// forward and the incremental (`infer::step`) path.
pub fn rmsnorm_row(row: &[f32], gain: &[f32], eps: f64, out: &mut [f32]) {
    let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / row.len() as f64;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(row).zip(gain) {
        *o = (v as f64 * inv) as f32 * g;
    }
}

pub fn rmsnorm(x: &Matrix, gain: &[f32], eps: f64) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        rmsnorm_row(x.row(r), gain, eps, out.row_mut(r));
    }
    out
}

/// (cos, sin) rows `[hd/2]` for a single absolute position — the
/// per-position primitive behind `rope_tables`, used directly by the
/// incremental decode path (one new position per step).
pub fn rope_pos(pos: usize, hd: usize, theta: f64) -> (Vec<f32>, Vec<f32>) {
    let half = hd / 2;
    let mut cos = vec![0.0f32; half];
    let mut sin = vec![0.0f32; half];
    rope_pos_into(pos, hd, theta, &mut cos, &mut sin);
    (cos, sin)
}

/// [`rope_pos`] into caller-owned `[hd/2]` slices — the allocation-free
/// form the fused multi-slot decode uses (one row per active slot, each
/// at its own absolute position).
pub fn rope_pos_into(pos: usize, hd: usize, theta: f64, cos: &mut [f32], sin: &mut [f32]) {
    let half = hd / 2;
    debug_assert_eq!(cos.len(), half);
    debug_assert_eq!(sin.len(), half);
    for i in 0..half {
        let inv = theta.powf(-((2 * i) as f64) / hd as f64);
        let ang = pos as f64 * inv;
        cos[i] = ang.cos() as f32;
        sin[i] = ang.sin() as f32;
    }
}

/// (cos, sin) tables `[T, hd/2]`, matching the python `rope_tables`.
pub fn rope_tables(t: usize, hd: usize, theta: f64) -> (Matrix, Matrix) {
    let half = hd / 2;
    let mut cos = Matrix::zeros(t, half);
    let mut sin = Matrix::zeros(t, half);
    for pos in 0..t {
        let (c, s) = rope_pos(pos, hd, theta);
        cos.row_mut(pos).copy_from_slice(&c);
        sin.row_mut(pos).copy_from_slice(&s);
    }
    (cos, sin)
}

/// In-place RoPE on one `[h*hd]` row given that position's (cos, sin)
/// rows (pairs (0,1),(2,3),… within each head).
pub fn rope_row(x: &mut [f32], h: usize, hd: usize, cos: &[f32], sin: &[f32]) {
    let half = hd / 2;
    for head in 0..h {
        let base = head * hd;
        for i in 0..half {
            let (c, s) = (cos[i], sin[i]);
            let a = x[base + 2 * i];
            let b = x[base + 2 * i + 1];
            x[base + 2 * i] = a * c - b * s;
            x[base + 2 * i + 1] = a * s + b * c;
        }
    }
}

/// In-place RoPE on `[T, h*hd]`.
pub fn apply_rope(x: &mut Matrix, h: usize, hd: usize, cos: &Matrix, sin: &Matrix) {
    for t in 0..x.rows {
        rope_row(x.row_mut(t), h, hd, cos.row(t), sin.row(t));
    }
}

/// Single-query softmax attention: one query row `[h*hd]` over `n`
/// cached K/V rows (`k_at`/`v_at` return chronological row `i`, width
/// `h*hd`), accumulated into `out`.  This is the primitive both the
/// batched causal forward and the KV-cached incremental step build on.
pub fn attend_one<'k, 'v>(
    q: &[f32],
    n: usize,
    k_at: impl Fn(usize) -> &'k [f32],
    v_at: impl Fn(usize) -> &'v [f32],
    h: usize,
    hd: usize,
    scores: &mut Vec<f64>,
    out: &mut [f32],
) {
    let scale = 1.0 / (hd as f64).sqrt();
    scores.resize(n, 0.0);
    out.fill(0.0);
    for head in 0..h {
        let base = head * hd;
        let qrow = &q[base..base + hd];
        let mut mx = f64::NEG_INFINITY;
        for ki in 0..n {
            let krow = &k_at(ki)[base..base + hd];
            let dot: f64 = qrow.iter().zip(krow).map(|(&a, &b)| a as f64 * b as f64).sum();
            scores[ki] = dot * scale;
            mx = mx.max(scores[ki]);
        }
        let mut denom = 0.0f64;
        for s in scores.iter_mut().take(n) {
            *s = (*s - mx).exp();
            denom += *s;
        }
        let orow = &mut out[base..base + hd];
        for ki in 0..n {
            let wgt = (scores[ki] / denom) as f32;
            let vrow = &v_at(ki)[base..base + hd];
            for (o, &vv) in orow.iter_mut().zip(vrow) {
                *o += wgt * vv;
            }
        }
    }
}

/// Causal softmax attention; q,k,v `[T, h*hd]` -> ctx `[T, h*hd]`.
pub fn causal_attention(q: &Matrix, k: &Matrix, v: &Matrix, h: usize, hd: usize) -> Matrix {
    let t = q.rows;
    let mut ctx = Matrix::zeros(t, h * hd);
    let mut scores = Vec::with_capacity(t);
    for qi in 0..t {
        let out = &mut ctx.data[qi * h * hd..(qi + 1) * h * hd];
        attend_one(q.row(qi), qi + 1, |i| k.row(i), |i| v.row(i), h, hd, &mut scores, out);
    }
    ctx
}

pub fn log_sum_exp(row: &[f32]) -> f64 {
    let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
    let s: f64 = row.iter().map(|&v| ((v as f64) - mx).exp()).sum();
    mx + s.ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            vocab: 128,
            seq_len: 32,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    #[test]
    fn forward_shape_and_finite() {
        let w = Weights::synthetic(&tiny(), 1);
        let logits = Forward::new(&w).run(&[1, 2, 3, 4, 5]);
        assert_eq!((logits.rows, logits.cols), (5, 128));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality() {
        let w = Weights::synthetic(&tiny(), 2);
        let l1 = Forward::new(&w).run(&[1, 2, 3, 4, 5, 6]);
        let l2 = Forward::new(&w).run(&[1, 2, 3, 9, 9, 9]);
        for c in 0..128 {
            assert!((l1.at(0, c) - l2.at(0, c)).abs() < 1e-5);
            assert!((l1.at(2, c) - l2.at(2, c)).abs() < 1e-5);
        }
        let diff: f32 = (0..128).map(|c| (l1.at(3, c) - l2.at(3, c)).abs()).sum();
        assert!(diff > 1e-3);
    }

    #[test]
    fn nll_matches_logits() {
        let w = Weights::synthetic(&tiny(), 3);
        let toks = [5u32, 7, 11, 13, 17];
        let nll = Forward::new(&w).nll(&toks);
        assert_eq!(nll.len(), 4);
        let logits = Forward::new(&w).run(&toks[..4]);
        for (i, &expect) in nll.iter().enumerate() {
            let row = logits.row(i);
            let got = log_sum_exp(row) - row[toks[i + 1] as usize] as f64;
            assert!((got - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn activations_collected_for_all_linears() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 4);
        let mut f = Forward::collecting(&w);
        let _ = f.run(&[1, 2, 3, 4]);
        let _ = f.run(&[5, 6, 7]);
        let acts = f.take_activations();
        assert_eq!(acts.len(), cfg.linear_names().len());
        let a = &acts["layers.0.wq"];
        assert_eq!((a.rows, a.cols), (7, 64)); // 4 + 3 positions
        let d = &acts["layers.1.w_down"];
        assert_eq!((d.rows, d.cols), (7, 192));
    }

    #[test]
    fn rope_identity_at_pos0_and_norm_preserving() {
        let (cos, sin) = rope_tables(4, 16, 10000.0);
        let mut rng = crate::util::Pcg32::seeded(5);
        let mut x = Matrix::randn(4, 64, &mut rng, 1.0);
        let orig = x.clone();
        apply_rope(&mut x, 4, 16, &cos, &sin);
        for c in 0..64 {
            assert!((x.at(0, c) - orig.at(0, c)).abs() < 1e-6);
        }
        for t in 0..4 {
            let n1: f64 = orig.row(t).iter().map(|&v| (v as f64).powi(2)).sum();
            let n2: f64 = x.row(t).iter().map(|&v| (v as f64).powi(2)).sum();
            assert!((n1 - n2).abs() < 1e-3);
        }
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        // with v = one-hot rows, outputs must be within the simplex hull
        let t = 5;
        let (h, hd) = (1, 4);
        let mut rng = crate::util::Pcg32::seeded(6);
        let q = Matrix::randn(t, hd, &mut rng, 1.0);
        let k = Matrix::randn(t, hd, &mut rng, 1.0);
        let v = Matrix::from_fn(t, hd, |r, c| if c == r % hd { 1.0 } else { 0.0 });
        let ctx = causal_attention(&q, &k, &v, h, hd);
        for r in 0..t {
            let sum: f32 = ctx.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "row {r} sum {sum}");
            assert!(ctx.row(r).iter().all(|&x| (-1e-6..=1.0 + 1e-6).contains(&x)));
        }
        // first row attends only to itself
        assert!((ctx.at(0, 0) - 1.0).abs() < 1e-6);
    }
}
