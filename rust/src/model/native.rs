//! Native CPU forward pass — a from-scratch implementation of the same
//! LLaMA-style architecture the JAX layer defines.
//!
//! Purposes:
//! 1. **Calibration**: GPTQ/AWQ/OmniQuant/PB-LLM need each linear's
//!    input activations; this forward records them without any HLO
//!    round-trip.
//! 2. **Cross-check**: integration tests assert this forward and the
//!    AOT `fwd_logits` executable agree to fp tolerance — validating
//!    both the runtime marshalling and this substrate at once.
//!
//! Shapes: activations are `Matrix[[T, d]]` per sequence (batch = loop).

use std::collections::BTreeMap;

use crate::tensor::Matrix;

use super::Weights;

/// Forward output: logits `[T, vocab]` and (optionally) per-linear
/// inputs concatenated over positions.
pub struct Forward<'w> {
    pub weights: &'w Weights,
    /// when set, every linear's input rows are appended here
    pub collect: Option<BTreeMap<String, Vec<Matrix>>>,
}

impl<'w> Forward<'w> {
    pub fn new(weights: &'w Weights) -> Self {
        Forward { weights, collect: None }
    }

    pub fn collecting(weights: &'w Weights) -> Self {
        Forward { weights, collect: Some(BTreeMap::new()) }
    }

    fn linear(&mut self, name: &str, x: &Matrix) -> Matrix {
        if let Some(c) = &mut self.collect {
            c.entry(name.to_string()).or_default().push(x.clone());
        }
        x.matmul(self.weights.mat(name))
    }

    /// Run one sequence of token ids; returns logits `[T, vocab]`.
    pub fn run(&mut self, tokens: &[u32]) -> Matrix {
        let cfg = self.weights.config.clone();
        let t = tokens.len();
        let d = cfg.d_model;

        // embed
        let emb = self.weights.mat("tok_emb");
        let mut x = Matrix::zeros(t, d);
        for (i, &tok) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(emb.row(tok as usize));
        }

        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        let (cos, sin) = rope_tables(t, hd, cfg.rope_theta);

        for l in 0..cfg.n_layers {
            let pre = format!("layers.{l}.");
            // attention
            let hn = rmsnorm(&x, self.weights.vec(&format!("{pre}attn_norm")), cfg.rmsnorm_eps);
            let mut q = self.linear(&format!("{pre}wq"), &hn);
            let mut k = self.linear(&format!("{pre}wk"), &hn);
            let v = self.linear(&format!("{pre}wv"), &hn);
            apply_rope(&mut q, h, hd, &cos, &sin);
            apply_rope(&mut k, h, hd, &cos, &sin);
            let ctx = causal_attention(&q, &k, &v, h, hd);
            let proj = self.linear(&format!("{pre}wo"), &ctx);
            x = x.add(&proj);
            // mlp
            let hn = rmsnorm(&x, self.weights.vec(&format!("{pre}mlp_norm")), cfg.rmsnorm_eps);
            let gate = self.linear(&format!("{pre}w_gate"), &hn);
            let up = self.linear(&format!("{pre}w_up"), &hn);
            let mut act = Matrix::zeros(t, cfg.d_ff);
            for i in 0..t * cfg.d_ff {
                act.data[i] = silu(gate.data[i]) * up.data[i];
            }
            let down = self.linear(&format!("{pre}w_down"), &act);
            x = x.add(&down);
        }

        let xn = rmsnorm(&x, self.weights.vec("final_norm"), cfg.rmsnorm_eps);
        xn.matmul(self.weights.mat("head"))
    }

    /// Per-token NLL (nats) of `tokens[1..]` under the model.
    pub fn nll(&mut self, tokens: &[u32]) -> Vec<f64> {
        let logits = self.run(&tokens[..tokens.len() - 1]);
        (0..logits.rows)
            .map(|i| {
                let row = logits.row(i);
                let lse = log_sum_exp(row);
                lse - row[tokens[i + 1] as usize] as f64
            })
            .collect()
    }

    /// Take the collected activations as Calib-ready matrices
    /// `[rows, in]` per linear.
    pub fn take_activations(&mut self) -> BTreeMap<String, Matrix> {
        let collected = self.collect.take().unwrap_or_default();
        collected
            .into_iter()
            .map(|(name, chunks)| {
                let cols = chunks[0].cols;
                let rows: usize = chunks.iter().map(|c| c.rows).sum();
                let mut m = Matrix::zeros(rows, cols);
                let mut r0 = 0;
                for ch in chunks {
                    m.data[r0 * cols..(r0 + ch.rows) * cols].copy_from_slice(&ch.data);
                    r0 += ch.rows;
                }
                (name, m)
            })
            .collect()
    }
}

pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

pub fn rmsnorm(x: &Matrix, gain: &[f32], eps: f64) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms: f64 =
            row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.cols as f64;
        let inv = 1.0 / (ms + eps).sqrt();
        for (c, &v) in row.iter().enumerate() {
            out.data[r * x.cols + c] = (v as f64 * inv) as f32 * gain[c];
        }
    }
    out
}

/// (cos, sin) tables `[T, hd/2]`, matching the python `rope_tables`.
pub fn rope_tables(t: usize, hd: usize, theta: f64) -> (Matrix, Matrix) {
    let half = hd / 2;
    let mut cos = Matrix::zeros(t, half);
    let mut sin = Matrix::zeros(t, half);
    for pos in 0..t {
        for i in 0..half {
            let inv = theta.powf(-((2 * i) as f64) / hd as f64);
            let ang = pos as f64 * inv;
            *cos.at_mut(pos, i) = ang.cos() as f32;
            *sin.at_mut(pos, i) = ang.sin() as f32;
        }
    }
    (cos, sin)
}

/// In-place RoPE on `[T, h*hd]` (pairs (0,1),(2,3),… within each head).
pub fn apply_rope(x: &mut Matrix, h: usize, hd: usize, cos: &Matrix, sin: &Matrix) {
    let half = hd / 2;
    for t in 0..x.rows {
        for head in 0..h {
            let base = head * hd;
            for i in 0..half {
                let (c, s) = (cos.at(t, i), sin.at(t, i));
                let a = x.at(t, base + 2 * i);
                let b = x.at(t, base + 2 * i + 1);
                *x.at_mut(t, base + 2 * i) = a * c - b * s;
                *x.at_mut(t, base + 2 * i + 1) = a * s + b * c;
            }
        }
    }
}

/// Causal softmax attention; q,k,v `[T, h*hd]` -> ctx `[T, h*hd]`.
pub fn causal_attention(q: &Matrix, k: &Matrix, v: &Matrix, h: usize, hd: usize) -> Matrix {
    let t = q.rows;
    let scale = 1.0 / (hd as f64).sqrt();
    let mut ctx = Matrix::zeros(t, h * hd);
    let mut scores = vec![0.0f64; t];
    for head in 0..h {
        let base = head * hd;
        for qi in 0..t {
            // scores over keys 0..=qi
            let qrow = &q.row(qi)[base..base + hd];
            let mut mx = f64::NEG_INFINITY;
            for ki in 0..=qi {
                let krow = &k.row(ki)[base..base + hd];
                let dot: f64 = qrow
                    .iter()
                    .zip(krow)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                scores[ki] = dot * scale;
                mx = mx.max(scores[ki]);
            }
            let mut denom = 0.0f64;
            for s in scores.iter_mut().take(qi + 1) {
                *s = (*s - mx).exp();
                denom += *s;
            }
            let out = &mut ctx.row_mut(qi)[base..base + hd];
            for ki in 0..=qi {
                let wgt = (scores[ki] / denom) as f32;
                let vrow = &v.row(ki)[base..base + hd];
                for (o, &vv) in out.iter_mut().zip(vrow) {
                    *o += wgt * vv;
                }
            }
        }
    }
    ctx
}

pub fn log_sum_exp(row: &[f32]) -> f64 {
    let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
    let s: f64 = row.iter().map(|&v| ((v as f64) - mx).exp()).sum();
    mx + s.ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            vocab: 128,
            seq_len: 32,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    #[test]
    fn forward_shape_and_finite() {
        let w = Weights::synthetic(&tiny(), 1);
        let logits = Forward::new(&w).run(&[1, 2, 3, 4, 5]);
        assert_eq!((logits.rows, logits.cols), (5, 128));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality() {
        let w = Weights::synthetic(&tiny(), 2);
        let l1 = Forward::new(&w).run(&[1, 2, 3, 4, 5, 6]);
        let l2 = Forward::new(&w).run(&[1, 2, 3, 9, 9, 9]);
        for c in 0..128 {
            assert!((l1.at(0, c) - l2.at(0, c)).abs() < 1e-5);
            assert!((l1.at(2, c) - l2.at(2, c)).abs() < 1e-5);
        }
        let diff: f32 = (0..128).map(|c| (l1.at(3, c) - l2.at(3, c)).abs()).sum();
        assert!(diff > 1e-3);
    }

    #[test]
    fn nll_matches_logits() {
        let w = Weights::synthetic(&tiny(), 3);
        let toks = [5u32, 7, 11, 13, 17];
        let nll = Forward::new(&w).nll(&toks);
        assert_eq!(nll.len(), 4);
        let logits = Forward::new(&w).run(&toks[..4]);
        for (i, &expect) in nll.iter().enumerate() {
            let row = logits.row(i);
            let got = log_sum_exp(row) - row[toks[i + 1] as usize] as f64;
            assert!((got - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn activations_collected_for_all_linears() {
        let cfg = tiny();
        let w = Weights::synthetic(&cfg, 4);
        let mut f = Forward::collecting(&w);
        let _ = f.run(&[1, 2, 3, 4]);
        let _ = f.run(&[5, 6, 7]);
        let acts = f.take_activations();
        assert_eq!(acts.len(), cfg.linear_names().len());
        let a = &acts["layers.0.wq"];
        assert_eq!((a.rows, a.cols), (7, 64)); // 4 + 3 positions
        let d = &acts["layers.1.w_down"];
        assert_eq!((d.rows, d.cols), (7, 192));
    }

    #[test]
    fn rope_identity_at_pos0_and_norm_preserving() {
        let (cos, sin) = rope_tables(4, 16, 10000.0);
        let mut rng = crate::util::Pcg32::seeded(5);
        let mut x = Matrix::randn(4, 64, &mut rng, 1.0);
        let orig = x.clone();
        apply_rope(&mut x, 4, 16, &cos, &sin);
        for c in 0..64 {
            assert!((x.at(0, c) - orig.at(0, c)).abs() < 1e-6);
        }
        for t in 0..4 {
            let n1: f64 = orig.row(t).iter().map(|&v| (v as f64).powi(2)).sum();
            let n2: f64 = x.row(t).iter().map(|&v| (v as f64).powi(2)).sum();
            assert!((n1 - n2).abs() < 1e-3);
        }
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        // with v = one-hot rows, outputs must be within the simplex hull
        let t = 5;
        let (h, hd) = (1, 4);
        let mut rng = crate::util::Pcg32::seeded(6);
        let q = Matrix::randn(t, hd, &mut rng, 1.0);
        let k = Matrix::randn(t, hd, &mut rng, 1.0);
        let v = Matrix::from_fn(t, hd, |r, c| if c == r % hd { 1.0 } else { 0.0 });
        let ctx = causal_attention(&q, &k, &v, h, hd);
        for r in 0..t {
            let sum: f32 = ctx.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "row {r} sum {sum}");
            assert!(ctx.row(r).iter().all(|&x| (-1e-6..=1.0 + 1e-6).contains(&x)));
        }
        // first row attends only to itself
        assert!((ctx.at(0, 0) - 1.0).abs() < 1e-6);
    }
}
