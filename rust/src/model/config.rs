//! Model hyper-parameters — parsed from `artifacts/manifest.json` (the
//! python layer is the single source of truth; this struct only mirrors
//! it) plus the published LLaMA configs used by the Table 6 regeneration.

use anyhow::Result;

use crate::util::Json;

/// LLaMA-style decoder-only transformer hyper-parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub rope_theta: f64,
    pub rmsnorm_eps: f64,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Exact parameter count (untied embeddings) — must equal the python
    /// side's `ModelConfig.n_params()`.
    pub fn n_params(&self) -> usize {
        let per_layer =
            4 * self.d_model * self.d_model + 3 * self.d_model * self.d_ff + 2 * self.d_model;
        self.vocab * self.d_model
            + self.n_layers * per_layer
            + self.d_model
            + self.d_model * self.vocab
    }

    /// Parse from a manifest `sizes.<key>` object.
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(ModelConfig {
            name: j.get("name")?.as_str()?.to_string(),
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            rope_theta: j.get("rope_theta")?.as_f64()?,
            rmsnorm_eps: j.get("rmsnorm_eps")?.as_f64()?,
        })
    }

    /// The published LLaMA-1-7B configuration — used to regenerate the
    /// paper's own Table 6 numbers from the analytic model.
    pub fn llama1_7b() -> Self {
        ModelConfig {
            name: "LLaMA-1-7B".into(),
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            d_ff: 11008,
            vocab: 32000,
            seq_len: 2048,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    /// The seven quantizable linear names per layer, canonical order
    /// (mirrors `python/compile/model.py::LINEAR_NAMES`).
    pub const LINEAR_NAMES: [&'static str; 7] =
        ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

    /// Full flat parameter order (mirrors python `param_names`).
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["tok_emb".to_string()];
        for i in 0..self.n_layers {
            names.push(format!("layers.{i}.attn_norm"));
            for n in ["wq", "wk", "wv", "wo"] {
                names.push(format!("layers.{i}.{n}"));
            }
            names.push(format!("layers.{i}.mlp_norm"));
            for n in ["w_gate", "w_up", "w_down"] {
                names.push(format!("layers.{i}.{n}"));
            }
        }
        names.push("final_norm".into());
        names.push("head".into());
        names
    }

    /// Quantizable subset, order preserved.
    pub fn linear_names(&self) -> Vec<String> {
        (0..self.n_layers)
            .flat_map(|i| Self::LINEAR_NAMES.iter().map(move |n| format!("layers.{i}.{n}")))
            .collect()
    }

    /// `[in, out]` shape of a linear by name.
    pub fn linear_shape(&self, name: &str) -> (usize, usize) {
        let base = name.rsplit('.').next().expect("rsplit yields at least one part");
        let (d, f) = (self.d_model, self.d_ff);
        match base {
            "wq" | "wk" | "wv" | "wo" => (d, d),
            "w_gate" | "w_up" => (d, f),
            "w_down" => (f, d),
            _ => panic!("not a linear: {name}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            vocab: 512,
            seq_len: 64,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    #[test]
    fn param_names_order_matches_python_convention() {
        let names = tiny().param_names();
        assert_eq!(names[0], "tok_emb");
        assert_eq!(names[1], "layers.0.attn_norm");
        assert_eq!(names[2], "layers.0.wq");
        assert_eq!(names[6], "layers.0.mlp_norm");
        assert_eq!(names[7], "layers.0.w_gate");
        assert_eq!(names[names.len() - 2], "final_norm");
        assert_eq!(names[names.len() - 1], "head");
        assert_eq!(names.len(), 1 + 2 * 9 + 2);
    }

    #[test]
    fn n_params_formula() {
        let c = tiny();
        // emb 512*64*2 + 2*(4*64*64 + 3*64*192 + 2*64) + 64
        let expect = 512 * 64 + 2 * (4 * 64 * 64 + 3 * 64 * 192 + 2 * 64) + 64 + 64 * 512;
        assert_eq!(c.n_params(), expect);
    }

    #[test]
    fn llama7b_param_count_close_to_published() {
        let c = ModelConfig::llama1_7b();
        let p = c.n_params() as f64;
        // published: ~6.74B
        assert!((6.4e9..7.1e9).contains(&p), "{p}");
    }

    #[test]
    fn linear_shapes() {
        let c = tiny();
        assert_eq!(c.linear_shape("layers.0.wq"), (64, 64));
        assert_eq!(c.linear_shape("layers.1.w_up"), (64, 192));
        assert_eq!(c.linear_shape("layers.1.w_down"), (192, 64));
        assert_eq!(c.linear_names().len(), 14);
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(
            r#"{"name":"S","d_model":64,"n_layers":2,"n_heads":4,"d_ff":192,
                "vocab":512,"seq_len":64,"rope_theta":10000.0,"rmsnorm_eps":1e-5,
                "head_dim":16,"n_params":0}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c.d_model, 64);
        assert_eq!(c.head_dim(), 16);
    }
}
