//! Analytic model-size / sparsity / FLOPs accounting — the machinery
//! behind Table 6 ("Model size, sparsity, and computational complexity
//! of LLaMA-1-7B … where the model processes a 32-token sentence").
//!
//! Conventions match the binarization literature the paper cites (Liu
//! et al. 2018 count bit-ops as a fixed fraction of fp ops): a dense
//! fp16 matmul of a length-T sequence through a `[in, out]` linear
//! costs 2·T·in·out FLOPs; a k-bit weight matmul costs the dense FLOPs
//! scaled by k/16 (narrow multiplies) and further discounted by the
//! weight sparsity (zero weights are skipped).  Attention score/value
//! matmuls, the lm head and norms stay fp16 for every scheme.
//!
//! This convention regenerates the paper's own Table 6 numbers from the
//! LLaMA-1-7B config: 423.4G (fp16) / 88.2G (3-bit) / 37.3G (2-bit at
//! 48.3% sparsity) / 36.4G (binary) / 29.8G (FDB at 62.8% sparsity) —
//! asserted in the tests below.

use super::ModelConfig;

/// Compression scheme being accounted.
#[derive(Clone, Debug, PartialEq)]
pub enum Scheme {
    /// fp16 dense baseline
    Fp16,
    /// k-bit uniform quantization with a given weight sparsity level
    /// (fraction of zero weights in the dequantized grid)
    Uniform { bits: f64, sparsity: f64 },
    /// 1-bit binarization (levels ±α — no zeros by construction)
    Binary,
    /// FDB dual-binary with measured plane sparsities
    Fdb { sparsity_b1: f64, sparsity_b2: f64, effective_bits: f64 },
}

/// Table-6 style report row.
#[derive(Clone, Debug)]
pub struct CostReport {
    pub method: String,
    pub model_size_bytes: f64,
    pub sparsity: Option<f64>,
    pub flops: f64,
}

/// Total linear (quantizable) weights of the model.
pub fn linear_params(cfg: &ModelConfig) -> f64 {
    cfg.linear_names()
        .iter()
        .map(|n| {
            let (i, o) = cfg.linear_shape(n);
            (i * o) as f64
        })
        .sum()
}

/// Non-quantized parameters (embeddings + head + norms) kept fp16.
pub fn other_params(cfg: &ModelConfig) -> f64 {
    cfg.n_params() as f64 - linear_params(cfg)
}

/// Model size in bytes for a scheme (scales/zero-points included via the
/// effective bits; non-linear params at fp16).
pub fn model_size_bytes(cfg: &ModelConfig, bits_per_weight: f64) -> f64 {
    linear_params(cfg) * bits_per_weight / 8.0 + other_params(cfg) * 2.0
}

/// FLOPs for processing a `t`-token sentence (single forward).
///
/// Linear layers dominate; attention score/value matmuls (2·2·T²·d per
/// layer) and the lm head are counted at full precision for every
/// scheme, matching the paper's accounting where only weight-matmul
/// cost shrinks.
pub fn forward_flops(cfg: &ModelConfig, t: f64, scheme: &Scheme) -> f64 {
    let lin = 2.0 * t * linear_params(cfg);
    let attn = cfg.n_layers as f64 * 2.0 * 2.0 * t * t * cfg.d_model as f64;
    let head = 2.0 * t * (cfg.d_model * cfg.vocab) as f64;
    let emb_norms = t * (cfg.n_layers as f64 * 2.0 + 1.0) * 4.0 * cfg.d_model as f64;
    let fixed = attn + head + emb_norms;
    let lin_cost = match scheme {
        Scheme::Fp16 => lin,
        // k-bit lanes cost k/16 of an fp16 lane; zero weights skipped
        Scheme::Uniform { bits, sparsity } => lin * (bits / 16.0) * (1.0 - sparsity),
        // ±α binary: 1-bit lanes, no zeros by construction
        Scheme::Binary => lin * (1.0 / 16.0),
        // FDB: two 1-bit planes, each contributing only its live lanes
        Scheme::Fdb { sparsity_b1, sparsity_b2, .. } => {
            let live = (1.0 - sparsity_b1) + (1.0 - sparsity_b2);
            lin * (1.0 / 16.0) * live
        }
    };
    lin_cost + fixed
}

/// Assemble a Table-6 row.
pub fn report(cfg: &ModelConfig, t: f64, scheme: &Scheme) -> CostReport {
    let (method, bits, sparsity) = match scheme {
        Scheme::Fp16 => ("FP-16".to_string(), 16.0, None),
        Scheme::Uniform { bits, sparsity } => {
            (format!("{}-bit quantization", bits.round() as u32), *bits + 0.25, Some(*sparsity))
        }
        Scheme::Binary => ("binarization".to_string(), 1.0 + 0.25, Some(0.0)),
        Scheme::Fdb { sparsity_b1, sparsity_b2, effective_bits } => (
            "Ours (DB-LLM)".to_string(),
            *effective_bits,
            Some(0.5 * (sparsity_b1 + sparsity_b2)),
        ),
    };
    CostReport {
        method,
        model_size_bytes: model_size_bytes(cfg, bits),
        sparsity,
        flops: forward_flops(cfg, t, scheme),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama7b_fp16_size_matches_paper() {
        // paper Table 6: FP-16 = 12.6 GB
        let cfg = ModelConfig::llama1_7b();
        let b = model_size_bytes(&cfg, 16.0);
        assert!((12.0e9..13.5e9).contains(&b), "{b}");
    }

    #[test]
    fn llama7b_fp16_flops_matches_paper() {
        // paper Table 6: 423.4 GFLOPs for a 32-token sentence
        let cfg = ModelConfig::llama1_7b();
        let f = forward_flops(&cfg, 32.0, &Scheme::Fp16);
        assert!(
            (400.0e9..450.0e9).contains(&f),
            "{} GFLOPs vs paper 423.4",
            f / 1e9
        );
    }

    #[test]
    fn llama7b_quant_flops_regenerate_table6() {
        // the whole Table 6 FLOPs column, within 15%
        let cfg = ModelConfig::llama1_7b();
        let rows = [
            (forward_flops(&cfg, 32.0, &Scheme::Uniform { bits: 3.0, sparsity: 0.0 }), 88.2e9),
            (forward_flops(&cfg, 32.0, &Scheme::Uniform { bits: 2.0, sparsity: 0.483 }), 37.3e9),
            (forward_flops(&cfg, 32.0, &Scheme::Binary), 36.4e9),
            (
                forward_flops(
                    &cfg,
                    32.0,
                    &Scheme::Fdb { sparsity_b1: 0.743, sparsity_b2: 0.513, effective_bits: 1.88 },
                ),
                29.8e9,
            ),
        ];
        for (got, paper) in rows {
            let rel = (got - paper).abs() / paper;
            assert!(rel < 0.15, "{:.1}G vs paper {:.1}G", got / 1e9, paper / 1e9);
        }
    }

    #[test]
    fn llama7b_quant_sizes_match_paper() {
        // paper: 3-bit 2.8G, 2-bit 2.2G, binarization 1.4G, ours 2.3G
        let cfg = ModelConfig::llama1_7b();
        let s3 = model_size_bytes(&cfg, 3.25);
        let s2 = model_size_bytes(&cfg, 2.25);
        let s1 = model_size_bytes(&cfg, 1.25);
        assert!((2.5e9..3.2e9).contains(&s3), "3bit {s3}");
        assert!((1.9e9..2.5e9).contains(&s2), "2bit {s2}");
        assert!((1.1e9..1.7e9).contains(&s1), "bin {s1}");
    }

    #[test]
    fn fdb_flops_reduction_vs_2bit_matches_paper_shape() {
        // paper: 2-bit 37.3G -> ours 29.8G (~20% lower) at the measured
        // sparsities (48.3% for 2-bit, 62.8% overall for FDB)
        let cfg = ModelConfig::llama1_7b();
        let f2 = forward_flops(&cfg, 32.0, &Scheme::Uniform { bits: 2.0, sparsity: 0.483 });
        let ffdb = forward_flops(
            &cfg,
            32.0,
            &Scheme::Fdb { sparsity_b1: 0.74, sparsity_b2: 0.51, effective_bits: 1.88 },
        );
        let reduction = 1.0 - ffdb / f2;
        assert!(
            (0.05..0.45).contains(&reduction),
            "reduction {reduction} (f2 {f2:.3e}, fdb {ffdb:.3e})"
        );
        // and FDB beats the FP baseline by >10x (paper: 14.2x)
        let fp = forward_flops(&cfg, 32.0, &Scheme::Fp16);
        assert!(fp / ffdb > 10.0, "speedup {}", fp / ffdb);
    }

    #[test]
    fn report_rows_have_labels() {
        let cfg = ModelConfig::llama1_7b();
        let r = report(&cfg, 32.0, &Scheme::Binary);
        assert_eq!(r.method, "binarization");
        assert_eq!(r.sparsity, Some(0.0));
    }
}
