//! Model substrate: configs (mirroring `python/compile/configs.py`),
//! the `.dbw` weight store, the canonical parameter naming/ordering
//! shared with the AOT exports, a native CPU forward (calibration +
//! runtime cross-checks) and the analytic size/sparsity/FLOPs
//! accounting behind Table 6.

pub mod config;
pub mod flops;
pub mod native;
pub mod store;
pub mod weights;

pub use config::ModelConfig;
pub use store::Dbw;
pub use weights::Weights;
