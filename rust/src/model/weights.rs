//! In-memory parameter set for one model: embeddings/norms/head plus the
//! quantizable linears, with helpers to swap quantized linears in and to
//! marshal the flat, manifest-ordered parameter list the AOT
//! executables expect.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::tensor::Matrix;
use crate::util::Pcg32;

use super::{Dbw, ModelConfig};

/// All parameters of one model, keyed by canonical name.
#[derive(Clone)]
pub struct Weights {
    pub config: ModelConfig,
    /// 2-D params ([in, out] linears + tok_emb [V,d] + head [d,V]).
    pub mats: BTreeMap<String, Matrix>,
    /// 1-D params (rmsnorm gains).
    pub vecs: BTreeMap<String, Vec<f32>>,
}

impl Weights {
    /// Load a teacher checkpoint written by the python layer.
    pub fn from_dbw(dbw: &Dbw, config: ModelConfig) -> Result<Weights> {
        let mut mats = BTreeMap::new();
        let mut vecs = BTreeMap::new();
        for name in config.param_names() {
            let (shape, _) = dbw
                .tensors
                .get(&name)
                .with_context(|| format!("checkpoint missing {name}"))?;
            if shape.len() == 2 {
                mats.insert(name.clone(), dbw.matrix(&name)?);
            } else {
                vecs.insert(name.clone(), dbw.vector(&name)?);
            }
        }
        Ok(Weights { config, mats, vecs })
    }

    pub fn mat(&self, name: &str) -> &Matrix {
        &self.mats[name]
    }

    pub fn vec(&self, name: &str) -> &[f32] {
        &self.vecs[name]
    }

    /// Replace one linear's weights (after quantization).
    pub fn set_linear(&mut self, name: &str, w: Matrix) {
        let old = self.mats.get(name).expect("unknown linear");
        assert_eq!((old.rows, old.cols), (w.rows, w.cols), "{name} shape change");
        self.mats.insert(name.to_string(), w);
    }

    /// Clone with every quantizable linear replaced via `f(name, w)`.
    pub fn map_linears(&self, mut f: impl FnMut(&str, &Matrix) -> Matrix) -> Weights {
        let mut out = self.clone();
        for name in self.config.linear_names() {
            let w = f(&name, &self.mats[&name]);
            out.set_linear(&name, w);
        }
        out
    }

    /// Flat (data, dims) list in `param_names` order — exactly the
    /// positional arguments of `fwd_logits_*` / `fwd_nll_*`.
    pub fn flat_params(&self) -> Vec<(Vec<f32>, Vec<i64>)> {
        self.config
            .param_names()
            .iter()
            .map(|name| {
                if let Some(m) = self.mats.get(name) {
                    (m.data.clone(), vec![m.rows as i64, m.cols as i64])
                } else {
                    let v = &self.vecs[name];
                    (v.clone(), vec![v.len() as i64])
                }
            })
            .collect()
    }

    /// Gaussian-initialized weights (tests + benches; teachers come from
    /// `.dbw` checkpoints).
    pub fn synthetic(config: &ModelConfig, seed: u64) -> Weights {
        let mut rng = Pcg32::seeded(seed);
        let mut mats = BTreeMap::new();
        let mut vecs = BTreeMap::new();
        mats.insert(
            "tok_emb".into(),
            Matrix::randn(config.vocab, config.d_model, &mut rng, 0.05),
        );
        mats.insert(
            "head".into(),
            Matrix::randn(config.d_model, config.vocab, &mut rng, 0.05),
        );
        vecs.insert("final_norm".into(), vec![1.0; config.d_model]);
        for i in 0..config.n_layers {
            vecs.insert(format!("layers.{i}.attn_norm"), vec![1.0; config.d_model]);
            vecs.insert(format!("layers.{i}.mlp_norm"), vec![1.0; config.d_model]);
        }
        for name in config.linear_names() {
            let (din, dout) = config.linear_shape(&name);
            mats.insert(name, Matrix::randn(din, dout, &mut rng, 0.05));
        }
        Weights { config: config.clone(), mats, vecs }
    }

    /// Mean/std of all linear weights (weight-distribution sanity stats).
    pub fn linear_stats(&self) -> (f64, f64) {
        let mut n = 0usize;
        let mut mean = 0.0f64;
        for name in self.config.linear_names() {
            let m = &self.mats[&name];
            mean += m.data.iter().map(|&x| x as f64).sum::<f64>();
            n += m.data.len();
        }
        mean /= n as f64;
        let mut var = 0.0f64;
        for name in self.config.linear_names() {
            for &x in &self.mats[&name].data {
                var += (x as f64 - mean).powi(2);
            }
        }
        (mean, (var / n as f64).sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic(config: &ModelConfig, seed: u64) -> Weights {
        Weights::synthetic(config, seed)
    }

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            vocab: 128,
            seq_len: 32,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    #[test]
    fn flat_params_order_and_shapes() {
        let cfg = tiny();
        let w = synthetic(&cfg, 1);
        let flat = w.flat_params();
        let names = cfg.param_names();
        assert_eq!(flat.len(), names.len());
        assert_eq!(flat[0].1, vec![128, 64]); // tok_emb
        assert_eq!(flat[1].1, vec![64]); // attn_norm
        assert_eq!(flat.last().unwrap().1, vec![64, 128]); // head
    }

    #[test]
    fn map_linears_touches_only_linears() {
        let cfg = tiny();
        let w = synthetic(&cfg, 2);
        let zeroed = w.map_linears(|_, m| Matrix::zeros(m.rows, m.cols));
        for name in cfg.linear_names() {
            assert!(zeroed.mat(&name).data.iter().all(|&v| v == 0.0));
        }
        assert_eq!(zeroed.mat("tok_emb").data, w.mat("tok_emb").data);
    }

    #[test]
    #[should_panic(expected = "shape change")]
    fn set_linear_rejects_shape_change() {
        let cfg = tiny();
        let mut w = synthetic(&cfg, 3);
        w.set_linear("layers.0.wq", Matrix::zeros(2, 2));
    }
}
