//! Synthetic zero-shot multiple-choice suites (Table 5 / Table 7
//! substitution for PIQA, ARC-e, ARC-c, HellaSwag, WinoGrande).
//!
//! Construction: a context window is cut from the evaluation stream;
//! the *true* continuation is the stream's actual next `cont_len`
//! tokens, distractors are continuations lifted from other positions.
//! The model scores each (context ‖ choice) by length-normalized
//! log-likelihood of the choice span — exactly the lm-eval-harness
//! protocol used by the paper's zero-shot numbers.
//!
//! Difficulty knobs mirror the real suites: more choices and similar
//! distractor contexts (matched prefix token) make ARC-c-like tasks
//! harder than PIQA-like ones.

use super::TokenStream;
use crate::util::Pcg32;

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct ZeroShotTask {
    pub context: Vec<u32>,
    /// `choices[answer]` is the true continuation
    pub choices: Vec<Vec<u32>>,
    pub answer: usize,
}

/// A named suite with generation parameters.
#[derive(Clone, Debug)]
pub struct TaskSuite {
    pub name: String,
    pub context_len: usize,
    pub cont_len: usize,
    pub n_choices: usize,
    /// if true, distractors must share the same preceding token —
    /// locally plausible, globally wrong (the "challenge" variant)
    pub hard_negatives: bool,
    pub n_items: usize,
    pub seed: u64,
}

impl TaskSuite {
    /// The five suites standing in for the paper's benchmarks.  The
    /// (context, continuation, choices) profile of each mirrors its
    /// counterpart: binary-choice physical ordering (PIQA/WinoGrande),
    /// 4-way easy/challenge (ARC-e/ARC-c), long endings (HellaSwag).
    pub fn standard(total_len: usize) -> Vec<TaskSuite> {
        // context + continuation == total_len (the NLL executable width)
        let ctx = |c: usize| total_len - c;
        vec![
            TaskSuite { name: "sPIQA".into(), context_len: ctx(6), cont_len: 6, n_choices: 2, hard_negatives: false, n_items: 200, seed: 101 },
            TaskSuite { name: "sARC-e".into(), context_len: ctx(4), cont_len: 4, n_choices: 4, hard_negatives: false, n_items: 200, seed: 102 },
            TaskSuite { name: "sARC-c".into(), context_len: ctx(4), cont_len: 4, n_choices: 4, hard_negatives: true, n_items: 200, seed: 103 },
            TaskSuite { name: "sHellaSwag".into(), context_len: ctx(12), cont_len: 12, n_choices: 4, hard_negatives: false, n_items: 200, seed: 104 },
            TaskSuite { name: "sWinoGrande".into(), context_len: ctx(2), cont_len: 2, n_choices: 2, hard_negatives: true, n_items: 200, seed: 105 },
        ]
    }

    /// Generate the items from an evaluation stream.
    pub fn generate(&self, stream: &TokenStream) -> Vec<ZeroShotTask> {
        let mut rng = Pcg32::seeded(self.seed);
        let need = self.context_len + self.cont_len;
        let hi = stream.tokens.len() - need - 1;
        // index continuations by preceding token for hard negatives
        let mut by_prev: Vec<Vec<usize>> = vec![Vec::new(); 65536];
        if self.hard_negatives {
            for i in self.context_len..stream.tokens.len() - self.cont_len {
                by_prev[stream.tokens[i - 1] as usize].push(i);
            }
        }

        let mut items = Vec::with_capacity(self.n_items);
        while items.len() < self.n_items {
            let s = rng.range(0, hi);
            let context = stream.tokens[s..s + self.context_len].to_vec();
            let true_start = s + self.context_len;
            let truth = stream.tokens[true_start..true_start + self.cont_len].to_vec();
            let prev = stream.tokens[true_start - 1] as usize;

            let mut choices = vec![truth.clone()];
            let mut guard = 0;
            while choices.len() < self.n_choices {
                guard += 1;
                if guard > 1000 {
                    break;
                }
                let cand_start = if self.hard_negatives && !by_prev[prev].is_empty() {
                    by_prev[prev][rng.range(0, by_prev[prev].len())]
                } else {
                    rng.range(self.context_len, stream.tokens.len() - self.cont_len)
                };
                if cand_start == true_start {
                    continue;
                }
                let cand = stream.tokens[cand_start..cand_start + self.cont_len].to_vec();
                if cand == truth || choices.contains(&cand) {
                    continue;
                }
                choices.push(cand);
            }
            if choices.len() < self.n_choices {
                continue;
            }
            // shuffle answer position
            let answer = rng.range(0, self.n_choices);
            choices.swap(0, answer);
            items.push(ZeroShotTask { context, choices, answer });
        }
        items
    }
}

impl ZeroShotTask {
    /// Token sequence for choice `i`: context ‖ choice.
    pub fn sequence(&self, i: usize) -> Vec<u32> {
        let mut s = self.context.clone();
        s.extend_from_slice(&self.choices[i]);
        s
    }

    pub fn cont_len(&self) -> usize {
        self.choices[0].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream() -> TokenStream {
        // structured stream: token depends on position so continuations
        // from different positions differ
        let mut rng = Pcg32::seeded(7);
        TokenStream {
            tokens: (0..20_000).map(|i| ((i * 7 + rng.range(0, 3)) % 512) as u32).collect(),
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let s = stream();
        let suite = &TaskSuite::standard(65)[0];
        let a = suite.generate(&s);
        let b = suite.generate(&s);
        assert_eq!(a.len(), suite.n_items);
        assert_eq!(a[0].context, b[0].context);
        assert_eq!(a[0].answer, b[0].answer);
    }

    #[test]
    fn true_choice_is_stream_continuation() {
        let s = stream();
        let suite = &TaskSuite::standard(65)[1];
        for item in suite.generate(&s).iter().take(20) {
            // the true continuation must occur right after the context
            // somewhere in the stream
            let truth = &item.choices[item.answer];
            let ctx_last = *item.context.last().expect("task items carry a non-empty context");
            let found = s
                .tokens
                .windows(1 + truth.len())
                .any(|w| w[0] == ctx_last && &w[1..] == truth.as_slice());
            assert!(found);
        }
    }

    #[test]
    fn all_sequences_same_length() {
        let s = stream();
        for suite in TaskSuite::standard(65) {
            let items = suite.generate(&s);
            for item in items.iter().take(10) {
                for i in 0..item.choices.len() {
                    assert_eq!(item.sequence(i).len(), 65);
                }
            }
        }
    }

    #[test]
    fn choices_distinct_and_answer_valid() {
        let s = stream();
        let suite = &TaskSuite::standard(65)[2];
        for item in suite.generate(&s).iter().take(30) {
            assert!(item.answer < item.choices.len());
            for i in 0..item.choices.len() {
                for j in i + 1..item.choices.len() {
                    assert_ne!(item.choices[i], item.choices[j]);
                }
            }
        }
    }

    #[test]
    fn hard_negative_shares_prev_token_context() {
        let s = stream();
        let suite = TaskSuite {
            name: "h".into(),
            context_len: 20,
            cont_len: 4,
            n_choices: 2,
            hard_negatives: true,
            n_items: 30,
            seed: 9,
        };
        let items = suite.generate(&s);
        assert_eq!(items.len(), 30);
    }
}
