//! Data substrate: token-stream I/O (shared `.tok` format with the
//! python layer), evaluation windows, and the synthetic zero-shot
//! multiple-choice suites standing in for PIQA/ARC/HellaSwag/WinoGrande
//! (DESIGN.md §2 substitution table).

pub mod tasks;
pub mod tokens;

pub use tasks::{TaskSuite, ZeroShotTask};
pub use tokens::TokenStream;
