//! `.tok` token streams (little-endian u16) + evaluation windows.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::Pcg32;

/// A loaded token stream.
#[derive(Clone)]
pub struct TokenStream {
    pub tokens: Vec<u32>,
}

impl TokenStream {
    pub fn load(path: impl AsRef<Path>) -> Result<TokenStream> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        ensure!(bytes.len() % 2 == 0, "odd byte count in token file");
        let tokens = bytes
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]) as u32)
            .collect();
        Ok(TokenStream { tokens })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut bytes = Vec::with_capacity(self.tokens.len() * 2);
        for &t in &self.tokens {
            bytes.extend_from_slice(&(t as u16).to_le_bytes());
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Sequential non-overlapping windows of `len` tokens (perplexity
    /// evaluation order — deterministic, covers the stream).
    pub fn windows(&self, len: usize) -> impl Iterator<Item = &[u32]> {
        self.tokens.chunks_exact(len)
    }

    /// `n` windows sampled uniformly (seeded).
    pub fn sample_windows(&self, n: usize, len: usize, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = Pcg32::seeded(seed);
        let hi = self.tokens.len().saturating_sub(len + 1);
        (0..n)
            .map(|_| {
                let s = rng.range(0, hi.max(1));
                self.tokens[s..s + len].to_vec()
            })
            .collect()
    }

    /// Unigram frequency histogram (Fig. 6 substrate).
    pub fn unigram(&self, vocab: usize) -> Vec<u64> {
        let mut counts = vec![0u64; vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dbllm_tok_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let s = TokenStream { tokens: vec![0, 1, 511, 65535, 7] };
        let p = tmp("x.tok");
        s.save(&p).unwrap();
        let back = TokenStream::load(&p).unwrap();
        assert_eq!(back.tokens, s.tokens);
    }

    #[test]
    fn windows_cover_stream() {
        let s = TokenStream { tokens: (0..100).collect() };
        let w: Vec<&[u32]> = s.windows(30).collect();
        assert_eq!(w.len(), 3);
        assert_eq!(w[0][0], 0);
        assert_eq!(w[2][29], 89);
    }

    #[test]
    fn sample_windows_deterministic() {
        let s = TokenStream { tokens: (0..1000).map(|i| i % 512).collect() };
        let a = s.sample_windows(5, 64, 42);
        let b = s.sample_windows(5, 64, 42);
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
        assert!(a.iter().all(|w| w.len() == 64));
    }

    #[test]
    fn unigram_counts() {
        let s = TokenStream { tokens: vec![1, 1, 2, 5] };
        let u = s.unigram(8);
        assert_eq!(u[1], 2);
        assert_eq!(u[5], 1);
        assert_eq!(u.iter().sum::<u64>(), 4);
    }

    #[test]
    fn rejects_odd_file() {
        let p = tmp("odd.tok");
        std::fs::write(&p, [1u8, 2, 3]).unwrap();
        assert!(TokenStream::load(&p).is_err());
    }
}
