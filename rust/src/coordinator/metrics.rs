//! Serving metrics: counters, derived gauges, and log₂-bucketed phase
//! histograms — end-to-end latency, time-to-first-token (TTFT),
//! inter-token latency (ITL), queue wait, prefill duration, and
//! scheduler tick duration — each with p50/p95/p99 estimation.
//!
//! Three export surfaces:
//! * [`Metrics::snapshot`] — the one-line human dump `db-llm serve`
//!   logs every `--metrics-interval-ms`.
//! * [`Metrics::to_json`] — machine-readable JSON (the `"cmd":"stats"`
//!   wire reply).
//! * [`Metrics::to_prometheus`] — Prometheus text exposition (one
//!   `# TYPE` line per metric family; histograms as summaries).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::Json;

/// Number of log₂ buckets per histogram: bucket `i` covers
/// `[2^i, 2^(i+1))` microseconds, so 40 buckets reach ≈ 2^40 µs
/// (~12.7 days) before the last bucket saturates.
pub const BUCKETS: usize = 40;

/// Log₂ bucket index for a microsecond value (values clamp to ≥ 1 µs,
/// so bucket 0 is "at most 1 µs").
pub fn bucket_index(us: u64) -> usize {
    let us = us.max(1);
    (63 - us.leading_zeros() as usize).min(BUCKETS - 1)
}

/// Representative microsecond value reported for bucket `i`: the
/// geometric mean `2^i · √2` of the bucket's `[2^i, 2^(i+1))` range.
///
/// The previous convention returned the bucket's *upper edge*, which
/// overstated every quantile by up to 2× (a steady 100 µs workload
/// reported p50 = 128 µs… as 256 µs).  The geometric mean is the
/// unbiased point estimate for log-uniform samples within a bucket.
pub fn bucket_value_us(i: usize) -> u64 {
    ((1u64 << i) as f64 * std::f64::consts::SQRT_2).round() as u64
}

/// Shared percentile walk over a bucket-count array: returns the
/// geometric mean of the bucket holding the `p`-quantile sample, or 0
/// when the histogram is empty.
fn percentile_of(counts: &[u64; BUCKETS], p: f64) -> u64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = (total as f64 * p).ceil().max(1.0) as u64;
    let mut acc = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        acc += c;
        if acc >= target {
            return bucket_value_us(i);
        }
    }
    bucket_value_us(BUCKETS - 1)
}

/// Plain (non-atomic) log₂ histogram for single-threaded owners.
///
/// The scheduler core records phase timings into `LocalHist`s so
/// deterministic `ManualClock` sims can assert on exact bucket
/// contents; `scheduler_loop` flushes bucket *deltas* into the shared
/// atomic [`Histogram`]s via [`Histogram::merge_delta`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LocalHist {
    /// Per-bucket sample counts (bucket `i` = `[2^i, 2^(i+1))` µs).
    pub buckets: [u64; BUCKETS],
    /// Total samples recorded.
    pub count: u64,
    /// Sum of recorded values in microseconds.
    pub sum_us: u64,
}

impl Default for LocalHist {
    fn default() -> Self {
        LocalHist { buckets: [0; BUCKETS], count: 0, sum_us: 0 }
    }
}

impl LocalHist {
    /// Record one value in microseconds (clamped to ≥ 1 µs).
    pub fn record_us(&mut self, us: u64) {
        let us = us.max(1);
        self.buckets[bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us += us;
    }

    /// Percentile estimate (bucket geometric mean; 0 when empty).
    pub fn percentile(&self, p: f64) -> u64 {
        percentile_of(&self.buckets, p)
    }

    /// Mean of recorded values in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }
}

/// Thread-safe log₂ histogram over microsecond values.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// Record one value in microseconds (clamped to ≥ 1 µs).
    pub fn record_us(&self, us: u64) {
        let us = us.max(1);
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Record one `Duration`.
    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded values in microseconds.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Mean of recorded values in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us() as f64 / n as f64
        }
    }

    /// Percentile estimate (bucket geometric mean; 0 when empty).
    pub fn percentile(&self, p: f64) -> u64 {
        let counts: [u64; BUCKETS] = std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed));
        percentile_of(&counts, p)
    }

    /// Flush the monotonic delta between two [`LocalHist`] snapshots
    /// into this shared histogram (the scheduler loop's per-tick
    /// delta-flush pattern; only touched buckets pay an atomic add).
    pub fn merge_delta(&self, cur: &LocalHist, last: &LocalHist) {
        for i in 0..BUCKETS {
            let d = cur.buckets[i] - last.buckets[i];
            if d > 0 {
                self.buckets[i].fetch_add(d, Ordering::Relaxed);
            }
        }
        if cur.count > last.count {
            self.count.fetch_add(cur.count - last.count, Ordering::Relaxed);
        }
        if cur.sum_us > last.sum_us {
            self.sum_us.fetch_add(cur.sum_us - last.sum_us, Ordering::Relaxed);
        }
    }
}

/// Thread-safe metrics registry.
pub struct Metrics {
    /// well-formed request lines received
    pub requests: AtomicU64,
    /// successful (non-error) replies sent, timeouts included
    pub responses: AtomicU64,
    /// requests answered with an error line (worker-side failures)
    pub errors: AtomicU64,
    /// requests rejected at admission because the shared queue was at
    /// `queue_cap` (backpressure, answered "server overloaded")
    pub rejected: AtomicU64,
    /// tokens decoded into successful replies
    pub tokens_out: AtomicU64,
    /// static batches collected by the worker pool
    pub batches: AtomicU64,
    /// summed static batch sizes (mean occupancy numerator)
    pub batch_occupancy_sum: AtomicU64,
    /// gauge: requests enqueued but not yet pulled into a batch
    /// (incremented by connection threads, decremented by workers)
    pub queue_depth: AtomicU64,
    /// forward steps *saved* by per-request early exit: the gap
    /// between each batch's largest token budget and the steps run
    pub early_exit_steps: AtomicU64,
    /// the static-batch stall: row-steps a finished row sat idle while
    /// its batch kept running (the lockstep waste continuous batching
    /// removes) — early-exited rows no longer masquerade as
    /// full-length decodes
    pub stalled_row_steps: AtomicU64,
    /// scheduler slot-ticks that decoded a token (occupancy numerator)
    pub slot_busy_ticks: AtomicU64,
    /// total scheduler slot-ticks: decode ticks × slots (denominator)
    pub slot_ticks: AtomicU64,
    /// scheduler admissions into a batch already mid-flight (a freed
    /// slot refilled while its neighbours kept decoding)
    pub refills: AtomicU64,
    /// requests finished by deadline expiry (partial-result replies,
    /// including requests that expired while still queued)
    pub timeouts: AtomicU64,
    /// scheduler ticks that ran at least one decode step (mean decode
    /// batch denominator)
    pub decode_batches: AtomicU64,
    /// slot-rows advanced by decode steps, summed over ticks (mean
    /// decode batch numerator)
    pub decode_batch_rows: AtomicU64,
    /// rows advanced through a multi-row fused `step_slots` call —
    /// rows whose per-layer linears shared one batched product with at
    /// least one neighbour slot
    pub fused_rows: AtomicU64,
    /// prompt tokens served from the shared prefix cache instead of
    /// being prefilled (cross-request prefix sharing, native backend)
    pub prefix_hit_tokens: AtomicU64,
    /// prompt tokens that paid prefill: uncached suffixes, plus whole
    /// prompts when the cache missed or was bypassed
    pub prefix_miss_tokens: AtomicU64,
    /// prefix-cache blocks evicted under the `--prefix-cache-mb` budget
    pub prefix_evictions: AtomicU64,
    /// poisoned prefix-lock events: a worker found the shared prefix
    /// cache's mutex poisoned and degraded to the cold (uncached) path
    /// — counted, never silently swallowed
    pub prefix_lock_poisoned: AtomicU64,
    /// trace/span ring-buffer entries overwritten before anyone read
    /// them (the bounded-ring drop counter; see `coordinator/trace.rs`)
    pub trace_dropped: AtomicU64,
    /// scheduler ticks that ran with phase timers on (the 1-in-N
    /// sampled profiling denominator)
    pub profiled_ticks: AtomicU64,
    /// summed wall nanoseconds the sampled ticks spent in queue-expiry
    /// + EDF admission (incl. prefill)
    pub sched_admit_ns: AtomicU64,
    /// summed wall nanoseconds the sampled ticks spent in the fused
    /// decode step
    pub sched_step_ns: AtomicU64,
    /// summed wall nanoseconds the sampled ticks spent expiring /
    /// finishing active slots
    pub sched_expire_ns: AtomicU64,
    /// summed wall nanoseconds of whole sampled ticks
    pub sched_tick_ns: AtomicU64,
    /// engine prefill calls timed (every prefill is timed — prefill is
    /// rare and heavy)
    pub engine_prefill_calls: AtomicU64,
    /// summed wall nanoseconds inside engine prefill (cache walk +
    /// block copy-in + suffix forward)
    pub engine_prefill_ns: AtomicU64,
    /// engine `step_slots` calls that were wall-timed (1-in-N sampled)
    pub engine_step_sampled: AtomicU64,
    /// summed wall nanoseconds of the sampled `step_slots` calls
    pub engine_step_ns: AtomicU64,
    /// scheduler-loop reply flushes timed (ticks that sent ≥ 1 reply)
    pub reply_calls: AtomicU64,
    /// summed wall nanoseconds rendering + sending those replies
    pub reply_ns: AtomicU64,
    /// scheduler-worker panics caught by the supervisor (each one
    /// answered its in-flight requests with error replies)
    pub worker_panics: AtomicU64,
    /// supervised workers rebuilt after a panic (`respawns <
    /// worker_panics` means a worker retired: respawn cap hit or
    /// engine recovery failed)
    pub respawns: AtomicU64,
    /// engine slots quarantined during panic recovery (KV state
    /// dropped, pool blocks released, prefix pins unpinned)
    pub quarantined_slots: AtomicU64,
    /// poisoned shared-queue-lock recoveries absorbed by the
    /// supervised worker pool (mirrors `prefix_lock_poisoned`)
    pub queue_lock_poisoned: AtomicU64,
    /// request lines rejected for exceeding `--max-line-bytes` (the
    /// connection is closed after the error reply)
    pub oversize_lines: AtomicU64,
    /// connections closed by the idle reaper (`--idle-timeout-ms`)
    pub conn_reaped: AtomicU64,
    /// requests shed by the deadline-aware overload policy before
    /// queueing (each reply carried a `retry_after_ms` hint)
    pub shed_requests: AtomicU64,
    /// draft tokens the speculative student proposed (k per eligible
    /// slot per speculative tick)
    pub spec_drafted: AtomicU64,
    /// draft tokens the teacher verify pass accepted — each one is a
    /// dense teacher forward the plain path would have paid, so this
    /// *is* the teacher-forwards-saved figure
    pub spec_accepted: AtomicU64,
    /// draft tokens the verify pass rejected (their KV rows were
    /// rolled back); `spec_drafted == spec_accepted + spec_rejected`
    pub spec_rejected: AtomicU64,
    /// bonus/correction tokens emitted from the verify row after the
    /// accepted prefix (one per verified group — speculative progress
    /// is never slower than one token per tick)
    pub spec_bonus: AtomicU64,
    /// batched teacher verify passes run (one per tick with ≥ 1
    /// drafting slot)
    pub spec_verify_passes: AtomicU64,
    /// KV cache positions discarded by accept-prefix rollback
    /// (block-table truncation — zero row copies)
    pub spec_rolled_back_rows: AtomicU64,
    /// speculative-path rows that decoded plain because the slot's
    /// chronology crossed the window gate (`T + k + 1 > window`)
    pub spec_fallback_rows: AtomicU64,
    /// end-to-end request latency (receipt → reply rendered), µs
    pub latency: Histogram,
    /// time-to-first-token: queue wait + prefill (the first token is
    /// sampled from prefill logits), µs
    pub ttft: Histogram,
    /// inter-token latency: gap between consecutive decoded tokens of
    /// one request, µs
    pub itl: Histogram,
    /// queue wait: request arrival (incl. upstream shared-queue time)
    /// → slot admission, µs
    pub queue_wait: Histogram,
    /// prefill duration (wall time inside `prefill_slot`), µs
    pub prefill: Histogram,
    /// scheduler tick duration (sampled ticks only), µs
    pub tick: Histogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            tokens_out: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_occupancy_sum: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            early_exit_steps: AtomicU64::new(0),
            stalled_row_steps: AtomicU64::new(0),
            slot_busy_ticks: AtomicU64::new(0),
            slot_ticks: AtomicU64::new(0),
            refills: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            decode_batches: AtomicU64::new(0),
            decode_batch_rows: AtomicU64::new(0),
            fused_rows: AtomicU64::new(0),
            prefix_hit_tokens: AtomicU64::new(0),
            prefix_miss_tokens: AtomicU64::new(0),
            prefix_evictions: AtomicU64::new(0),
            prefix_lock_poisoned: AtomicU64::new(0),
            trace_dropped: AtomicU64::new(0),
            profiled_ticks: AtomicU64::new(0),
            sched_admit_ns: AtomicU64::new(0),
            sched_step_ns: AtomicU64::new(0),
            sched_expire_ns: AtomicU64::new(0),
            sched_tick_ns: AtomicU64::new(0),
            engine_prefill_calls: AtomicU64::new(0),
            engine_prefill_ns: AtomicU64::new(0),
            engine_step_sampled: AtomicU64::new(0),
            engine_step_ns: AtomicU64::new(0),
            reply_calls: AtomicU64::new(0),
            reply_ns: AtomicU64::new(0),
            worker_panics: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            quarantined_slots: AtomicU64::new(0),
            queue_lock_poisoned: AtomicU64::new(0),
            oversize_lines: AtomicU64::new(0),
            conn_reaped: AtomicU64::new(0),
            shed_requests: AtomicU64::new(0),
            spec_drafted: AtomicU64::new(0),
            spec_accepted: AtomicU64::new(0),
            spec_rejected: AtomicU64::new(0),
            spec_bonus: AtomicU64::new(0),
            spec_verify_passes: AtomicU64::new(0),
            spec_rolled_back_rows: AtomicU64::new(0),
            spec_fallback_rows: AtomicU64::new(0),
            latency: Histogram::default(),
            ttft: Histogram::default(),
            itl: Histogram::default(),
            queue_wait: Histogram::default(),
            prefill: Histogram::default(),
            tick: Histogram::default(),
        }
    }
}

impl Metrics {
    /// Record one request's end-to-end latency into the log₂ histogram.
    pub fn record_latency(&self, d: Duration) {
        self.latency.record(d);
    }

    /// Record one collected static batch and its row count.
    pub fn record_batch(&self, occupancy: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_occupancy_sum.fetch_add(occupancy as u64, Ordering::Relaxed);
    }

    /// Approximate end-to-end latency percentile in microseconds
    /// (geometric mean of the quantile's log₂ bucket; 0 when empty).
    pub fn latency_percentile(&self, p: f64) -> u64 {
        self.latency.percentile(p)
    }

    /// Mean rows per collected static batch (0 before any batch).
    pub fn mean_batch_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_occupancy_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Fraction of scheduler slot-ticks that decoded a token (0 when
    /// the continuous scheduler never ran).
    pub fn slot_occupancy(&self) -> f64 {
        let total = self.slot_ticks.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        self.slot_busy_ticks.load(Ordering::Relaxed) as f64 / total as f64
    }

    /// Mean rows per decode step tick — how many slots each tick's one
    /// fused pass actually advanced (0 when the scheduler never ran).
    pub fn mean_decode_batch(&self) -> f64 {
        let b = self.decode_batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.decode_batch_rows.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Fraction of prompt tokens served from the shared prefix cache
    /// (0 when the native scheduler never admitted anything, or prefix
    /// sharing is off).  `hit / (hit + miss)`: a value of 0.5 means
    /// half of all prompt-token work was skipped.
    pub fn prefix_hit_rate(&self) -> f64 {
        let hit = self.prefix_hit_tokens.load(Ordering::Relaxed);
        let miss = self.prefix_miss_tokens.load(Ordering::Relaxed);
        if hit + miss == 0 {
            return 0.0;
        }
        hit as f64 / (hit + miss) as f64
    }

    /// Fraction of speculative draft tokens the teacher accepted
    /// (`accepted / drafted`; 0 before any draft).  The speedup lever:
    /// each speculative tick emits `rate·k + 1` tokens for one batched
    /// teacher pass, so a rate near 1 means the student (the 2-bit FDB
    /// model) is a faithful stand-in and dense forwards drop ≈ `k/(k+1)`;
    /// a rate near 0 means speculation is pure overhead — lower `k` or
    /// improve the student (e.g. DAD fine-tuning).
    pub fn spec_accept_rate(&self) -> f64 {
        let drafted = self.spec_drafted.load(Ordering::Relaxed);
        if drafted == 0 {
            return 0.0;
        }
        self.spec_accepted.load(Ordering::Relaxed) as f64 / drafted as f64
    }

    /// One-line human-readable dump of every counter plus per-phase
    /// p50/p95/p99 (the `[metrics]` line `db-llm serve` prints every
    /// `--metrics-interval-ms`).
    pub fn snapshot(&self) -> String {
        let q3 = |h: &Histogram| (h.percentile(0.50), h.percentile(0.95), h.percentile(0.99));
        let (e50, e95, e99) = q3(&self.latency);
        let (t50, t95, t99) = q3(&self.ttft);
        let (i50, i95, i99) = q3(&self.itl);
        let (q50, q95, q99) = q3(&self.queue_wait);
        let (f50, f95, f99) = q3(&self.prefill);
        let (k50, k95, k99) = q3(&self.tick);
        format!(
            "req={} resp={} err={} rejected={} tokens={} batches={} occ={:.2} queue={} \
             saved_steps={} stalled={} slot_occ={:.2} refills={} timeouts={} \
             fused_rows={} decode_batch={:.2} prefix_hit={} prefix_miss={} \
             prefix_hit_rate={:.2} prefix_evict={} prefix_poisoned={} \
             spec_drafted={} spec_accepted={} spec_accept_rate={:.2} \
             spec_bonus={} spec_fallback={} spec_rolled_back={} \
             panics={} respawns={} quarantined={} queue_poisoned={} \
             oversize={} reaped={} shed={} \
             p50={}us p95={}us p99={}us \
             ttft_p50={}us ttft_p95={}us ttft_p99={}us \
             itl_p50={}us itl_p95={}us itl_p99={}us \
             qwait_p50={}us qwait_p95={}us qwait_p99={}us \
             prefill_p50={}us prefill_p95={}us prefill_p99={}us \
             tick_p50={}us tick_p95={}us tick_p99={}us trace_dropped={}",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.tokens_out.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.queue_depth.load(Ordering::Relaxed),
            self.early_exit_steps.load(Ordering::Relaxed),
            self.stalled_row_steps.load(Ordering::Relaxed),
            self.slot_occupancy(),
            self.refills.load(Ordering::Relaxed),
            self.timeouts.load(Ordering::Relaxed),
            self.fused_rows.load(Ordering::Relaxed),
            self.mean_decode_batch(),
            self.prefix_hit_tokens.load(Ordering::Relaxed),
            self.prefix_miss_tokens.load(Ordering::Relaxed),
            self.prefix_hit_rate(),
            self.prefix_evictions.load(Ordering::Relaxed),
            self.prefix_lock_poisoned.load(Ordering::Relaxed),
            self.spec_drafted.load(Ordering::Relaxed),
            self.spec_accepted.load(Ordering::Relaxed),
            self.spec_accept_rate(),
            self.spec_bonus.load(Ordering::Relaxed),
            self.spec_fallback_rows.load(Ordering::Relaxed),
            self.spec_rolled_back_rows.load(Ordering::Relaxed),
            self.worker_panics.load(Ordering::Relaxed),
            self.respawns.load(Ordering::Relaxed),
            self.quarantined_slots.load(Ordering::Relaxed),
            self.queue_lock_poisoned.load(Ordering::Relaxed),
            self.oversize_lines.load(Ordering::Relaxed),
            self.conn_reaped.load(Ordering::Relaxed),
            self.shed_requests.load(Ordering::Relaxed),
            e50,
            e95,
            e99,
            t50,
            t95,
            t99,
            i50,
            i95,
            i99,
            q50,
            q95,
            q99,
            f50,
            f95,
            f99,
            k50,
            k95,
            k99,
            self.trace_dropped.load(Ordering::Relaxed),
        )
    }

    /// Machine-readable export: every counter, the derived gauges
    /// (`prefix_hit_rate`, `mean_decode_batch`, `slot_occ`, …) as
    /// first-class values, each histogram as
    /// `{count, mean_us, p50_us, p95_us, p99_us}`, and the sampled
    /// profiling breakdown.  This is the `"stats"` object in the
    /// `{"cmd":"stats"}` wire reply.
    pub fn to_json(&self) -> Json {
        let c = |a: &AtomicU64| Json::num(a.load(Ordering::Relaxed) as f64);
        let hist = |h: &Histogram| {
            Json::obj(vec![
                ("count", Json::num(h.count() as f64)),
                ("mean_us", Json::num(h.mean_us())),
                ("p50_us", Json::num(h.percentile(0.50) as f64)),
                ("p95_us", Json::num(h.percentile(0.95) as f64)),
                ("p99_us", Json::num(h.percentile(0.99) as f64)),
            ])
        };
        Json::obj(vec![
            (
                "counters",
                Json::obj(vec![
                    ("requests", c(&self.requests)),
                    ("responses", c(&self.responses)),
                    ("errors", c(&self.errors)),
                    ("rejected", c(&self.rejected)),
                    ("tokens_out", c(&self.tokens_out)),
                    ("batches", c(&self.batches)),
                    ("batch_occupancy_sum", c(&self.batch_occupancy_sum)),
                    ("early_exit_steps", c(&self.early_exit_steps)),
                    ("stalled_row_steps", c(&self.stalled_row_steps)),
                    ("slot_busy_ticks", c(&self.slot_busy_ticks)),
                    ("slot_ticks", c(&self.slot_ticks)),
                    ("refills", c(&self.refills)),
                    ("timeouts", c(&self.timeouts)),
                    ("decode_batches", c(&self.decode_batches)),
                    ("decode_batch_rows", c(&self.decode_batch_rows)),
                    ("fused_rows", c(&self.fused_rows)),
                    ("prefix_hit_tokens", c(&self.prefix_hit_tokens)),
                    ("prefix_miss_tokens", c(&self.prefix_miss_tokens)),
                    ("prefix_evictions", c(&self.prefix_evictions)),
                    ("prefix_lock_poisoned", c(&self.prefix_lock_poisoned)),
                    ("spec_drafted", c(&self.spec_drafted)),
                    ("spec_accepted", c(&self.spec_accepted)),
                    ("spec_rejected", c(&self.spec_rejected)),
                    ("spec_bonus", c(&self.spec_bonus)),
                    ("spec_verify_passes", c(&self.spec_verify_passes)),
                    ("spec_rolled_back_rows", c(&self.spec_rolled_back_rows)),
                    ("spec_fallback_rows", c(&self.spec_fallback_rows)),
                    ("trace_dropped", c(&self.trace_dropped)),
                    ("worker_panics", c(&self.worker_panics)),
                    ("respawns", c(&self.respawns)),
                    ("quarantined_slots", c(&self.quarantined_slots)),
                    ("queue_lock_poisoned", c(&self.queue_lock_poisoned)),
                    ("oversize_lines", c(&self.oversize_lines)),
                    ("conn_reaped", c(&self.conn_reaped)),
                    ("shed_requests", c(&self.shed_requests)),
                ]),
            ),
            (
                "gauges",
                Json::obj(vec![
                    ("queue_depth", c(&self.queue_depth)),
                    ("slot_occ", Json::num(self.slot_occupancy())),
                    ("prefix_hit_rate", Json::num(self.prefix_hit_rate())),
                    ("spec_accept_rate", Json::num(self.spec_accept_rate())),
                    ("mean_decode_batch", Json::num(self.mean_decode_batch())),
                    ("mean_batch_occupancy", Json::num(self.mean_batch_occupancy())),
                ]),
            ),
            (
                "histograms",
                Json::obj(vec![
                    ("latency_us", hist(&self.latency)),
                    ("ttft_us", hist(&self.ttft)),
                    ("itl_us", hist(&self.itl)),
                    ("queue_wait_us", hist(&self.queue_wait)),
                    ("prefill_us", hist(&self.prefill)),
                    ("tick_us", hist(&self.tick)),
                ]),
            ),
            (
                "profile",
                Json::obj(vec![
                    ("profiled_ticks", c(&self.profiled_ticks)),
                    ("sched_admit_ns", c(&self.sched_admit_ns)),
                    ("sched_step_ns", c(&self.sched_step_ns)),
                    ("sched_expire_ns", c(&self.sched_expire_ns)),
                    ("sched_tick_ns", c(&self.sched_tick_ns)),
                    ("engine_prefill_calls", c(&self.engine_prefill_calls)),
                    ("engine_prefill_ns", c(&self.engine_prefill_ns)),
                    ("engine_step_sampled", c(&self.engine_step_sampled)),
                    ("engine_step_ns", c(&self.engine_step_ns)),
                    ("reply_calls", c(&self.reply_calls)),
                    ("reply_ns", c(&self.reply_ns)),
                ]),
            ),
        ])
    }

    /// Prometheus text-exposition rendering: one `# TYPE` line per
    /// metric family; counters carry the `_total` suffix, derived
    /// ratios are gauges, histograms are summaries with
    /// `quantile="0.5|0.95|0.99"` labels plus `_sum`/`_count`.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let l = |a: &AtomicU64| a.load(Ordering::Relaxed);
        for (name, v) in [
            ("requests", l(&self.requests)),
            ("responses", l(&self.responses)),
            ("errors", l(&self.errors)),
            ("rejected", l(&self.rejected)),
            ("tokens_out", l(&self.tokens_out)),
            ("batches", l(&self.batches)),
            ("early_exit_steps", l(&self.early_exit_steps)),
            ("stalled_row_steps", l(&self.stalled_row_steps)),
            ("slot_busy_ticks", l(&self.slot_busy_ticks)),
            ("slot_ticks", l(&self.slot_ticks)),
            ("refills", l(&self.refills)),
            ("timeouts", l(&self.timeouts)),
            ("decode_batches", l(&self.decode_batches)),
            ("decode_batch_rows", l(&self.decode_batch_rows)),
            ("fused_rows", l(&self.fused_rows)),
            ("prefix_hit_tokens", l(&self.prefix_hit_tokens)),
            ("prefix_miss_tokens", l(&self.prefix_miss_tokens)),
            ("prefix_evictions", l(&self.prefix_evictions)),
            ("prefix_lock_poisoned", l(&self.prefix_lock_poisoned)),
            ("spec_drafted", l(&self.spec_drafted)),
            ("spec_accepted", l(&self.spec_accepted)),
            ("spec_rejected", l(&self.spec_rejected)),
            ("spec_bonus", l(&self.spec_bonus)),
            ("spec_verify_passes", l(&self.spec_verify_passes)),
            ("spec_rolled_back_rows", l(&self.spec_rolled_back_rows)),
            ("spec_fallback_rows", l(&self.spec_fallback_rows)),
            ("trace_dropped", l(&self.trace_dropped)),
            ("profiled_ticks", l(&self.profiled_ticks)),
            ("sched_admit_ns", l(&self.sched_admit_ns)),
            ("sched_step_ns", l(&self.sched_step_ns)),
            ("sched_expire_ns", l(&self.sched_expire_ns)),
            ("sched_tick_ns", l(&self.sched_tick_ns)),
            ("engine_prefill_calls", l(&self.engine_prefill_calls)),
            ("engine_prefill_ns", l(&self.engine_prefill_ns)),
            ("engine_step_sampled", l(&self.engine_step_sampled)),
            ("engine_step_ns", l(&self.engine_step_ns)),
            ("reply_calls", l(&self.reply_calls)),
            ("reply_ns", l(&self.reply_ns)),
            ("worker_panics", l(&self.worker_panics)),
            ("respawns", l(&self.respawns)),
            ("quarantined_slots", l(&self.quarantined_slots)),
            ("queue_lock_poisoned", l(&self.queue_lock_poisoned)),
            ("oversize_lines", l(&self.oversize_lines)),
            ("conn_reaped", l(&self.conn_reaped)),
            ("shed_requests", l(&self.shed_requests)),
        ] {
            prom_counter(&mut out, name, v);
        }
        prom_gauge(&mut out, "queue_depth", l(&self.queue_depth) as f64);
        prom_gauge(&mut out, "slot_occ", self.slot_occupancy());
        prom_gauge(&mut out, "prefix_hit_rate", self.prefix_hit_rate());
        prom_gauge(&mut out, "spec_accept_rate", self.spec_accept_rate());
        prom_gauge(&mut out, "mean_decode_batch", self.mean_decode_batch());
        prom_gauge(&mut out, "mean_batch_occupancy", self.mean_batch_occupancy());
        prom_summary(&mut out, "latency_us", &self.latency);
        prom_summary(&mut out, "ttft_us", &self.ttft);
        prom_summary(&mut out, "itl_us", &self.itl);
        prom_summary(&mut out, "queue_wait_us", &self.queue_wait);
        prom_summary(&mut out, "prefill_us", &self.prefill);
        prom_summary(&mut out, "tick_us", &self.tick);
        out
    }
}

fn prom_counter(out: &mut String, name: &str, v: u64) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# TYPE dbllm_{name}_total counter");
    let _ = writeln!(out, "dbllm_{name}_total {v}");
}

fn prom_gauge(out: &mut String, name: &str, v: f64) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# TYPE dbllm_{name} gauge");
    let _ = writeln!(out, "dbllm_{name} {v}");
}

fn prom_summary(out: &mut String, name: &str, h: &Histogram) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# TYPE dbllm_{name} summary");
    for (q, label) in [(0.50, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
        let _ = writeln!(out, "dbllm_{name}{{quantile=\"{label}\"}} {}", h.percentile(q));
    }
    let _ = writeln!(out, "dbllm_{name}_sum {}", h.sum_us());
    let _ = writeln!(out, "dbllm_{name}_count {}", h.count());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_monotonic() {
        let m = Metrics::default();
        for us in [100u64, 200, 400, 800, 1600, 3200] {
            m.record_latency(Duration::from_micros(us));
        }
        let p50 = m.latency_percentile(0.5);
        let p99 = m.latency_percentile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= 128 && p99 <= 8192, "{p50} {p99}");
    }

    #[test]
    fn percentile_reports_bucket_geometric_mean() {
        // The old convention returned the bucket's upper edge: a
        // steady 100 µs stream landed in bucket [64,128) and reported
        // p50 = 128 — and a 65 µs stream would too, overstating ~2×.
        // The geometric mean of bucket [64,128) is 64·√2 ≈ 91.
        let m = Metrics::default();
        for _ in 0..10 {
            m.record_latency(Duration::from_micros(100));
        }
        assert_eq!(m.latency_percentile(0.5), 91, "geometric mean of [64,128)");
        assert_eq!(m.latency_percentile(0.99), 91);

        // Mixed stream: 100,200,400,800,1600,3200 µs (one each).
        let m = Metrics::default();
        for us in [100u64, 200, 400, 800, 1600, 3200] {
            m.record_latency(Duration::from_micros(us));
        }
        // p50 target = 3rd sample → 400 µs → bucket [256,512) → 362.
        assert_eq!(m.latency_percentile(0.5), 362);
        // p99 target = 6th sample → 3200 µs → bucket [2048,4096) → 2896.
        assert_eq!(m.latency_percentile(0.99), 2896);
    }

    #[test]
    fn local_hist_matches_atomic_after_merge() {
        let mut local = LocalHist::default();
        let shared = Histogram::default();
        let mut last = LocalHist::default();
        for us in [5u64, 50, 500, 5000] {
            local.record_us(us);
            shared.merge_delta(&local, &last);
            last = local;
        }
        assert_eq!(shared.count(), 4);
        assert_eq!(shared.sum_us(), local.sum_us);
        for p in [0.5, 0.95, 0.99] {
            assert_eq!(shared.percentile(p), local.percentile(p));
        }
    }

    #[test]
    fn occupancy_mean() {
        let m = Metrics::default();
        m.record_batch(4);
        m.record_batch(2);
        assert!((m.mean_batch_occupancy() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let m = Metrics::default();
        assert_eq!(m.latency_percentile(0.99), 0);
        assert!(m.snapshot().contains("req=0"));
        assert!(m.snapshot().contains("queue=0"));
        assert!(m.snapshot().contains("saved_steps=0"));
        assert!(m.snapshot().contains("stalled=0"));
        assert!(m.snapshot().contains("slot_occ=0.00"));
        assert!(m.snapshot().contains("timeouts=0"));
        assert!(m.snapshot().contains("fused_rows=0"));
        assert!(m.snapshot().contains("decode_batch=0.00"));
        assert!(m.snapshot().contains("ttft_p50=0us"));
        assert!(m.snapshot().contains("itl_p99=0us"));
        assert!(m.snapshot().contains("trace_dropped=0"));
        assert_eq!(m.slot_occupancy(), 0.0, "no scheduler ticks -> 0, not NaN");
        assert_eq!(m.mean_decode_batch(), 0.0, "no decode ticks -> 0, not NaN");
    }

    #[test]
    fn scheduler_counters_surface() {
        let m = Metrics::default();
        // 10 decode ticks on 4 slots, 29 of 40 slot-ticks busy
        m.slot_ticks.fetch_add(40, Ordering::Relaxed);
        m.slot_busy_ticks.fetch_add(29, Ordering::Relaxed);
        m.refills.fetch_add(3, Ordering::Relaxed);
        m.timeouts.fetch_add(2, Ordering::Relaxed);
        m.stalled_row_steps.fetch_add(11, Ordering::Relaxed);
        assert!((m.slot_occupancy() - 0.725).abs() < 1e-12);
        let s = m.snapshot();
        assert!(s.contains("slot_occ=0.72"), "{s}");
        assert!(s.contains("refills=3"), "{s}");
        assert!(s.contains("timeouts=2"), "{s}");
        assert!(s.contains("stalled=11"), "{s}");
    }

    #[test]
    fn fused_decode_counters_surface() {
        let m = Metrics::default();
        // 5 decode-step ticks advanced 15 rows, 12 of them in
        // multi-row fused calls
        m.decode_batches.fetch_add(5, Ordering::Relaxed);
        m.decode_batch_rows.fetch_add(15, Ordering::Relaxed);
        m.fused_rows.fetch_add(12, Ordering::Relaxed);
        assert!((m.mean_decode_batch() - 3.0).abs() < 1e-12);
        let s = m.snapshot();
        assert!(s.contains("fused_rows=12"), "{s}");
        assert!(s.contains("decode_batch=3.00"), "{s}");
    }

    #[test]
    fn prefix_cache_counters_surface() {
        let m = Metrics::default();
        assert_eq!(m.prefix_hit_rate(), 0.0, "no prefix traffic -> 0, not NaN");
        // 30 of 40 prompt tokens served from the cache, 2 evictions
        m.prefix_hit_tokens.fetch_add(30, Ordering::Relaxed);
        m.prefix_miss_tokens.fetch_add(10, Ordering::Relaxed);
        m.prefix_evictions.fetch_add(2, Ordering::Relaxed);
        m.prefix_lock_poisoned.fetch_add(1, Ordering::Relaxed);
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
        let s = m.snapshot();
        assert!(s.contains("prefix_hit=30"), "{s}");
        assert!(s.contains("prefix_miss=10"), "{s}");
        assert!(s.contains("prefix_hit_rate=0.75"), "{s}");
        assert!(s.contains("prefix_evict=2"), "{s}");
        assert!(s.contains("prefix_poisoned=1"), "{s}");
    }

    #[test]
    fn supervision_counters_surface() {
        let m = Metrics::default();
        m.worker_panics.fetch_add(3, Ordering::Relaxed);
        m.respawns.fetch_add(2, Ordering::Relaxed);
        m.quarantined_slots.fetch_add(5, Ordering::Relaxed);
        m.queue_lock_poisoned.fetch_add(1, Ordering::Relaxed);
        m.oversize_lines.fetch_add(4, Ordering::Relaxed);
        m.conn_reaped.fetch_add(6, Ordering::Relaxed);
        m.shed_requests.fetch_add(7, Ordering::Relaxed);
        let s = m.snapshot();
        assert!(s.contains("panics=3"), "{s}");
        assert!(s.contains("respawns=2"), "{s}");
        assert!(s.contains("quarantined=5"), "{s}");
        assert!(s.contains("queue_poisoned=1"), "{s}");
        assert!(s.contains("oversize=4"), "{s}");
        assert!(s.contains("reaped=6"), "{s}");
        assert!(s.contains("shed=7"), "{s}");
        let prom = m.to_prometheus();
        assert!(prom.contains("dbllm_worker_panics_total 3"), "{prom}");
        assert!(prom.contains("dbllm_respawns_total 2"), "{prom}");
        assert!(prom.contains("dbllm_quarantined_slots_total 5"), "{prom}");
        assert!(prom.contains("dbllm_queue_lock_poisoned_total 1"), "{prom}");
        assert!(prom.contains("dbllm_oversize_lines_total 4"), "{prom}");
        assert!(prom.contains("dbllm_conn_reaped_total 6"), "{prom}");
        assert!(prom.contains("dbllm_shed_requests_total 7"), "{prom}");
        let json = m.to_json().to_string();
        assert!(json.contains("\"worker_panics\":3"), "{json}");
        assert!(json.contains("\"shed_requests\":7"), "{json}");
    }

    #[test]
    fn speculative_counters_surface() {
        let m = Metrics::default();
        assert_eq!(m.spec_accept_rate(), 0.0, "no drafts -> 0, not NaN");
        // 20 drafts: 15 accepted, 5 rejected, 6 verify passes each
        // emitting a bonus row, 2 window-gated fallbacks, 5 rolled-back
        // teacher rows
        m.spec_drafted.fetch_add(20, Ordering::Relaxed);
        m.spec_accepted.fetch_add(15, Ordering::Relaxed);
        m.spec_rejected.fetch_add(5, Ordering::Relaxed);
        m.spec_bonus.fetch_add(6, Ordering::Relaxed);
        m.spec_verify_passes.fetch_add(6, Ordering::Relaxed);
        m.spec_rolled_back_rows.fetch_add(5, Ordering::Relaxed);
        m.spec_fallback_rows.fetch_add(2, Ordering::Relaxed);
        assert!((m.spec_accept_rate() - 0.75).abs() < 1e-12);
        let s = m.snapshot();
        assert!(s.contains("spec_drafted=20"), "{s}");
        assert!(s.contains("spec_accepted=15"), "{s}");
        assert!(s.contains("spec_accept_rate=0.75"), "{s}");
        assert!(s.contains("spec_bonus=6"), "{s}");
        assert!(s.contains("spec_fallback=2"), "{s}");
        assert!(s.contains("spec_rolled_back=5"), "{s}");
        let json = m.to_json().to_string();
        assert!(json.contains("\"spec_drafted\":20"), "{json}");
        assert!(json.contains("\"spec_verify_passes\":6"), "{json}");
        assert!(json.contains("\"spec_accept_rate\":0.75"), "{json}");
        let prom = m.to_prometheus();
        assert!(prom.contains("dbllm_spec_drafted_total 20"), "{prom}");
        assert!(prom.contains("dbllm_spec_accepted_total 15"), "{prom}");
        assert!(prom.contains("# TYPE dbllm_spec_accept_rate gauge"), "{prom}");
        assert!(prom.contains("dbllm_spec_accept_rate 0.75"), "{prom}");
    }

    #[test]
    fn queue_and_early_exit_counters_surface() {
        let m = Metrics::default();
        m.queue_depth.fetch_add(3, Ordering::Relaxed);
        m.queue_depth.fetch_sub(1, Ordering::Relaxed);
        m.early_exit_steps.fetch_add(7, Ordering::Relaxed);
        m.errors.fetch_add(1, Ordering::Relaxed);
        m.rejected.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert!(s.contains("queue=2"), "{s}");
        assert!(s.contains("saved_steps=7"), "{s}");
        assert!(s.contains("err=1"), "{s}");
        assert!(s.contains("rejected=2"), "{s}");
    }

    #[test]
    fn json_export_roundtrips_with_first_class_gauges() {
        let m = Metrics::default();
        m.requests.fetch_add(7, Ordering::Relaxed);
        m.prefix_hit_tokens.fetch_add(30, Ordering::Relaxed);
        m.prefix_miss_tokens.fetch_add(10, Ordering::Relaxed);
        m.decode_batches.fetch_add(5, Ordering::Relaxed);
        m.decode_batch_rows.fetch_add(15, Ordering::Relaxed);
        m.slot_ticks.fetch_add(40, Ordering::Relaxed);
        m.slot_busy_ticks.fetch_add(29, Ordering::Relaxed);
        m.ttft.record_us(1000);
        let parsed = Json::parse(&m.to_json().to_string()).unwrap();
        let gauges = parsed.get("gauges").unwrap();
        assert!((gauges.get("prefix_hit_rate").unwrap().as_f64().unwrap() - 0.75).abs() < 1e-12);
        assert!((gauges.get("mean_decode_batch").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-12);
        assert!((gauges.get("slot_occ").unwrap().as_f64().unwrap() - 0.725).abs() < 1e-12);
        let counters = parsed.get("counters").unwrap();
        assert_eq!(counters.get("requests").unwrap().as_f64().unwrap(), 7.0);
        let ttft = parsed.get("histograms").unwrap().get("ttft_us").unwrap();
        assert_eq!(ttft.get("count").unwrap().as_f64().unwrap(), 1.0);
        // 1000 µs → bucket [512,1024) → geometric mean 724
        assert_eq!(ttft.get("p50_us").unwrap().as_f64().unwrap(), 724.0);
    }

    #[test]
    fn prometheus_has_one_type_line_per_family() {
        let m = Metrics::default();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.ttft.record_us(1000);
        let text = m.to_prometheus();
        let mut families = std::collections::BTreeSet::new();
        for line in text.lines().filter(|l| l.starts_with("# TYPE ")) {
            let name = line.split_whitespace().nth(2).unwrap();
            assert!(families.insert(name.to_string()), "duplicate # TYPE for {name}");
        }
        // every sample line's family (strip labels and summary
        // suffixes) must have exactly one TYPE declaration
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let name = line.split(['{', ' ']).next().unwrap();
            let base = name.strip_suffix("_sum").unwrap_or(name);
            let base = base.strip_suffix("_count").unwrap_or(base);
            assert!(
                families.contains(base) || families.contains(name),
                "sample {name} has no # TYPE line"
            );
        }
        assert!(text.contains("# TYPE dbllm_ttft_us summary"));
        assert!(text.contains("dbllm_ttft_us{quantile=\"0.5\"} 724"));
        assert!(text.contains("dbllm_requests_total 3"));
        assert!(text.contains("# TYPE dbllm_prefix_hit_rate gauge"));
    }
}
