//! Serving metrics: counters + log-bucketed latency histogram with
//! p50/p95/p99 estimation, printable as a one-line snapshot.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const BUCKETS: usize = 40;

/// Thread-safe metrics registry.
pub struct Metrics {
    /// well-formed request lines received
    pub requests: AtomicU64,
    /// successful (non-error) replies sent, timeouts included
    pub responses: AtomicU64,
    /// requests answered with an error line (worker-side failures)
    pub errors: AtomicU64,
    /// requests rejected at admission because the shared queue was at
    /// `queue_cap` (backpressure, answered "server overloaded")
    pub rejected: AtomicU64,
    /// tokens decoded into successful replies
    pub tokens_out: AtomicU64,
    /// static batches collected by the worker pool
    pub batches: AtomicU64,
    /// summed static batch sizes (mean occupancy numerator)
    pub batch_occupancy_sum: AtomicU64,
    /// gauge: requests enqueued but not yet pulled into a batch
    /// (incremented by connection threads, decremented by workers)
    pub queue_depth: AtomicU64,
    /// forward steps *saved* by per-request early exit: the gap
    /// between each batch's largest token budget and the steps run
    pub early_exit_steps: AtomicU64,
    /// the static-batch stall: row-steps a finished row sat idle while
    /// its batch kept running (the lockstep waste continuous batching
    /// removes) — early-exited rows no longer masquerade as
    /// full-length decodes
    pub stalled_row_steps: AtomicU64,
    /// scheduler slot-ticks that decoded a token (occupancy numerator)
    pub slot_busy_ticks: AtomicU64,
    /// total scheduler slot-ticks: decode ticks × slots (denominator)
    pub slot_ticks: AtomicU64,
    /// scheduler admissions into a batch already mid-flight (a freed
    /// slot refilled while its neighbours kept decoding)
    pub refills: AtomicU64,
    /// requests finished by deadline expiry (partial-result replies,
    /// including requests that expired while still queued)
    pub timeouts: AtomicU64,
    /// scheduler ticks that ran at least one decode step (mean decode
    /// batch denominator)
    pub decode_batches: AtomicU64,
    /// slot-rows advanced by decode steps, summed over ticks (mean
    /// decode batch numerator)
    pub decode_batch_rows: AtomicU64,
    /// rows advanced through a multi-row fused `step_slots` call —
    /// rows whose per-layer linears shared one batched product with at
    /// least one neighbour slot
    pub fused_rows: AtomicU64,
    /// prompt tokens served from the shared prefix cache instead of
    /// being prefilled (cross-request prefix sharing, native backend)
    pub prefix_hit_tokens: AtomicU64,
    /// prompt tokens that paid prefill: uncached suffixes, plus whole
    /// prompts when the cache missed or was bypassed
    pub prefix_miss_tokens: AtomicU64,
    /// prefix-cache blocks evicted under the `--prefix-cache-mb` budget
    pub prefix_evictions: AtomicU64,
    /// poisoned prefix-lock events: a worker found the shared prefix
    /// cache's mutex poisoned and degraded to the cold (uncached) path
    /// — counted, never silently swallowed
    pub prefix_lock_poisoned: AtomicU64,
    /// log₂-bucketed latencies, bucket i = [2^i, 2^(i+1)) microseconds
    lat_buckets: [AtomicU64; BUCKETS],
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            tokens_out: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_occupancy_sum: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            early_exit_steps: AtomicU64::new(0),
            stalled_row_steps: AtomicU64::new(0),
            slot_busy_ticks: AtomicU64::new(0),
            slot_ticks: AtomicU64::new(0),
            refills: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            decode_batches: AtomicU64::new(0),
            decode_batch_rows: AtomicU64::new(0),
            fused_rows: AtomicU64::new(0),
            prefix_hit_tokens: AtomicU64::new(0),
            prefix_miss_tokens: AtomicU64::new(0),
            prefix_evictions: AtomicU64::new(0),
            prefix_lock_poisoned: AtomicU64::new(0),
            lat_buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl Metrics {
    /// Record one request's end-to-end latency into the log₂ histogram.
    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.lat_buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one collected static batch and its row count.
    pub fn record_batch(&self, occupancy: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_occupancy_sum.fetch_add(occupancy as u64, Ordering::Relaxed);
    }

    /// Approximate latency percentile (upper bucket edge, microseconds).
    pub fn latency_percentile(&self, p: f64) -> u64 {
        let counts: Vec<u64> = self.lat_buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }

    /// Mean rows per collected static batch (0 before any batch).
    pub fn mean_batch_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_occupancy_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Fraction of scheduler slot-ticks that decoded a token (0 when
    /// the continuous scheduler never ran).
    pub fn slot_occupancy(&self) -> f64 {
        let total = self.slot_ticks.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        self.slot_busy_ticks.load(Ordering::Relaxed) as f64 / total as f64
    }

    /// Mean rows per decode step tick — how many slots each tick's one
    /// fused pass actually advanced (0 when the scheduler never ran).
    pub fn mean_decode_batch(&self) -> f64 {
        let b = self.decode_batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.decode_batch_rows.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Fraction of prompt tokens served from the shared prefix cache
    /// (0 when the native scheduler never admitted anything, or prefix
    /// sharing is off).  `hit / (hit + miss)`: a value of 0.5 means
    /// half of all prompt-token work was skipped.
    pub fn prefix_hit_rate(&self) -> f64 {
        let hit = self.prefix_hit_tokens.load(Ordering::Relaxed);
        let miss = self.prefix_miss_tokens.load(Ordering::Relaxed);
        if hit + miss == 0 {
            return 0.0;
        }
        hit as f64 / (hit + miss) as f64
    }

    /// One-line human-readable dump of every counter (the `[metrics]`
    /// line `db-llm serve` prints every 10 s).
    pub fn snapshot(&self) -> String {
        format!(
            "req={} resp={} err={} rejected={} tokens={} batches={} occ={:.2} queue={} \
             saved_steps={} stalled={} slot_occ={:.2} refills={} timeouts={} \
             fused_rows={} decode_batch={:.2} prefix_hit={} prefix_miss={} \
             prefix_hit_rate={:.2} prefix_evict={} prefix_poisoned={} \
             p50={}us p95={}us p99={}us",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.tokens_out.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.queue_depth.load(Ordering::Relaxed),
            self.early_exit_steps.load(Ordering::Relaxed),
            self.stalled_row_steps.load(Ordering::Relaxed),
            self.slot_occupancy(),
            self.refills.load(Ordering::Relaxed),
            self.timeouts.load(Ordering::Relaxed),
            self.fused_rows.load(Ordering::Relaxed),
            self.mean_decode_batch(),
            self.prefix_hit_tokens.load(Ordering::Relaxed),
            self.prefix_miss_tokens.load(Ordering::Relaxed),
            self.prefix_hit_rate(),
            self.prefix_evictions.load(Ordering::Relaxed),
            self.prefix_lock_poisoned.load(Ordering::Relaxed),
            self.latency_percentile(0.50),
            self.latency_percentile(0.95),
            self.latency_percentile(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_monotonic() {
        let m = Metrics::default();
        for us in [100u64, 200, 400, 800, 1600, 3200] {
            m.record_latency(Duration::from_micros(us));
        }
        let p50 = m.latency_percentile(0.5);
        let p99 = m.latency_percentile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= 128 && p99 <= 8192, "{p50} {p99}");
    }

    #[test]
    fn occupancy_mean() {
        let m = Metrics::default();
        m.record_batch(4);
        m.record_batch(2);
        assert!((m.mean_batch_occupancy() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let m = Metrics::default();
        assert_eq!(m.latency_percentile(0.99), 0);
        assert!(m.snapshot().contains("req=0"));
        assert!(m.snapshot().contains("queue=0"));
        assert!(m.snapshot().contains("saved_steps=0"));
        assert!(m.snapshot().contains("stalled=0"));
        assert!(m.snapshot().contains("slot_occ=0.00"));
        assert!(m.snapshot().contains("timeouts=0"));
        assert!(m.snapshot().contains("fused_rows=0"));
        assert!(m.snapshot().contains("decode_batch=0.00"));
        assert_eq!(m.slot_occupancy(), 0.0, "no scheduler ticks -> 0, not NaN");
        assert_eq!(m.mean_decode_batch(), 0.0, "no decode ticks -> 0, not NaN");
    }

    #[test]
    fn scheduler_counters_surface() {
        let m = Metrics::default();
        // 10 decode ticks on 4 slots, 29 of 40 slot-ticks busy
        m.slot_ticks.fetch_add(40, Ordering::Relaxed);
        m.slot_busy_ticks.fetch_add(29, Ordering::Relaxed);
        m.refills.fetch_add(3, Ordering::Relaxed);
        m.timeouts.fetch_add(2, Ordering::Relaxed);
        m.stalled_row_steps.fetch_add(11, Ordering::Relaxed);
        assert!((m.slot_occupancy() - 0.725).abs() < 1e-12);
        let s = m.snapshot();
        assert!(s.contains("slot_occ=0.72"), "{s}");
        assert!(s.contains("refills=3"), "{s}");
        assert!(s.contains("timeouts=2"), "{s}");
        assert!(s.contains("stalled=11"), "{s}");
    }

    #[test]
    fn fused_decode_counters_surface() {
        let m = Metrics::default();
        // 5 decode-step ticks advanced 15 rows, 12 of them in
        // multi-row fused calls
        m.decode_batches.fetch_add(5, Ordering::Relaxed);
        m.decode_batch_rows.fetch_add(15, Ordering::Relaxed);
        m.fused_rows.fetch_add(12, Ordering::Relaxed);
        assert!((m.mean_decode_batch() - 3.0).abs() < 1e-12);
        let s = m.snapshot();
        assert!(s.contains("fused_rows=12"), "{s}");
        assert!(s.contains("decode_batch=3.00"), "{s}");
    }

    #[test]
    fn prefix_cache_counters_surface() {
        let m = Metrics::default();
        assert_eq!(m.prefix_hit_rate(), 0.0, "no prefix traffic -> 0, not NaN");
        // 30 of 40 prompt tokens served from the cache, 2 evictions
        m.prefix_hit_tokens.fetch_add(30, Ordering::Relaxed);
        m.prefix_miss_tokens.fetch_add(10, Ordering::Relaxed);
        m.prefix_evictions.fetch_add(2, Ordering::Relaxed);
        m.prefix_lock_poisoned.fetch_add(1, Ordering::Relaxed);
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
        let s = m.snapshot();
        assert!(s.contains("prefix_hit=30"), "{s}");
        assert!(s.contains("prefix_miss=10"), "{s}");
        assert!(s.contains("prefix_hit_rate=0.75"), "{s}");
        assert!(s.contains("prefix_evict=2"), "{s}");
        assert!(s.contains("prefix_poisoned=1"), "{s}");
    }

    #[test]
    fn queue_and_early_exit_counters_surface() {
        let m = Metrics::default();
        m.queue_depth.fetch_add(3, Ordering::Relaxed);
        m.queue_depth.fetch_sub(1, Ordering::Relaxed);
        m.early_exit_steps.fetch_add(7, Ordering::Relaxed);
        m.errors.fetch_add(1, Ordering::Relaxed);
        m.rejected.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert!(s.contains("queue=2"), "{s}");
        assert!(s.contains("saved_steps=7"), "{s}");
        assert!(s.contains("err=1"), "{s}");
        assert!(s.contains("rejected=2"), "{s}");
    }
}
