//! Deviation-Aware Distillation fine-tuning (paper §3.3, §4.3).
//!
//! The python layer exported `dad_step_<size>`: one XLA call computing
//! ℓ_total (Eq. 11) and ∂ℓ/∂α for every FDB scale.  This module owns
//! everything around that call:
//!   * the data-free calibration batches (teacher-generated tokens),
//!   * teacher logits (one `fwd_logits` call per batch, precomputed),
//!   * the AdamW optimizer over the α tensors (paper: lr 1e-5, 1 epoch,
//!     batch 2 — we keep the recipe, scaled to the small testbed),
//!   * optional plane re-splitting (Eq. 6-7) after the scales move.

use std::collections::BTreeMap;

use anyhow::{ensure, Context, Result};

use crate::data::TokenStream;
use crate::model::Weights;
use crate::quant::FdbLinear;
use crate::runtime::{lit_f32, lit_i32, Runtime, Session};

/// Fine-tuning hyper-parameters (defaults follow the paper §4.3; lr is
/// raised from 1e-5 because our α tensors are ~10⁴× smaller than
/// LLaMA's — documented in DESIGN.md §2).
#[derive(Clone, Debug)]
pub struct DadConfig {
    /// distillation temperature-style weight on the DAD term
    pub gamma: f64,
    /// weight of the plane-consistency regularizer
    pub lambda: f64,
    /// AdamW learning rate over the flat α vector
    pub lr: f64,
    /// passes over the calibration stream
    pub epochs: usize,
    /// batches per epoch cap (bounds fine-tuning cost)
    pub max_batches: usize,
    /// re-derive planes from the fp weights after fine-tuning (Eq. 6-7)
    pub resplit: bool,
    /// record a [`StepLog`] every this many steps
    pub log_every: usize,
}

impl Default for DadConfig {
    fn default() -> Self {
        DadConfig {
            gamma: 0.1,
            lambda: 0.1,
            lr: 1e-3,
            epochs: 1,
            max_batches: 64,
            resplit: true,
            log_every: 16,
        }
    }
}

/// Deviation weight from Eq. 10 — the factor that scales each
/// position's soft cross-entropy inside ℓ_DAD:
/// `(Hᵗ + ε)^γ · (Hˢ + ε)^(1−γ)` with ε = 1e-6.
///
/// The fused `dad_step_<size>` executable computes this inside the XLA
/// loss (see `python/compile/model.py::dad_losses`); this pure mirror
/// exists so the Rust layer can assert the semantics — ambiguous
/// positions (high entropy) are up-weighted, confident ones damped, and
/// γ interpolates between teacher- and student-ambiguity — without a
/// device round trip.
pub fn deviation_weight(teacher_entropy: f64, student_entropy: f64, gamma: f64) -> f64 {
    const EPS: f64 = 1e-6;
    (teacher_entropy + EPS).powf(gamma) * (student_entropy + EPS).powf(1.0 - gamma)
}

/// AdamW state over the flat α vector.
struct AdamW {
    m: Vec<f32>,
    v: Vec<f32>,
    t: i32,
    lr: f32,
    b1: f32,
    b2: f32,
    eps: f32,
    wd: f32,
}

impl AdamW {
    fn new(n: usize, lr: f32) -> Self {
        AdamW { m: vec![0.0; n], v: vec![0.0; n], t: 0, lr, b1: 0.9, b2: 0.999, eps: 1e-8, wd: 0.0 }
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        self.t += 1;
        let bc1 = 1.0 - self.b1.powi(self.t);
        let bc2 = 1.0 - self.b2.powi(self.t);
        for i in 0..params.len() {
            self.m[i] = self.b1 * self.m[i] + (1.0 - self.b1) * grads[i];
            self.v[i] = self.b2 * self.v[i] + (1.0 - self.b2) * grads[i] * grads[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= self.lr * (mhat / (vhat.sqrt() + self.eps) + self.wd * params[i]);
        }
    }
}

/// One recorded step.
#[derive(Clone, Debug)]
pub struct StepLog {
    /// optimizer step index
    pub step: usize,
    /// total loss (ce + weighted dad)
    pub total: f64,
    /// cross-entropy component
    pub ce: f64,
    /// deviation-aware distillation component
    pub dad: f64,
}

/// The DAD fine-tuning driver for one FDB-quantized model.
pub struct DadTrainer {
    /// hyper-parameters this trainer was built with
    pub config: DadConfig,
    /// model size tag (selects the AOT `dad_step_<size>` executable)
    pub size: String,
    alpha_names: Vec<String>,
    plane_names: Vec<String>,
    frozen_names: Vec<String>,
    /// flat α storage, in `alpha_names` order (each entry [g*out])
    alphas: BTreeMap<String, (Vec<f32>, Vec<i64>)>,
    /// recorded loss curve (every `log_every` steps)
    pub history: Vec<StepLog>,
}

impl DadTrainer {
    /// Build from the quantized FDB layers + the teacher weights.
    pub fn new(
        rt: &Runtime,
        size: &str,
        fdb_layers: &BTreeMap<String, FdbLinear>,
        config: DadConfig,
    ) -> Result<DadTrainer> {
        let key = format!("dad_step_{size}");
        let (alpha_names, plane_names, frozen_names) = rt.manifest.dad_step_order(&key)?;
        let mut alphas = BTreeMap::new();
        for name in &alpha_names {
            let (lin, kind) = name.rsplit_once('.').context("bad alpha name")?;
            let layer = fdb_layers
                .get(lin)
                .with_context(|| format!("missing FDB layer {lin}"))?;
            let a = if kind == "a1" { &layer.a1 } else { &layer.a2 };
            alphas.insert(
                name.clone(),
                (a.data.clone(), vec![a.rows as i64, a.cols as i64]),
            );
        }
        Ok(DadTrainer {
            config,
            size: size.to_string(),
            alpha_names,
            plane_names,
            frozen_names,
            alphas,
            history: Vec::new(),
        })
    }

    /// Run the fine-tuning loop.
    ///
    /// `teacher` is the pinned FP session (for teacher logits), `calib`
    /// the data-free token stream, `fdb_layers` supply the frozen planes,
    /// `teacher_weights` the frozen non-quantized parameters.
    pub fn train(
        &mut self,
        rt: &mut Runtime,
        teacher: &Session,
        teacher_weights: &Weights,
        fdb_layers: &BTreeMap<String, FdbLinear>,
        calib: &TokenStream,
        mut log: impl FnMut(&StepLog),
    ) -> Result<()> {
        let key = format!("dad_step_{}", self.size);
        let (b, t) = (teacher.logits_batch, teacher.seq_len);
        let vocab = teacher.vocab;

        // ---- assemble the constant literals (planes + frozen) ----------
        let mut plane_lits = Vec::new();
        for name in &self.plane_names {
            let (lin, kind) = name.rsplit_once('.').expect("plane names are <layer>.<kind>");
            let layer = &fdb_layers[lin];
            let plane = if kind == "b1" { &layer.b1 } else { &layer.b2 };
            let m = plane.unpack();
            plane_lits.push(lit_f32(&m.data, &[m.rows as i64, m.cols as i64])?);
        }
        let mut frozen_lits = Vec::new();
        for name in &self.frozen_names {
            if let Some(m) = teacher_weights.mats.get(name) {
                frozen_lits.push(lit_f32(&m.data, &[m.rows as i64, m.cols as i64])?);
            } else {
                let v = &teacher_weights.vecs[name];
                frozen_lits.push(lit_f32(v, &[v.len() as i64])?);
            }
        }
        let gamma_lit = lit_f32(&[self.config.gamma as f32], &[])?;
        let lambda_lit = lit_f32(&[self.config.lambda as f32], &[])?;

        // ---- batches + teacher logits (precomputed once) ---------------
        let windows: Vec<Vec<u32>> = calib.windows(t).map(|w| w.to_vec()).collect();
        let n_batches = (windows.len() / b).min(self.config.max_batches);
        ensure!(n_batches > 0, "calibration stream too short");
        let mut batches = Vec::with_capacity(n_batches);
        for i in 0..n_batches {
            let toks: Vec<i32> = windows[i * b..(i + 1) * b]
                .iter()
                .flat_map(|w| w.iter().map(|&x| x as i32))
                .collect();
            let t_logits = teacher.logits(rt, &toks)?;
            batches.push((toks, t_logits));
        }

        // ---- optimizer over the concatenated α vector -------------------
        let total_len: usize = self.alphas.values().map(|(d, _)| d.len()).sum();
        let mut opt = AdamW::new(total_len, self.config.lr as f32);

        let mut step = 0usize;
        for _epoch in 0..self.config.epochs {
            for (toks, t_logits) in &batches {
                // build args: alphas, planes, frozen, tokens, logits, γ, λ
                let mut args: Vec<xla::Literal> = Vec::new();
                for name in &self.alpha_names {
                    let (d, dims) = &self.alphas[name];
                    args.push(lit_f32(d, dims)?);
                }
                args.extend(plane_lits.iter().map(clone_lit));
                args.extend(frozen_lits.iter().map(clone_lit));
                args.push(lit_i32(toks, &[b as i64, t as i64])?);
                args.push(lit_f32(t_logits, &[b as i64, t as i64, vocab as i64])?);
                args.push(clone_lit(&gamma_lit));
                args.push(clone_lit(&lambda_lit));

                let out = rt.run(&key, &args)?;
                ensure!(
                    out.len() == 3 + self.alpha_names.len(),
                    "dad_step arity: got {}",
                    out.len()
                );
                let total = out[0].to_vec::<f32>()?[0] as f64;
                let ce = out[1].to_vec::<f32>()?[0] as f64;
                let dad = out[2].to_vec::<f32>()?[0] as f64;

                // flatten grads and step
                let mut flat_g = Vec::with_capacity(total_len);
                for (i, _name) in self.alpha_names.iter().enumerate() {
                    flat_g.extend(out[3 + i].to_vec::<f32>()?);
                }
                let mut flat_p = Vec::with_capacity(total_len);
                for name in &self.alpha_names {
                    flat_p.extend_from_slice(&self.alphas[name].0);
                }
                opt.step(&mut flat_p, &flat_g);
                let mut off = 0;
                for name in &self.alpha_names {
                    let entry = self.alphas.get_mut(name).expect("alpha_names index alphas");
                    let n = entry.0.len();
                    entry.0.copy_from_slice(&flat_p[off..off + n]);
                    off += n;
                }

                let rec = StepLog { step, total, ce, dad };
                if step % self.config.log_every == 0 {
                    log(&rec);
                }
                self.history.push(rec);
                step += 1;
            }
        }
        Ok(())
    }

    /// Write the fine-tuned scales back into the FDB layers (optionally
    /// re-splitting planes around the new level centers, Eq. 6-7).
    pub fn apply(
        &self,
        fdb_layers: &mut BTreeMap<String, FdbLinear>,
        original_weights: &Weights,
    ) {
        let mut by_layer: BTreeMap<String, (Option<Vec<f32>>, Option<Vec<f32>>)> = BTreeMap::new();
        for name in &self.alpha_names {
            let (lin, kind) = name.rsplit_once('.').expect("alpha names are <layer>.<kind>");
            let e = by_layer.entry(lin.to_string()).or_default();
            if kind == "a1" {
                e.0 = Some(self.alphas[name].0.clone());
            } else {
                e.1 = Some(self.alphas[name].0.clone());
            }
        }
        for (lin, (a1, a2)) in by_layer {
            let layer = fdb_layers.get_mut(&lin).expect("alpha names reference known layers");
            let (g, o) = (layer.a1.rows, layer.a1.cols);
            let a1 = crate::tensor::Matrix::from_vec(g, o, a1.expect("a1 trained per layer"));
            let a2 = crate::tensor::Matrix::from_vec(g, o, a2.expect("a2 trained per layer"));
            if self.config.resplit {
                layer.resplit(original_weights.mat(&lin), a1, a2);
            } else {
                layer.a1 = a1;
                layer.a2 = a2;
            }
        }
    }

    /// Final loss trend: (first, last) recorded totals.
    pub fn loss_trend(&self) -> Option<(f64, f64)> {
        Some((self.history.first()?.total, self.history.last()?.total))
    }
}

/// xla::Literal lacks Clone; shallow-copy via serialize round trip is
/// wasteful, so rebuild from raw parts.
fn clone_lit(l: &xla::Literal) -> xla::Literal {
    // Literal supports to_vec + shape; rebuild accordingly.
    let shape = l.array_shape().expect("literal shape");
    let dims: Vec<i64> = shape.dims().to_vec();
    match l.ty().expect("ty") {
        xla::ElementType::F32 => {
            let v = l.to_vec::<f32>().expect("f32 vec");
            if dims.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(&v).reshape(&dims).expect("reshape")
            }
        }
        xla::ElementType::S32 => {
            let v = l.to_vec::<i32>().expect("i32 vec");
            xla::Literal::vec1(&v).reshape(&dims).expect("reshape")
        }
        t => panic!("clone_lit: unsupported {t:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_reduces_quadratic() {
        // sanity: AdamW on f(x) = ||x - c||² converges toward c
        let c = [0.3f32, -0.7, 1.1];
        let mut x = vec![0.0f32; 3];
        let mut opt = AdamW::new(3, 0.05);
        for _ in 0..500 {
            let g: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| 2.0 * (xi - ci)).collect();
            opt.step(&mut x, &g);
        }
        for (xi, ci) in x.iter().zip(&c) {
            assert!((xi - ci).abs() < 0.05, "{xi} vs {ci}");
        }
    }

    #[test]
    fn default_config_matches_paper_gamma_lambda() {
        let c = DadConfig::default();
        assert!((c.gamma - 0.1).abs() < 1e-12);
        assert!((c.lambda - 0.1).abs() < 1e-12);
        assert_eq!(c.epochs, 1);
    }

    #[test]
    fn deviation_weight_monotone_in_ambiguity() {
        // Eq. 10: more ambiguous samples (higher entropy on either
        // side) must always be weighted harder, for any γ in (0, 1)
        for &gamma in &[0.1, 0.5, 0.9] {
            let mut last = 0.0;
            for i in 1..=8 {
                let h = f64::from(i) * 0.5;
                let w = deviation_weight(h, 1.0, gamma);
                assert!(w > last, "teacher ambiguity must up-weight (γ={gamma}, H={h})");
                last = w;
            }
            last = 0.0;
            for i in 1..=8 {
                let h = f64::from(i) * 0.5;
                let w = deviation_weight(1.0, h, gamma);
                assert!(w > last, "student ambiguity must up-weight (γ={gamma}, H={h})");
                last = w;
            }
        }
        // γ interpolates: γ=1 tracks the teacher entropy alone, γ=0
        // the student's (up to ε)
        assert!((deviation_weight(2.0, 7.0, 1.0) - 2.0).abs() < 1e-4);
        assert!((deviation_weight(7.0, 3.0, 0.0) - 3.0).abs() < 1e-4);
        // fully confident positions are damped to (almost) nothing
        assert!(deviation_weight(0.0, 0.0, 0.5) < 1e-5);
    }

    /// A trainer with no XLA manifest behind it — enough structure for
    /// the pure bookkeeping paths (`loss_trend`, `apply`).
    fn scripted_trainer() -> DadTrainer {
        DadTrainer {
            config: DadConfig::default(),
            size: "s".to_string(),
            alpha_names: Vec::new(),
            plane_names: Vec::new(),
            frozen_names: Vec::new(),
            alphas: BTreeMap::new(),
            history: Vec::new(),
        }
    }

    #[test]
    fn loss_trend_reports_scripted_direction() {
        let mut t = scripted_trainer();
        assert_eq!(t.loss_trend(), None, "no steps recorded yet");
        for (i, &total) in [4.0f64, 3.1, 2.6, 2.5].iter().enumerate() {
            t.history.push(StepLog { step: i, total, ce: total * 0.9, dad: total });
        }
        let (first, last) = t.loss_trend().expect("history recorded");
        assert!((first - 4.0).abs() < 1e-12 && (last - 2.5).abs() < 1e-12);
        assert!(first > last, "scripted losses decrease; the trend must agree");
    }

    #[test]
    fn apply_round_trips_trained_scales() {
        let cfg = crate::model::ModelConfig {
            name: "tiny".to_string(),
            d_model: 64,
            n_layers: 1,
            n_heads: 4,
            d_ff: 192,
            vocab: 96,
            seq_len: 32,
            rope_theta: 10_000.0,
            rmsnorm_eps: 1e-5,
        };
        let w = Weights::synthetic(&cfg, 7);
        let lin = "layers.0.wq".to_string();
        let layer = FdbLinear::from_weights(w.mat(&lin), 64);
        let (g, o) = (layer.a1.rows, layer.a1.cols);
        let (orig_a1, orig_a2) = (layer.a1.data.clone(), layer.a2.data.clone());
        let mut fdb = BTreeMap::new();
        fdb.insert(lin.clone(), layer);

        let mut t = scripted_trainer();
        t.config.resplit = false;
        t.alpha_names = vec![format!("{lin}.a1"), format!("{lin}.a2")];

        // identity round trip: applying a layer's own scales back with
        // resplit off must leave every field untouched
        t.alphas.insert(format!("{lin}.a1"), (orig_a1.clone(), vec![g as i64, o as i64]));
        t.alphas.insert(format!("{lin}.a2"), (orig_a2.clone(), vec![g as i64, o as i64]));
        let b1_before = fdb[&lin].b1.unpack().data.clone();
        t.apply(&mut fdb, &w);
        assert_eq!(fdb[&lin].a1.data, orig_a1, "identity apply must not move α₁");
        assert_eq!(fdb[&lin].a2.data, orig_a2, "identity apply must not move α₂");
        assert_eq!(fdb[&lin].b1.unpack().data, b1_before, "resplit=false freezes planes");

        // trained scales land verbatim, in [g, out] shape
        let a1: Vec<f32> = (0..g * o).map(|i| 0.01 + i as f32 * 1e-3).collect();
        let a2: Vec<f32> = (0..g * o).map(|i| 0.005 + i as f32 * 5e-4).collect();
        t.alphas.insert(format!("{lin}.a1"), (a1.clone(), vec![g as i64, o as i64]));
        t.alphas.insert(format!("{lin}.a2"), (a2.clone(), vec![g as i64, o as i64]));
        t.apply(&mut fdb, &w);
        assert_eq!(fdb[&lin].a1.data, a1, "resplit=false writes α₁ back verbatim");
        assert_eq!(fdb[&lin].a2.data, a2, "resplit=false writes α₂ back verbatim");
        assert_eq!((fdb[&lin].a1.rows, fdb[&lin].a1.cols), (g, o), "shape preserved");

        // with resplit on, the planes are re-derived around the new
        // level centers — shapes survive and scales stay finite
        t.config.resplit = true;
        t.apply(&mut fdb, &w);
        let l = &fdb[&lin];
        assert_eq!((l.din, l.dout), (64, 64));
        assert_eq!((l.a1.rows, l.a1.cols), (g, o));
        assert!(l.a1.data.iter().chain(&l.a2.data).all(|x| x.is_finite()));
    }
}
