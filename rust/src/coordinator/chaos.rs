//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] scripts engine-boundary faults by **call ordinal**:
//! the N-th prefill (or row-step) an engine executes fails, panics, or
//! stalls, regardless of wall time or thread interleaving.  Ordinals
//! make multi-seed chaos soaks reproducible — the same plan over the
//! same request set injects the same faults — which is what lets
//! `tests/chaos.rs` assert exactly-one-reply and bit-identical
//! uninjected streams across runs.
//!
//! [`ChaosEngine`] wraps any [`SlotEngine`] and applies a plan at the
//! engine boundary.  It deliberately does **not** override
//! [`SlotEngine::step_slots`] and keeps the default
//! `step_slots_atomic() == false`, which forces the scheduler onto its
//! row-by-row stepping path — exactly one [`SlotEngine::step_slot`]
//! ordinal per advanced row, so a plan names individual row-steps, not
//! whole fused batches.  For the same reason it pins
//! [`SlotEngine::speculate_k`] to 0: a speculative tick emits a
//! *variable* number of tokens per row (accepted drafts + bonus), so
//! letting a wrapped [`crate::infer::SpecDecoder`] speculate would make
//! step ordinals depend on acceptance luck and seeded replays would
//! stop being 1:1 with row-steps.  Speculation off is pure policy, not
//! semantics — greedy speculative and plain streams are bit-identical —
//! so chaos soaks exercise the identical token streams either way.
//! Counters live behind an `Arc` so a test can
//! keep observing them after the engine moves into a worker thread,
//! and they accumulate across supervisor respawns (the engine survives
//! inside the scheduler core).
//!
//! Faults at the *connection* boundary (oversized lines, mid-line
//! disconnects, stalls) need no engine hook — the chaos and
//! failure-injection suites drive those directly over a socket — and
//! queue-lock poisoning is injected with
//! [`super::serve::SharedQueue::poison_for_chaos`].

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::util::Pcg32;

use super::scheduler::{EngineTimers, PrefixCounters, SlotEngine};

/// Scripted faults, keyed by engine-call ordinal (0-based: the first
/// prefill an engine runs is prefill ordinal 0).  Sets are `BTreeSet`s
/// so plans print deterministically in test failure output.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// prefill ordinals that return an injected error (the scheduler
    /// answers that request with an error reply; the slot stays free)
    pub prefill_fail: BTreeSet<u64>,
    /// row-step ordinals that return an injected error (that row alone
    /// degrades to an error reply with its partial tokens)
    pub step_fail: BTreeSet<u64>,
    /// prefill ordinals that panic the worker (supervisor territory)
    pub panic_at_prefill: BTreeSet<u64>,
    /// row-step ordinals that panic the worker
    pub panic_at_step: BTreeSet<u64>,
    /// admission-check ordinals forced to report "no pool headroom"
    /// (the scheduler defers the request, re-trying next tick)
    pub admit_deny: BTreeSet<u64>,
    /// row-step ordinals that stall for [`slow_step_ms`](Self::slow_step_ms)
    /// before stepping — slow-tick injection for deadline/shed paths
    pub slow_steps: BTreeSet<u64>,
    /// stall duration for [`slow_steps`](Self::slow_steps) ordinals
    pub slow_step_ms: u64,
}

impl FaultPlan {
    /// A plan that injects nothing — the fault-free control run.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Seeded random plan: roughly `faults` injections of each flavor
    /// scattered over call ordinals `0..horizon`.  The same
    /// `(seed, horizon, faults)` always yields the same plan.
    pub fn random(seed: u64, horizon: u64, faults: usize) -> FaultPlan {
        let mut rng = Pcg32::new(seed, 0xC4A0_5);
        let mut draw = |n: usize| -> BTreeSet<u64> {
            (0..n).map(|_| rng.next_u64() % horizon.max(1)).collect()
        };
        FaultPlan {
            prefill_fail: draw(faults),
            step_fail: draw(faults),
            panic_at_prefill: draw(faults.div_ceil(2)),
            panic_at_step: draw(faults.div_ceil(2)),
            admit_deny: draw(faults),
            slow_steps: draw(faults),
            slow_step_ms: 1,
        }
    }
}

/// What a [`ChaosEngine`] actually did — call tallies and injection
/// counts, shared out through an `Arc` so tests observe them after the
/// engine moves into a worker thread.  Injection counters let a soak
/// assert its respawn/error totals against the plan as *executed*
/// (ordinals past the workload's natural length never fire).
#[derive(Debug, Default)]
pub struct ChaosCounters {
    /// prefill calls that reached the chaos boundary
    pub prefills: AtomicU64,
    /// row-step calls that reached the chaos boundary
    pub steps: AtomicU64,
    /// admission checks that reached the chaos boundary
    pub admission_checks: AtomicU64,
    /// prefill errors injected
    pub injected_prefill_failures: AtomicU64,
    /// row-step errors injected
    pub injected_step_failures: AtomicU64,
    /// worker panics injected (prefill + step)
    pub injected_panics: AtomicU64,
    /// admissions denied by the scripted pool-exhaustion fault
    pub denied_admissions: AtomicU64,
    /// row-steps stalled by the slow-tick fault
    pub injected_slow_steps: AtomicU64,
}

/// A [`SlotEngine`] wrapper that executes a [`FaultPlan`] at the
/// engine boundary.  Everything not named by the plan delegates to the
/// wrapped engine unchanged, so uninjected requests decode
/// bit-identically to a run without the wrapper.
pub struct ChaosEngine<E: SlotEngine> {
    inner: E,
    plan: FaultPlan,
    counters: Arc<ChaosCounters>,
}

impl<E: SlotEngine> ChaosEngine<E> {
    /// Wrap `inner`, injecting per `plan`.
    pub fn new(inner: E, plan: FaultPlan) -> ChaosEngine<E> {
        ChaosEngine { inner, plan, counters: Arc::new(ChaosCounters::default()) }
    }

    /// Shared handle to the execution tally (clone before moving the
    /// engine into a worker).
    pub fn counters(&self) -> Arc<ChaosCounters> {
        Arc::clone(&self.counters)
    }

    /// The wrapped engine.
    pub fn inner(&self) -> &E {
        &self.inner
    }
}

impl<E: SlotEngine> SlotEngine for ChaosEngine<E> {
    fn slots(&self) -> usize {
        self.inner.slots()
    }

    fn prefill_slot(&mut self, slot: usize, prompt: &[u32]) -> Result<Vec<f32>> {
        let n = self.counters.prefills.fetch_add(1, Ordering::Relaxed);
        if self.plan.panic_at_prefill.contains(&n) {
            self.counters.injected_panics.fetch_add(1, Ordering::Relaxed);
            panic!("chaos: scripted prefill panic at ordinal {n}");
        }
        if self.plan.prefill_fail.contains(&n) {
            self.counters.injected_prefill_failures.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("chaos: scripted prefill failure at ordinal {n}");
        }
        self.inner.prefill_slot(slot, prompt)
    }

    fn step_slot(&mut self, slot: usize, token: u32) -> Result<Vec<f32>> {
        let n = self.counters.steps.fetch_add(1, Ordering::Relaxed);
        if self.plan.slow_steps.contains(&n) {
            self.counters.injected_slow_steps.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(self.plan.slow_step_ms));
        }
        if self.plan.panic_at_step.contains(&n) {
            self.counters.injected_panics.fetch_add(1, Ordering::Relaxed);
            panic!("chaos: scripted step panic at ordinal {n}");
        }
        if self.plan.step_fail.contains(&n) {
            self.counters.injected_step_failures.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("chaos: scripted step failure at ordinal {n}");
        }
        self.inner.step_slot(slot, token)
    }

    // no `step_slots` override and the default `step_slots_atomic()`
    // (false): the scheduler steps row by row through `step_slot`, so
    // fault ordinals map 1:1 onto advanced rows — deterministic
    // regardless of how requests pack into ticks

    /// Chaos gates speculation off entirely (even when the wrapped
    /// engine is a speculative one): a speculative tick advances a row
    /// by `accepted + 1` tokens in a single engine call, which would
    /// decouple step ordinals from row-steps and make seeded fault
    /// replays depend on draft-acceptance luck.  With `k == 0` the
    /// scheduler never calls `step_slots_speculative`, every advanced
    /// row is exactly one `step_slot` ordinal, and — because greedy
    /// speculative output is bit-identical to plain decode — the soak
    /// still observes the same token streams a speculative run would
    /// produce.
    fn speculate_k(&self) -> usize {
        0
    }

    fn reset_slot(&mut self, slot: usize) {
        self.inner.reset_slot(slot)
    }

    fn quarantine_slot(&mut self, slot: usize) {
        self.inner.quarantine_slot(slot)
    }

    fn recover(&mut self) -> Result<()> {
        self.inner.recover()
    }

    fn can_admit(&self, prompt_tokens: usize) -> bool {
        let n = self.counters.admission_checks.fetch_add(1, Ordering::Relaxed);
        if self.plan.admit_deny.contains(&n) {
            self.counters.denied_admissions.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        self.inner.can_admit(prompt_tokens)
    }

    fn prefix_counters(&self) -> Option<PrefixCounters> {
        self.inner.prefix_counters()
    }

    fn phase_timers(&self) -> Option<EngineTimers> {
        self.inner.phase_timers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_plans_are_reproducible_and_seed_sensitive() {
        let a = FaultPlan::random(7, 100, 4);
        let b = FaultPlan::random(7, 100, 4);
        assert_eq!(a.step_fail, b.step_fail);
        assert_eq!(a.panic_at_step, b.panic_at_step);
        assert_eq!(a.admit_deny, b.admit_deny);
        let c = FaultPlan::random(8, 100, 4);
        assert!(
            a.step_fail != c.step_fail
                || a.prefill_fail != c.prefill_fail
                || a.admit_deny != c.admit_deny,
            "different seeds produced identical plans"
        );
        assert!(a.step_fail.iter().all(|&n| n < 100), "ordinal past the horizon");
    }

    /// A minimal scripted engine for boundary checks.
    struct Echo;
    impl SlotEngine for Echo {
        fn slots(&self) -> usize {
            1
        }
        fn prefill_slot(&mut self, _s: usize, _p: &[u32]) -> Result<Vec<f32>> {
            Ok(vec![1.0, 0.0])
        }
        fn step_slot(&mut self, _s: usize, _t: u32) -> Result<Vec<f32>> {
            Ok(vec![0.0, 1.0])
        }
        fn reset_slot(&mut self, _s: usize) {}
    }

    #[test]
    fn ordinals_script_failures_exactly() {
        let plan = FaultPlan {
            prefill_fail: [1].into_iter().collect(),
            step_fail: [0, 2].into_iter().collect(),
            ..FaultPlan::none()
        };
        let mut e = ChaosEngine::new(Echo, plan);
        let ctr = e.counters();
        assert!(e.prefill_slot(0, &[1]).is_ok(), "ordinal 0 clean");
        assert!(e.prefill_slot(0, &[1]).is_err(), "ordinal 1 injected");
        assert!(e.prefill_slot(0, &[1]).is_ok(), "ordinal 2 clean");
        assert!(e.step_slot(0, 1).is_err());
        assert!(e.step_slot(0, 1).is_ok());
        assert!(e.step_slot(0, 1).is_err());
        assert_eq!(ctr.prefills.load(Ordering::Relaxed), 3);
        assert_eq!(ctr.steps.load(Ordering::Relaxed), 3);
        assert_eq!(ctr.injected_prefill_failures.load(Ordering::Relaxed), 1);
        assert_eq!(ctr.injected_step_failures.load(Ordering::Relaxed), 2);
        assert_eq!(ctr.injected_panics.load(Ordering::Relaxed), 0);
        assert!(!e.step_slots_atomic(), "chaos must force the per-row scheduler path");
    }

    #[test]
    fn scripted_panic_fires_at_its_ordinal() {
        let plan =
            FaultPlan { panic_at_step: [1].into_iter().collect(), ..FaultPlan::none() };
        let mut e = ChaosEngine::new(Echo, plan);
        let ctr = e.counters();
        assert!(e.step_slot(0, 1).is_ok());
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = e.step_slot(0, 1);
        }));
        assert!(caught.is_err(), "ordinal 1 must panic");
        assert_eq!(ctr.injected_panics.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn speculation_is_gated_off_even_when_inner_speculates() {
        /// Claims to draft 3 tokens per tick; the wrapper must hide it.
        struct Spec;
        impl SlotEngine for Spec {
            fn slots(&self) -> usize {
                1
            }
            fn prefill_slot(&mut self, _s: usize, _p: &[u32]) -> Result<Vec<f32>> {
                Ok(vec![1.0, 0.0])
            }
            fn step_slot(&mut self, _s: usize, _t: u32) -> Result<Vec<f32>> {
                Ok(vec![0.0, 1.0])
            }
            fn reset_slot(&mut self, _s: usize) {}
            fn speculate_k(&self) -> usize {
                3
            }
        }
        let e = ChaosEngine::new(Spec, FaultPlan::none());
        assert_eq!(e.speculate_k(), 0, "chaos must pin speculation off");
        assert!(e.spec_counters().is_none(), "no speculative surface through chaos");
        assert!(
            !e.step_slots_atomic(),
            "chaos must keep the per-row path so ordinals stay 1:1 with row-steps"
        );
    }

    #[test]
    fn admission_denials_follow_the_plan() {
        let plan = FaultPlan { admit_deny: [0, 2].into_iter().collect(), ..FaultPlan::none() };
        let e = ChaosEngine::new(Echo, plan);
        let ctr = e.counters();
        assert!(!e.can_admit(4));
        assert!(e.can_admit(4));
        assert!(!e.can_admit(4));
        assert!(e.can_admit(4));
        assert_eq!(ctr.denied_admissions.load(Ordering::Relaxed), 2);
        assert_eq!(ctr.admission_checks.load(Ordering::Relaxed), 4);
    }
}
