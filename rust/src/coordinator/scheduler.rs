//! Iteration-level continuous-batching scheduler (Orca/vLLM-style) for
//! the native KV-cached backend, with per-request deadlines.
//!
//! The static batcher (`worker_loop` + `decode_batch`) runs whole
//! batches in lockstep: a row that hits EOS keeps its slot until the
//! *slowest* row of the batch finishes.  This scheduler instead owns a
//! fixed set of decode **slots** and re-plans between decode steps:
//!
//! - a queued request is admitted into any free slot *mid-flight* — its
//!   per-slot KV prefill runs while the other slots keep decoding;
//! - every active slot emits exactly one token per `tick` (the
//!   admission tick's token comes from the prefill logits);
//! - a finished row (budget / stop token) frees its slot at the end of
//!   the tick, so the next tick's admission refills it immediately;
//! - a request whose **deadline** expires is evicted with a
//!   partial-result reply flagged `timeout` (a request that expires
//!   while still queued — including `timeout_ms: 0` — is answered
//!   without ever occupying a slot).
//!
//! Determinism: the core is driven by an abstract [`Clock`] and an
//! abstract [`SlotEngine`], so `tests/scheduler_sim.rs` scripts arrival
//! times, lengths and EOS positions against a virtual clock and asserts
//! exact slot-assignment traces.  Sampling state is **forked per
//! request** — the stream is seeded from (scheduler seed, request id)
//! alone, so neither admission interleaving nor the fate of earlier
//! requests changes a request's sampled tokens; greedy rows are
//! interleaving-independent by construction, which is what makes the
//! single-slot / no-refill configurations token-for-token identical to
//! the static path.

use std::collections::{HashMap, VecDeque};
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex, TryLockError};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::Pcg32;

use super::metrics::{LocalHist, Metrics};
use super::trace::{RequestSpan, TraceRing};
use super::serve::{
    argmax, bind_listener, sample, spawn_accept_loop, ConnConfig, DecodeParams, Request,
    Response, SharedQueue,
};

/// Default cap on how many times one supervised worker is rebuilt after
/// a panic before the supervisor gives up on it (see
/// [`supervised_scheduler_loop`]).  High enough that a rare
/// engine-state corruption never takes a worker down for good, low
/// enough that a deterministic crash loop cannot spin forever.
pub const DEFAULT_MAX_RESPAWNS: u64 = 8;

/// How long an idle scheduler worker waits for a first request before
/// re-checking the shutdown flag (mirrors the static batcher).
const SHUTDOWN_POLL: Duration = Duration::from_millis(50);

/// Milliseconds on a monotonic axis with an arbitrary origin.  The
/// scheduler only ever compares instants on the same clock, so the
/// origin does not matter — which is what lets simulations drive the
/// deadline logic with a manually advanced clock.
pub trait Clock {
    /// Milliseconds since this clock's (arbitrary) origin.
    fn now_ms(&self) -> u64;

    /// Microseconds since the origin.  The default derives µs from
    /// [`now_ms`](Clock::now_ms) — exact for scripted clocks, which
    /// advance in whole milliseconds — while `WallClock` overrides it
    /// with native µs resolution so sub-millisecond TTFT and
    /// inter-token gaps are not rounded away.
    fn now_us(&self) -> u64 {
        self.now_ms().saturating_mul(1000)
    }
}

/// Real time, measured from construction.
pub struct WallClock {
    origin: Instant,
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock { origin: Instant::now() }
    }
}

impl Clock for WallClock {
    fn now_ms(&self) -> u64 {
        self.origin.elapsed().as_millis() as u64
    }

    fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }
}

/// Manually advanced clock for deterministic scheduler simulations.
#[derive(Clone, Default)]
pub struct ManualClock(Arc<AtomicU64>);

impl ManualClock {
    /// Move time forward by `ms` milliseconds.
    pub fn advance(&self, ms: u64) {
        self.0.fetch_add(ms, Ordering::Relaxed);
    }

    /// Jump to an absolute `ms` reading.
    pub fn set(&self, ms: u64) {
        self.0.store(ms, Ordering::Relaxed);
    }
}

impl Clock for ManualClock {
    fn now_ms(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Slot-granular decode backend: per-slot KV lifecycle instead of the
/// batch-at-a-time [`super::serve::Generator`] contract.  Implemented
/// by `infer::NativeEngine` (one `KvCache` per slot) and by the test
/// doubles in `tests/scheduler_sim.rs`.
///
/// # Examples
///
/// The slot lifecycle the scheduler drives — prefill a free slot, step
/// it once per tick, reset it when the request finishes:
///
/// ```no_run
/// # use db_llm::coordinator::scheduler::SlotEngine;
/// # fn run<E: SlotEngine>(engine: &mut E) -> anyhow::Result<()> {
/// let logits = engine.prefill_slot(0, &[1, 2, 3])?; // admission
/// let first = logits.iter().cloned().fold(f32::MIN, f32::max);
/// let logits = engine.step_slot(0, 4)?; // one token per tick
/// engine.reset_slot(0); // request finished: slot is reusable
/// # let _ = (first, logits);
/// # Ok(())
/// # }
/// ```
///
/// (`Scheduler::tick`'s example shows a complete scripted
/// implementation.)
pub trait SlotEngine {
    /// Number of independent decode slots this engine holds state for.
    fn slots(&self) -> usize;

    /// Reset `slot` and prefill it with `prompt`; returns the logits of
    /// the first decoded token.  Other slots' state is untouched — this
    /// is the contract that lets admission run mid-flight.
    fn prefill_slot(&mut self, slot: usize, prompt: &[u32]) -> Result<Vec<f32>>;

    /// One incremental decode step on `slot` given its last token;
    /// returns the next-token logits.
    fn step_slot(&mut self, slot: usize, token: u32) -> Result<Vec<f32>>;

    /// Advance several slots in one call: `steps` pairs each distinct
    /// slot with the token feeding its next step, and the result holds
    /// one next-token logits row per entry, in order.  Batched engines
    /// override this to amortize every weight traversal across the
    /// active rows (`infer::NativeEngine` runs each linear once per
    /// tick as an `[m, d]` product); the default just loops
    /// [`step_slot`](Self::step_slot), so scripted test engines keep
    /// working unchanged.
    fn step_slots(&mut self, steps: &[(usize, u32)]) -> Result<Vec<Vec<f32>>> {
        steps.iter().map(|&(slot, token)| self.step_slot(slot, token)).collect()
    }

    /// Whether [`step_slots`](Self::step_slots) fails *atomically*: an
    /// `Err` guarantees no slot's state advanced (the implementation
    /// validates the whole batch before mutating anything).  The
    /// scheduler only issues the batched call when this holds — a
    /// failed atomic batch can be retried row by row, isolating the
    /// failing request, whereas retrying a partially-advanced batch
    /// would double-step the surviving slots.  Engines that return
    /// `false` (the default, matching the default `step_slots`, which
    /// loops `step_slot` and can fail mid-batch) are stepped row by
    /// row by the scheduler itself — identical work, exact per-row
    /// isolation, no fused fast path.  Engines overriding
    /// `step_slots` with upfront validation (like
    /// `infer::NativeEngine`) — or whose `step_slot` cannot fail —
    /// should return `true`.
    fn step_slots_atomic(&self) -> bool {
        false
    }

    /// Drop `slot`'s sequence state (eviction / completion).
    fn reset_slot(&mut self, slot: usize);

    /// Reclaim `slot` after its worker *panicked* mid-operation.  The
    /// slot's sequence state must be dropped like
    /// [`reset_slot`](Self::reset_slot) — KV rows freed, pool block
    /// handles released, pinned prefix refs unpinned — but under the
    /// weaker precondition that the slot may have been left half-way
    /// through a prefill or step.  Implementations must make this
    /// panic-free on any reachable slot state: the supervisor calls it
    /// from the recovery path, where a second panic would strand the
    /// worker's whole request set.  The default delegates to
    /// `reset_slot`, which is already total for the scripted test
    /// engines.
    fn quarantine_slot(&mut self, slot: usize) {
        self.reset_slot(slot);
    }

    /// Engine-wide audit + repair after every slot has been
    /// quarantined, before the supervisor re-enters the serving loop.
    /// Implementations verify shared structures survived the panic
    /// (e.g. `infer::NativeEngine` clears a poisoned prefix-cache lock
    /// and runs `KvPool::assert_invariants`) and return `Err` when the
    /// engine cannot be trusted to serve again — the supervisor then
    /// retires the worker instead of respawning it.  The default is
    /// `Ok(())`: stateless scripted engines are always recoverable.
    fn recover(&mut self) -> Result<()> {
        Ok(())
    }

    /// Whether the engine can take a request with `prompt_tokens` of
    /// prompt right now without overcommitting its KV block pool
    /// (worst-case reservation: the prompt's blocks plus one decode
    /// tail block).  The scheduler consults this before every
    /// admission while another slot is active; engines without a
    /// bounded pool (the default) always accept.  `false` *defers*
    /// the request — it is re-tried next tick, never dropped — and
    /// the gate is bypassed when every slot is idle, so one oversized
    /// prompt can never wedge the queue (`infer::KvPool::alloc` stays
    /// infallible past the budget, it just over-commits).
    fn can_admit(&self, prompt_tokens: usize) -> bool {
        let _ = prompt_tokens;
        true
    }

    /// Cumulative cross-request prefix-cache counters for *this*
    /// engine, or `None` when the engine has no prefix sharing (the
    /// default).  Counters are per-engine (not cache-global) so the
    /// serving loop's per-worker metric deltas never double-count a
    /// cache shared across workers.  The scheduler snapshots these into
    /// [`SchedStats`] after every admission phase.
    fn prefix_counters(&self) -> Option<PrefixCounters> {
        None
    }

    /// Cumulative wall-clock phase timers this engine accumulated, or
    /// `None` when the engine does not time itself (the default).
    /// Engines that do (like `infer::NativeEngine`) time every prefill
    /// — rare and heavy — and sample decode steps 1-in-N so the hot
    /// loop stays untouched between samples.  The scheduler snapshots
    /// these into [`SchedStats`] every tick; the serving loop flushes
    /// deltas into the shared [`Metrics`].
    fn phase_timers(&self) -> Option<EngineTimers> {
        None
    }

    /// Draft length `k` for engines that decode speculatively
    /// (`infer::SpecDecoder`), `0` — the default — for everything else.
    /// When positive, the scheduler routes each tick's greedy
    /// speculation-opted rows through
    /// [`step_slots_speculative`](Self::step_slots_speculative) (which
    /// may emit up to `k + 1` tokens per row per tick) and the
    /// remaining rows through the plain [`step_slots`](Self::step_slots)
    /// path.
    fn speculate_k(&self) -> usize {
        0
    }

    /// Advance several distinct slots one *speculative* tick: for each
    /// `(slot, token)` entry the engine may draft up to
    /// [`speculate_k`](Self::speculate_k) tokens on its cheap student
    /// model, verify them in one batched teacher pass, and return the
    /// accepted prefix — one [`SpecRows`] group per entry, in order,
    /// each carrying `accepted + 1` teacher logits rows (the `+ 1` is
    /// the bonus/correction row after the accepted prefix).  Every
    /// returned row must be bit-identical to what the plain teacher
    /// path would have produced, so greedy speculative streams match
    /// teacher-only streams exactly.
    ///
    /// The same atomicity contract as [`step_slots`](Self::step_slots)
    /// applies when [`step_slots_atomic`](Self::step_slots_atomic)
    /// holds: an `Err` means no slot advanced, and the scheduler
    /// retries row by row through the plain path.  The default wraps
    /// `step_slots` — one teacher row per slot, nothing drafted — so
    /// non-speculative engines never see this path misbehave.
    fn step_slots_speculative(&mut self, steps: &[(usize, u32)]) -> Result<Vec<SpecRows>> {
        Ok(self
            .step_slots(steps)?
            .into_iter()
            .map(|row| SpecRows { rows: vec![row], drafted: 0, accepted: 0 })
            .collect())
    }

    /// Cumulative speculative-decode counters, or `None` when the
    /// engine never speculates (the default).  The scheduler snapshots
    /// these into [`SchedStats`] every tick; the serving loop flushes
    /// deltas into the shared [`Metrics`].
    fn spec_counters(&self) -> Option<SpecCounters> {
        None
    }
}

/// One slot's result from a speculative tick (see
/// [`SlotEngine::step_slots_speculative`]): the accepted-prefix logits
/// rows plus this tick's draft/accept tally for span accounting.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SpecRows {
    /// teacher logits rows to emit, in order — `accepted + 1` rows on a
    /// speculative tick (accepted drafts, then the bonus/correction
    /// row), exactly one row when nothing was drafted.  Each row is
    /// bit-identical to the plain teacher path's row for the same fed
    /// token.
    pub rows: Vec<Vec<f32>>,
    /// draft tokens proposed for this slot this tick (0 = plain row)
    pub drafted: u32,
    /// drafts accepted by the teacher verify pass (`≤ drafted`)
    pub accepted: u32,
}

/// Cumulative speculative-decode counters one engine accumulated (see
/// [`SlotEngine::spec_counters`]).  The deterministic work model the
/// bench asserts: `drafted == accepted + rejected`, every verified
/// group emits `accepted + 1` tokens (so `bonus` counts groups), and
/// each accepted draft is one teacher forward the plain path would
/// have run separately — `accepted` IS the teacher-forwards-saved
/// figure.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecCounters {
    /// draft tokens proposed by the student model
    pub drafted: u64,
    /// drafts accepted by the teacher verify pass
    pub accepted: u64,
    /// drafts rejected (their KV rolled back): `drafted - accepted`
    pub rejected: u64,
    /// bonus/correction tokens emitted from the verify row after the
    /// accepted prefix (one per verified group — every speculative
    /// tick emits at least this token, so decode always progresses)
    pub bonus: u64,
    /// batched teacher verify passes run (each one forward covering
    /// every speculating slot's `k + 1` rows)
    pub verify_passes: u64,
    /// cache positions truncated by accept-prefix rollback (teacher
    /// rejects plus discarded student draft rows; block-table edits,
    /// never row copies)
    pub rolled_back_rows: u64,
    /// speculation-opted rows that decoded plain because their slot
    /// could no longer fit `k + 1` positions before the window slides
    /// (speculation is permanently off for such a slot)
    pub fallback_rows: u64,
}

/// Cumulative prefix-cache counters one engine accumulated (see
/// [`SlotEngine::prefix_counters`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PrefixCounters {
    /// prompt tokens served from cached prefix blocks instead of
    /// running prefill
    pub hit_tokens: u64,
    /// prompt tokens that did run prefill (the uncached suffix, plus
    /// whole prompts on cache bypass/miss)
    pub miss_tokens: u64,
    /// cache blocks this engine's publishes evicted under budget
    /// pressure
    pub evictions: u64,
    /// times the prefix-cache mutex was found poisoned: the engine
    /// degrades to the cold (uncached) path, but the event is counted
    /// here instead of being silently swallowed
    pub lock_poisoned: u64,
}

/// Cumulative wall-clock phase timers one engine accumulated (see
/// [`SlotEngine::phase_timers`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineTimers {
    /// prefill calls wall-timed (every prefill: rare and heavy)
    pub prefill_calls: u64,
    /// summed wall nanoseconds inside those prefill calls (cache walk
    /// + block copy-in + suffix forward)
    pub prefill_ns: u64,
    /// batched decode (`step_slots`) calls sampled for timing (1-in-N)
    pub step_sampled: u64,
    /// summed wall nanoseconds inside the sampled calls
    pub step_ns: u64,
}

/// Scheduler policy knobs.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// decode slots (clamped to the engine's capacity)
    pub slots: usize,
    /// admit into freed slots mid-flight; `false` degrades to static
    /// waves (a new wave only starts once every slot is free) — the
    /// configuration the equivalence tests pin against `decode_batch`
    pub refill: bool,
    /// default deadline for requests that carry no `timeout_ms`
    pub default_timeout_ms: Option<u64>,
    /// base seed for the per-request sampling streams
    pub seed: u64,
    /// record [`TraceEvent`]s into the bounded trace ring.  Safe to
    /// leave on while serving: the ring overwrites its oldest entry
    /// when full and counts the drops ([`SchedStats::trace_dropped`]);
    /// [`Scheduler::take_trace`] keeps its draining semantics for the
    /// simulation tests
    pub trace: bool,
    /// capacity (entries) of the trace and request-span ring buffers —
    /// memory is paid once at construction (see `coordinator/trace.rs`)
    pub trace_capacity: usize,
    /// wall-time a full per-phase tick breakdown every N ticks
    /// (1 = every tick, 0 = never); sampling keeps the steady-state
    /// decode loop free of timer overhead between samples
    pub profile_every: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            slots: 4,
            refill: true,
            default_timeout_ms: None,
            seed: 42,
            trace: false,
            trace_capacity: 4096,
            profile_every: 64,
        }
    }
}

/// One unit of work for the scheduler.
pub struct Job {
    /// prompt token ids (also the prefix-sharing key on engines with a
    /// prefix cache — admission hands it to `prefill_slot` verbatim)
    pub prompt: Vec<u32>,
    /// decode budget and sampling settings
    pub params: DecodeParams,
    /// per-request deadline override; `None` = the scheduler default
    pub timeout_ms: Option<u64>,
    /// time the request already spent queued upstream (the shared
    /// server queue) — counted against the deadline, so `timeout_ms`
    /// bounds the wait from *arrival*, not from worker pickup
    pub queued_for_ms: u64,
}

/// Why a request left the scheduler.
#[derive(Clone, Debug, PartialEq)]
pub enum FinishReason {
    /// budget reached or stop token emitted
    Done,
    /// deadline expired: `tokens` holds the partial result
    Timeout,
    /// engine failure — degrades to an error reply
    Error(String),
}

/// One finished request: every submitted job produces exactly one.
#[derive(Clone, Debug)]
pub struct Completion {
    /// the id `submit` returned for this job
    pub id: u64,
    /// decoded tokens (partial on timeout, empty on queued expiry)
    pub tokens: Vec<u32>,
    /// how the request finished
    pub reason: FinishReason,
}

/// Scheduler decision log, recorded when `SchedulerConfig::trace` is
/// set; the simulation tests assert exact event sequences.
#[derive(Clone, Debug, PartialEq)]
#[allow(missing_docs)] // field names (id/slot/at_ms/…) are the docs
pub enum TraceEvent {
    /// request placed into a slot (its prefill ran this tick);
    /// `refill` marks admissions into a batch already mid-flight
    Admit { id: u64, slot: usize, at_ms: u64, refill: bool },
    /// request left its slot ("done" | "timeout" | "error" | "supervisor")
    Finish { id: u64, slot: usize, at_ms: u64, reason: &'static str, decoded: usize },
    /// deadline expired while still queued — never occupied a slot
    Expire { id: u64, at_ms: u64 },
}

/// Cumulative scheduler counters (monotonic; the serving loop feeds
/// deltas into the shared [`Metrics`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedStats {
    /// decode ticks run (ticks where at least one slot was active)
    pub ticks: u64,
    /// slots that decoded a token, summed over ticks (occupancy
    /// numerator; `ticks * slots` is the denominator)
    pub busy_slot_ticks: u64,
    /// requests admitted into a slot
    pub admissions: u64,
    /// admissions that refilled a batch already mid-flight
    pub refills: u64,
    /// requests finished by deadline (evicted or expired in queue)
    pub timeouts: u64,
    /// admissions deferred because the engine's KV block pool could
    /// not reserve the prompt's worst-case block count while other
    /// slots were active ([`SlotEngine::can_admit`]); the request
    /// stays queued and is re-tried next tick
    pub admit_deferred: u64,
    /// ticks that ran at least one decode step (mean decode batch
    /// denominator; fresh slots consume their prefill token instead of
    /// stepping, so this can trail `ticks`)
    pub step_ticks: u64,
    /// slot-rows advanced by decode steps, summed over ticks (mean
    /// decode batch = stepped_rows / step_ticks)
    pub stepped_rows: u64,
    /// rows advanced through a multi-row fused `step_slots` call —
    /// rows whose linears shared one batched product with at least one
    /// neighbour
    pub fused_rows: u64,
    /// prompt tokens served from the shared prefix cache instead of
    /// prefilling (snapshot of [`SlotEngine::prefix_counters`])
    pub prefix_hit_tokens: u64,
    /// prompt tokens that paid prefill (uncached suffixes + bypasses)
    pub prefix_miss_tokens: u64,
    /// prefix-cache blocks evicted by this engine's publishes
    pub prefix_evictions: u64,
    /// poisoned prefix-lock events this engine degraded through (see
    /// [`PrefixCounters::lock_poisoned`])
    pub prefix_lock_poisoned: u64,
    /// poisoned shared-queue-lock recoveries this worker absorbed
    /// (mirrors `prefix_lock_poisoned`): each one is a sibling worker
    /// panicking while holding the queue lock, which the supervised
    /// queue recovers from instead of wedging on
    pub queue_lock_poisoned: u64,
    /// ticks that ran with the sampled phase timers on
    /// (`SchedulerConfig::profile_every`)
    pub profiled_ticks: u64,
    /// wall ns the sampled ticks spent in queue-expiry + EDF admission
    /// (prefill included)
    pub admit_ns: u64,
    /// wall ns the sampled ticks spent in the decode-step phase
    pub step_ns: u64,
    /// wall ns the sampled ticks spent expiring deadline-passed rows
    pub expire_ns: u64,
    /// total wall ns of the sampled ticks
    pub tick_ns: u64,
    /// snapshot of [`EngineTimers::prefill_calls`] (0 without timers)
    pub engine_prefill_calls: u64,
    /// snapshot of [`EngineTimers::prefill_ns`]
    pub engine_prefill_ns: u64,
    /// snapshot of [`EngineTimers::step_sampled`]
    pub engine_step_sampled: u64,
    /// snapshot of [`EngineTimers::step_ns`]
    pub engine_step_ns: u64,
    /// snapshot of [`SpecCounters::drafted`] (0 without speculation)
    pub spec_drafted: u64,
    /// snapshot of [`SpecCounters::accepted`] — dense teacher forwards
    /// the speculative path saved
    pub spec_accepted: u64,
    /// snapshot of [`SpecCounters::rejected`]
    pub spec_rejected: u64,
    /// snapshot of [`SpecCounters::bonus`]
    pub spec_bonus: u64,
    /// snapshot of [`SpecCounters::verify_passes`]
    pub spec_verify_passes: u64,
    /// snapshot of [`SpecCounters::rolled_back_rows`]
    pub spec_rolled_back_rows: u64,
    /// snapshot of [`SpecCounters::fallback_rows`]
    pub spec_fallback_rows: u64,
    /// trace + span ring entries overwritten before being read
    pub trace_dropped: u64,
}

/// Per-phase latency histograms the scheduler core records locally —
/// plain counters, so deterministic `ManualClock` sims can assert
/// exact bucket contents.  The serving loop flushes bucket deltas into
/// the shared atomic [`Metrics`] histograms after every tick.
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedHists {
    /// time-to-first-token: queue wait + prefill, µs (one sample per
    /// admission — the first token is sampled from prefill logits)
    pub ttft_us: LocalHist,
    /// inter-token gap between consecutive decoded tokens, µs (one
    /// sample per decode-stepped row)
    pub itl_us: LocalHist,
    /// request arrival (incl. upstream queue time) → slot admission, µs
    pub queue_wait_us: LocalHist,
    /// wall time inside `prefill_slot`, µs
    pub prefill_us: LocalHist,
    /// whole-tick wall duration, µs (sampled ticks only)
    pub tick_us: LocalHist,
}

struct Queued {
    id: u64,
    prompt: Vec<u32>,
    params: DecodeParams,
    deadline_ms: Option<u64>,
    /// clock stamp when `submit` saw the job (queue-wait start)
    submitted_at_us: u64,
    /// time already spent in the upstream shared queue, µs
    upstream_us: u64,
}

struct Active {
    id: u64,
    params: DecodeParams,
    deadline_ms: Option<u64>,
    out: Vec<u32>,
    rng: Pcg32,
    /// token feeding the next incremental step
    last: u32,
    /// admitted this tick: its token came from the prefill logits
    fresh: bool,
    /// clock stamp at admission (span decode_us start)
    admitted_at_us: u64,
    /// arrival → admission, µs (upstream queue time included)
    queue_wait_us: u64,
    /// wall time the admission prefill took, µs
    prefill_us: u64,
    /// prompt tokens served from the shared prefix cache
    prefix_hit: u32,
    /// prompt tokens that paid prefill
    prefix_miss: u32,
    /// clock stamp of the last accepted token (ITL numerator)
    last_token_at_us: u64,
    /// draft tokens the speculative student proposed for this request
    drafted: u32,
    /// draft tokens the teacher verify pass accepted
    accepted: u32,
}

/// The continuous-batching core: a fixed slot set over a [`SlotEngine`]
/// plus an admission queue, advanced one decode step per [`tick`].
///
/// [`tick`]: Scheduler::tick
pub struct Scheduler<E: SlotEngine, C: Clock> {
    engine: E,
    clock: C,
    cfg: SchedulerConfig,
    active: Vec<Option<Active>>,
    queue: VecDeque<Queued>,
    next_id: u64,
    /// cumulative counters (see [`SchedStats`])
    pub stats: SchedStats,
    /// per-phase latency histograms (see [`SchedHists`])
    pub hists: SchedHists,
    trace: TraceRing<TraceEvent>,
    /// always-on phase-timed lifecycle record per finished request
    spans: TraceRing<RequestSpan>,
    /// monotonic tick counter driving the 1-in-N profile sampling
    tick_seq: u64,
    /// per-tick step list, reused across ticks so the steady-state
    /// decode loop stops allocating once it has grown to the slot count
    steps_buf: Vec<(usize, u32)>,
    /// per-tick speculative row list (greedy rows routed through
    /// [`SlotEngine::step_slots_speculative`]), reused like `steps_buf`
    spec_buf: Vec<(usize, u32)>,
}

impl<E: SlotEngine, C: Clock> Scheduler<E, C> {
    /// Build over `engine`, clamping the configured slot count to the
    /// engine's actual capacity.
    pub fn new(engine: E, clock: C, cfg: SchedulerConfig) -> Scheduler<E, C> {
        let slots = cfg.slots.clamp(1, engine.slots().max(1));
        let trace_cap = cfg.trace_capacity;
        Scheduler {
            engine,
            clock,
            cfg,
            active: (0..slots).map(|_| None).collect(),
            queue: VecDeque::new(),
            next_id: 0,
            stats: SchedStats::default(),
            hists: SchedHists::default(),
            trace: TraceRing::new(trace_cap),
            spans: TraceRing::new(trace_cap),
            tick_seq: 0,
            steps_buf: Vec::with_capacity(slots),
            spec_buf: Vec::with_capacity(slots),
        }
    }

    /// Enqueue a job.  Its deadline budget is `timeout_ms` (or the
    /// scheduler default) minus the time it already waited upstream
    /// (`queued_for_ms`), so the deadline bounds the wait from request
    /// arrival.  Returns the id its [`Completion`] will carry.
    pub fn submit(&mut self, job: Job) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let timeout = job.timeout_ms.or(self.cfg.default_timeout_ms);
        let deadline_ms = timeout.map(|t| {
            self.clock.now_ms().saturating_add(t.saturating_sub(job.queued_for_ms))
        });
        self.queue.push_back(Queued {
            id,
            prompt: job.prompt,
            params: job.params,
            deadline_ms,
            submitted_at_us: self.clock.now_us(),
            upstream_us: job.queued_for_ms.saturating_mul(1000),
        });
        id
    }

    /// Decode slots this scheduler plans over.
    pub fn slots(&self) -> usize {
        self.active.len()
    }

    /// Slots not currently holding an active request.
    pub fn free_slots(&self) -> usize {
        self.active.iter().filter(|s| s.is_none()).count()
    }

    /// Requests admitted to the core but not yet holding a slot.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// True when nothing is queued and no slot is active.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.iter().all(|s| s.is_none())
    }

    /// The wrapped engine (tests inspect scripted-engine state).
    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// Mutable access to the wrapped engine (the supervisor runs
    /// [`SlotEngine::recover`] through this after a panic).
    pub fn engine_mut(&mut self) -> &mut E {
        &mut self.engine
    }

    /// The retained decision log, oldest first (`SchedulerConfig::trace`).
    /// Takes `&mut self` because the backing ring may need to be made
    /// contiguous in place (no allocation).
    pub fn trace(&mut self) -> &[TraceEvent] {
        self.trace.as_slice()
    }

    /// Drain the decision log, oldest first, leaving it empty (the
    /// simulation tests' snapshot-and-reset semantics).
    pub fn take_trace(&mut self) -> Vec<TraceEvent> {
        self.trace.take()
    }

    /// Phase-timed lifecycle spans of finished requests, oldest first.
    /// Always on — the ring is bounded by
    /// `SchedulerConfig::trace_capacity`, so long-running servers pay
    /// fixed memory.
    pub fn spans(&mut self) -> &[RequestSpan] {
        self.spans.as_slice()
    }

    /// Drain the span ring, oldest first.
    pub fn take_spans(&mut self) -> Vec<RequestSpan> {
        self.spans.take()
    }

    /// Trace + span ring entries overwritten before anyone read them.
    pub fn trace_dropped(&self) -> u64 {
        self.trace.dropped() + self.spans.dropped()
    }

    /// One scheduler iteration: expire queued requests, refill free
    /// slots (prefill + first token), then one decode step per active
    /// slot, then evict deadline-expired rows.  Every completed request
    /// (and only completed requests) comes back as a [`Completion`].
    ///
    /// # Examples
    ///
    /// Drive a scripted one-slot engine to completion, one token per
    /// tick:
    ///
    /// ```
    /// use anyhow::Result;
    /// use db_llm::coordinator::scheduler::{
    ///     Job, ManualClock, Scheduler, SchedulerConfig, SlotEngine,
    /// };
    /// use db_llm::coordinator::serve::DecodeParams;
    ///
    /// /// Always predicts token 7.
    /// struct Const;
    /// impl SlotEngine for Const {
    ///     fn slots(&self) -> usize {
    ///         1
    ///     }
    ///     fn prefill_slot(&mut self, _s: usize, _p: &[u32]) -> Result<Vec<f32>> {
    ///         let mut l = vec![0.0; 16];
    ///         l[7] = 1.0;
    ///         Ok(l)
    ///     }
    ///     fn step_slot(&mut self, s: usize, _t: u32) -> Result<Vec<f32>> {
    ///         self.prefill_slot(s, &[])
    ///     }
    ///     fn reset_slot(&mut self, _s: usize) {}
    /// }
    ///
    /// let cfg = SchedulerConfig { slots: 1, ..Default::default() };
    /// let mut core = Scheduler::new(Const, ManualClock::default(), cfg);
    /// core.submit(Job {
    ///     prompt: vec![1, 2],
    ///     params: DecodeParams::greedy(3),
    ///     timeout_ms: None,
    ///     queued_for_ms: 0,
    /// });
    /// let mut replies = Vec::new();
    /// while !core.is_idle() {
    ///     replies.extend(core.tick());
    /// }
    /// assert_eq!(replies.len(), 1, "every submitted job completes exactly once");
    /// assert_eq!(replies[0].tokens, vec![7, 7, 7]);
    /// ```
    pub fn tick(&mut self) -> Vec<Completion> {
        // tidy:no-alloc(start): the tick frame itself only reuses
        // state — admission/expiry allocate in their own (cold-path)
        // bodies, and the completions vec starts empty.  The sampled
        // phase timers are Instant reads + integer adds into
        // pre-sized histograms: allocation-free by construction.
        let sampled = self.cfg.profile_every > 0 && self.tick_seq % self.cfg.profile_every == 0;
        self.tick_seq += 1;
        let t_frame = if sampled { Some(Instant::now()) } else { None };
        let mut done = Vec::new();
        self.expire_queued(&mut done);
        self.admit(&mut done);
        // admissions may have walked the prefix cache: snapshot the
        // engine's cumulative counters (assignment, not accumulation —
        // both sides are monotonic totals)
        if let Some(p) = self.engine.prefix_counters() {
            self.stats.prefix_hit_tokens = p.hit_tokens;
            self.stats.prefix_miss_tokens = p.miss_tokens;
            self.stats.prefix_evictions = p.evictions;
            self.stats.prefix_lock_poisoned = p.lock_poisoned;
        }
        let t_admit = t_frame.map(|t0| t0.elapsed());
        // a tick that decodes nothing (e.g. it only expired queued
        // requests) must not count slot-ticks, or slot_occ deflates
        let active = (self.active.len() - self.free_slots()) as u64;
        if active > 0 {
            self.stats.busy_slot_ticks += active;
            self.stats.ticks += 1;
        }
        self.step_active(&mut done);
        let t_step = t_frame.map(|t0| t0.elapsed());
        self.expire_active(&mut done);
        if let (Some(t0), Some(admit), Some(step)) = (t_frame, t_admit, t_step) {
            let total = t0.elapsed();
            self.stats.profiled_ticks += 1;
            self.stats.admit_ns += admit.as_nanos() as u64;
            self.stats.step_ns += (step - admit).as_nanos() as u64;
            self.stats.expire_ns += (total - step).as_nanos() as u64;
            self.stats.tick_ns += total.as_nanos() as u64;
            self.hists.tick_us.record_us(total.as_micros() as u64);
        }
        // timers accumulate inside the engine; snapshot like the prefix
        // counters (assignment of monotonic totals)
        if let Some(t) = self.engine.phase_timers() {
            self.stats.engine_prefill_calls = t.prefill_calls;
            self.stats.engine_prefill_ns = t.prefill_ns;
            self.stats.engine_step_sampled = t.step_sampled;
            self.stats.engine_step_ns = t.step_ns;
        }
        // speculative counters accumulate inside the engine too:
        // same assignment-of-monotonic-totals snapshot
        if let Some(c) = self.engine.spec_counters() {
            self.stats.spec_drafted = c.drafted;
            self.stats.spec_accepted = c.accepted;
            self.stats.spec_rejected = c.rejected;
            self.stats.spec_bonus = c.bonus;
            self.stats.spec_verify_passes = c.verify_passes;
            self.stats.spec_rolled_back_rows = c.rolled_back_rows;
            self.stats.spec_fallback_rows = c.fallback_rows;
        }
        self.stats.trace_dropped = self.trace.dropped() + self.spans.dropped();
        // tidy:no-alloc(end)
        #[cfg(debug_assertions)]
        self.assert_invariants();
        done
    }

    /// Audit the scheduler's structural invariants; panics on the
    /// first violation.  Debug builds run this after every [`tick`];
    /// release builds compile the call sites out, and tests may call
    /// it directly at any point.
    ///
    /// Checked invariants:
    /// - the slot table never changes size after construction;
    /// - every active id was issued by `submit` (`id < next_id`) and
    ///   no id occupies two slots or a slot and the queue at once;
    /// - counters are mutually consistent: occupancy never exceeds
    ///   `ticks * slots`, decode ticks never exceed busy ticks, fused
    ///   rows are a subset of stepped rows, and refills of mid-flight
    ///   batches are a subset of admissions;
    /// - a fresh slot's output holds exactly its prefill token.
    ///
    /// [`tick`]: Scheduler::tick
    pub fn assert_invariants(&self) {
        let slots = self.active.len();
        assert!(slots >= 1, "scheduler lost its slot table");
        let mut seen = Vec::with_capacity(slots + self.queue.len());
        for a in self.active.iter().flatten() {
            assert!(a.id < self.next_id, "active id {} never issued by submit", a.id);
            assert!(!seen.contains(&a.id), "id {} occupies two slots", a.id);
            assert!(!a.out.is_empty(), "active row decoded nothing (admission samples a token)");
            if a.fresh {
                assert_eq!(a.out.len(), 1, "fresh slot must hold exactly its prefill token");
            }
            assert!(a.out.len() <= a.params.max_tokens, "row decoded past its budget");
            assert!(a.accepted <= a.drafted, "row accepted more drafts than were proposed");
            seen.push(a.id);
        }
        for q in &self.queue {
            assert!(q.id < self.next_id, "queued id {} never issued by submit", q.id);
            assert!(!seen.contains(&q.id), "id {} is both queued and active", q.id);
            seen.push(q.id);
        }
        let s = &self.stats;
        assert!(
            s.busy_slot_ticks <= s.ticks * slots as u64,
            "occupancy {} exceeds {} ticks x {} slots",
            s.busy_slot_ticks,
            s.ticks,
            slots
        );
        assert!(s.ticks <= s.busy_slot_ticks, "a counted tick had at least one busy slot");
        assert!(s.step_ticks <= s.ticks, "decode ticks exceed scheduler ticks");
        assert!(s.fused_rows <= s.stepped_rows, "fused rows exceed stepped rows");
        assert!(s.step_ticks <= s.stepped_rows, "a step tick advances at least one row");
        assert!(s.refills <= s.admissions, "refills exceed admissions");
        assert!(
            self.steps_buf.len() <= slots,
            "step scratch holds more rows than slots exist"
        );
        assert!(
            self.spec_buf.len() <= slots,
            "speculative scratch holds more rows than slots exist"
        );
        assert_eq!(
            s.spec_drafted,
            s.spec_accepted + s.spec_rejected,
            "every drafted token is accepted or rejected"
        );
        let h = &self.hists;
        assert_eq!(h.ttft_us.count, s.admissions, "one TTFT sample per admission");
        assert_eq!(h.queue_wait_us.count, s.admissions, "one queue-wait sample per admission");
        assert_eq!(h.prefill_us.count, s.admissions, "one prefill sample per admission");
        assert_eq!(h.itl_us.count, s.stepped_rows, "one ITL sample per stepped row");
        assert_eq!(h.tick_us.count, s.profiled_ticks, "one tick sample per profiled tick");
    }

    /// Shutdown: answer everything still queued or in flight with an
    /// error completion (never a silent drop).
    pub fn abort_all(&mut self, msg: &str) -> Vec<Completion> {
        let mut done = Vec::new();
        while let Some(q) = self.queue.pop_front() {
            done.push(Completion {
                id: q.id,
                tokens: Vec::new(),
                reason: FinishReason::Error(msg.to_string()),
            });
        }
        for slot in 0..self.active.len() {
            if self.active[slot].is_some() {
                self.finish(slot, FinishReason::Error(msg.to_string()), &mut done);
            }
        }
        done
    }

    /// Post-panic recovery: quarantine every active slot
    /// ([`SlotEngine::quarantine_slot`] — the panic may have left it
    /// half-prefilled or half-stepped, so the ordinary `reset_slot`
    /// contract is not enough), answer every owed request with an
    /// error completion (partial tokens for rows that held a slot,
    /// empty for queued ones), and re-arm the bookkeeping so the
    /// worker can keep serving.  Finished requests are recorded with
    /// the `"supervisor"` span/trace reason.  Returns the completions
    /// plus the number of slots quarantined.
    ///
    /// Stats and histograms are reset *together*: the panic may have
    /// struck mid-tick, between updates [`assert_invariants`] requires
    /// to move in lockstep (e.g. one TTFT sample per admission).
    /// Assignment-style snapshots of monotonic totals (prefix
    /// counters, engine timers, trace drops) are then re-seeded from
    /// their sources so the serving loop's next delta flush does not
    /// re-count totals it already flushed before the panic.
    ///
    /// [`assert_invariants`]: Scheduler::assert_invariants
    pub fn recover_after_panic(&mut self, msg: &str) -> (Vec<Completion>, usize) {
        let mut done = Vec::new();
        let now_ms = self.clock.now_ms();
        let now_us = self.clock.now_us();
        let mut quarantined = 0usize;
        for slot in 0..self.active.len() {
            let Some(a) = self.active[slot].take() else { continue };
            self.engine.quarantine_slot(slot);
            quarantined += 1;
            if self.cfg.trace {
                self.trace.push(TraceEvent::Finish {
                    id: a.id,
                    slot,
                    at_ms: now_ms,
                    reason: "supervisor",
                    decoded: a.out.len(),
                });
            }
            self.spans.push(RequestSpan {
                id: a.id,
                queue_wait_us: a.queue_wait_us,
                admitted_at_us: a.admitted_at_us,
                prefill_us: a.prefill_us,
                prefix_hit_tokens: a.prefix_hit,
                prefix_miss_tokens: a.prefix_miss,
                decoded: a.out.len() as u32,
                drafted: a.drafted,
                accepted: a.accepted,
                decode_us: now_us.saturating_sub(a.admitted_at_us),
                reason: "supervisor",
            });
            done.push(Completion {
                id: a.id,
                tokens: a.out,
                reason: FinishReason::Error(msg.to_string()),
            });
        }
        while let Some(q) = self.queue.pop_front() {
            self.spans.push(RequestSpan {
                id: q.id,
                queue_wait_us: now_us.saturating_sub(q.submitted_at_us) + q.upstream_us,
                admitted_at_us: 0,
                prefill_us: 0,
                prefix_hit_tokens: 0,
                prefix_miss_tokens: 0,
                decoded: 0,
                drafted: 0,
                accepted: 0,
                decode_us: 0,
                reason: "supervisor",
            });
            done.push(Completion {
                id: q.id,
                tokens: Vec::new(),
                reason: FinishReason::Error(msg.to_string()),
            });
        }
        self.stats = SchedStats::default();
        self.hists = SchedHists::default();
        self.steps_buf.clear();
        self.spec_buf.clear();
        if let Some(p) = self.engine.prefix_counters() {
            self.stats.prefix_hit_tokens = p.hit_tokens;
            self.stats.prefix_miss_tokens = p.miss_tokens;
            self.stats.prefix_evictions = p.evictions;
            self.stats.prefix_lock_poisoned = p.lock_poisoned;
        }
        if let Some(t) = self.engine.phase_timers() {
            self.stats.engine_prefill_calls = t.prefill_calls;
            self.stats.engine_prefill_ns = t.prefill_ns;
            self.stats.engine_step_sampled = t.step_sampled;
            self.stats.engine_step_ns = t.step_ns;
        }
        if let Some(c) = self.engine.spec_counters() {
            self.stats.spec_drafted = c.drafted;
            self.stats.spec_accepted = c.accepted;
            self.stats.spec_rejected = c.rejected;
            self.stats.spec_bonus = c.bonus;
            self.stats.spec_verify_passes = c.verify_passes;
            self.stats.spec_rolled_back_rows = c.rolled_back_rows;
            self.stats.spec_fallback_rows = c.fallback_rows;
        }
        self.stats.trace_dropped = self.trace.dropped() + self.spans.dropped();
        #[cfg(debug_assertions)]
        self.assert_invariants();
        (done, quarantined)
    }

    /// Drop queued requests whose deadline already passed: they are
    /// answered with an (empty) timeout reply *before* occupying a slot
    /// — this is also the path a `timeout_ms: 0` request takes.
    fn expire_queued(&mut self, done: &mut Vec<Completion>) {
        let now = self.clock.now_ms();
        if !self.queue.iter().any(|q| q.deadline_ms.is_some_and(|d| now >= d)) {
            return;
        }
        let now_us = self.clock.now_us();
        let mut keep = VecDeque::with_capacity(self.queue.len());
        while let Some(q) = self.queue.pop_front() {
            if q.deadline_ms.is_some_and(|d| now >= d) {
                self.stats.timeouts += 1;
                if self.cfg.trace {
                    self.trace.push(TraceEvent::Expire { id: q.id, at_ms: now });
                }
                // a request that dies in queue still gets a lifecycle
                // span: its whole life was queue wait
                self.spans.push(RequestSpan {
                    id: q.id,
                    queue_wait_us: now_us.saturating_sub(q.submitted_at_us) + q.upstream_us,
                    admitted_at_us: 0,
                    prefill_us: 0,
                    prefix_hit_tokens: 0,
                    prefix_miss_tokens: 0,
                    decoded: 0,
                    drafted: 0,
                    accepted: 0,
                    decode_us: 0,
                    reason: "expired",
                });
                done.push(Completion {
                    id: q.id,
                    tokens: Vec::new(),
                    reason: FinishReason::Timeout,
                });
            } else {
                keep.push_back(q);
            }
        }
        self.queue = keep;
    }

    /// Pop the queued request admission picks next: earliest effective
    /// deadline first (EDF), no-deadline requests ranking last, FCFS
    /// among ties (the strict `<` keeps the earliest arrival, since
    /// `submit` pushes in arrival order).
    fn pop_next(&mut self) -> Option<Queued> {
        let mut best: Option<(usize, u64)> = None;
        for (i, q) in self.queue.iter().enumerate() {
            let d = q.deadline_ms.unwrap_or(u64::MAX);
            match best {
                Some((_, bd)) if d >= bd => {}
                _ => best = Some((i, d)),
            }
        }
        best.and_then(|(i, _)| self.queue.remove(i))
    }

    /// Refill every free slot from the queue (earliest-deadline-first,
    /// slot order).  The prefill samples the request's first token, so
    /// an admitted slot produces a token this very tick — a freed slot
    /// never sits idle while work is queued.
    fn admit(&mut self, done: &mut Vec<Completion>) {
        if self.queue.is_empty() {
            return;
        }
        // slots still decoding from previous ticks: admissions next to
        // them are refills; `false` only for a fresh wave from idle
        let carried = self.active.len() - self.free_slots();
        if !self.cfg.refill && carried > 0 {
            return;
        }
        let now = self.clock.now_ms();
        let now_us = self.clock.now_us();
        for slot in 0..self.active.len() {
            if self.active[slot].is_some() {
                continue;
            }
            while let Some(q) = self.pop_next() {
                if q.params.max_tokens == 0 {
                    // a zero-budget request never needs a slot
                    done.push(Completion {
                        id: q.id,
                        tokens: Vec::new(),
                        reason: FinishReason::Done,
                    });
                    continue;
                }
                // block-pool admission gate: a prompt whose worst-case
                // block reservation does not fit the pool's free set
                // would force every later decode step to over-commit
                // the budget.  Defer it (push back to the queue front:
                // EDF rescans the whole queue, and front keeps it the
                // FCFS tie-winner) — unless every slot is idle, in
                // which case it runs anyway so one oversized request
                // can never deadlock the scheduler.
                if self.active.iter().any(Option::is_some)
                    && !self.engine.can_admit(q.prompt.len())
                {
                    self.stats.admit_deferred += 1;
                    self.queue.push_front(q);
                    return;
                }
                // wall-time the prefill and attribute its prefix
                // hit/miss split via the engine counter delta
                let prefix_before = self.engine.prefix_counters().unwrap_or_default();
                let t_prefill = Instant::now();
                match self.engine.prefill_slot(slot, &q.prompt) {
                    Ok(logits) => {
                        let prefill_us = t_prefill.elapsed().as_micros() as u64;
                        let prefix_after = self.engine.prefix_counters().unwrap_or_default();
                        let queue_wait_us =
                            now_us.saturating_sub(q.submitted_at_us) + q.upstream_us;
                        self.hists.queue_wait_us.record_us(queue_wait_us);
                        self.hists.prefill_us.record_us(prefill_us);
                        // TTFT: the first token is sampled from these
                        // prefill logits, so it is ready right now
                        self.hists.ttft_us.record_us(queue_wait_us + prefill_us);
                        // sampling stream derived from (seed, id) only:
                        // no shared RNG draw, so the fate of earlier
                        // requests never shifts this request's stream
                        let state = self.cfg.seed ^ q.id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
                        let mut rng = Pcg32::new(state, q.id);
                        let tok = pick(&logits, q.params, &mut rng);
                        self.stats.admissions += 1;
                        let refill = carried > 0;
                        if refill {
                            self.stats.refills += 1;
                        }
                        if self.cfg.trace {
                            let ev = TraceEvent::Admit { id: q.id, slot, at_ms: now, refill };
                            self.trace.push(ev);
                        }
                        self.active[slot] = Some(Active {
                            id: q.id,
                            params: q.params,
                            deadline_ms: q.deadline_ms,
                            out: vec![tok],
                            rng,
                            last: tok,
                            fresh: true,
                            admitted_at_us: now_us,
                            queue_wait_us,
                            prefill_us,
                            prefix_hit: (prefix_after.hit_tokens - prefix_before.hit_tokens)
                                as u32,
                            prefix_miss: (prefix_after.miss_tokens - prefix_before.miss_tokens)
                                as u32,
                            last_token_at_us: now_us,
                            drafted: 0,
                            accepted: 0,
                        });
                        break;
                    }
                    Err(e) => {
                        // per-request failure: error completion, slot
                        // stays free for the next queued request
                        self.engine.reset_slot(slot);
                        done.push(Completion {
                            id: q.id,
                            tokens: Vec::new(),
                            reason: FinishReason::Error(format!("{e:#}")),
                        });
                    }
                }
            }
        }
    }

    /// One decode step per active slot.  Engines whose batched step is
    /// atomic on failure ([`SlotEngine::step_slots_atomic`]) advance
    /// every row through a single [`SlotEngine::step_slots`] call — the
    /// hot loop runs each linear once per tick instead of once per slot
    /// — and a failed call is retried row by row, so one slot's
    /// failure answers that request alone, not the whole tick.
    /// Engines without that guarantee are stepped row by row directly
    /// (the same work their default `step_slots` would do, with exact
    /// per-row isolation and no risk of double-stepping a
    /// partially-advanced batch).  Fresh slots already hold this
    /// tick's token (from the prefill logits) — they only run the
    /// finish check, keeping the invariant of exactly one token per
    /// active slot per tick.
    ///
    /// Speculative engines ([`SlotEngine::speculate_k`] > 0) get a
    /// second phase: greedy rows that opted in
    /// (`DecodeParams::speculate`, temperature ≤ 0) are routed through
    /// one [`SlotEngine::step_slots_speculative`] call and may emit
    /// *several* tokens this tick (accepted drafts + the bonus row) —
    /// sampled rows stay on the plain path, because a draft/verify
    /// split cannot replay their RNG stream bit-exactly.  Every
    /// emitted token counts as a stepped row (one ITL sample each), so
    /// the occupancy and latency invariants hold unchanged; a
    /// mid-group budget/stop exit always coincides with the finish
    /// check below, which resets the slot and with it the engine's
    /// overextended cache.
    fn step_active(&mut self, done: &mut Vec<Completion>) {
        // tidy:no-alloc(start): per-tick decode hot loop — the step
        // lists reuse scratch buffers across ticks; only the error
        // paths (annotated per line) may allocate.
        self.steps_buf.clear();
        self.spec_buf.clear();
        let speculating = self.engine.speculate_k() > 0;
        for (slot, a) in self.active.iter().enumerate() {
            match a {
                Some(a) if !a.fresh => {
                    if speculating && a.params.temperature <= 0.0 && a.params.speculate {
                        self.spec_buf.push((slot, a.last));
                    } else {
                        self.steps_buf.push((slot, a.last));
                    }
                }
                _ => {}
            }
        }

        let mut failures: Vec<(usize, String)> = Vec::new();
        // rows that actually advanced this tick (accounted only
        // after the engine calls resolve — a failed fused call must
        // not masquerade as fused throughput in the metrics)
        let mut advanced = 0u64;
        let mut fused = 0u64;
        // one clock read per tick: every token accepted this tick
        // shares the same inter-token-latency endpoint
        let now_us = if self.steps_buf.is_empty() && self.spec_buf.is_empty() {
            0
        } else {
            self.clock.now_us()
        };
        if !self.steps_buf.is_empty() {
            let m = self.steps_buf.len();
            let mut batch_failed = false;
            if self.engine.step_slots_atomic() {
                match self.engine.step_slots(&self.steps_buf) {
                    Ok(rows) if rows.len() == m => {
                        for (i, logits) in rows.iter().enumerate() {
                            let slot = self.steps_buf[i].0;
                            self.accept_token(slot, logits, now_us);
                        }
                        advanced = m as u64;
                        if m > 1 {
                            fused = m as u64;
                        }
                    }
                    Ok(rows) => {
                        // a row-count mismatch is an engine bug
                        // affecting the whole batch — there is no
                        // telling which row got which logits
                        let msg = format!( // tidy:allow(no-alloc): error path
                            "engine returned {} logits rows for {} stepped slots",
                            rows.len(),
                            m
                        );
                        for &(slot, _) in &self.steps_buf {
                            failures.push((slot, msg.clone())); // tidy:allow(no-alloc): error path
                        }
                    }
                    // atomic contract: the failed call advanced
                    // nothing, so the per-row pass below can safely
                    // isolate the failing request
                    Err(_) => batch_failed = true,
                }
            }
            if !self.engine.step_slots_atomic() || batch_failed {
                for i in 0..m {
                    let (slot, last) = self.steps_buf[i];
                    match self.engine.step_slot(slot, last) {
                        Ok(logits) => {
                            self.accept_token(slot, &logits, now_us);
                            advanced += 1;
                        }
                        Err(e) => failures.push((slot, format!("{e:#}"))), // tidy:allow(no-alloc): error path
                    }
                }
            }
        }
        if !self.spec_buf.is_empty() {
            let m = self.spec_buf.len();
            match self.engine.step_slots_speculative(&self.spec_buf) {
                Ok(groups) if groups.len() == m => {
                    for (i, g) in groups.iter().enumerate() {
                        let slot = self.spec_buf[i].0;
                        debug_assert!(g.accepted <= g.drafted, "accepted beyond drafted");
                        debug_assert_eq!(
                            g.rows.len() as u64,
                            g.accepted as u64 + 1,
                            "a verified group holds its accepted rows plus the bonus row"
                        );
                        {
                            let a =
                                self.active[slot].as_mut().expect("stepped slot emptied mid-tick");
                            a.drafted += g.drafted;
                            a.accepted += g.accepted;
                        }
                        for row in &g.rows {
                            // the first row can never trip these (a
                            // finished slot was reaped last tick); a
                            // later exit leaves the engine cache
                            // overextended, which the finish check
                            // below clears via reset_slot
                            let a =
                                self.active[slot].as_ref().expect("stepped slot emptied mid-tick");
                            if a.out.len() >= a.params.max_tokens
                                || a.params.stop.is_some_and(|s| a.last == s)
                            {
                                break;
                            }
                            self.accept_token(slot, row, now_us);
                            advanced += 1;
                        }
                    }
                }
                Ok(groups) => {
                    let msg = format!( // tidy:allow(no-alloc): error path
                        "engine returned {} speculative groups for {} stepped slots",
                        groups.len(),
                        m
                    );
                    for &(slot, _) in &self.spec_buf {
                        failures.push((slot, msg.clone())); // tidy:allow(no-alloc): error path
                    }
                }
                // the speculative call validates up front and is
                // atomic on failure: nothing advanced, so each row is
                // retried on the plain (teacher-only) path to isolate
                // the failing request
                Err(_) => {
                    for i in 0..m {
                        let (slot, last) = self.spec_buf[i];
                        match self.engine.step_slot(slot, last) {
                            Ok(logits) => {
                                self.accept_token(slot, &logits, now_us);
                                advanced += 1;
                            }
                            Err(e) => failures.push((slot, format!("{e:#}"))), // tidy:allow(no-alloc): error path
                        }
                    }
                }
            }
        }
        if advanced > 0 {
            self.stats.step_ticks += 1;
            self.stats.stepped_rows += advanced;
            self.stats.fused_rows += fused;
        }
        // tidy:no-alloc(end)
        for (slot, msg) in failures {
            if self.active[slot].is_some() {
                self.finish(slot, FinishReason::Error(msg), done);
            }
        }

        // finish checks (budget / stop token) for every surviving slot,
        // fresh ones included
        for slot in 0..self.active.len() {
            let Some(a) = self.active[slot].as_mut() else { continue };
            if a.fresh {
                a.fresh = false;
            }
            let finished =
                a.out.len() >= a.params.max_tokens || a.params.stop.is_some_and(|s| a.last == s);
            if finished {
                self.finish(slot, FinishReason::Done, done);
            }
        }
    }

    /// Record one decoded logits row for `slot`: sample under the
    /// slot's own params/stream, append, remember the token for the
    /// next step, and record the inter-token gap since the slot's
    /// previous token.
    fn accept_token(&mut self, slot: usize, logits: &[f32], now_us: u64) {
        let a = self.active[slot].as_mut().expect("stepped slot emptied mid-tick");
        let tok = pick(logits, a.params, &mut a.rng);
        a.out.push(tok);
        a.last = tok;
        self.hists.itl_us.record_us(now_us.saturating_sub(a.last_token_at_us));
        a.last_token_at_us = now_us;
    }

    /// Evict rows whose deadline passed, carrying the tokens decoded so
    /// far as the partial result.
    fn expire_active(&mut self, done: &mut Vec<Completion>) {
        let now = self.clock.now_ms();
        for slot in 0..self.active.len() {
            let expired = self.active[slot]
                .as_ref()
                .is_some_and(|a| a.deadline_ms.is_some_and(|d| now >= d));
            if expired {
                self.finish(slot, FinishReason::Timeout, done);
            }
        }
    }

    fn finish(&mut self, slot: usize, reason: FinishReason, done: &mut Vec<Completion>) {
        let a = self.active[slot].take().expect("finish on empty slot");
        self.engine.reset_slot(slot);
        if matches!(reason, FinishReason::Timeout) {
            self.stats.timeouts += 1;
        }
        let label = match &reason {
            FinishReason::Done => "done",
            FinishReason::Timeout => "timeout",
            FinishReason::Error(_) => "error",
        };
        if self.cfg.trace {
            self.trace.push(TraceEvent::Finish {
                id: a.id,
                slot,
                at_ms: self.clock.now_ms(),
                reason: label,
                decoded: a.out.len(),
            });
        }
        // the always-on lifecycle span: one phase-timed record per
        // request that held a slot
        self.spans.push(RequestSpan {
            id: a.id,
            queue_wait_us: a.queue_wait_us,
            admitted_at_us: a.admitted_at_us,
            prefill_us: a.prefill_us,
            prefix_hit_tokens: a.prefix_hit,
            prefix_miss_tokens: a.prefix_miss,
            decoded: a.out.len() as u32,
            drafted: a.drafted,
            accepted: a.accepted,
            decode_us: self.clock.now_us().saturating_sub(a.admitted_at_us),
            reason: label,
        });
        done.push(Completion { id: a.id, tokens: a.out, reason });
    }
}

/// Sample one token from a logits row under `params` (greedy when
/// temperature <= 0) — the same semantics as the static decode loop.
fn pick(logits: &[f32], params: DecodeParams, rng: &mut Pcg32) -> u32 {
    let idx = if params.temperature <= 0.0 {
        argmax(logits)
    } else {
        sample(logits, params.temperature, rng)
    };
    idx as u32
}

struct PendingReply {
    reply: Sender<Response>,
    arrived: Instant,
}

/// The continuous-batching worker loop: pull requests off the shared
/// queue into the scheduler core, drive `tick()` until idle, reply per
/// completion.  Several scheduler workers may compete on one queue;
/// each request is answered exactly once — success, timeout (partial
/// result), or error.
pub fn scheduler_loop<E: SlotEngine>(
    engine: E,
    rx: Arc<Mutex<Receiver<Request>>>,
    cfg: SchedulerConfig,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
) {
    let mut core = Scheduler::new(engine, WallClock::default(), cfg);
    let mut pending: HashMap<u64, PendingReply> = HashMap::new();
    let mut last = SchedStats::default();
    let mut last_hists = SchedHists::default();
    loop {
        if !running.load(Ordering::Relaxed) {
            fail_pending(&mut core, &mut pending, &metrics, "server shutting down");
            if let Ok(guard) = rx.lock() {
                while let Ok(req) = guard.try_recv() {
                    metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                    let us = req.arrived.elapsed().as_micros() as u64;
                    let _ = req.reply.send(Response::err("server shutting down", us));
                }
            }
            break;
        }

        let mut disconnected = false;
        if core.is_idle() {
            // idle: block (bounded) for the first request, then top up
            // one wave of lookahead while the lock is already held
            let Ok(guard) = rx.lock() else {
                // poisoned pool lock: answer what this worker owes
                // before bailing — never a silent drop, never a
                // silent count (the loop exits before the next stats
                // flush, so the counter is bumped directly)
                metrics.queue_lock_poisoned.fetch_add(1, Ordering::Relaxed);
                fail_pending(&mut core, &mut pending, &metrics, "server worker pool failed");
                break;
            };
            match guard.recv_timeout(SHUTDOWN_POLL) {
                Ok(req) => submit_request(&mut core, &mut pending, &metrics, req),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => disconnected = true,
            }
            while !disconnected && core.queue_len() < core.free_slots() {
                match guard.try_recv() {
                    Ok(req) => submit_request(&mut core, &mut pending, &metrics, req),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => disconnected = true,
                }
            }
        } else if core.queue_len() < core.free_slots() {
            // decoding: never block on the queue lock — an idle
            // neighbour worker holds it for a full SHUTDOWN_POLL while
            // it waits, and a decode tick must not stall behind that
            // (skipped top-ups retry next tick).  Lookahead is bounded
            // by *free* slots: a fully-busy worker pulls nothing, so a
            // request is never stranded behind this worker's long
            // decodes while an idle neighbour could admit it at once.
            match rx.try_lock() {
                Ok(guard) => {
                    while core.queue_len() < core.free_slots() {
                        match guard.try_recv() {
                            Ok(req) => submit_request(&mut core, &mut pending, &metrics, req),
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => {
                                disconnected = true;
                                break;
                            }
                        }
                    }
                }
                Err(TryLockError::WouldBlock) => {}
                Err(TryLockError::Poisoned(_)) => {
                    metrics.queue_lock_poisoned.fetch_add(1, Ordering::Relaxed);
                    fail_pending(&mut core, &mut pending, &metrics, "server worker pool failed");
                    break;
                }
            }
        }
        if core.is_idle() {
            if disconnected {
                break;
            }
            continue;
        }

        let completions = core.tick();
        // flush this tick's counter deltas *before* the replies go out:
        // a client that just read its reply must observe the metrics
        // that include its own decode
        flush_sched_metrics(&core, &metrics, &mut last, &mut last_hists);
        if !completions.is_empty() {
            // reply phase: render + send every completion of this tick
            let t_reply = Instant::now();
            for c in completions {
                respond(&metrics, &mut pending, c);
            }
            metrics.reply_calls.fetch_add(1, Ordering::Relaxed);
            metrics.reply_ns.fetch_add(t_reply.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }
}

/// Flush the core's cumulative counters and histogram buckets into the
/// shared [`Metrics`] as deltas against the previous flush (`last` /
/// `last_hists`, updated in place).  Shared by [`scheduler_loop`] and
/// [`supervised_scheduler_loop`]: only counters that moved this tick
/// pay an atomic add.
fn flush_sched_metrics<E: SlotEngine, C: Clock>(
    core: &Scheduler<E, C>,
    metrics: &Metrics,
    last: &mut SchedStats,
    last_hists: &mut SchedHists,
) {
    let s = core.stats;
    let slots = core.slots() as u64;
    metrics.slot_ticks.fetch_add((s.ticks - last.ticks) * slots, Ordering::Relaxed);
    metrics
        .slot_busy_ticks
        .fetch_add(s.busy_slot_ticks - last.busy_slot_ticks, Ordering::Relaxed);
    metrics.refills.fetch_add(s.refills - last.refills, Ordering::Relaxed);
    metrics.timeouts.fetch_add(s.timeouts - last.timeouts, Ordering::Relaxed);
    metrics.decode_batches.fetch_add(s.step_ticks - last.step_ticks, Ordering::Relaxed);
    metrics
        .decode_batch_rows
        .fetch_add(s.stepped_rows - last.stepped_rows, Ordering::Relaxed);
    metrics.fused_rows.fetch_add(s.fused_rows - last.fused_rows, Ordering::Relaxed);
    metrics
        .prefix_hit_tokens
        .fetch_add(s.prefix_hit_tokens - last.prefix_hit_tokens, Ordering::Relaxed);
    metrics
        .prefix_miss_tokens
        .fetch_add(s.prefix_miss_tokens - last.prefix_miss_tokens, Ordering::Relaxed);
    metrics
        .prefix_evictions
        .fetch_add(s.prefix_evictions - last.prefix_evictions, Ordering::Relaxed);
    metrics
        .prefix_lock_poisoned
        .fetch_add(s.prefix_lock_poisoned - last.prefix_lock_poisoned, Ordering::Relaxed);
    metrics
        .queue_lock_poisoned
        .fetch_add(s.queue_lock_poisoned - last.queue_lock_poisoned, Ordering::Relaxed);
    metrics.trace_dropped.fetch_add(s.trace_dropped - last.trace_dropped, Ordering::Relaxed);
    metrics.profiled_ticks.fetch_add(s.profiled_ticks - last.profiled_ticks, Ordering::Relaxed);
    metrics.sched_admit_ns.fetch_add(s.admit_ns - last.admit_ns, Ordering::Relaxed);
    metrics.sched_step_ns.fetch_add(s.step_ns - last.step_ns, Ordering::Relaxed);
    metrics.sched_expire_ns.fetch_add(s.expire_ns - last.expire_ns, Ordering::Relaxed);
    metrics.sched_tick_ns.fetch_add(s.tick_ns - last.tick_ns, Ordering::Relaxed);
    metrics
        .engine_prefill_calls
        .fetch_add(s.engine_prefill_calls - last.engine_prefill_calls, Ordering::Relaxed);
    metrics
        .engine_prefill_ns
        .fetch_add(s.engine_prefill_ns - last.engine_prefill_ns, Ordering::Relaxed);
    metrics
        .engine_step_sampled
        .fetch_add(s.engine_step_sampled - last.engine_step_sampled, Ordering::Relaxed);
    metrics
        .engine_step_ns
        .fetch_add(s.engine_step_ns - last.engine_step_ns, Ordering::Relaxed);
    metrics.spec_drafted.fetch_add(s.spec_drafted - last.spec_drafted, Ordering::Relaxed);
    metrics.spec_accepted.fetch_add(s.spec_accepted - last.spec_accepted, Ordering::Relaxed);
    metrics.spec_rejected.fetch_add(s.spec_rejected - last.spec_rejected, Ordering::Relaxed);
    metrics.spec_bonus.fetch_add(s.spec_bonus - last.spec_bonus, Ordering::Relaxed);
    metrics
        .spec_verify_passes
        .fetch_add(s.spec_verify_passes - last.spec_verify_passes, Ordering::Relaxed);
    metrics
        .spec_rolled_back_rows
        .fetch_add(s.spec_rolled_back_rows - last.spec_rolled_back_rows, Ordering::Relaxed);
    metrics
        .spec_fallback_rows
        .fetch_add(s.spec_fallback_rows - last.spec_fallback_rows, Ordering::Relaxed);
    *last = s;
    // same delta-flush pattern for the phase histograms: only buckets
    // touched this tick pay an atomic add
    let h = core.hists;
    metrics.ttft.merge_delta(&h.ttft_us, &last_hists.ttft_us);
    metrics.itl.merge_delta(&h.itl_us, &last_hists.itl_us);
    metrics.queue_wait.merge_delta(&h.queue_wait_us, &last_hists.queue_wait_us);
    metrics.prefill.merge_delta(&h.prefill_us, &last_hists.prefill_us);
    metrics.tick.merge_delta(&h.tick_us, &last_hists.tick_us);
    *last_hists = h;
}

/// Answer everything this worker still owes — in-flight rows and
/// requests queued in its core — with an error reply.  Used on
/// shutdown and on pool failure (poisoned queue lock): the
/// exactly-once reply contract holds even on the exit paths.
fn fail_pending<E: SlotEngine, C: Clock>(
    core: &mut Scheduler<E, C>,
    pending: &mut HashMap<u64, PendingReply>,
    metrics: &Metrics,
    msg: &str,
) {
    for c in core.abort_all(msg) {
        if let Some(p) = pending.remove(&c.id) {
            metrics.errors.fetch_add(1, Ordering::Relaxed);
            let us = p.arrived.elapsed().as_micros() as u64;
            let _ = p.reply.send(Response::err(msg, us));
        }
    }
}

fn submit_request<E: SlotEngine, C: Clock>(
    core: &mut Scheduler<E, C>,
    pending: &mut HashMap<u64, PendingReply>,
    metrics: &Metrics,
    req: Request,
) {
    metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
    let id = core.submit(Job {
        prompt: req.prompt,
        params: req.params,
        timeout_ms: req.timeout_ms,
        // deadline budget counts from arrival, not worker pickup
        queued_for_ms: req.arrived.elapsed().as_millis() as u64,
    });
    pending.insert(id, PendingReply { reply: req.reply, arrived: req.arrived });
}

fn respond(metrics: &Metrics, pending: &mut HashMap<u64, PendingReply>, c: Completion) {
    let Some(p) = pending.remove(&c.id) else { return };
    let latency = p.arrived.elapsed();
    let us = latency.as_micros() as u64;
    let resp = match c.reason {
        FinishReason::Error(msg) => {
            metrics.errors.fetch_add(1, Ordering::Relaxed);
            Response::err(msg, us)
        }
        FinishReason::Timeout => {
            metrics.record_latency(latency);
            metrics.responses.fetch_add(1, Ordering::Relaxed);
            metrics.tokens_out.fetch_add(c.tokens.len() as u64, Ordering::Relaxed);
            Response::timed_out(c.tokens, us)
        }
        FinishReason::Done => {
            metrics.record_latency(latency);
            metrics.responses.fetch_add(1, Ordering::Relaxed);
            metrics.tokens_out.fetch_add(c.tokens.len() as u64, Ordering::Relaxed);
            Response::ok(c.tokens, us)
        }
    };
    let _ = p.reply.send(resp);
}

/// Best-effort human-readable panic payload: in practice panics carry
/// a `&str` or a `String`; anything else is reported opaquely.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "opaque panic payload"
    }
}

/// One supervised serving epoch: pull requests off the shared queue
/// into the core, drive `tick()`, flush metric deltas, reply per
/// completion.  Returns on shutdown or on queue closure with the queue
/// drained; a panic anywhere inside (engine, sampling, bookkeeping)
/// unwinds to [`supervised_scheduler_loop`], which recovers and calls
/// back in.
fn pump<E: SlotEngine>(
    core: &mut Scheduler<E, WallClock>,
    pending: &mut HashMap<u64, PendingReply>,
    last: &mut SchedStats,
    last_hists: &mut SchedHists,
    queue: &SharedQueue,
    metrics: &Metrics,
    running: &AtomicBool,
) {
    loop {
        // a sibling worker panicking while holding the queue lock is
        // absorbed by SharedQueue and surfaced here as a counter, not
        // as this worker's death
        core.stats.queue_lock_poisoned += queue.take_recovered();
        if !running.load(Ordering::Relaxed) {
            fail_pending(core, pending, metrics, "server shutting down");
            while let Some(req) = queue.try_pop() {
                metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                let us = req.arrived.elapsed().as_micros() as u64;
                let _ = req.reply.send(Response::err("server shutting down", us));
            }
            // counters folded since the last tick (idle-phase poison
            // recoveries) must not die with the loop
            flush_sched_metrics(core, metrics, last, last_hists);
            return;
        }
        if core.is_idle() {
            // idle: block (bounded) for the first request so shutdown
            // stays responsive
            match queue.pop_timeout(SHUTDOWN_POLL) {
                Some(req) => submit_request(core, pending, metrics, req),
                None => {
                    if queue.is_closed() && queue.is_empty() {
                        flush_sched_metrics(core, metrics, last, last_hists);
                        return;
                    }
                    continue;
                }
            }
        }
        // top up one wave of lookahead, bounded by *free* slots: a
        // fully-busy worker pulls nothing, so a request is never
        // stranded behind this worker's long decodes while an idle
        // neighbour could admit it at once
        while core.queue_len() < core.free_slots() {
            match queue.try_pop() {
                Some(req) => submit_request(core, pending, metrics, req),
                None => break,
            }
        }
        if core.is_idle() {
            continue;
        }
        let completions = core.tick();
        // flush this tick's counter deltas *before* the replies go
        // out: a client that just read its reply must observe the
        // metrics that include its own decode
        flush_sched_metrics(core, metrics, last, last_hists);
        if !completions.is_empty() {
            // reply phase: render + send every completion of this tick
            let t_reply = Instant::now();
            for c in completions {
                respond(metrics, pending, c);
            }
            metrics.reply_calls.fetch_add(1, Ordering::Relaxed);
            metrics.reply_ns.fetch_add(t_reply.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }
}

/// The panic-isolated worker loop: [`pump`] runs under `catch_unwind`,
/// and a panic anywhere inside it — a poisoned engine assertion, a
/// scripted chaos fault, a bug — is contained to *this* worker.  The
/// supervisor then:
///
/// 1. answers every request this worker owes with an error reply
///    ([`Scheduler::recover_after_panic`] — active rows carry their
///    partial tokens; the `"supervisor"` reason lands in the span
///    ring) and quarantines every active slot
///    ([`SlotEngine::quarantine_slot`]);
/// 2. runs the engine-wide repair hook ([`SlotEngine::recover`]),
///    itself under `catch_unwind` — a failed or panicking repair
///    retires the worker instead of looping on a corrupt engine;
/// 3. re-enters the serving loop, up to `max_respawns` times
///    ([`DEFAULT_MAX_RESPAWNS`]), so a crash loop cannot spin forever.
///
/// Siblings on the same [`SharedQueue`] are unaffected throughout —
/// the queue recovers from poisoning instead of propagating it.
/// `worker_panics` / `respawns` / `quarantined_slots` count each stage
/// in [`Metrics`].
pub fn supervised_scheduler_loop<E: SlotEngine>(
    engine: E,
    queue: Arc<SharedQueue>,
    cfg: SchedulerConfig,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
    max_respawns: u64,
) {
    let mut core = Scheduler::new(engine, WallClock::default(), cfg);
    let mut pending: HashMap<u64, PendingReply> = HashMap::new();
    let mut last = SchedStats::default();
    let mut last_hists = SchedHists::default();
    let mut respawns = 0u64;
    loop {
        let outcome = panic::catch_unwind(AssertUnwindSafe(|| {
            pump(&mut core, &mut pending, &mut last, &mut last_hists, &queue, &metrics, &running)
        }));
        let payload = match outcome {
            Ok(()) => break, // clean exit: shutdown or queue closed
            Err(payload) => payload,
        };
        let what = panic_message(payload.as_ref());
        metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
        let (completions, quarantined) =
            core.recover_after_panic(&format!("worker panicked: {what}"));
        metrics.quarantined_slots.fetch_add(quarantined as u64, Ordering::Relaxed);
        for c in completions {
            respond(&metrics, &mut pending, c);
        }
        // completions computed before the panic but not yet sent died
        // on pump's stack; their pending entries are all that is left
        // of them — the reply contract is absolute, so answer those
        // too
        for (_, p) in pending.drain() {
            metrics.errors.fetch_add(1, Ordering::Relaxed);
            let us = p.arrived.elapsed().as_micros() as u64;
            let _ = p.reply.send(Response::err(format!("worker panicked: {what}"), us));
        }
        // recover_after_panic reset the core's stats/hists in place:
        // re-anchor the delta baselines or the next flush re-counts
        // history
        last = core.stats;
        last_hists = core.hists;
        if respawns >= max_respawns {
            eprintln!("scheduler worker exceeded {max_respawns} respawns; retiring");
            break;
        }
        // engine-wide repair, itself guarded: recovery code that
        // panics (e.g. a pool invariant audit failing) must retire
        // the worker, not kill the supervisor
        match panic::catch_unwind(AssertUnwindSafe(|| core.engine_mut().recover())) {
            Ok(Ok(())) => {
                respawns += 1;
                metrics.respawns.fetch_add(1, Ordering::Relaxed);
            }
            Ok(Err(e)) => {
                eprintln!("scheduler worker engine unrecoverable: {e:#}");
                break;
            }
            Err(_) => {
                eprintln!("scheduler worker engine recovery panicked; retiring");
                break;
            }
        }
    }
    // safety net for the retirement paths: everything still owed gets
    // an error reply (no-op after a clean pump exit)
    fail_pending(&mut core, &mut pending, &metrics, "server worker pool failed");
}

/// Run the server with the continuous-batching scheduler driving every
/// worker — the native-backend counterpart of [`super::serve::serve`]
/// (which keeps the static batcher for the XLA path), with default
/// connection hardening and panic supervision
/// ([`serve_continuous_with`] exposes the knobs).
pub fn serve_continuous<E: SlotEngine>(
    factory: impl Fn() -> Result<E> + Send + Sync + 'static,
    addr: &str,
    queue_cap: usize,
    cfg: SchedulerConfig,
    workers: usize,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
) -> Result<std::net::SocketAddr> {
    serve_continuous_with(
        factory,
        addr,
        queue_cap,
        cfg,
        workers,
        metrics,
        running,
        ConnConfig::default(),
        DEFAULT_MAX_RESPAWNS,
    )
}

/// [`serve_continuous`] with explicit connection-hardening and
/// supervision knobs.  Each worker builds its own engine via `factory`
/// on its own thread and runs [`supervised_scheduler_loop`] against
/// one poison-tolerant [`SharedQueue`]; the accept loop applies
/// `conn`'s read/write timeouts, line cap, and idle reaping to every
/// connection.
#[allow(clippy::too_many_arguments)] // a knob bundle, every caller names them in order
pub fn serve_continuous_with<E: SlotEngine>(
    factory: impl Fn() -> Result<E> + Send + Sync + 'static,
    addr: &str,
    queue_cap: usize,
    cfg: SchedulerConfig,
    workers: usize,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
    conn: ConnConfig,
    max_respawns: u64,
) -> Result<std::net::SocketAddr> {
    // bind before spawning anything: a bad --addr must fail fast, not
    // after every worker has spent seconds building its engine
    let (listener, local) = bind_listener(addr)?;
    let queue = Arc::new(SharedQueue::new());
    let factory = Arc::new(factory);
    for w in 0..workers.max(1) {
        let q = queue.clone();
        let cfg = cfg.clone();
        let m = metrics.clone();
        let r = running.clone();
        let f = factory.clone();
        std::thread::Builder::new()
            .name(format!("sched-worker-{w}"))
            .spawn(move || match f() {
                Ok(engine) => {
                    // one sampling stream base per worker — the pool
                    // builds every engine from one factory
                    let mut cfg = cfg;
                    cfg.seed = cfg.seed.wrapping_add(w as u64);
                    supervised_scheduler_loop(engine, q, cfg, m, r, max_respawns)
                }
                Err(e) => eprintln!("engine init failed: {e:#}"),
            })
            .context("spawning scheduler worker")?;
    }
    spawn_accept_loop(listener, queue, metrics, queue_cap, running, conn);
    Ok(local)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal scripted engine: request key = prompt[0]; emits the key
    /// until the scripted EOS position, then the EOS token.
    struct TinyGen {
        slots: usize,
        eos: u32,
        /// key -> content tokens before EOS
        lens: Vec<(u32, usize)>,
        state: Vec<Option<(u32, usize)>>,
    }

    impl TinyGen {
        fn new(slots: usize, eos: u32, lens: Vec<(u32, usize)>) -> TinyGen {
            TinyGen { slots, eos, lens, state: (0..slots).map(|_| None).collect() }
        }

        fn logits(&self, key: u32, emitted: usize) -> Vec<f32> {
            let n = self.lens.iter().find(|(k, _)| *k == key).map(|(_, n)| *n).unwrap();
            let mut l = vec![0.0f32; 64];
            let target = if emitted >= n { self.eos } else { key };
            l[target as usize] = 10.0;
            l
        }
    }

    impl SlotEngine for TinyGen {
        fn slots(&self) -> usize {
            self.slots
        }

        fn prefill_slot(&mut self, slot: usize, prompt: &[u32]) -> Result<Vec<f32>> {
            anyhow::ensure!(!prompt.is_empty(), "empty prompt");
            let key = prompt[0];
            self.state[slot] = Some((key, 0));
            Ok(self.logits(key, 0))
        }

        fn step_slot(&mut self, slot: usize, _token: u32) -> Result<Vec<f32>> {
            let (key, emitted) = self.state[slot].expect("step before prefill");
            self.state[slot] = Some((key, emitted + 1));
            Ok(self.logits(key, emitted + 1))
        }

        fn step_slots_atomic(&self) -> bool {
            // step_slot is infallible, so the default batched loop
            // trivially never fails mid-batch — the scheduler may use
            // the batched path
            true
        }

        fn reset_slot(&mut self, slot: usize) {
            self.state[slot] = None;
        }
    }

    fn drain<E: SlotEngine, C: Clock>(core: &mut Scheduler<E, C>) -> Vec<Completion> {
        let mut out = Vec::new();
        let mut guard = 0;
        while !core.is_idle() {
            out.extend(core.tick());
            guard += 1;
            assert!(guard < 10_000, "scheduler failed to drain");
        }
        out
    }

    fn greedy_stop(max_tokens: usize, eos: u32) -> DecodeParams {
        DecodeParams { stop: Some(eos), ..DecodeParams::greedy(max_tokens) }
    }

    fn job(key: u32, params: DecodeParams) -> Job {
        Job { prompt: vec![key], params, timeout_ms: None, queued_for_ms: 0 }
    }

    #[test]
    fn single_request_decodes_to_eos() {
        let eos = 63;
        let gen = TinyGen::new(1, eos, vec![(7, 3)]);
        let cfg = SchedulerConfig { slots: 1, trace: true, ..Default::default() };
        let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
        let id = core.submit(job(7, greedy_stop(16, eos)));
        let done = drain(&mut core);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, id);
        assert_eq!(done[0].tokens, vec![7, 7, 7, eos]);
        assert_eq!(done[0].reason, FinishReason::Done);
        assert_eq!(core.stats.ticks, 4, "one token per tick");
        assert_eq!(core.stats.busy_slot_ticks, 4);
        assert_eq!(core.stats.refills, 0);
    }

    #[test]
    fn budget_caps_before_eos() {
        let eos = 63;
        let gen = TinyGen::new(1, eos, vec![(5, 100)]);
        let mut core =
            Scheduler::new(gen, ManualClock::default(), SchedulerConfig::default());
        core.submit(job(5, greedy_stop(4, eos)));
        let done = drain(&mut core);
        assert_eq!(done[0].tokens, vec![5, 5, 5, 5]);
        assert_eq!(done[0].reason, FinishReason::Done);
    }

    #[test]
    fn upstream_wait_counts_against_the_deadline() {
        let eos = 63;
        let gen = TinyGen::new(1, eos, vec![(9, 100)]);
        let mut core =
            Scheduler::new(gen, ManualClock::default(), SchedulerConfig::default());
        // 10ms budget already fully spent in the shared server queue:
        // expires on the first tick, before taking a slot
        core.submit(Job {
            prompt: vec![9],
            params: greedy_stop(50, eos),
            timeout_ms: Some(10),
            queued_for_ms: 10,
        });
        let done = drain(&mut core);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].reason, FinishReason::Timeout);
        assert!(done[0].tokens.is_empty());
        assert_eq!(core.stats.admissions, 0, "never occupied a slot");
    }

    #[test]
    fn zero_budget_completes_without_a_slot() {
        let eos = 63;
        let gen = TinyGen::new(1, eos, vec![(5, 3)]);
        let cfg = SchedulerConfig { trace: true, ..Default::default() };
        let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
        core.submit(job(5, greedy_stop(0, eos)));
        let done = drain(&mut core);
        assert_eq!(done.len(), 1);
        assert!(done[0].tokens.is_empty());
        assert_eq!(done[0].reason, FinishReason::Done);
        assert_eq!(core.stats.admissions, 0, "never occupied a slot");
        assert!(core.trace().is_empty());
    }

    #[test]
    fn slots_clamped_to_engine_capacity() {
        let gen = TinyGen::new(2, 63, vec![]);
        let cfg = SchedulerConfig { slots: 8, ..Default::default() };
        let core = Scheduler::new(gen, ManualClock::default(), cfg);
        assert_eq!(core.slots(), 2);
        assert_eq!(core.free_slots(), 2);
    }

    #[test]
    fn prefill_error_degrades_to_error_completion() {
        struct FailGen;
        impl SlotEngine for FailGen {
            fn slots(&self) -> usize {
                1
            }
            fn prefill_slot(&mut self, _s: usize, _p: &[u32]) -> Result<Vec<f32>> {
                anyhow::bail!("injected prefill failure")
            }
            fn step_slot(&mut self, _s: usize, _t: u32) -> Result<Vec<f32>> {
                unreachable!()
            }
            fn reset_slot(&mut self, _s: usize) {}
        }
        let mut core =
            Scheduler::new(FailGen, ManualClock::default(), SchedulerConfig::default());
        core.submit(job(1, DecodeParams::greedy(4)));
        let done = drain(&mut core);
        assert_eq!(done.len(), 1);
        match &done[0].reason {
            FinishReason::Error(msg) => assert!(msg.contains("injected"), "{msg}"),
            other => panic!("expected error completion, got {other:?}"),
        }
    }

    #[test]
    fn abort_answers_queued_and_active() {
        let eos = 63;
        let gen = TinyGen::new(1, eos, vec![(1, 50), (2, 50)]);
        let mut core =
            Scheduler::new(gen, ManualClock::default(), SchedulerConfig::default());
        core.submit(job(1, greedy_stop(50, eos)));
        core.submit(job(2, greedy_stop(50, eos)));
        let ticked = core.tick();
        assert!(ticked.is_empty());
        let done = core.abort_all("server shutting down");
        assert_eq!(done.len(), 2, "active + queued both answered");
        assert!(done
            .iter()
            .all(|c| matches!(&c.reason, FinishReason::Error(m) if m.contains("shutting"))));
        assert!(core.is_idle());
    }

    /// Two rows decoding together advance through one fused call per
    /// tick: the step counters account the batch sizes exactly.
    #[test]
    fn fused_step_counters_account_batches() {
        let eos = 63;
        let gen = TinyGen::new(2, eos, vec![(1, 4), (2, 4)]);
        let cfg = SchedulerConfig { slots: 2, ..Default::default() };
        let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
        core.submit(job(1, greedy_stop(8, eos)));
        core.submit(job(2, greedy_stop(8, eos)));
        let done = drain(&mut core);
        assert_eq!(done.len(), 2);
        // tick 1 admits both (fresh: prefill token, no step); ticks 2-5
        // step both rows to their 5-token streams
        assert_eq!(core.stats.step_ticks, 4);
        assert_eq!(core.stats.stepped_rows, 8);
        assert_eq!(core.stats.fused_rows, 8, "both rows shared every batched step");

        // a lone request never fuses
        let gen = TinyGen::new(2, eos, vec![(1, 4)]);
        let cfg = SchedulerConfig { slots: 2, ..Default::default() };
        let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
        core.submit(job(1, greedy_stop(8, eos)));
        drain(&mut core);
        assert_eq!(core.stats.step_ticks, 4);
        assert_eq!(core.stats.stepped_rows, 4);
        assert_eq!(core.stats.fused_rows, 0, "single-row ticks are not fused");
    }

    /// The block-pool admission gate defers a queued request while the
    /// engine reports no headroom ([`SlotEngine::can_admit`]) — but
    /// never when every slot is idle, so the queue always drains even
    /// against an engine that claims permanent exhaustion.
    #[test]
    fn pool_gate_defers_but_never_wedges() {
        struct Gated(TinyGen);
        impl SlotEngine for Gated {
            fn slots(&self) -> usize {
                self.0.slots()
            }
            fn prefill_slot(&mut self, s: usize, p: &[u32]) -> Result<Vec<f32>> {
                self.0.prefill_slot(s, p)
            }
            fn step_slot(&mut self, s: usize, t: u32) -> Result<Vec<f32>> {
                self.0.step_slot(s, t)
            }
            fn reset_slot(&mut self, s: usize) {
                self.0.reset_slot(s)
            }
            fn can_admit(&self, _prompt_tokens: usize) -> bool {
                // pool permanently "full": only the all-idle bypass
                // lets anything through
                false
            }
        }
        let eos = 63;
        let gen = Gated(TinyGen::new(2, eos, vec![(1, 2), (2, 2)]));
        let cfg = SchedulerConfig { slots: 2, ..Default::default() };
        let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
        core.submit(job(1, greedy_stop(8, eos)));
        core.submit(job(2, greedy_stop(8, eos)));
        let done = drain(&mut core);
        assert_eq!(done.len(), 2, "deferred request still completes");
        assert_eq!(done[0].tokens, vec![1, 1, eos]);
        assert_eq!(done[1].tokens, vec![2, 2, eos]);
        // ticks 1-3 carry request 1; request 2 is popped and pushed
        // back each of those ticks, then admitted into the idle engine
        assert_eq!(core.stats.admit_deferred, 3);
        assert_eq!(core.stats.refills, 0, "gate blocked every mid-flight refill");
        assert_eq!(core.stats.admissions, 2);
    }

    /// EDF admission: with both queued, the tighter deadline wins the
    /// slot even though the loose request arrived first; no-deadline
    /// requests rank last.
    #[test]
    fn edf_prefers_earliest_deadline() {
        let eos = 63;
        let gen = TinyGen::new(1, eos, vec![(1, 1), (2, 1)]);
        let cfg = SchedulerConfig { slots: 1, trace: true, ..Default::default() };
        let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
        let loose = core.submit(job(1, greedy_stop(8, eos)));
        let tight = core.submit(Job {
            prompt: vec![2],
            params: greedy_stop(8, eos),
            timeout_ms: Some(1_000),
            queued_for_ms: 0,
        });
        let done = drain(&mut core);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, tight, "deadline request admitted first");
        assert_eq!(done[0].tokens, vec![2, eos]);
        assert_eq!(done[1].id, loose);
        let admits: Vec<u64> = core
            .take_trace()
            .into_iter()
            .filter_map(|ev| match ev {
                TraceEvent::Admit { id, .. } => Some(id),
                _ => None,
            })
            .collect();
        assert_eq!(admits, vec![tight, loose]);
    }

    /// FCFS survives as the EDF tie-break: equal deadlines (and the
    /// no-deadline case, exercised everywhere else) admit in arrival
    /// order.
    #[test]
    fn edf_ties_stay_fcfs() {
        let eos = 63;
        let gen = TinyGen::new(1, eos, vec![(1, 1), (2, 1)]);
        let mut core =
            Scheduler::new(gen, ManualClock::default(), SchedulerConfig::default());
        let first = core.submit(Job {
            prompt: vec![1],
            params: greedy_stop(8, eos),
            timeout_ms: Some(500),
            queued_for_ms: 0,
        });
        let second = core.submit(Job {
            prompt: vec![2],
            params: greedy_stop(8, eos),
            timeout_ms: Some(500),
            queued_for_ms: 0,
        });
        let done = drain(&mut core);
        assert_eq!(done.iter().map(|c| c.id).collect::<Vec<_>>(), vec![first, second]);
    }

    /// A batched step failure is retried row by row: only the slot
    /// whose individual step also fails degrades to an error reply —
    /// its neighbour's stream is untouched.
    #[test]
    fn step_failure_is_isolated_per_row() {
        /// `step_slots` always errs without stepping; `step_slot` fails
        /// only for the poisoned key.
        struct FlakyGen {
            inner: TinyGen,
            fail_key: u32,
        }
        impl SlotEngine for FlakyGen {
            fn slots(&self) -> usize {
                self.inner.slots()
            }
            fn prefill_slot(&mut self, slot: usize, prompt: &[u32]) -> Result<Vec<f32>> {
                self.inner.prefill_slot(slot, prompt)
            }
            fn step_slot(&mut self, slot: usize, token: u32) -> Result<Vec<f32>> {
                let (key, _) = self.inner.state[slot].expect("step before prefill");
                anyhow::ensure!(key != self.fail_key, "injected step failure for {key}");
                self.inner.step_slot(slot, token)
            }
            fn step_slots(&mut self, _steps: &[(usize, u32)]) -> Result<Vec<Vec<f32>>> {
                anyhow::bail!("fused path unavailable")
            }
            fn step_slots_atomic(&self) -> bool {
                // the override above fails without stepping anything,
                // so the per-row retry is sound
                true
            }
            fn reset_slot(&mut self, slot: usize) {
                self.inner.reset_slot(slot)
            }
        }

        let eos = 63;
        let gen = FlakyGen { inner: TinyGen::new(2, eos, vec![(1, 3), (2, 3)]), fail_key: 1 };
        let cfg = SchedulerConfig { slots: 2, ..Default::default() };
        let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
        let bad = core.submit(job(1, greedy_stop(8, eos)));
        let good = core.submit(job(2, greedy_stop(8, eos)));
        let done = drain(&mut core);
        assert_eq!(done.len(), 2);
        let bad_c = done.iter().find(|c| c.id == bad).unwrap();
        assert!(
            matches!(&bad_c.reason, FinishReason::Error(m) if m.contains("injected")),
            "{:?}",
            bad_c.reason
        );
        assert_eq!(bad_c.tokens, vec![1], "kept the prefill token decoded before the failure");
        let good_c = done.iter().find(|c| c.id == good).unwrap();
        assert_eq!(good_c.reason, FinishReason::Done);
        assert_eq!(good_c.tokens, vec![2, 2, 2, eos], "neighbour stream disturbed");
    }

    /// An engine without the atomic-batch guarantee (the trait
    /// default) never sees a batched call: the scheduler steps its
    /// rows individually, so one slot's failure is still isolated —
    /// and nothing counts as fused throughput.
    #[test]
    fn non_atomic_engine_keeps_per_row_isolation_without_fusing() {
        struct FragileGen {
            inner: TinyGen,
            fail_key: u32,
            batched_calls: usize,
        }
        impl SlotEngine for FragileGen {
            fn slots(&self) -> usize {
                self.inner.slots()
            }
            fn prefill_slot(&mut self, slot: usize, prompt: &[u32]) -> Result<Vec<f32>> {
                self.inner.prefill_slot(slot, prompt)
            }
            fn step_slot(&mut self, slot: usize, token: u32) -> Result<Vec<f32>> {
                let (key, _) = self.inner.state[slot].expect("step before prefill");
                anyhow::ensure!(key != self.fail_key, "injected step failure for {key}");
                self.inner.step_slot(slot, token)
            }
            fn step_slots(&mut self, steps: &[(usize, u32)]) -> Result<Vec<Vec<f32>>> {
                self.batched_calls += 1;
                steps.iter().map(|&(slot, token)| self.step_slot(slot, token)).collect()
            }
            // default `step_slots_atomic()` == false: the batched call
            // above can fail after mutating earlier rows
            fn reset_slot(&mut self, slot: usize) {
                self.inner.reset_slot(slot)
            }
        }

        let eos = 63;
        let gen = FragileGen {
            inner: TinyGen::new(2, eos, vec![(1, 3), (2, 3)]),
            fail_key: 1,
            batched_calls: 0,
        };
        let cfg = SchedulerConfig { slots: 2, ..Default::default() };
        let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
        let bad = core.submit(job(1, greedy_stop(8, eos)));
        let good = core.submit(job(2, greedy_stop(8, eos)));
        let done = drain(&mut core);
        assert_eq!(done.len(), 2, "both requests answered exactly once");
        assert_eq!(
            core.engine().batched_calls, 0,
            "a non-atomic engine must never receive the batched call"
        );
        let bad_c = done.iter().find(|c| c.id == bad).unwrap();
        assert!(matches!(&bad_c.reason, FinishReason::Error(m) if m.contains("injected")));
        assert_eq!(bad_c.tokens, vec![1]);
        let good_c = done.iter().find(|c| c.id == good).unwrap();
        assert_eq!(good_c.reason, FinishReason::Done);
        assert_eq!(good_c.tokens, vec![2, 2, 2, eos], "neighbour stream disturbed");
        assert_eq!(core.stats.fused_rows, 0, "row-by-row stepping is not fused throughput");
        assert_eq!(core.stats.stepped_rows, 3, "good's three decode steps still count");
    }

    #[test]
    fn manual_clock_advances() {
        let c = ManualClock::default();
        assert_eq!(c.now_ms(), 0);
        c.advance(5);
        assert_eq!(c.now_ms(), 5);
        c.set(100);
        assert_eq!(c.now_ms(), 100);
    }

    #[test]
    fn recover_after_panic_answers_queued_and_active_exactly_once() {
        let eos = 63;
        let gen = TinyGen::new(2, eos, vec![(1, 50), (2, 50), (3, 50)]);
        let cfg = SchedulerConfig { slots: 2, trace: true, ..Default::default() };
        let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
        let a = core.submit(job(1, greedy_stop(50, eos)));
        let b = core.submit(job(2, greedy_stop(50, eos)));
        let c = core.submit(job(3, greedy_stop(50, eos)));
        // tick 1 admits a+b (2 slots); c stays queued
        assert!(core.tick().is_empty());
        let (done, quarantined) = core.recover_after_panic("worker panicked: boom");
        assert_eq!(quarantined, 2, "both active slots quarantined");
        assert_eq!(done.len(), 3, "active and queued all answered");
        let by_id = |id: u64| done.iter().find(|d| d.id == id).unwrap();
        assert_eq!(by_id(a).tokens, vec![1], "active row keeps its partial tokens");
        assert_eq!(by_id(b).tokens, vec![2]);
        assert!(by_id(c).tokens.is_empty(), "queued request never decoded");
        assert!(done
            .iter()
            .all(|d| matches!(&d.reason, FinishReason::Error(m) if m.contains("boom"))));
        assert!(core.is_idle());
        assert!(
            core.engine().state.iter().all(Option::is_none),
            "quarantine dropped every slot's engine state"
        );
        let spans = core.take_spans();
        assert_eq!(spans.len(), 3);
        assert!(spans.iter().all(|s| s.reason == "supervisor"));
        assert!(core
            .take_trace()
            .iter()
            .any(|ev| matches!(ev, TraceEvent::Finish { reason: "supervisor", .. })));
        // bookkeeping re-armed: stats reset, fresh work decodes fine
        assert_eq!(core.stats.ticks, 0);
        let d = core.submit(job(1, greedy_stop(8, eos)));
        let done = drain(&mut core);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, d);
        assert_eq!(done[0].reason, FinishReason::Done);
    }

    /// Scripted panic injection: panics on the N-th `step_slot` call.
    struct PanicGen {
        inner: TinyGen,
        panic_on_step: usize,
        steps: usize,
    }

    impl SlotEngine for PanicGen {
        fn slots(&self) -> usize {
            self.inner.slots()
        }
        fn prefill_slot(&mut self, slot: usize, prompt: &[u32]) -> Result<Vec<f32>> {
            self.inner.prefill_slot(slot, prompt)
        }
        fn step_slot(&mut self, slot: usize, token: u32) -> Result<Vec<f32>> {
            self.steps += 1;
            assert!(self.steps != self.panic_on_step, "scripted panic at step {}", self.steps);
            self.inner.step_slot(slot, token)
        }
        // default step_slots_atomic() == false: the scheduler steps
        // row by row through step_slot, so the panic ordinal is exact
        fn reset_slot(&mut self, slot: usize) {
            self.inner.reset_slot(slot)
        }
    }

    fn wire_request(key: u32, params: DecodeParams, metrics: &Metrics) -> (Request, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = std::sync::mpsc::channel();
        // mirrors the accept loop: depth is incremented at admission,
        // decremented by submit_request
        metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            prompt: vec![key],
            params,
            reply: tx,
            arrived: Instant::now(),
            timeout_ms: None,
        };
        (req, rx)
    }

    #[test]
    fn supervised_loop_survives_panic_and_keeps_serving() {
        let eos = 63;
        let queue = Arc::new(SharedQueue::new());
        let metrics = Arc::new(Metrics::default());
        let running = Arc::new(AtomicBool::new(true));
        let cfg = SchedulerConfig { slots: 1, ..Default::default() };
        let gen = PanicGen {
            inner: TinyGen::new(1, eos, vec![(1, 5), (2, 2)]),
            panic_on_step: 1,
            steps: 0,
        };
        let worker = {
            let (q, m, r) = (queue.clone(), metrics.clone(), running.clone());
            std::thread::spawn(move || supervised_scheduler_loop(gen, q, cfg, m, r, 4))
        };

        // the first request's first decode step panics the worker
        let (req, rx) = wire_request(1, greedy_stop(8, eos), &metrics);
        assert!(queue.push(req).is_ok());
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("exactly one reply");
        let err = resp.error.expect("panic degrades to an error reply");
        assert!(err.contains("worker panicked"), "{err}");
        assert!(err.contains("scripted panic"), "{err}");

        // the respawned worker serves the next request normally
        let (req, rx) = wire_request(2, greedy_stop(8, eos), &metrics);
        assert!(queue.push(req).is_ok());
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("served after respawn");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens, vec![2, 2, eos], "stream identical to a fault-free run");

        assert_eq!(metrics.worker_panics.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.respawns.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.quarantined_slots.load(Ordering::Relaxed), 1);

        running.store(false, Ordering::Relaxed);
        queue.close();
        worker.join().expect("supervisor thread exits cleanly");
    }

    #[test]
    fn supervisor_retires_the_worker_after_max_respawns() {
        /// Unservable engine: every prefill panics.
        struct AlwaysPanic;
        impl SlotEngine for AlwaysPanic {
            fn slots(&self) -> usize {
                1
            }
            fn prefill_slot(&mut self, _s: usize, _p: &[u32]) -> Result<Vec<f32>> {
                panic!("scripted prefill panic")
            }
            fn step_slot(&mut self, _s: usize, _t: u32) -> Result<Vec<f32>> {
                unreachable!()
            }
            fn reset_slot(&mut self, _s: usize) {}
        }
        let queue = Arc::new(SharedQueue::new());
        let metrics = Arc::new(Metrics::default());
        let running = Arc::new(AtomicBool::new(true));
        let worker = {
            let (q, m, r) = (queue.clone(), metrics.clone(), running.clone());
            let cfg = SchedulerConfig { slots: 1, ..Default::default() };
            std::thread::spawn(move || supervised_scheduler_loop(AlwaysPanic, q, cfg, m, r, 0))
        };
        let (req, rx) = wire_request(1, DecodeParams::greedy(4), &metrics);
        assert!(queue.push(req).is_ok());
        // the request was popped into the core before the panic, so
        // only the supervisor's pending drain can still answer it
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("reply before retirement");
        let err = resp.error.expect("error reply");
        assert!(err.contains("worker panicked"), "{err}");
        // max_respawns = 0: the worker retires itself without any
        // shutdown signal
        worker.join().expect("worker retired cleanly");
        assert_eq!(metrics.worker_panics.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.respawns.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pump_counts_absorbed_queue_poisoning() {
        let eos = 63;
        let queue = Arc::new(SharedQueue::new());
        queue.poison_for_chaos();
        let metrics = Arc::new(Metrics::default());
        let running = Arc::new(AtomicBool::new(true));
        let gen = TinyGen::new(1, eos, vec![(1, 2)]);
        let worker = {
            let (q, m, r) = (queue.clone(), metrics.clone(), running.clone());
            let cfg = SchedulerConfig { slots: 1, ..Default::default() };
            std::thread::spawn(move || {
                supervised_scheduler_loop(gen, q, cfg, m, r, DEFAULT_MAX_RESPAWNS)
            })
        };
        let (req, rx) = wire_request(1, greedy_stop(8, eos), &metrics);
        assert!(queue.push(req).is_ok(), "a poisoned queue still accepts work");
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("reply");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens, vec![1, 1, eos]);
        running.store(false, Ordering::Relaxed);
        queue.close();
        worker.join().expect("worker exits");
        assert!(
            metrics.queue_lock_poisoned.load(Ordering::Relaxed) >= 1,
            "the absorbed poisoning reached the shared metrics"
        );
    }
}
