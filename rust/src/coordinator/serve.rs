//! Serving stack: a TCP line-protocol server in front of a pool of
//! generation engines that drive the AOT `fwd_logits` executable.
//!
//! Topology (std threads; rust owns the event loop — python is never on
//! this path):
//!
//!   client ──TCP──▶ connection thread ──sink──▶ shared request queue
//!                                                 │ (static XLA path:
//!                                                 │  Mutex<Receiver>;
//!                                                 │  continuous path:
//!                                                 │  SharedQueue)
//!                                   worker 0 ◀────┼────▶ worker N-1
//!                                   │ fwd_logits (XLA, one engine each)
//!   client ◀──TCP── response channel ◀┘
//!
//! Each worker owns its *own* `Runtime` + `Engine` (PJRT handles are
//! not `Send`, so every engine is born on the thread that uses it) and
//! competes for batches on the shared queue: one worker at a time holds
//! the queue lock while it collects a batch, then releases it and
//! decodes, so batch collection and decoding pipeline across workers.
//! The continuous scheduler's workers instead pull single requests from
//! a poison-tolerant [`SharedQueue`] under a supervisor that catches
//! worker panics and respawns (`scheduler::supervised_scheduler_loop`);
//! the connection side is abstracted over both hand-offs by
//! [`RequestSink`].
//!
//! Connections are hardened per [`ConnConfig`]: socket read/write
//! timeouts, a hard cap on one request line (oversize → structured
//! error reply, then close), an idle reaper, and a stall policy (a peer
//! that pauses mid-line is dropped — there is no re-synchronizing a
//! half-frame stream).
//!
//! Decode state is **per request**: every row of a batch carries its
//! own `max_tokens`, `temperature`, and optional `stop` token, is
//! sampled with its own temperature, and finishes independently.  The
//! step loop exits as soon as every row is done, so a batch of short
//! requests never pays forwards up to the batch-wide maximum.
//!
//! Protocol: one JSON object per line.
//!   request:  {"prompt": [int, ...], "max_tokens": int,
//!              "temperature"?: float, "stop"?: int, "timeout_ms"?: int}
//!   response: {"tokens": [int, ...], "latency_us": int}
//!   timeout:  {"tokens": [int, ...], "latency_us": int, "timeout": true}
//!   error:    {"error": str, "latency_us": int}
//!   overload: {"error": str, "latency_us": int, "retry_after_ms": int}
//!             — shed at admission (queue full, or the request's own
//!             deadline is shorter than the estimated queue wait);
//!             `retry_after_ms` tells the client when to retry
//!   control:  {"cmd": "stats"} — answered inline by the connection
//!             thread (never queued behind decode work) with
//!             {"stats": {...}, "prometheus": str}: the full metrics
//!             JSON (`Metrics::to_json`) plus a Prometheus text
//!             exposition rendering.  Unknown commands get an error
//!             line back.
//!
//! `timeout_ms` is a per-request deadline honored by the continuous
//! scheduler (`--backend native`); a deadline-expired request gets the
//! tokens decoded so far back, flagged `"timeout": true`.  The static
//! XLA batcher ignores it (documented in rust/README.md).
//!
//! Errors are *per request*: a failed forward degrades every request of
//! the batch to an error line, never a dropped connection.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::runtime::{session::pack_decode_windows, Runtime, Session};
use crate::util::{Json, Pcg32};

use super::batcher::{next_batch_shared, BatchPolicy};
use super::metrics::Metrics;

/// Server-side ceiling on a single request's decode budget: without it
/// one request could pin a worker in the step loop indefinitely (each
/// step is a full XLA forward) and stall everything batched with it.
pub const MAX_TOKENS_CAP: usize = 4096;

/// Per-request decode parameters: each row of a batch decodes under its
/// own budget and sampling settings.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecodeParams {
    /// decode exactly this many tokens (unless `stop` fires earlier)
    pub max_tokens: usize,
    /// 0 (or negative) = greedy; otherwise softmax temperature
    pub temperature: f32,
    /// optional stop token: emitted, then the row is finished
    pub stop: Option<u32>,
    /// opt-in to the speculative decode path (`--speculate-k`); only
    /// effective for greedy rows on a speculative engine — the output
    /// stream is bit-identical either way, this knob only trades draft
    /// work for fewer dense teacher forwards.  Wire requests may opt
    /// out per request with `"speculate": false` (default true).
    pub speculate: bool,
}

impl DecodeParams {
    /// Greedy decoding for exactly `max_tokens` tokens, no stop token.
    pub fn greedy(max_tokens: usize) -> DecodeParams {
        DecodeParams { max_tokens, temperature: 0.0, stop: None, speculate: true }
    }
}

/// An in-flight request.
pub struct Request {
    /// prompt token ids (validated non-empty at parse time)
    pub prompt: Vec<u32>,
    /// per-request decode budget and sampling settings
    pub params: DecodeParams,
    /// channel the owning connection thread waits on
    pub reply: Sender<Response>,
    /// arrival instant (latency measurement + deadline origin)
    pub arrived: Instant,
    /// per-request deadline (wire field `timeout_ms`), honored by the
    /// continuous scheduler; `None` = the server default
    pub timeout_ms: Option<u64>,
}

/// One reply line: success, timeout (partial result) or error.
#[derive(Clone, Debug)]
pub struct Response {
    /// decoded tokens (empty on error)
    pub tokens: Vec<u32>,
    /// end-to-end latency in microseconds
    pub latency_us: u64,
    /// Some(message) degrades this response to an error line.
    pub error: Option<String>,
    /// deadline expired: `tokens` holds the partial result decoded
    /// before eviction (rendered as `"timeout": true`)
    pub timeout: bool,
    /// overload shed: how long the client should back off before
    /// retrying (rendered as `"retry_after_ms"` on the error line)
    pub retry_after_ms: Option<u64>,
}

impl Response {
    /// A successful reply carrying the decoded tokens.
    pub fn ok(tokens: Vec<u32>, latency_us: u64) -> Response {
        Response { tokens, latency_us, error: None, timeout: false, retry_after_ms: None }
    }

    /// An error reply (rendered as `{"error": ...}`).
    pub fn err(message: impl Into<String>, latency_us: u64) -> Response {
        Response {
            tokens: Vec::new(),
            latency_us,
            error: Some(message.into()),
            timeout: false,
            retry_after_ms: None,
        }
    }

    /// A deadline-expired reply carrying the partial result.
    pub fn timed_out(tokens: Vec<u32>, latency_us: u64) -> Response {
        Response { tokens, latency_us, error: None, timeout: true, retry_after_ms: None }
    }

    /// An overload-shed reply: an error line that also tells the
    /// client when to come back (`retry_after_ms`).
    pub fn overloaded(message: impl Into<String>, retry_after_ms: u64) -> Response {
        Response {
            tokens: Vec::new(),
            latency_us: 0,
            error: Some(message.into()),
            timeout: false,
            retry_after_ms: Some(retry_after_ms),
        }
    }
}

/// One decoded batch: per-row outputs plus the number of forward steps
/// actually run (≤ the largest row budget when rows stop early).
pub struct Generation {
    /// decoded tokens per row, in prompt order
    pub outputs: Vec<Vec<u32>>,
    /// batch forward steps actually run (early exit can trail budgets)
    pub steps: usize,
}

/// Anything that can decode a batch of per-request rows — the
/// XLA-backed `EngineWorker`, the KV-cached `infer::NativeEngine`, or
/// a test double for driving `worker_loop` without artifacts.
///
/// # Examples
///
/// A scripted generator (the shape every test double takes):
///
/// ```
/// use anyhow::Result;
/// use db_llm::coordinator::serve::{DecodeParams, Generation, Generator};
///
/// /// Echoes each row's first prompt token, `max_tokens` times.
/// struct Echo;
/// impl Generator for Echo {
///     fn generate(
///         &mut self,
///         prompts: &[Vec<u32>],
///         params: &[DecodeParams],
///     ) -> Result<Generation> {
///         let outputs: Vec<Vec<u32>> = prompts
///             .iter()
///             .zip(params)
///             .map(|(p, d)| vec![p[0]; d.max_tokens])
///             .collect();
///         let steps = params.iter().map(|d| d.max_tokens).max().unwrap_or(0);
///         Ok(Generation { outputs, steps })
///     }
/// }
///
/// let mut e = Echo;
/// let g = e.generate(&[vec![5]], &[DecodeParams::greedy(3)]).unwrap();
/// assert_eq!(g.outputs, vec![vec![5, 5, 5]]);
/// ```
pub trait Generator {
    /// Decode every row to completion under its own [`DecodeParams`]
    /// (budget, temperature, stop token), returning one output per
    /// prompt in order.  Errors fail the whole batch — the worker loop
    /// degrades each affected request to an error reply.
    fn generate(&mut self, prompts: &[Vec<u32>], params: &[DecodeParams]) -> Result<Generation>;

    /// Largest number of rows one `generate` call accepts.  The AOT
    /// executables have a fixed batch dimension; native backends are
    /// unbounded (the default).  Workers clamp their batch policy to
    /// this at startup.
    fn max_batch(&self) -> usize {
        usize::MAX
    }

    /// Move this generator's sampler onto its own stream — the pool
    /// builds every worker from one factory, so without this every
    /// worker would sample byte-identical sequences.  No-op for
    /// generators that never sample.
    fn fork_rng(&mut self, _stream: u64) {}
}

/// Generation engine over a pinned session.
pub struct Engine {
    /// the pinned-weight XLA session this engine decodes through
    pub session: Session,
    /// vocabulary size (logits row width)
    pub vocab: usize,
    rng: Pcg32,
}

impl Engine {
    /// Build over a pinned session with a seeded sampling stream.
    pub fn new(session: Session, vocab: usize, seed: u64) -> Engine {
        Engine { session, vocab, rng: Pcg32::seeded(seed) }
    }

    /// Move this engine's sampler onto its own PCG stream.  The pool
    /// builds every worker from one factory, so without this every
    /// worker would sample byte-identical sequences.
    pub fn fork_rng(&mut self, stream: u64) {
        let state = self.rng.next_u64();
        self.rng = Pcg32::new(state, stream);
    }

    /// Decode a batch of prompts, each row under its own
    /// `DecodeParams` (greedy where temperature == 0).
    ///
    /// The AOT executable has a fixed [B, T] shape: the context is a
    /// sliding window over the last T tokens; each step runs one full
    /// forward and reads the logits at each row's current last
    /// position.  Finished rows keep their slot (the shape is fixed)
    /// but are no longer sampled; the loop ends when all rows are done.
    pub fn generate(
        &mut self,
        rt: &mut Runtime,
        prompts: &[Vec<u32>],
        params: &[DecodeParams],
    ) -> Result<Generation> {
        let b = self.session.logits_batch;
        let t = self.session.seq_len;
        let vocab = self.vocab;
        let session = &self.session;
        decode_batch(|toks| session.logits(rt, toks), b, t, vocab, prompts, params, &mut self.rng)
    }
}

/// The decode loop over an abstract forward function `step` (tokens
/// `[b, t]` row-major → logits `[b, t, vocab]` flattened).  Split out
/// from `Engine` so per-request semantics are testable without XLA.
pub fn decode_batch(
    mut step: impl FnMut(&[i32]) -> Result<Vec<f32>>,
    b: usize,
    t: usize,
    vocab: usize,
    prompts: &[Vec<u32>],
    params: &[DecodeParams],
    rng: &mut Pcg32,
) -> Result<Generation> {
    let n = prompts.len();
    anyhow::ensure!(n <= b, "batch too large: {n} > {b}");
    anyhow::ensure!(params.len() == n, "params/prompts length mismatch");
    let mut seqs: Vec<Vec<u32>> = prompts.to_vec();
    for s in &mut seqs {
        anyhow::ensure!(!s.is_empty(), "empty prompt");
        // sliding-window model: keep the *last* t tokens (the most
        // recent context), not the first t
        if s.len() > t {
            let cut = s.len() - t;
            s.drain(..cut);
        }
    }
    let mut outputs: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut done: Vec<bool> = params.iter().map(|p| p.max_tokens == 0).collect();
    let budget = params.iter().map(|p| p.max_tokens).max().unwrap_or(0);
    let mut steps = 0;

    while steps < budget && done.iter().any(|d| !d) {
        let (toks, pos) = pack_decode_windows(&seqs, b, t)?;
        let logits = step(&toks)?;
        anyhow::ensure!(logits.len() == b * t * vocab, "bad logits length {}", logits.len());
        steps += 1;
        for r in 0..n {
            if done[r] {
                continue;
            }
            let off = (r * t + pos[r]) * vocab;
            let row = &logits[off..off + vocab];
            let p = params[r];
            let idx =
                if p.temperature <= 0.0 { argmax(row) } else { sample(row, p.temperature, rng) };
            let next = idx as u32;
            // growth is bounded by max_tokens; pack_decode_windows
            // re-windows to the last t tokens every step
            seqs[r].push(next);
            outputs[r].push(next);
            if outputs[r].len() >= p.max_tokens || p.stop == Some(next) {
                done[r] = true;
            }
        }
    }
    Ok(Generation { outputs, steps })
}

/// Rank tokens skipping NaN logits: a degraded model degrades to the
/// best well-defined logit (index 0 if there is none) instead of
/// panicking the worker thread.  NaNs must be filtered, not ordered:
/// `total_cmp` ranks positive NaN *above* +inf, so a plain `max_by`
/// would elect the NaN's index as the token.  Public: the native
/// backend (`infer::engine`) samples with the same semantics.
pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Temperature sampling over one logits row; NaN logits get zero mass.
pub fn sample(row: &[f32], temperature: f32, rng: &mut Pcg32) -> usize {
    let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| if v > m { v } else { m });
    if !mx.is_finite() {
        // all-NaN / all -inf row: degrade to the total_cmp argmax
        return argmax(row);
    }
    let w: Vec<f64> = row
        .iter()
        .map(|&v| if v.is_nan() { 0.0 } else { (((v - mx) / temperature) as f64).exp() })
        .collect();
    rng.categorical(&w)
}

/// A worker's engine half: the runtime plus the engine pinned to it.
/// Built inside the worker thread (PJRT handles are not `Send`).
pub struct EngineWorker {
    /// the PJRT runtime this worker thread owns
    pub rt: Runtime,
    /// the engine pinned to that runtime
    pub engine: Engine,
}

impl Generator for EngineWorker {
    fn generate(&mut self, prompts: &[Vec<u32>], params: &[DecodeParams]) -> Result<Generation> {
        self.engine.generate(&mut self.rt, prompts, params)
    }

    fn max_batch(&self) -> usize {
        self.engine.session.logits_batch
    }

    fn fork_rng(&mut self, stream: u64) {
        self.engine.fork_rng(stream);
    }
}

/// The worker loop: pull a batch off the shared queue, decode, reply.
/// Several workers may run this concurrently against one queue; each
/// request is answered exactly once — on success with its own
/// `max_tokens`-long output, on failure with an error response per
/// request (never a dropped batch).  Requests still queued at shutdown
/// are answered with an error reply instead of being decoded.
pub fn worker_loop<G: Generator>(
    mut engine: G,
    rx: Arc<Mutex<Receiver<Request>>>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
) {
    loop {
        let Some(mut batch) = next_batch_shared(&rx, &policy, &running) else { break };
        metrics.queue_depth.fetch_sub(batch.len() as u64, Ordering::Relaxed);
        if !running.load(Ordering::Relaxed) {
            // shutdown drain: answer what was already queued, fast
            for req in batch {
                let latency = req.arrived.elapsed();
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                let resp = Response::err("server shutting down", latency.as_micros() as u64);
                let _ = req.reply.send(resp);
            }
            continue;
        }
        metrics.record_batch(batch.len());
        // take the prompts out of the owned batch: decode_batch makes
        // the one working copy it mutates, no second clone here
        let prompts: Vec<Vec<u32>> =
            batch.iter_mut().map(|r| std::mem::take(&mut r.prompt)).collect();
        let params: Vec<DecodeParams> = batch.iter().map(|r| r.params).collect();
        let budget = params.iter().map(|p| p.max_tokens).max().unwrap_or(0);
        match engine.generate(&prompts, &params) {
            Ok(g) => {
                metrics
                    .early_exit_steps
                    .fetch_add(budget.saturating_sub(g.steps) as u64, Ordering::Relaxed);
                // the static-batch stall: a row that finished early
                // still sat in the batch for every remaining step.
                // Count those idle row-steps instead of pretending the
                // row decoded for the batch's full length — the metric
                // the continuous scheduler exists to drive to zero.
                let stalled: usize =
                    g.outputs.iter().map(|o| g.steps.saturating_sub(o.len())).sum();
                metrics.stalled_row_steps.fetch_add(stalled as u64, Ordering::Relaxed);
                for (req, out) in batch.into_iter().zip(g.outputs) {
                    let latency = req.arrived.elapsed();
                    metrics.record_latency(latency);
                    metrics.responses.fetch_add(1, Ordering::Relaxed);
                    metrics.tokens_out.fetch_add(out.len() as u64, Ordering::Relaxed);
                    let _ = req.reply.send(Response::ok(out, latency.as_micros() as u64));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                eprintln!("worker error: {msg}");
                for req in batch {
                    let latency = req.arrived.elapsed();
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = req.reply.send(Response::err(&msg, latency.as_micros() as u64));
                }
            }
        }
    }
}

/// Parse one request line: `(prompt, params, timeout_ms)`.
pub fn parse_request(line: &str) -> Result<(Vec<u32>, DecodeParams, Option<u64>)> {
    let j = Json::parse(line).context("bad request json")?;
    let prompt: Vec<u32> = j
        .get("prompt")?
        .as_arr()?
        .iter()
        .map(|v| v.as_usize().map(|u| u as u32))
        .collect::<Result<_>>()?;
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_tokens = j.get("max_tokens")?.as_usize()?;
    anyhow::ensure!(
        max_tokens <= MAX_TOKENS_CAP,
        "max_tokens {max_tokens} exceeds cap {MAX_TOKENS_CAP}"
    );
    // a present-but-bad temperature is a client error, not "greedy":
    // coercing `"temperature": "hot"` (or NaN/negative) to 0.0 would
    // silently decode a different distribution than the client asked
    // for — reject it on the request line instead
    let temperature = match j.opt("temperature") {
        Some(v) => {
            let t = v.as_f64().context("temperature must be a number")?;
            anyhow::ensure!(
                t.is_finite() && t >= 0.0,
                "temperature {t} out of range (must be finite and >= 0)"
            );
            t as f32
        }
        None => 0.0,
    };
    let stop = match j.opt("stop") {
        Some(v) => {
            let s = v.as_usize()?;
            anyhow::ensure!(s <= u32::MAX as usize, "stop token {s} out of u32 range");
            Some(s as u32)
        }
        None => None,
    };
    let timeout_ms = match j.opt("timeout_ms") {
        Some(v) => Some(v.as_usize()? as u64),
        None => None,
    };
    // speculation is an opt-out: it never changes the decoded stream
    // (greedy speculative == greedy teacher-only, bitwise), so the only
    // reason to turn it off per request is benchmarking the plain path
    let speculate = match j.opt("speculate") {
        Some(v) => v.as_bool().context("speculate must be a boolean")?,
        None => true,
    };
    Ok((prompt, DecodeParams { max_tokens, temperature, stop, speculate }, timeout_ms))
}

/// Render one response (or error) line.
pub fn render_response(resp: &Response) -> String {
    match &resp.error {
        Some(msg) => {
            let mut pairs = vec![
                ("error", Json::str(msg.clone())),
                ("latency_us", Json::num(resp.latency_us as f64)),
            ];
            if let Some(ms) = resp.retry_after_ms {
                pairs.push(("retry_after_ms", Json::num(ms as f64)));
            }
            Json::obj(pairs).to_string()
        }
        None => {
            let toks = Json::Arr(resp.tokens.iter().map(|&t| Json::num(t as f64)).collect());
            let mut pairs =
                vec![("tokens", toks), ("latency_us", Json::num(resp.latency_us as f64))];
            if resp.timeout {
                pairs.push(("timeout", Json::Bool(true)));
            }
            Json::obj(pairs).to_string()
        }
    }
}

/// Render the one-line `{"cmd": "stats"}` reply: the full metrics JSON
/// under `"stats"` plus a Prometheus text exposition rendering under
/// `"prometheus"` (the multi-line text is escaped into one JSON string
/// by `Json`'s writer, so the line protocol is preserved).
pub fn render_stats(metrics: &Metrics) -> String {
    Json::obj(vec![
        ("prometheus", Json::str(metrics.to_prometheus())),
        ("stats", metrics.to_json()),
    ])
    .to_string()
}

/// Intercept a `{"cmd": ...}` control line and build its reply;
/// `None` means the line is not a control line (no `"cmd"` key) and
/// should be parsed as a generate request.  Control lines are answered
/// by the connection thread itself — a stats probe never queues behind
/// decode work, so it stays responsive under full load.
pub fn command_response(line: &str, metrics: &Metrics) -> Option<String> {
    // cheap reject: generate requests carry "prompt"/"max_tokens" only,
    // so most lines skip the parse entirely
    if !line.contains("\"cmd\"") {
        return None;
    }
    let j = Json::parse(line).ok()?;
    let cmd = j.opt("cmd")?.as_str().ok()?;
    Some(match cmd {
        "stats" => render_stats(metrics),
        other => {
            Json::obj(vec![("error", Json::str(format!("unknown cmd {other:?}")))]).to_string()
        }
    })
}

/// Admission control (backpressure): a request only enters the shared
/// queue while its depth is below `queue_cap`; beyond that the client
/// gets an immediate `"server overloaded"` error line instead of an
/// unbounded queue silently growing latency.  Reserves the gauge slot
/// *before* checking (increment, then undo on reject) so concurrent
/// connection threads cannot all pass a below-cap read and overshoot
/// the cap.  On `true` the caller owns one `queue_depth` increment and
/// must pair it with the worker-side decrement (or undo it if the
/// enqueue fails); rejections count in `metrics.rejected`.
pub fn admit(metrics: &Metrics, queue_cap: usize) -> bool {
    let prev = metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
    if prev >= queue_cap as u64 {
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        metrics.rejected.fetch_add(1, Ordering::Relaxed);
        return false;
    }
    true
}

/// Estimate how long a shed client should wait before retrying, in
/// milliseconds: the mean end-to-end latency scaled by queue pressure,
/// clamped to a sane band.  Deliberately coarse — the hint's job is to
/// spread the retry stampede over time, not to predict the queue.
pub fn retry_after_hint(metrics: &Metrics, queue_cap: usize) -> u64 {
    let depth = metrics.queue_depth.load(Ordering::Relaxed) as f64;
    let cap = queue_cap.max(1) as f64;
    let mean_ms = metrics.latency.mean_us() / 1000.0;
    // a cold server has no latency samples yet: assume ~100 ms
    let base = if mean_ms > 0.0 { mean_ms } else { 100.0 };
    (base * (1.0 + depth / cap)).clamp(50.0, 5_000.0) as u64
}

/// Deadline-aware shedding above the high-water mark (¾ of
/// `queue_cap`): a request whose own `timeout_ms` deadline is shorter
/// than the estimated queue wait would only be admitted, sit in queue,
/// and expire — exactly the request the EDF scheduler would pull
/// first, prefill, and then evict at its deadline.  Shedding it at the
/// door with a `retry_after_ms` hint keeps queue capacity (and prefill
/// work) for requests that can still make their deadlines, which is
/// the same ordering judgment EDF itself applies.  Returns the hint
/// when the request should be shed.  Call only after a successful
/// [`admit`]: the caller still owns the `queue_depth` reservation and
/// must roll it back when shedding.
pub fn shed_decision(metrics: &Metrics, queue_cap: usize, timeout_ms: Option<u64>) -> Option<u64> {
    let deadline = timeout_ms?;
    let depth = metrics.queue_depth.load(Ordering::Relaxed);
    if (depth as usize).saturating_mul(4) < queue_cap.saturating_mul(3) {
        return None;
    }
    let hint = retry_after_hint(metrics, queue_cap);
    if deadline < hint {
        Some(hint)
    } else {
        None
    }
}

/// Default cap on one request line (see [`ConnConfig::max_line_bytes`]):
/// generous for token-id prompts, small enough that one malicious line
/// cannot OOM a connection thread.
pub const DEFAULT_MAX_LINE_BYTES: usize = 4 << 20;

/// Per-connection hardening knobs: socket timeouts, the request-line
/// byte cap, and the idle reaper.  `Default` preserves legacy behavior
/// (no timeouts, no reaper) apart from the line cap, which always
/// applies.
#[derive(Clone, Debug)]
pub struct ConnConfig {
    /// socket read timeout — also the idle reaper's polling step;
    /// `None` blocks forever (and disables the reaper)
    pub read_timeout: Option<Duration>,
    /// socket write timeout: a peer that stops draining replies errors
    /// the write instead of wedging the connection thread
    pub write_timeout: Option<Duration>,
    /// hard cap on one request line; an oversized line gets a
    /// structured error reply and the connection is closed
    pub max_line_bytes: usize,
    /// reap a connection that sat idle (zero bytes between requests)
    /// this long; needs `read_timeout` to drive the polling
    pub idle_timeout: Option<Duration>,
}

impl Default for ConnConfig {
    fn default() -> Self {
        ConnConfig {
            read_timeout: None,
            write_timeout: None,
            max_line_bytes: DEFAULT_MAX_LINE_BYTES,
            idle_timeout: None,
        }
    }
}

/// Poison-tolerant shared request queue for the supervised continuous
/// worker pool — the replacement for the old `Mutex<Receiver<Request>>`
/// hand-off, whose lock a panicking worker poisoned for every sibling.
/// A caller that finds the mutex poisoned repairs the guard
/// (`into_inner`) and keeps serving; every recovery is counted and
/// drained via [`SharedQueue::take_recovered`] into
/// `SchedStats::queue_lock_poisoned`, so the degradation stays
/// observable without ever becoming fatal.
///
/// Lock discipline: `jobs` is a leaf mutex — guard scopes hold queue
/// bookkeeping only (push/pop/len), never an engine call.  The
/// `db-llm-tidy` lock-order rule tracks the `jobs.lock()` receiver
/// textually, same as the prefix-cache and pool-recycle mutexes.
pub struct SharedQueue {
    /// FIFO of requests awaiting a worker (leaf lock; see above)
    jobs: Mutex<VecDeque<Request>>,
    /// wakes blocked poppers on push and on close
    ready: Condvar,
    /// closed: pushes are refused, idle poppers drain out
    closed: AtomicBool,
    /// mutex-poison recoveries not yet drained by `take_recovered`
    poison_recoveries: AtomicU64,
}

impl Default for SharedQueue {
    fn default() -> Self {
        SharedQueue::new()
    }
}

impl SharedQueue {
    /// An open, empty queue.
    pub fn new() -> SharedQueue {
        SharedQueue {
            jobs: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            closed: AtomicBool::new(false),
            poison_recoveries: AtomicU64::new(0),
        }
    }

    /// Lock `jobs`, repairing (and counting) a poisoned guard instead
    /// of propagating the poison — the whole point of this queue.
    fn lock_jobs(&self) -> std::sync::MutexGuard<'_, VecDeque<Request>> {
        match self.jobs.lock() {
            Ok(guard) => guard,
            Err(poisoned) => {
                self.poison_recoveries.fetch_add(1, Ordering::Relaxed);
                // repair, don't just bypass: one count per poisoning
                // event, not one per subsequent lock of a sticky flag
                self.jobs.clear_poison();
                poisoned.into_inner()
            }
        }
    }

    /// Enqueue a request; `Err` hands it back when the queue is closed
    /// (shutdown) so the caller can answer the client directly.
    pub fn push(&self, req: Request) -> Result<(), Request> {
        if self.closed.load(Ordering::Relaxed) {
            return Err(req);
        }
        self.lock_jobs().push_back(req);
        self.ready.notify_one();
        Ok(())
    }

    /// Dequeue, blocking up to `timeout` for a request to arrive.
    /// `None` on timeout or when the queue is closed and drained —
    /// callers poll this at shutdown cadence.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<Request> {
        let mut guard = self.lock_jobs();
        if let Some(req) = guard.pop_front() {
            return Some(req);
        }
        if self.closed.load(Ordering::Relaxed) {
            return None;
        }
        let mut guard = match self.ready.wait_timeout(guard, timeout) {
            Ok((guard, _timed_out)) => guard,
            Err(poisoned) => {
                self.poison_recoveries.fetch_add(1, Ordering::Relaxed);
                self.jobs.clear_poison();
                poisoned.into_inner().0
            }
        };
        guard.pop_front()
    }

    /// Non-blocking dequeue (the mid-flight refill top-up path).
    pub fn try_pop(&self) -> Option<Request> {
        self.lock_jobs().pop_front()
    }

    /// Requests currently queued.
    pub fn len(&self) -> usize {
        self.lock_jobs().len()
    }

    /// True when no request is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Refuse further pushes and wake every blocked popper so idle
    /// workers can drain out.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        self.ready.notify_all();
    }

    /// True once [`close`](Self::close) was called.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Relaxed)
    }

    /// Drain the poison-recovery tally (swap to 0): each worker folds
    /// the delta it drained into its own `SchedStats`, so concurrent
    /// workers never double-count one recovery.
    pub fn take_recovered(&self) -> u64 {
        self.poison_recoveries.swap(0, Ordering::Relaxed)
    }

    /// Poison the queue mutex on purpose: a throwaway thread panics
    /// while holding the guard.  Fault-injection helper for the chaos
    /// suite — the next queue operation must repair and count it.
    pub fn poison_for_chaos(self: &Arc<Self>) {
        let q = Arc::clone(self);
        let _ = std::thread::spawn(move || {
            let _guard = q.jobs.lock().expect("poisoning a healthy queue lock");
            panic!("chaos: poisoning the shared queue lock");
        })
        .join();
    }
}

/// Where a connection thread hands an admitted request: the static XLA
/// pool's mpsc sender, or the supervised continuous pool's
/// [`SharedQueue`].  `Err` returns the request (workers gone — the
/// connection answers the client and closes).
pub trait RequestSink: Clone + Send + 'static {
    /// Deliver one admitted request to the worker pool.
    fn deliver(&self, req: Request) -> Result<(), Request>;
}

impl RequestSink for Sender<Request> {
    fn deliver(&self, req: Request) -> Result<(), Request> {
        self.send(req).map_err(|e| e.0)
    }
}

impl RequestSink for Arc<SharedQueue> {
    fn deliver(&self, req: Request) -> Result<(), Request> {
        self.push(req)
    }
}

fn handle_conn<S: RequestSink>(
    stream: TcpStream,
    sink: S,
    metrics: Arc<Metrics>,
    queue_cap: usize,
    conn: ConnConfig,
) {
    let peer = stream.peer_addr().ok();
    if stream.set_read_timeout(conn.read_timeout).is_err()
        || stream.set_write_timeout(conn.write_timeout).is_err()
    {
        return;
    }
    // a failed dup (fd exhaustion, peer already reset) is a
    // per-connection condition a client can trigger at will — log and
    // close this connection instead of panicking the thread
    let read_half = match stream.try_clone() {
        Ok(read_half) => read_half,
        Err(e) => {
            eprintln!("dropping connection from {peer:?}: cannot clone stream: {e}");
            return;
        }
    };
    // the Take bound is re-armed per line with +1 slack so a line of
    // exactly max_line_bytes plus its newline still parses; anything
    // past the bound hits the Take's EOF and is detectably oversized
    let mut reader = BufReader::new(read_half.take(0));
    let mut writer = stream;
    let mut buf: Vec<u8> = Vec::new();
    'conn: loop {
        buf.clear();
        reader.get_mut().set_limit(conn.max_line_bytes as u64 + 1);
        let mut idle = Duration::ZERO;
        loop {
            match reader.read_until(b'\n', &mut buf) {
                Ok(0) => break 'conn, // peer closed between requests
                Ok(_) if buf.ends_with(b"\n") => break,
                Ok(_) => {
                    if buf.len() > conn.max_line_bytes {
                        metrics.oversize_lines.fetch_add(1, Ordering::Relaxed);
                        let resp = Response::err(
                            format!("request line exceeds {} bytes", conn.max_line_bytes),
                            0,
                        );
                        let _ = writeln!(writer, "{}", render_response(&resp));
                    }
                    // oversized, or the peer closed mid-line: close —
                    // there is no re-synchronizing a half-frame stream
                    break 'conn;
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if !buf.is_empty() {
                        // mid-line stall: a peer that pauses inside a
                        // frame holds no claim on this thread
                        break 'conn;
                    }
                    idle += conn.read_timeout.unwrap_or(Duration::ZERO);
                    if conn.idle_timeout.is_some_and(|t| idle >= t) {
                        metrics.conn_reaped.fetch_add(1, Ordering::Relaxed);
                        break 'conn;
                    }
                }
                Err(_) => break 'conn,
            }
        }
        let Ok(line) = std::str::from_utf8(&buf) else {
            let resp = Response::err("request line is not valid utf-8", 0);
            let _ = writeln!(writer, "{}", render_response(&resp));
            continue;
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(reply) = command_response(line, &metrics) {
            let _ = writeln!(writer, "{reply}");
            continue;
        }
        match parse_request(line) {
            Ok((prompt, params, timeout_ms)) => {
                metrics.requests.fetch_add(1, Ordering::Relaxed);
                // admit() already reserved this request's queue_depth
                // slot; the worker decrements it when batching
                if !admit(&metrics, queue_cap) {
                    let resp = Response::overloaded(
                        "server overloaded",
                        retry_after_hint(&metrics, queue_cap),
                    );
                    let _ = writeln!(writer, "{}", render_response(&resp));
                    continue;
                }
                if let Some(hint) = shed_decision(&metrics, queue_cap, timeout_ms) {
                    // graceful degradation: above the high-water mark a
                    // deadline shorter than the estimated queue wait
                    // could only expire in queue — shed it at the door
                    metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    metrics.shed_requests.fetch_add(1, Ordering::Relaxed);
                    let resp = Response::overloaded(
                        "server overloaded: deadline shorter than estimated queue wait",
                        hint,
                    );
                    let _ = writeln!(writer, "{}", render_response(&resp));
                    continue;
                }
                let (reply_tx, reply_rx) = channel();
                let req = Request {
                    prompt,
                    params,
                    reply: reply_tx,
                    arrived: Instant::now(),
                    timeout_ms,
                };
                if sink.deliver(req).is_err() {
                    metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    break;
                }
                match reply_rx.recv() {
                    Ok(resp) => {
                        let _ = writeln!(writer, "{}", render_response(&resp));
                    }
                    Err(_) => break,
                }
            }
            Err(e) => {
                let err_line = Json::obj(vec![("error", Json::str(format!("{e:#}")))]);
                let _ = writeln!(writer, "{err_line}");
            }
        }
    }
    let _ = peer;
}

/// Run the server until `running` is cleared.  Binds `addr`, spawns one
/// thread per connection and `workers` engine workers competing on a
/// shared request queue; each worker *constructs* its own generator via
/// `factory` on its own thread (PJRT handles are not `Send`, so the
/// XLA backend must be born on the thread that uses it; the native
/// backend simply builds its engine there too).
pub fn serve<G: Generator>(
    factory: impl Fn() -> Result<G> + Send + Sync + 'static,
    addr: &str,
    policy: BatchPolicy,
    workers: usize,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
) -> Result<std::net::SocketAddr> {
    serve_with(factory, addr, policy, workers, metrics, running, ConnConfig::default())
}

/// [`serve`] with explicit per-connection hardening knobs (timeouts,
/// line cap, idle reaper) — what `db-llm serve` calls; the plain
/// [`serve`] delegates here with [`ConnConfig::default`] so existing
/// callers keep their behavior.
pub fn serve_with<G: Generator>(
    factory: impl Fn() -> Result<G> + Send + Sync + 'static,
    addr: &str,
    policy: BatchPolicy,
    workers: usize,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
    conn: ConnConfig,
) -> Result<std::net::SocketAddr> {
    // bind before spawning anything: a bad --addr must fail fast, not
    // after every worker has spent seconds building its engine
    let (listener, local) = bind_listener(addr)?;
    let (tx, rx) = channel::<Request>();
    let rx = Arc::new(Mutex::new(rx));
    let factory = Arc::new(factory);
    let queue_cap = policy.queue_cap;

    for w in 0..workers.max(1) {
        let rx = rx.clone();
        let policy = policy.clone();
        let m = metrics.clone();
        let r = running.clone();
        let f = factory.clone();
        std::thread::Builder::new()
            .name(format!("engine-worker-{w}"))
            .spawn(move || match f() {
                Ok(mut engine) => {
                    engine.fork_rng(w as u64);
                    // a max_batch above the backend's capacity (the
                    // executable's fixed batch dim) would make every
                    // decode bail "batch too large" — clamp to it
                    let mut policy = policy;
                    if let Some(asked) = policy.clamp_max_batch(engine.max_batch()) {
                        eprintln!(
                            "worker {w}: max_batch {asked} exceeds the backend's \
                             batch capacity; clamped to {}",
                            policy.max_batch
                        );
                    }
                    worker_loop(engine, rx, policy, m, r)
                }
                Err(e) => eprintln!("engine init failed: {e:#}"),
            })
            .context("spawning engine worker")?;
    }

    spawn_accept_loop(listener, tx, metrics, queue_cap, running, conn);
    Ok(local)
}

/// Bind `addr` for the serving front door.  Split from
/// [`spawn_accept_loop`] so callers can fail fast on a bad address
/// *before* building any engine.
pub(crate) fn bind_listener(addr: &str) -> Result<(TcpListener, std::net::SocketAddr)> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    Ok((listener, local))
}

/// Spawn the accept loop over an already-bound listener: one connection
/// thread per client, requests funneled into the [`RequestSink`].
/// Shared by the static worker pool ([`serve`], mpsc sender) and the
/// supervised continuous scheduler (`scheduler::serve_continuous`,
/// [`SharedQueue`]).
pub(crate) fn spawn_accept_loop<S: RequestSink>(
    listener: TcpListener,
    sink: S,
    metrics: Arc<Metrics>,
    queue_cap: usize,
    running: Arc<AtomicBool>,
    conn: ConnConfig,
) {
    std::thread::spawn(move || {
        while running.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let sink = sink.clone();
                    let m = metrics.clone();
                    let c = conn.clone();
                    std::thread::spawn(move || handle_conn(stream, sink, m, queue_cap, c));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(_) => break,
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_roundtrip() {
        let (p, d, to) = parse_request(r#"{"prompt": [1, 2, 3], "max_tokens": 8}"#).unwrap();
        assert_eq!(p, vec![1, 2, 3]);
        assert_eq!(d.max_tokens, 8);
        assert_eq!(d.temperature, 0.0);
        assert_eq!(d.stop, None);
        assert!(d.speculate, "speculation is opt-out: absent means on");
        assert_eq!(to, None);
        let (_, d2, to2) = parse_request(
            r#"{"prompt": [1], "max_tokens": 1, "temperature": 0.7, "stop": 2, "timeout_ms": 250}"#,
        )
        .unwrap();
        assert!((d2.temperature - 0.7).abs() < 1e-6);
        assert_eq!(d2.stop, Some(2));
        assert_eq!(to2, Some(250));
        // zero is a valid (immediately-expiring) deadline; negatives are not
        let (_, _, to3) =
            parse_request(r#"{"prompt": [1], "max_tokens": 1, "timeout_ms": 0}"#).unwrap();
        assert_eq!(to3, Some(0));
        assert!(parse_request(r#"{"prompt": [1], "max_tokens": 1, "timeout_ms": -5}"#).is_err());
    }

    #[test]
    fn parse_speculate_opt_out() {
        let (_, d, _) =
            parse_request(r#"{"prompt": [1], "max_tokens": 4, "speculate": false}"#).unwrap();
        assert!(!d.speculate);
        let (_, d, _) =
            parse_request(r#"{"prompt": [1], "max_tokens": 4, "speculate": true}"#).unwrap();
        assert!(d.speculate);
        // a present-but-bad flag is a client error, not a default
        assert!(parse_request(r#"{"prompt": [1], "max_tokens": 4, "speculate": 1}"#).is_err());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"prompt": [], "max_tokens": 4}"#).is_err());
        assert!(parse_request(r#"{"max_tokens": 4}"#).is_err());
    }

    #[test]
    fn parse_rejects_bad_temperature() {
        // a non-numeric temperature must be an error line, not a
        // silent coercion to greedy decoding
        let err = parse_request(r#"{"prompt": [1], "max_tokens": 4, "temperature": "hot"}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("temperature"), "{err}");
        assert!(
            parse_request(r#"{"prompt": [1], "max_tokens": 4, "temperature": -0.5}"#).is_err()
        );
        // overflowing exponent parses to +inf — also out of range
        assert!(
            parse_request(r#"{"prompt": [1], "max_tokens": 4, "temperature": 1e400}"#).is_err()
        );
        // boundary values stay accepted
        let (_, d, _) =
            parse_request(r#"{"prompt": [1], "max_tokens": 4, "temperature": 0.0}"#).unwrap();
        assert_eq!(d.temperature, 0.0);
        let (_, d, _) =
            parse_request(r#"{"prompt": [1], "max_tokens": 4, "temperature": 2}"#).unwrap();
        assert!((d.temperature - 2.0).abs() < 1e-6);
    }

    #[test]
    fn parse_rejects_out_of_range_stop() {
        // 2^32 must not silently truncate to stop token 0
        let req = r#"{"prompt": [1], "max_tokens": 4, "stop": 4294967296}"#;
        let err = parse_request(req).unwrap_err().to_string();
        assert!(err.contains("out of u32 range"), "{err}");
    }

    #[test]
    fn parse_caps_max_tokens() {
        // one request must not be able to pin a worker forever
        let over = format!(r#"{{"prompt": [1], "max_tokens": {}}}"#, MAX_TOKENS_CAP + 1);
        let err = parse_request(&over).unwrap_err().to_string();
        assert!(err.contains("exceeds cap"), "{err}");
        assert!(parse_request(&format!(
            r#"{{"prompt": [1], "max_tokens": {MAX_TOKENS_CAP}}}"#
        ))
        .is_ok());
    }

    /// A peer that vanishes (or a stream whose read half cannot be
    /// set up) must drop the connection cleanly: `handle_conn` logs
    /// and returns instead of panicking the connection thread — the
    /// old `try_clone().expect(...)` was a client-reachable panic.
    #[test]
    fn handle_conn_survives_vanished_peer() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        // peer resets before the server reads a single line
        drop(client);
        let (tx, _rx) = channel::<Request>();
        // must return (EOF/error -> close), not panic
        handle_conn(server_side, tx, Arc::new(Metrics::default()), 4, ConnConfig::default());
    }

    /// Spin one `handle_conn` over a fresh loopback pair, returning
    /// the client half and the join handle for the connection thread.
    fn conn_pair(
        sink: impl RequestSink,
        metrics: Arc<Metrics>,
        conn: ConnConfig,
    ) -> (TcpStream, std::thread::JoinHandle<()>) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let handle = std::thread::spawn(move || handle_conn(server_side, sink, metrics, 4, conn));
        (client, handle)
    }

    fn read_line(stream: &mut TcpStream) -> String {
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line
    }

    #[test]
    fn oversize_line_gets_error_then_close() {
        let m = Arc::new(Metrics::default());
        let (tx, _rx) = channel::<Request>();
        let cfg = ConnConfig { max_line_bytes: 256, ..ConnConfig::default() };
        let (mut client, handle) = conn_pair(tx, m.clone(), cfg);
        client.write_all(&vec![b'x'; 4096]).unwrap();
        client.write_all(b"\n").unwrap();
        let line = read_line(&mut client);
        let j = Json::parse(line.trim()).unwrap();
        assert!(
            j.get("error").unwrap().as_str().unwrap().contains("exceeds 256 bytes"),
            "{line}"
        );
        // connection is closed after the error reply
        let mut rest = String::new();
        let n = BufReader::new(&client).read_line(&mut rest).unwrap();
        assert_eq!(n, 0, "server must close after an oversized line");
        handle.join().unwrap();
        assert_eq!(m.oversize_lines.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn exact_cap_line_still_parses() {
        // a request line of exactly max_line_bytes (newline excluded)
        // must still be served — the +2 Take slack exists for this
        let m = Arc::new(Metrics::default());
        let (tx, rx) = channel::<Request>();
        std::thread::spawn(move || {
            while let Ok(req) = rx.recv() {
                let _ = req.reply.send(Response::ok(vec![1], 5));
            }
        });
        let mut line = String::from(r#"{"prompt": [1], "max_tokens": 1}"#);
        let cap = 128;
        while line.len() < cap {
            line.insert(1, ' ');
        }
        assert_eq!(line.len(), cap);
        let cfg = ConnConfig { max_line_bytes: cap, ..ConnConfig::default() };
        let (mut client, _handle) = conn_pair(tx, m.clone(), cfg);
        client.write_all(line.as_bytes()).unwrap();
        client.write_all(b"\n").unwrap();
        let reply = read_line(&mut client);
        let j = Json::parse(reply.trim()).unwrap();
        assert_eq!(j.usize_list("tokens").unwrap(), vec![1], "{reply}");
        assert_eq!(m.oversize_lines.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn binary_garbage_gets_error_line_not_close() {
        let m = Arc::new(Metrics::default());
        let (tx, _rx) = channel::<Request>();
        let (mut client, _handle) = conn_pair(tx, m, ConnConfig::default());
        client.write_all(&[0xff, 0xfe, 0x80, b'\n']).unwrap();
        let line = read_line(&mut client);
        let j = Json::parse(line.trim()).unwrap();
        assert!(j.get("error").unwrap().as_str().unwrap().contains("utf-8"), "{line}");
        // connection survives: a stats probe still answers
        client.write_all(b"{\"cmd\": \"stats\"}\n").unwrap();
        let line = read_line(&mut client);
        assert!(line.contains("\"stats\""), "{line}");
    }

    #[test]
    fn idle_connection_is_reaped() {
        let m = Arc::new(Metrics::default());
        let (tx, _rx) = channel::<Request>();
        let cfg = ConnConfig {
            read_timeout: Some(Duration::from_millis(20)),
            idle_timeout: Some(Duration::from_millis(60)),
            ..ConnConfig::default()
        };
        let (client, handle) = conn_pair(tx, m.clone(), cfg);
        // send nothing: the reaper must close the connection
        handle.join().unwrap();
        assert_eq!(m.conn_reaped.load(Ordering::Relaxed), 1);
        drop(client);
    }

    #[test]
    fn mid_line_stall_closes_connection() {
        let m = Arc::new(Metrics::default());
        let (tx, _rx) = channel::<Request>();
        let cfg = ConnConfig {
            read_timeout: Some(Duration::from_millis(20)),
            ..ConnConfig::default()
        };
        let (mut client, handle) = conn_pair(tx, m.clone(), cfg);
        // half a frame, then silence: the stall policy drops the peer
        client.write_all(b"{\"prompt\": [1, 2").unwrap();
        handle.join().unwrap();
        // a stall is not an idle reap and not an oversize
        assert_eq!(m.conn_reaped.load(Ordering::Relaxed), 0);
        assert_eq!(m.oversize_lines.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn shared_queue_fifo_and_close() {
        let q = SharedQueue::new();
        let (tx, _rx) = channel();
        let mk = |id: u32| Request {
            prompt: vec![id],
            params: DecodeParams::greedy(1),
            reply: tx.clone(),
            arrived: Instant::now(),
            timeout_ms: None,
        };
        assert!(q.is_empty());
        q.push(mk(1)).map_err(|_| ()).unwrap();
        q.push(mk(2)).map_err(|_| ()).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop().unwrap().prompt, vec![1]);
        assert_eq!(q.pop_timeout(Duration::from_millis(10)).unwrap().prompt, vec![2]);
        assert!(q.pop_timeout(Duration::from_millis(1)).is_none());
        q.close();
        assert!(q.is_closed());
        assert!(q.push(mk(3)).is_err(), "closed queue refuses pushes");
        assert!(q.pop_timeout(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn shared_queue_recovers_from_poison_and_counts_it() {
        let q = Arc::new(SharedQueue::new());
        q.poison_for_chaos();
        // the queue still works after the poisoning …
        let (tx, _rx) = channel();
        let req = Request {
            prompt: vec![7],
            params: DecodeParams::greedy(1),
            reply: tx,
            arrived: Instant::now(),
            timeout_ms: None,
        };
        q.push(req).map_err(|_| ()).unwrap();
        assert_eq!(q.try_pop().unwrap().prompt, vec![7]);
        // … and the recovery is counted exactly once per drain
        assert!(q.take_recovered() >= 1);
        assert_eq!(q.take_recovered(), 0, "tally drains to zero");
    }

    #[test]
    fn shared_queue_close_wakes_blocked_popper() {
        let q = Arc::new(SharedQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_timeout(Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        // must return promptly (well under the 30 s timeout)
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn overloaded_response_carries_retry_hint() {
        let r = Response::overloaded("server overloaded", 250);
        let s = render_response(&r);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "server overloaded");
        assert_eq!(j.get("retry_after_ms").unwrap().as_usize().unwrap(), 250);
        // plain errors never carry the key
        let plain = render_response(&Response::err("boom", 1));
        assert!(Json::parse(&plain).unwrap().opt("retry_after_ms").is_none());
    }

    #[test]
    fn retry_after_hint_is_clamped_and_pressure_scaled() {
        let m = Metrics::default();
        // cold server: no samples -> the 100 ms floor assumption
        assert_eq!(retry_after_hint(&m, 8), 100);
        // very fast server: clamped up to 50 ms
        m.record_latency(Duration::from_micros(100));
        assert_eq!(retry_after_hint(&m, 8), 50);
        // very slow server: clamped down to 5 s
        let m = Metrics::default();
        m.record_latency(Duration::from_secs(60));
        assert_eq!(retry_after_hint(&m, 8), 5_000);
    }

    #[test]
    fn shed_decision_is_deadline_aware_above_high_water() {
        let m = Metrics::default();
        m.record_latency(Duration::from_millis(400));
        let cap = 8;
        // below the ¾ high-water mark: never shed
        m.queue_depth.store(2, Ordering::Relaxed);
        assert!(shed_decision(&m, cap, Some(1)).is_none());
        // above it: a deadline shorter than the estimated wait sheds …
        m.queue_depth.store(7, Ordering::Relaxed);
        let hint = shed_decision(&m, cap, Some(10)).expect("tight deadline sheds");
        assert!((50..=5_000).contains(&hint), "{hint}");
        // … a generous deadline is admitted …
        assert!(shed_decision(&m, cap, Some(60_000)).is_none());
        // … and no-deadline requests are never shed
        assert!(shed_decision(&m, cap, None).is_none());
    }

    #[test]
    fn render_response_shape() {
        let r = Response::ok(vec![4, 5], 123);
        let s = render_response(&r);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.usize_list("tokens").unwrap(), vec![4, 5]);
        assert_eq!(j.get("latency_us").unwrap().as_usize().unwrap(), 123);
    }

    #[test]
    fn render_timeout_shape() {
        // a timeout reply carries the partial result plus the flag …
        let r = Response::timed_out(vec![4, 5], 123);
        let s = render_response(&r);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.usize_list("tokens").unwrap(), vec![4, 5]);
        assert!(j.get("timeout").unwrap().as_bool().unwrap());
        // … and a normal reply never carries the key at all
        let ok = render_response(&Response::ok(vec![1], 1));
        assert!(Json::parse(&ok).unwrap().opt("timeout").is_none());
    }

    #[test]
    fn render_error_shape() {
        let r = Response::err("engine \"died\"", 7);
        let s = render_response(&r);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "engine \"died\"");
        assert_eq!(j.get("latency_us").unwrap().as_usize().unwrap(), 7);
        assert!(j.opt("tokens").is_none());
    }

    #[test]
    fn argmax_and_sample() {
        let mut row = vec![0.0f32; 16];
        row[7] = 5.0;
        assert_eq!(argmax(&row), 7);
        let mut rng = Pcg32::seeded(1);
        // low temperature concentrates on the argmax
        let mut hits = 0;
        for _ in 0..50 {
            if sample(&row, 0.05, &mut rng) == 7 {
                hits += 1;
            }
        }
        assert!(hits >= 48, "{hits}");
    }

    #[test]
    fn argmax_survives_nan() {
        let row = vec![f32::NAN, 1.0, f32::NAN, 3.0, 2.0];
        assert_eq!(argmax(&row), 3);
        // total_cmp ranks positive NaN above +inf, so NaN must be
        // filtered out, not just ordered
        assert_eq!(argmax(&[f32::NAN, f32::INFINITY]), 1);
        assert_eq!(argmax(&[1.0, f32::NAN]), 0);
        let all_nan = vec![f32::NAN; 4];
        // no finite logit at all: fall back to index 0, no panic
        assert_eq!(argmax(&all_nan), 0);
        let mut rng = Pcg32::seeded(2);
        assert!(sample(&row, 0.5, &mut rng) < 5);
        assert!(sample(&all_nan, 0.5, &mut rng) < 4);
    }

    /// Fake forward: row r's logits peak hard at token r+1, at every
    /// position.  Peak is big enough that even the sampling path is
    /// deterministic (other weights underflow to exactly 0).
    fn row_peaked_step(b: usize, t: usize, vocab: usize) -> impl FnMut(&[i32]) -> Result<Vec<f32>> {
        move |toks: &[i32]| {
            assert_eq!(toks.len(), b * t);
            let mut logits = vec![0.0f32; b * t * vocab];
            for r in 0..b {
                for p in 0..t {
                    logits[(r * t + p) * vocab + (r + 1) % vocab] = 100.0;
                }
            }
            Ok(logits)
        }
    }

    #[test]
    fn decode_batch_mixed_params() {
        let (b, t, vocab) = (3, 4, 8);
        let mut rng = Pcg32::seeded(3);
        let prompts = vec![vec![5u32], vec![6, 7], vec![1, 2, 3]];
        let params = vec![
            DecodeParams::greedy(2),
            DecodeParams { max_tokens: 5, temperature: 0.001, stop: None, speculate: true },
            DecodeParams::greedy(3),
        ];
        let g = decode_batch(row_peaked_step(b, t, vocab), b, t, vocab, &prompts, &params, &mut rng)
            .unwrap();
        // each row got exactly its own budget, decoded with its own
        // temperature against its own logits
        assert_eq!(g.outputs[0], vec![1, 1]);
        assert_eq!(g.outputs[1], vec![2, 2, 2, 2, 2]);
        assert_eq!(g.outputs[2], vec![3, 3, 3]);
        // the longest row bounds the step count
        assert_eq!(g.steps, 5);
    }

    #[test]
    fn decode_batch_stop_token_early_exit() {
        let (b, t, vocab) = (2, 4, 8);
        let mut rng = Pcg32::seeded(4);
        let prompts = vec![vec![5u32], vec![6u32]];
        // both rows would run 10 steps, but their peaked tokens are
        // also their stop tokens: the loop exits after a single step
        let params = vec![
            DecodeParams { max_tokens: 10, temperature: 0.0, stop: Some(1), speculate: true },
            DecodeParams { max_tokens: 10, temperature: 0.0, stop: Some(2), speculate: true },
        ];
        let g = decode_batch(row_peaked_step(b, t, vocab), b, t, vocab, &prompts, &params, &mut rng)
            .unwrap();
        assert_eq!(g.outputs[0], vec![1]);
        assert_eq!(g.outputs[1], vec![2]);
        assert_eq!(g.steps, 1, "all rows done -> early exit");
    }

    #[test]
    fn decode_batch_keeps_recent_context() {
        // prompt longer than the window: the window must hold the
        // *last* t tokens, so the fake step should see them
        let (b, t, vocab) = (1, 3, 8);
        let mut rng = Pcg32::seeded(5);
        let mut seen = Vec::new();
        let step = |toks: &[i32]| {
            seen.push(toks.to_vec());
            Ok(vec![0.0f32; b * t * vocab])
        };
        let prompts = vec![vec![9u32, 8, 7, 6, 5]];
        let params = vec![DecodeParams::greedy(1)];
        let _ = decode_batch(step, b, t, vocab, &prompts, &params, &mut rng).unwrap();
        assert_eq!(seen[0][..3], [7, 6, 5], "window must keep the most recent tokens");
    }

    #[test]
    fn admit_rejects_at_capacity() {
        let ord = std::sync::atomic::Ordering::Relaxed;
        let m = Metrics::default();
        // each successful admit reserves one queue_depth slot
        assert!(admit(&m, 2));
        assert_eq!(m.queue_depth.load(ord), 1);
        assert!(admit(&m, 2));
        assert_eq!(m.queue_depth.load(ord), 2);
        // at cap: rejected, and the reservation is rolled back
        assert!(!admit(&m, 2), "at cap: reject");
        assert!(!admit(&m, 1));
        assert_eq!(m.queue_depth.load(ord), 2, "failed admits leave the gauge untouched");
        assert_eq!(m.rejected.load(ord), 2);
        // a worker draining one request reopens admission
        m.queue_depth.fetch_sub(1, ord);
        assert!(admit(&m, 2), "below cap again: admit");
        assert_eq!(m.queue_depth.load(ord), 2);
        assert_eq!(m.rejected.load(ord), 2);
    }

    #[test]
    fn stats_command_is_intercepted_with_json_and_prometheus() {
        let m = Metrics::default();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.ttft.record_us(1000);
        let line = command_response(r#"{"cmd": "stats"}"#, &m).expect("stats is a control line");
        assert!(!line.contains('\n'), "reply must stay a single protocol line");
        let j = Json::parse(&line).unwrap();
        let stats = j.get("stats").unwrap();
        assert_eq!(
            stats.get("counters").unwrap().get("requests").unwrap().as_usize().unwrap(),
            3
        );
        let prom = j.get("prometheus").unwrap().as_str().unwrap();
        assert!(prom.contains("# TYPE dbllm_requests_total counter"), "{prom}");
        assert!(prom.contains("dbllm_ttft_us{quantile=\"0.5\"}"), "{prom}");
    }

    #[test]
    fn non_command_lines_fall_through_and_unknown_cmds_error() {
        let m = Metrics::default();
        // generate requests and garbage are not control lines
        assert!(command_response(r#"{"prompt": [1], "max_tokens": 4}"#, &m).is_none());
        assert!(command_response("not json", &m).is_none());
        // a non-string cmd is not a control line either
        assert!(command_response(r#"{"cmd": 7}"#, &m).is_none());
        let line = command_response(r#"{"cmd": "reboot"}"#, &m).unwrap();
        let j = Json::parse(&line).unwrap();
        assert!(j.get("error").unwrap().as_str().unwrap().contains("unknown cmd"), "{line}");
    }

    #[test]
    fn overload_error_line_shape() {
        let s = render_response(&Response::err("server overloaded", 0));
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "server overloaded");
    }

    #[test]
    fn decode_batch_zero_budget() {
        let (b, t, vocab) = (1, 4, 8);
        let mut rng = Pcg32::seeded(6);
        let g = decode_batch(
            |_| panic!("no forward should run"),
            b,
            t,
            vocab,
            &[vec![1u32]],
            &[DecodeParams::greedy(0)],
            &mut rng,
        )
        .unwrap();
        assert!(g.outputs[0].is_empty());
        assert_eq!(g.steps, 0);
    }
}
