//! Serving stack: a TCP line-protocol server in front of a generation
//! engine that drives the AOT `fwd_logits` executable.
//!
//! Topology (std threads; rust owns the event loop — python is never on
//! this path):
//!
//!   client ──TCP──▶ connection thread ──mpsc──▶ batcher/worker thread
//!                                                 │ fwd_logits (XLA)
//!   client ◀──TCP── response channel ◀────────────┘
//!
//! Protocol: one JSON object per line.
//!   request:  {"prompt": [int, ...], "max_tokens": int, "temperature"?: float}
//!   response: {"tokens": [int, ...], "latency_us": int}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::{Runtime, Session};
use crate::util::{Json, Pcg32};

use super::batcher::{next_batch, BatchPolicy};
use super::metrics::Metrics;

/// An in-flight request.
pub struct Request {
    pub prompt: Vec<u32>,
    pub max_tokens: usize,
    pub temperature: f32,
    pub reply: Sender<Response>,
    pub arrived: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub tokens: Vec<u32>,
    pub latency_us: u64,
}

/// Generation engine over a pinned session.
pub struct Engine {
    pub session: Session,
    pub vocab: usize,
    rng: Pcg32,
}

impl Engine {
    pub fn new(session: Session, vocab: usize, seed: u64) -> Engine {
        Engine { session, vocab, rng: Pcg32::seeded(seed) }
    }

    /// Decode a batch of prompts (greedy if temperature == 0).
    ///
    /// The AOT executable has a fixed [B, T] shape: the context is a
    /// sliding window over the last T tokens; each step runs one full
    /// forward and reads the logits at each row's current last position.
    pub fn generate(
        &mut self,
        rt: &mut Runtime,
        prompts: &[Vec<u32>],
        max_new: usize,
        temperature: f32,
    ) -> Result<Vec<Vec<u32>>> {
        let b = self.session.logits_batch;
        let t = self.session.seq_len;
        anyhow::ensure!(prompts.len() <= b, "batch too large");
        let mut seqs: Vec<Vec<u32>> = prompts.to_vec();
        for s in &mut seqs {
            anyhow::ensure!(!s.is_empty(), "empty prompt");
            s.truncate(t);
        }
        let mut outputs: Vec<Vec<u32>> = vec![Vec::new(); prompts.len()];

        for _ in 0..max_new {
            // pack the sliding windows (right-padded with last token)
            let mut toks = vec![0i32; b * t];
            let mut pos = vec![0usize; prompts.len()];
            for (r, s) in seqs.iter().enumerate() {
                let start = s.len().saturating_sub(t);
                let window = &s[start..];
                for (i, &tok) in window.iter().enumerate() {
                    toks[r * t + i] = tok as i32;
                }
                for i in window.len()..t {
                    toks[r * t + i] = *window.last().unwrap() as i32;
                }
                pos[r] = window.len() - 1;
            }
            let logits = self.session.logits(rt, &toks)?;
            for r in 0..prompts.len() {
                let off = (r * t + pos[r]) * self.vocab;
                let row = &logits[off..off + self.vocab];
                let next = if temperature <= 0.0 {
                    argmax(row)
                } else {
                    sample(row, temperature, &mut self.rng)
                };
                seqs[r].push(next as u32);
                outputs[r].push(next as u32);
            }
        }
        Ok(outputs)
    }
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

fn sample(row: &[f32], temperature: f32, rng: &mut Pcg32) -> usize {
    let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let w: Vec<f64> = row.iter().map(|&v| (((v - mx) / temperature) as f64).exp()).collect();
    rng.categorical(&w)
}

/// The worker loop: batch → generate → reply.
pub fn worker_loop(
    mut rt: Runtime,
    mut engine: Engine,
    rx: Receiver<Request>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
) {
    while running.load(Ordering::Relaxed) {
        let Some(batch) = next_batch(&rx, &policy) else { break };
        metrics.record_batch(batch.len());
        let prompts: Vec<Vec<u32>> = batch.iter().map(|r| r.prompt.clone()).collect();
        let max_new = batch.iter().map(|r| r.max_tokens).max().unwrap_or(1);
        let temperature = batch[0].temperature;
        match engine.generate(&mut rt, &prompts, max_new, temperature) {
            Ok(outs) => {
                for (req, mut out) in batch.into_iter().zip(outs) {
                    out.truncate(req.max_tokens);
                    let latency = req.arrived.elapsed();
                    metrics.record_latency(latency);
                    metrics.responses.fetch_add(1, Ordering::Relaxed);
                    metrics.tokens_out.fetch_add(out.len() as u64, Ordering::Relaxed);
                    let _ = req.reply.send(Response {
                        tokens: out,
                        latency_us: latency.as_micros() as u64,
                    });
                }
            }
            Err(e) => {
                eprintln!("worker error: {e:#}");
            }
        }
    }
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<(Vec<u32>, usize, f32)> {
    let j = Json::parse(line).context("bad request json")?;
    let prompt: Vec<u32> = j
        .get("prompt")?
        .as_arr()?
        .iter()
        .map(|v| v.as_usize().map(|u| u as u32))
        .collect::<Result<_>>()?;
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_tokens = j.get("max_tokens")?.as_usize()?;
    let temperature = j.opt("temperature").map(|t| t.as_f64().unwrap_or(0.0)).unwrap_or(0.0) as f32;
    Ok((prompt, max_tokens, temperature))
}

/// Render one response line.
pub fn render_response(resp: &Response) -> String {
    let toks = Json::Arr(resp.tokens.iter().map(|&t| Json::num(t as f64)).collect());
    Json::obj(vec![
        ("tokens", toks),
        ("latency_us", Json::num(resp.latency_us as f64)),
    ])
    .to_string()
}

fn handle_conn(stream: TcpStream, tx: Sender<Request>, metrics: Arc<Metrics>) {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        match parse_request(&line) {
            Ok((prompt, max_tokens, temperature)) => {
                metrics.requests.fetch_add(1, Ordering::Relaxed);
                let (reply_tx, reply_rx) = channel();
                if tx
                    .send(Request {
                        prompt,
                        max_tokens,
                        temperature,
                        reply: reply_tx,
                        arrived: Instant::now(),
                    })
                    .is_err()
                {
                    break;
                }
                match reply_rx.recv() {
                    Ok(resp) => {
                        let _ = writeln!(writer, "{}", render_response(&resp));
                    }
                    Err(_) => break,
                }
            }
            Err(e) => {
                let _ = writeln!(writer, "{{\"error\": \"{e}\"}}");
            }
        }
    }
    let _ = peer;
}

/// Run the server until `running` is cleared.  Binds `addr`, spawns one
/// thread per connection; the worker thread *constructs* the XLA
/// runtime via `factory` (PJRT handles are not `Send`, so they must be
/// born on the thread that uses them).
pub fn serve(
    factory: impl FnOnce() -> Result<(Runtime, Engine)> + Send + 'static,
    addr: &str,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let (tx, rx) = channel::<Request>();

    let m2 = metrics.clone();
    let r2 = running.clone();
    std::thread::spawn(move || match factory() {
        Ok((rt, engine)) => worker_loop(rt, engine, rx, policy, m2, r2),
        Err(e) => eprintln!("engine init failed: {e:#}"),
    });

    let m3 = metrics;
    let r3 = running;
    std::thread::spawn(move || {
        while r3.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let tx = tx.clone();
                    let m = m3.clone();
                    std::thread::spawn(move || handle_conn(stream, tx, m));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(_) => break,
            }
        }
    });
    Ok(local)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_roundtrip() {
        let (p, m, t) = parse_request(r#"{"prompt": [1, 2, 3], "max_tokens": 8}"#).unwrap();
        assert_eq!(p, vec![1, 2, 3]);
        assert_eq!(m, 8);
        assert_eq!(t, 0.0);
        let (_, _, t2) =
            parse_request(r#"{"prompt": [1], "max_tokens": 1, "temperature": 0.7}"#).unwrap();
        assert!((t2 - 0.7).abs() < 1e-6);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"prompt": [], "max_tokens": 4}"#).is_err());
        assert!(parse_request(r#"{"max_tokens": 4}"#).is_err());
    }

    #[test]
    fn render_response_shape() {
        let r = Response { tokens: vec![4, 5], latency_us: 123 };
        let s = render_response(&r);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.usize_list("tokens").unwrap(), vec![4, 5]);
        assert_eq!(j.get("latency_us").unwrap().as_usize().unwrap(), 123);
    }

    #[test]
    fn argmax_and_sample() {
        let mut row = vec![0.0f32; 16];
        row[7] = 5.0;
        assert_eq!(argmax(&row), 7);
        let mut rng = Pcg32::seeded(1);
        // low temperature concentrates on the argmax
        let mut hits = 0;
        for _ in 0..50 {
            if sample(&row, 0.05, &mut rng) == 7 {
                hits += 1;
            }
        }
        assert!(hits >= 48, "{hits}");
    }
}
