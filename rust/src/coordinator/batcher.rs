//! Continuous batcher: groups incoming requests into fixed-capacity
//! batches under a linger deadline — the standard dynamic-batching
//! policy of LLM serving stacks (vLLM/Orca style), sized here to the
//! AOT executables' fixed batch dimension.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// hard cap = the executable's batch dimension
    pub max_batch: usize,
    /// wait at most this long to fill a batch
    pub linger: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 4, linger: Duration::from_millis(20) }
    }
}

/// Pull the next batch from `rx`.  Blocks for the first item, then
/// lingers up to the deadline collecting more, never exceeding
/// `max_batch`.  Returns None when the channel is closed and drained.
pub fn next_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.linger;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Multi-consumer batch pull for a worker pool: `Receiver` is not
/// `Sync`, so competing workers share it behind a mutex.  Exactly one
/// worker holds the lock while it collects a batch (blocking for the
/// first item, then lingering), releases it, and decodes — so batch
/// collection and decoding pipeline across workers, and every queued
/// item lands in exactly one batch.  Returns None once the channel is
/// closed and drained (or the lock is poisoned); callers treat that as
/// shutdown.
pub fn next_batch_shared<T>(rx: &Mutex<Receiver<T>>, policy: &BatchPolicy) -> Option<Vec<T>> {
    let guard = rx.lock().ok()?;
    next_batch(&guard, policy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    #[test]
    fn batches_respect_capacity() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, linger: Duration::from_millis(5) };
        let b1 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b1, vec![0, 1, 2, 3]);
        let b2 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b2.len(), 4);
    }

    #[test]
    fn partial_batch_after_linger() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let policy = BatchPolicy { max_batch: 8, linger: Duration::from_millis(10) };
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![1, 2]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn none_when_closed() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn drains_before_close() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        drop(tx);
        let b = next_batch(&rx, &BatchPolicy::default()).unwrap();
        assert_eq!(b, vec![7]);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn shared_receiver_partitions_items_exactly_once() {
        let (tx, rx) = channel();
        let rx = Arc::new(Mutex::new(rx));
        let n_items = 64usize;
        for i in 0..n_items {
            tx.send(i).unwrap();
        }
        drop(tx);
        let policy = BatchPolicy { max_batch: 4, linger: Duration::from_millis(1) };
        let mut handles = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            let policy = policy.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(batch) = next_batch_shared(&rx, &policy) {
                    assert!(batch.len() <= policy.max_batch);
                    got.extend(batch);
                }
                got
            }));
        }
        let mut all: Vec<usize> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        // every item consumed exactly once across the pool
        assert_eq!(all, (0..n_items).collect::<Vec<_>>());
    }

    #[test]
    fn late_arrivals_join_within_linger() {
        let (tx, rx) = channel();
        tx.send(0).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(3));
            tx.send(1).unwrap();
        });
        let policy = BatchPolicy { max_batch: 4, linger: Duration::from_millis(50) };
        let b = next_batch(&rx, &policy).unwrap();
        handle.join().unwrap();
        assert_eq!(b, vec![0, 1]);
    }
}
