//! Continuous batcher: groups incoming requests into fixed-capacity
//! batches under a linger deadline — the standard dynamic-batching
//! policy of LLM serving stacks (vLLM/Orca style), sized here to the
//! AOT executables' fixed batch dimension.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// How long a shared-queue worker waits for a first item before
/// re-checking the shutdown flag: bounds shutdown latency without
/// spinning while the queue is idle.
const SHUTDOWN_POLL: Duration = Duration::from_millis(50);

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// hard cap = the executable's batch dimension
    pub max_batch: usize,
    /// wait at most this long to fill a batch
    pub linger: Duration,
    /// admission cap on the shared request queue: requests arriving
    /// while `queue_depth >= queue_cap` are rejected immediately with
    /// `"server overloaded"` instead of growing latency without bound
    pub queue_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 4, linger: Duration::from_millis(20), queue_cap: 1024 }
    }
}

impl BatchPolicy {
    /// Clamp `max_batch` to an executable's fixed batch dimension.  A
    /// batch collected above that dimension makes every decode bail
    /// with "batch too large" — a persistent misconfiguration that
    /// looks like engine failure — so workers clamp at startup.
    /// Returns the rejected value when clamping happened.
    pub fn clamp_max_batch(&mut self, batch_dim: usize) -> Option<usize> {
        let cap = batch_dim.max(1);
        (self.max_batch > cap).then(|| std::mem::replace(&mut self.max_batch, cap))
    }
}

/// Pull the next batch from `rx`.  Blocks for the first item, then
/// lingers up to the deadline collecting more, never exceeding
/// `max_batch`.  Returns None when the channel is closed and drained.
pub fn next_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    linger_fill(rx, policy, &mut batch);
    Some(batch)
}

/// After the first item: linger up to the deadline topping the batch up
/// to `max_batch`.
fn linger_fill<T>(rx: &Receiver<T>, policy: &BatchPolicy, batch: &mut Vec<T>) {
    let deadline = Instant::now() + policy.linger;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
}

/// Multi-consumer batch pull for a worker pool: `Receiver` is not
/// `Sync`, so competing workers share it behind a mutex.  Exactly one
/// worker holds the lock while it collects a batch, releases it, and
/// decodes — so batch collection and decoding pipeline across workers,
/// and every queued item lands in exactly one batch.
///
/// The wait for the *first* item is bounded (`SHUTDOWN_POLL`) so a
/// cleared `running` flag is observed even while the queue is idle and
/// senders are still alive (connection threads hold `tx` clones for as
/// long as clients stay connected; an unbounded `recv` would pin the
/// lock until the last one disconnects).  Once the flag is cleared,
/// items already queued are still handed back (without lingering) so
/// the caller can answer them — a queued request is never silently
/// dropped.  Returns None on shutdown with an empty queue, or once the
/// channel is closed and drained (or the lock is poisoned).
pub fn next_batch_shared<T>(
    rx: &Mutex<Receiver<T>>,
    policy: &BatchPolicy,
    running: &AtomicBool,
) -> Option<Vec<T>> {
    loop {
        let guard = rx.lock().ok()?;
        if !running.load(Ordering::Relaxed) {
            let mut batch = Vec::new();
            while batch.len() < policy.max_batch {
                match guard.try_recv() {
                    Ok(item) => batch.push(item),
                    Err(_) => break,
                }
            }
            return (!batch.is_empty()).then_some(batch);
        }
        match guard.recv_timeout(SHUTDOWN_POLL) {
            Ok(first) => {
                let mut batch = vec![first];
                linger_fill(&guard, policy, &mut batch);
                return Some(batch);
            }
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    #[test]
    fn batches_respect_capacity() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 4,
            linger: Duration::from_millis(5),
            ..Default::default()
        };
        let b1 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b1, vec![0, 1, 2, 3]);
        let b2 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b2.len(), 4);
    }

    #[test]
    fn partial_batch_after_linger() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let policy = BatchPolicy {
            max_batch: 8,
            linger: Duration::from_millis(10),
            ..Default::default()
        };
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![1, 2]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn none_when_closed() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn drains_before_close() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        drop(tx);
        let b = next_batch(&rx, &BatchPolicy::default()).unwrap();
        assert_eq!(b, vec![7]);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn shared_receiver_partitions_items_exactly_once() {
        let (tx, rx) = channel();
        let rx = Arc::new(Mutex::new(rx));
        let running = Arc::new(AtomicBool::new(true));
        let n_items = 64usize;
        for i in 0..n_items {
            tx.send(i).unwrap();
        }
        drop(tx);
        let policy = BatchPolicy {
            max_batch: 4,
            linger: Duration::from_millis(1),
            ..Default::default()
        };
        let mut handles = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            let policy = policy.clone();
            let running = running.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(batch) = next_batch_shared(&rx, &policy, &running) {
                    assert!(batch.len() <= policy.max_batch);
                    got.extend(batch);
                }
                got
            }));
        }
        let mut all: Vec<usize> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        // every item consumed exactly once across the pool
        assert_eq!(all, (0..n_items).collect::<Vec<_>>());
    }

    #[test]
    fn shared_pull_observes_shutdown_while_idle() {
        let (tx, rx) = channel::<u32>();
        let rx = Arc::new(Mutex::new(rx));
        let running = Arc::new(AtomicBool::new(true));
        let handle = {
            let (rx, running) = (rx.clone(), running.clone());
            std::thread::spawn(move || next_batch_shared(&rx, &BatchPolicy::default(), &running))
        };
        std::thread::sleep(Duration::from_millis(5));
        running.store(false, Ordering::Relaxed);
        // the sender stays alive: only the cleared flag can end the wait
        assert!(handle.join().unwrap().is_none());
        drop(tx);
    }

    #[test]
    fn shutdown_hands_back_queued_items() {
        let (tx, rx) = channel();
        let rx = Mutex::new(rx);
        let running = AtomicBool::new(false);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        // flag already cleared: queued items still come back (no
        // linger) so the caller can answer them, then None
        let b = next_batch_shared(&rx, &BatchPolicy::default(), &running).unwrap();
        assert_eq!(b, vec![1, 2]);
        assert!(next_batch_shared(&rx, &BatchPolicy::default(), &running).is_none());
        drop(tx);
    }

    #[test]
    fn policy_clamps_to_batch_dim() {
        let mut p = BatchPolicy {
            max_batch: 16,
            linger: Duration::from_millis(1),
            ..Default::default()
        };
        assert_eq!(p.clamp_max_batch(4), Some(16));
        assert_eq!(p.max_batch, 4);
        // already within the dim: untouched
        assert_eq!(p.clamp_max_batch(4), None);
        assert_eq!(p.max_batch, 4);
        // degenerate batch dim still leaves a working (size-1) pool
        assert_eq!(p.clamp_max_batch(0), Some(4));
        assert_eq!(p.max_batch, 1);
    }

    #[test]
    fn late_arrivals_join_within_linger() {
        let (tx, rx) = channel();
        tx.send(0).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(3));
            tx.send(1).unwrap();
        });
        let policy = BatchPolicy {
            max_batch: 4,
            linger: Duration::from_millis(50),
            ..Default::default()
        };
        let b = next_batch(&rx, &policy).unwrap();
        handle.join().unwrap();
        assert_eq!(b, vec![0, 1]);
    }
}
