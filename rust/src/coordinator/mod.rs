//! Layer-3 coordinator: the DAD fine-tuning driver (AdamW loop around
//! the AOT `dad_step` executable — gradients come from XLA, the
//! optimizer and state management live here) and the serving stack
//! (TCP line-protocol server, dynamic batcher, static worker pool,
//! iteration-level continuous-batching scheduler, metrics).
//!
//! The request lifecycle across these modules is documented end to end
//! in `rust/docs/ARCHITECTURE.md`.

#![warn(missing_docs)]

pub mod batcher;
pub mod chaos;
pub mod finetune;
pub mod metrics;
pub mod scheduler;
pub mod serve;
pub mod trace;

pub use finetune::{DadConfig, DadTrainer};
