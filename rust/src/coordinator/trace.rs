//! Request-lifecycle tracing: a bounded overwrite-oldest ring buffer
//! and the per-request phase-timing span it stores.
//!
//! The scheduler's original `trace: Vec<TraceEvent>` was explicitly
//! simulation-only — unbounded growth made it unsafe to leave on in a
//! long-running server.  [`TraceRing`] fixes that: capacity is paid
//! once at construction, `push` never allocates (safe to call next to
//! `tidy:no-alloc` hot regions), and when full the *oldest* entry is
//! overwritten while a drop counter records the loss.  The scheduler
//! keeps two rings: the fine-grained `TraceEvent` log (admissions,
//! finishes, expiries) and the always-on [`RequestSpan`] ring with one
//! phase-timed record per finished request
//! (queue-wait → admission → prefill → decode → reply; see
//! `docs/ARCHITECTURE.md` for the span lifecycle diagram).

use std::collections::VecDeque;

/// Fixed-capacity ring buffer that overwrites its oldest entry when
/// full and counts every overwritten (dropped) entry.
///
/// The backing `VecDeque` is reserved once in [`TraceRing::new`];
/// `push` is allocation-free for the lifetime of the ring, so tracing
/// can stay enabled inside the serving hot path.
#[derive(Debug)]
pub struct TraceRing<T> {
    buf: VecDeque<T>,
    cap: usize,
    dropped: u64,
}

impl<T> TraceRing<T> {
    /// Create a ring holding at most `cap` entries (`cap` is clamped
    /// to ≥ 1 so `push` always retains the newest entry).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        TraceRing { buf: VecDeque::with_capacity(cap), cap, dropped: 0 }
    }

    /// Append an entry; when the ring is full the oldest entry is
    /// discarded and the drop counter incremented.  Never allocates.
    pub fn push(&mut self, item: T) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(item);
    }

    /// Number of entries currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when the ring holds no entries.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Maximum number of entries the ring retains.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Total entries overwritten before being read (monotonic).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// The retained entries, oldest first, as one contiguous slice.
    /// Takes `&mut self` because the two halves of the deque may need
    /// to be made contiguous in place (no allocation).
    pub fn as_slice(&mut self) -> &[T] {
        self.buf.make_contiguous();
        self.buf.as_slices().0
    }

    /// Drain every retained entry, oldest first, leaving the ring
    /// empty (capacity and drop counter are kept).
    pub fn take(&mut self) -> Vec<T> {
        self.buf.drain(..).collect()
    }
}

/// One finished request's phase-timed lifecycle record.
///
/// Stamped by the scheduler as the request leaves its slot (or expires
/// in queue) and retained in the span ring for the stats surface and
/// post-hoc debugging.  All timings are microseconds on the
/// scheduler's `Clock` (wall in production, scripted in sims) except
/// `prefill_us`, which is wall time inside the engine's prefill call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RequestSpan {
    /// Scheduler-assigned request id (matches `TraceEvent` ids).
    pub id: u64,
    /// Arrival → slot admission, µs — includes time spent in the
    /// upstream shared request queue before `submit` saw it.
    pub queue_wait_us: u64,
    /// Clock stamp (µs since scheduler start) when the request was
    /// admitted to a slot; 0 for requests that expired in queue.
    pub admitted_at_us: u64,
    /// Wall time inside `prefill_slot` (cache walk + block copy-in +
    /// suffix forward), µs; 0 for requests that expired in queue.
    pub prefill_us: u64,
    /// Prompt tokens served from the shared prefix cache during this
    /// request's prefill.
    pub prefix_hit_tokens: u32,
    /// Prompt tokens that paid prefill (uncached suffix, or the whole
    /// prompt on a cache miss/bypass).
    pub prefix_miss_tokens: u32,
    /// Tokens decoded into the reply.
    pub decoded: u32,
    /// Draft tokens the speculative student proposed for this request
    /// (0 for plain rows or non-speculative engines).
    pub drafted: u32,
    /// Draft tokens the teacher verify pass accepted — each one is a
    /// dense teacher forward this request did not pay.
    pub accepted: u32,
    /// Slot admission → finish, µs (covers prefill + every decode
    /// tick); 0 for requests that expired in queue.
    pub decode_us: u64,
    /// Why the request left: `"done"` (budget/stop token), `"timeout"`
    /// (deadline eviction), `"expired"` (deadline passed while still
    /// queued), `"error"`, or `"supervisor"` (worker panicked; the
    /// supervisor answered the request while quarantining its slot).
    pub reason: &'static str,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_newest_and_counts_drops() {
        let mut r = TraceRing::new(3);
        for i in 0..7u32 {
            r.push(i);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.capacity(), 3);
        assert_eq!(r.dropped(), 4);
        assert_eq!(r.as_slice(), &[4, 5, 6], "oldest first, newest retained");
    }

    #[test]
    fn take_drains_in_order_and_preserves_drop_counter() {
        let mut r = TraceRing::new(2);
        r.push("a");
        r.push("b");
        r.push("c");
        assert_eq!(r.take(), vec!["b", "c"]);
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 1, "drop counter survives take()");
        r.push("d");
        assert_eq!(r.as_slice(), &["d"], "ring is reusable after take()");
    }

    #[test]
    fn push_never_allocates_after_construction() {
        let mut r = TraceRing::new(8);
        let cap_before = r.buf.capacity();
        for i in 0..1000u64 {
            r.push(i);
        }
        assert_eq!(r.buf.capacity(), cap_before, "push must not grow the backing deque");
        assert_eq!(r.len(), 8);
        assert_eq!(r.dropped(), 992);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut r = TraceRing::new(0);
        r.push(1u8);
        r.push(2u8);
        assert_eq!(r.as_slice(), &[2]);
        assert_eq!(r.dropped(), 1);
    }
}
