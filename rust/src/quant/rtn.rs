//! Round-to-nearest (RTN) uniform quantization — the baseline every
//! table starts from, and the proxy initializer FDB splits (Eq. 1-2).
//!
//! Per-(group, out-column) symmetric grids:
//!   k = 1: XNOR-style binarization {-α, +α}, α = mean|w|  (Table 6 row)
//!   k ≥ 2: levels {-2^(k-1), …, 2^(k-1)-1}·s, s = max|w| / 2^(k-1).

use super::{group_ranges, scale_overhead_bits, Calib, Quantized, Quantizer};
use crate::tensor::Matrix;

/// k-bit RTN with per-group scales.
pub struct Rtn {
    /// target weight bits (1 = XNOR-style binarization)
    pub bits: u32,
    /// quantization group size along the in-dimension
    pub group: usize,
}

impl Rtn {
    /// `bits`-bit, group-`group` RTN (`bits` must be in 1..=8).
    pub fn new(bits: u32, group: usize) -> Self {
        assert!(bits >= 1 && bits <= 8);
        Rtn { bits, group }
    }

    /// Quantize one group of one column; returns (scale, levels written).
    fn quantize_group(&self, w: &Matrix, range: std::ops::Range<usize>, col: usize) -> f32 {
        if self.bits == 1 {
            // binarization: α = mean|w| minimizes L2 for sign codes
            let mut acc = 0.0f64;
            for r in range.clone() {
                acc += w.at(r, col).abs() as f64;
            }
            (acc / range.len() as f64) as f32
        } else {
            let mut mx = 0.0f32;
            for r in range.clone() {
                mx = mx.max(w.at(r, col).abs());
            }
            (mx / (1 << (self.bits - 1)) as f32).max(1e-8)
        }
    }

    #[inline]
    fn quantize_value(&self, v: f32, s: f32) -> f32 {
        if self.bits == 1 {
            if v >= 0.0 {
                s
            } else {
                -s
            }
        } else {
            let qmax = (1 << (self.bits - 1)) as f32 - 1.0;
            let qmin = -((1 << (self.bits - 1)) as f32);
            let q = (v / s).round().clamp(qmin, qmax);
            q * s
        }
    }

    /// Dequantized matrix + per-group scales `[g, out]`.
    pub fn quantize_with_scales(&self, w: &Matrix) -> (Matrix, Matrix) {
        let groups = group_ranges(w.rows, self.group);
        let mut w_hat = Matrix::zeros(w.rows, w.cols);
        let mut scales = Matrix::zeros(groups.len(), w.cols);
        for c in 0..w.cols {
            for (g, range) in &groups {
                let s = self.quantize_group(w, range.clone(), c);
                *scales.at_mut(*g, c) = s;
                for r in range.clone() {
                    *w_hat.at_mut(r, c) = self.quantize_value(w.at(r, c), s);
                }
            }
        }
        (w_hat, scales)
    }
}

impl Quantizer for Rtn {
    fn name(&self) -> String {
        format!("RTN-W{}", self.bits)
    }

    fn quantize(&self, w: &Matrix, _calib: &Calib) -> Quantized {
        let (w_hat, _) = self.quantize_with_scales(w);
        Quantized {
            w_hat,
            bits_per_weight: self.bits as f64 + scale_overhead_bits(self.group),
            method: self.name(),
            fdb: None,
        }
    }
}

/// The 2-bit proxy scale FDB initializes from: s = max|w| / 2 per group.
pub fn proxy_scales(w: &Matrix, group: usize) -> Matrix {
    let groups = group_ranges(w.rows, group);
    let mut scales = Matrix::zeros(groups.len(), w.cols);
    for c in 0..w.cols {
        for (g, range) in &groups {
            let mut mx = 0.0f32;
            for r in range.clone() {
                mx = mx.max(w.at(r, c).abs());
            }
            *scales.at_mut(*g, c) = (mx / 2.0).max(1e-8);
        }
    }
    scales
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg32};

    #[test]
    fn rtn_error_bounded_by_scale() {
        prop::check(20, |rng| {
            let bits = rng.range(2, 5) as u32;
            let w = Matrix::randn(128, rng.range(1, 20), rng, 2.0);
            let rtn = Rtn::new(bits, 64);
            let (w_hat, scales) = rtn.quantize_with_scales(&w);
            for c in 0..w.cols {
                for r in 0..w.rows {
                    let s = scales.at(r / 64, c);
                    let err = (w.at(r, c) - w_hat.at(r, c)).abs();
                    // grid covers [-max,max-s]: worst-case err is s (top clip)
                    assert!(err <= s * 1.0001 + 1e-6, "err {err} > s {s}");
                }
            }
        });
    }

    #[test]
    fn rtn_values_on_grid() {
        let mut rng = Pcg32::seeded(7);
        let w = Matrix::randn(64, 8, &mut rng, 1.0);
        let rtn = Rtn::new(2, 64);
        let (w_hat, scales) = rtn.quantize_with_scales(&w);
        for c in 0..8 {
            let s = scales.at(0, c);
            for r in 0..64 {
                let q = w_hat.at(r, c) / s;
                assert!((q.round() - q).abs() < 1e-4);
                assert!((-2.0..=1.0).contains(&q.round()));
            }
        }
    }

    #[test]
    fn binarization_uses_sign_and_mean() {
        let w = Matrix::from_vec(
            64,
            1,
            (0..64).map(|i| if i % 2 == 0 { 2.0 } else { -4.0 }).collect(),
        );
        let rtn = Rtn::new(1, 64);
        let (w_hat, scales) = rtn.quantize_with_scales(&w);
        let alpha = scales.at(0, 0);
        assert!((alpha - 3.0).abs() < 1e-5);
        for r in 0..64 {
            let expect = if r % 2 == 0 { alpha } else { -alpha };
            assert_eq!(w_hat.at(r, 0), expect);
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Pcg32::seeded(8);
        let w = Matrix::randn(256, 16, &mut rng, 1.5);
        let c = Calib::empty(256);
        let e2 = Rtn::new(2, 64).quantize(&w, &c).w_hat.mse(&w);
        let e3 = Rtn::new(3, 64).quantize(&w, &c).w_hat.mse(&w);
        let e4 = Rtn::new(4, 64).quantize(&w, &c).w_hat.mse(&w);
        assert!(e3 < e2);
        assert!(e4 < e3);
    }

    #[test]
    fn bits_per_weight_accounting() {
        let q = Rtn::new(2, 64).quantize(&Matrix::zeros(64, 4), &Calib::empty(64));
        assert!((q.bits_per_weight - 2.25).abs() < 1e-12);
    }
}
