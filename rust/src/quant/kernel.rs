//! Optimized execution forms of the FDB layer (§Perf L3 iteration log —
//! see EXPERIMENTS.md §Perf for the measured ladder):
//!
//!   v0  `FdbLinear::matvec`     — per-bit trailing_zeros walk (baseline)
//!   v1  `bit_dot_bytes`         — byte-granular zero skipping
//!   v2  `FdbExec` (this file)   — the packed planes are *decoded once
//!       per layer load* into a CSC level stream (storage on disk stays
//!       2 bits/weight; this is a runtime cache, like a dequant kernel's
//!       shared-memory staging), and the matmul runs column-major with
//!       the batch dimension innermost so every nonzero level touches
//!       `m` contiguous activations — the CPU analogue of the paper's
//!       "two binary matmuls feeding one accumulator".

use std::cell::RefCell;

use super::fdb::FdbLinear;
use super::packing::WORD_BITS;
use crate::tensor::Matrix;

/// Reusable transpose scratch for [`FdbExec::matmul`].  The decode hot
/// loop calls matmul every linear of every step; without this each call
/// churned two fresh `din*m` / `dout*m` allocations.
#[derive(Default)]
pub struct FdbScratch {
    xt: Vec<f32>,
    yt: Vec<f32>,
}

impl FdbScratch {
    /// Pre-size for products up to `[m, din]·[din, dout]` so later
    /// calls against this scratch allocate nothing — engines call this
    /// at build time so the first decode tick pays no allocation.
    pub fn reserve(&mut self, m: usize, din: usize, dout: usize) {
        if self.xt.len() < din * m {
            self.xt.resize(din * m, 0.0);
        }
        if self.yt.len() < dout * m {
            self.yt.resize(dout * m, 0.0);
        }
    }
}

thread_local! {
    /// Per-thread scratch behind the allocation-free [`FdbExec::matmul`]
    /// entry point (engine workers each live on their own thread).
    static MM_SCRATCH: RefCell<FdbScratch> = RefCell::new(FdbScratch::default());
}

/// Pre-size this thread's [`FdbExec::matmul`] scratch.  Engine
/// construction runs on the worker thread that will decode, so warming
/// here makes the first prefill on that thread allocation-free too.
pub fn warm_thread_scratch(m: usize, din: usize, dout: usize) {
    MM_SCRATCH.with(|s| s.borrow_mut().reserve(m, din, dout));
}

/// Compiled FDB layer: combined-level CSC.
pub struct FdbExec {
    /// input width (rows of the logical weight matrix)
    pub din: usize,
    /// output width (columns of the logical weight matrix)
    pub dout: usize,
    /// column start offsets into (row_idx, val), length dout+1
    col_ptr: Vec<u32>,
    row_idx: Vec<u16>,
    val: Vec<f32>,
    /// fraction of weights with a non-zero level (work density)
    pub level_density: f64,
}

impl FdbExec {
    /// Decode the dual planes into the execution form.  Levels are
    /// α₁·b1 + α₂·b2 per element; zeros (the majority, Table 6) are
    /// dropped entirely.
    pub fn compile(layer: &FdbLinear) -> FdbExec {
        assert!(layer.din <= u16::MAX as usize + 1, "row index overflows u16");
        let words_per_col = layer.din / WORD_BITS;
        let mut col_ptr = Vec::with_capacity(layer.dout + 1);
        let mut row_idx = Vec::new();
        let mut val = Vec::new();
        col_ptr.push(0u32);
        for c in 0..layer.dout {
            for wi in 0..words_per_col {
                let w1 = layer.b1.words[c * words_per_col + wi];
                let w2 = layer.b2.words[c * words_per_col + wi];
                let mut any = w1 | w2;
                let base = wi * WORD_BITS;
                let sg = base / layer.group;
                let (a1, a2) = (layer.a1.at(sg, c), layer.a2.at(sg, c));
                while any != 0 {
                    let k = any.trailing_zeros() as usize;
                    let bit = 1u64 << k;
                    let mut v = 0.0f32;
                    if w1 & bit != 0 {
                        v += a1;
                    }
                    if w2 & bit != 0 {
                        v += a2;
                    }
                    if v != 0.0 {
                        row_idx.push((base + k) as u16);
                        val.push(v);
                    }
                    any &= any - 1;
                }
            }
            col_ptr.push(row_idx.len() as u32);
        }
        let level_density = row_idx.len() as f64 / (layer.din * layer.dout) as f64;
        FdbExec { din: layer.din, dout: layer.dout, col_ptr, row_idx, val, level_density }
    }

    /// y = x·Ŵ with x `[m, din]` row-major -> y `[m, dout]`.
    ///
    /// Internally transposes x so the batch is contiguous: each nonzero
    /// level performs `m` sequential FMAs — auto-vectorizable.  Uses a
    /// per-thread [`FdbScratch`] so repeated calls (the decode loop)
    /// allocate nothing but the returned matrix.
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        MM_SCRATCH.with(|s| self.matmul_with(x, &mut s.borrow_mut()))
    }

    /// [`matmul`](Self::matmul) against an explicit caller-owned scratch.
    pub fn matmul_with(&self, x: &Matrix, scratch: &mut FdbScratch) -> Matrix {
        assert_eq!(x.cols, self.din);
        let m = x.rows;
        // xt[k*m + r] = x[r, k] — every entry is overwritten below, so
        // the scratch only grows (never shrinks back and re-zeroes)
        if scratch.xt.len() < self.din * m {
            scratch.xt.resize(self.din * m, 0.0);
        }
        let xt = &mut scratch.xt[..self.din * m];
        for r in 0..m {
            let row = x.row(r);
            for k in 0..self.din {
                xt[k * m + r] = row[k];
            }
        }
        // yt accumulates, so its used prefix must start zeroed — but
        // exactly once: growth zero-fills the whole buffer, steady-state
        // reuse re-zeroes just the prefix (the old resize-then-fill did
        // both passes on every growing call)
        let need = self.dout * m;
        if scratch.yt.len() < need {
            scratch.yt.clear();
            scratch.yt.resize(need, 0.0);
        } else {
            scratch.yt[..need].fill(0.0);
        }
        let yt = &mut scratch.yt[..need];
        for c in 0..self.dout {
            let s = self.col_ptr[c] as usize;
            let e = self.col_ptr[c + 1] as usize;
            let acc = &mut yt[c * m..(c + 1) * m];
            for i in s..e {
                let k = self.row_idx[i] as usize;
                let v = self.val[i];
                let src = &xt[k * m..k * m + m];
                for (a, &xv) in acc.iter_mut().zip(src) {
                    *a += v * xv;
                }
            }
        }
        // transpose back
        let mut y = Matrix::zeros(m, self.dout);
        for c in 0..self.dout {
            for r in 0..m {
                y.data[r * self.dout + c] = yt[c * m + r];
            }
        }
        y
    }

    /// Row-major-in / row-major-out batched product into a caller-owned
    /// `[m, dout]` buffer — the fused multi-slot decode entry.
    ///
    /// Keeps the batch innermost like [`matmul_with`](Self::matmul_with)
    /// (every nonzero level does up to `TILE` contiguous FMAs), but
    /// accumulates each column's rows in a stack-resident tile and
    /// scatters them straight into `y`, so the `[dout, m]` scratch
    /// accumulator — its zeroing pass and the final output transpose —
    /// disappears entirely.  Per output element the additions run in
    /// the same CSC order as [`matvec`](Self::matvec), which keeps
    /// fused and sequential decode bit-identical.
    pub fn matmul_rows(&self, x: &Matrix, y: &mut [f32], scratch: &mut FdbScratch) {
        // tidy:no-alloc(start): fused-decode kernel — writes into the
        // caller's buffer; the transpose scratch only grows until the
        // shapes stabilize (reserve_rows pre-sizes it).
        assert_eq!(x.cols, self.din);
        let m = x.rows;
        assert_eq!(y.len(), m * self.dout, "output buffer is not [m, dout]");
        // xt[k*m + r] = x[r, k] — every entry overwritten
        if scratch.xt.len() < self.din * m {
            scratch.xt.resize(self.din * m, 0.0);
        }
        let xt = &mut scratch.xt[..self.din * m];
        for r in 0..m {
            let row = x.row(r);
            for k in 0..self.din {
                xt[k * m + r] = row[k];
            }
        }
        const TILE: usize = 8;
        let mut r0 = 0;
        while r0 < m {
            let tw = TILE.min(m - r0);
            for c in 0..self.dout {
                let s = self.col_ptr[c] as usize;
                let e = self.col_ptr[c + 1] as usize;
                let mut acc = [0.0f32; TILE];
                for i in s..e {
                    let k = self.row_idx[i] as usize;
                    let v = self.val[i];
                    let src = &xt[k * m + r0..k * m + r0 + tw];
                    for (a, &xv) in acc[..tw].iter_mut().zip(src) {
                        *a += v * xv;
                    }
                }
                for (r, &a) in acc[..tw].iter().enumerate() {
                    y[(r0 + r) * self.dout + c] = a;
                }
            }
            r0 += TILE;
        }
        // tidy:no-alloc(end)
    }

    /// Single-vector product (decode-cached v2 path).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        // tidy:no-alloc(start): the sequential decode-step kernel —
        // pure reads over the CSC stream into the caller's buffer.
        assert_eq!(x.len(), self.din);
        for c in 0..self.dout {
            let s = self.col_ptr[c] as usize;
            let e = self.col_ptr[c + 1] as usize;
            let mut acc = 0.0f32;
            for i in s..e {
                acc += self.val[i] * x[self.row_idx[i] as usize];
            }
            y[c] = acc;
        }
        // tidy:no-alloc(end)
    }

    /// Number of stored non-zero combined levels (CSC entries).
    pub fn nnz(&self) -> usize {
        self.row_idx.len()
    }
}

/// v1 inner kernel: byte-granular skip before the bit walk — zero bytes
/// of the mask cost one branch instead of up to 8 dependent pops.
#[inline]
pub fn bit_dot_bytes(word: u64, xs: &[f32]) -> f32 {
    debug_assert_eq!(xs.len(), WORD_BITS);
    let mut acc = 0.0f32;
    let mut w = word;
    while w != 0 {
        let byte_i = (w.trailing_zeros() / 8) as usize;
        let mut m = ((word >> (8 * byte_i)) & 0xff) as u8;
        let base = 8 * byte_i;
        while m != 0 {
            let k = m.trailing_zeros() as usize;
            acc += xs[base + k];
            m &= m - 1;
        }
        w &= !(0xffu64 << (8 * byte_i));
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fdb::bit_dot;
    use crate::util::{prop, Pcg32};

    #[test]
    fn bit_dot_bytes_matches_bit_dot() {
        prop::check(30, |rng| {
            let xs: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
            let word = rng.next_u64() & rng.next_u64(); // ~25% density
            let a = bit_dot(word, &xs);
            let b = bit_dot_bytes(word, &xs);
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        });
    }

    #[test]
    fn exec_matches_reference_matmul() {
        prop::check(12, |rng| {
            let din = 64 * rng.range(1, 5);
            let dout = rng.range(1, 48);
            let w = Matrix::randn(din, dout, rng, 1.0);
            let layer = FdbLinear::from_weights(&w, 64);
            let exec = FdbExec::compile(&layer);
            let x = Matrix::randn(rng.range(1, 9), din, rng, 1.0);
            let y_exec = exec.matmul(&x);
            let y_ref = x.matmul(&layer.dequant());
            for (a, b) in y_exec.data.iter().zip(&y_ref.data) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
            }
        });
    }

    #[test]
    fn exec_matvec_matches_matmul() {
        let mut rng = Pcg32::seeded(77);
        let w = Matrix::randn(128, 32, &mut rng, 1.0);
        let layer = FdbLinear::from_weights(&w, 64);
        let exec = FdbExec::compile(&layer);
        let x = Matrix::randn(1, 128, &mut rng, 1.0);
        let mut y = vec![0.0f32; 32];
        exec.matvec(x.row(0), &mut y);
        let y2 = exec.matmul(&x);
        for (a, b) in y.iter().zip(&y2.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn scratch_reuse_across_shapes_is_clean() {
        // a stale (larger) scratch must not leak accumulator state into
        // a later, smaller matmul
        let mut rng = Pcg32::seeded(79);
        let w_big = Matrix::randn(256, 48, &mut rng, 1.0);
        let w_small = Matrix::randn(64, 8, &mut rng, 1.0);
        let exec_big = FdbExec::compile(&FdbLinear::from_weights(&w_big, 64));
        let exec_small = FdbExec::compile(&FdbLinear::from_weights(&w_small, 64));
        let mut scratch = FdbScratch::default();
        let xb = Matrix::randn(5, 256, &mut rng, 1.0);
        let xs = Matrix::randn(2, 64, &mut rng, 1.0);
        for _ in 0..2 {
            let yb = exec_big.matmul_with(&xb, &mut scratch);
            let ys = exec_small.matmul_with(&xs, &mut scratch);
            for (a, b) in yb.data.iter().zip(&exec_big.matmul(&xb).data) {
                assert!((a - b).abs() < 1e-6);
            }
            for (a, b) in ys.data.iter().zip(&exec_small.matmul(&xs).data) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn matmul_rows_matches_matmul_and_matvec_exactly() {
        prop::check(12, |rng| {
            let din = 64 * rng.range(1, 4);
            let dout = rng.range(1, 48);
            let w = Matrix::randn(din, dout, rng, 1.0);
            let exec = FdbExec::compile(&FdbLinear::from_weights(&w, 64));
            // m spans partial tiles (< 8), one full tile, and a ragged
            // second tile
            let m = rng.range(1, 12);
            let x = Matrix::randn(m, din, rng, 1.0);
            let mut scratch = FdbScratch::default();
            let mut y = vec![0.0f32; m * dout];
            exec.matmul_rows(&x, &mut y, &mut scratch);
            // fp-tolerance against the transposing batched kernel
            let y_ref = exec.matmul(&x);
            for (a, b) in y.iter().zip(&y_ref.data) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
            // bit-exact against the per-row matvec — the contract the
            // fused decode path leans on
            let mut row = vec![0.0f32; dout];
            for r in 0..m {
                exec.matvec(x.row(r), &mut row);
                assert_eq!(&y[r * dout..(r + 1) * dout], &row[..], "row {r} not bit-identical");
            }
        });
    }

    #[test]
    fn matmul_rows_reuses_oversized_scratch_cleanly() {
        // a stale (larger) xt must not leak into a later, smaller call
        let mut rng = Pcg32::seeded(81);
        let w = Matrix::randn(128, 16, &mut rng, 1.0);
        let exec = FdbExec::compile(&FdbLinear::from_weights(&w, 64));
        let mut scratch = FdbScratch::default();
        scratch.reserve(16, 512, 512);
        let x = Matrix::randn(3, 128, &mut rng, 1.0);
        let mut y = vec![0.0f32; 3 * 16];
        exec.matmul_rows(&x, &mut y, &mut scratch);
        for (a, b) in y.iter().zip(&exec.matmul(&x).data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn reserve_presizes_without_corrupting_results() {
        let mut rng = Pcg32::seeded(80);
        let w = Matrix::randn(192, 24, &mut rng, 1.0);
        let exec = FdbExec::compile(&FdbLinear::from_weights(&w, 64));
        let x = Matrix::randn(4, 192, &mut rng, 1.0);
        let mut cold = FdbScratch::default();
        let mut warm = FdbScratch::default();
        warm.reserve(8, 192, 24);
        let xt_cap = warm.xt.capacity();
        let yt_cap = warm.yt.capacity();
        let a = exec.matmul_with(&x, &mut cold);
        let b = exec.matmul_with(&x, &mut warm);
        assert_eq!(a.data, b.data, "warm scratch changed the result");
        assert_eq!(warm.xt.capacity(), xt_cap, "pre-sized xt still reallocated");
        assert_eq!(warm.yt.capacity(), yt_cap, "pre-sized yt still reallocated");
    }

    #[test]
    fn exec_density_matches_level_sparsity() {
        let mut rng = Pcg32::seeded(78);
        let w = Matrix::randn(512, 64, &mut rng, 1.0);
        let layer = FdbLinear::from_weights(&w, 64);
        let exec = FdbExec::compile(&layer);
        // nnz fraction == fraction of non-zero dequant levels
        let wh = layer.dequant();
        let nz = wh.data.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(exec.nnz(), nz);
        assert!((exec.level_density - nz as f64 / wh.data.len() as f64).abs() < 1e-12);
    }
}
