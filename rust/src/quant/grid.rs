//! Exhaustive level-grid search — the oracle behind Fig. 3 (optimal
//! quantization levels per format) and a reference used by quantizer
//! unit tests.
//!
//! For a weight slice the search minimizes either plain weight MSE or
//! the activation-weighted proxy loss, over:
//!   * binarization:  levels {-α, +α},       1-D grid over α
//!   * 2-bit uniform: levels {-2,-1,0,1}·s,  1-D grid over s
//!   * FDB:           levels {α₂,0,α₁+α₂,α₁}, 2-D grid over (α₁, α₂)

/// A searched format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// binarization: levels {-α, +α}
    Binary,
    /// 2-bit uniform: levels {-2, -1, 0, 1}·s
    Int2,
    /// dual binarization: levels {α₂, 0, α₁+α₂, α₁}
    Fdb,
}

/// Result of a grid search on one weight slice.
#[derive(Clone, Debug)]
pub struct GridResult {
    /// the format searched
    pub format: Format,
    /// The four (or two) representable levels, ascending.
    pub levels: Vec<f32>,
    /// mean squared error of the slice under the best levels
    pub mse: f64,
    /// max(level) - min(level): the "expression span" Fig. 3 annotates.
    pub span: f32,
}

fn mse_for_levels(w: &[f32], levels: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for &v in w {
        let mut best = f32::INFINITY;
        for &l in levels {
            let d = (v - l).abs();
            if d < best {
                best = d;
            }
        }
        acc += (best as f64) * (best as f64);
    }
    acc / w.len().max(1) as f64
}

/// Grid-search the optimal levels of `format` for the slice `w`.
/// `steps` controls the grid resolution per dimension.
pub fn search(w: &[f32], format: Format, steps: usize) -> GridResult {
    let mx = w.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8);
    let grid: Vec<f32> = (1..=steps).map(|i| mx * i as f32 / steps as f32).collect();
    match format {
        Format::Binary => {
            let mut best = (f64::INFINITY, 0.0f32);
            for &a in &grid {
                let m = mse_for_levels(w, &[-a, a]);
                if m < best.0 {
                    best = (m, a);
                }
            }
            GridResult {
                format,
                levels: vec![-best.1, best.1],
                mse: best.0,
                span: 2.0 * best.1,
            }
        }
        Format::Int2 => {
            let mut best = (f64::INFINITY, 0.0f32);
            for &s in &grid {
                let m = mse_for_levels(w, &[-2.0 * s, -s, 0.0, s]);
                if m < best.0 {
                    best = (m, s);
                }
            }
            let s = best.1;
            GridResult {
                format,
                levels: vec![-2.0 * s, -s, 0.0, s],
                mse: best.0,
                span: 3.0 * s,
            }
        }
        Format::Fdb => {
            // α₁ > 0 > α₂ per Fig. 5; levels {α₂, 0, α₁+α₂, α₁}
            let mut best = (f64::INFINITY, 0.0f32, 0.0f32);
            for &a1 in &grid {
                for &a2m in &grid {
                    let a2 = -a2m;
                    let m = mse_for_levels(w, &[a2, 0.0, a1 + a2, a1]);
                    if m < best.0 {
                        best = (m, a1, a2);
                    }
                }
            }
            let (_, a1, a2) = best;
            let mut levels = vec![a2, 0.0, a1 + a2, a1];
            levels.sort_by(|a, b| a.partial_cmp(b).expect("levels are finite"));
            GridResult {
                format,
                levels,
                mse: best.0,
                span: a1 - a2,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg32};

    fn gaussian(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        rng.normal_vec(n)
    }

    #[test]
    fn fdb_at_least_as_good_as_int2() {
        // FDB's grid strictly contains asymmetric variants of the 2-bit
        // grid (choose α₁ = 2s, α₂ = -s ⇒ {-s,0,s,2s}); with free (α₁,α₂)
        // its optimum can only be better or equal — the Fig. 3/4 claim.
        prop::check(10, |rng| {
            let w = gaussian(rng, 512);
            let fdb = search(&w, Format::Fdb, 40);
            let int2 = search(&w, Format::Int2, 40);
            assert!(fdb.mse <= int2.mse * 1.05, "fdb {} int2 {}", fdb.mse, int2.mse);
        });
    }

    #[test]
    fn int2_beats_binary_on_gaussian() {
        prop::check(10, |rng| {
            let w = gaussian(rng, 512);
            let int2 = search(&w, Format::Int2, 40);
            let bin = search(&w, Format::Binary, 40);
            assert!(int2.mse < bin.mse);
        });
    }

    #[test]
    fn spans_match_fig3_ordering() {
        // Fig. 3: binarization's expression span is less than half the
        // 2-bit span (its levels collapse toward 0 on normal weights)
        let mut rng = Pcg32::seeded(61);
        let w = gaussian(&mut rng, 4096);
        let bin = search(&w, Format::Binary, 60);
        let int2 = search(&w, Format::Int2, 60);
        assert!(
            bin.span < 0.5 * int2.span * 1.2,
            "bin span {} vs int2 span {}",
            bin.span,
            int2.span
        );
    }

    #[test]
    fn binary_optimum_near_mean_abs() {
        // analytic optimum for {-α,α} under L2 is α = E|w|
        let mut rng = Pcg32::seeded(62);
        let w = gaussian(&mut rng, 8192);
        let res = search(&w, Format::Binary, 200);
        let mean_abs: f32 = w.iter().map(|v| v.abs()).sum::<f32>() / w.len() as f32;
        assert!(
            (res.levels[1] - mean_abs).abs() < 0.05,
            "{} vs {}",
            res.levels[1],
            mean_abs
        );
    }

    #[test]
    fn zero_mse_when_weights_on_grid() {
        let w = vec![-0.5, 0.0, 0.5, 1.0, 0.5, 0.0];
        let res = search(&w, Format::Fdb, 100);
        assert!(res.mse < 1e-4, "mse {}", res.mse);
    }
}
