//! Calibration context: a sample of a layer's input activations plus the
//! derived statistics the optimization-based quantizers need (Hessian for
//! GPTQ, per-channel magnitudes for AWQ/PB-LLM, output-MSE probes for
//! AWQ/OmniQuant search loops).

use crate::tensor::{linalg, Matrix};

/// Activation sample for one linear layer: `x` is `[n_samples, in]`.
pub struct Calib {
    /// sampled input activations, `[n_samples, in]`
    pub x: Matrix,
}

impl Calib {
    /// Wrap an `[n_samples, in]` activation sample.
    pub fn new(x: Matrix) -> Self {
        Calib { x }
    }

    /// Data-free placeholder (RTN and friends don't look at activations).
    pub fn empty(din: usize) -> Self {
        Calib { x: Matrix::zeros(0, din) }
    }

    /// True when no activations were sampled (data-free path).
    pub fn is_empty(&self) -> bool {
        self.x.rows == 0
    }

    /// The layer's input width.
    pub fn din(&self) -> usize {
        self.x.cols
    }

    /// GPTQ Hessian H = 2·XᵀX, `[in, in]`.
    pub fn hessian(&self) -> Matrix {
        let xt = self.x.t();
        xt.matmul(&self.x).scale(2.0)
    }

    /// Dampened upper Cholesky factor of H⁻¹ (GPTQ's walk order).
    pub fn hessian_inv_chol(&self, lambda: f64) -> anyhow::Result<Matrix> {
        let mut h = self.hessian();
        linalg::dampen(&mut h, lambda);
        linalg::cholesky_inverse_upper(&h)
    }

    /// Per-in-channel mean |x| (AWQ's activation-awareness signal).
    pub fn chan_abs_mean(&self) -> Vec<f32> {
        let n = self.x.rows.max(1) as f64;
        let mut acc = vec![0.0f64; self.x.cols];
        for r in 0..self.x.rows {
            for (c, &v) in self.x.row(r).iter().enumerate() {
                acc[c] += v.abs() as f64;
            }
        }
        acc.into_iter().map(|a| (a / n) as f32).collect()
    }

    /// Mean squared error between `X·w_ref` and `X·w_hat` — the proxy
    /// loss every output-aware search (AWQ, OmniQuant, Fig. 3/4 grids)
    /// minimizes.
    pub fn output_mse(&self, w_ref: &Matrix, w_hat: &Matrix) -> f64 {
        if self.is_empty() {
            // fall back to weight MSE when no activations are available
            return w_ref.mse(w_hat);
        }
        let y_ref = self.x.matmul(w_ref);
        let y_hat = self.x.matmul(w_hat);
        y_ref.mse(&y_hat)
    }

    /// Subsample rows to at most `n` (deterministic stride) to bound the
    /// cost of Hessian/search loops.
    pub fn subsample(&self, n: usize) -> Calib {
        if self.x.rows <= n {
            return Calib { x: self.x.clone() };
        }
        let stride = self.x.rows as f64 / n as f64;
        let mut m = Matrix::zeros(n, self.x.cols);
        for i in 0..n {
            let src = (i as f64 * stride) as usize;
            m.row_mut(i).copy_from_slice(self.x.row(src));
        }
        Calib { x: m }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg32};

    #[test]
    fn hessian_is_symmetric_psd() {
        prop::check(10, |rng| {
            let n = rng.range(4, 32);
            let d = rng.range(2, 12);
            let x = Matrix::randn(n, d, rng, 1.0);
            let h = Calib::new(x).hessian();
            for r in 0..d {
                for c in 0..d {
                    assert!((h.at(r, c) - h.at(c, r)).abs() < 1e-3);
                }
                assert!(h.at(r, r) >= -1e-6);
            }
        });
    }

    #[test]
    fn output_mse_zero_for_identical() {
        let mut rng = Pcg32::seeded(3);
        let x = Matrix::randn(16, 8, &mut rng, 1.0);
        let w = Matrix::randn(8, 4, &mut rng, 1.0);
        let c = Calib::new(x);
        assert_eq!(c.output_mse(&w, &w), 0.0);
        let w2 = w.scale(1.1);
        assert!(c.output_mse(&w, &w2) > 0.0);
    }

    #[test]
    fn chan_abs_mean_known() {
        let x = Matrix::from_vec(2, 2, vec![1.0, -2.0, 3.0, 2.0]);
        let m = Calib::new(x).chan_abs_mean();
        assert_eq!(m, vec![2.0, 2.0]);
    }

    #[test]
    fn subsample_bounds_rows() {
        let mut rng = Pcg32::seeded(4);
        let x = Matrix::randn(100, 4, &mut rng, 1.0);
        let c = Calib::new(x).subsample(10);
        assert_eq!(c.x.rows, 10);
        let small = Calib::new(Matrix::randn(5, 4, &mut rng, 1.0)).subsample(10);
        assert_eq!(small.x.rows, 5);
    }

    #[test]
    fn empty_calib_falls_back_to_weight_mse() {
        let mut rng = Pcg32::seeded(5);
        let w = Matrix::randn(8, 4, &mut rng, 1.0);
        let w2 = w.scale(0.9);
        let c = Calib::empty(8);
        assert!((c.output_mse(&w, &w2) - w.mse(&w2)).abs() < 1e-12);
    }
}
