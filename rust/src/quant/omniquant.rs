//! OmniQuant-style learnable weight clipping (Shao et al., 2023), the
//! strongest published W2 baseline in the paper's tables.
//!
//! The reference learns per-group clipping factors (γ, β) by gradient on
//! a block-wise reconstruction loss.  In the weight-only setting this
//! reduces to choosing per-(group, column) asymmetric clip fractions; we
//! implement it as coordinate descent over a fine clip grid against the
//! layer output MSE — the same search space, derivative-free (converges
//! to the same fixed points for this convex-per-coordinate objective).

use super::{scale_overhead_bits, Calib, Quantized, Quantizer};
use crate::tensor::Matrix;

/// OmniQuant-style learnable weight clipping (per-group clip search by
/// coordinate descent on output MSE).
pub struct OmniQuant {
    /// target weight bits
    pub bits: u32,
    /// quantization group size along the in-dimension
    pub group: usize,
    /// candidate clip fractions for the per-group search
    pub grid: Vec<f32>,
    /// coordinate-descent sweeps
    pub rounds: usize,
}

impl OmniQuant {
    /// `bits`-bit, group-`group` LWC with the reference clip grid.
    pub fn new(bits: u32, group: usize) -> Self {
        OmniQuant {
            bits,
            group,
            grid: vec![1.0, 0.95, 0.9, 0.85, 0.8, 0.75, 0.7, 0.65, 0.6, 0.55, 0.5],
            rounds: 2,
        }
    }

    /// Asymmetric k-bit quantization of one group/column slice under a
    /// clip fraction: grid spans [γ·min, γ·max].
    fn quant_group(&self, vals: &[f32], clip: f32) -> Vec<f32> {
        let levels = (1u32 << self.bits) as f32 - 1.0;
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in vals {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let (lo, hi) = (clip * lo, clip * hi);
        let s = ((hi - lo) / levels).max(1e-8);
        vals.iter()
            .map(|&v| {
                let q = ((v - lo) / s).round().clamp(0.0, levels);
                lo + q * s
            })
            .collect()
    }
}

impl Quantizer for OmniQuant {
    fn name(&self) -> String {
        format!("OmniQuant-W{}", self.bits)
    }

    fn quantize(&self, w: &Matrix, calib: &Calib) -> Quantized {
        // asymmetric grids carry a zero-point: ~2 extra f16 per group
        let bits = self.bits as f64 + 2.0 * scale_overhead_bits(self.group);
        let gs = w.rows / self.group;
        // per (group, column) clip fraction, initialized at no-clip
        let mut clips = vec![1.0f32; gs * w.cols];
        let mut w_hat = w.clone();

        // initial quantization with clip = 1
        for c in 0..w.cols {
            for g in 0..gs {
                let range = g * self.group..(g + 1) * self.group;
                let vals: Vec<f32> = range.clone().map(|r| w.at(r, c)).collect();
                let q = self.quant_group(&vals, 1.0);
                for (i, r) in range.enumerate() {
                    *w_hat.at_mut(r, c) = q[i];
                }
            }
        }

        // coordinate descent: per group/column the objective decomposes
        // (columns are independent; with a diagonal-dominant XᵀX the group
        // term dominates), so we score candidates on the group slice MSE
        // weighted by the activation second moment of its rows.
        let row_energy: Vec<f32> = if calib.is_empty() {
            vec![1.0; w.rows]
        } else {
            let mut e = vec![0.0f32; w.rows];
            for r in 0..calib.x.rows {
                for (c, &v) in calib.x.row(r).iter().enumerate() {
                    e[c] += v * v;
                }
            }
            e
        };

        for _ in 0..self.rounds {
            for c in 0..w.cols {
                for g in 0..gs {
                    let range = g * self.group..(g + 1) * self.group;
                    let vals: Vec<f32> = range.clone().map(|r| w.at(r, c)).collect();
                    let energies: Vec<f32> = range.clone().map(|r| row_energy[r]).collect();
                    let mut best = (f64::INFINITY, clips[g * w.cols + c]);
                    for &clip in &self.grid {
                        let q = self.quant_group(&vals, clip);
                        let loss: f64 = vals
                            .iter()
                            .zip(&q)
                            .zip(&energies)
                            .map(|((v, qq), e)| {
                                let d = (v - qq) as f64;
                                d * d * (*e as f64)
                            })
                            .sum();
                        if loss < best.0 {
                            best = (loss, clip);
                        }
                    }
                    clips[g * w.cols + c] = best.1;
                    let q = self.quant_group(&vals, best.1);
                    for (i, r) in range.enumerate() {
                        *w_hat.at_mut(r, c) = q[i];
                    }
                }
            }
        }

        Quantized { w_hat, bits_per_weight: bits, method: self.name(), fdb: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::util::{prop, Pcg32};

    #[test]
    fn omniquant_beats_symmetric_rtn() {
        prop::check(6, |rng| {
            let w = Matrix::randn(128, rng.range(4, 16), rng, 1.0);
            let calib = Calib::new(Matrix::randn(128, 128, rng, 1.0));
            let o = OmniQuant::new(2, 64).quantize(&w, &calib);
            let r = Rtn::new(2, 64).quantize(&w, &calib);
            let mo = calib.output_mse(&w, &o.w_hat);
            let mr = calib.output_mse(&w, &r.w_hat);
            assert!(mo <= mr * 1.05, "omni {mo:.4e} rtn {mr:.4e}");
        });
    }

    #[test]
    fn clip_search_helps_heavy_tails() {
        // inject outliers: clipping the grid should reduce error on the bulk
        let mut rng = Pcg32::seeded(41);
        let mut w = Matrix::randn(64, 8, &mut rng, 0.1);
        for c in 0..8 {
            *w.at_mut(0, c) = 5.0; // single outlier per column
        }
        let calib = Calib::empty(64);
        let o = OmniQuant::new(2, 64).quantize(&w, &calib);
        let r = Rtn::new(2, 64).quantize(&w, &calib);
        assert!(o.w_hat.mse(&w) < r.w_hat.mse(&w));
    }

    #[test]
    fn asymmetric_grid_handles_shifted_weights() {
        let mut rng = Pcg32::seeded(42);
        // all-positive weights: symmetric RTN wastes half its grid
        let w = Matrix::from_fn(64, 4, |_, _| 1.0 + 0.3 * rng.normal());
        let calib = Calib::empty(64);
        let o = OmniQuant::new(2, 64).quantize(&w, &calib);
        let r = Rtn::new(2, 64).quantize(&w, &calib);
        assert!(o.w_hat.mse(&w) < r.w_hat.mse(&w) * 0.8);
    }

    #[test]
    fn quantized_values_bounded_by_clip_window() {
        let mut rng = Pcg32::seeded(43);
        let w = Matrix::randn(64, 4, &mut rng, 1.0);
        let o = OmniQuant::new(2, 64).quantize(&w, &Calib::empty(64));
        assert!(o.w_hat.abs_max() <= w.abs_max() * 1.0001);
    }
}
