//! GPTQ (Frantar et al., 2022): layer-wise optimal brain quantization
//! with second-order error compensation.
//!
//! Faithful to the reference algorithm: Hessian H = 2XᵀX from the
//! calibration activations, dampened, inverted via Cholesky; weights are
//! quantized one *in-row* at a time in natural order, and the rounding
//! error of row j is propagated into the not-yet-quantized rows through
//! the upper Cholesky factor U of H⁻¹ (out-columns are independent and
//! vectorized).  Group scales are (re)computed from the *updated*
//! weights at each group boundary, exactly like `gptq`'s grouped mode.

use super::{scale_overhead_bits, Calib, Quantized, Quantizer};
use crate::tensor::Matrix;

/// GPTQ: Hessian-guided sequential rounding with error feedback.
pub struct Gptq {
    /// target weight bits
    pub bits: u32,
    /// quantization group size along the in-dimension
    pub group: usize,
    /// Dampening fraction λ of mean diag (reference default 0.01).
    pub damp: f64,
}

impl Gptq {
    /// `bits`-bit, group-`group` GPTQ with the reference dampening.
    pub fn new(bits: u32, group: usize) -> Self {
        Gptq { bits, group, damp: 0.01 }
    }
}

impl Quantizer for Gptq {
    fn name(&self) -> String {
        format!("GPTQ-W{}", self.bits)
    }

    fn quantize(&self, w: &Matrix, calib: &Calib) -> Quantized {
        let bits = self.bits as f64 + scale_overhead_bits(self.group);
        // No calibration data -> degrade gracefully to RTN.
        let u = if calib.is_empty() {
            None
        } else {
            calib.hessian_inv_chol(self.damp).ok()
        };
        let w_hat = match u {
            Some(u) => gptq_core(w, &u, self.bits, self.group),
            None => super::rtn::Rtn::new(self.bits, self.group).quantize_with_scales(w).0,
        };
        Quantized { w_hat, bits_per_weight: bits, method: self.name(), fdb: None }
    }
}

/// The OBQ loop.  `w` is `[in, out]`; `u` is the upper Cholesky factor of
/// the dampened H⁻¹, `[in, in]`.
fn gptq_core(w: &Matrix, u: &Matrix, bits: u32, group: usize) -> Matrix {
    let (din, dout) = (w.rows, w.cols);
    let qmax = if bits == 1 { 0.0 } else { (1 << (bits - 1)) as f32 - 1.0 };
    let qmin = if bits == 1 { 0.0 } else { -((1 << (bits - 1)) as f32) };

    let mut work = w.clone(); // updated in place by error propagation
    let mut w_hat = Matrix::zeros(din, dout);
    let mut scales = vec![0.0f32; dout]; // current group's scale per column

    for r in 0..din {
        // recompute scales at group boundaries from the *updated* weights
        if r % group == 0 {
            let end = (r + group).min(din);
            for c in 0..dout {
                if bits == 1 {
                    let mut acc = 0.0f64;
                    for rr in r..end {
                        acc += work.at(rr, c).abs() as f64;
                    }
                    scales[c] = ((acc / (end - r) as f64) as f32).max(1e-8);
                } else {
                    let mut mx = 0.0f32;
                    for rr in r..end {
                        mx = mx.max(work.at(rr, c).abs());
                    }
                    scales[c] = (mx / (1 << (bits - 1)) as f32).max(1e-8);
                }
            }
        }

        let d = u.at(r, r).max(1e-10);
        for c in 0..dout {
            let v = work.at(r, c);
            let q = if bits == 1 {
                if v >= 0.0 {
                    scales[c]
                } else {
                    -scales[c]
                }
            } else {
                (v / scales[c]).round().clamp(qmin, qmax) * scales[c]
            };
            *w_hat.at_mut(r, c) = q;
            // propagate the normalized error into the remaining rows
            let err = (v - q) / d;
            for rr in r + 1..din {
                let urr = u.at(r, rr);
                if urr != 0.0 {
                    *work.at_mut(rr, c) -= err * urr;
                }
            }
        }
    }
    w_hat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::util::{prop, Pcg32};

    fn calib(rng: &mut Pcg32, n: usize, din: usize) -> Calib {
        Calib::new(Matrix::randn(n, din, rng, 1.0))
    }

    #[test]
    fn gptq_beats_rtn_on_output_mse() {
        // the whole point of second-order compensation
        prop::check(8, |rng| {
            let din = 64 * rng.range(1, 3);
            let dout = rng.range(4, 24);
            let w = Matrix::randn(din, dout, rng, 1.0);
            let c = calib(rng, 256, din);
            let g = Gptq::new(2, 64).quantize(&w, &c);
            let r = Rtn::new(2, 64).quantize(&w, &c);
            let mse_g = c.output_mse(&w, &g.w_hat);
            let mse_r = c.output_mse(&w, &r.w_hat);
            assert!(
                mse_g <= mse_r * 1.02 + 1e-9,
                "gptq {mse_g:.5e} vs rtn {mse_r:.5e}"
            );
        });
    }

    #[test]
    fn gptq_values_on_group_grid() {
        let mut rng = Pcg32::seeded(21);
        let w = Matrix::randn(128, 8, &mut rng, 1.0);
        let c = calib(&mut rng, 128, 128);
        let q = Gptq::new(2, 64).quantize(&w, &c);
        // every output value must be an integer multiple of *some* scale
        // <= the max level; verify per group by reconstructing the scale
        for col in 0..8 {
            for g in 0..2 {
                let vals: Vec<f32> = (g * 64..(g + 1) * 64).map(|r| q.w_hat.at(r, col)).collect();
                let s = vals
                    .iter()
                    .filter(|v| **v != 0.0)
                    .map(|v| v.abs())
                    .fold(f32::INFINITY, f32::min);
                if !s.is_finite() {
                    continue; // all-zero group
                }
                for v in vals {
                    let q = v / s;
                    assert!((q.round() - q).abs() < 1e-3, "{v} not multiple of {s}");
                }
            }
        }
    }

    #[test]
    fn gptq_without_calib_equals_rtn() {
        let mut rng = Pcg32::seeded(22);
        let w = Matrix::randn(64, 8, &mut rng, 1.0);
        let empty = Calib::empty(64);
        let g = Gptq::new(2, 64).quantize(&w, &empty);
        let r = Rtn::new(2, 64).quantize(&w, &empty);
        assert_eq!(g.w_hat.data, r.w_hat.data);
    }

    #[test]
    fn gptq_3bit_better_than_2bit() {
        let mut rng = Pcg32::seeded(23);
        let w = Matrix::randn(128, 16, &mut rng, 1.0);
        let c = calib(&mut rng, 256, 128);
        let e2 = c.output_mse(&w, &Gptq::new(2, 64).quantize(&w, &c).w_hat);
        let e3 = c.output_mse(&w, &Gptq::new(3, 64).quantize(&w, &c).w_hat);
        assert!(e3 < e2);
    }
}
