//! AWQ (Lin et al., 2023): activation-aware weight quantization.
//!
//! Salient weight channels (identified by mean activation magnitude) are
//! protected by a per-in-channel equivalent scaling  w' = w·s,
//! x' = x/s with s = (mean|x|)^β, β grid-searched to minimize the layer
//! output MSE on the calibration sample; a per-group max-clip search
//! then shrinks the quantization grid.  Matches the published method's
//! two searches (scale + clip) for the weight-only setting.

use super::rtn::Rtn;
use super::{scale_overhead_bits, Calib, Quantized, Quantizer};
use crate::tensor::Matrix;

/// AWQ: activation-aware weight quantization (per-channel scale + clip
/// search against the layer's output MSE).
pub struct Awq {
    /// target weight bits
    pub bits: u32,
    /// quantization group size along the in-dimension
    pub group: usize,
    /// β grid resolution (reference uses 20 points on [0,1]).
    pub beta_steps: usize,
    /// clip-search grid (fractions of max kept).
    pub clip_grid: Vec<f32>,
}

impl Awq {
    /// Reference-default search grids for `bits`-bit, group-`group` AWQ.
    pub fn new(bits: u32, group: usize) -> Self {
        Awq {
            bits,
            group,
            beta_steps: 10,
            clip_grid: vec![1.0, 0.95, 0.9, 0.85, 0.8, 0.7],
        }
    }

    /// Quantize `w` with per-channel scaling `s` applied then undone.
    fn quantize_scaled(&self, w: &Matrix, s: &[f32], clip: f32) -> Matrix {
        let mut scaled = w.clone();
        for r in 0..w.rows {
            let f = s[r];
            for v in scaled.row_mut(r) {
                *v *= f;
            }
        }
        let w_hat_scaled = rtn_clip(&scaled, self.bits, self.group, clip);
        let mut out = w_hat_scaled;
        for r in 0..w.rows {
            let f = s[r];
            for v in out.row_mut(r) {
                *v /= f;
            }
        }
        out
    }
}

/// RTN with the group max shrunk by `clip` before the grid is built.
fn rtn_clip(w: &Matrix, bits: u32, group: usize, clip: f32) -> Matrix {
    if clip >= 1.0 {
        return Rtn::new(bits, group).quantize_with_scales(w).0;
    }
    let qmax = (1 << (bits - 1)) as f32 - 1.0;
    let qmin = -((1 << (bits - 1)) as f32);
    let mut out = Matrix::zeros(w.rows, w.cols);
    for c in 0..w.cols {
        for g in 0..w.rows / group {
            let range = g * group..(g + 1) * group;
            let mut mx = 0.0f32;
            for r in range.clone() {
                mx = mx.max(w.at(r, c).abs());
            }
            let s = (clip * mx / (1 << (bits - 1)) as f32).max(1e-8);
            for r in range {
                *out.at_mut(r, c) = (w.at(r, c) / s).round().clamp(qmin, qmax) * s;
            }
        }
    }
    out
}

impl Quantizer for Awq {
    fn name(&self) -> String {
        format!("AWQ-W{}", self.bits)
    }

    fn quantize(&self, w: &Matrix, calib: &Calib) -> Quantized {
        let bits = self.bits as f64 + scale_overhead_bits(self.group);
        if calib.is_empty() {
            let w_hat = Rtn::new(self.bits, self.group).quantize_with_scales(w).0;
            return Quantized { w_hat, bits_per_weight: bits, method: self.name(), fdb: None };
        }
        // the β/clip search only needs a small activation sample; the
        // full calib set would make the 60-point grid quadratic in cost
        let search = calib.subsample(128);
        let chan = calib.chan_abs_mean();
        // normalize so the geometric mean of s is ~1 (keeps scales sane)
        let mean: f32 = chan.iter().map(|c| c.max(1e-6)).sum::<f32>() / chan.len() as f32;

        let mut best: Option<(f64, Matrix)> = None;
        for bi in 0..=self.beta_steps {
            let beta = bi as f32 / self.beta_steps as f32;
            let s: Vec<f32> = chan
                .iter()
                .map(|&c| (c.max(1e-6) / mean).powf(beta).clamp(1e-4, 1e4))
                .collect();
            for &clip in &self.clip_grid {
                let w_hat = self.quantize_scaled(w, &s, clip);
                let mse = search.output_mse(w, &w_hat);
                if best.as_ref().map_or(true, |(b, _)| mse < *b) {
                    best = Some((mse, w_hat));
                }
            }
        }
        Quantized {
            w_hat: best.expect("grid search visits at least one candidate").1,
            bits_per_weight: bits,
            method: self.name(),
            fdb: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg32};

    #[test]
    fn awq_beats_rtn_with_skewed_activations() {
        // AWQ's advantage appears when some in-channels carry much larger
        // activations — exactly the salient-channel story of the paper.
        prop::check(6, |rng| {
            let din = 128;
            let dout = 16;
            let w = Matrix::randn(din, dout, rng, 1.0);
            let mut x = Matrix::randn(192, din, rng, 1.0);
            // make 8 channels hot
            for r in 0..x.rows {
                for c in 0..8 {
                    *x.at_mut(r, c) *= 16.0;
                }
            }
            let calib = Calib::new(x);
            let a = Awq::new(2, 64).quantize(&w, &calib);
            let r2 = Rtn::new(2, 64).quantize(&w, &calib);
            let mse_a = calib.output_mse(&w, &a.w_hat);
            let mse_r = calib.output_mse(&w, &r2.w_hat);
            assert!(mse_a <= mse_r * 1.001, "awq {mse_a:.4e} rtn {mse_r:.4e}");
        });
    }

    #[test]
    fn beta_zero_clip_one_included() {
        // the search space must contain plain RTN, so AWQ can never be
        // (meaningfully) worse than RTN on the calibration loss
        let mut rng = Pcg32::seeded(31);
        let w = Matrix::randn(64, 8, &mut rng, 1.0);
        let calib = Calib::new(Matrix::randn(64, 64, &mut rng, 1.0));
        let a = Awq::new(2, 64).quantize(&w, &calib);
        let r = Rtn::new(2, 64).quantize(&w, &calib);
        assert!(
            calib.output_mse(&w, &a.w_hat) <= calib.output_mse(&w, &r.w_hat) + 1e-9
        );
    }

    #[test]
    fn awq_empty_calib_is_rtn() {
        let mut rng = Pcg32::seeded(32);
        let w = Matrix::randn(64, 8, &mut rng, 1.0);
        let a = Awq::new(2, 64).quantize(&w, &Calib::empty(64));
        let r = Rtn::new(2, 64).quantize(&w, &Calib::empty(64));
        assert_eq!(a.w_hat.data, r.w_hat.data);
    }

    #[test]
    fn rtn_clip_shrinks_grid() {
        let mut rng = Pcg32::seeded(33);
        let w = Matrix::randn(64, 4, &mut rng, 1.0);
        let clipped = rtn_clip(&w, 2, 64, 0.5);
        let full = rtn_clip(&w, 2, 64, 1.0);
        assert!(clipped.abs_max() <= full.abs_max());
    }
}
