//! The quantization engine: the paper's FDB contribution plus every
//! baseline it compares against (RTN, GPTQ, AWQ, OmniQuant-style LWC,
//! PB-LLM), all sharing one per-group grid convention.
//!
//! Conventions (identical to the python layer):
//! * linear weights are `[in, out]` matrices (`y = x @ W`),
//! * quantization groups tile the *in* dimension (`group_size` = 64 by
//!   default, the paper's W2A16 g64 headline setting),
//! * per-group scales have shape `[in/group, out]`.

#![warn(missing_docs)]

/// AWQ baseline: activation-aware per-channel scale search.
pub mod awq;
/// Per-layer activation statistics shared by the data-aware methods.
pub mod calib;
/// The paper's FDB layer: dual binary planes with per-group scales.
pub mod fdb;
/// GPTQ baseline: Hessian-guided sequential rounding.
pub mod gptq;
/// Compiled execution forms of the FDB layer (CSC level stream).
pub mod kernel;
/// Shared 2-bit grid search utilities and format taxonomy.
pub mod grid;
/// OmniQuant-style baseline: learnable weight clipping.
pub mod omniquant;
/// u64 bit-plane packing shared by FDB storage and the codec.
pub mod packing;
/// PB-LLM baseline: salient weights kept dense, the rest binarized.
pub mod pbllm;
/// Round-to-nearest baseline (data-free).
pub mod rtn;

use crate::tensor::Matrix;

/// Re-export: the activation-statistics carrier.
pub use calib::Calib;
/// Re-export: the packed dual-binary layer.
pub use fdb::FdbLinear;

/// Default group size (paper: W2A16 with group 64).
pub const GROUP_SIZE: usize = 64;

/// Result of quantizing one linear layer.
pub struct Quantized {
    /// Dequantized weights (what the XLA forward consumes).
    pub w_hat: Matrix,
    /// Nominal storage bits per weight (scales amortized over the group).
    pub bits_per_weight: f64,
    /// Method label for reporting.
    pub method: String,
    /// The packed dual-binary form (FDB only) — feeds the bit-serial
    /// runtime path and the codec.
    pub fdb: Option<FdbLinear>,
}

/// A weight-only quantization method.
pub trait Quantizer {
    /// Method label for reporting (table/figure row names).
    fn name(&self) -> String;
    /// Quantize one `[in, out]` linear. `calib` carries this layer's
    /// activation sample (may be empty for data-free methods like RTN).
    fn quantize(&self, w: &Matrix, calib: &Calib) -> Quantized;
}

/// Per-group scale storage overhead in bits/weight (one f16 scale per
/// `group` weights — matches how GPTQ/AWQ/OmniQuant report group-wise
/// quantization cost).
pub fn scale_overhead_bits(group: usize) -> f64 {
    16.0 / group as f64
}

/// Split a `[in, out]` matrix view into (group index, rows-range) pairs.
pub fn group_ranges(din: usize, group: usize) -> Vec<(usize, std::ops::Range<usize>)> {
    assert!(din % group == 0, "group {group} must divide in-dim {din}");
    (0..din / group).map(|g| (g, g * group..(g + 1) * group)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_ranges_tile_exactly() {
        let r = group_ranges(192, 64);
        assert_eq!(r.len(), 3);
        assert_eq!(r[0].1, 0..64);
        assert_eq!(r[2].1, 128..192);
    }

    #[test]
    #[should_panic]
    fn group_ranges_reject_misaligned() {
        group_ranges(100, 64);
    }

    #[test]
    fn scale_overhead() {
        assert!((scale_overhead_bits(64) - 0.25).abs() < 1e-12);
    }
}
