//! Flexible Dual Binarization — the paper's micro-level contribution
//! (§3.2, Eq. 4-8).
//!
//! A 2-bit proxy grid is split into two independent {0,1} planes with
//! per-group scales α₁ = 2s, α₂ = -s, giving levels {-s, 0, s, 2s}
//! (Fig. 5).  Plane assignment compares against level centers (Eq. 6-7)
//! and is re-derivable after the scales move (post-DAD `resplit`).
//!
//! The planes pack into u64 bit-words (`packing::BitPlane`) — with group
//! size 64 one (group, column) pair is exactly one word, so the forward
//! (Eq. 8) becomes popcount-style bit-serial accumulation over sparse
//! words.  `matvec`/`matmul` here are the measured CPU realization of
//! the paper's "efficient bitwise operation" claim (Table 6 / §Perf).

use super::packing::{BitPlane, WORD_BITS};
use super::rtn::proxy_scales;
use super::{scale_overhead_bits, Calib, Quantized, Quantizer};
use crate::tensor::Matrix;

/// One FDB-quantized linear layer.
#[derive(Clone, Debug)]
pub struct FdbLinear {
    /// input width (rows of the logical weight matrix)
    pub din: usize,
    /// output width (columns of the logical weight matrix)
    pub dout: usize,
    /// quantization group size along the in-dimension
    pub group: usize,
    /// Packed binary plane b₁ (the α₁ carrier).
    pub b1: BitPlane,
    /// Packed binary plane b₂ (the α₂ carrier).
    pub b2: BitPlane,
    /// Per-group α₁ scales `[g, out]`.
    pub a1: Matrix,
    /// Per-group α₂ scales `[g, out]`.
    pub a2: Matrix,
}

impl FdbLinear {
    /// Split fp weights into the dual-binary form (Eq. 5-7): the 2-bit
    /// proxy supplies s, then α₁ := 2s, α₂ := -s.
    pub fn from_weights(w: &Matrix, group: usize) -> Self {
        assert!(group % WORD_BITS == 0, "group must be a multiple of 64");
        let s = proxy_scales(w, group);
        let a1 = s.scale(2.0);
        let a2 = s.scale(-1.0);
        Self::from_scales(w, &a1, &a2, group)
    }

    /// Eq. 6-7: derive planes from fp weights and given scales.
    ///   b1 = H(w - (α₁+α₂)/2)
    ///   b2 = H(-(w - α₁·b1 - α₂/2))
    pub fn from_scales(w: &Matrix, a1: &Matrix, a2: &Matrix, group: usize) -> Self {
        let g_count = w.rows / group;
        assert_eq!(a1.rows, g_count);
        assert_eq!(a1.cols, w.cols);
        let mut m1 = Matrix::zeros(w.rows, w.cols);
        let mut m2 = Matrix::zeros(w.rows, w.cols);
        for c in 0..w.cols {
            for r in 0..w.rows {
                let g = r / group;
                let (s1, s2) = (a1.at(g, c), a2.at(g, c));
                let v = w.at(r, c);
                let b1 = if v - 0.5 * (s1 + s2) > 0.0 { 1.0 } else { 0.0 };
                let b2 = if -(v - s1 * b1 - 0.5 * s2) > 0.0 { 1.0 } else { 0.0 };
                *m1.at_mut(r, c) = b1;
                *m2.at_mut(r, c) = b2;
            }
        }
        FdbLinear {
            din: w.rows,
            dout: w.cols,
            group,
            b1: BitPlane::pack(&m1),
            b2: BitPlane::pack(&m2),
            a1: a1.clone(),
            a2: a2.clone(),
        }
    }

    /// Re-derive the planes for updated scales (applied after DAD moves
    /// α) — the level centers shift, so assignment is recomputed from
    /// the original fp weights.
    pub fn resplit(&mut self, w: &Matrix, a1: Matrix, a2: Matrix) {
        let new = Self::from_scales(w, &a1, &a2, self.group);
        *self = new;
    }

    /// ŵ = α₁·b1 + α₂·b2 (Eq. 4) as a dense matrix.
    pub fn dequant(&self) -> Matrix {
        let u1 = self.b1.unpack();
        let u2 = self.b2.unpack();
        let mut w = Matrix::zeros(self.din, self.dout);
        for c in 0..self.dout {
            for r in 0..self.din {
                let g = r / self.group;
                *w.at_mut(r, c) =
                    self.a1.at(g, c) * u1.at(r, c) + self.a2.at(g, c) * u2.at(r, c);
            }
        }
        w
    }

    /// Bit-serial y = xᵀ·Ŵ for one activation vector (Eq. 8).
    ///
    /// Per (column, group): two u64 words select which x-lanes join each
    /// plane's partial sum; sparsity in the words directly skips work.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.din);
        assert_eq!(y.len(), self.dout);
        for c in 0..self.dout {
            let mut acc = 0.0f32;
            let words_per_col = self.din / WORD_BITS;
            let w1 = &self.b1.words[c * words_per_col..(c + 1) * words_per_col];
            let w2 = &self.b2.words[c * words_per_col..(c + 1) * words_per_col];
            for wi in 0..words_per_col {
                let base = wi * WORD_BITS;
                let sg = base / self.group; // scale group of this word
                let xs = &x[base..base + WORD_BITS];
                let p1 = bit_dot(w1[wi], xs);
                let p2 = bit_dot(w2[wi], xs);
                acc += self.a1.at(sg, c) * p1 + self.a2.at(sg, c) * p2;
            }
            y[c] = acc;
        }
    }

    /// Bit-serial matmul: X `[n, in]` -> Y `[n, out]`.
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.din);
        let mut y = Matrix::zeros(x.rows, self.dout);
        for r in 0..x.rows {
            let (xr, yr) = (x.row(r), r);
            let row = &mut y.data[yr * self.dout..(yr + 1) * self.dout];
            self.matvec(xr, row);
        }
        y
    }

    /// Mean sparsity over both planes (Table 6's headline column).
    pub fn sparsity(&self) -> f64 {
        0.5 * (self.b1.sparsity() + self.b2.sparsity())
    }

    /// Nominal storage bits/weight: 2 plane bits + 2 f16 group scales.
    pub fn bits_per_weight(&self) -> f64 {
        2.0 + 2.0 * scale_overhead_bits(self.group)
    }
}

impl FdbLinear {
    /// Layer-wise scale fine-tuning (the "fine-tune the scales to
    /// further enhance the representation capability" step of §3.2),
    /// realized in closed form: for fixed planes the layer output is
    /// *linear* in the per-group scales,
    ///
    ///   `y_col = Σ_g α₁[g]·(X_g·b1_g) + α₂[g]·(X_g·b2_g)`,
    ///
    /// so the reconstruction-optimal scales solve a small least-squares
    /// system per output column.  Alternating with plane re-assignment
    /// (Eq. 6-7) gives a coordinate-descent on the true layer MSE.
    /// Falls back to weight-space LS when no activations are available
    /// (still data-free: the calib set is teacher-generated).
    pub fn fit_scales(&mut self, w: &Matrix, calib: &Calib, rounds: usize) {
        use crate::tensor::linalg;
        let g_count = self.din / self.group;
        let k = 2 * g_count;
        // design rows: activations (output-space) or identity (weight-space)
        let x = if calib.is_empty() { None } else { Some(&calib.x) };
        for _ in 0..rounds {
            let u1 = self.b1.unpack();
            let u2 = self.b2.unpack();
            let n_rows = x.map_or(self.din, |x| x.rows);
            for c in 0..self.dout {
                // features[j][row]: j < g_count -> plane1 group j, else plane2
                let mut feats = vec![vec![0.0f32; n_rows]; k];
                let mut target = vec![0.0f32; n_rows];
                match x {
                    Some(x) => {
                        for row in 0..n_rows {
                            let xr = x.row(row);
                            let mut acc_t = 0.0f32;
                            for g in 0..g_count {
                                let mut a1 = 0.0f32;
                                let mut a2 = 0.0f32;
                                for i in 0..self.group {
                                    let r = g * self.group + i;
                                    let xv = xr[r];
                                    a1 += xv * u1.at(r, c);
                                    a2 += xv * u2.at(r, c);
                                    acc_t += xv * w.at(r, c);
                                }
                                feats[g][row] = a1;
                                feats[g_count + g][row] = a2;
                            }
                            target[row] = acc_t;
                        }
                    }
                    None => {
                        for r in 0..n_rows {
                            let g = r / self.group;
                            feats[g][r] = u1.at(r, c);
                            feats[g_count + g][r] = u2.at(r, c);
                            target[r] = w.at(r, c);
                        }
                    }
                }
                // normal equations A·s = b, A = FᵀF (+damp), b = Fᵀt
                let mut a = Matrix::zeros(k, k);
                let mut b = vec![0.0f32; k];
                for i in 0..k {
                    for j in i..k {
                        let mut acc = 0.0f64;
                        for row in 0..n_rows {
                            acc += feats[i][row] as f64 * feats[j][row] as f64;
                        }
                        *a.at_mut(i, j) = acc as f32;
                        *a.at_mut(j, i) = acc as f32;
                    }
                    let mut acc = 0.0f64;
                    for row in 0..n_rows {
                        acc += feats[i][row] as f64 * target[row] as f64;
                    }
                    b[i] = acc as f32;
                }
                linalg::dampen(&mut a, 1e-4);
                let Ok(l) = linalg::cholesky(&a) else { continue };
                let y = linalg::solve_lower(&l, &b);
                let s = linalg::solve_lower_t(&l, &y);
                // keep the Fig. 5 sign structure (α₁ > 0 > α₂); groups whose
                // LS solution flips sign stay at their previous value
                for g in 0..g_count {
                    if s[g] > 1e-8 {
                        *self.a1.at_mut(g, c) = s[g];
                    }
                    if s[g_count + g] < -1e-8 {
                        *self.a2.at_mut(g, c) = s[g_count + g];
                    }
                }
            }
            // re-assign planes around the moved level centers (Eq. 6-7)
            let a1 = self.a1.clone();
            let a2 = self.a2.clone();
            self.resplit(w, a1, a2);
        }
    }
}

/// `Σ_{k: bit k set} xs[k]` — the bit-serial inner kernel.
#[inline]
pub fn bit_dot(mut word: u64, xs: &[f32]) -> f32 {
    debug_assert_eq!(xs.len(), WORD_BITS);
    let mut acc = 0.0f32;
    while word != 0 {
        let k = word.trailing_zeros() as usize;
        acc += xs[k];
        word &= word - 1;
    }
    acc
}

/// Per-(group, column) MSE refinement of the (α₁, α₂) scales: a coarse
/// 2-D grid around the Eq. 5 init, keeping nearest-level assignment.
/// This is the *layer-wise* optimum the paper's scale fine-tuning
/// gravitates toward (Fig. 3's "optimal solutions from grid search");
/// the end-to-end DAD pass then polishes it with network-level signal.
pub fn mse_refine_scales(w: &Matrix, group: usize) -> (Matrix, Matrix) {
    let s0 = proxy_scales(w, group);
    let g_count = w.rows / group;
    let mut a1 = Matrix::zeros(g_count, w.cols);
    let mut a2 = Matrix::zeros(g_count, w.cols);
    // candidate multipliers around the (2s, -s) init
    const U: [f32; 7] = [0.8, 1.1, 1.4, 1.7, 2.0, 2.4, 2.8];
    const V: [f32; 7] = [0.35, 0.5, 0.65, 0.8, 1.0, 1.2, 1.45];
    let mut vals = vec![0.0f32; group];
    for c in 0..w.cols {
        for g in 0..g_count {
            for (i, r) in (g * group..(g + 1) * group).enumerate() {
                vals[i] = w.at(r, c);
            }
            let s = s0.at(g, c);
            let mut best = (f32::INFINITY, 2.0 * s, -s);
            for &u in &U {
                let l1 = u * s;
                for &v in &V {
                    let l2 = -v * s;
                    // levels {l2, 0, l1+l2, l1}
                    let mut err = 0.0f32;
                    for &x in &vals {
                        let mut e = x.abs().min((x - l2).abs());
                        e = e.min((x - l1 - l2).abs()).min((x - l1).abs());
                        err += e * e;
                    }
                    if err < best.0 {
                        best = (err, l1, l2);
                    }
                }
            }
            *a1.at_mut(g, c) = best.1;
            *a2.at_mut(g, c) = best.2;
        }
    }
    (a1, a2)
}

/// The FDB quantizer (init only; DAD fine-tuning happens in
/// `coordinator::finetune` on top of this).
pub struct Fdb {
    /// quantization group size along the in-dimension
    pub group: usize,
}

impl Quantizer for Fdb {
    fn name(&self) -> String {
        "DB-LLM(FDB)".into()
    }

    fn quantize(&self, w: &Matrix, calib: &Calib) -> Quantized {
        // Eq. 5 init (keeps the paper's sparsity structure), then the
        // closed-form scale fine-tune on the data-free calibration set.
        let mut fdb = FdbLinear::from_weights(w, self.group);
        fdb.fit_scales(w, calib, 2);
        Quantized {
            w_hat: fdb.dequant(),
            bits_per_weight: fdb.bits_per_weight(),
            method: self.name(),
            fdb: Some(fdb),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg32};

    fn randw(rng: &mut Pcg32, din: usize, dout: usize) -> Matrix {
        Matrix::randn(din, dout, rng, 1.0)
    }

    #[test]
    fn dequant_on_grid() {
        prop::check(15, |rng| {
            let din = 64 * rng.range(1, 4);
            let dout = rng.range(1, 24);
            let w = randw(rng, din, dout);
            let f = FdbLinear::from_weights(&w, 64);
            let wh = f.dequant();
            for c in 0..w.cols {
                for r in 0..w.rows {
                    let g = r / 64;
                    let s = -f.a2.at(g, c); // s > 0
                    let q = wh.at(r, c) / s;
                    assert!(
                        (q.round() - q).abs() < 1e-3 && (-1.0..=2.0).contains(&q.round()),
                        "value {} not on grid (s={s})",
                        wh.at(r, c)
                    );
                }
            }
        });
    }

    #[test]
    fn split_is_nearest_level() {
        prop::check(15, |rng| {
            let w = randw(rng, 128, 8);
            let f = FdbLinear::from_weights(&w, 64);
            let wh = f.dequant();
            for c in 0..w.cols {
                for r in 0..w.rows {
                    let g = r / 64;
                    let s = -f.a2.at(g, c);
                    let v = w.at(r, c);
                    let levels = [-s, 0.0, s, 2.0 * s];
                    let mut best = levels[0];
                    for &l in &levels[1..] {
                        if (v - l).abs() < (v - best).abs() {
                            best = l;
                        }
                    }
                    let got = wh.at(r, c);
                    // ties can go either way: accept if error matches best
                    assert!(
                        (got - best).abs() < 1e-4 || ((v - got).abs() - (v - best).abs()).abs() < 1e-4,
                        "r{r} c{c}: w={v} got={got} best={best} s={s}"
                    );
                }
            }
        });
    }

    #[test]
    fn matvec_matches_dequant_matmul() {
        prop::check(15, |rng| {
            let din = 64 * rng.range(1, 4);
            let dout = rng.range(1, 32);
            let w = randw(rng, din, dout);
            let f = FdbLinear::from_weights(&w, 64);
            let wh = f.dequant();
            let x = Matrix::randn(3, w.rows, rng, 1.0);
            let y_bit = f.matmul(&x);
            let y_ref = x.matmul(&wh);
            for (a, b) in y_bit.data.iter().zip(&y_ref.data) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
            }
        });
    }

    #[test]
    fn resplit_tracks_new_scales() {
        let mut rng = Pcg32::seeded(42);
        let w = randw(&mut rng, 128, 16);
        let mut f = FdbLinear::from_weights(&w, 64);
        let before = f.dequant().mse(&w);
        // moving scales toward a better grid must not use stale planes
        let a1 = f.a1.scale(1.2);
        let a2 = f.a2.clone();
        f.resplit(&w, a1.clone(), a2.clone());
        assert_eq!(f.a1, a1);
        // planes re-derived: dequant is still on the (new) grid
        let wh = f.dequant();
        for c in 0..16 {
            for r in 0..128 {
                let g = r / 64;
                let (s1, s2) = (f.a1.at(g, c), f.a2.at(g, c));
                let v = wh.at(r, c);
                let on_grid = [0.0, s1, s2, s1 + s2]
                    .iter()
                    .any(|&l| (v - l).abs() < 1e-5);
                assert!(on_grid, "{v} not in grid ({s1},{s2})");
            }
        }
        let _ = before;
    }

    #[test]
    fn init_mse_beats_binarization() {
        // the representation-capability claim behind Fig. 3: FDB (≈2-bit
        // grid) must beat 1-bit on Gaussian weights by a wide margin
        use super::super::rtn::Rtn;
        let mut rng = Pcg32::seeded(13);
        let w = randw(&mut rng, 256, 64);
        let f = FdbLinear::from_weights(&w, 64);
        let fdb_mse = f.dequant().mse(&w);
        let (bin, _) = Rtn::new(1, 64).quantize_with_scales(&w);
        let bin_mse = bin.mse(&w);
        assert!(fdb_mse < 0.6 * bin_mse, "fdb {fdb_mse} vs bin {bin_mse}");
    }

    #[test]
    fn sparsity_above_half_on_gaussian() {
        let mut rng = Pcg32::seeded(14);
        let w = randw(&mut rng, 512, 128);
        let f = FdbLinear::from_weights(&w, 64);
        assert!(f.sparsity() > 0.55, "sparsity {}", f.sparsity());
    }

    #[test]
    fn bit_dot_counts_selected_lanes() {
        let xs: Vec<f32> = (0..64).map(|i| i as f32).collect();
        assert_eq!(bit_dot(0, &xs), 0.0);
        assert_eq!(bit_dot(0b1011, &xs), 0.0 + 1.0 + 3.0);
        assert_eq!(bit_dot(u64::MAX, &xs), (0..64).sum::<i32>() as f32);
    }

    #[test]
    fn bits_per_weight_is_2p5() {
        let w = Matrix::zeros(64, 4);
        let f = FdbLinear::from_weights(&w, 64);
        assert!((f.bits_per_weight() - 2.5).abs() < 1e-12);
    }
}
