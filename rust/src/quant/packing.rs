//! Bit-plane packing: one {0,1} plane of a `[in, out]` linear packed as
//! u64 words.  With the default group size of 64, **one group of one
//! output column is exactly one u64 word** — the unit the bit-serial
//! matmul (`fdb::FdbLinear::matvec`) and the codec consume.
//!
//! Layout: word(col, g) = words[col * g_count + g]; bit k of the word is
//! row `g * 64 + k`.  Column-major so a column's group-words are
//! contiguous in the matvec inner loop.

use crate::tensor::Matrix;

/// Bits per packed word (one group of one column at group size 64).
pub const WORD_BITS: usize = 64;

/// A packed binary plane.
#[derive(Clone, Debug, PartialEq)]
pub struct BitPlane {
    /// input width (rows of the plane; must be a multiple of 64)
    pub din: usize,
    /// output width (columns of the plane)
    pub dout: usize,
    /// column-major packed words: `words[col * g_count + g]`
    pub words: Vec<u64>,
}

impl BitPlane {
    /// Packed words per column (`din / 64`).
    pub fn g_count(&self) -> usize {
        self.din / WORD_BITS
    }

    /// Pack a {0,1} f32 matrix (values must be exactly 0.0 or 1.0).
    pub fn pack(m: &Matrix) -> Self {
        assert!(
            m.rows % WORD_BITS == 0,
            "in-dim {} must be a multiple of {WORD_BITS}",
            m.rows
        );
        let g_count = m.rows / WORD_BITS;
        let mut words = vec![0u64; m.cols * g_count];
        for r in 0..m.rows {
            let (g, bit) = (r / WORD_BITS, r % WORD_BITS);
            for c in 0..m.cols {
                let v = m.at(r, c);
                debug_assert!(v == 0.0 || v == 1.0, "non-binary value {v}");
                if v == 1.0 {
                    words[c * g_count + g] |= 1u64 << bit;
                }
            }
        }
        BitPlane { din: m.rows, dout: m.cols, words }
    }

    /// Unpack to a {0,1} f32 matrix.
    pub fn unpack(&self) -> Matrix {
        let g_count = self.g_count();
        let mut m = Matrix::zeros(self.din, self.dout);
        for c in 0..self.dout {
            for g in 0..g_count {
                let mut w = self.words[c * g_count + g];
                while w != 0 {
                    let bit = w.trailing_zeros() as usize;
                    *m.at_mut(g * WORD_BITS + bit, c) = 1.0;
                    w &= w - 1;
                }
            }
        }
        m
    }

    /// The packed word for group `g` of column `col`.
    #[inline]
    pub fn word(&self, col: usize, g: usize) -> u64 {
        self.words[col * self.g_count() + g]
    }

    /// Number of set bits (ones) in the whole plane.
    pub fn ones(&self) -> u64 {
        self.words.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// Fraction of zeros — the sparsity the paper's Table 6 reports.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.ones() as f64 / (self.din * self.dout) as f64
    }

    /// Raw little-endian bytes (codec input).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.words.len() * 8);
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg32};

    fn random_plane(rng: &mut Pcg32, din: usize, dout: usize, density: f32) -> Matrix {
        Matrix::from_fn(din, dout, |_, _| if rng.f32() < density { 1.0 } else { 0.0 })
    }

    #[test]
    fn pack_unpack_roundtrip() {
        prop::check(25, |rng| {
            let din = 64 * rng.range(1, 5);
            let dout = rng.range(1, 40);
            let density = rng.f32();
            let m = random_plane(rng, din, dout, density);
            let p = BitPlane::pack(&m);
            assert_eq!(p.unpack(), m);
        });
    }

    #[test]
    fn ones_counts_match_matrix() {
        prop::check(15, |rng| {
            let m = random_plane(rng, 128, 17, 0.3);
            let p = BitPlane::pack(&m);
            let expected: u64 = m.data.iter().map(|&v| v as u64).sum();
            assert_eq!(p.ones(), expected);
            assert!((p.sparsity() - m.zero_fraction()).abs() < 1e-12);
        });
    }

    #[test]
    fn word_layout_is_column_major_groups() {
        // set exactly row 65 (g=1, bit=1) of column 2
        let mut m = Matrix::zeros(128, 3);
        *m.at_mut(65, 2) = 1.0;
        let p = BitPlane::pack(&m);
        assert_eq!(p.word(2, 1), 1u64 << 1);
        assert_eq!(p.word(2, 0), 0);
        assert_eq!(p.word(0, 1), 0);
    }

    #[test]
    fn to_bytes_length() {
        let m = Matrix::zeros(64, 5);
        let p = BitPlane::pack(&m);
        assert_eq!(p.to_bytes().len(), 5 * 8);
    }

    #[test]
    #[should_panic]
    fn pack_rejects_misaligned() {
        BitPlane::pack(&Matrix::zeros(63, 4));
    }
}
