//! PB-LLM (Shang et al., 2023): partially-binarized LLM baseline.
//!
//! A salient fraction ρ of the weights is kept in 8-bit, the rest is
//! binarized to {-α, +α}.  Following the paper's Table-setup (§4.2) we
//! use ρ = 1/7 so the weight budget matches 2 bits:
//! (1/7)·8 + (6/7)·1 = 2.  Saliency is per-weight |w|·√E[x²] (Hessian
//! diagonal proxy, as in the published method's magnitude criterion).

use super::{scale_overhead_bits, Calib, Quantized, Quantizer};
use crate::tensor::Matrix;

/// PB-LLM: the salient fraction of weights kept at 8 bits, the rest
/// binarized — the paper's partial-binarization baseline.
pub struct PbLlm {
    /// fraction of weights kept high-precision (reference: 1/7)
    pub salient_frac: f64,
    /// quantization group size along the in-dimension
    pub group: usize,
}

impl PbLlm {
    /// Group-`group` PB-LLM with the reference 1/7 salient fraction.
    pub fn new(group: usize) -> Self {
        PbLlm { salient_frac: 1.0 / 7.0, group }
    }
}

impl Quantizer for PbLlm {
    fn name(&self) -> String {
        "PB-LLM".into()
    }

    fn quantize(&self, w: &Matrix, calib: &Calib) -> Quantized {
        // saliency score per weight
        let row_energy: Vec<f32> = if calib.is_empty() {
            vec![1.0; w.rows]
        } else {
            let mut e = vec![0.0f32; w.rows];
            for r in 0..calib.x.rows {
                for (c, &v) in calib.x.row(r).iter().enumerate() {
                    e[c] += v * v;
                }
            }
            e.iter_mut().for_each(|v| *v = (*v / calib.x.rows.max(1) as f32).sqrt());
            e
        };
        let mut scores: Vec<(f32, usize)> = w
            .data
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                let r = i / w.cols;
                (v.abs() * row_energy[r], i)
            })
            .collect();
        let n_salient = ((w.data.len() as f64) * self.salient_frac).round() as usize;
        scores.sort_by(|a, b| b.0.partial_cmp(&a.0).expect("saliency scores are finite"));
        let mut salient = vec![false; w.data.len()];
        for &(_, i) in scores.iter().take(n_salient) {
            salient[i] = true;
        }

        // 8-bit per-group symmetric grid for salient, α-binary for the rest
        let mut w_hat = Matrix::zeros(w.rows, w.cols);
        let gs = w.rows / self.group;
        for c in 0..w.cols {
            for g in 0..gs {
                let range = g * self.group..(g + 1) * self.group;
                // stats over the two partitions
                let (mut mx8, mut sum1, mut n1) = (0.0f32, 0.0f64, 0usize);
                for r in range.clone() {
                    let i = r * w.cols + c;
                    if salient[i] {
                        mx8 = mx8.max(w.data[i].abs());
                    } else {
                        sum1 += w.data[i].abs() as f64;
                        n1 += 1;
                    }
                }
                let s8 = (mx8 / 127.0).max(1e-8);
                let alpha = if n1 > 0 { (sum1 / n1 as f64) as f32 } else { 0.0 };
                for r in range {
                    let i = r * w.cols + c;
                    let v = w.data[i];
                    w_hat.data[i] = if salient[i] {
                        (v / s8).round().clamp(-128.0, 127.0) * s8
                    } else if v >= 0.0 {
                        alpha
                    } else {
                        -alpha
                    };
                }
            }
        }

        // budget: ρ·8 + (1-ρ)·1 bits + scales (α + s8 per group) + the
        // salient bitmap (1 bit/weight in the published packing)
        let bits = self.salient_frac * 8.0
            + (1.0 - self.salient_frac) * 1.0
            + 2.0 * scale_overhead_bits(self.group);
        Quantized { w_hat, bits_per_weight: bits, method: self.name(), fdb: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::util::{prop, Pcg32};

    #[test]
    fn pbllm_between_binary_and_2bit() {
        // with a 2-bit-equivalent budget, PB-LLM should beat pure
        // binarization on weight MSE (it protects the salient tail)
        prop::check(8, |rng| {
            let w = Matrix::randn(128, rng.range(4, 16), rng, 1.0);
            let calib = Calib::new(Matrix::randn(96, 128, rng, 1.0));
            let p = PbLlm::new(64).quantize(&w, &calib);
            let b = Rtn::new(1, 64).quantize(&w, &calib);
            assert!(p.w_hat.mse(&w) < b.w_hat.mse(&w));
        });
    }

    #[test]
    fn salient_weights_survive() {
        let mut rng = Pcg32::seeded(51);
        let mut w = Matrix::randn(64, 8, &mut rng, 0.05);
        *w.at_mut(3, 2) = 4.0; // a clearly salient weight
        let p = PbLlm::new(64).quantize(&w, &Calib::empty(64));
        // reproduced within 8-bit precision, not collapsed to ±α
        assert!((p.w_hat.at(3, 2) - 4.0).abs() < 0.05);
    }

    #[test]
    fn budget_matches_paper_2bit_equiv() {
        let p = PbLlm::new(64);
        let q = p.quantize(&Matrix::zeros(64, 4), &Calib::empty(64));
        assert!((q.bits_per_weight - (8.0 / 7.0 + 6.0 / 7.0 + 0.5)).abs() < 1e-9);
    }

    #[test]
    fn salient_fraction_respected() {
        let mut rng = Pcg32::seeded(52);
        let w = Matrix::randn(128, 16, &mut rng, 1.0);
        let p = PbLlm { salient_frac: 0.25, group: 64 };
        let q = p.quantize(&w, &Calib::empty(128));
        // at least the non-salient 75% collapse onto two values per group/col
        let distinct: std::collections::BTreeSet<u32> =
            q.w_hat.data.iter().map(|v| v.to_bits()).collect();
        // 2 binary values + up to 255 8-bit values per (group,col) — far
        // fewer than the 2048 distinct fp weights
        assert!(distinct.len() < 1500, "{}", distinct.len());
    }
}
