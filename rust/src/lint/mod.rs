//! Repo-native tidy lints (`db-llm-tidy`), modeled on rustc's
//! `src/tools/tidy`: zero-dependency static checks for the invariants a
//! generic clippy cannot see because they are *this repo's* contracts.
//!
//! Rules (see `docs/INVARIANTS.md` for the contracts they enforce):
//! * **lock-order** — the `PrefixCache` mutex is a leaf lock: it must
//!   never be held across a model forward, a prefill, or a
//!   `KvCache::append_block` copy-in (the PR-5 "outside the cache lock"
//!   rule).  Escape hatch: `tidy:allow(lock-order)` on the line.
//! * **no-alloc** — regions bracketed by `tidy:no-alloc` start/end
//!   comments must not contain heap-allocating
//!   calls (`vec![`, `to_vec`, `clone`, `collect`, `with_capacity`,
//!   `format!`, `Box::new`, `Matrix::zeros`, ...).  `Vec::new()` and
//!   `String::new()` are *not* denied — they are allocation-free — and
//!   `push`/`extend` into pre-reserved scratch is the sanctioned
//!   steady-state idiom.  Escape hatch: `tidy:allow(no-alloc): reason`.
//! * **unwrap-ban** — `.unwrap()` is banned outside `#[cfg(test)]`;
//!   production code uses `.expect("message naming the invariant")` or
//!   propagates the error.  (`expect` is deliberately permitted: the
//!   message *is* the machine-checked documentation of why the value
//!   cannot be absent.)
//! * **missing-docs-attr** — the serving/quant/codec surfaces
//!   (`coordinator`, `infer`, `quant`, `codec`) must carry
//!   `#![warn(missing_docs)]` so the CI doc gate keeps them documented.
//! * **bench-schema** — every repo-root `BENCH_*.json` must parse, carry
//!   its declared fields for its `bench` id, and contain no `null`
//!   values (in particular no null `wall_ns_*`: speed claims stay
//!   pinned to committed numbers).
//!
//! The analysis is deliberately line/token-textual — a comment- and
//! string-aware scanner with brace-depth scope tracking — not a full
//! parser.  That keeps the binary dependency-free and the rules cheap
//! and predictable; the escape comments cover the rare false positive.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use crate::util::Json;

/// One lint finding, anchored to a `file:line`.
#[derive(Clone, Debug)]
pub struct Violation {
    /// Repo-relative path of the offending file.
    pub file: String,
    /// 1-indexed line.
    pub line: usize,
    /// Rule identifier (also the `tidy:allow(..)` key where applicable).
    pub rule: &'static str,
    /// Human-readable diagnostic.
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Pre-processed view of one Rust source file: raw lines, comment- and
/// string-stripped lines (so patterns inside literals or comments never
/// fire), running brace depth, and `#[cfg(test)]` region membership.
pub struct FileCx {
    /// Repo-relative display name.
    pub name: String,
    /// Raw source lines (used for `tidy:` escape comments).
    pub raw: Vec<String>,
    /// Comment-stripped, string-blanked lines (used for rule patterns).
    pub code: Vec<String>,
    /// Brace depth after each line (strings/comments excluded).
    pub depth_after: Vec<i32>,
    /// Whether the line sits inside a `#[cfg(test)]` item.
    pub in_test: Vec<bool>,
}

impl FileCx {
    /// Build the per-line view for `text`.
    pub fn new(name: &str, text: &str) -> FileCx {
        let raw: Vec<String> = text.lines().map(str::to_string).collect();
        let stripped = strip_comments_and_strings(text);
        let mut code: Vec<String> = stripped.lines().map(str::to_string).collect();
        while code.len() < raw.len() {
            code.push(String::new());
        }
        code.truncate(raw.len());

        let mut depth = 0i32;
        let mut depth_after = Vec::with_capacity(code.len());
        for line in &code {
            for ch in line.chars() {
                match ch {
                    '{' => depth += 1,
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            depth_after.push(depth);
        }

        let n = raw.len();
        let mut in_test = vec![false; n];
        let mut i = 0;
        while i < n {
            if code[i].trim_start().starts_with("#[cfg(test)]") {
                let d = if i == 0 { 0 } else { depth_after[i - 1] };
                // find where the annotated item's block opens ...
                let mut open = i;
                while open < n && depth_after[open] <= d {
                    open += 1;
                }
                if open >= n {
                    // attribute on a braceless item (e.g. `mod tests;`)
                    in_test[i] = true;
                    i += 1;
                    continue;
                }
                // ... and where it closes again
                let mut close = open;
                while close < n && depth_after[close] > d {
                    close += 1;
                }
                let end = close.min(n - 1);
                for t in i..=end {
                    in_test[t] = true;
                }
                i = end + 1;
            } else {
                i += 1;
            }
        }

        FileCx { name: name.to_string(), raw, code, depth_after, in_test }
    }

    /// Does line `i` carry a `tidy:allow(rule)` escape comment?
    pub fn allows(&self, i: usize, rule: &str) -> bool {
        let needle = format!("tidy:allow({rule})");
        self.raw.get(i).map(|l| l.contains(&needle)).unwrap_or(false)
    }
}

/// Blank out comments (line, nested block, doc) and the *contents* of
/// string/char literals, preserving newlines so line numbers survive.
/// Handles raw strings (`r"..."`, `r#"..."#`) and byte strings; treats
/// `'x'` as a char literal but leaves lifetimes (`'a`) alone.
fn strip_comments_and_strings(text: &str) -> String {
    let b: Vec<char> = text.chars().collect();
    let n = b.len();
    let mut out = String::with_capacity(n);
    let mut i = 0;
    while i < n {
        let c = b[i];
        // line comment
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                i += 1;
            }
            continue;
        }
        // (nested) block comment
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == '\n' {
                        out.push('\n');
                    }
                    i += 1;
                }
            }
            continue;
        }
        // raw / byte string prefixes: r".."  r#".."#  b".."  br".."
        if (c == 'r' || c == 'b') && !prev_is_ident(&b, i) {
            let mut j = i;
            if b[j] == 'b' {
                j += 1;
            }
            let mut hashes = 0usize;
            if j < n && b[j] == 'r' {
                j += 1;
                while j < n && b[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == '"' {
                    i = skip_raw_string(&b, j + 1, hashes, &mut out);
                    continue;
                }
            } else if j < n && b[j] == '"' {
                i = skip_plain_string(&b, j + 1, &mut out);
                continue;
            } else if j < n && b[j] == '\'' {
                // byte char literal b'x'
                i = skip_char_literal(&b, j + 1);
                continue;
            }
            out.push(c);
            i += 1;
            continue;
        }
        if c == '"' {
            i = skip_plain_string(&b, i + 1, &mut out);
            continue;
        }
        if c == '\'' {
            // char literal vs lifetime
            if i + 1 < n && b[i + 1] == '\\' {
                i = skip_char_literal(&b, i + 1);
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' {
                i += 3; // 'x'
                continue;
            }
            // lifetime: drop the quote, keep scanning
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

/// Skip a non-raw string body starting just past the opening quote;
/// newlines are preserved, contents blanked.  Returns the next index.
fn skip_plain_string(b: &[char], mut i: usize, out: &mut String) -> usize {
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '"' => return i + 1,
            '\n' => {
                out.push('\n');
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Skip a raw string body (`hashes` trailing `#`s close it).
fn skip_raw_string(b: &[char], mut i: usize, hashes: usize, out: &mut String) -> usize {
    while i < b.len() {
        if b[i] == '"' {
            let close = (1..=hashes).all(|k| i + k < b.len() && b[i + k] == '#');
            if close {
                return i + 1 + hashes;
            }
        }
        if b[i] == '\n' {
            out.push('\n');
        }
        i += 1;
    }
    i
}

/// Skip a (possibly escaped) char literal body starting just past the
/// opening quote.  Returns the next index after the closing quote.
fn skip_char_literal(b: &[char], mut i: usize) -> usize {
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '\'' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

// ---------------------------------------------------------------------------
// rule: unwrap-ban
// ---------------------------------------------------------------------------

/// `.unwrap()` outside `#[cfg(test)]` — production code must `.expect()`
/// with a message naming the invariant, or propagate the error.
pub fn rule_unwrap_ban(cx: &FileCx) -> Vec<Violation> {
    let mut v = Vec::new();
    for i in 0..cx.code.len() {
        if cx.in_test[i] || cx.allows(i, "unwrap") {
            continue;
        }
        if cx.code[i].contains(".unwrap()") {
            v.push(Violation {
                file: cx.name.clone(),
                line: i + 1,
                rule: "unwrap-ban",
                msg: "`.unwrap()` outside #[cfg(test)]; use `.expect(\"<invariant>\")` \
                      or propagate the error"
                    .into(),
            });
        }
    }
    v
}

// ---------------------------------------------------------------------------
// rule: lock-order
// ---------------------------------------------------------------------------

/// Receivers whose `.lock()` opens a cache-layer critical section: the
/// PrefixCache mutex, the KvPool recycle-list mutex, and the shared
/// request queue's job list.  All are leaf locks in the documented lock
/// DAG (docs/INVARIANTS.md).
const LOCK_RECV: &[&str] =
    &["pc.lock()", "prefix.lock()", "prefix_cache.lock()", "recycled.lock()", "jobs.lock()"];

/// Calls that must never run while a cache-layer mutex is held: model
/// forwards, prefills, steps, and the bulk K/V copy-in.
const LOCK_DENY: &[&str] = &[
    ".prefill",
    ".step(",
    ".step_slot",
    ".step_rows",
    ".generate(",
    ".append_block(",
    ".matmul",
    ".forward(",
    ".run(",
];

/// The PrefixCache mutex and the KvPool recycle mutex are leaf locks:
/// inside their guard scopes only cache bookkeeping may run (for the
/// prefix cache `acquire`/`release`/`publish`/`block`; for the pool a
/// single free-list push/pop).  The guard scope is taken to extend to
/// the end of the enclosing block (or a `drop(..)` of the guard,
/// whichever comes first).
pub fn rule_lock_order(cx: &FileCx) -> Vec<Violation> {
    let mut v = Vec::new();
    for i in 0..cx.code.len() {
        let line = &cx.code[i];
        if !LOCK_RECV.iter().any(|r| line.contains(r)) {
            continue;
        }
        if cx.in_test[i] || cx.allows(i, "lock-order") {
            continue;
        }
        let d = cx.depth_after[i];
        let mut end = cx.code.len();
        for k in (i + 1)..cx.code.len() {
            if cx.code[k].contains("drop(") {
                end = k;
                break;
            }
            if cx.depth_after[k] < d {
                end = k + 1; // include the closing-brace line
                break;
            }
        }
        for k in i..end.min(cx.code.len()) {
            if cx.in_test[k] || cx.allows(k, "lock-order") {
                continue;
            }
            for pat in LOCK_DENY {
                if cx.code[k].contains(pat) {
                    v.push(Violation {
                        file: cx.name.clone(),
                        line: k + 1,
                        rule: "lock-order",
                        msg: format!(
                            "`{pat}` while a cache-layer mutex (locked at line {}) may \
                             still be held; forwards and K/V copy-ins run outside the \
                             cache lock",
                            i + 1
                        ),
                    });
                }
            }
        }
    }
    v
}

// ---------------------------------------------------------------------------
// rule: no-alloc
// ---------------------------------------------------------------------------

/// Heap-allocating patterns denied inside `tidy:no-alloc` regions.
/// `Vec::new()`/`String::new()` are allocation-free and therefore
/// allowed; `push`/`extend` into pre-reserved scratch is the sanctioned
/// steady-state idiom (capacity is paid once, outside the hot loop).
const NO_ALLOC_DENY: &[&str] = &[
    "vec![",
    ".to_vec()",
    ".clone()",
    ".collect",
    "with_capacity(",
    "Box::new(",
    "format!(",
    ".to_string()",
    ".to_owned()",
    "String::from(",
    "Matrix::zeros(",
];

/// Region-marker needles, assembled with `concat!` so that this file's
/// own source (which `run_all` walks like any other) never contains the
/// contiguous marker text and trips the rule on itself.
const NO_ALLOC_START: &str = concat!("tidy:no-alloc", "(start");
/// See [`NO_ALLOC_START`].
const NO_ALLOC_END: &str = concat!("tidy:no-alloc", "(end");

/// Steady-state hot paths bracketed by `tidy:no-alloc` start/end
/// comments must not heap-allocate.
pub fn rule_no_alloc(cx: &FileCx) -> Vec<Violation> {
    let mut v = Vec::new();
    let mut region_start: Option<usize> = None;
    for i in 0..cx.raw.len() {
        // Test modules are exempt wholesale — including from marker
        // tracking, so lint-fixture strings containing marker text
        // (this file's own unit tests) cannot open phantom regions.
        if cx.in_test[i] {
            continue;
        }
        let raw = &cx.raw[i];
        if raw.contains(NO_ALLOC_START) {
            if let Some(s) = region_start {
                v.push(Violation {
                    file: cx.name.clone(),
                    line: i + 1,
                    rule: "no-alloc",
                    msg: format!(
                        "nested no-alloc start marker (previous region opened at line {})",
                        s + 1,
                    ),
                });
            }
            region_start = Some(i);
            continue;
        }
        if raw.contains(NO_ALLOC_END) {
            if region_start.is_none() {
                v.push(Violation {
                    file: cx.name.clone(),
                    line: i + 1,
                    rule: "no-alloc",
                    msg: "no-alloc end marker without a matching start".into(),
                });
            }
            region_start = None;
            continue;
        }
        if region_start.is_none() || cx.allows(i, "no-alloc") {
            continue;
        }
        for pat in NO_ALLOC_DENY {
            if cx.code[i].contains(pat) {
                v.push(Violation {
                    file: cx.name.clone(),
                    line: i + 1,
                    rule: "no-alloc",
                    msg: format!(
                        "`{pat}` inside a tidy:no-alloc region; steady-state hot paths \
                         must reuse pre-sized scratch (see docs/INVARIANTS.md)"
                    ),
                });
            }
        }
    }
    if let Some(s) = region_start {
        v.push(Violation {
            file: cx.name.clone(),
            line: s + 1,
            rule: "no-alloc",
            msg: "unclosed no-alloc region (missing end marker)".into(),
        });
    }
    v
}

// ---------------------------------------------------------------------------
// rule: missing-docs-attr
// ---------------------------------------------------------------------------

/// Modules whose `mod.rs` must opt into `#![warn(missing_docs)]`.
const DOCUMENTED_SURFACES: &[&str] = &["coordinator", "infer", "quant", "codec"];

/// The serving, quantization, and codec surfaces stay documented: their
/// `mod.rs` files must carry `#![warn(missing_docs)]` (the CI doc gate
/// promotes the warnings to errors).
pub fn rule_missing_docs_attr(src_root: &Path) -> Vec<Violation> {
    let mut v = Vec::new();
    for m in DOCUMENTED_SURFACES {
        let p = src_root.join(m).join("mod.rs");
        let file = p.display().to_string();
        match fs::read_to_string(&p) {
            Ok(text) => {
                if !text.contains("#![warn(missing_docs)]") {
                    v.push(Violation {
                        file,
                        line: 1,
                        rule: "missing-docs-attr",
                        msg: format!("module `{m}` must carry #![warn(missing_docs)]"),
                    });
                }
            }
            Err(e) => v.push(Violation {
                file,
                line: 1,
                rule: "missing-docs-attr",
                msg: format!("cannot read module root: {e}"),
            }),
        }
    }
    v
}

// ---------------------------------------------------------------------------
// rule: bench-schema
// ---------------------------------------------------------------------------

/// Declared top-level fields per bench id (beyond `bench` itself).
/// A committed `BENCH_*.json` whose id is unknown, whose declared fields
/// are absent, or which contains any `null` fails the gate.
fn bench_required_keys(bench: &str) -> Option<&'static [&'static str]> {
    match bench {
        "fused_step_slots" => {
            Some(&["model", "d_model", "n_layers", "window", "slots_sweep", "sweep", "note"])
        }
        "scheduler_mixed_lengths" => Some(&[
            "slots",
            "requests",
            "lengths_cycle",
            "tokens",
            "ticks_static",
            "ticks_continuous",
            "stalled_row_steps_static",
            "stalled_row_steps_continuous",
            "lockstep_speedup",
            "slot_occupancy_continuous",
            "wall_ns_per_drain_continuous",
            "wall_ns_per_drain_static",
            "wall_tokens_per_sec_continuous",
            "wall_tokens_per_sec_static",
            "note",
        ]),
        "kv_pool" => Some(&[
            "model",
            "d_model",
            "n_layers",
            "window",
            "block_tokens",
            "budget_bytes",
            "block_bytes",
            "worst_case_bytes_per_slot",
            "requests_resident_worst_case",
            "requests_resident_paged",
            "hit_tokens",
            "warm_copy_bytes_worst_case",
            "warm_copy_bytes_paged",
            "wall_ns_per_warm_prefill",
            "wall_ns_per_cold_prefill",
            "note",
        ]),
        "prefix_cache_shared_prefill" => Some(&[
            "model",
            "d_model",
            "n_layers",
            "window",
            "block_tokens",
            "sweep",
            "note",
        ]),
        "serving_trace" => Some(&[
            "model",
            "d_model",
            "n_layers",
            "window",
            "slots",
            "requests",
            "decode_tokens",
            "ttft_p50_us",
            "ttft_p95_us",
            "ttft_p99_us",
            "itl_p50_us",
            "itl_p95_us",
            "itl_p99_us",
            "queue_wait_p50_us",
            "prefill_p50_us",
            "wall_ns_per_token_decode",
            "wall_ns_per_prefill",
            "trace_events",
            "trace_dropped",
            "profiled_ticks",
            "note",
        ]),
        // chaos soak outcomes are counts, not timings: deliberately no
        // wall_ns_* fields (nothing here may gate on wall clock)
        "chaos_soak" => Some(&[
            "seeds",
            "requests_per_seed",
            "injected_panics",
            "injected_prefill_faults",
            "injected_step_faults",
            "replies_ok",
            "replies_err",
            "respawns",
            "leaked_blocks",
            "note",
        ]),
        "spec_decode" => Some(&[
            "model",
            "d_model",
            "n_layers",
            "window",
            "slots",
            "k",
            "prompt_tokens",
            "decode_tokens_per_slot",
            "drafted",
            "accepted",
            "rejected",
            "bonus_tokens",
            "fallback_rows",
            "rolled_back_rows",
            "acceptance_rate",
            "teacher_forwards_saved",
            "verify_passes",
            "ticks_speculative",
            "ticks_teacher_only",
            "tick_reduction",
            "wall_ns_per_token_speculative",
            "wall_ns_per_token_teacher_only",
            "wall_speculative_speedup",
            "note",
        ]),
        _ => None,
    }
}

fn scan_nulls(j: &Json, path: &str, out: &mut Vec<String>) {
    match j {
        Json::Null => out.push(path.to_string()),
        Json::Arr(a) => {
            for (i, x) in a.iter().enumerate() {
                scan_nulls(x, &format!("{path}[{i}]"), out);
            }
        }
        Json::Obj(m) => {
            for (k, x) in m {
                scan_nulls(x, &format!("{path}.{k}"), out);
            }
        }
        _ => {}
    }
}

/// List the repo-root `BENCH_*.json` files, sorted.
fn bench_files(repo_root: &Path) -> Result<Vec<PathBuf>, String> {
    let entries = fs::read_dir(repo_root)
        .map_err(|e| format!("cannot read repo root {}: {e}", repo_root.display()))?;
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                .unwrap_or(false)
        })
        .collect();
    paths.sort();
    Ok(paths)
}

/// Every committed `BENCH_*.json` parses, matches its declared schema,
/// and carries no `null` values — so the repo's speed claims stay
/// pinned to real committed numbers.
pub fn rule_bench_schema(repo_root: &Path) -> Vec<Violation> {
    let mut v = Vec::new();
    let paths = match bench_files(repo_root) {
        Ok(p) => p,
        Err(msg) => {
            v.push(Violation { file: repo_root.display().to_string(), line: 1, rule: "bench-schema", msg });
            return v;
        }
    };
    if paths.is_empty() {
        v.push(Violation {
            file: repo_root.display().to_string(),
            line: 1,
            rule: "bench-schema",
            msg: "no BENCH_*.json found at the repo root (wrong --root?)".into(),
        });
        return v;
    }
    for p in paths {
        let file = p.display().to_string();
        let text = match fs::read_to_string(&p) {
            Ok(t) => t,
            Err(e) => {
                v.push(Violation {
                    file,
                    line: 1,
                    rule: "bench-schema",
                    msg: format!("cannot read: {e}"),
                });
                continue;
            }
        };
        let json = match Json::parse(&text) {
            Ok(j) => j,
            Err(e) => {
                v.push(Violation {
                    file,
                    line: 1,
                    rule: "bench-schema",
                    msg: format!("does not parse as JSON: {e}"),
                });
                continue;
            }
        };
        let bench_id = json.opt("bench").and_then(|b| b.as_str().ok()).map(str::to_string);
        match bench_id.as_deref().and_then(bench_required_keys) {
            Some(required) => {
                for key in required {
                    if json.opt(key).is_none() {
                        v.push(Violation {
                            file: file.clone(),
                            line: 1,
                            rule: "bench-schema",
                            msg: format!("missing declared field `{key}`"),
                        });
                    }
                }
            }
            None => v.push(Violation {
                file: file.clone(),
                line: 1,
                rule: "bench-schema",
                msg: match bench_id {
                    Some(id) => format!(
                        "unknown bench id {id:?}; declare its schema in lint::bench_required_keys"
                    ),
                    None => "missing string field `bench`".into(),
                },
            }),
        }
        let mut nulls = Vec::new();
        scan_nulls(&json, "$", &mut nulls);
        for path in nulls {
            v.push(Violation {
                file: file.clone(),
                line: 1,
                rule: "bench-schema",
                msg: format!(
                    "null value at {path}; run `cargo bench --bench decode` and commit \
                     real numbers (wall fields must never be null)"
                ),
            });
        }
    }
    v
}

// ---------------------------------------------------------------------------
// perf-regression check (tidy --perf-check)
// ---------------------------------------------------------------------------

/// Collect `(json-path, value)` for every numeric key starting with
/// `wall_ns` (lower is better; throughput keys are excluded on purpose).
fn scan_wall_ns(j: &Json, path: &str, out: &mut Vec<(String, f64)>) {
    match j {
        Json::Arr(a) => {
            for (i, x) in a.iter().enumerate() {
                scan_wall_ns(x, &format!("{path}[{i}]"), out);
            }
        }
        Json::Obj(m) => {
            for (k, x) in m {
                let sub = format!("{path}.{k}");
                if k.starts_with("wall_ns") {
                    if let Json::Num(n) = x {
                        out.push((sub.clone(), *n));
                    }
                }
                scan_wall_ns(x, &sub, out);
            }
        }
        _ => {}
    }
}

/// Compare the repo's current `BENCH_*.json` wall-clock numbers against
/// baseline copies in `baseline_dir`: any `wall_ns_*` field more than
/// `tolerance`× slower than its baseline is a regression.  Fields absent
/// from the baseline (new bench cases) are skipped.
pub fn perf_check(repo_root: &Path, baseline_dir: &Path, tolerance: f64) -> Vec<Violation> {
    let mut v = Vec::new();
    let paths = match bench_files(repo_root) {
        Ok(p) => p,
        Err(msg) => {
            v.push(Violation { file: repo_root.display().to_string(), line: 1, rule: "perf-regression", msg });
            return v;
        }
    };
    for p in paths {
        let file = p.display().to_string();
        let name = match p.file_name().and_then(|n| n.to_str()) {
            Some(n) => n.to_string(),
            None => continue,
        };
        let base_path = baseline_dir.join(&name);
        let (cur, base) = match (fs::read_to_string(&p), fs::read_to_string(&base_path)) {
            (Ok(c), Ok(b)) => (c, b),
            (_, Err(e)) => {
                v.push(Violation {
                    file,
                    line: 1,
                    rule: "perf-regression",
                    msg: format!("no baseline {}: {e}", base_path.display()),
                });
                continue;
            }
            (Err(e), _) => {
                v.push(Violation {
                    file,
                    line: 1,
                    rule: "perf-regression",
                    msg: format!("cannot read: {e}"),
                });
                continue;
            }
        };
        let (cur, base) = match (Json::parse(&cur), Json::parse(&base)) {
            (Ok(c), Ok(b)) => (c, b),
            (c, b) => {
                let e = c.err().or(b.err()).map(|e| e.to_string()).unwrap_or_default();
                v.push(Violation {
                    file,
                    line: 1,
                    rule: "perf-regression",
                    msg: format!("bench json does not parse: {e}"),
                });
                continue;
            }
        };
        let mut cur_walls = Vec::new();
        let mut base_walls = Vec::new();
        scan_wall_ns(&cur, "$", &mut cur_walls);
        scan_wall_ns(&base, "$", &mut base_walls);
        for (path, c) in &cur_walls {
            let Some((_, b)) = base_walls.iter().find(|(bp, _)| bp == path) else {
                continue;
            };
            if *b > 0.0 && *c > *b * tolerance {
                v.push(Violation {
                    file: file.clone(),
                    line: 1,
                    rule: "perf-regression",
                    msg: format!(
                        "{path}: {c:.0} ns vs baseline {b:.0} ns exceeds the {tolerance}x \
                         tolerance band"
                    ),
                });
            }
        }
    }
    v
}

// ---------------------------------------------------------------------------
// driver
// ---------------------------------------------------------------------------

/// Recursively collect `.rs` files under `dir`, sorted for stable output.
fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else {
        return;
    };
    let mut paths: Vec<PathBuf> = entries.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            walk_rs(&p, out);
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
}

/// Run every rule over the repo rooted at `repo_root` (the directory
/// holding `rust/` and the `BENCH_*.json` files).
pub fn run_all(repo_root: &Path) -> Vec<Violation> {
    let src_root = repo_root.join("rust").join("src");
    let mut violations = Vec::new();
    let mut files = Vec::new();
    walk_rs(&src_root, &mut files);
    if files.is_empty() {
        violations.push(Violation {
            file: src_root.display().to_string(),
            line: 1,
            rule: "tidy",
            msg: "no .rs files found under rust/src (wrong --root?)".into(),
        });
        return violations;
    }
    for path in &files {
        let text = match fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                violations.push(Violation {
                    file: path.display().to_string(),
                    line: 1,
                    rule: "tidy",
                    msg: format!("cannot read: {e}"),
                });
                continue;
            }
        };
        let name = path
            .strip_prefix(repo_root)
            .map(|p| p.display().to_string())
            .unwrap_or_else(|_| path.display().to_string());
        let cx = FileCx::new(&name, &text);
        violations.extend(rule_unwrap_ban(&cx));
        violations.extend(rule_lock_order(&cx));
        violations.extend(rule_no_alloc(&cx));
    }
    violations.extend(rule_missing_docs_attr(&src_root));
    violations.extend(rule_bench_schema(repo_root));
    violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    violations
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cx(text: &str) -> FileCx {
        FileCx::new("fixture.rs", text)
    }

    #[test]
    fn stripper_blanks_strings_and_comments() {
        let text = concat!(
            "let a = \"contains .unwrap() and { braces\"; // trailing .unwrap()\n",
            "/* block .unwrap()\n",
            "   spanning lines */ let b = 1;\n",
            "let c = '{';\n",
        );
        let f = cx(text);
        assert_eq!(f.code.len(), 4);
        assert!(!f.code[0].contains(".unwrap()"));
        assert!(!f.code[1].contains(".unwrap()"));
        assert!(f.code[2].contains("let b"));
        // brace inside string and char literal must not affect depth
        assert_eq!(f.depth_after[3], 0);
    }

    #[test]
    fn stripper_handles_raw_strings() {
        let text = "let s = r#\"has \"quotes\" and .unwrap()\"#;\nlet t = 2;\n";
        let f = cx(text);
        assert!(!f.code[0].contains(".unwrap()"));
        assert!(f.code[1].contains("let t"));
    }

    #[test]
    fn test_regions_are_tracked() {
        let text = concat!(
            "fn prod() {\n",
            "    work();\n",
            "}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn t() { x.unwrap(); }\n",
            "}\n",
            "fn prod2() { y.unwrap(); }\n",
        );
        let f = cx(text);
        assert!(!f.in_test[1]);
        assert!(f.in_test[4] && f.in_test[5] && f.in_test[6]);
        assert!(!f.in_test[7]);
        let v = rule_unwrap_ban(&f);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 8);
    }

    #[test]
    fn unwrap_ban_fires_with_file_line() {
        let f = cx("fn f() {\n    let x = o.unwrap();\n}\n");
        let v = rule_unwrap_ban(&f);
        assert_eq!(v.len(), 1);
        assert_eq!((v[0].file.as_str(), v[0].line), ("fixture.rs", 2));
        assert!(v[0].to_string().contains("fixture.rs:2"));
    }

    #[test]
    fn unwrap_ban_permits_expect_and_allow() {
        let text = concat!(
            "fn f() {\n",
            "    let x = o.expect(\"pinned block vanished\");\n",
            "    let y = p.unwrap(); // tidy:allow(unwrap): fixture\n",
            "}\n",
        );
        assert!(rule_unwrap_ban(&cx(text)).is_empty());
    }

    #[test]
    fn lock_order_flags_forward_under_guard() {
        let text = concat!(
            "fn f(&mut self) {\n",
            "    if let Ok(mut g) = pc.lock() {\n",
            "        let h = g.acquire(prompt);\n",
            "        self.engine.prefill(&toks);\n",
            "    }\n",
            "    self.engine.prefill(&toks); // outside: fine\n",
            "}\n",
        );
        let v = rule_lock_order(&cx(text));
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 4);
        assert!(v[0].msg.contains("outside the cache lock"));
    }

    #[test]
    fn lock_order_flags_copy_in_after_plain_let_guard() {
        let text = concat!(
            "fn f(&mut self) {\n",
            "    let mut g = pc.lock().expect(\"prefix mutex\");\n",
            "    cache.append_block(&blk);\n",
            "}\n",
        );
        let v = rule_lock_order(&cx(text));
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn lock_order_covers_pool_recycle_mutex() {
        let text = concat!(
            "fn retire(&self) {\n",
            "    if let Ok(mut free) = self.recycled.lock() {\n",
            "        free.push(data);\n",
            "        cache.append_block(&blk);\n",
            "    }\n",
            "}\n",
        );
        let v = rule_lock_order(&cx(text));
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 4);
        let clean = concat!(
            "fn retire(&self) {\n",
            "    if let Ok(mut free) = self.recycled.lock() {\n",
            "        free.push(data);\n",
            "    }\n",
            "    cache.append_block(&blk); // outside the leaf lock: fine\n",
            "}\n",
        );
        assert!(rule_lock_order(&cx(clean)).is_empty());
    }

    #[test]
    fn lock_order_covers_shared_queue_jobs_mutex() {
        let text = concat!(
            "pub fn push(&self, req: Request) {\n",
            "    if let Ok(mut q) = self.jobs.lock() {\n",
            "        q.push_back(req);\n",
            "        engine.generate(&prompts);\n",
            "    }\n",
            "}\n",
        );
        let v = rule_lock_order(&cx(text));
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 4);
        let clean = concat!(
            "pub fn push(&self, req: Request) {\n",
            "    if let Ok(mut q) = self.jobs.lock() {\n",
            "        q.push_back(req);\n",
            "    }\n",
            "    engine.generate(&prompts); // queue lock released: fine\n",
            "}\n",
        );
        assert!(rule_lock_order(&cx(clean)).is_empty());
    }

    #[test]
    fn lock_order_respects_drop_and_allow() {
        let dropped = concat!(
            "fn f(&mut self) {\n",
            "    let mut g = pc.lock().expect(\"prefix mutex\");\n",
            "    let pins = g.acquire(prompt);\n",
            "    drop(g);\n",
            "    cache.append_block(&blk);\n",
            "}\n",
        );
        assert!(rule_lock_order(&cx(dropped)).is_empty());
        let allowed = concat!(
            "fn f(&mut self) {\n",
            "    if let Ok(mut g) = pc.lock() {\n",
            "        cache.append_block(&blk); // tidy:allow(lock-order): fixture\n",
            "    }\n",
            "}\n",
        );
        assert!(rule_lock_order(&cx(allowed)).is_empty());
    }

    #[test]
    fn no_alloc_region_flags_allocations() {
        let text = concat!(
            "fn hot(&mut self) {\n",
            "    // tidy:no-alloc(start): steady-state decode\n",
            "    let a = Vec::new();\n",           // alloc-free: fine
            "    self.scratch.buf.push(x);\n",     // reuse idiom: fine
            "    let b = xs.to_vec();\n",          // line 5: flagged
            "    let c = vec![0.0; n]; // tidy:allow(no-alloc): fixture\n",
            "    // tidy:no-alloc(end)\n",
            "    let d = ys.to_vec();\n",          // outside: fine
            "}\n",
        );
        let v = rule_no_alloc(&cx(text));
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 5);
        assert!(v[0].msg.contains("to_vec"));
    }

    #[test]
    fn no_alloc_unclosed_region_is_flagged() {
        let text = "fn hot() {\n    // tidy:no-alloc(start)\n    work();\n}\n";
        let v = rule_no_alloc(&cx(text));
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("unclosed"));
    }

    #[test]
    #[cfg_attr(miri, ignore = "touches the real filesystem")]
    fn bench_schema_catches_nulls_and_unknown_ids() {
        let dir = std::env::temp_dir().join(format!("tidy-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("BENCH_x.json"),
            "{\"bench\": \"fused_step_slots\", \"model\": \"m\", \"d_model\": 1, \
             \"n_layers\": 1, \"window\": 8, \"slots_sweep\": [1], \
             \"sweep\": [{\"wall_ns_per_tick_fused\": null}], \"note\": \"n\"}",
        )
        .unwrap();
        std::fs::write(dir.join("BENCH_y.json"), "{\"bench\": \"mystery\"}").unwrap();
        let v = rule_bench_schema(&dir);
        assert!(
            v.iter().any(|x| x.msg.contains("null value at $.sweep[0].wall_ns_per_tick_fused")),
            "{v:?}"
        );
        assert!(v.iter().any(|x| x.msg.contains("unknown bench id")), "{v:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[cfg_attr(miri, ignore = "touches the real filesystem")]
    fn bench_schema_knows_chaos_soak() {
        let dir = std::env::temp_dir().join(format!("tidy-chaos-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // a complete chaos_soak record passes
        std::fs::write(
            dir.join("BENCH_ok.json"),
            "{\"bench\": \"chaos_soak\", \"seeds\": 6, \"requests_per_seed\": 24, \
             \"injected_panics\": 4, \"injected_prefill_faults\": 3, \
             \"injected_step_faults\": 5, \"replies_ok\": 130, \"replies_err\": 14, \
             \"respawns\": 4, \"leaked_blocks\": 0, \"note\": \"n\"}",
        )
        .unwrap();
        assert!(rule_bench_schema(&dir).is_empty(), "{:?}", rule_bench_schema(&dir));
        // dropping a declared field fails the gate
        std::fs::write(
            dir.join("BENCH_bad.json"),
            "{\"bench\": \"chaos_soak\", \"seeds\": 6, \"note\": \"n\"}",
        )
        .unwrap();
        let v = rule_bench_schema(&dir);
        assert!(v.iter().any(|x| x.msg.contains("missing declared field `leaked_blocks`")), "{v:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[cfg_attr(miri, ignore = "touches the real filesystem")]
    fn bench_schema_knows_spec_decode() {
        let dir = std::env::temp_dir().join(format!("tidy-spec-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // a complete spec_decode record passes
        std::fs::write(
            dir.join("BENCH_ok.json"),
            "{\"bench\": \"spec_decode\", \"model\": \"bench\", \"d_model\": 128, \
             \"n_layers\": 4, \"window\": 128, \"slots\": 4, \"k\": 3, \
             \"prompt_tokens\": 8, \"decode_tokens_per_slot\": 16, \"drafted\": 60, \
             \"accepted\": 56, \"rejected\": 4, \"bonus_tokens\": 20, \
             \"fallback_rows\": 0, \"rolled_back_rows\": 4, \"acceptance_rate\": 0.93, \
             \"teacher_forwards_saved\": 56, \"verify_passes\": 20, \
             \"ticks_speculative\": 20, \"ticks_teacher_only\": 15, \
             \"tick_reduction\": 0.0, \"wall_ns_per_token_speculative\": 1.0, \
             \"wall_ns_per_token_teacher_only\": 1.0, \
             \"wall_speculative_speedup\": 1.0, \"note\": \"n\"}",
        )
        .unwrap();
        assert!(rule_bench_schema(&dir).is_empty(), "{:?}", rule_bench_schema(&dir));
        // dropping the headline counter fails the gate
        std::fs::write(
            dir.join("BENCH_bad.json"),
            "{\"bench\": \"spec_decode\", \"drafted\": 60, \"note\": \"n\"}",
        )
        .unwrap();
        let v = rule_bench_schema(&dir);
        assert!(
            v.iter().any(|x| x.msg.contains("missing declared field `teacher_forwards_saved`")),
            "{v:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[cfg_attr(miri, ignore = "touches the real filesystem")]
    fn perf_check_flags_only_out_of_band_walls() {
        let root = std::env::temp_dir().join(format!("tidy-perf-{}", std::process::id()));
        let base = root.join("baseline");
        std::fs::create_dir_all(&base).unwrap();
        std::fs::write(
            root.join("BENCH_x.json"),
            "{\"bench\": \"b\", \"wall_ns_a\": 900, \"wall_ns_b\": 5000}",
        )
        .unwrap();
        std::fs::write(
            base.join("BENCH_x.json"),
            "{\"bench\": \"b\", \"wall_ns_a\": 1000, \"wall_ns_b\": 1000}",
        )
        .unwrap();
        let v = perf_check(&root, &base, 4.0);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("wall_ns_b"), "{v:?}");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    #[cfg_attr(miri, ignore = "walks the real repo tree")]
    fn run_all_is_clean_on_this_repo() {
        // the tree itself must satisfy its own lints; this is the same
        // check CI runs via `cargo run --bin db-llm-tidy`
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("rust/ lives inside the repo root")
            .to_path_buf();
        let v = run_all(&root);
        assert!(v.is_empty(), "tidy violations:\n{}", {
            let mut s = String::new();
            for x in &v {
                s.push_str(&x.to_string());
                s.push('\n');
            }
            s
        });
    }
}
