//! `db-llm-tidy` — the repo-native lint gate (see `src/lint/mod.rs` and
//! `docs/INVARIANTS.md`).
//!
//! ```text
//! db-llm-tidy [--root <repo_root>]                    # lint the tree
//! db-llm-tidy --perf-check <baseline_dir> \
//!             [--tolerance <x>] [--root <repo_root>]  # bench regression gate
//! ```
//!
//! With no arguments the repo root is derived from the crate location
//! (`rust/..`), which is what CI and `cargo run --bin db-llm-tidy` use.
//! `--perf-check` compares the repo's current `BENCH_*.json` wall-clock
//! fields against baseline copies in `<baseline_dir>`; any `wall_ns_*`
//! more than `--tolerance`× (default 4.0) slower fails.  Exit status is
//! the violation count's truthiness: 0 clean, 1 violations, 2 bad usage.

use std::path::PathBuf;
use std::process::ExitCode;

use db_llm::lint;

fn usage() {
    eprintln!(
        "usage: db-llm-tidy [--root <repo_root>] \
         [--perf-check <baseline_dir> [--tolerance <x>]]"
    );
}

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let mut root: Option<PathBuf> = None;
    let mut baseline: Option<PathBuf> = None;
    let mut tolerance = 4.0f64;
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => root = args.next().map(PathBuf::from),
            "--perf-check" => baseline = args.next().map(PathBuf::from),
            "--tolerance" => match args.next().and_then(|s| s.parse::<f64>().ok()) {
                Some(t) if t >= 1.0 => tolerance = t,
                _ => {
                    eprintln!("--tolerance needs a number >= 1.0");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                usage();
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("unknown argument {other:?}");
                usage();
                return ExitCode::from(2);
            }
        }
    }
    // the crate lives at <repo_root>/rust
    let root = root.unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."))
    });

    let (mode, violations) = match baseline {
        Some(dir) => ("perf-check", lint::perf_check(&root, &dir, tolerance)),
        None => ("lint", lint::run_all(&root)),
    };
    if violations.is_empty() {
        println!("db-llm-tidy: {mode} clean ({})", root.display());
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        eprintln!("db-llm-tidy: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}
