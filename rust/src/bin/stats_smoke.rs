//! `stats-smoke` — boots the continuous-batching server on a loopback
//! port with a tiny synthetic model, drives one generate request plus
//! two `{"cmd": "stats"}` control requests over the wire, and validates
//! the live stats surface end to end:
//!
//! - the stats reply is a single JSON line carrying both the full
//!   metrics object (`stats`) and a Prometheus text exposition
//!   (`prometheus`),
//! - the Prometheus text is well-formed (exactly one `# TYPE` per
//!   metric family, every sample belongs to a declared family) and
//!   includes the required serving families,
//! - counters are monotone across two stats calls separated by a
//!   generate request.
//!
//! CI runs this as a named gate (`cargo run --release --bin
//! stats-smoke`, wrapped by `scripts/stats_smoke.sh`); it needs no
//! artifacts and exits 0 on success, 1 with a diagnostic on failure.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::process::ExitCode;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};
use db_llm::coordinator::metrics::Metrics;
use db_llm::coordinator::scheduler::{serve_continuous, SchedulerConfig};
use db_llm::infer::NativeEngine;
use db_llm::model::{ModelConfig, Weights};
use db_llm::util::Json;

/// Metric families the serving stack must always export.
const REQUIRED_FAMILIES: &[&str] = &[
    "dbllm_requests_total",
    "dbllm_responses_total",
    "dbllm_ttft_us",
    "dbllm_itl_us",
    "dbllm_queue_wait_us",
    "dbllm_prefill_us",
    "dbllm_tick_us",
    "dbllm_prefix_hit_rate",
    "dbllm_slot_occ",
    "dbllm_mean_decode_batch",
];

fn tiny() -> ModelConfig {
    ModelConfig {
        name: "smoke".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 192,
        vocab: 96,
        seq_len: 32,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

/// One request/one reply over the newline-delimited wire protocol.
fn ask(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> Result<Json> {
    writeln!(stream, "{req}").context("writing request")?;
    let mut line = String::new();
    reader.read_line(&mut line).context("reading reply")?;
    ensure!(!line.trim().is_empty(), "server closed the connection");
    Json::parse(line.trim()).with_context(|| format!("parsing reply {line:?}"))
}

/// Validate a Prometheus text exposition: one `# TYPE` per family and
/// no samples outside a declared family.  Returns the declared family
/// names.
fn check_prometheus(text: &str) -> Result<Vec<String>> {
    let mut families: Vec<String> = Vec::new();
    for l in text.lines() {
        if let Some(rest) = l.strip_prefix("# TYPE ") {
            let name = rest
                .split(' ')
                .next()
                .context("empty # TYPE line")?
                .to_string();
            ensure!(!families.contains(&name), "duplicate # TYPE for {name}");
            families.push(name);
        }
    }
    for l in text.lines() {
        if l.starts_with('#') || l.trim().is_empty() {
            continue;
        }
        let sample = l
            .split(|c: char| c == ' ' || c == '{')
            .next()
            .context("empty sample line")?;
        let base = sample
            .strip_suffix("_sum")
            .or_else(|| sample.strip_suffix("_count"))
            .unwrap_or(sample);
        ensure!(
            families.iter().any(|f| f == base),
            "sample {sample} has no # TYPE family"
        );
    }
    Ok(families)
}

fn counter(stats: &Json, name: &str) -> Result<f64> {
    stats.get("counters")?.get(name)?.as_f64()
}

fn run() -> Result<()> {
    let cfg = tiny();
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let factory_cfg = cfg.clone();
    let addr = serve_continuous(
        move || {
            let weights = Weights::synthetic(&factory_cfg, 23);
            Ok(NativeEngine::new(weights, &BTreeMap::new(), factory_cfg.seq_len, 7)
                .with_slots(2))
        },
        "127.0.0.1:0",
        64,
        SchedulerConfig { slots: 2, trace: true, profile_every: 1, ..Default::default() },
        1,
        metrics.clone(),
        running.clone(),
    )
    .context("starting server")?;

    let mut stream = {
        let mut tries = 0u32;
        loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    tries += 1;
                    ensure!(tries < 250, "server never came up: {e}");
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
            }
        }
    };
    let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);

    // one real decode so the phase histograms have samples
    let gen = ask(&mut stream, &mut reader, "{\"prompt\": [5, 10, 15], \"max_tokens\": 6}")?;
    ensure!(gen.opt("error").is_none(), "generate failed: {gen}");
    ensure!(gen.usize_list("tokens")?.len() == 6, "wrong token count: {gen}");

    // stats call #1: JSON shape + Prometheus well-formedness
    let reply = ask(&mut stream, &mut reader, "{\"cmd\": \"stats\"}")?;
    let stats = reply.get("stats").context("stats reply missing 'stats'")?;
    let prom = reply.get("prometheus")?.as_str().context("'prometheus' not a string")?;
    let requests = counter(stats, "requests")?;
    let responses = counter(stats, "responses")?;
    ensure!(requests >= 1.0 && responses >= 1.0, "no traffic counted: {reply}");
    let ttft_count = stats.get("histograms")?.get("ttft_us")?.get("count")?.as_f64()?;
    ensure!(ttft_count >= 1.0, "TTFT histogram is empty");
    for g in ["prefix_hit_rate", "slot_occ", "mean_decode_batch", "queue_depth"] {
        stats
            .get("gauges")?
            .get(g)?
            .as_f64()
            .with_context(|| format!("gauge {g} missing or non-numeric"))?;
    }
    let families = check_prometheus(prom)?;
    for f in REQUIRED_FAMILIES {
        ensure!(families.iter().any(|have| have == f), "missing family {f}");
    }

    // stats call #2 after another request: counters are monotone
    let gen2 = ask(&mut stream, &mut reader, "{\"prompt\": [7], \"max_tokens\": 4}")?;
    ensure!(gen2.opt("error").is_none(), "second generate failed: {gen2}");
    let reply2 = ask(&mut stream, &mut reader, "{\"cmd\": \"stats\"}")?;
    let stats2 = reply2.get("stats")?;
    ensure!(
        counter(stats2, "requests")? > requests,
        "requests counter did not advance"
    );
    ensure!(
        counter(stats2, "responses")? > responses,
        "responses counter did not advance"
    );

    // unknown control commands get an error reply, not a hang
    let bad = ask(&mut stream, &mut reader, "{\"cmd\": \"reboot\"}")?;
    match bad.opt("error").map(Json::to_string) {
        Some(msg) if msg.contains("unknown cmd") => {}
        other => bail!("expected unknown-cmd error, got {other:?}"),
    }

    running.store(false, Ordering::Relaxed);
    println!(
        "stats-smoke OK: {} prometheus families, {} requests counted",
        families.len(),
        counter(stats2, "requests")?
    );
    Ok(())
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("stats-smoke FAILED: {e:#}");
            ExitCode::FAILURE
        }
    }
}
