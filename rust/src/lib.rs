//! DB-LLM: Accurate Dual-Binarization for Efficient LLMs (ACL 2024
//! Findings) — a rust + JAX + Pallas reproduction.
//!
//! Three layers (see DESIGN.md): the Pallas FDB kernel and the JAX model
//! are AOT-lowered to HLO at build time (python, never on the request
//! path); this crate is the system — quantization engine, entropy codec,
//! PJRT runtime, serving/fine-tuning coordinator and the evaluation
//! harness that regenerates every table and figure of the paper.

pub mod codec;
pub mod coordinator;
pub mod eval;
pub mod data;
pub mod infer;
pub mod lint;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;
