//! A `Session` pins one model's weights on the PJRT device and exposes
//! the two forward entry points (`logits`, `nll`).  Only the per-call
//! tokens are uploaded in the hot loop — weight re-transfer was the
//! dominant cost of the naive literal path (see EXPERIMENTS.md §Perf).

use anyhow::{ensure, Context, Result};

use crate::model::Weights;

use super::Runtime;

/// Device-resident weights + the executables that consume them.
pub struct Session {
    pub size: String,
    pub vocab: usize,
    pub seq_len: usize,
    pub logits_batch: usize,
    pub nll_batch: usize,
    weight_bufs: Vec<xla::PjRtBuffer>,
}

impl Session {
    /// Upload `weights` (teacher or dequantized student) once.
    pub fn new(rt: &Runtime, weights: &Weights) -> Result<Session> {
        let size = weights.config.name.clone();
        let mut weight_bufs = Vec::new();
        for (data, dims) in weights.flat_params() {
            let dims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
            weight_bufs.push(rt.client.buffer_from_host_buffer::<f32>(&data, &dims, None)?);
        }
        Ok(Session {
            size,
            vocab: weights.config.vocab,
            seq_len: rt.manifest.seq_len(),
            logits_batch: rt.manifest.logits_batch(),
            nll_batch: rt.manifest.nll_batch(),
            weight_bufs,
        })
    }

    fn run_with_tokens(
        &self,
        rt: &mut Runtime,
        key: &str,
        tokens: &[i32],
        dims: &[usize],
    ) -> Result<Vec<f32>> {
        let tok_buf = rt.client.buffer_from_host_buffer::<i32>(tokens, dims, None)?;
        let exe = rt.executable(key)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        let out = exe.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        ensure!(parts.len() == 1, "expected 1-tuple from {key}");
        Ok(parts[0].to_vec::<f32>()?)
    }

    /// `tokens` is `[logits_batch, seq_len]` row-major; returns logits
    /// `[logits_batch, seq_len, vocab]` flattened.
    pub fn logits(&self, rt: &mut Runtime, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, t) = (self.logits_batch, self.seq_len);
        ensure!(tokens.len() == b * t, "logits expects [{b},{t}] tokens");
        self.run_with_tokens(rt, &format!("fwd_logits_{}", self.size), tokens, &[b, t])
    }

    /// `tokens` is `[nll_batch, seq_len+1]`; returns per-token NLL
    /// (nats) `[nll_batch, seq_len]` flattened.
    pub fn nll(&self, rt: &mut Runtime, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, t) = (self.nll_batch, self.seq_len + 1);
        ensure!(tokens.len() == b * t, "nll expects [{b},{t}] tokens");
        self.run_with_tokens(rt, &format!("fwd_nll_{}", self.size), tokens, &[b, t])
    }

    /// Number of pinned weight buffers (diagnostics).
    pub fn n_weight_buffers(&self) -> usize {
        self.weight_bufs.len()
    }
}

/// Pack a batch of token windows into the flat i32 layout `Session`
/// expects, padding with repeats of the last window if short.
pub fn pack_batch(windows: &[Vec<u32>], batch: usize, width: usize) -> Result<Vec<i32>> {
    ensure!(!windows.is_empty(), "empty batch");
    let mut out = Vec::with_capacity(batch * width);
    for i in 0..batch {
        let w = windows.get(i).unwrap_or_else(|| windows.last().expect("batch checked non-empty"));
        ensure!(w.len() == width, "window width {} != {width}", w.len());
        out.extend(w.iter().map(|&t| t as i32));
    }
    Ok(out)
}

/// The sliding-window view of a sequence: the *last* `width` tokens
/// (the most recent context).  Single source of the window semantics
/// shared by the XLA decode loop (`pack_decode_windows`,
/// `coordinator::serve::decode_batch`) and the native KV-cached engine
/// (`infer`): both keep the tail, never the head.
pub fn recent_window(s: &[u32], width: usize) -> &[u32] {
    &s[s.len().saturating_sub(width)..]
}

/// Pack decode-loop sliding windows into the flat `[batch, width]` i32
/// layout `Session::logits` expects.  Each row holds the *last*
/// `width` tokens of its sequence (the most recent context); short
/// rows are right-padded by repeating their own last token, and unused
/// batch slots stay zero — the AOT executable's shape is fixed, so
/// finished or absent rows still occupy a slot but their logits are
/// simply never read.  Returns the packed tokens plus each row's
/// last-content position (where its next-token logits live).
pub fn pack_decode_windows(
    seqs: &[Vec<u32>],
    batch: usize,
    width: usize,
) -> Result<(Vec<i32>, Vec<usize>)> {
    ensure!(seqs.len() <= batch, "batch too large: {} > {batch}", seqs.len());
    let mut toks = vec![0i32; batch * width];
    let mut pos = vec![0usize; seqs.len()];
    for (r, s) in seqs.iter().enumerate() {
        ensure!(!s.is_empty(), "empty sequence in row {r}");
        let window = recent_window(s, width);
        for (i, &tok) in window.iter().enumerate() {
            toks[r * width + i] = tok as i32;
        }
        for i in window.len()..width {
            toks[r * width + i] = *window.last().expect("windows checked non-empty") as i32;
        }
        pos[r] = window.len() - 1;
    }
    Ok((toks, pos))
}

/// Convenience: read back the teacher weights named in the manifest.
pub fn load_teacher(rt: &Runtime, tag: &str) -> Result<Weights> {
    let info = rt.manifest.teacher(tag)?;
    let cfg = rt.manifest.size_config(&info.size)?;
    let dbw = crate::model::Dbw::load(rt.artifacts_dir.join(&info.dbw))
        .with_context(|| format!("loading teacher {tag}"))?;
    Weights::from_dbw(&dbw, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_batch_pads_with_last() {
        let w = vec![vec![1u32, 2], vec![3, 4]];
        let packed = pack_batch(&w, 4, 2).unwrap();
        assert_eq!(packed, vec![1, 2, 3, 4, 3, 4, 3, 4]);
    }

    #[test]
    fn pack_batch_rejects_bad_width() {
        assert!(pack_batch(&[vec![1u32, 2, 3]], 1, 2).is_err());
        assert!(pack_batch(&[], 1, 2).is_err());
    }

    #[test]
    fn decode_windows_keep_recent_and_pad() {
        let seqs = vec![vec![9u32, 8, 7, 6], vec![5u32]];
        let (toks, pos) = pack_decode_windows(&seqs, 3, 3).unwrap();
        // row 0: last 3 tokens of a long sequence
        assert_eq!(&toks[0..3], &[8, 7, 6]);
        assert_eq!(pos[0], 2);
        // row 1: short row right-padded with its own last token
        assert_eq!(&toks[3..6], &[5, 5, 5]);
        assert_eq!(pos[1], 0);
        // unused slot stays zero
        assert_eq!(&toks[6..9], &[0, 0, 0]);
    }

    #[test]
    fn decode_windows_reject_bad_rows() {
        assert!(pack_decode_windows(&[vec![1u32], vec![2]], 1, 4).is_err());
        assert!(pack_decode_windows(&[vec![]], 1, 4).is_err());
    }

    #[test]
    fn recent_window_keeps_tail() {
        let s = [1u32, 2, 3, 4, 5];
        assert_eq!(recent_window(&s, 3), &[3, 4, 5]);
        assert_eq!(recent_window(&s, 5), &s);
        assert_eq!(recent_window(&s, 9), &s);
        assert_eq!(recent_window(&s, 0), &[] as &[u32]);
    }
}
