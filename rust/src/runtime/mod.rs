//! PJRT runtime: loads the AOT-compiled HLO-text artifacts the python
//! layer produced and executes them on the CPU PJRT client.
//!
//! HLO TEXT is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md and
//! DESIGN.md §3).
//!
//! The `Runtime` owns one `PjRtClient` plus a compiled-executable cache;
//! `Session` pins a model's weights as device buffers so the hot loop
//! only uploads the per-call inputs (tokens / teacher logits).

pub mod manifest;
pub mod session;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use manifest::Manifest;
pub use session::Session;

/// Handle to the PJRT client + executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU runtime over an artifacts directory.
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, artifacts_dir: dir, manifest, executables: HashMap::new() })
    }

    /// Load + compile (cached) an executable by manifest key, e.g.
    /// `fwd_nll_S`.
    pub fn executable(&mut self, key: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(key) {
            let file = self.manifest.executable_file(key)?;
            let path = self.artifacts_dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))?;
            self.executables.insert(key.to_string(), exe);
        }
        Ok(&self.executables[key])
    }

    /// Execute with literal inputs; decomposes the 1-tuple/tuple output.
    pub fn run(&mut self, key: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(key)?;
        let result = exe.execute::<xla::Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Upload a literal to the device (for `Session` weight pinning).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }
}

/// Build an f32 literal of the given dims.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "literal shape mismatch");
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal (token ids).
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "literal shape mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Extract an f32 vec from a literal.
pub fn lit_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
