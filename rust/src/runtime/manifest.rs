//! Typed view over `artifacts/manifest.json` — the contract between the
//! python compile path and this runtime.

use std::path::Path;

use anyhow::{Context, Result};

use crate::model::ModelConfig;
use crate::util::Json;

pub struct Manifest {
    pub json: Json,
}

/// Metadata for one teacher checkpoint.
#[derive(Clone, Debug)]
pub struct TeacherInfo {
    pub tag: String,
    pub size: String,
    pub dbw: String,
    pub calib: String,
    pub calib_seqs: usize,
    pub eval_ppl_wiki: f64,
    pub eval_ppl_web: f64,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?} (run `make artifacts`)", path.as_ref()))?;
        Ok(Manifest { json: Json::parse(&text)? })
    }

    pub fn group_size(&self) -> usize {
        self.json.get("group_size").and_then(|j| j.as_usize()).unwrap_or(64)
    }

    pub fn vocab(&self) -> usize {
        self.json.get("vocab").and_then(|j| j.as_usize()).unwrap_or(512)
    }

    pub fn seq_len(&self) -> usize {
        self.json.get("seq_len").and_then(|j| j.as_usize()).unwrap_or(64)
    }

    pub fn logits_batch(&self) -> usize {
        self.json.get("logits_batch").and_then(|j| j.as_usize()).unwrap_or(4)
    }

    pub fn nll_batch(&self) -> usize {
        self.json.get("nll_batch").and_then(|j| j.as_usize()).unwrap_or(8)
    }

    pub fn dad_gamma(&self) -> f64 {
        self.json
            .get("dad")
            .and_then(|d| d.get("gamma"))
            .and_then(|g| g.as_f64())
            .unwrap_or(0.1)
    }

    pub fn dad_lambda(&self) -> f64 {
        self.json
            .get("dad")
            .and_then(|d| d.get("lambda"))
            .and_then(|g| g.as_f64())
            .unwrap_or(0.1)
    }

    /// Model config for an architecture size key ("S".."XL").
    pub fn size_config(&self, size: &str) -> Result<ModelConfig> {
        ModelConfig::from_json(self.json.get("sizes")?.get(size)?)
    }

    /// All size keys, ascending by parameter count.
    pub fn sizes(&self) -> Result<Vec<String>> {
        let obj = self.json.get("sizes")?.as_obj()?;
        let mut v: Vec<(usize, String)> = obj
            .iter()
            .map(|(k, j)| {
                let p = j.get("n_params").and_then(|n| n.as_usize()).unwrap_or(0);
                (p, k.clone())
            })
            .collect();
        v.sort();
        Ok(v.into_iter().map(|(_, k)| k).collect())
    }

    /// Teacher tags in manifest order (v1 family then v2).
    pub fn teacher_tags(&self) -> Result<Vec<String>> {
        Ok(self.json.get("teachers")?.as_obj()?.keys().cloned().collect())
    }

    pub fn teacher(&self, tag: &str) -> Result<TeacherInfo> {
        let t = self.json.get("teachers")?.get(tag)?;
        let ppl = t.get("eval_ppl")?;
        Ok(TeacherInfo {
            tag: tag.to_string(),
            size: t.get("size")?.as_str()?.to_string(),
            dbw: t.get("dbw")?.as_str()?.to_string(),
            calib: t.get("calib")?.as_str()?.to_string(),
            calib_seqs: t.get("calib_seqs")?.as_usize()?,
            eval_ppl_wiki: ppl.get("wiki")?.as_f64()?,
            eval_ppl_web: ppl.get("web")?.as_f64()?,
        })
    }

    /// HLO file for an executable key.
    pub fn executable_file(&self, key: &str) -> Result<String> {
        Ok(self
            .json
            .get("executables")?
            .get(key)?
            .get("file")?
            .as_str()?
            .to_string())
    }

    /// Ordered HLO parameter names of a fwd executable.
    pub fn executable_params(&self, key: &str) -> Result<Vec<String>> {
        self.json.get("executables")?.get(key)?.str_list("params")
    }

    /// Ordered names for a dad_step executable: (alphas, planes, frozen).
    pub fn dad_step_order(&self, key: &str) -> Result<(Vec<String>, Vec<String>, Vec<String>)> {
        let e = self.json.get("executables")?.get(key)?;
        Ok((e.str_list("alphas")?, e.str_list("planes")?, e.str_list("frozen")?))
    }

    /// Ordered names for fwd_fdb executables: (frozen, quads).
    pub fn fdb_order(&self, key: &str) -> Result<(Vec<String>, Vec<String>)> {
        let e = self.json.get("executables")?.get(key)?;
        Ok((e.str_list("frozen")?, e.str_list("quads")?))
    }

    /// Corpus eval-stream file name.
    pub fn corpus_eval_file(&self, name: &str) -> Result<String> {
        Ok(self
            .json
            .get("corpora")?
            .get(name)?
            .get("eval_file")?
            .as_str()?
            .to_string())
    }

    pub fn corpus_names(&self) -> Result<Vec<String>> {
        Ok(self.json.get("corpora")?.as_obj()?.keys().cloned().collect())
    }

    pub fn corpus_ppl_floor(&self, name: &str) -> Result<f64> {
        self.json.get("corpora")?.get(name)?.get("ppl_floor")?.as_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(content: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dbllm_manifest_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("m{}.json", content.len()));
        std::fs::write(&p, content).unwrap();
        p
    }

    #[test]
    fn parses_minimal_manifest() {
        let p = write_tmp(
            r#"{"group_size": 64, "vocab": 512, "seq_len": 64,
                "logits_batch": 4, "nll_batch": 8,
                "dad": {"gamma": 0.1, "lambda": 0.1},
                "sizes": {"S": {"name":"S","d_model":64,"n_layers":2,
                  "n_heads":4,"d_ff":192,"vocab":512,"seq_len":64,
                  "rope_theta":10000.0,"rmsnorm_eps":1e-5}},
                "teachers": {"S": {"size":"S","dbw":"teacher_S.dbw",
                  "calib":"calib_S.tok","calib_seqs":512,
                  "eval_ppl":{"wiki":21.0,"web":45.0}}},
                "executables": {"fwd_nll_S": {"file":"fwd_nll_S.hlo.txt",
                  "params":["tok_emb","head"]}},
                "corpora": {"wiki": {"eval_file":"corpus_wiki_eval.tok",
                  "ppl_floor": 19.2}}}"#,
        );
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.group_size(), 64);
        assert_eq!(m.sizes().unwrap(), vec!["S"]);
        let t = m.teacher("S").unwrap();
        assert_eq!(t.dbw, "teacher_S.dbw");
        assert!((t.eval_ppl_wiki - 21.0).abs() < 1e-12);
        assert_eq!(m.executable_file("fwd_nll_S").unwrap(), "fwd_nll_S.hlo.txt");
        assert_eq!(m.executable_params("fwd_nll_S").unwrap(), vec!["tok_emb", "head"]);
        assert!((m.corpus_ppl_floor("wiki").unwrap() - 19.2).abs() < 1e-12);
    }

    #[test]
    fn missing_keys_error() {
        let p = write_tmp(r#"{"sizes": {}}"#);
        let m = Manifest::load(&p).unwrap();
        assert!(m.teacher("S").is_err());
        assert!(m.executable_file("nope").is_err());
        // defaults still work
        assert_eq!(m.group_size(), 64);
    }
}
