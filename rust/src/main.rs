//! db-llm — the Layer-3 CLI.
//!
//! Subcommands:
//!   info                         manifest / teacher / corpus summary
//!   quantize  --teacher S --method dbllm [--out w.dbw]
//!   eval      --teacher S --method dbllm [--windows N]
//!   table     --id 1|2|3|4|5|6|7 [--windows N] [--teachers S,M]
//!   figure    --id 1|3|4|6|7
//!   serve     --teacher S [--method dbllm] [--addr 127.0.0.1:7878]
//!             [--backend native|xla] [--workers 2] [--max-batch 4]
//!             [--linger-ms 20] [--queue-cap 1024] [--window T]
//!             [--slots 4] [--timeout-ms N] [--no-refill]
//!             [--prefix-cache-mb 64] [--kv-pool-mb 0]
//!             [--speculate-k 0] [--draft dbllm]
//!             [--metrics-interval-ms 10000]
//!             [--read-timeout-ms N] [--idle-timeout-ms N]
//!             [--max-line-bytes N] [--max-respawns N]
//!   client    --addr 127.0.0.1:7878 --prompt 1,2,3 --max-tokens 8
//!             [--temperature 0.7] [--stop 0] [--timeout-ms N]
//!             [--retries 3]
//!             (or --stats to fetch the live metrics/Prometheus line)
//!
//! Argument parsing is hand-rolled (offline build, no clap); every flag
//! is `--name value`.

use std::collections::BTreeMap;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use db_llm::coordinator::batcher::BatchPolicy;
use db_llm::coordinator::metrics::Metrics;
use db_llm::coordinator::scheduler::{
    serve_continuous_with, SchedulerConfig, DEFAULT_MAX_RESPAWNS,
};
use db_llm::coordinator::serve::{serve_with, ConnConfig, Engine, EngineWorker};
use db_llm::data::TokenStream;
use db_llm::infer::{NativeEngine, PrefixCache, SpecDecoder};
use db_llm::eval::ppl::perplexity;
use db_llm::eval::tables::{self, Method, TableOpts};
use db_llm::runtime::{Runtime, Session};

fn parse_flags(args: &[String]) -> BTreeMap<String, String> {
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    flags
}

fn method_from_str(s: &str) -> Result<Method> {
    Ok(match s.to_lowercase().as_str() {
        "fp16" | "fp" => Method::Fp16,
        "rtn2" | "rtn-w2" | "rtn" => Method::RtnW2,
        "rtn3" | "rtn-w3" => Method::RtnW3,
        "awq2" | "awq" => Method::AwqW2,
        "awq3" => Method::AwqW3,
        "gptq" | "gptq2" => Method::GptqW2,
        "omniquant" | "omni" => Method::OmniW2,
        "pbllm" | "pb-llm" => Method::PbLlm,
        "dbllm" | "db-llm" | "fdb" => Method::DbLlm,
        "dbllm-nodad" => Method::DbLlmNoDad,
        other => bail!("unknown method {other}"),
    })
}

fn dad_from_flags(flags: &BTreeMap<String, String>) -> Option<db_llm::coordinator::DadConfig> {
    let mut cfg = db_llm::coordinator::DadConfig::default();
    let mut touched = false;
    if let Some(v) = flags.get("dad-lr") {
        cfg.lr = v.parse().unwrap_or(cfg.lr);
        touched = true;
    }
    if let Some(v) = flags.get("dad-epochs") {
        cfg.epochs = v.parse().unwrap_or(cfg.epochs);
        touched = true;
    }
    if let Some(v) = flags.get("dad-resplit") {
        cfg.resplit = v != "false";
        touched = true;
    }
    if let Some(v) = flags.get("dad-gamma") {
        cfg.gamma = v.parse().unwrap_or(cfg.gamma);
        touched = true;
    }
    touched.then_some(cfg)
}

fn opts_from_flags(flags: &BTreeMap<String, String>) -> TableOpts {
    let mut opts = TableOpts::default();
    if let Some(w) = flags.get("windows") {
        opts.windows = w.parse().unwrap_or(opts.windows);
    }
    if let Some(d) = flags.get("dad-batches") {
        opts.dad_batches = d.parse().unwrap_or(opts.dad_batches);
    }
    if let Some(t) = flags.get("teachers") {
        opts.teachers = t.split(',').map(str::to_string).collect();
    }
    if let Some(z) = flags.get("zs-items") {
        opts.zs_items = z.parse().unwrap_or(opts.zs_items);
    }
    if let Some(o) = flags.get("out-dir") {
        opts.out_dir = o.into();
    }
    if let Some(c) = flags.get("calib") {
        opts.calib_override = Some(c.into());
    }
    if let Some(g) = flags.get("group") {
        opts.group_override = g.parse().ok();
    }
    opts
}

fn artifacts_dir(flags: &BTreeMap<String, String>) -> String {
    flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".to_string())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        print_help();
        return Ok(());
    };
    let flags = parse_flags(&args[1..]);

    match cmd.as_str() {
        "info" => cmd_info(&flags),
        "quantize" => cmd_quantize(&flags),
        "eval" => cmd_eval(&flags),
        "table" => cmd_table(&flags),
        "figure" => cmd_figure(&flags),
        "serve" => cmd_serve(&flags),
        "client" => cmd_client(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other} (try `db-llm help`)"),
    }
}

fn print_help() {
    println!(
        "db-llm — DB-LLM (ACL 2024) reproduction CLI\n\
         \n\
         commands:\n\
           info                              artifacts summary\n\
           quantize --teacher S --method M   quantize + report stats\n\
           eval     --teacher S --method M   perplexity on both corpora\n\
           table    --id N                   regenerate paper table N (1-7)\n\
           figure   --id N                   regenerate paper figure N (1,3,4,6,7)\n\
           serve    --teacher S [--method M] [--addr A] TCP serving demo\n\
                    [--backend native|xla] [--workers N] [--max-batch N]\n\
                    [--linger-ms N] [--queue-cap N] [--window T]\n\
                    [--slots N] [--timeout-ms N] [--no-refill]\n\
                    [--prefix-cache-mb N] [--kv-pool-mb N]\n\
                    [--speculate-k N] [--draft M]\n\
                    [--metrics-interval-ms N]\n\
                    [--read-timeout-ms N] [--idle-timeout-ms N]\n\
                    [--max-line-bytes N] [--max-respawns N]\n\
           client   --addr A --prompt 1,2,3 --max-tokens 8\n\
                    [--temperature T] [--stop TOKEN] [--timeout-ms N]\n\
                    [--retries N]  exponential backoff on overload\n\
                    --addr A --stats    fetch live metrics + Prometheus\n\
         \n\
         common flags: --artifacts DIR --windows N --dad-batches N\n\
                       --teachers S,M,L --zs-items N --out-dir results\n\
         methods: fp16 rtn2 rtn3 gptq awq2 awq3 omniquant pbllm dbllm"
    );
}

fn cmd_info(flags: &BTreeMap<String, String>) -> Result<()> {
    let rt = Runtime::open(artifacts_dir(flags))?;
    let m = &rt.manifest;
    println!("artifacts: {:?}", rt.artifacts_dir);
    println!("group_size={} vocab={} seq_len={}", m.group_size(), m.vocab(), m.seq_len());
    println!("\nsizes:");
    for s in m.sizes()? {
        let c = m.size_config(&s)?;
        println!(
            "  {s:<4} d={} L={} h={} ff={} params={}",
            c.d_model,
            c.n_layers,
            c.n_heads,
            c.d_ff,
            db_llm::util::eng(c.n_params() as f64)
        );
    }
    println!("\nteachers:");
    for tag in m.teacher_tags()? {
        let t = m.teacher(&tag)?;
        println!(
            "  {tag:<4} size={} ppl(wiki)={:.2} ppl(web)={:.2}",
            t.size, t.eval_ppl_wiki, t.eval_ppl_web
        );
    }
    println!("\ncorpora:");
    for c in m.corpus_names()? {
        println!("  {c:<5} ppl floor={:.2}", m.corpus_ppl_floor(&c)?);
    }
    Ok(())
}

fn cmd_quantize(flags: &BTreeMap<String, String>) -> Result<()> {
    let mut rt = Runtime::open(artifacts_dir(flags))?;
    let teacher = flags.get("teacher").context("--teacher required")?.clone();
    let method = method_from_str(flags.get("method").context("--method required")?)?;
    let opts = opts_from_flags(flags);
    let t0 = std::time::Instant::now();
    let student = tables::make_student(&mut rt, &teacher, method, &opts, dad_from_flags(flags))?;
    println!(
        "quantized {teacher} with {} in {:.1}s",
        method.label(),
        t0.elapsed().as_secs_f64()
    );
    if !student.fdb_layers.is_empty() {
        let (s1, s2, avg) = db_llm::eval::QuantPipeline::fdb_sparsity(&student.fdb_layers);
        println!(
            "FDB sparsity: b1 {:.1}% b2 {:.1}% avg {:.1}%",
            s1 * 100.0,
            s2 * 100.0,
            avg * 100.0
        );
        let mut eff = 0.0;
        for l in student.fdb_layers.values() {
            eff += db_llm::codec::effective_bits(l).total;
        }
        println!(
            "effective bits/weight after coding: {:.3}",
            eff / student.fdb_layers.len() as f64
        );
    }
    if let Some((first, last)) = student.dad_trend {
        println!("DAD loss: {first:.4} -> {last:.4}");
    }
    if let Some(out) = flags.get("out") {
        let mut tensors = std::collections::BTreeMap::new();
        for (name, m) in &student.weights.mats {
            tensors.insert(name.clone(), (vec![m.rows, m.cols], m.data.clone()));
        }
        for (name, v) in &student.weights.vecs {
            tensors.insert(name.clone(), (vec![v.len()], v.clone()));
        }
        let dbw = db_llm::model::Dbw {
            config: db_llm::util::Json::obj(vec![
                ("teacher", db_llm::util::Json::str(teacher.clone())),
                ("method", db_llm::util::Json::str(method.label())),
            ]),
            tensors,
        };
        dbw.save(out)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_eval(flags: &BTreeMap<String, String>) -> Result<()> {
    let mut rt = Runtime::open(artifacts_dir(flags))?;
    let teacher = flags.get("teacher").context("--teacher required")?.clone();
    let method = method_from_str(flags.get("method").context("--method required")?)?;
    let opts = opts_from_flags(flags);
    let student = tables::make_student(&mut rt, &teacher, method, &opts, dad_from_flags(flags))?;
    let session = Session::new(&rt, &student.weights)?;
    for name in rt.manifest.corpus_names()? {
        let f = rt.manifest.corpus_eval_file(&name)?;
        let stream = TokenStream::load(rt.artifacts_dir.join(f))?;
        let ppl = perplexity(&mut rt, &session, &stream, opts.windows)?;
        println!("{teacher} {} {name}: ppl {ppl:.3}", method.label());
    }
    Ok(())
}

fn cmd_table(flags: &BTreeMap<String, String>) -> Result<()> {
    let mut rt = Runtime::open(artifacts_dir(flags))?;
    let opts = opts_from_flags(flags);
    let id = flags.get("id").context("--id required (1-7)")?.as_str();
    match id {
        "1" => tables::table_ppl(&mut rt, &opts, false).map(drop),
        "2" => tables::table_ppl(&mut rt, &opts, true).map(drop),
        "3" => tables::table3(&mut rt, &opts).map(drop),
        "4" => tables::table4(&mut rt, &opts).map(drop),
        "5" => tables::table_zeroshot(&mut rt, &opts, false).map(drop),
        "6" => tables::table6(&mut rt, &opts).map(drop),
        "7" => tables::table_zeroshot(&mut rt, &opts, true).map(drop),
        other => bail!("unknown table {other}"),
    }
}

fn cmd_figure(flags: &BTreeMap<String, String>) -> Result<()> {
    let mut rt = Runtime::open(artifacts_dir(flags))?;
    let opts = opts_from_flags(flags);
    let id = flags.get("id").context("--id required (1,3,4,6,7)")?.as_str();
    match id {
        "1" => tables::figure1(&mut rt, &opts).map(drop),
        "3" => tables::figure3(&mut rt, &opts).map(drop),
        "4" => tables::figure4(&mut rt, &opts).map(drop),
        "6" => tables::figure6(&mut rt, &opts).map(drop),
        "7" => tables::figure7(&mut rt, &opts).map(drop),
        other => bail!("unknown figure {other} (2 and 5 are method illustrations)"),
    }
}

fn cmd_serve(flags: &BTreeMap<String, String>) -> Result<()> {
    let dir = artifacts_dir(flags);
    let teacher = flags.get("teacher").context("--teacher required")?.clone();
    let method = method_from_str(flags.get("method").map(String::as_str).unwrap_or("fp16"))?;
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7878".to_string());
    let workers: usize = flags.get("workers").map(|s| s.parse()).transpose()?.unwrap_or(1).max(1);
    let backend = flags.get("backend").cloned().unwrap_or_else(|| "xla".to_string());
    let mut policy = BatchPolicy::default();
    if let Some(v) = flags.get("max-batch").map(|s| s.parse()).transpose()? {
        policy.max_batch = v;
    }
    if let Some(v) = flags.get("linger-ms").map(|s| s.parse()).transpose()? {
        policy.linger = std::time::Duration::from_millis(v);
    }
    if let Some(v) = flags.get("queue-cap").map(|s| s.parse()).transpose()? {
        policy.queue_cap = v;
    }
    let window_override: Option<usize> = flags.get("window").map(|s| s.parse()).transpose()?;
    let slots: usize = flags.get("slots").map(|s| s.parse()).transpose()?.unwrap_or(4).max(1);
    let timeout_ms: Option<u64> = flags.get("timeout-ms").map(|s| s.parse()).transpose()?;
    let refill = !flags.contains_key("no-refill");
    // cross-request prefix sharing budget (MiB of cached K/V blocks,
    // shared across every scheduler worker); 0 disables sharing
    let prefix_cache_mb: usize =
        flags.get("prefix-cache-mb").map(|s| s.parse()).transpose()?.unwrap_or(64);
    // soft per-worker KV block-pool budget (MiB); admission defers new
    // requests once the pool cannot reserve a prompt's worst-case block
    // count, and 0 leaves the pool unbounded
    let kv_pool_mb: usize =
        flags.get("kv-pool-mb").map(|s| s.parse()).transpose()?.unwrap_or(0);
    // speculative decoding: draft length per tick for the 2-bit FDB
    // student (0 keeps the plain dense/FDB NativeEngine path)
    let speculate_k: usize =
        flags.get("speculate-k").map(|s| s.parse()).transpose()?.unwrap_or(0);
    // quantization method for the speculative draft student (the
    // verifying teacher is always the dense fp16 model)
    let draft_method = method_from_str(flags.get("draft").map(String::as_str).unwrap_or("dbllm"))?;
    if speculate_k > 0 && flags.contains_key("prefix-cache-mb") && prefix_cache_mb > 0 {
        bail!(
            "--prefix-cache-mb cannot be combined with --speculate-k: the speculative \
             decoder owns paired teacher+student KV caches and has no prefix-cache \
             integration yet (drop one of the flags, or pass --prefix-cache-mb 0)"
        );
    }
    if speculate_k == 0 && flags.contains_key("draft") {
        eprintln!("warning: --draft has no effect without --speculate-k N (N >= 1)");
    }
    if speculate_k > 0 && flags.contains_key("method") {
        eprintln!("warning: --method is ignored with --speculate-k (the verify engine is \
                   always the dense teacher; pick the draft quantizer with --draft)");
    }
    // periodic snapshot logger cadence; 0 disables the log line (the
    // wire-level {"cmd":"stats"} surface stays available either way)
    let metrics_interval_ms: u64 =
        flags.get("metrics-interval-ms").map(|s| s.parse()).transpose()?.unwrap_or(10_000);
    // connection hardening: socket timeouts, request-line byte cap,
    // idle reaper; 0 means "off" for the timeout knobs
    let mut conn = ConnConfig::default();
    if let Some(ms) = flags.get("read-timeout-ms").map(|s| s.parse::<u64>()).transpose()? {
        conn.read_timeout = (ms > 0).then(|| std::time::Duration::from_millis(ms));
        conn.write_timeout = conn.read_timeout;
    }
    if let Some(ms) = flags.get("idle-timeout-ms").map(|s| s.parse::<u64>()).transpose()? {
        conn.idle_timeout = (ms > 0).then(|| std::time::Duration::from_millis(ms));
        // the reaper needs a finite read timeout to poll on; give it one
        if conn.idle_timeout.is_some() && conn.read_timeout.is_none() {
            conn.read_timeout = Some(std::time::Duration::from_millis(1_000));
        }
    }
    if let Some(b) = flags.get("max-line-bytes").map(|s| s.parse::<usize>()).transpose()? {
        conn.max_line_bytes = b.max(1);
    }
    // supervisor budget: how many times a panicking scheduler worker is
    // respawned before it is retired for good
    let max_respawns: u64 =
        flags.get("max-respawns").map(|s| s.parse()).transpose()?.unwrap_or(DEFAULT_MAX_RESPAWNS);
    let opts = opts_from_flags(flags);
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));

    if backend == "xla" {
        if window_override.is_some() {
            eprintln!("warning: --window only applies to --backend native; ignored (the xla \
                       executable's window is fixed at the manifest seq_len)");
        }
        if timeout_ms.is_some() || flags.contains_key("slots") || !refill {
            eprintln!("warning: --slots/--timeout-ms/--no-refill only apply to the \
                       continuous scheduler (--backend native); the xla path keeps the \
                       static batcher and ignores them");
        }
        if flags.contains_key("prefix-cache-mb") {
            eprintln!("warning: --prefix-cache-mb only applies to --backend native \
                       (the xla executable recomputes the full window every step and \
                       has no KV cache to share); ignored");
        }
        if flags.contains_key("kv-pool-mb") {
            eprintln!("warning: --kv-pool-mb only applies to --backend native (the xla \
                       executable has no KV block pool to budget); ignored");
        }
        if flags.contains_key("max-respawns") {
            eprintln!("warning: --max-respawns only applies to the supervised continuous \
                       scheduler (--backend native); the xla worker pool ignores it");
        }
        if speculate_k > 0 {
            eprintln!("warning: --speculate-k only applies to --backend native (the xla \
                       executable recomputes the full window per step and has no \
                       incremental KV path to draft against); ignored");
        }
    } else if flags.contains_key("max-batch") || flags.contains_key("linger-ms") {
        eprintln!("warning: --max-batch/--linger-ms only apply to the static batcher \
                   (--backend xla); the continuous scheduler admits per slot (--slots) \
                   and ignores them");
    }
    let m2 = metrics.clone();
    let local = match backend.as_str() {
        // the AOT fwd_logits executable: full-window recompute per
        // step, static batches under the dynamic batcher
        "xla" => serve_with(
            move || {
                let mut rt = Runtime::open(&dir)?;
                let student = tables::make_student(&mut rt, &teacher, method, &opts, None)?;
                let vocab = rt.manifest.vocab();
                let session = Session::new(&rt, &student.weights)?;
                eprintln!("engine ready ({} weights pinned)", session.n_weight_buffers());
                Ok(EngineWorker { rt, engine: Engine::new(session, vocab, 42) })
            },
            &addr,
            policy,
            workers,
            m2,
            running.clone(),
            conn.clone(),
        )?,
        // speculative serving: a 2-bit FDB draft student proposes k
        // tokens per tick and the dense teacher verifies them in one
        // batched forward; greedy streams stay bit-identical to
        // teacher-only decode while accepted drafts skip dense forwards
        "native" if speculate_k > 0 => serve_continuous_with(
            move || {
                let mut rt = Runtime::open(&dir)?;
                let dense = tables::make_student(&mut rt, &teacher, Method::Fp16, &opts, None)?;
                let draft = tables::make_student(&mut rt, &teacher, draft_method, &opts, None)?;
                let window = window_override.unwrap_or_else(|| rt.manifest.seq_len());
                let engine = SpecDecoder::new(
                    dense.weights,
                    draft.weights,
                    &draft.fdb_layers,
                    window,
                    speculate_k,
                )
                .with_slots(slots)
                .with_kv_pool_bytes(kv_pool_mb << 20);
                eprintln!(
                    "speculative engine ready (window {window}, {slots} slots, k={speculate_k} \
                     {} draft with {} FDB-compiled linears, KV pool {})",
                    draft_method.label(),
                    engine.n_fdb_ops(),
                    if kv_pool_mb > 0 {
                        format!("{kv_pool_mb} MiB soft budget")
                    } else {
                        "unbounded".to_string()
                    },
                );
                Ok(engine)
            },
            &addr,
            policy.queue_cap,
            SchedulerConfig {
                slots,
                refill,
                default_timeout_ms: timeout_ms,
                seed: 42,
                trace: true,
                ..SchedulerConfig::default()
            },
            workers,
            m2,
            running.clone(),
            conn.clone(),
            max_respawns,
        )?,
        // the KV-cached incremental engine behind the iteration-level
        // continuous-batching scheduler: finished slots refill
        // mid-flight, per-request deadlines get partial-result replies,
        // and prompts share prefilled K/V prefixes across requests and
        // workers through one PrefixCache
        "native" => {
            let prefix = (prefix_cache_mb > 0).then(|| {
                Arc::new(std::sync::Mutex::new(PrefixCache::new(
                    db_llm::infer::DEFAULT_BLOCK_TOKENS,
                    prefix_cache_mb << 20,
                )))
            });
            serve_continuous_with(
                move || {
                    let mut rt = Runtime::open(&dir)?;
                    let student = tables::make_student(&mut rt, &teacher, method, &opts, None)?;
                    let window = window_override.unwrap_or_else(|| rt.manifest.seq_len());
                    let mut engine =
                        NativeEngine::new(student.weights, &student.fdb_layers, window, 42)
                            .with_slots(slots)
                            .with_kv_pool_bytes(kv_pool_mb << 20);
                    if let Some(pc) = &prefix {
                        engine = engine.with_prefix_cache(pc.clone());
                    }
                    eprintln!(
                        "native engine ready (window {window}, {slots} slots, {} \
                         FDB-compiled linears, prefix cache {}, KV pool {})",
                        engine.n_fdb_ops(),
                        if prefix_cache_mb > 0 {
                            format!("{prefix_cache_mb} MiB shared")
                        } else {
                            "off".to_string()
                        },
                        if kv_pool_mb > 0 {
                            format!("{kv_pool_mb} MiB soft budget")
                        } else {
                            "unbounded".to_string()
                        },
                    );
                    Ok(engine)
                },
                &addr,
                policy.queue_cap,
                SchedulerConfig {
                    slots,
                    refill,
                    default_timeout_ms: timeout_ms,
                    seed: 42,
                    // tracing is production-safe now that the event and
                    // span logs are bounded rings (default capacity /
                    // 1-in-64 profiling sample from Default)
                    trace: true,
                    ..SchedulerConfig::default()
                },
                workers,
                m2,
                running.clone(),
                conn.clone(),
                max_respawns,
            )?
        }
        other => bail!("unknown backend {other} (expected native|xla)"),
    };
    println!(
        "serving on {local} with {workers} {backend} worker(s) — protocol: one JSON per line"
    );
    println!(
        "  {{\"prompt\": [1,2,3], \"max_tokens\": 8, \"temperature\": 0.7, \"stop\": 0, \
         \"timeout_ms\": 500}}"
    );
    println!("  {{\"cmd\": \"stats\"}}  — live metrics JSON + Prometheus text");
    if metrics_interval_ms == 0 {
        // logging disabled: park the main thread, serve until killed
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    loop {
        std::thread::sleep(std::time::Duration::from_millis(metrics_interval_ms));
        println!("[metrics] {}", metrics.snapshot());
    }
}

/// Pull the server's backoff hint off an overload-shed reply line
/// (compact JSON: `"retry_after_ms":N`).
fn parse_retry_after_ms(line: &str) -> Option<u64> {
    let rest = line.split("\"retry_after_ms\":").nth(1)?;
    let digits: String = rest.chars().take_while(char::is_ascii_digit).collect();
    digits.parse().ok()
}

/// One connect → request → reply round trip.
fn client_round_trip(addr: &str, req: &str) -> Result<String> {
    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(addr)?;
    writeln!(stream, "{req}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    if line.is_empty() {
        bail!("server closed the connection without a reply");
    }
    Ok(line.trim().to_string())
}

fn cmd_client(flags: &BTreeMap<String, String>) -> Result<()> {
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7878".to_string());
    let req = if flags.contains_key("stats") {
        // control line: fetch the live metrics JSON + Prometheus text
        "{\"cmd\": \"stats\"}".to_string()
    } else {
        let prompt = flags.get("prompt").context("--prompt 1,2,3 required (or --stats)")?;
        let max_tokens: usize =
            flags.get("max-tokens").map(|s| s.parse()).transpose()?.unwrap_or(8);
        let mut req = format!("{{\"prompt\": [{prompt}], \"max_tokens\": {max_tokens}");
        if let Some(t) = flags.get("temperature") {
            let t: f64 = t.parse()?;
            req.push_str(&format!(", \"temperature\": {t}"));
        }
        if let Some(s) = flags.get("stop") {
            let s: usize = s.parse()?;
            req.push_str(&format!(", \"stop\": {s}"));
        }
        if let Some(t) = flags.get("timeout-ms") {
            let t: u64 = t.parse()?;
            req.push_str(&format!(", \"timeout_ms\": {t}"));
        }
        req.push('}');
        req
    };
    // bounded exponential backoff over connect failures and overload
    // sheds; an overload reply's own retry_after_ms hint overrides the
    // doubling schedule when it is longer
    let retries: u32 = flags.get("retries").map(|s| s.parse()).transpose()?.unwrap_or(3);
    let mut backoff_ms: u64 = 100;
    for attempt in 0..=retries {
        match client_round_trip(&addr, &req) {
            Ok(line) => {
                let shed_hint = parse_retry_after_ms(&line);
                if shed_hint.is_none() || attempt == retries {
                    println!("{line}");
                    return Ok(());
                }
                let wait = backoff_ms.max(shed_hint.unwrap_or(0)).min(5_000);
                eprintln!("overloaded (attempt {}/{}), retrying in {wait}ms", attempt + 1, retries);
                std::thread::sleep(std::time::Duration::from_millis(wait));
            }
            Err(e) if attempt < retries => {
                let wait = backoff_ms.min(5_000);
                eprintln!("connect failed: {e} (attempt {}/{}), retrying in {wait}ms",
                          attempt + 1, retries);
                std::thread::sleep(std::time::Duration::from_millis(wait));
            }
            Err(e) => return Err(e),
        }
        backoff_ms = backoff_ms.saturating_mul(2);
    }
    unreachable!("retry loop always returns on its final attempt");
}
