//! Dense f32 tensor substrate: row-major matrices + the small amount of
//! linear algebra the quantization engine needs (blocked matmul,
//! Cholesky factorization/inversion for GPTQ's Hessian path, stats).
//!
//! This is deliberately minimal — the heavy model math runs inside the
//! AOT-compiled XLA executables; this substrate exists for the
//! quantizers, calibration statistics, and the native cross-check
//! forward (`model::native`).

pub mod linalg;
pub mod matrix;

pub use matrix::Matrix;
